//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build environment is hermetic (no crates.io), so this in-repo shim
//! provides exactly the surface catquant uses: [`Error`], [`Result`],
//! [`Context`] on `Result`/`Option`, and the `anyhow!` / `bail!` /
//! `ensure!` macros. Like the real crate, [`Error`] deliberately does
//! *not* implement `std::error::Error` — that is what allows both the
//! blanket `From<E: std::error::Error>` conversion (for `?`) and a
//! `Context` impl on `Result<T, Error>` to coexist.
//!
//! Unsupported (unused in this repo): downcasting, backtraces.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error: a cause chain of human-readable messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause-chain messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost (most recently attached) message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Attach context to the error arm of a `Result` (or a `None`).
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*).into())
    };
}

/// Return early with an error unless the condition holds. The bare form
/// (no message) stringifies the condition, like the real crate.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err(
                $crate::Error::msg(::std::concat!(
                    "Condition failed: `",
                    ::std::stringify!($cond),
                    "`"
                ))
                .into(),
            );
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing thing"));
    }

    #[test]
    fn context_wraps_outermost_first() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading config").unwrap_err();
        assert_eq!(e.root_message(), "loading config");
        assert_eq!(e.chain().count(), 2);
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u32> = None;
        assert!(v.context("empty").is_err());
        fn f(x: u32) -> Result<u32> {
            ensure!(x > 2, "x too small: {x}");
            if x > 100 {
                bail!("x too big: {x}");
            }
            Ok(x)
        }
        assert!(f(1).is_err());
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(200).is_err());
        // Bare (message-less) ensure stringifies the condition.
        fn g(x: u32) -> Result<u32> {
            ensure!(x % 2 == 0);
            Ok(x)
        }
        assert_eq!(g(4).unwrap(), 4);
        let e = g(3).unwrap_err();
        assert!(e.to_string().contains("Condition failed"), "{e}");
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn context_on_anyhow_result_chains() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.with_context(|| "outer").unwrap_err();
        let chain: Vec<_> = e.chain().collect();
        assert_eq!(chain, vec!["outer", "inner"]);
    }
}
