//! Offline stub of the `xla` crate surface catquant's PJRT layer uses.
//!
//! The hermetic build has no native XLA/PJRT libraries, so this shim
//! keeps Layer-2 (`catquant::runtime::PjrtEngine` and everything above
//! it) *compiling* while making the runtime state explicit:
//!
//! * [`Literal`] is fully functional in-memory (build/reshape/read-back) —
//!   argument packing and token encoding work and are unit-testable.
//! * [`PjRtClient::cpu`] returns an error, so `PjrtEngine::new` fails
//!   with a clear message and every PJRT caller (parity tests, serving
//!   examples) skips or reports cleanly instead of crashing.
//!
//! Swapping in a real `xla` build is a one-line change in the root
//! `Cargo.toml` — the API here matches the call sites exactly.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Stub error type (also what the real crate's fallible ops return).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT runtime unavailable (catquant was built with the offline xla stub; \
         native-engine paths are unaffected)"
    )))
}

/// Element storage for [`Literal`]. Public only so the sealed
/// [`NativeType`] trait can name it.
#[doc(hidden)]
#[derive(Clone, Debug)]
pub enum Data {
    I32(Vec<i32>),
    F32(Vec<f32>),
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for i32 {}
    impl Sealed for f32 {}
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: sealed::Sealed + Clone {
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for i32 {
    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<i32>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for f32 {
    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<f32>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host-side tensor literal (functional in the stub).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        let n = v.len() as i64;
        Literal { data: T::wrap(v.to_vec()), dims: vec![n] }
    }

    /// Same elements, new shape.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(Error(format!(
                "reshape: {have} elements do not fit shape {dims:?}"
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy the elements out (row-major).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .ok_or_else(|| Error("literal element type mismatch".to_string()))
    }

    /// Flatten a tuple literal — only produced by execution, so
    /// unreachable in the stub.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    fn element_count(&self) -> usize {
        match &self.data {
            Data::I32(v) => v.len(),
            Data::F32(v) => v.len(),
        }
    }
}

/// Stub PJRT client: construction fails, so no downstream op can be
/// reached with a live instance.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_literal")
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Parsed HLO module (opaque in the stub; parsing requires native XLA).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        ))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.dims(), &[6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn i32_literals_work() {
        let l = Literal::vec1(&[7i32, 8, 9]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![7, 8, 9]);
    }

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT runtime unavailable"), "{e}");
    }
}
