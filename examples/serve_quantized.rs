//! Batched serving: FP vs CAT-W4A4 through the coordinator.
//!
//! Spins up the serving loop twice (same prompts, same sampling seed) and
//! reports latency/throughput for both configurations — the W4A4 path
//! pays the online transform cost inside the compiled graph, exactly like
//! a deployment would.
//!
//! ```bash
//! cargo run --release --example serve_quantized -- [model] [n_requests]
//! ```

use catquant::calib::Corpus;
use catquant::coordinator::{
    BatcherCfg, Coordinator, GenEngine, PjrtGenerator, SamplingCfg, ServeMetrics,
};
use catquant::experiments::load_zoo;
use catquant::pipeline::{build_quant_config, PipelineCfg, WeightQuantizer};
use catquant::runtime::{Manifest, PjrtEngine};
use catquant::transforms::TransformKind;
use std::rc::Rc;

fn run_mode(manifest: &Manifest, model: &str, quantized: bool, prompts: Vec<Vec<u8>>) -> ServeMetrics {
    let manifest2 = manifest.clone();
    let model2 = model.to_string();
    let coord = Coordinator::start(
        move || {
            let engine = Rc::new(PjrtEngine::new(manifest2.clone()).expect("engine"));
            let zoo = load_zoo(&manifest2, &model2, 0).expect("zoo");
            let sampling = SamplingCfg { temperature: 0.8, seed: 7 };
            let gen: Box<dyn GenEngine> = if quantized {
                let (qc, _) = build_quant_config(
                    &zoo.model,
                    &zoo.calib,
                    PipelineCfg::w4a4(TransformKind::CatBlock, WeightQuantizer::Rtn, 0),
                );
                Box::new(
                    PjrtGenerator::quant(engine, &model2, &zoo.model.params, &qc, sampling)
                        .expect("gen"),
                )
            } else {
                Box::new(
                    PjrtGenerator::fp(engine, &model2, &zoo.model.params, sampling).expect("gen"),
                )
            };
            gen
        },
        BatcherCfg::default(),
    );
    let rxs: Vec<_> = prompts.into_iter().map(|p| coord.submit(p, 24)).collect();
    for rx in rxs {
        rx.recv().expect("response");
    }
    coord.shutdown()
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).map(|s| s.as_str()).unwrap_or("small").to_string();
    let n: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(16);

    let manifest = Manifest::load(&Manifest::default_dir())?;
    let corpus = Corpus::load(&manifest.corpus_eval)?;
    let prompts = corpus.sample_sequences(n, manifest.prompt_len, 99);

    println!("== FP serving ({model}, {n} requests, 24 new tokens each) ==");
    let fp = run_mode(&manifest, &model, false, prompts.clone());
    println!("{}\n", fp.summary());

    println!("== CAT W4A4 serving (same prompts) ==");
    let q = run_mode(&manifest, &model, true, prompts);
    println!("{}\n", q.summary());

    println!(
        "quantized/fp throughput ratio: {:.2}× (W4A4 pays the online transform; \
         on real int4 hardware the matmuls repay it — see DESIGN.md §Perf)",
        q.throughput_tok_s() / fp.throughput_tok_s().max(1e-9)
    );
    Ok(())
}
