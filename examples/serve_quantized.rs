//! Batched serving: FP vs CAT-W4A4 through the coordinator.
//!
//! Spins up the serving loop twice (same prompts, same sampling seed) and
//! reports latency/throughput for both configurations — the W4A4 path
//! pays the online transform cost inside the compiled graph, exactly like
//! a deployment would.
//!
//! The quantized worker boots **from a saved artifact**: the pipeline
//! runs once up front, `save_artifact` persists it, and the serving
//! factory restores the packed codes with
//! [`PjrtGenerator::quant_from_artifact`] — the production boot path
//! (milliseconds, no calibration/GPTQ at startup).
//!
//! ```bash
//! cargo run --release --example serve_quantized -- [model] [n_requests]
//! ```

use catquant::calib::Corpus;
use catquant::coordinator::{
    BatcherCfg, Coordinator, GenEngine, PjrtGenerator, SamplingCfg, ServeMetrics,
};
use catquant::experiments::{load_model, load_zoo};
use catquant::pipeline::{build_quant_config, QuantPlan, WeightQuantizer};
use catquant::runtime::{save_artifact, Manifest, PjrtEngine};
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

fn run_mode(
    manifest: &Manifest,
    model: &str,
    artifact: Option<PathBuf>,
    prompts: Vec<Vec<u8>>,
) -> ServeMetrics {
    let manifest2 = manifest.clone();
    let model2 = model.to_string();
    let mut coord = Coordinator::start(
        move || {
            let engine = Rc::new(PjrtEngine::new(manifest2.clone()).expect("engine"));
            // Serving workers load weights only — no calibration pass;
            // the quantized state comes from the saved artifact.
            let native = load_model(&manifest2, &model2).expect("model");
            let sampling = SamplingCfg { temperature: 0.8, seed: 7 };
            let gen: Box<dyn GenEngine> = match &artifact {
                Some(dir) => {
                    let t0 = Instant::now();
                    let gen = PjrtGenerator::quant_from_artifact(
                        engine, &model2, &native, dir, sampling,
                    )
                    .expect("gen");
                    eprintln!(
                        "quantized worker booted from artifact in {:.0} ms \
                         (weights + codes, no calibration/pipeline rerun)",
                        t0.elapsed().as_secs_f64() * 1e3
                    );
                    Box::new(gen)
                }
                None => Box::new(
                    PjrtGenerator::fp(engine, &model2, &native.params, sampling).expect("gen"),
                ),
            };
            gen
        },
        BatcherCfg::default(),
    );
    let rxs: Vec<_> = prompts.into_iter().map(|p| coord.submit(p, 24)).collect();
    for rx in rxs {
        rx.recv().expect("response");
    }
    coord.shutdown()
}

/// Build the CAT-W4A4 config once and persist it where the serving
/// factory can boot from.
fn build_artifact(manifest: &Manifest, model: &str, dir: &Path) -> anyhow::Result<()> {
    let zoo = load_zoo(manifest, model, 0)?;
    let plan = QuantPlan::new()
        .transform("cat-block")
        .quantizer(WeightQuantizer::Rtn)
        .bits(4, 4)
        .seed(0);
    let t0 = Instant::now();
    let (qc, rep) = build_quant_config(&zoo.model, &zoo.calib, &plan)?;
    let build_s = t0.elapsed().as_secs_f64();
    save_artifact(&qc, &rep, dir)?;
    println!(
        "pipeline built in {build_s:.1}s; artifact saved to {} ({:.1} KiB packed codes)",
        dir.display(),
        qc.packed_bytes() as f64 / 1024.0
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).map(|s| s.as_str()).unwrap_or("small").to_string();
    let n: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(16);

    let manifest = Manifest::load(&Manifest::default_dir())?;
    let corpus = Corpus::load(&manifest.corpus_eval)?;
    let prompts = corpus.sample_sequences(n, manifest.prompt_len, 99);

    let art_dir = std::env::temp_dir().join(format!("catquant-serve-artifact-{model}"));
    build_artifact(&manifest, &model, &art_dir)?;

    println!("== FP serving ({model}, {n} requests, 24 new tokens each) ==");
    let fp = run_mode(&manifest, &model, None, prompts.clone());
    println!("{}\n", fp.summary());

    println!("== CAT W4A4 serving from artifact (same prompts) ==");
    let q = run_mode(&manifest, &model, Some(art_dir), prompts);
    println!("{}\n", q.summary());

    println!(
        "quantized/fp throughput ratio: {:.2}× (W4A4 pays the online transform; \
         on real int4 hardware the matmuls repay it — see DESIGN.md §Perf)",
        q.throughput_tok_s() / fp.throughput_tok_s().max(1e-9)
    );
    Ok(())
}
