//! End-to-end driver: the full system on a real (trained) model.
//!
//! Exercises every layer of the stack in one run, on the `base` model
//! (d=256, 6 blocks, ~4.1M params, trained at build time on the synthetic
//! corpus — see artifacts/train_log_base.json for the loss curve):
//!
//!   1. load weights (Rust loader ← python-trained .catw artifact)
//!   2. calibrate on 128 corpus sequences (native engine probe)
//!   3. PTQ pipeline over `QuantPlan`s: three uniform W4A4 plans
//!      ({identity, quarot, cat-block} × RTN) plus one **mixed-precision**
//!      plan (attention W8A8 / MLP W4A4 via per-group overrides)
//!   4. evaluate perplexity + 6-task 0-shot through the PJRT graphs
//!      (uniform plans; the mixed plan evaluates on the native engine —
//!      the compiled A4 graphs are single-precision by construction)
//!   5. save the CAT-W4A4 config as an artifact, reload it (bit-exact),
//!      and serve a batch of generation requests from the loaded state
//!      through the coordinator (batched prefill + KV-cache decode)
//!
//! Results are recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! cargo run --release --example e2e_pipeline           # full (base model)
//! cargo run --release --example e2e_pipeline -- small  # faster
//! ```

use catquant::calib::Corpus;
use catquant::coordinator::{BatcherCfg, Coordinator, GenEngine, PjrtGenerator, SamplingCfg};
use catquant::eval::{perplexity, zero_shot_suite, NativeLogits, PjrtLogits, SeqLogits};
use catquant::experiments::{load_model, load_zoo};
use catquant::model::LayerGroup;
use catquant::pipeline::{build_quant_config, QuantPlan, WeightQuantizer};
use catquant::runtime::{save_artifact, Manifest, PjrtEngine};
use std::rc::Rc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).map(|s| s.as_str()).unwrap_or("base").to_string();

    let t_all = Instant::now();
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let entry = manifest.model(&model)?;
    println!(
        "[1/5] loaded manifest; model {model}: d={} L={} params={}",
        entry.config.d,
        entry.config.n_layers,
        entry.config.n_params()
    );

    let t0 = Instant::now();
    let zoo = load_zoo(&manifest, &model, 0)?;
    println!("[2/5] calibrated on 128 sequences in {:.1}s", t0.elapsed().as_secs_f64());

    let engine = Rc::new(PjrtEngine::new(manifest.clone())?);
    let corpus = Corpus::load(&manifest.corpus_eval)?;
    let windows = corpus.eval_windows(16, entry.config.seq);

    // FP reference.
    let fp = PjrtLogits::fp(engine.clone(), &model, &zoo.model.params)?;
    let fp_ppl = perplexity(&fp, &windows)?;
    let fp_acc = acc(&fp, &corpus)?;
    println!("[3/5] FP reference: ppl {fp_ppl:.3}, 0-shot {fp_acc:.1}%");

    let mut cat = None;
    for recipe in ["identity", "quarot", "cat-block"] {
        let plan = QuantPlan::new()
            .transform(recipe)
            .quantizer(WeightQuantizer::Rtn)
            .bits(4, 4)
            .seed(0);
        let t0 = Instant::now();
        let (qc, rep) = build_quant_config(&zoo.model, &zoo.calib, &plan)?;
        let build_s = t0.elapsed().as_secs_f64();
        let eng = PjrtLogits::quant(engine.clone(), &model, &zoo.model.params, &qc, 4)?;
        let ppl = perplexity(&eng, &windows)?;
        let a = acc(&eng, &corpus)?;
        println!(
            "[4/5] {recipe:<14} W4A4: ppl {ppl:.3}  0-shot {a:.1}%  (layer SQNR {:.1} dB, built in {build_s:.1}s)",
            rep.mean_sqnr_db
        );
        if recipe == "cat-block" {
            cat = Some((qc, rep));
        }
    }

    // Mixed precision through per-group overrides: attention at W8A8,
    // the MLP at W4A4 — inexpressible under the old flat config.
    let mixed_plan = QuantPlan::new()
        .transform("cat-block")
        .quantizer(WeightQuantizer::Rtn)
        .bits(4, 4)
        .seed(0)
        .for_group(LayerGroup::AttnIn, |g| g.bits(8, 8))
        .for_group(LayerGroup::OIn, |g| g.bits(8, 8));
    let (mixed_qc, mixed_rep) = build_quant_config(&zoo.model, &zoo.calib, &mixed_plan)?;
    let mixed_eng = NativeLogits { model: &zoo.model, qc: Some(&mixed_qc) };
    let mixed_ppl = perplexity(&mixed_eng, &windows)?;
    println!(
        "[4/5] attn-W8A8/mlp-W4A4: ppl {mixed_ppl:.3} (native engine; layer SQNR {:.1} dB)",
        mixed_rep.mean_sqnr_db
    );

    // Persist the CAT-W4A4 run and serve from the loaded artifact.
    let (qc, rep) = cat.unwrap();
    let art_dir = std::env::temp_dir().join("catquant-e2e-artifact");
    let t0 = Instant::now();
    save_artifact(&qc, &rep, &art_dir)?;
    let save_s = t0.elapsed().as_secs_f64();
    println!("[5/5] artifact saved to {} in {save_s:.2}s", art_dir.display());

    let manifest2 = manifest.clone();
    let model2 = model.clone();
    let art_dir2 = art_dir.clone();
    let mut coord = Coordinator::start(
        move || {
            let engine = Rc::new(PjrtEngine::new(manifest2.clone()).expect("engine"));
            // No calibration on the boot path: weights + the saved
            // artifact are all a serving worker needs.
            let t0 = Instant::now();
            let native = load_model(&manifest2, &model2).expect("model");
            let gen = PjrtGenerator::quant_from_artifact(
                engine,
                &model2,
                &native,
                &art_dir2,
                SamplingCfg { temperature: 0.8, seed: 3 },
            )
            .expect("gen");
            eprintln!(
                "[5/5] worker booted from artifact in {:.0} ms (weights + codes, \
                 no calibration/pipeline rerun)",
                t0.elapsed().as_secs_f64() * 1e3
            );
            Box::new(gen) as Box<dyn GenEngine>
        },
        BatcherCfg::default(),
    );
    let prompts = corpus.sample_sequences(12, manifest.prompt_len, 5);
    let rxs: Vec<_> = prompts.into_iter().map(|p| coord.submit(p, 24)).collect();
    for rx in rxs {
        rx.recv()?;
    }
    let metrics = coord.shutdown();
    println!("[5/5] served CAT-W4A4 from artifact: {}", metrics.summary());
    println!("\nE2E complete in {:.1}s", t_all.elapsed().as_secs_f64());
    Ok(())
}

fn acc(engine: &dyn SeqLogits, corpus: &Corpus) -> anyhow::Result<f64> {
    let res = zero_shot_suite(engine, corpus, 10, 0)?;
    Ok(100.0 * res.iter().map(|r| r.accuracy).sum::<f64>() / res.len() as f64)
}
