//! End-to-end driver: the full system on a real (trained) model.
//!
//! Exercises every layer of the stack in one run, on the `base` model
//! (d=256, 6 blocks, ~4.1M params, trained at build time on the synthetic
//! corpus — see artifacts/train_log_base.json for the loss curve):
//!
//!   1. load weights (Rust loader ← python-trained .catw artifact)
//!   2. calibrate on 128 corpus sequences (native engine probe)
//!   3. PTQ pipeline: {None, QuaRot, CAT block} × RTN at W4A4
//!   4. evaluate perplexity + 6-task 0-shot through the PJRT graphs
//!      (L2 JAX-lowered HLO, L1 kernel-verified ops, weights as args)
//!   5. serve a batch of generation requests on the CAT-W4A4 config
//!      through the coordinator (batched prefill + KV-cache decode)
//!
//! Results are recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! cargo run --release --example e2e_pipeline           # full (base model)
//! cargo run --release --example e2e_pipeline -- small  # faster
//! ```

use catquant::calib::Corpus;
use catquant::coordinator::{BatcherCfg, Coordinator, GenEngine, PjrtGenerator, SamplingCfg};
use catquant::eval::{perplexity, zero_shot_suite, PjrtLogits, SeqLogits};
use catquant::experiments::load_zoo;
use catquant::pipeline::{build_quant_config, PipelineCfg, WeightQuantizer};
use catquant::runtime::{Manifest, PjrtEngine};
use catquant::transforms::TransformKind;
use std::rc::Rc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).map(|s| s.as_str()).unwrap_or("base").to_string();

    let t_all = Instant::now();
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let entry = manifest.model(&model)?;
    println!(
        "[1/5] loaded manifest; model {model}: d={} L={} params={}",
        entry.config.d,
        entry.config.n_layers,
        entry.config.n_params()
    );

    let t0 = Instant::now();
    let zoo = load_zoo(&manifest, &model, 0)?;
    println!("[2/5] calibrated on 128 sequences in {:.1}s", t0.elapsed().as_secs_f64());

    let engine = Rc::new(PjrtEngine::new(manifest.clone())?);
    let corpus = Corpus::load(&manifest.corpus_eval)?;
    let windows = corpus.eval_windows(16, entry.config.seq);

    // FP reference.
    let fp = PjrtLogits::fp(engine.clone(), &model, &zoo.model.params)?;
    let fp_ppl = perplexity(&fp, &windows)?;
    let fp_acc = acc(&fp, &corpus)?;
    println!("[3/5] FP reference: ppl {fp_ppl:.3}, 0-shot {fp_acc:.1}%");

    let mut cat_qc = None;
    for kind in [TransformKind::None, TransformKind::QuaRot, TransformKind::CatBlock] {
        let t0 = Instant::now();
        let (qc, rep) = build_quant_config(
            &zoo.model,
            &zoo.calib,
            PipelineCfg::w4a4(kind, WeightQuantizer::Rtn, 0),
        );
        let build_s = t0.elapsed().as_secs_f64();
        let eng = PjrtLogits::quant(engine.clone(), &model, &zoo.model.params, &qc, 4)?;
        let ppl = perplexity(&eng, &windows)?;
        let a = acc(&eng, &corpus)?;
        println!(
            "[4/5] {:<14} W4A4: ppl {ppl:.3}  0-shot {a:.1}%  (layer SQNR {:.1} dB, built in {build_s:.1}s)",
            kind.label(),
            rep.mean_sqnr_db
        );
        if kind == TransformKind::CatBlock {
            cat_qc = Some(qc);
        }
    }

    // Serve the CAT-W4A4 config.
    let qc = cat_qc.unwrap();
    let manifest2 = manifest.clone();
    let model2 = model.clone();
    let coord = Coordinator::start(
        move || {
            let engine = Rc::new(PjrtEngine::new(manifest2.clone()).expect("engine"));
            let zoo = load_zoo(&manifest2, &model2, 0).expect("zoo");
            Box::new(
                PjrtGenerator::quant(
                    engine,
                    &model2,
                    &zoo.model.params,
                    &qc,
                    SamplingCfg { temperature: 0.8, seed: 3 },
                )
                .expect("gen"),
            ) as Box<dyn GenEngine>
        },
        BatcherCfg::default(),
    );
    let prompts = corpus.sample_sequences(12, manifest.prompt_len, 5);
    let rxs: Vec<_> = prompts.into_iter().map(|p| coord.submit(p, 24)).collect();
    for rx in rxs {
        rx.recv()?;
    }
    let metrics = coord.shutdown();
    println!("[5/5] served CAT-W4A4: {}", metrics.summary());
    println!("\nE2E complete in {:.1}s", t_all.elapsed().as_secs_f64());
    Ok(())
}

fn acc(engine: &dyn SeqLogits, corpus: &Corpus) -> anyhow::Result<f64> {
    let res = zero_shot_suite(engine, corpus, 10, 0)?;
    Ok(100.0 * res.iter().map(|r| r.accuracy).sum::<f64>() / res.len() as f64)
}
