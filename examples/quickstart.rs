//! Quickstart: calibrate → CAT-quantize → evaluate, in ~40 lines of API.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use catquant::calib::Corpus;
use catquant::eval::{perplexity, PjrtLogits};
use catquant::experiments::load_zoo;
use catquant::pipeline::{build_quant_config, PipelineCfg, WeightQuantizer};
use catquant::runtime::{Manifest, PjrtEngine};
use catquant::transforms::TransformKind;
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    // 1. Artifacts: trained weights + AOT-compiled graphs + corpus.
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let model = "small";
    let entry = manifest.model(model)?;
    println!("model {model}: {} params", entry.config.n_params());

    // 2. Calibrate on 128 corpus sequences (collects Σ_x per layer group).
    let zoo = load_zoo(&manifest, model, 0)?;

    // 3. Build the paper's transform — CAT (block) — and quantize W4A4.
    let (qc, report) = build_quant_config(
        &zoo.model,
        &zoo.calib,
        PipelineCfg::w4a4(TransformKind::CatBlock, WeightQuantizer::Rtn, 0),
    );
    println!("mean post-transform layer SQNR: {:.1} dB", report.mean_sqnr_db);

    // 4. Evaluate perplexity through the compiled serving graphs.
    let engine = Rc::new(PjrtEngine::new(manifest.clone())?);
    let corpus = Corpus::load(&manifest.corpus_eval)?;
    let windows = corpus.eval_windows(16, entry.config.seq);

    let fp = PjrtLogits::fp(engine.clone(), model, &zoo.model.params)?;
    let quant = PjrtLogits::quant(engine, model, &zoo.model.params, &qc, 4)?;
    let ppl_fp = perplexity(&fp, &windows)?;
    let ppl_q = perplexity(&quant, &windows)?;
    println!("perplexity: FP {ppl_fp:.3}  |  CAT W4A4 {ppl_q:.3}");
    Ok(())
}
