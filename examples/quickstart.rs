//! Quickstart: calibrate → plan → CAT-quantize → persist → evaluate.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use catquant::calib::Corpus;
use catquant::eval::{perplexity, PjrtLogits};
use catquant::experiments::load_zoo;
use catquant::pipeline::{build_quant_config, QuantPlan, WeightQuantizer};
use catquant::runtime::{load_artifact, save_artifact, Manifest, PjrtEngine};
use std::rc::Rc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    // 1. Artifacts: trained weights + AOT-compiled graphs + corpus.
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let model = "small";
    let entry = manifest.model(model)?;
    println!("model {model}: {} params", entry.config.n_params());

    // 2. Calibrate on 128 corpus sequences (collects Σ_x per layer group).
    let zoo = load_zoo(&manifest, model, 0)?;

    // 3. Plan the run: the paper's transform — CAT (block) — at W4A4,
    //    uniform across every layer group. (Per-group overrides and
    //    mixed precision: see examples/e2e_pipeline.rs.)
    let plan = QuantPlan::new()
        .transform("cat-block")
        .quantizer(WeightQuantizer::Rtn)
        .bits(4, 4)
        .seed(0);
    let t0 = Instant::now();
    let (qc, report) = build_quant_config(&zoo.model, &zoo.calib, &plan)?;
    let build_s = t0.elapsed().as_secs_f64();
    println!("mean post-transform layer SQNR: {:.1} dB", report.mean_sqnr_db);

    // 4. Persist the built config and load it back — a server boots from
    //    this directory in milliseconds instead of re-running step 3.
    let dir = std::env::temp_dir().join("catquant-quickstart-artifact");
    save_artifact(&qc, &report, &dir)?;
    let t0 = Instant::now();
    let loaded = load_artifact(&dir, &zoo.model)?;
    let load_s = t0.elapsed().as_secs_f64();
    let toks: Vec<u8> = (0..entry.config.seq.min(16)).map(|i| (i * 31) as u8).collect();
    let diff = zoo
        .model
        .forward_quant(&toks, &qc)
        .max_abs_diff(&zoo.model.forward_quant(&toks, &loaded));
    println!(
        "artifact round trip: build {build_s:.2}s vs load {load_s:.3}s, logits diff {diff} (must be 0)"
    );
    assert_eq!(diff, 0.0, "loaded artifact must be bit-exact");

    // 5. Evaluate perplexity through the compiled serving graphs.
    let engine = Rc::new(PjrtEngine::new(manifest.clone())?);
    let corpus = Corpus::load(&manifest.corpus_eval)?;
    let windows = corpus.eval_windows(16, entry.config.seq);

    let fp = PjrtLogits::fp(engine.clone(), model, &zoo.model.params)?;
    let quant = PjrtLogits::quant(engine, model, &zoo.model.params, &loaded, 4)?;
    let ppl_fp = perplexity(&fp, &windows)?;
    let ppl_q = perplexity(&quant, &windows)?;
    println!("perplexity: FP {ppl_fp:.3}  |  CAT W4A4 {ppl_q:.3}");
    Ok(())
}
