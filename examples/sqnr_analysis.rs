//! The Concentration–Alignment framework on controlled synthetic layers.
//!
//! Walks the paper's §2 decomposition term by term on five labeled
//! pathologies (Gaussian / outlier channels / heavy tails / misaligned /
//! pathological) and shows what each transform family can and cannot fix:
//! rotations fix concentration only; CAT fixes both.
//!
//! ```bash
//! cargo run --release --example sqnr_analysis
//! ```

use catquant::calib::{synth_suite, SynthLayer};
use catquant::linalg::{syrk_at_a, Mat};
use catquant::quant::{ActQuantCfg, QScheme, WeightQuantCfg};
use catquant::sqnr::{
    alignment_data, approx_sqnr_joint, concentration_act, concentration_weights, db,
    max_alignment, measured_sqnr_joint,
};
use catquant::transforms::{cat_block, Transform};

fn main() {
    let act = ActQuantCfg { scheme: QScheme::asym(4), clip_ratio: 1.0 };
    let wq = WeightQuantCfg::minmax(4);
    let d = 128;
    println!("Theorem 2.4: SQNR ≈ 12·(N(b_x)²C(x) ∥ N(b_w)²C(W))·A(x,W)   [all dB]\n");
    println!(
        "{:<22} {:>8} {:>8} {:>8} {:>8} {:>9} {:>9}",
        "layer", "C(x)", "C(W)", "A", "A*", "approx", "measured"
    );
    for layer in synth_suite(d, 4096, 42) {
        let SynthLayer { name, x, w, .. } = layer;
        let sigma = syrk_at_a(&x).scale(1.0 / x.rows() as f64);
        println!(
            "{:<22} {:>8.1} {:>8.1} {:>8.1} {:>8.1} {:>9.1} {:>9.1}",
            name,
            db(concentration_act(&x, act)),
            db(concentration_weights(&w, wq)),
            db(alignment_data(&x, &w)),
            db(max_alignment(&sigma, &w)),
            db(approx_sqnr_joint(&x, &w, act, wq)),
            db(measured_sqnr_joint(&x, &w, act, wq)),
        );
    }

    println!("\n-- what transforms fix (pathological layer, W4A4) --");
    let layer = synth_suite(d, 4096, 42).pop().unwrap();
    let sigma_x = syrk_at_a(&layer.x).scale(1.0 / layer.x.rows() as f64);
    let sigma_w = syrk_at_a(&layer.w);
    let configs: Vec<(&str, Transform)> = vec![
        ("identity", Transform::identity(d)),
        (
            "hadamard (rotation)",
            Transform::orthogonal("H", catquant::linalg::hadamard_matrix(d)),
        ),
        ("CAT block k=32", cat_block(&sigma_x, &sigma_w, 32, 0)),
    ];
    println!(
        "{:<22} {:>8} {:>8} {:>8} {:>9}",
        "transform", "C(x)", "C(W)", "A", "measured"
    );
    for (label, t) in configs {
        let x = t.apply_acts(&layer.x);
        let w = t.fuse_weights(&layer.w);
        println!(
            "{:<22} {:>8.1} {:>8.1} {:>8.1} {:>9.1}",
            label,
            db(concentration_act(&x, act)),
            db(concentration_weights(&w, wq)),
            db(alignment_data(&x, &w)),
            db(measured_sqnr_joint(&x, &w, act, wq)),
        );
    }
    println!("\nNote how the rotation row matches identity in column A exactly");
    println!("(paper eq. 4) while CAT moves both C and A.");

    // Bit-width equivalence (paper §2.1): alignment gain k ≈ both bit
    // widths + log2(√k).
    let t = cat_block(&sigma_x, &sigma_w, 32, 0);
    let x = t.apply_acts(&layer.x);
    let w = t.fuse_weights(&layer.w);
    let gain_db = db(measured_sqnr_joint(&x, &w, act, wq))
        - db(measured_sqnr_joint(&layer.x, &layer.w, act, wq));
    println!(
        "\nCAT gain {:.1} dB ≈ {:.1} extra bits on BOTH weights and activations",
        gain_db,
        gain_db / 6.02
    );
    let _ = Mat::eye(1);
}
