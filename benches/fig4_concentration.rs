//! Bench/regenerator for Figure 4 (concentration under transforms).
//! Run: `cargo bench --bench fig4_concentration`

use catquant::experiments::run_fig4;
use catquant::runtime::Manifest;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let t0 = Instant::now();
    let rows = run_fig4(&manifest, &["tiny", "small"], 0)?;
    println!(
        "\n[bench] fig4 regenerated: {} rows in {:.2}s",
        rows.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
