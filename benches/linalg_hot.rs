//! L3 hot-path microbenches: matmul (tiled vs the retained pre-tiling
//! reference, serial vs dispatched), syrk covariance, eigh, FWHT,
//! geometric mean, GPTQ's Cholesky. (Plain harness — criterion is not in
//! the offline vendor set.)
//!
//! Run: `cargo bench --bench linalg_hot` (full sweep) or
//! `cargo bench --bench linalg_hot -- --quick` (CI perf smoke: runs the
//! 512³ tiled-vs-reference A/B plus the SIMD-vs-scalar-ISA A/B and
//! **exits nonzero if the tiled kernel is not faster than the reference
//! or the SIMD path is not faster than the forced-scalar path** — the
//! hard gates against silent kernel regressions; the SIMD gate skips,
//! not fails, on hosts with no SIMD ISA).
//!
//! Both modes write `BENCH_linalg.json` — a `meta` header (detected /
//! active ISA, `CATQUANT_SIMD`/`CATQUANT_THREADS`, worker count, so perf
//! trajectories are comparable across machines) plus machine-readable
//! `records` `{kernel, shape, isa, threads, ms_per_iter, gflops,
//! speedup}` — which CI uploads as an artifact so the perf trajectory is
//! recorded per run.

use catquant::linalg::{
    eigh, fwht_inplace, geometric_mean, matmul, matmul_a_bt, matmul_a_bt_serial, matmul_at_b,
    matmul_at_b_serial, matmul_serial, matmul_serial_ref, par, simd, syrk_at_a, Cholesky, Mat,
    Rng,
};
use std::time::Instant;

/// One machine-readable bench record (JSON object).
struct Rec {
    kernel: String,
    shape: String,
    /// The `linalg::simd` ISA active while this record was measured.
    isa: String,
    threads: usize,
    ms_per_iter: f64,
    gflops: f64,
    /// Speedup vs this record's baseline (1.0 when it *is* the baseline).
    speedup: f64,
}

/// The metadata header shared by the BENCH_*.json files: where the
/// numbers came from, so trajectories are comparable across machines.
fn meta_json(bench: &str) -> String {
    let env_or = |k: &str| std::env::var(k).unwrap_or_else(|_| "unset".into());
    format!(
        "{{\"bench\": \"{bench}\", \"isa_detected\": \"{}\", \"isa_active\": \"{}\", \
         \"catquant_simd\": \"{}\", \"catquant_threads\": \"{}\", \"workers\": {}}}",
        simd::detected().name(),
        simd::active().name(),
        env_or("CATQUANT_SIMD"),
        env_or("CATQUANT_THREADS"),
        par::num_threads()
    )
}

fn write_json(path: &str, recs: &[Rec]) {
    let mut s = format!("{{\"meta\": {},\n \"records\": [\n", meta_json("linalg_hot"));
    for (i, r) in recs.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"kernel\": \"{}\", \"shape\": \"{}\", \"isa\": \"{}\", \"threads\": {}, \
             \"ms_per_iter\": {:.6}, \"gflops\": {:.3}, \"speedup\": {:.3}}}{}\n",
            r.kernel,
            r.shape,
            r.isa,
            r.threads,
            r.ms_per_iter,
            r.gflops,
            r.speedup,
            if i + 1 < recs.len() { "," } else { "" }
        ));
    }
    s.push_str("]}\n");
    match std::fs::write(path, s) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn time<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<44} {:>10.3} ms/iter", per * 1e3);
    per
}

fn random(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(rows, cols, |_, _| rng.normal())
}

/// Tiled-vs-reference serial A/B at `n³` — the acceptance measurement
/// (≥2× single-thread GFLOP/s at 512³) and the CI perf gate. Returns
/// (t_ref, t_tiled).
fn ref_vs_tiled(n: usize, iters: usize, recs: &mut Vec<Rec>) -> (f64, f64) {
    let a = random(n, n, 21);
    let b = random(n, n, 22);
    let gf = 2.0 * (n as f64).powi(3) / 1e9;
    let t_ref = time(&format!("matmul {n}³ serial REFERENCE (pre-PR)"), iters, || {
        std::hint::black_box(matmul_serial_ref(&a, &b));
    });
    let t_tiled = time(&format!("matmul {n}³ serial tiled"), iters, || {
        std::hint::black_box(matmul_serial(&a, &b));
    });
    println!(
        "{:<44} {:>6.2} -> {:.2} GFLOP/s ({:.2}× vs reference)",
        format!("  -> single-thread tiling gain {n}³"),
        gf / t_ref,
        gf / t_tiled,
        t_ref / t_tiled
    );
    recs.push(Rec {
        kernel: "matmul_serial_ref".into(),
        shape: format!("{n}x{n}x{n}"),
        isa: simd::active().name().into(),
        threads: 1,
        ms_per_iter: t_ref * 1e3,
        gflops: gf / t_ref,
        speedup: 1.0,
    });
    recs.push(Rec {
        // Distinct key from the serial-vs-dispatched sweep's
        // "matmul_serial_tiled" record: same kernel, but this row's
        // speedup is measured against the retained reference.
        kernel: "matmul_tiled_vs_ref".into(),
        shape: format!("{n}x{n}x{n}"),
        isa: simd::active().name().into(),
        threads: 1,
        ms_per_iter: t_tiled * 1e3,
        gflops: gf / t_tiled,
        speedup: t_ref / t_tiled,
    });
    (t_ref, t_tiled)
}

/// Forced-scalar vs best-detected-ISA A/B on the serial tiled kernel at
/// `n³` (same binary, `simd::set_active` flip — results are
/// bit-identical, only speed moves). Returns `None` (and records
/// nothing) when the host has no SIMD ISA; CI's gate skips, not fails.
fn simd_vs_scalar_gemm(n: usize, iters: usize, recs: &mut Vec<Rec>) -> Option<(f64, f64)> {
    let best = simd::detected();
    if best == simd::Isa::Scalar {
        println!("simd vs scalar {n}³: skipped (no SIMD ISA on this host)");
        return None;
    }
    let a = random(n, n, 31);
    let b = random(n, n, 32);
    let gf = 2.0 * (n as f64).powi(3) / 1e9;
    let prev = simd::active();
    simd::set_active(simd::Isa::Scalar);
    let t_scalar = time(&format!("matmul {n}³ serial ISA=scalar"), iters, || {
        std::hint::black_box(matmul_serial(&a, &b));
    });
    simd::set_active(best);
    let t_simd = time(&format!("matmul {n}³ serial ISA={}", best.name()), iters, || {
        std::hint::black_box(matmul_serial(&a, &b));
    });
    simd::set_active(prev);
    println!(
        "{:<44} {:>6.2} -> {:.2} GFLOP/s ({:.2}× vs scalar ISA)",
        format!("  -> {} lane gain {n}³", best.name()),
        gf / t_scalar,
        gf / t_simd,
        t_scalar / t_simd
    );
    recs.push(Rec {
        kernel: "matmul_tiled_scalar_isa".into(),
        shape: format!("{n}x{n}x{n}"),
        isa: "scalar".into(),
        threads: 1,
        ms_per_iter: t_scalar * 1e3,
        gflops: gf / t_scalar,
        speedup: 1.0,
    });
    recs.push(Rec {
        kernel: "matmul_tiled_simd_isa".into(),
        shape: format!("{n}x{n}x{n}"),
        isa: best.name().into(),
        threads: 1,
        ms_per_iter: t_simd * 1e3,
        gflops: gf / t_simd,
        speedup: t_scalar / t_simd,
    });
    Some((t_scalar, t_simd))
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let workers = par::num_threads();
    let mut recs: Vec<Rec> = Vec::new();
    println!("== linalg hot paths ==");
    println!(
        "workers: {workers} (CATQUANT_THREADS to override) | simd: {} active, {} detected \
         (CATQUANT_SIMD to force)\n",
        simd::active().name(),
        simd::detected().name()
    );

    if quick {
        // CI perf smoke: the 512³ tiled-vs-reference A/B plus the
        // SIMD-vs-scalar-ISA A/B, both hard-gated.
        let (t_ref, t_tiled) = ref_vs_tiled(512, 3, &mut recs);
        let simd_ab = simd_vs_scalar_gemm(512, 3, &mut recs);
        write_json("BENCH_linalg.json", &recs);
        if t_tiled >= t_ref {
            eprintln!(
                "PERF REGRESSION: tiled matmul 512³ ({:.1} ms) is not faster than the \
                 reference kernel ({:.1} ms)",
                t_tiled * 1e3,
                t_ref * 1e3
            );
            std::process::exit(1);
        }
        println!(
            "perf smoke OK: tiled 512³ is {:.2}× the reference kernel",
            t_ref / t_tiled
        );
        match simd_ab {
            None => println!("perf smoke: simd gate skipped (no SIMD ISA)"),
            Some((t_scalar, t_simd)) => {
                if t_simd >= t_scalar {
                    eprintln!(
                        "PERF REGRESSION: {} tiled matmul 512³ ({:.1} ms) is not faster \
                         than the forced-scalar ISA ({:.1} ms)",
                        simd::detected().name(),
                        t_simd * 1e3,
                        t_scalar * 1e3
                    );
                    std::process::exit(1);
                }
                println!(
                    "perf smoke OK: {} 512³ is {:.2}× the scalar ISA",
                    simd::detected().name(),
                    t_scalar / t_simd
                );
            }
        }
        return;
    }

    // Serial vs dispatched (parallel above the size threshold) A/B — the
    // acceptance gates are ≥2× single-thread from tiling at 512³ and ≥2×
    // from threading with ≥4 workers (PERF.md).
    for &n in &[128usize, 256, 512] {
        let a = random(n, n, 1);
        let b = random(n, n, 2);
        let gf = 2.0 * (n as f64).powi(3) / 1e9;
        let iters = 10.max(2048 / n);
        let t_ser = time(&format!("matmul {n}×{n} serial"), iters, || {
            std::hint::black_box(matmul_serial(&a, &b));
        });
        let t_par = time(&format!("matmul {n}×{n} dispatched"), iters, || {
            std::hint::black_box(matmul(&a, &b));
        });
        println!(
            "{:<44} {:>10.2} GFLOP/s ({:.2}× vs serial)",
            format!("  -> throughput {n}"),
            gf / t_par,
            t_ser / t_par
        );
        recs.push(Rec {
            kernel: "matmul_serial_tiled".into(),
            shape: format!("{n}x{n}x{n}"),
            isa: simd::active().name().into(),
            threads: 1,
            ms_per_iter: t_ser * 1e3,
            gflops: gf / t_ser,
            speedup: 1.0,
        });
        recs.push(Rec {
            kernel: "matmul_dispatched".into(),
            shape: format!("{n}x{n}x{n}"),
            isa: simd::active().name().into(),
            // Effective worker count: 128³ sits below PAR_MIN_FMA and
            // runs serial — the JSON must not attribute it to the pool.
            threads: par::threads_for(n * n * n, n),
            ms_per_iter: t_par * 1e3,
            gflops: gf / t_par,
            speedup: t_ser / t_par,
        });
    }
    // The single-thread tiling acceptance A/B, then the ISA A/B (the PR 6
    // acceptance measurement: explicit SIMD lanes vs the forced-scalar
    // path on the same binary).
    ref_vs_tiled(512, 4, &mut recs);
    simd_vs_scalar_gemm(512, 4, &mut recs);
    {
        let x = random(2048, 256, 3);
        let gf_syrk = (2048.0 * 256.0 * 256.0) / 1e9; // full-product FLOP for comparability
        let t_ser = time("Σ accumulation  XᵀX (2048×256) at_b serial", 8, || {
            std::hint::black_box(matmul_at_b_serial(&x, &x));
        });
        let t_full = time("Σ accumulation  XᵀX (2048×256) at_b dispatched", 8, || {
            std::hint::black_box(matmul_at_b(&x, &x));
        });
        let t_syrk = time("Σ accumulation  XᵀX (2048×256) syrk", 8, || {
            std::hint::black_box(syrk_at_a(&x));
        });
        println!(
            "{:<44} {:>9.2}× vs at_b serial ({:.2}× vs at_b dispatched)",
            "  -> syrk speedup",
            t_ser / t_syrk,
            t_full / t_syrk
        );
        recs.push(Rec {
            kernel: "matmul_at_b".into(),
            shape: "2048x256->256x256".into(),
            isa: simd::active().name().into(),
            threads: par::threads_for(2048 * 256 * 256, 256),
            ms_per_iter: t_full * 1e3,
            gflops: 2.0 * gf_syrk / t_full,
            speedup: t_ser / t_full,
        });
        recs.push(Rec {
            kernel: "syrk_at_a".into(),
            shape: "2048x256->256x256".into(),
            isa: simd::active().name().into(),
            threads: par::threads_for(2048 * 256 * 256 / 2, 256),
            ms_per_iter: t_syrk * 1e3,
            gflops: 2.0 * gf_syrk / t_syrk,
            speedup: t_ser / t_syrk,
        });
        let w = random(256, 256, 4);
        let t_ser = time("layer fwd  X·Wᵀ (2048×256·256) serial", 8, || {
            std::hint::black_box(matmul_a_bt_serial(&x, &w));
        });
        let t_par = time("layer fwd  X·Wᵀ (2048×256·256) dispatched", 8, || {
            std::hint::black_box(matmul_a_bt(&x, &w));
        });
        println!("{:<44} {:>9.2}× vs serial", "  -> X·Wᵀ speedup", t_ser / t_par);
        recs.push(Rec {
            kernel: "matmul_a_bt".into(),
            shape: "2048x256x256".into(),
            isa: simd::active().name().into(),
            threads: par::threads_for(2048 * 256 * 256, 2048),
            ms_per_iter: t_par * 1e3,
            gflops: 2.0 * 2048.0 * 256.0 * 256.0 / 1e9 / t_par,
            speedup: t_ser / t_par,
        });
    }
    for &n in &[64usize, 128, 256] {
        let g = random(n + 8, n, 5);
        let s = syrk_at_a(&g);
        time(&format!("eigh (cyclic Jacobi) {n}×{n}"), if n > 128 { 2 } else { 6 }, || {
            std::hint::black_box(eigh(&s));
        });
    }
    {
        let ga = random(136, 128, 6);
        let a = syrk_at_a(&ga);
        let gb = random(136, 128, 7);
        let b = syrk_at_a(&gb);
        time("geometric mean A#B 128×128 (CAT block)", 3, || {
            std::hint::black_box(geometric_mean(&a, &b));
        });
        time("cholesky 128×128 (GPTQ factor)", 50, || {
            std::hint::black_box(Cholesky::new(&a));
        });
    }
    {
        // A/B for the §Perf dot-product change: naive single-accumulator
        // reduction vs the shipped 4-accumulator kernel (what matvec
        // uses; the matmul kernels moved to 4×8 register tiles).
        let mut rng = Rng::new(9);
        let a: Vec<f64> = (0..4096).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..4096).map(|_| rng.normal()).collect();
        let naive = |a: &[f64], b: &[f64]| -> f64 {
            let mut acc = 0.0;
            for (x, y) in a.iter().zip(b) {
                acc += x * y;
            }
            acc
        };
        let iters = 100_000;
        let t0 = Instant::now();
        let mut sink = 0.0;
        for _ in 0..iters {
            sink += naive(std::hint::black_box(&a), std::hint::black_box(&b));
        }
        let t_naive = t0.elapsed().as_secs_f64() / iters as f64;
        let t0 = Instant::now();
        for _ in 0..iters {
            let mut acc = [0.0f64; 4];
            let ca = a.chunks_exact(4);
            let cb = b.chunks_exact(4);
            for (xa, xb) in ca.zip(cb) {
                acc[0] += xa[0] * xb[0];
                acc[1] += xa[1] * xb[1];
                acc[2] += xa[2] * xb[2];
                acc[3] += xa[3] * xb[3];
            }
            sink += (acc[0] + acc[2]) + (acc[1] + acc[3]);
        }
        let t_unrolled = t0.elapsed().as_secs_f64() / iters as f64;
        std::hint::black_box(sink);
        println!(
            "{:<44} {:>10.3} µs naive vs {:.3} µs unrolled ({:.2}×)",
            "dot product d=4096 (§Perf A/B)",
            t_naive * 1e6,
            t_unrolled * 1e6,
            t_naive / t_unrolled
        );
    }
    {
        let mut rng = Rng::new(8);
        let mut x: Vec<f64> = (0..512).map(|_| rng.normal()).collect();
        let t0 = Instant::now();
        let iters = 200_000;
        for _ in 0..iters {
            fwht_inplace(&mut x);
        }
        let per = t0.elapsed().as_secs_f64() / iters as f64;
        println!("{:<44} {:>10.3} µs/iter", "FWHT d=512", per * 1e6);
    }
    write_json("BENCH_linalg.json", &recs);
}
