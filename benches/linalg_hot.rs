//! L3 hot-path microbenches: matmul, eigh, FWHT, geometric mean, GPTQ's
//! Cholesky. (Plain harness — criterion is not in the offline vendor set.)
//!
//! Run: `cargo bench --bench linalg_hot`

use catquant::linalg::{
    eigh, fwht_inplace, geometric_mean, matmul, matmul_a_bt, matmul_a_bt_serial, matmul_at_b,
    matmul_at_b_serial, matmul_serial, par, Cholesky, Mat, Rng,
};
use std::time::Instant;

fn time<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<44} {:>10.3} ms/iter", per * 1e3);
    per
}

fn random(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(rows, cols, |_, _| rng.normal())
}

fn main() {
    println!("== linalg hot paths ==");
    println!("workers: {} (CATQUANT_THREADS to override)\n", par::num_threads());
    // Serial vs dispatched (parallel above the size threshold) A/B — the
    // acceptance gate is ≥2× on matmul 512³ with ≥4 workers (PERF.md).
    for &n in &[128usize, 256, 512] {
        let a = random(n, n, 1);
        let b = random(n, n, 2);
        let gf = 2.0 * (n as f64).powi(3) / 1e9;
        let iters = 10.max(2048 / n);
        let t_ser = time(&format!("matmul {n}×{n} serial"), iters, || {
            std::hint::black_box(matmul_serial(&a, &b));
        });
        let t_par = time(&format!("matmul {n}×{n} dispatched"), iters, || {
            std::hint::black_box(matmul(&a, &b));
        });
        println!(
            "{:<44} {:>10.2} GFLOP/s ({:.2}× vs serial)",
            format!("  -> throughput {n}"),
            gf / t_par,
            t_ser / t_par
        );
    }
    {
        let x = random(2048, 256, 3);
        let t_ser = time("Σ accumulation  XᵀX (2048×256) serial", 8, || {
            std::hint::black_box(matmul_at_b_serial(&x, &x));
        });
        let t_par = time("Σ accumulation  XᵀX (2048×256) dispatched", 8, || {
            std::hint::black_box(matmul_at_b(&x, &x));
        });
        println!("{:<44} {:>9.2}× vs serial", "  -> XᵀX speedup", t_ser / t_par);
        let w = random(256, 256, 4);
        let t_ser = time("layer fwd  X·Wᵀ (2048×256·256) serial", 8, || {
            std::hint::black_box(matmul_a_bt_serial(&x, &w));
        });
        let t_par = time("layer fwd  X·Wᵀ (2048×256·256) dispatched", 8, || {
            std::hint::black_box(matmul_a_bt(&x, &w));
        });
        println!("{:<44} {:>9.2}× vs serial", "  -> X·Wᵀ speedup", t_ser / t_par);
    }
    for &n in &[64usize, 128, 256] {
        let mut s = random(n + 8, n, 5);
        s = matmul_at_b(&s, &s);
        time(&format!("eigh (cyclic Jacobi) {n}×{n}"), if n > 128 { 2 } else { 6 }, || {
            std::hint::black_box(eigh(&s));
        });
    }
    {
        let mut a = random(136, 128, 6);
        a = matmul_at_b(&a, &a);
        let mut b = random(136, 128, 7);
        b = matmul_at_b(&b, &b);
        time("geometric mean A#B 128×128 (CAT block)", 3, || {
            std::hint::black_box(geometric_mean(&a, &b));
        });
        time("cholesky 128×128 (GPTQ factor)", 50, || {
            std::hint::black_box(Cholesky::new(&a));
        });
    }
    {
        // A/B for the §Perf dot-product change: naive single-accumulator
        // reduction vs the shipped 4-accumulator kernel (what
        // matmul_a_bt / matvec use).
        let mut rng = Rng::new(9);
        let a: Vec<f64> = (0..4096).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..4096).map(|_| rng.normal()).collect();
        let naive = |a: &[f64], b: &[f64]| -> f64 {
            let mut acc = 0.0;
            for (x, y) in a.iter().zip(b) {
                acc += x * y;
            }
            acc
        };
        let iters = 100_000;
        let t0 = Instant::now();
        let mut sink = 0.0;
        for _ in 0..iters {
            sink += naive(std::hint::black_box(&a), std::hint::black_box(&b));
        }
        let t_naive = t0.elapsed().as_secs_f64() / iters as f64;
        let t0 = Instant::now();
        for _ in 0..iters {
            let mut acc = [0.0f64; 4];
            let ca = a.chunks_exact(4);
            let cb = b.chunks_exact(4);
            for (xa, xb) in ca.zip(cb) {
                acc[0] += xa[0] * xb[0];
                acc[1] += xa[1] * xb[1];
                acc[2] += xa[2] * xb[2];
                acc[3] += xa[3] * xb[3];
            }
            sink += (acc[0] + acc[2]) + (acc[1] + acc[3]);
        }
        let t_unrolled = t0.elapsed().as_secs_f64() / iters as f64;
        std::hint::black_box(sink);
        println!(
            "{:<44} {:>10.3} µs naive vs {:.3} µs unrolled ({:.2}×)",
            "dot product d=4096 (§Perf A/B)",
            t_naive * 1e6,
            t_unrolled * 1e6,
            t_naive / t_unrolled
        );
    }
    {
        let mut rng = Rng::new(8);
        let mut x: Vec<f64> = (0..512).map(|_| rng.normal()).collect();
        let t0 = Instant::now();
        let iters = 200_000;
        for _ in 0..iters {
            fwht_inplace(&mut x);
        }
        let per = t0.elapsed().as_secs_f64() / iters as f64;
        println!("{:<44} {:>10.3} µs/iter", "FWHT d=512", per * 1e6);
    }
}
