//! Quantizer hot paths: per-token activation quant, RTN, GPTQ, transform
//! builders, and the packed-integer vs dense-f64 serving A/B.
//! Run: `cargo bench --bench quant_hot`

use catquant::linalg::{matmul_a_bt, matmul_at_b, qmatmul_a_bt, Mat, Rng};
use catquant::quant::{
    gptq_quantize, quantize_activations_per_token, quantize_weights_rtn, GptqConfig, QScheme,
    QuantizedTensor, WeightQuantCfg,
};
use catquant::transforms::{cat_block, kronecker_cat};
use std::time::Instant;

fn time<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<48} {:>10.3} ms/iter", per * 1e3);
    per
}

fn main() {
    println!("== quantization hot paths ==");
    let mut rng = Rng::new(1);
    let x = Mat::from_fn(2048, 256, |_, _| rng.normal());
    let per = time("per-token dyn-asym quant (2048×256, 4b)", 20, || {
        std::hint::black_box(quantize_activations_per_token(&x, QScheme::asym(4), 1.0));
    });
    println!(
        "{:<48} {:>10.1} Mtok/s",
        "  -> token throughput",
        2048.0 / per / 1e6
    );

    let w = Mat::from_fn(512, 256, |_, _| rng.normal() * 0.05);
    time("RTN minmax (512×256, 4b)", 50, || {
        std::hint::black_box(quantize_weights_rtn(&w, WeightQuantCfg::minmax(4)));
    });
    time("RTN L2.4 clip search (512×256, 4b)", 3, || {
        std::hint::black_box(quantize_weights_rtn(&w, WeightQuantCfg::rtn_default(4)));
    });

    let sigma = {
        let mut s = matmul_at_b(&x, &x).scale(1.0 / 2048.0);
        s.add_diag(0.01);
        s
    };
    time("GPTQ (512×256, Σ 256×256, 4b)", 3, || {
        std::hint::black_box(gptq_quantize(
            &w,
            &sigma,
            WeightQuantCfg::minmax(4),
            GptqConfig::default(),
        ));
    });

    let sigma_w = matmul_at_b(&w, &w);
    time("CAT block build k=128 (d=256)", 3, || {
        std::hint::black_box(cat_block(&sigma, &sigma_w, 128, 0));
    });
    time("FlatQuant kronecker build (d=256)", 3, || {
        std::hint::black_box(kronecker_cat(&sigma, &sigma_w, 0));
    });

    // ---- packed integer kernel vs dense f64 quant path (W4A4) ---------
    // Both sides include the per-token activation quantization, so this
    // A/B measures the full serving-path linear: dense = fake-quant f64
    // matmul over dequantized weights; packed = integer codes through
    // qmatmul_a_bt. Acceptance: packed beats dense at W4A4.
    println!("\n== packed vs dense quant linear (W4A4, 2048×256 · 512×256ᵀ) ==");
    let q4 = quantize_weights_rtn(&w, WeightQuantCfg::minmax(4));
    let wd = q4.deq();
    let act4 = QScheme::asym(4);
    let t_dense = time("dense: per-token quant + f64 matmul_a_bt", 10, || {
        let (xq, _) = quantize_activations_per_token(&x, act4, 1.0);
        std::hint::black_box(matmul_a_bt(&xq, &wd));
    });
    let t_packed = time("packed: quantize to codes + i32 qmatmul", 10, || {
        let xq = QuantizedTensor::quantize_acts(&x, act4, 1.0);
        std::hint::black_box(qmatmul_a_bt(&xq.view(), &q4.codes.view()));
    });
    println!("{:<48} {:>9.2}×", "  -> packed speedup vs dense", t_dense / t_packed);
    let f64_bytes = w.rows() * w.cols() * 8;
    println!(
        "{:<48} {:>7} B vs {} B f64 ({:.1}× smaller)",
        "  -> W4 packed weight footprint",
        q4.codes.packed_bytes(),
        f64_bytes,
        f64_bytes as f64 / q4.codes.packed_bytes() as f64
    );
}
