//! Quantizer hot paths: per-token activation quant, RTN, GPTQ, transform
//! builders. Run: `cargo bench --bench quant_hot`

use catquant::linalg::{matmul_at_b, Mat, Rng};
use catquant::quant::{
    gptq_quantize, quantize_activations_per_token, quantize_weights_rtn, GptqConfig, QScheme,
    WeightQuantCfg,
};
use catquant::transforms::{cat_block, kronecker_cat};
use std::time::Instant;

fn time<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<48} {:>10.3} ms/iter", per * 1e3);
    per
}

fn main() {
    println!("== quantization hot paths ==");
    let mut rng = Rng::new(1);
    let x = Mat::from_fn(2048, 256, |_, _| rng.normal());
    let per = time("per-token dyn-asym quant (2048×256, 4b)", 20, || {
        std::hint::black_box(quantize_activations_per_token(&x, QScheme::asym(4), 1.0));
    });
    println!(
        "{:<48} {:>10.1} Mtok/s",
        "  -> token throughput",
        2048.0 / per / 1e6
    );

    let w = Mat::from_fn(512, 256, |_, _| rng.normal() * 0.05);
    time("RTN minmax (512×256, 4b)", 50, || {
        std::hint::black_box(quantize_weights_rtn(&w, WeightQuantCfg::minmax(4)));
    });
    time("RTN L2.4 clip search (512×256, 4b)", 3, || {
        std::hint::black_box(quantize_weights_rtn(&w, WeightQuantCfg::rtn_default(4)));
    });

    let sigma = {
        let mut s = matmul_at_b(&x, &x).scale(1.0 / 2048.0);
        s.add_diag(0.01);
        s
    };
    time("GPTQ (512×256, Σ 256×256, 4b)", 3, || {
        std::hint::black_box(gptq_quantize(
            &w,
            &sigma,
            WeightQuantCfg::minmax(4),
            GptqConfig::default(),
        ));
    });

    let sigma_w = matmul_at_b(&w, &w);
    time("CAT block build k=128 (d=256)", 3, || {
        std::hint::black_box(cat_block(&sigma, &sigma_w, 128, 0));
    });
    time("FlatQuant kronecker build (d=256)", 3, || {
        std::hint::black_box(kronecker_cat(&sigma, &sigma_w, 0));
    });
}
