//! Quantizer hot paths: per-token activation quant, RTN, GPTQ, transform
//! builders, the packed-integer vs dense-f64 serving A/B, and the
//! persistent-panel vs unpack-per-call decode-shape A/B.
//!
//! Run: `cargo bench --bench quant_hot` (full) or
//! `cargo bench --bench quant_hot -- --quick` (CI perf smoke: runs the
//! small-m panel A/B and the decode-shape qGEMV SIMD-vs-scalar A/B and
//! exits nonzero if persistent panels are not faster than per-call
//! unpacking, or if the SIMD integer path misses its multiplier — ≥2×
//! over the forced-scalar ISA on AVX2/AVX-512 hosts, >1× on NEON;
//! skipped, not failed, on hosts with no SIMD ISA).
//!
//! Both modes write `BENCH_quant.json` — a `meta` header (detected /
//! active ISA, `CATQUANT_SIMD`/`CATQUANT_THREADS`, worker count) plus
//! machine-readable `records` with a per-record `isa` field; CI uploads
//! the file as an artifact.

use catquant::linalg::{
    matmul_a_bt, par, qmatmul_a_bt, qmatmul_a_bt_panels, simd, syrk_at_a, Mat, QPanels, Rng,
};
use catquant::quant::{
    gptq_quantize, quantize_activations_per_token, quantize_weights_rtn, GptqConfig, QScheme,
    QuantizedTensor, WeightQuantCfg,
};
use catquant::transforms::{cat_block, kronecker_cat};
use std::time::Instant;

struct Rec {
    kernel: String,
    shape: String,
    /// The `linalg::simd` ISA active while this record was measured.
    isa: String,
    threads: usize,
    ms_per_iter: f64,
    speedup: f64,
}

/// Metadata header: where the numbers came from, so perf trajectories
/// are comparable across machines.
fn meta_json(bench: &str) -> String {
    let env_or = |k: &str| std::env::var(k).unwrap_or_else(|_| "unset".into());
    format!(
        "{{\"bench\": \"{bench}\", \"isa_detected\": \"{}\", \"isa_active\": \"{}\", \
         \"catquant_simd\": \"{}\", \"catquant_threads\": \"{}\", \"workers\": {}}}",
        simd::detected().name(),
        simd::active().name(),
        env_or("CATQUANT_SIMD"),
        env_or("CATQUANT_THREADS"),
        par::num_threads()
    )
}

fn write_json(path: &str, recs: &[Rec]) {
    let mut s = format!("{{\"meta\": {},\n \"records\": [\n", meta_json("quant_hot"));
    for (i, r) in recs.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"kernel\": \"{}\", \"shape\": \"{}\", \"isa\": \"{}\", \"threads\": {}, \
             \"ms_per_iter\": {:.6}, \"speedup\": {:.3}}}{}\n",
            r.kernel,
            r.shape,
            r.isa,
            r.threads,
            r.ms_per_iter,
            r.speedup,
            if i + 1 < recs.len() { "," } else { "" }
        ));
    }
    s.push_str("]}\n");
    match std::fs::write(path, s) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn time<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<48} {:>10.3} ms/iter", per * 1e3);
    per
}

/// Decode/prefill-shaped `qmatmul_a_bt` (small m, full output width):
/// per-call weight unpack vs persistent panels. This isolates the
/// persistent-panel win — the acceptance bar is ≥1.5× at small m.
/// Returns (t_per_call, t_panels).
fn small_m_panel_ab(
    m: usize,
    k: usize,
    n: usize,
    iters: usize,
    recs: &mut Vec<Rec>,
) -> (f64, f64) {
    let mut rng = Rng::new(77 + m as u64);
    let x = Mat::from_fn(m, k, |_, _| rng.normal());
    let w = Mat::from_fn(n, k, |_, _| rng.normal() * 0.05);
    let scheme = QScheme::asym(4);
    let xq = QuantizedTensor::quantize_acts(&x, scheme, 1.0);
    let wq = QuantizedTensor::quantize_acts(&w, scheme, 1.0);
    let panels: QPanels = wq.panels();
    // Effective worker count for this shape (decode shapes sit below
    // PAR_MIN_FMA and run serial) — what the JSON should attribute.
    let threads = catquant::linalg::par::threads_for(m * k * n, n);
    let t_call = time(
        &format!("qmatmul m={m} ({k}→{n}) per-call unpack"),
        iters,
        || {
            std::hint::black_box(qmatmul_a_bt(&xq.view(), &wq.view()));
        },
    );
    let t_panel = time(&format!("qmatmul m={m} ({k}→{n}) persistent panels"), iters, || {
        std::hint::black_box(qmatmul_a_bt_panels(&xq.view(), &wq.view(), &panels));
    });
    println!(
        "{:<48} {:>9.2}×",
        format!("  -> panel speedup m={m}"),
        t_call / t_panel
    );
    recs.push(Rec {
        kernel: "qmatmul_per_call".into(),
        shape: format!("{m}x{k}x{n}"),
        isa: simd::active().name().into(),
        threads,
        ms_per_iter: t_call * 1e3,
        speedup: 1.0,
    });
    recs.push(Rec {
        kernel: "qmatmul_panels".into(),
        shape: format!("{m}x{k}x{n}"),
        isa: simd::active().name().into(),
        threads,
        ms_per_iter: t_panel * 1e3,
        speedup: t_call / t_panel,
    });
    (t_call, t_panel)
}

/// Decode-shape qGEMV (persistent panels, small m) with the integer
/// kernel forced to the scalar ISA vs the best detected SIMD path —
/// the PR 6 acceptance measurement (`madd_epi16`/`vmlal` lanes vs the
/// 8-lane scalar dot). Returns `None` (skip, not fail) when the host
/// has no SIMD ISA.
fn qgemv_simd_vs_scalar(
    m: usize,
    k: usize,
    n: usize,
    iters: usize,
    recs: &mut Vec<Rec>,
) -> Option<(f64, f64)> {
    let best = simd::detected();
    if best == simd::Isa::Scalar {
        println!("qGEMV simd vs scalar: skipped (no SIMD ISA on this host)");
        return None;
    }
    let mut rng = Rng::new(177);
    let x = Mat::from_fn(m, k, |_, _| rng.normal());
    let w = Mat::from_fn(n, k, |_, _| rng.normal() * 0.05);
    let scheme = QScheme::asym(4);
    let xq = QuantizedTensor::quantize_acts(&x, scheme, 1.0);
    let wq = QuantizedTensor::quantize_acts(&w, scheme, 1.0);
    let panels: QPanels = wq.panels();
    let threads = par::threads_for(m * k * n, n);
    let prev = simd::active();
    simd::set_active(simd::Isa::Scalar);
    let t_scalar = time(&format!("qGEMV m={m} ({k}→{n}) ISA=scalar"), iters, || {
        std::hint::black_box(qmatmul_a_bt_panels(&xq.view(), &wq.view(), &panels));
    });
    simd::set_active(best);
    let t_simd = time(&format!("qGEMV m={m} ({k}→{n}) ISA={}", best.name()), iters, || {
        std::hint::black_box(qmatmul_a_bt_panels(&xq.view(), &wq.view(), &panels));
    });
    simd::set_active(prev);
    println!(
        "{:<48} {:>9.2}×",
        format!("  -> {} qGEMV speedup vs scalar ISA", best.name()),
        t_scalar / t_simd
    );
    recs.push(Rec {
        kernel: "qgemv_panels_scalar_isa".into(),
        shape: format!("{m}x{k}x{n}"),
        isa: "scalar".into(),
        threads,
        ms_per_iter: t_scalar * 1e3,
        speedup: 1.0,
    });
    recs.push(Rec {
        kernel: "qgemv_panels_simd_isa".into(),
        shape: format!("{m}x{k}x{n}"),
        isa: best.name().into(),
        threads,
        ms_per_iter: t_simd * 1e3,
        speedup: t_scalar / t_simd,
    });
    Some((t_scalar, t_simd))
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut recs: Vec<Rec> = Vec::new();
    println!("== quantization hot paths ==");
    println!(
        "simd: {} active, {} detected (CATQUANT_SIMD to force)\n",
        simd::active().name(),
        simd::detected().name()
    );

    if quick {
        // CI perf smoke: decode-shaped panel A/B plus the qGEMV
        // SIMD-vs-scalar A/B, both gated.
        let (t_call, t_panel) = small_m_panel_ab(4, 256, 512, 200, &mut recs);
        let simd_ab = qgemv_simd_vs_scalar(4, 256, 512, 300, &mut recs);
        write_json("BENCH_quant.json", &recs);
        if t_panel >= t_call {
            eprintln!(
                "PERF REGRESSION: persistent panels ({:.3} ms) not faster than per-call \
                 unpack ({:.3} ms) at the decode shape",
                t_panel * 1e3,
                t_call * 1e3
            );
            std::process::exit(1);
        }
        println!("perf smoke OK: panels are {:.2}× per-call unpack", t_call / t_panel);
        match simd_ab {
            None => println!("perf smoke: qGEMV simd gate skipped (no SIMD ISA)"),
            Some((t_scalar, t_simd)) => {
                // Acceptance: ≥2× on the wide-vector x86 paths; NEON's
                // 8-lane vmlal only has to beat the scalar kernel.
                let need = match simd::detected() {
                    simd::Isa::Avx2 | simd::Isa::Avx512 => 2.0,
                    _ => 1.0,
                };
                let got = t_scalar / t_simd;
                if got < need {
                    eprintln!(
                        "PERF REGRESSION: {} qGEMV is {got:.2}× the scalar ISA at the \
                         decode shape (gate: ≥{need:.1}×)",
                        simd::detected().name()
                    );
                    std::process::exit(1);
                }
                println!(
                    "perf smoke OK: {} qGEMV is {got:.2}× the scalar ISA (gate ≥{need:.1}×)",
                    simd::detected().name()
                );
            }
        }
        return;
    }

    let mut rng = Rng::new(1);
    let x = Mat::from_fn(2048, 256, |_, _| rng.normal());
    let per = time("per-token dyn-asym quant (2048×256, 4b)", 20, || {
        std::hint::black_box(quantize_activations_per_token(&x, QScheme::asym(4), 1.0));
    });
    println!(
        "{:<48} {:>10.1} Mtok/s",
        "  -> token throughput",
        2048.0 / per / 1e6
    );

    let w = Mat::from_fn(512, 256, |_, _| rng.normal() * 0.05);
    time("RTN minmax (512×256, 4b)", 50, || {
        std::hint::black_box(quantize_weights_rtn(&w, WeightQuantCfg::minmax(4)));
    });
    time("RTN L2.4 clip search (512×256, 4b)", 3, || {
        std::hint::black_box(quantize_weights_rtn(&w, WeightQuantCfg::rtn_default(4)));
    });

    let sigma = {
        let mut s = syrk_at_a(&x).scale(1.0 / 2048.0);
        s.add_diag(0.01);
        s
    };
    time("GPTQ (512×256, Σ 256×256, 4b)", 3, || {
        std::hint::black_box(gptq_quantize(
            &w,
            &sigma,
            WeightQuantCfg::minmax(4),
            GptqConfig::default(),
        ));
    });

    let sigma_w = syrk_at_a(&w);
    time("CAT block build k=128 (d=256)", 3, || {
        std::hint::black_box(cat_block(&sigma, &sigma_w, 128, 0));
    });
    time("FlatQuant kronecker build (d=256)", 3, || {
        std::hint::black_box(kronecker_cat(&sigma, &sigma_w, 0));
    });

    // ---- packed integer kernel vs dense f64 quant path (W4A4) ---------
    // Both sides include the per-token activation quantization, so this
    // A/B measures the full serving-path linear: dense = fake-quant f64
    // matmul over dequantized weights; packed = integer codes through
    // qmatmul_a_bt. Acceptance: packed beats dense at W4A4.
    println!("\n== packed vs dense quant linear (W4A4, 2048×256 · 512×256ᵀ) ==");
    let q4 = quantize_weights_rtn(&w, WeightQuantCfg::minmax(4));
    let wd = q4.deq();
    let act4 = QScheme::asym(4);
    let threads = catquant::linalg::par::threads_for(2048 * 256 * 512, 2048);
    let t_dense = time("dense: per-token quant + f64 matmul_a_bt", 10, || {
        let (xq, _) = quantize_activations_per_token(&x, act4, 1.0);
        std::hint::black_box(matmul_a_bt(&xq, &wd));
    });
    let t_packed = time("packed: quantize to codes + i32 qmatmul", 10, || {
        let xq = QuantizedTensor::quantize_acts(&x, act4, 1.0);
        std::hint::black_box(qmatmul_a_bt(&xq.view(), &q4.codes.view()));
    });
    println!("{:<48} {:>9.2}×", "  -> packed speedup vs dense", t_dense / t_packed);
    recs.push(Rec {
        kernel: "dense_fakequant_linear".into(),
        shape: "2048x256x512".into(),
        isa: simd::active().name().into(),
        threads,
        ms_per_iter: t_dense * 1e3,
        speedup: 1.0,
    });
    recs.push(Rec {
        kernel: "packed_qmatmul_linear".into(),
        shape: "2048x256x512".into(),
        isa: simd::active().name().into(),
        threads,
        ms_per_iter: t_packed * 1e3,
        speedup: t_dense / t_packed,
    });
    let wpanels = q4.codes.panels();
    let t_panels = time("packed + persistent panels (prefill shape)", 10, || {
        let xq = QuantizedTensor::quantize_acts(&x, act4, 1.0);
        std::hint::black_box(qmatmul_a_bt_panels(&xq.view(), &q4.codes.view(), &wpanels));
    });
    println!("{:<48} {:>9.2}×", "  -> panels speedup vs per-call", t_packed / t_panels);
    recs.push(Rec {
        kernel: "packed_qmatmul_panels_linear".into(),
        shape: "2048x256x512".into(),
        isa: simd::active().name().into(),
        threads,
        ms_per_iter: t_panels * 1e3,
        speedup: t_dense / t_panels,
    });
    let f64_bytes = w.rows() * w.cols() * 8;
    println!(
        "{:<48} {:>7} B vs {} B f64 ({:.1}× smaller; +{} B panels)",
        "  -> W4 packed weight footprint",
        q4.codes.packed_bytes(),
        f64_bytes,
        f64_bytes as f64 / q4.codes.packed_bytes() as f64,
        wpanels.bytes(),
    );

    // ---- persistent panels at decode/prefill shapes -------------------
    // The small-m path used to unpack (or stream-unpack) W on every
    // call; panels amortize that to zero. Acceptance: ≥1.5× at small m.
    println!("\n== persistent panels vs per-call unpack (W4A4, k=256, n=512) ==");
    for m in [1usize, 4, 16] {
        small_m_panel_ab(m, 256, 512, 400 / m.max(1), &mut recs);
    }

    // ---- SIMD ISA vs forced-scalar at decode shapes -------------------
    // The PR 6 acceptance A/B: explicit madd_epi16/vmlal lanes vs the
    // 8-lane scalar integer dot, per-call state flip, bit-identical out.
    println!("\n== qGEMV SIMD ISA vs scalar ISA (W4A4 panels, k=256, n=512) ==");
    for m in [1usize, 4, 16] {
        qgemv_simd_vs_scalar(m, 256, 512, 400 / m.max(1), &mut recs);
    }
    write_json("BENCH_quant.json", &recs);
}
