//! Serving throughput/latency bench on the **native** engine: batched
//! prefill + KV-cache decode, FP vs packed CAT-W4A4, with the
//! prefill/decode phase split and the O(T)-vs-O(T²) decode argument
//! measured rather than asserted.
//!
//! Also measures the server-boot question the artifact layer answers:
//! **artifact load vs calibration rebuild** wall-clock (bit-exactness
//! asserted), emitted as `BENCH_serve.json` for the CI perf record.
//!
//! Run: `cargo bench --bench serve_throughput` (add `-- --quick` for the
//! CI smoke configuration: tiny model, few tokens).
//!
//! A PJRT section (device-pack A/B) runs only when a compiled manifest is
//! present; the offline vendor stub skips it gracefully.

use catquant::calib::calibrate;
use catquant::coordinator::{
    BatcherCfg, ContinuousCfg, Coordinator, GenEngine, NativeGenerator, ReplicaCfg, ReplicaPool,
    SamplingCfg, ServeMetrics, StepEngine,
};
use catquant::model::{KvCache, KvPoolCfg, ModelConfig, NativeModel, QuantConfig};
use catquant::pipeline::{build_quant_config, QuantPlan, WeightQuantizer};
use catquant::runtime::{load_artifact, save_artifact, Chaos, ChaosPlan};
use std::time::{Duration, Instant};

fn bench_cfg(quick: bool) -> ModelConfig {
    if quick {
        ModelConfig {
            name: "smoke".into(),
            d: 32,
            n_layers: 2,
            n_heads: 4,
            ff: 64,
            seq: 48,
            vocab: 256,
        }
    } else {
        // seq 288 so the decode sweep reaches ≈256 with headroom.
        ModelConfig {
            name: "bench".into(),
            d: 128,
            n_layers: 4,
            n_heads: 4,
            ff: 256,
            seq: 288,
            vocab: 256,
        }
    }
}

fn tokens(n: usize, salt: usize) -> Vec<u8> {
    (0..n).map(|i| ((i * 31 + salt * 17 + 5) % 251) as u8).collect()
}

/// Per-token decode cost at several cache depths (flat ⇒ O(T) total), vs
/// the per-token cost of a full-recompute loop at the deepest checkpoint
/// (grows with T ⇒ O(T²) total).
fn decode_flatness(
    model: &NativeModel,
    qc: Option<&QuantConfig>,
    label: &str,
    checkpoints: &[usize],
    window: usize,
) {
    let prompt = tokens(8, 1);
    let (_, mut cache) = model.prefill(&prompt, qc);
    let step = |cache: &mut KvCache, s: usize| {
        let t = ((s * 13 + 7) % 251) as u8;
        let mut refs = vec![&mut *cache];
        std::hint::black_box(model.decode_step(&mut refs, &[t], qc));
    };
    let mut s = 0usize;
    let mut per_tok = Vec::new();
    for &cp in checkpoints {
        while cache.len() < cp {
            step(&mut cache, s);
            s += 1;
        }
        let t0 = Instant::now();
        for _ in 0..window {
            step(&mut cache, s);
            s += 1;
        }
        per_tok.push((cp, t0.elapsed().as_secs_f64() / window as f64));
    }
    let deepest = *checkpoints.last().unwrap();
    let seq = tokens(deepest, 2);
    // One full forward = the cost a recompute loop pays per token there.
    let iters = 3.max(window / 8);
    let t0 = Instant::now();
    for _ in 0..iters {
        match qc {
            None => std::hint::black_box(model.forward(&seq)),
            Some(qc) => std::hint::black_box(model.forward_quant(&seq, qc)),
        };
    }
    let recompute = t0.elapsed().as_secs_f64() / iters as f64;
    let (_, steady) = *per_tok.last().unwrap();
    print!("{label:<9} decode µs/tok:");
    for (cp, dt) in &per_tok {
        print!("  T={cp}: {:.1}", dt * 1e6);
    }
    println!(
        "  | recompute@T={deepest}: {:.1} µs/tok  speedup {:.1}×  kv={} B",
        recompute * 1e6,
        recompute / steady,
        cache.kv_bytes()
    );
}

/// Coordinator-driven serving: dynamic batching over the native engine.
fn serve_native(
    model: NativeModel,
    qc: Option<QuantConfig>,
    n_requests: usize,
    prompt_len: usize,
    max_new: usize,
    max_batch: usize,
) -> ServeMetrics {
    let mut coord = Coordinator::start(
        // The factory may be re-invoked to respawn after an engine panic,
        // so it clones rather than consumes the weights.
        move || {
            let sampling = SamplingCfg { temperature: 0.8, seed: 1 };
            let g: Box<dyn GenEngine> = match qc.clone() {
                Some(qc) => {
                    Box::new(NativeGenerator::quant(model.clone(), qc, max_batch, sampling))
                }
                None => Box::new(NativeGenerator::fp(model.clone(), max_batch, sampling)),
            };
            g
        },
        BatcherCfg { max_batch, max_wait: std::time::Duration::from_millis(5) },
    );
    let rxs: Vec<_> = (0..n_requests)
        .map(|i| coord.submit(tokens(prompt_len - (i % 3), 3 + i), max_new))
        .collect();
    for rx in rxs {
        rx.recv().expect("resp");
    }
    coord.shutdown()
}

/// §Continuous batching: the same open-loop Poisson workload served by
/// the static dynamic-batching coordinator vs the continuous scheduler
/// over the paged KV pool. Heterogeneous `max_new` is the point: static
/// batches decode every member to the batch-wide max and make arrivals
/// wait for batch formation; continuous sequences join mid-decode and
/// leave at their own length. Greedy outputs are asserted bit-identical
/// to per-sequence decode, and continuous must beat static on *useful*
/// decode rate (delivered tokens per decode second) and p95 latency —
/// the CI gate. Returns the `BENCH_serve.json` record.
fn open_loop_poisson(cfg: &ModelConfig, quick: bool) -> anyhow::Result<String> {
    let (n_req, plen, mean_gap_ms) =
        if quick { (10usize, 8usize, 2.0f64) } else { (24, 32, 8.0) };
    let (short, long) = if quick { (3usize, 10usize) } else { (4, 32) };
    let max_news: Vec<usize> =
        (0..n_req).map(|i| if i % 2 == 0 { short } else { long }).collect();
    let prompts: Vec<Vec<u8>> = (0..n_req).map(|i| tokens(plen - (i % 3), 60 + i)).collect();
    let sampling = SamplingCfg { temperature: 0.0, seed: 3 };

    // Greedy per-sequence reference: what every request must receive
    // bit-for-bit, no matter how it was batched or preempted.
    let mut reference = Vec::with_capacity(n_req);
    for (p, &mn) in prompts.iter().zip(&max_news) {
        let mut solo =
            NativeGenerator::fp(NativeModel::init_random(cfg.clone(), 7), 1, sampling);
        reference.push(solo.generate_batch(&[p.clone()], mn)?.remove(0));
    }

    // Seeded Poisson process: exponential inter-arrival gaps.
    let mut rng = catquant::linalg::Rng::new(0xA881);
    let mut arrivals = Vec::with_capacity(n_req);
    let mut t = 0.0f64;
    for _ in 0..n_req {
        t += -mean_gap_ms * (1.0 - rng.uniform()).ln();
        arrivals.push(std::time::Duration::from_secs_f64(t / 1e3));
    }

    let submit_all = |coord: &Coordinator| {
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(n_req);
        for i in 0..n_req {
            let now = t0.elapsed();
            if arrivals[i] > now {
                std::thread::sleep(arrivals[i] - now);
            }
            rxs.push(coord.submit(prompts[i].clone(), max_news[i]));
        }
        rxs
    };
    // Delivered tokens per decode second — static batching decodes
    // batch-wide max_new for everyone, so its wasted tail work shows up
    // here as a lower rate.
    let useful = |m: &ServeMetrics| {
        if m.engine.decode_time.is_zero() {
            0.0
        } else {
            m.tokens_out as f64 / m.engine.decode_time.as_secs_f64()
        }
    };

    // Arm A: static dynamic batching.
    let model = NativeModel::init_random(cfg.clone(), 7);
    let mut coord = Coordinator::start(
        move || Box::new(NativeGenerator::fp(model.clone(), 4, sampling)) as Box<dyn GenEngine>,
        BatcherCfg { max_batch: 4, max_wait: std::time::Duration::from_millis(5) },
    );
    for rx in submit_all(&coord) {
        rx.recv()?;
    }
    let stat = coord.shutdown();

    // Arm B: continuous scheduler over the paged pool (same weights,
    // same arrivals).
    let model = NativeModel::init_random(cfg.clone(), 7);
    let mut coord = Coordinator::start_continuous(
        move || {
            Box::new(NativeGenerator::fp(model.clone(), 4, sampling).with_serve_pool(
                KvPoolCfg::default(),
                true,
            )) as Box<dyn StepEngine>
        },
        ContinuousCfg::default(),
    );
    let outs: Result<Vec<Vec<u8>>, _> =
        submit_all(&coord).into_iter().map(|rx| rx.recv().map(|r| r.tokens)).collect();
    let cont = coord.shutdown();
    for (o, want) in outs?.iter().zip(&reference) {
        assert_eq!(o, want, "continuous batching must be bit-exact vs per-sequence decode");
    }

    let (s_rate, c_rate) = (useful(&stat), useful(&cont));
    let s_p95 = stat.request_latency.quantile(0.95);
    let c_p95 = cont.request_latency.quantile(0.95);
    println!(
        "open-loop poisson ({n_req} reqs, gap {mean_gap_ms} ms, max_new {short}/{long}):\n\
           static     useful {s_rate:.1} tok/s  p50 {:?} p95 {s_p95:?}\n\
           continuous useful {c_rate:.1} tok/s  p50 {:?} p95 {c_p95:?}  \
         (queue_mean {:.2}, kv_peak {} B, prefix_hit_rate {:.0}%, bit-exact)",
        stat.request_latency.quantile(0.5),
        cont.request_latency.quantile(0.5),
        cont.mean_queue_depth(),
        cont.kv_peak_bytes,
        cont.prefix_hit_rate() * 100.0,
    );
    assert!(
        c_rate > s_rate && c_p95 <= s_p95,
        "continuous must beat static: useful {c_rate:.1} vs {s_rate:.1} tok/s, \
         p95 {c_p95:?} vs {s_p95:?}"
    );
    Ok(format!(
        "  {{\"section\": \"open_loop\", \"quick\": {quick}, \"requests\": {n_req}, \
         \"mean_gap_ms\": {mean_gap_ms}, \"static_useful_tok_s\": {s_rate:.1}, \
         \"continuous_useful_tok_s\": {c_rate:.1}, \"static_p50_ms\": {:.3}, \
         \"continuous_p50_ms\": {:.3}, \"static_p95_ms\": {:.3}, \
         \"continuous_p95_ms\": {:.3}, \"preemptions\": {}, \"rejected\": {}, \
         \"prefix_hit_rate\": {:.3}, \"kv_peak_bytes\": {}, \"bit_exact\": true}}",
        stat.request_latency.quantile(0.5).as_secs_f64() * 1e3,
        cont.request_latency.quantile(0.5).as_secs_f64() * 1e3,
        s_p95.as_secs_f64() * 1e3,
        c_p95.as_secs_f64() * 1e3,
        cont.preemptions,
        cont.rejected,
        cont.prefix_hit_rate(),
        cont.kv_peak_bytes,
    ))
}

/// §Hedging A/B: one replica of two is a chaos-injected straggler
/// (every decode step sleeps), inflating the latency tail for whatever
/// lands on it. The same workload runs unhedged and hedged; hedging
/// must claw the p99 back — the CI gate — and, because outputs are
/// key-seeded and schedule-independent, must not move a bit. Returns
/// the `BENCH_serve.json` record.
fn hedging_ab(cfg: &ModelConfig, quick: bool) -> anyhow::Result<String> {
    let (n_req, plen, max_new) = if quick { (12usize, 8usize, 6usize) } else { (24, 16, 12) };
    let slow_ms: u64 = if quick { 15 } else { 25 };
    let hedge_ms: u64 = 5;
    let sampling = SamplingCfg { temperature: 0.0, seed: 3 };

    let run = |hedge: Option<Duration>| -> anyhow::Result<(ServeMetrics, Vec<Vec<u8>>)> {
        let model = NativeModel::init_random(cfg.clone(), 7);
        // Fresh chaos per run so the straggler schedule is identical in
        // both arms: replica 0 sleeps every decode step, replica 1 is
        // healthy.
        let chaos = [
            Chaos::new(ChaosPlan {
                slow_step_every: Some(1),
                slow_step_ms: slow_ms,
                ..Default::default()
            }),
            Chaos::off(),
        ];
        let mut pool = ReplicaPool::start(
            move |r, _plan| {
                Box::new(
                    NativeGenerator::fp(model.clone(), 4, sampling)
                        .with_serve_pool(KvPoolCfg::default(), false)
                        .with_chaos(chaos[r].clone()),
                ) as Box<dyn StepEngine>
            },
            ReplicaCfg { replicas: 2, hedge_after: hedge, ..Default::default() },
        );
        let rxs: Vec<_> = (0..n_req).map(|i| pool.submit(tokens(plen, 80 + i), max_new)).collect();
        let outs: Result<Vec<Vec<u8>>, _> =
            rxs.into_iter().map(|rx| rx.recv().map(|r| r.tokens)).collect();
        Ok((pool.shutdown(), outs?))
    };

    let (plain, plain_outs) = run(None)?;
    let (hedged, hedged_outs) = run(Some(Duration::from_millis(hedge_ms)))?;
    assert_eq!(plain_outs, hedged_outs, "hedging must not move a bit");
    let p_p99 = plain.request_latency.quantile(0.99);
    let h_p99 = hedged.request_latency.quantile(0.99);
    println!(
        "hedging a/b ({n_req} reqs, straggler {slow_ms} ms/step, hedge {hedge_ms} ms):\n\
           unhedged p50 {:?} p99 {p_p99:?}\n\
           hedged   p50 {:?} p99 {h_p99:?}  \
         (fired {}, won {}, bit-exact)",
        plain.request_latency.quantile(0.5),
        hedged.request_latency.quantile(0.5),
        hedged.hedges_fired,
        hedged.hedges_won,
    );
    assert!(hedged.hedges_fired >= 1, "the straggler must trigger hedges");
    // The CI gate: duplicating stragglers onto the healthy replica must
    // beat riding out the slow one on tail latency.
    assert!(h_p99 < p_p99, "hedging must beat no-hedging on p99: {h_p99:?} vs {p_p99:?}");
    Ok(format!(
        "  {{\"section\": \"hedging_ab\", \"quick\": {quick}, \"requests\": {n_req}, \
         \"straggler_slow_ms\": {slow_ms}, \"hedge_after_ms\": {hedge_ms}, \
         \"unhedged_p99_ms\": {:.3}, \"hedged_p99_ms\": {:.3}, \"p99_speedup\": {:.2}, \
         \"hedges_fired\": {}, \"hedges_won\": {}, \"bit_exact\": true}}",
        p_p99.as_secs_f64() * 1e3,
        h_p99.as_secs_f64() * 1e3,
        p_p99.as_secs_f64() / h_p99.as_secs_f64().max(1e-9),
        hedged.hedges_fired,
        hedged.hedges_won,
    ))
}

/// §Artifacts: what a serving process pays at boot — re-running
/// calibration + the pipeline vs loading the saved artifact. Asserts the
/// loaded config is bit-exact, reports both wall-clocks, and returns the
/// `BENCH_serve.json` record so the boot-cost trajectory is
/// machine-recorded per run.
fn artifact_vs_rebuild(cfg: &ModelConfig, quick: bool) -> anyhow::Result<String> {
    let model = NativeModel::init_random(cfg.clone(), 21);
    let n_seqs = if quick { 6 } else { 16 };
    let seqs: Vec<Vec<u8>> = (0..n_seqs).map(|i| tokens(cfg.seq.min(24), 40 + i)).collect();

    // Rebuild cost: what every boot paid before artifacts existed —
    // calibration forwards plus transform fits + weight quantization.
    let t0 = Instant::now();
    let calib = calibrate(&model, &seqs, 512, 0);
    let plan = QuantPlan::new()
        .transform("cat-block")
        .quantizer(WeightQuantizer::Rtn)
        .bits(4, 4)
        .cat_block(16)
        .seed(0);
    let (qc, rep) = build_quant_config(&model, &calib, &plan)?;
    let rebuild_ms = t0.elapsed().as_secs_f64() * 1e3;

    let dir = std::env::temp_dir().join(format!("catquant-serve-bench-{}", std::process::id()));
    let t0 = Instant::now();
    save_artifact(&qc, &rep, &dir)?;
    let save_ms = t0.elapsed().as_secs_f64() * 1e3;
    let artifact_bytes: u64 = ["artifact.json", "codes.bin"]
        .iter()
        .map(|f| std::fs::metadata(dir.join(f)).map(|m| m.len()).unwrap_or(0))
        .sum();

    // Best-of-3 load (page cache warm after the first).
    let mut load_ms = f64::INFINITY;
    let mut loaded = None;
    for _ in 0..3 {
        let t0 = Instant::now();
        let l = load_artifact(&dir, &model)?;
        load_ms = load_ms.min(t0.elapsed().as_secs_f64() * 1e3);
        loaded = Some(l);
    }
    let loaded = loaded.unwrap();
    let toks = tokens(12, 9);
    let diff = model.forward_quant(&toks, &qc).max_abs_diff(&model.forward_quant(&toks, &loaded));
    assert_eq!(diff, 0.0, "loaded artifact must serve bit-exactly");
    let _ = std::fs::remove_dir_all(&dir);

    println!(
        "artifact boot: rebuild {rebuild_ms:.1} ms vs load {load_ms:.2} ms ({:.0}× faster, \
         save {save_ms:.2} ms, {artifact_bytes} B on disk, bit-exact)",
        rebuild_ms / load_ms.max(1e-9)
    );
    Ok(format!(
        "  {{\"section\": \"artifact_boot\", \"quick\": {quick}, \"threads\": {}, \
         \"rebuild_ms\": {rebuild_ms:.3}, \"artifact_load_ms\": {load_ms:.3}, \
         \"artifact_save_ms\": {save_ms:.3}, \"load_speedup\": {:.1}, \
         \"artifact_bytes\": {artifact_bytes}}}",
        catquant::linalg::par::num_threads(),
        rebuild_ms / load_ms.max(1e-9)
    ))
}

/// Emit `BENCH_serve.json` (uploaded by CI). Same meta header shape as
/// BENCH_linalg/BENCH_quant: detected and active ISA plus the forcing
/// env knobs, so trajectories are comparable across machines.
fn write_bench_json(records: &[String]) {
    let env_or = |k: &str| std::env::var(k).unwrap_or_else(|_| "unset".into());
    let json = format!(
        "{{\"meta\": {{\"bench\": \"serve_throughput\", \"isa_detected\": \"{}\", \
         \"isa_active\": \"{}\", \"catquant_simd\": \"{}\", \"catquant_threads\": \"{}\", \
         \"workers\": {}}},\n \"records\": [\n{}\n]}}\n",
        catquant::linalg::simd::detected().name(),
        catquant::linalg::simd::active().name(),
        env_or("CATQUANT_SIMD"),
        env_or("CATQUANT_THREADS"),
        catquant::linalg::par::num_threads(),
        records.join(",\n")
    );
    match std::fs::write("BENCH_serve.json", json) {
        Ok(()) => println!("wrote BENCH_serve.json"),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
}

/// §Perf A/B (PJRT only): per-decode-call cost with the weight pack passed
/// as host literals vs device-resident buffers. Skipped without a manifest.
fn pjrt_pack_upload_ab() -> anyhow::Result<()> {
    use catquant::runtime::{token_literal, Manifest, PjrtEngine};
    let manifest = match Manifest::load(&Manifest::default_dir()) {
        Ok(m) => m,
        Err(e) => {
            println!("pjrt: skipped (no manifest: {e})");
            return Ok(());
        }
    };
    for model in ["tiny", "small", "base"] {
        let engine = PjrtEngine::new(manifest.clone())?;
        let entry = manifest.model(model)?.clone();
        let native = NativeModel::from_catw(entry.config.clone(), &entry.weights)?;
        let pack = catquant::runtime::ArgPack::fp(&entry, &native.params)?;
        let pack2 = catquant::runtime::ArgPack::fp(&entry, &native.params)?;
        let dev = engine.device_pack(pack2)?;
        let b = manifest.serve_batch;
        let prompts: Vec<Vec<u8>> = (0..b).map(|_| vec![1u8; manifest.prompt_len]).collect();
        let tok = token_literal(&prompts, manifest.prompt_len)?;
        let out = engine.run_b(model, "prefill_fp", &[&tok], &dev)?;
        let (kc, vc) = (&out[1], &out[2]);
        let ntok = token_literal(&vec![vec![1u8]; b], 1)?;
        let pos = xla::Literal::vec1(&[manifest.prompt_len as i32]);
        let iters = 20;
        let t0 = Instant::now();
        for _ in 0..iters {
            let mut args: Vec<&xla::Literal> = vec![&ntok, &pos, kc, vc];
            args.extend(pack.literals.iter());
            std::hint::black_box(engine.run(model, "decode_fp", &args)?);
        }
        let t_lit = t0.elapsed().as_secs_f64() / iters as f64;
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(engine.run_b(model, "decode_fp", &[&ntok, &pos, kc, vc], &dev)?);
        }
        let t_dev = t0.elapsed().as_secs_f64() / iters as f64;
        println!(
            "{model:<6} decode step: {:.2} ms literal-pack vs {:.2} ms device-pack ({:.2}×)",
            t_lit * 1e3,
            t_dev * 1e3,
            t_lit / t_dev
        );
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = bench_cfg(quick);
    let model = NativeModel::init_random(cfg.clone(), 7);
    let w4 = QuantConfig::identity_for_test(&model, 4);
    println!(
        "native serving bench: model d={} layers={} seq={} workers={} ({})",
        cfg.d,
        cfg.n_layers,
        cfg.seq,
        catquant::linalg::par::num_threads(),
        if quick { "quick" } else { "full" }
    );

    // 1. Decode cost flat in T, and the O(T) vs O(T²) speedup.
    let (checkpoints, window): (Vec<usize>, usize) =
        if quick { (vec![16, 32], 8) } else { (vec![64, 128, 256], 32) };
    decode_flatness(&model, None, "FP", &checkpoints, window);
    decode_flatness(&model, Some(&w4), "CAT-W4A4", &checkpoints, window);

    // 2. Coordinator serving with the prefill/decode phase split.
    let (n_req, plen, max_new) = if quick { (6, 12, 6) } else { (16, 64, 48) };
    for quantized in [false, true] {
        // The served model's own weights feed its QuantConfig — packed
        // codes and FP params must come from the same instance.
        let serve_model = NativeModel::init_random(cfg.clone(), 7);
        let qc = quantized.then(|| QuantConfig::identity_for_test(&serve_model, 4));
        let m = serve_native(serve_model, qc, n_req, plen, max_new, 4);
        println!("{:<9} {}", if quantized { "CAT-W4A4" } else { "FP" }, m.summary());
    }

    // 3. Open-loop Poisson arrivals: static vs continuous batching, with
    //    the continuous-beats-static gate and bit-exactness assertion.
    let open_record = open_loop_poisson(&cfg, quick)?;

    // 4. Replicated serving: hedging vs riding out a straggler replica,
    //    with the hedging-beats-p99 gate and bit-exactness assertion.
    let hedge_record = hedging_ab(&cfg, quick)?;

    // 5. Server boot: artifact load vs calibration rebuild (bit-exact).
    let boot_record = artifact_vs_rebuild(&cfg, quick)?;
    write_bench_json(&[boot_record, open_record, hedge_record]);

    // 6. PJRT device-pack A/B when a compiled manifest exists.
    if !quick {
        pjrt_pack_upload_ab()?;
    }
    Ok(())
}
