//! Serving throughput/latency bench: FP vs CAT-W4A4 through the
//! coordinator (batched prefill + KV-cache decode via PJRT).
//! Run: `cargo bench --bench serve_throughput`

use catquant::calib::Corpus;
use catquant::coordinator::{
    BatcherCfg, Coordinator, GenEngine, PjrtGenerator, SamplingCfg, ServeMetrics,
};
use catquant::experiments::load_zoo;
use catquant::pipeline::{build_quant_config, PipelineCfg, WeightQuantizer};
use catquant::runtime::{Manifest, PjrtEngine};
use catquant::transforms::TransformKind;
use std::rc::Rc;

fn serve(manifest: &Manifest, model: &str, quantized: bool, n: usize) -> ServeMetrics {
    let manifest2 = manifest.clone();
    let model2 = model.to_string();
    let coord = Coordinator::start(
        move || {
            let engine = Rc::new(PjrtEngine::new(manifest2.clone()).expect("engine"));
            let zoo = load_zoo(&manifest2, &model2, 0).expect("zoo");
            let sampling = SamplingCfg { temperature: 0.8, seed: 1 };
            let g: Box<dyn GenEngine> = if quantized {
                let (qc, _) = build_quant_config(
                    &zoo.model,
                    &zoo.calib,
                    PipelineCfg::w4a4(TransformKind::CatBlock, WeightQuantizer::Rtn, 0),
                );
                Box::new(
                    PjrtGenerator::quant(engine, &model2, &zoo.model.params, &qc, sampling)
                        .expect("gen"),
                )
            } else {
                Box::new(
                    PjrtGenerator::fp(engine, &model2, &zoo.model.params, sampling).expect("gen"),
                )
            };
            g
        },
        BatcherCfg::default(),
    );
    let corpus = Corpus::load(&manifest.corpus_eval).expect("corpus");
    let prompts = corpus.sample_sequences(n, manifest.prompt_len, 3);
    let rxs: Vec<_> = prompts.into_iter().map(|p| coord.submit(p, 24)).collect();
    for rx in rxs {
        rx.recv().expect("resp");
    }
    coord.shutdown()
}

/// §Perf A/B: per-decode-call cost with the weight pack passed as host
/// literals (old path, re-uploaded every call) vs device-resident buffers.
fn pack_upload_ab(manifest: &Manifest, model: &str) -> anyhow::Result<()> {
    use catquant::model::NativeModel;
    use catquant::runtime::token_literal;
    let engine = PjrtEngine::new(manifest.clone())?;
    let entry = manifest.model(model)?.clone();
    let native = NativeModel::from_catw(entry.config.clone(), &entry.weights)?;
    let pack = catquant::runtime::ArgPack::fp(&entry, &native.params)?;
    let pack2 = catquant::runtime::ArgPack::fp(&entry, &native.params)?;
    let dev = engine.device_pack(pack2)?;
    let b = manifest.serve_batch;
    let prompts: Vec<Vec<u8>> = (0..b).map(|_| vec![1u8; manifest.prompt_len]).collect();
    let tok = token_literal(&prompts, manifest.prompt_len)?;
    // Prefill once to get a kv cache.
    let out = engine.run_b(model, "prefill_fp", &[&tok], &dev)?;
    let (kc, vc) = (&out[1], &out[2]);
    let ntok = token_literal(&vec![vec![1u8]; b], 1)?;
    let pos = xla::Literal::vec1(&[manifest.prompt_len as i32]);

    let iters = 20;
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        let mut args: Vec<&xla::Literal> = vec![&ntok, &pos, kc, vc];
        args.extend(pack.literals.iter());
        std::hint::black_box(engine.run(model, "decode_fp", &args)?);
    }
    let t_lit = t0.elapsed().as_secs_f64() / iters as f64;
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        std::hint::black_box(engine.run_b(model, "decode_fp", &[&ntok, &pos, kc, vc], &dev)?);
    }
    let t_dev = t0.elapsed().as_secs_f64() / iters as f64;
    println!(
        "{model:<6} decode step: {:.2} ms literal-pack vs {:.2} ms device-pack ({:.2}×)",
        t_lit * 1e3,
        t_dev * 1e3,
        t_lit / t_dev
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    for model in ["tiny", "small", "base"] {
        pack_upload_ab(&manifest, model)?;
    }
    for model in ["tiny", "small", "base"] {
        for quantized in [false, true] {
            let m = serve(&manifest, model, quantized, 16);
            println!(
                "{model:<6} {:<9} {}",
                if quantized { "CAT-W4A4" } else { "FP" },
                m.summary()
            );
        }
    }
    Ok(())
}
