//! Bench/regenerator for Figure 2 (Theorem 2.4 verification).
//! Run: `cargo bench --bench fig2_sqnr_approx`

use catquant::experiments::run_fig2;
use catquant::runtime::Manifest;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let t0 = Instant::now();
    let pts = run_fig2(&manifest, &["tiny", "small"], 0)?;
    println!(
        "\n[bench] fig2 regenerated: {} points in {:.2}s",
        pts.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
