//! Bench/regenerator for Table 1 (end-to-end W4A4 grid).
//!
//! Default is the quick grid; pass `--full` for the full 3-model,
//! 4-seed, RTN+GPTQ grid (tens of minutes on the single-core testbed).
//! Run: `cargo bench --bench table1_e2e [-- --full]`

use catquant::experiments::{run_table1, Table1Opts};
use catquant::runtime::Manifest;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let opts = if full { Table1Opts::default() } else { Table1Opts::quick() };
    let t0 = Instant::now();
    let cells = run_table1(&manifest, &opts)?;
    println!(
        "\n[bench] table1 regenerated: {} cells in {:.1}s ({})",
        cells.len(),
        t0.elapsed().as_secs_f64(),
        if full { "full" } else { "quick" }
    );
    Ok(())
}
