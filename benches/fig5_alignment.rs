//! Bench/regenerator for Figure 5 (alignment under transforms vs optimum).
//! Run: `cargo bench --bench fig5_alignment`

use catquant::experiments::run_fig5;
use catquant::runtime::Manifest;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let t0 = Instant::now();
    let rows = run_fig5(&manifest, &["tiny", "small"], 0)?;
    println!(
        "\n[bench] fig5 regenerated: {} rows in {:.2}s",
        rows.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
