//! Bench/regenerator for Figure 6 (W4A4 under transforms vs W6A6) and
//! Figure 3 (the bit-width plane).
//! Run: `cargo bench --bench fig6_joint_sqnr`

use catquant::experiments::{run_fig3, run_fig6};
use catquant::runtime::Manifest;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let t0 = Instant::now();
    run_fig3(&manifest, "small", 0)?;
    let rows = run_fig6(&manifest, &["tiny", "small"], 0)?;
    println!(
        "\n[bench] fig3+fig6 regenerated: {} rows in {:.2}s",
        rows.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
