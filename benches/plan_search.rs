//! Planner search: searched-vs-uniform SQNR under an equal byte budget,
//! plus the wall-clock cost of the search itself.
//!
//! Run: `cargo bench --bench plan_search` (full: sweeps the uniform
//! bit grid for the identity and cat-block baselines alongside the
//! searched plan) or `cargo bench --bench plan_search -- --quick` (CI
//! perf smoke: searched vs best uniform only, and exits nonzero if the
//! searched plan does not achieve strictly higher measured SQNR than the
//! best uniform-identity plan at the same budget, or overruns it).
//!
//! Both modes write `BENCH_plan.json` — a `meta` header plus one record
//! per plan row (`plan`, `recipe`, `w_bits`, `bytes`, `approx_db`,
//! `measured_db`, `search_ms`); CI uploads the file as an artifact.

use catquant::calib::calibrate;
use catquant::linalg::{par, simd, Rng};
use catquant::model::{ModelConfig, NativeModel};
use catquant::pipeline::{
    best_uniform_plan, build_quant_config, measured_plan_sqnr_db, plan_bytes, search_plan, Budget,
    PlannerCfg, QuantPlan,
};
use std::time::Instant;

struct Rec {
    plan: String,
    recipe: String,
    /// "mixed" for the searched plan, the uniform width otherwise.
    w_bits: String,
    bytes: usize,
    /// Mean per-group approx SQNR (Theorem 2.4), dB.
    approx_db: f64,
    /// Measured mean SQNR over the calibration sample, dB.
    measured_db: f64,
    /// Search wall-clock (0 for uniform baselines — there is no search).
    search_ms: f64,
}

fn meta_json(bench: &str) -> String {
    let env_or = |k: &str| std::env::var(k).unwrap_or_else(|_| "unset".into());
    format!(
        "{{\"bench\": \"{bench}\", \"isa_detected\": \"{}\", \"isa_active\": \"{}\", \
         \"catquant_simd\": \"{}\", \"catquant_threads\": \"{}\", \"workers\": {}}}",
        simd::detected().name(),
        simd::active().name(),
        env_or("CATQUANT_SIMD"),
        env_or("CATQUANT_THREADS"),
        par::num_threads()
    )
}

fn write_json(path: &str, recs: &[Rec]) {
    let mut s = format!("{{\"meta\": {},\n \"records\": [\n", meta_json("plan_search"));
    for (i, r) in recs.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"plan\": \"{}\", \"recipe\": \"{}\", \"w_bits\": \"{}\", \"bytes\": {}, \
             \"approx_db\": {:.4}, \"measured_db\": {:.4}, \"search_ms\": {:.3}}}{}\n",
            r.plan,
            r.recipe,
            r.w_bits,
            r.bytes,
            r.approx_db,
            r.measured_db,
            r.search_ms,
            if i + 1 < recs.len() { "," } else { "" }
        ));
    }
    s.push_str("]}\n");
    match std::fs::write(path, s) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// The fixture the pipeline tests use: a tiny random model plus a seeded
/// synthetic calibration set — big enough for the group structure to
/// matter, small enough for CI.
fn setup() -> (NativeModel, catquant::calib::CalibStats) {
    let cfg = ModelConfig {
        name: "bench".into(),
        d: 32,
        n_layers: 2,
        n_heads: 4,
        ff: 64,
        seq: 16,
        vocab: 256,
    };
    let model = NativeModel::init_random(cfg, 11);
    let mut rng = Rng::new(5);
    let seqs: Vec<Vec<u8>> =
        (0..8).map(|_| (0..16).map(|_| rng.below(256) as u8).collect()).collect();
    let calib = calibrate(&model, &seqs, 256, 0);
    (model, calib)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut recs: Vec<Rec> = Vec::new();
    println!("== planner search: searched vs uniform at equal bytes ==\n");

    let (model, calib) = setup();
    // Equal-bytes comparison point: what uniform W4 costs.
    let budget = plan_bytes(&model, &QuantPlan::new()).unwrap();
    let mut cfg = PlannerCfg::new(Budget::Size { max_bytes: budget });
    cfg.cat_block = 8;
    // Skip spinquant (its seed search dominates wall-clock at this size)
    // but keep both adaptive recipes in the pool.
    cfg.recipes =
        ["identity", "quarot", "cat-block", "cat-block-permuted", "wush-adaptive", "fpt-merged"]
            .iter()
            .map(|s| s.to_string())
            .collect();

    let t0 = Instant::now();
    let planned = search_plan(&model, &calib, &cfg).expect("search failed");
    let search_ms = t0.elapsed().as_secs_f64() * 1e3;
    let (qc, _rep) = planned.build(&model, &calib).expect("build failed");
    let searched_bytes = qc.packed_bytes();
    let searched_measured = measured_plan_sqnr_db(&model, &calib, &qc);
    println!(
        "searched: {} B of {} B budget, approx {:.2} dB/group, measured {:.2} dB, {:.0} ms",
        searched_bytes,
        budget,
        planned.score_db / planned.decisions.len() as f64,
        searched_measured,
        search_ms
    );
    for d in &planned.decisions {
        println!("  {:<8} {}", d.group.key(), d.cell.summary());
    }
    recs.push(Rec {
        plan: "searched".into(),
        recipe: "searched".into(),
        w_bits: "mixed".into(),
        bytes: searched_bytes,
        approx_db: planned.score_db / planned.decisions.len() as f64,
        measured_db: searched_measured,
        search_ms,
    });

    // Uniform baselines at the same budget: largest uniform width that
    // fits, per recipe.
    let mut identity_measured = f64::NEG_INFINITY;
    for recipe in ["identity", "cat-block"] {
        let Some((b, up)) = best_uniform_plan(&model, &cfg, recipe) else {
            println!("uniform {recipe}: nothing fits the budget");
            continue;
        };
        let (uqc, urep) = build_quant_config(&model, &calib, &up).expect("uniform build");
        let measured = measured_plan_sqnr_db(&model, &calib, &uqc);
        if recipe == "identity" {
            identity_measured = measured;
        }
        println!(
            "uniform {recipe} W{b}: {} B, approx {:.2} dB, measured {:.2} dB",
            uqc.packed_bytes(),
            urep.mean_sqnr_db,
            measured
        );
        recs.push(Rec {
            plan: format!("uniform-{recipe}"),
            recipe: recipe.into(),
            w_bits: b.to_string(),
            bytes: uqc.packed_bytes(),
            approx_db: urep.mean_sqnr_db,
            measured_db: measured,
            search_ms: 0.0,
        });
    }

    if !quick {
        // Full mode: the uniform bit trajectory for both baselines, so
        // BENCH_plan.json carries the whole frontier, not just the
        // budget-feasible points.
        for recipe in ["identity", "cat-block"] {
            for b in [2u32, 3, 4, 6, 8] {
                let up = QuantPlan::new()
                    .transform(recipe)
                    .bits(b, b.max(cfg.min_act_bits))
                    .cat_block(cfg.cat_block)
                    .seed(cfg.seed);
                let (uqc, urep) = build_quant_config(&model, &calib, &up).expect("build");
                let measured = measured_plan_sqnr_db(&model, &calib, &uqc);
                println!(
                    "grid    {recipe} W{b}: {} B, approx {:.2} dB, measured {:.2} dB",
                    uqc.packed_bytes(),
                    urep.mean_sqnr_db,
                    measured
                );
                recs.push(Rec {
                    plan: format!("grid-{recipe}-w{b}"),
                    recipe: recipe.into(),
                    w_bits: b.to_string(),
                    bytes: uqc.packed_bytes(),
                    approx_db: urep.mean_sqnr_db,
                    measured_db: measured,
                    search_ms: 0.0,
                });
            }
        }
    }

    write_json("BENCH_plan.json", &recs);

    // The PR 10 acceptance gate: under the equal byte budget the searched
    // plan must beat the best uniform plan on *measured* SQNR, and must
    // actually fit.
    if searched_bytes > budget {
        eprintln!(
            "PLAN REGRESSION: searched plan is {searched_bytes} B, over the {budget} B budget"
        );
        std::process::exit(1);
    }
    if searched_measured <= identity_measured {
        eprintln!(
            "PLAN REGRESSION: searched plan measured {searched_measured:.2} dB does not beat \
             the best uniform-identity plan ({identity_measured:.2} dB) at equal bytes"
        );
        std::process::exit(1);
    }
    println!(
        "\nplan gate OK: searched {searched_measured:.2} dB > uniform identity \
         {identity_measured:.2} dB at {searched_bytes}/{budget} B"
    );
}
