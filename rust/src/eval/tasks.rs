//! Six synthetic zero-shot tasks — the LM-harness substitute.
//!
//! Each task is multiple-choice continuation ranking (the mechanics of
//! PIQA/HellaSwag/LAMBADA): given a context from the held-out corpus,
//! score the true continuation against 3 distractors by total
//! log-likelihood under the model; accuracy = fraction where the truth
//! ranks first. The six variants differ in context/continuation lengths —
//! longer contexts reward models whose long-range statistics survive
//! quantization, mirroring how the real suite spans difficulty.

use super::SeqLogits;
use crate::calib::Corpus;
use crate::linalg::Rng;
use crate::model::softmax_row;
use anyhow::Result;

/// (name, context length, continuation length).
pub const TASK_SPECS: [(&str, usize, usize); 6] = [
    ("ctx16-c4", 16, 4),
    ("ctx32-c4", 32, 4),
    ("ctx32-c8", 32, 8),
    ("ctx48-c8", 48, 8),
    ("ctx64-c4", 64, 4),
    ("ctx64-c8", 64, 8),
];

/// One task's outcome.
#[derive(Clone, Debug)]
pub struct TaskResult {
    pub name: String,
    pub accuracy: f64,
    pub n_items: usize,
}

/// Build and score all six tasks. `n_items` questions per task,
/// deterministic per seed (the same items are used for every model
/// configuration — paired comparison, as with a fixed benchmark).
pub fn zero_shot_suite(
    engine: &dyn SeqLogits,
    corpus: &Corpus,
    n_items: usize,
    seed: u64,
) -> Result<Vec<TaskResult>> {
    let mut results = Vec::new();
    for (ti, (name, ctx_len, cont_len)) in TASK_SPECS.iter().enumerate() {
        let mut rng = Rng::new(seed ^ ((ti as u64 + 1) * 0x7A5C5));
        let mut correct = 0usize;
        // Build all items, score in batches.
        let mut items = Vec::with_capacity(n_items);
        for _ in 0..n_items {
            let windows = corpus.sample_sequences(5, ctx_len + cont_len, rng.next_u64());
            let context = windows[0][..*ctx_len].to_vec();
            let truth = windows[0][*ctx_len..].to_vec();
            // Distractors: continuations harvested from elsewhere.
            let distractors: Vec<Vec<u8>> =
                windows[1..4].iter().map(|w| w[*ctx_len..].to_vec()).collect();
            items.push((context, truth, distractors));
        }
        for (context, truth, distractors) in &items {
            let mut seqs = Vec::with_capacity(4);
            let mut full = context.clone();
            full.extend(truth);
            seqs.push(full);
            for d in distractors {
                let mut f = context.clone();
                f.extend(d);
                seqs.push(f);
            }
            let logits = engine.logits(&seqs)?;
            let scores: Vec<f64> = seqs
                .iter()
                .zip(&logits)
                .map(|(s, l)| continuation_ll(s, l, context.len()))
                .collect();
            let best = scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if best == 0 {
                correct += 1;
            }
        }
        results.push(TaskResult {
            name: name.to_string(),
            accuracy: correct as f64 / n_items as f64,
            n_items,
        });
    }
    Ok(results)
}

/// Total log-likelihood of `seq[ctx..]` under the logits.
fn continuation_ll(seq: &[u8], logits: &crate::linalg::Mat, ctx: usize) -> f64 {
    let mut ll = 0.0;
    for t in ctx - 1..seq.len() - 1 {
        let mut row = logits.row(t).to_vec();
        softmax_row(&mut row);
        ll += row[seq[t + 1] as usize].max(1e-30).ln();
    }
    ll
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::NativeLogits;
    use crate::model::{ModelConfig, NativeModel};

    fn corpus() -> Corpus {
        // Deterministic structured stream: next = prev + 1 mod 199, with
        // occasional jumps — learnable-ish, definitely non-uniform.
        let mut t = Vec::with_capacity(30_000);
        let mut v = 1u32;
        for i in 0..30_000 {
            v = if i % 97 == 0 { (v * 7 + 3) % 199 } else { (v + 1) % 199 };
            t.push(v as u8);
        }
        Corpus::from_tokens(t)
    }

    #[test]
    fn random_model_near_chance() {
        let cfg = ModelConfig {
            name: "t".into(),
            d: 32,
            n_layers: 1,
            n_heads: 2,
            ff: 64,
            seq: 128,
            vocab: 256,
        };
        let model = NativeModel::init_random(cfg, 2);
        let eng = NativeLogits { model: &model, qc: None };
        let res = zero_shot_suite(&eng, &corpus(), 12, 0).unwrap();
        assert_eq!(res.len(), 6);
        let mean: f64 = res.iter().map(|r| r.accuracy).sum::<f64>() / 6.0;
        // Chance is 0.25; a random model should not be systematically
        // far above it.
        assert!(mean < 0.7, "mean {mean}");
    }

    #[test]
    fn suite_deterministic_given_seed() {
        let cfg = ModelConfig {
            name: "t".into(),
            d: 32,
            n_layers: 1,
            n_heads: 2,
            ff: 64,
            seq: 128,
            vocab: 256,
        };
        let model = NativeModel::init_random(cfg, 3);
        let eng = NativeLogits { model: &model, qc: None };
        let a = zero_shot_suite(&eng, &corpus(), 6, 1).unwrap();
        let b = zero_shot_suite(&eng, &corpus(), 6, 1).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.accuracy, y.accuracy);
        }
    }
}
