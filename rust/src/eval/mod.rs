//! Evaluation harnesses: perplexity + the six zero-shot tasks
//! (the WikiText / LM-harness substitutes — DESIGN.md §3).

mod engines;
mod perplexity;
mod tasks;

pub use engines::{NativeLogits, PjrtLogits, SeqLogits};
pub use perplexity::{perplexity, perplexity_subset};
pub use tasks::{zero_shot_suite, TaskResult, TASK_SPECS};
