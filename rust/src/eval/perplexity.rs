//! Perplexity evaluation (the WikiText-2 column of Table 1).

use super::SeqLogits;
use crate::model::softmax_row;
use anyhow::Result;

/// Next-token perplexity over evaluation windows:
/// `exp( − mean_t log p(x_{t+1} | x_{≤t}) )`.
pub fn perplexity(engine: &dyn SeqLogits, windows: &[Vec<u8>]) -> Result<f64> {
    anyhow::ensure!(!windows.is_empty(), "no evaluation windows");
    let mut nll = 0.0;
    let mut count = 0usize;
    for batch in windows.chunks(8) {
        let logits = engine.logits(batch)?;
        for (w, l) in batch.iter().zip(&logits) {
            for t in 0..w.len() - 1 {
                let mut row = l.row(t).to_vec();
                softmax_row(&mut row);
                let p = row[w[t + 1] as usize].max(1e-30);
                nll -= p.ln();
                count += 1;
            }
        }
    }
    Ok((nll / count as f64).exp())
}

/// Perplexity over the first `n` windows (the experiment grid's quick
/// setting).
pub fn perplexity_subset(
    engine: &dyn SeqLogits,
    windows: &[Vec<u8>],
    n: usize,
) -> Result<f64> {
    perplexity(engine, &windows[..n.min(windows.len())])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::NativeLogits;
    use crate::model::{ModelConfig, NativeModel};

    fn tiny() -> NativeModel {
        let cfg = ModelConfig {
            name: "t".into(),
            d: 32,
            n_layers: 1,
            n_heads: 2,
            ff: 64,
            seq: 16,
            vocab: 256,
        };
        NativeModel::init_random(cfg, 1)
    }

    #[test]
    fn random_model_near_uniform_ppl() {
        let model = tiny();
        let eng = NativeLogits { model: &model, qc: None };
        let windows: Vec<Vec<u8>> = (0..4)
            .map(|i| (0..16).map(|t| ((i * 37 + t * 11) % 256) as u8).collect())
            .collect();
        let ppl = perplexity(&eng, &windows).unwrap();
        // An untrained model should sit near vocab-size perplexity.
        assert!(ppl > 100.0 && ppl < 600.0, "ppl {ppl}");
    }

    #[test]
    fn perplexity_deterministic() {
        let model = tiny();
        let eng = NativeLogits { model: &model, qc: None };
        let windows: Vec<Vec<u8>> = vec![vec![1; 16], vec![2; 16]];
        assert_eq!(
            perplexity(&eng, &windows).unwrap(),
            perplexity(&eng, &windows).unwrap()
        );
    }

    #[test]
    fn subset_uses_fewer_windows() {
        let model = tiny();
        let eng = NativeLogits { model: &model, qc: None };
        let windows: Vec<Vec<u8>> = (0..6).map(|i| vec![(i * 3) as u8; 16]).collect();
        let full = perplexity(&eng, &windows).unwrap();
        let sub = perplexity_subset(&eng, &windows, 2).unwrap();
        assert!(full.is_finite() && sub.is_finite());
    }
}
