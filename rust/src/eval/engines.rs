//! Logits engines: the abstraction the evaluators run on.

use crate::linalg::Mat;
use crate::model::{NativeModel, QuantConfig};
use crate::runtime::{token_literal, ArgPack, DevicePack, PjrtEngine};
use anyhow::Result;

/// Anything that maps token sequences to per-position logits.
pub trait SeqLogits {
    /// Full-sequence logits for each input (each `[len, vocab]`).
    /// Implementations may pad internally; outputs match input lengths.
    fn logits(&self, seqs: &[Vec<u8>]) -> Result<Vec<Mat>>;

    fn vocab(&self) -> usize;
}

/// Native-engine logits (FP or quantized).
pub struct NativeLogits<'a> {
    pub model: &'a NativeModel,
    pub qc: Option<&'a QuantConfig>,
}

impl SeqLogits for NativeLogits<'_> {
    fn logits(&self, seqs: &[Vec<u8>]) -> Result<Vec<Mat>> {
        // Sequences are independent full forwards — fan them out across
        // the worker pool (perplexity batches run ~#workers× faster).
        let jobs: Vec<usize> = (0..seqs.len()).collect();
        Ok(crate::linalg::par::par_map(
            jobs,
            crate::linalg::par::num_threads(),
            |i| match self.qc {
                None => self.model.forward(&seqs[i]),
                Some(qc) => self.model.forward_quant(&seqs[i], qc),
            },
        ))
    }

    fn vocab(&self) -> usize {
        self.model.cfg.vocab
    }
}

/// PJRT logits through a compiled full-sequence graph
/// (`logits_fp` / `logits_a{bits}`), batching to the graph width and
/// padding sequences to the graph length (causality makes padding safe).
pub struct PjrtLogits {
    engine: std::rc::Rc<PjrtEngine>,
    model: String,
    graph: String,
    pack: DevicePack,
    batch: usize,
    seq: usize,
    vocab: usize,
}

impl PjrtLogits {
    pub fn fp(
        engine: std::rc::Rc<PjrtEngine>,
        model: &str,
        params: &std::collections::HashMap<String, Mat>,
    ) -> Result<PjrtLogits> {
        let entry = engine.manifest().model(model)?.clone();
        let pack = ArgPack::fp(&entry, params)?;
        Self::new(engine, model, "logits_fp", pack)
    }

    /// Quantized graph at the pipeline's activation bit width.
    pub fn quant(
        engine: std::rc::Rc<PjrtEngine>,
        model: &str,
        params: &std::collections::HashMap<String, Mat>,
        qc: &QuantConfig,
        bits_a: u32,
    ) -> Result<PjrtLogits> {
        let entry = engine.manifest().model(model)?.clone();
        let pack = ArgPack::quant(&entry, params, qc)?;
        Self::new(engine, model, &format!("logits_a{bits_a}"), pack)
    }

    fn new(
        engine: std::rc::Rc<PjrtEngine>,
        model: &str,
        graph: &str,
        pack: ArgPack,
    ) -> Result<PjrtLogits> {
        let m = engine.manifest().model(model)?;
        let g = m
            .graphs
            .get(graph)
            .ok_or_else(|| anyhow::anyhow!("graph {graph} missing for {model}"))?;
        // §Perf: upload the weight pack once per eval config.
        let pack = engine.device_pack(pack)?;
        Ok(PjrtLogits {
            model: model.to_string(),
            graph: graph.to_string(),
            pack,
            batch: g.batch,
            seq: m.config.seq,
            vocab: m.config.vocab,
            engine,
        })
    }
}

impl SeqLogits for PjrtLogits {
    fn logits(&self, seqs: &[Vec<u8>]) -> Result<Vec<Mat>> {
        let mut out = Vec::with_capacity(seqs.len());
        for chunk in seqs.chunks(self.batch) {
            // Pad sequences to graph length, batch to graph width.
            let mut padded: Vec<Vec<u8>> = chunk
                .iter()
                .map(|s| {
                    anyhow::ensure!(s.len() <= self.seq, "sequence longer than graph");
                    let mut p = s.clone();
                    p.resize(self.seq, 0);
                    Ok(p)
                })
                .collect::<Result<_>>()?;
            while padded.len() < self.batch {
                padded.push(vec![0; self.seq]);
            }
            let tok = token_literal(&padded, self.seq)?;
            let res = self.engine.run_b(&self.model, &self.graph, &[&tok], &self.pack)?;
            let flat: Vec<f32> = res[0].to_vec()?;
            for (i, s) in chunk.iter().enumerate() {
                let full = &flat[i * self.seq * self.vocab..(i + 1) * self.seq * self.vocab];
                out.push(Mat::from_f32(s.len(), self.vocab, &full[..s.len() * self.vocab]));
            }
        }
        Ok(out)
    }

    fn vocab(&self) -> usize {
        self.vocab
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    #[test]
    fn native_logits_shapes() {
        let cfg = ModelConfig {
            name: "t".into(),
            d: 32,
            n_layers: 1,
            n_heads: 2,
            ff: 64,
            seq: 16,
            vocab: 256,
        };
        let model = NativeModel::init_random(cfg, 1);
        let eng = NativeLogits { model: &model, qc: None };
        let out = eng.logits(&[vec![1, 2, 3], vec![4, 5, 6, 7]]).unwrap();
        assert_eq!(out[0].rows(), 3);
        assert_eq!(out[1].rows(), 4);
        assert_eq!(out[0].cols(), 256);
    }
}
