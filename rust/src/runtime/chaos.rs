//! Deterministic chaos injection for the serving stack.
//!
//! A [`ChaosPlan`] names faults by *count*, not by chance: fail the Nth
//! KV page allocation, panic during decode step K, corrupt the first N
//! artifact loads, sleep on every Mth step. Because every trigger is an
//! atomic counter against a fixed plan, a fault schedule replays
//! identically run after run — the property tests in
//! `chaos_serve_props` rely on that to pin recovery paths bit-exactly.
//!
//! The [`Chaos`] handle is an `Option<Arc<state>>`: a disabled handle
//! (the default everywhere) costs one pointer-null check per seam and
//! allocates nothing. Seams live in `KvPagePool` (allocation failure),
//! `NativeGenerator::step` (panic + slow step), and
//! [`load_artifact_with`](crate::runtime::load_artifact_with) (byte
//! corruption). Production binaries opt in with `--chaos SPEC` or
//! `CATQUANT_CHAOS=SPEC`.

use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

/// One artifact-load fault. Positions are taken modulo the file length,
/// so a plan built from a seed never misses the buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactFault {
    /// XOR `0xFF` into the manifest byte at this position.
    FlipManifestByte(usize),
    /// XOR `0xFF` into the code-blob byte at this position.
    FlipBlobByte(usize),
    /// Truncate the code blob to this length.
    TruncateBlob(usize),
}

/// A deterministic fault schedule. All counters are 0-based and global
/// per handle (cloned handles share state).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ChaosPlan {
    /// Fail these page allocations (0-based allocation index).
    pub fail_allocs: Vec<u64>,
    /// Additionally fail every Nth allocation (1 = every allocation).
    pub fail_alloc_every: Option<u64>,
    /// Panic once inside decode at each of these engine steps. Each
    /// entry fires exactly once — the retry after recovery proceeds —
    /// so these model *transient* faults.
    pub panic_steps: Vec<u64>,
    /// Panic whenever this engine-local sequence id is in the decode
    /// group — a *persistent* fault that only quarantine can clear.
    pub panic_seq: Option<u64>,
    /// Sleep on every Nth engine step.
    pub slow_step_every: Option<u64>,
    /// How long a slow step sleeps, in milliseconds.
    pub slow_step_ms: u64,
    /// Corrupt artifact bytes at load time.
    pub artifact_fault: Option<ArtifactFault>,
    /// How many load attempts the artifact fault applies to (the
    /// retry-then-succeed boot path is testable with a finite count).
    pub artifact_fault_loads: u64,
}

#[derive(Debug, Default)]
struct ChaosState {
    plan: ChaosPlan,
    allocs: AtomicU64,
    steps: AtomicU64,
    loads: AtomicU64,
    /// `panic_steps` entries that already fired (one-shot semantics).
    fired_steps: Mutex<Vec<u64>>,
}

/// Shareable handle to a fault schedule; `Chaos::off()` (the default)
/// injects nothing and costs one branch per seam.
#[derive(Clone, Debug, Default)]
pub struct Chaos {
    state: Option<Arc<ChaosState>>,
}

impl Chaos {
    /// The no-fault handle every production path starts from.
    pub fn off() -> Chaos {
        Chaos::default()
    }

    pub fn new(plan: ChaosPlan) -> Chaos {
        Chaos { state: Some(Arc::new(ChaosState { plan, ..Default::default() })) }
    }

    /// Build from `CATQUANT_CHAOS` (absent or empty → off). Lenient:
    /// malformed or out-of-context clauses are *warned to stderr and
    /// skipped* — a typo in the environment must not silently disarm the
    /// whole plan, nor crash a production boot. (The `Result` is kept
    /// for call-site stability; this never errors.)
    pub fn from_env() -> Result<Chaos> {
        match std::env::var("CATQUANT_CHAOS") {
            Ok(s) if !s.trim().is_empty() => Ok(Chaos::parse_lenient(&s, None)),
            _ => Ok(Chaos::off()),
        }
    }

    /// Parse a comma-separated `key=value` spec, e.g.
    /// `fail_alloc=3,fail_alloc=9,panic_step=2,slow_every=4,slow_ms=2`.
    ///
    /// Keys: `fail_alloc` (repeatable), `fail_alloc_every`,
    /// `panic_step` (repeatable), `panic_seq`, `slow_every`, `slow_ms`,
    /// `flip_manifest`, `flip_blob`, `trunc_blob`, `fault_loads`.
    ///
    /// Strict and unscoped: any malformed clause is an error, and
    /// replica-scoped keys (`panic_seq@r1`) are rejected — use
    /// [`Chaos::parse_scoped`] when building per-replica plans.
    pub fn parse(spec: &str) -> Result<Chaos> {
        Chaos::parse_scoped(spec, None)
    }

    /// [`Chaos::parse`] with a replica scope: a key may carry an `@rN`
    /// suffix (`panic_seq@r1`, `slow_every@r0`) and then applies only
    /// when parsing for replica `N` — one spec arms a whole fleet, each
    /// replica extracting its own plan. Out-of-scope clauses are still
    /// fully validated (a typo'd key never hides behind a scope).
    /// Scoped keys with `replica == None` are an error: there is no
    /// replica for them to name.
    pub fn parse_scoped(spec: &str, replica: Option<usize>) -> Result<Chaos> {
        let mut plan = ChaosPlan::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            Chaos::apply_clause(&mut plan, part, replica)?;
        }
        if plan.artifact_fault.is_some() && plan.artifact_fault_loads == 0 {
            plan.artifact_fault_loads = 1;
        }
        Ok(Chaos::new(plan))
    }

    /// [`Chaos::parse_scoped`] that warns to stderr and skips bad
    /// clauses instead of failing — the environment-variable path, where
    /// an error would otherwise silently disable every fault.
    pub fn parse_lenient(spec: &str, replica: Option<usize>) -> Chaos {
        let mut plan = ChaosPlan::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            if let Err(e) = Chaos::apply_clause(&mut plan, part, replica) {
                eprintln!("warning: ignoring CATQUANT_CHAOS clause `{part}`: {e}");
            }
        }
        if plan.artifact_fault.is_some() && plan.artifact_fault_loads == 0 {
            plan.artifact_fault_loads = 1;
        }
        Chaos::new(plan)
    }

    /// Validate one `key[@rN]=value` clause and apply it to `plan` if it
    /// is in scope for `replica` (out-of-scope clauses are validated
    /// against a scratch plan and dropped).
    fn apply_clause(plan: &mut ChaosPlan, part: &str, replica: Option<usize>) -> Result<()> {
        let (key, val) = match part.split_once('=') {
            Some(kv) => kv,
            None => bail!("chaos spec entry `{part}` is not key=value"),
        };
        let (key, scope) = match key.trim().split_once('@') {
            Some((k, s)) => {
                let r: usize = match s.trim().strip_prefix('r').and_then(|d| d.parse().ok()) {
                    Some(r) => r,
                    None => bail!("chaos scope `@{s}` is not `@rN`"),
                };
                (k.trim(), Some(r))
            }
            None => (key.trim(), None),
        };
        let n: u64 = match val.trim().parse() {
            Ok(n) => n,
            Err(_) => bail!("chaos spec `{key}` value `{val}` is not an integer"),
        };
        let mut scratch = ChaosPlan::default();
        let plan = match scope {
            None => plan,
            Some(r) => match replica {
                None => bail!("replica-scoped chaos key `{key}@r{r}` outside replicated serving"),
                Some(me) if me == r => plan,
                Some(_) => &mut scratch,
            },
        };
        match key {
            "fail_alloc" => plan.fail_allocs.push(n),
            "fail_alloc_every" => plan.fail_alloc_every = Some(n.max(1)),
            "panic_step" => plan.panic_steps.push(n),
            "panic_seq" => plan.panic_seq = Some(n),
            "slow_every" => plan.slow_step_every = Some(n.max(1)),
            "slow_ms" => plan.slow_step_ms = n,
            "flip_manifest" => {
                plan.artifact_fault = Some(ArtifactFault::FlipManifestByte(n as usize))
            }
            "flip_blob" => plan.artifact_fault = Some(ArtifactFault::FlipBlobByte(n as usize)),
            "trunc_blob" => plan.artifact_fault = Some(ArtifactFault::TruncateBlob(n as usize)),
            "fault_loads" => plan.artifact_fault_loads = n,
            other => bail!("unknown chaos spec key `{other}`"),
        }
        Ok(())
    }

    pub fn enabled(&self) -> bool {
        self.state.is_some()
    }

    /// Called by the pool on every page allocation attempt; `true`
    /// means the allocation must be refused (counted as a failed
    /// alloc by the pool, like a budget miss).
    pub fn fail_this_alloc(&self) -> bool {
        let Some(st) = &self.state else { return false };
        let n = st.allocs.fetch_add(1, Ordering::Relaxed);
        if st.plan.fail_allocs.contains(&n) {
            return true;
        }
        match st.plan.fail_alloc_every {
            Some(k) => (n + 1) % k == 0,
            None => false,
        }
    }

    /// Called once per top-level engine step; returns the 0-based step
    /// index this handle has seen (bisect retries reuse the index, so
    /// per-step faults key off the *scheduler* tick, not the retry).
    pub fn next_step(&self) -> u64 {
        match &self.state {
            Some(st) => st.steps.fetch_add(1, Ordering::Relaxed),
            None => 0,
        }
    }

    /// Decode-time injection point: sleeps on slow steps, panics per
    /// plan. Must be called *inside* the engine's `catch_unwind` region
    /// with the ids of the decode group.
    pub fn on_decode(&self, step: u64, ids: &[u64]) {
        let Some(st) = &self.state else { return };
        if let Some(every) = st.plan.slow_step_every {
            if step % every == 0 && st.plan.slow_step_ms > 0 {
                std::thread::sleep(std::time::Duration::from_millis(st.plan.slow_step_ms));
            }
        }
        if let Some(seq) = st.plan.panic_seq {
            if ids.contains(&seq) {
                panic!("chaos: injected panic for sequence {seq}");
            }
        }
        if st.plan.panic_steps.contains(&step) {
            let mut fired = st.fired_steps.lock().unwrap_or_else(PoisonError::into_inner);
            if !fired.contains(&step) {
                fired.push(step);
                drop(fired);
                panic!("chaos: injected panic at step {step}");
            }
        }
    }

    /// Artifact-load injection point: counts the attempt and returns
    /// the fault to apply to it, if any.
    pub fn artifact_fault(&self) -> Option<ArtifactFault> {
        let st = self.state.as_ref()?;
        let n = st.loads.fetch_add(1, Ordering::Relaxed);
        if n < st.plan.artifact_fault_loads {
            st.plan.artifact_fault
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_handle_injects_nothing() {
        let c = Chaos::off();
        assert!(!c.enabled());
        for _ in 0..32 {
            assert!(!c.fail_this_alloc());
        }
        c.on_decode(c.next_step(), &[0, 1, 2]);
        assert_eq!(c.artifact_fault(), None);
    }

    #[test]
    fn alloc_faults_fire_at_planned_indices() {
        let c = Chaos::new(ChaosPlan {
            fail_allocs: vec![1, 4],
            fail_alloc_every: Some(10),
            ..Default::default()
        });
        let fails: Vec<bool> = (0..12).map(|_| c.fail_this_alloc()).collect();
        let want: Vec<bool> = (0..12u64).map(|n| n == 1 || n == 4 || (n + 1) % 10 == 0).collect();
        assert_eq!(fails, want);
    }

    #[test]
    fn panic_step_fires_exactly_once() {
        let c = Chaos::new(ChaosPlan { panic_steps: vec![1], ..Default::default() });
        let s0 = c.next_step();
        c.on_decode(s0, &[0]); // step 0: nothing
        let s1 = c.next_step();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| c.on_decode(s1, &[0])));
        assert!(r.is_err(), "step 1 must panic");
        // Retry at the same step index (the bisect path) proceeds.
        c.on_decode(s1, &[0]);
    }

    #[test]
    fn panic_seq_is_persistent() {
        let c = Chaos::new(ChaosPlan { panic_seq: Some(7), ..Default::default() });
        for _ in 0..3 {
            let s = c.next_step();
            let r =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| c.on_decode(s, &[3, 7])));
            assert!(r.is_err(), "seq 7 in group must always panic");
            c.on_decode(s, &[3]); // group without 7 is fine
        }
    }

    #[test]
    fn artifact_fault_applies_to_first_n_loads() {
        let c = Chaos::new(ChaosPlan {
            artifact_fault: Some(ArtifactFault::FlipBlobByte(5)),
            artifact_fault_loads: 2,
            ..Default::default()
        });
        assert_eq!(c.artifact_fault(), Some(ArtifactFault::FlipBlobByte(5)));
        assert_eq!(c.artifact_fault(), Some(ArtifactFault::FlipBlobByte(5)));
        assert_eq!(c.artifact_fault(), None);
    }

    #[test]
    fn spec_round_trips_and_rejects_garbage() {
        let c = Chaos::parse("fail_alloc=3, panic_step=2,slow_every=4,slow_ms=1").unwrap();
        assert!(c.enabled());
        assert!(Chaos::parse("bogus_key=1").is_err());
        assert!(Chaos::parse("fail_alloc").is_err());
        assert!(Chaos::parse("fail_alloc=x").is_err());
        // A lone artifact fault defaults to faulting the first load.
        let c = Chaos::parse("flip_blob=9").unwrap();
        assert_eq!(c.artifact_fault(), Some(ArtifactFault::FlipBlobByte(9)));
        assert_eq!(c.artifact_fault(), None);
    }

    #[test]
    fn scoped_clauses_apply_only_to_their_replica() {
        let spec = "panic_seq@r1=7, fail_alloc@r0=0, slow_ms=2";
        // Replica 1 gets the persistent panic but not replica 0's alloc
        // fault; the unscoped clause reaches everyone.
        let c1 = Chaos::parse_scoped(spec, Some(1)).unwrap();
        let s = c1.next_step();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| c1.on_decode(s, &[7])));
        assert!(r.is_err(), "scoped panic_seq must fire on replica 1");
        assert!(!c1.fail_this_alloc(), "replica 0's alloc fault leaked to replica 1");
        let c0 = Chaos::parse_scoped(spec, Some(0)).unwrap();
        let s = c0.next_step();
        c0.on_decode(s, &[7]); // no panic: the seq fault is r1-only
        assert!(c0.fail_this_alloc());
    }

    #[test]
    fn scoped_clause_validation_is_strict() {
        // Scoped keys outside replicated serving are an error, as are
        // malformed scopes and typo'd keys hiding behind a scope.
        assert!(Chaos::parse("panic_seq@r1=7").is_err());
        assert!(Chaos::parse_scoped("panic_seq@x1=7", Some(0)).is_err());
        assert!(Chaos::parse_scoped("bogus_key@r1=7", Some(0)).is_err(), "out-of-scope clauses must still be validated");
    }

    #[test]
    fn lenient_parse_keeps_good_clauses_and_drops_bad_ones() {
        // The env path: a typo warns (to stderr) and is skipped; the
        // rest of the plan still arms.
        let c = Chaos::parse_lenient("bogus_key=1, panic_seq=7, fail_alloc=oops", None);
        assert!(c.enabled());
        let s = c.next_step();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| c.on_decode(s, &[7])));
        assert!(r.is_err(), "valid clause must survive lenient parsing");
        assert!(!c.fail_this_alloc(), "malformed clause must be dropped");
    }
}
