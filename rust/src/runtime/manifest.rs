//! The artifact manifest (written by `python/compile/aot.py`).

use super::json::Json;
use crate::model::ModelConfig;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One lowered graph.
#[derive(Clone, Debug)]
pub struct GraphEntry {
    pub name: String,
    pub file: PathBuf,
    pub batch: usize,
    pub bits: Option<u32>,
}

/// One model's artifact bundle.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub config: ModelConfig,
    pub weights: PathBuf,
    pub graphs: BTreeMap<String, GraphEntry>,
}

/// The full manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub corpus_train: PathBuf,
    pub corpus_eval: PathBuf,
    pub vocab: usize,
    pub calib_batch: usize,
    pub eval_batch: usize,
    pub serve_batch: usize,
    pub prompt_len: usize,
    pub models: BTreeMap<String, ModelEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let conv = j.at("conventions")?;
        let corpus = j.at("corpus")?;
        let mut models = BTreeMap::new();
        for (name, mj) in j.at("models")?.as_obj()? {
            let cj = mj.at("config")?;
            let config = ModelConfig {
                name: name.clone(),
                d: cj.at("d")?.as_usize()?,
                n_layers: cj.at("n_layers")?.as_usize()?,
                n_heads: cj.at("n_heads")?.as_usize()?,
                ff: cj.at("ff")?.as_usize()?,
                seq: cj.at("seq")?.as_usize()?,
                vocab: cj.at("vocab")?.as_usize()?,
            };
            let mut graphs = BTreeMap::new();
            for (gname, gj) in mj.at("graphs")?.as_obj()? {
                graphs.insert(
                    gname.clone(),
                    GraphEntry {
                        name: gname.clone(),
                        file: dir.join(gj.at("file")?.as_str()?),
                        batch: gj.at("batch")?.as_usize()?,
                        bits: gj.get("bits").map(|b| b.as_f64().unwrap_or(0.0) as u32),
                    },
                );
            }
            models.insert(
                name.clone(),
                ModelEntry {
                    config,
                    weights: dir.join(mj.at("weights")?.as_str()?),
                    graphs,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            corpus_train: dir.join(corpus.at("train")?.as_str()?),
            corpus_eval: dir.join(corpus.at("eval")?.as_str()?),
            vocab: corpus.at("vocab")?.as_usize()?,
            calib_batch: conv.at("calib_batch")?.as_usize()?,
            eval_batch: conv.at("eval_batch")?.as_usize()?,
            serve_batch: conv.at("serve_batch")?.as_usize()?,
            prompt_len: conv.at("prompt_len")?.as_usize()?,
            models,
        })
    }

    /// Default artifact location (`./artifacts`, overridable via
    /// `CATQUANT_ARTIFACTS`).
    pub fn default_dir() -> PathBuf {
        std::env::var("CATQUANT_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model {name} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest_if_present() {
        // Integration-level check, skipped when artifacts are not built.
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.models.contains_key("tiny"));
        let tiny = m.model("tiny").unwrap();
        assert_eq!(tiny.config.d, 64);
        assert!(tiny.graphs.contains_key("logits_fp"));
        assert!(tiny.graphs["logits_fp"].file.exists());
        assert!(tiny.weights.exists());
    }
}
