//! Artifact runtime: manifest parsing, quantization artifacts, and PJRT
//! execution.
//!
//! The request path is `Rust → PJRT CPU client → compiled HLO`; python is
//! build-time only. [`PjrtEngine`] loads `artifacts/hlo/*.hlo.txt` (HLO
//! *text* — see `python/compile/aot.py` for why not serialized protos),
//! compiles each graph once, and executes with weights/transforms as
//! runtime arguments so one executable serves every quantization config.
//!
//! [`save_artifact`] / [`load_artifact`] persist a built
//! [`QuantConfig`](crate::model::QuantConfig) so serving processes load
//! prebuilt transforms + packed codes in milliseconds instead of
//! re-running calibration and GPTQ at boot.

mod artifact;
pub mod chaos;
mod engine;
pub mod json;
mod manifest;

pub use artifact::{
    brownout_dir, load_artifact, load_artifact_retry, load_artifact_with, save_artifact,
    ARTIFACT_VERSION,
};
pub use chaos::{ArtifactFault, Chaos, ChaosPlan};
pub use engine::{literal_to_mat, token_literal, ArgPack, DevicePack, PjrtEngine};
pub use manifest::{GraphEntry, Manifest, ModelEntry};
