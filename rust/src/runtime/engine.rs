//! The PJRT execution engine.
//!
//! Wraps the `xla` crate: HLO text → `HloModuleProto` → compile on the CPU
//! PJRT client → execute. Graphs compile lazily and are cached; weights
//! and transforms are packed once per quantization config ([`ArgPack`])
//! and reused across calls, so the request-path cost is one host-to-device
//! copy of the small activations plus the compiled computation.

use super::manifest::{Manifest, ModelEntry};
use crate::linalg::Mat;
use crate::model::QuantConfig;
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;

/// A pre-converted argument bundle (params [+ transforms]) in graph order.
pub struct ArgPack {
    pub literals: Vec<xla::Literal>,
}

impl ArgPack {
    /// FP pack: the model parameters in `param_spec` order.
    pub fn fp(model: &ModelEntry, params: &HashMap<String, Mat>) -> Result<ArgPack> {
        let mut literals = Vec::new();
        for (name, shape) in model.config.param_spec() {
            let m = params
                .get(&name)
                .ok_or_else(|| anyhow::anyhow!("missing param {name}"))?;
            literals.push(mat_literal(m, &shape)?);
        }
        Ok(ArgPack { literals })
    }

    /// Quantized pack: packed weights dequantized once per pack build
    /// where available (FP params elsewhere), followed by the transforms
    /// in `transform_spec` order — the graphs consume dense f32 runtime
    /// args, so this is the one seam that still materializes f64 mats
    /// from the codes.
    ///
    /// The compiled quant graphs (`*_a4`) quantize activations at a
    /// *baked-in* uniform asymmetric A4, so every PJRT consumer of a
    /// `QuantConfig` funnels through this check: mixed-precision or
    /// non-A4 plans are rejected here instead of served/evaluated with
    /// numerics that match neither the plan nor the native engine.
    pub fn quant(
        model: &ModelEntry,
        params: &HashMap<String, Mat>,
        qc: &QuantConfig,
    ) -> Result<ArgPack> {
        let act = qc.uniform_act().ok_or_else(|| {
            anyhow::anyhow!(
                "the compiled A4 graphs cannot serve a mixed-precision config; \
                 use the native engine for per-group activation plans"
            )
        })?;
        anyhow::ensure!(
            act.scheme.bits == 4 && !act.scheme.symmetric,
            "the compiled A4 graphs expect asymmetric 4-bit activations, got {}-bit {}",
            act.scheme.bits,
            if act.scheme.symmetric { "symmetric" } else { "asymmetric" }
        );
        let mut literals = Vec::new();
        for (name, shape) in model.config.param_spec() {
            let lit = match qc.linear_named(&name) {
                Some(lin) => mat_literal(&lin.deq(), &shape)?,
                None => {
                    let m = params
                        .get(&name)
                        .ok_or_else(|| anyhow::anyhow!("missing param {name}"))?;
                    mat_literal(m, &shape)?
                }
            };
            literals.push(lit);
        }
        for (name, shape) in model.config.transform_spec() {
            let t = qc
                .transforms
                .get(&name)
                .ok_or_else(|| anyhow::anyhow!("missing transform {name}"))?;
            literals.push(mat_literal(t, &shape)?);
        }
        Ok(ArgPack { literals })
    }
}

/// Convert an analysis matrix to an f32 literal of the given logical shape.
fn mat_literal(m: &Mat, shape: &[usize]) -> Result<xla::Literal> {
    let data = m.to_f32();
    let expect: usize = shape.iter().product();
    anyhow::ensure!(
        data.len() == expect,
        "literal size mismatch: {} vs shape {:?}",
        data.len(),
        shape
    );
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(&data).reshape(&dims)?)
}

/// Tokens (`batch × seq`, u8 ids) as an i32 literal.
pub fn token_literal(tokens: &[Vec<u8>], seq: usize) -> Result<xla::Literal> {
    let b = tokens.len();
    let mut flat = Vec::with_capacity(b * seq);
    for row in tokens {
        anyhow::ensure!(row.len() == seq, "token row length {} != {seq}", row.len());
        flat.extend(row.iter().map(|&t| t as i32));
    }
    Ok(xla::Literal::vec1(&flat).reshape(&[b as i64, seq as i64])?)
}

/// The PJRT engine: one CPU client, a lazy cache of compiled graphs.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtEngine {
    pub fn new(manifest: Manifest) -> Result<PjrtEngine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtEngine { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) a graph: key `"<model>/<graph>"`.
    pub fn executable(
        &self,
        model: &str,
        graph: &str,
    ) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        let key = format!("{model}/{graph}");
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let entry = self.manifest.model(model)?;
        let g = entry
            .graphs
            .get(graph)
            .ok_or_else(|| anyhow::anyhow!("graph {graph} not in manifest for {model}"))?;
        let proto = xla::HloModuleProto::from_text_file(&g.file)
            .with_context(|| format!("parsing {}", g.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {key}"))?;
        let rc = std::rc::Rc::new(exe);
        self.cache.borrow_mut().insert(key, rc.clone());
        Ok(rc)
    }

    /// Execute a graph; returns the flattened tuple outputs.
    pub fn run(
        &self,
        model: &str,
        graph: &str,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(model, graph)?;
        // `execute` accepts any Borrow<Literal>, so borrowed args avoid
        // re-copying the (large, cached) weight literals per call.
        let bufs = exe.execute(inputs)?;
        let out = bufs[0][0].to_literal_sync()?;
        Ok(out.to_tuple()?)
    }

    /// Upload an argument pack to device buffers once (§Perf: the weight
    /// pack dominates per-call host→device traffic; a `base` decode step
    /// would otherwise re-upload ~16 MB of weights per generated token).
    ///
    /// Consumes the pack: the TFRT CPU client may *alias* the literal's
    /// host memory instead of copying (zero-copy donation), so the
    /// literals must stay alive as long as the buffers — [`DevicePack`]
    /// owns both.
    pub fn device_pack(&self, pack: ArgPack) -> Result<DevicePack> {
        let buffers = pack
            .literals
            .iter()
            .map(|l| Ok(self.client.buffer_from_host_literal(None, l)?))
            .collect::<Result<Vec<_>>>()?;
        Ok(DevicePack { buffers, _literals: pack })
    }

    /// Execute with per-call literals (`head`) + a device-resident tail
    /// (the uploaded pack). Argument order: head first, pack after —
    /// matching every graph's `tokens[, pos, kv...], params[, transforms]`
    /// convention.
    pub fn run_b(
        &self,
        model: &str,
        graph: &str,
        head: &[&xla::Literal],
        pack: &DevicePack,
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(model, graph)?;
        // `head` literals outlive the call (borrowed), so aliased
        // host-memory buffers are safe here too.
        let mut args: Vec<xla::PjRtBuffer> = Vec::with_capacity(head.len());
        for l in head {
            args.push(self.client.buffer_from_host_literal(None, l)?);
        }
        let mut refs: Vec<&xla::PjRtBuffer> = args.iter().collect();
        refs.extend(pack.buffers.iter());
        let bufs = exe.execute_b(&refs)?;
        let out = bufs[0][0].to_literal_sync()?;
        Ok(out.to_tuple()?)
    }
}

/// Device-resident argument pack: the uploaded buffers plus the host
/// literals they may alias (TFRT CPU zero-copy).
pub struct DevicePack {
    pub buffers: Vec<xla::PjRtBuffer>,
    _literals: ArgPack,
}

/// Extract an output literal into a `rows × cols` matrix (f32 source).
pub fn literal_to_mat(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Mat> {
    let v: Vec<f32> = lit.to_vec()?;
    anyhow::ensure!(v.len() == rows * cols, "literal size {} != {rows}×{cols}", v.len());
    Ok(Mat::from_f32(rows, cols, &v))
}
