//! Minimal JSON parser + writer (no external crates in this environment).
//!
//! Supports the subset the artifact manifest and experiment reports use:
//! objects, arrays, strings (with escapes), f64 numbers, bools, null.

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` chain with a useful error.
    pub fn at(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object"),
        }
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.skip_ws();
        if self.i >= self.b.len() {
            bail!("unexpected end of input");
        }
        Ok(self.b[self.i])
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            if self.i >= self.b.len() {
                bail!("unterminated string");
            }
            let c = self.b[self.i];
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.b.get(self.i).copied().ok_or_else(|| anyhow::anyhow!("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape at byte {}", self.i);
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // Collect the full UTF-8 sequence.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    if start + len > self.b.len() {
                        bail!("truncated UTF-8 sequence at byte {start}");
                    }
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        self.skip_ws();
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let text = r#"{"version": 1, "models": {"tiny": {"config": {"d": 64},
            "graphs": {"logits_fp": {"file": "hlo/x.txt", "batch": 4}}}},
            "list": [1, 2.5, -3e2, true, null, "sA"]}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.at("version").unwrap().as_usize().unwrap(), 1);
        let d = j.at("models").unwrap().at("tiny").unwrap().at("config").unwrap();
        assert_eq!(d.at("d").unwrap().as_usize().unwrap(), 64);
        let l = j.at("list").unwrap().as_arr().unwrap();
        assert_eq!(l[2], Json::Num(-300.0));
        assert_eq!(l[5], Json::Str("sA".into()));
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"a":[1,2,{"b":"x\"y"}],"c":null,"d":false,"e":1.25}"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(j, Json::Str("héllo ☃".into()));
    }
}
