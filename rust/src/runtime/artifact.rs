//! Serializable quantization artifacts.
//!
//! A built [`QuantConfig`] is expensive — calibration forwards, transform
//! fits, GPTQ column sweeps — but a *server* should pay that once,
//! offline. [`save_artifact`] persists a config as:
//!
//! * `artifact.json` — a versioned manifest: the resolved-plan echo and
//!   report, per-group activation schemes, every transform matrix, and
//!   per-linear metadata (shape, scheme, per-row scales / zero-points /
//!   code-sums, blob offsets). Numbers are written with Rust's
//!   shortest-round-trip float formatting, so every f64 reparses
//!   bit-exactly.
//! * `codes.bin` — one little-endian blob of the packed integer codes,
//!   FNV-1a-checksummed by the manifest. The manifest checksums *itself*
//!   too (`manifest_fnv64` over the canonical dump minus that key), so a
//!   flipped digit in a scale or transform entry is rejected at load,
//!   not served. Both files are written to temp names and renamed, so a
//!   kill mid-save never leaves a manifest that points at missing or
//!   half-written data.
//!
//! [`load_artifact`] validates the version, the blob checksum and
//! length, and every shape against the serving model, then rebuilds the
//! packed tensors *and their persistent kernel panels* — the loaded
//! config is bit-exact against the in-memory build (`forward_quant`,
//! prefill/decode: diff == 0.0), at a wall-clock cost of reading bytes
//! rather than re-running the pipeline.

use crate::linalg::Mat;
use crate::model::{LinearId, NativeModel, QuantConfig, QuantizedLinear, ALL_GROUPS};
use crate::pipeline::PipelineReport;
use crate::quant::{ActQuantCfg, QScheme, QuantizedTensor};
use crate::runtime::chaos::{ArtifactFault, Chaos};
use crate::runtime::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::path::Path;

/// Artifact format version — bumped on any incompatible layout change;
/// the loader refuses other versions.
pub const ARTIFACT_VERSION: usize = 1;

const ARTIFACT_FORMAT: &str = "catquant.artifact";
const MANIFEST_FILE: &str = "artifact.json";
const CODES_FILE: &str = "codes.bin";
/// Manifest key holding the manifest's own checksum. The checksum is
/// computed over the canonical dump of the manifest *without* this key;
/// the loader removes it, re-dumps (parse→dump is byte-stable for
/// manifests produced by [`save_artifact`]), and compares.
const MANIFEST_FNV_KEY: &str = "manifest_fnv64";

/// FNV-1a over the code blob — cheap corruption detection.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn scheme_json(scheme: QScheme, clip_ratio: Option<f64>) -> Json {
    let mut m = BTreeMap::new();
    m.insert("bits".to_string(), Json::Num(scheme.bits as f64));
    m.insert("symmetric".to_string(), Json::Bool(scheme.symmetric));
    if let Some(c) = clip_ratio {
        m.insert("clip_ratio".to_string(), Json::Num(c));
    }
    Json::Obj(m)
}

fn parse_act(j: &Json) -> Result<ActQuantCfg> {
    let bits = j.at("bits")?.as_usize()? as u32;
    anyhow::ensure!((1..=24).contains(&bits), "activation bits {bits} out of range");
    let symmetric = j.at("symmetric")?.as_bool()?;
    let clip_ratio = j.at("clip_ratio")?.as_f64()?;
    let scheme = if symmetric { QScheme::sym(bits) } else { QScheme::asym(bits) };
    Ok(ActQuantCfg { scheme, clip_ratio })
}

fn f64_arr(values: impl Iterator<Item = f64>) -> Json {
    Json::Arr(values.map(Json::Num).collect())
}

/// Report metrics may legitimately be non-finite (e.g. an SQNR of +inf
/// when a layer's error is exactly zero); `Json::Num` would emit an
/// `inf` token the parser cannot read back, so those are stored as
/// strings (the loader never parses report metrics).
fn metric_json(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Str(v.to_string())
    }
}

fn parse_f64_arr(j: &Json, want_len: usize, what: &str) -> Result<Vec<f64>> {
    let a = j.as_arr()?;
    anyhow::ensure!(a.len() == want_len, "{what}: length {} != {want_len}", a.len());
    a.iter().map(|v| v.as_f64()).collect()
}

/// Where the precision-brownout fallback plan lives relative to the
/// primary artifact: a `brownout-wNaN` subdirectory, so one artifact
/// directory ships both the configured plan and its degraded sibling
/// and replicated serving can swap plans without a second `--artifact`
/// path.
pub fn brownout_dir(dir: &Path, bits: u32) -> std::path::PathBuf {
    dir.join(format!("brownout-w{bits}a{bits}"))
}

/// Persist `qc` (+ the build report / plan echo) under `dir`.
pub fn save_artifact(qc: &QuantConfig, report: &PipelineReport, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating artifact dir {}", dir.display()))?;

    let mut blob: Vec<u8> = Vec::new();
    let mut linears = BTreeMap::new();
    // Deterministic blob layout: sort by (block, name).
    let mut ids: Vec<&LinearId> = qc.linears.keys().collect();
    ids.sort_by_key(|id| (id.block(), id.name()));
    for id in ids {
        let ql = &qc.linears[id];
        let t = &ql.weight;
        anyhow::ensure!(
            t.scales().iter().all(|s| s.is_finite()),
            "refusing to save {id}: non-finite scale"
        );
        let offset = blob.len();
        blob.extend_from_slice(&t.code_bytes_le());
        let v = t.view();
        let mut e = BTreeMap::new();
        e.insert("rows".to_string(), Json::Num(t.rows() as f64));
        e.insert("cols".to_string(), Json::Num(t.cols() as f64));
        e.insert("scheme".to_string(), scheme_json(t.scheme(), None));
        e.insert("group".to_string(), Json::Str(id.group().key().to_string()));
        e.insert("offset".to_string(), Json::Num(offset as f64));
        e.insert("len".to_string(), Json::Num((blob.len() - offset) as f64));
        e.insert("scales".to_string(), f64_arr(t.scales().iter().copied()));
        e.insert("zps".to_string(), f64_arr(v.zps.iter().map(|&z| z as f64)));
        e.insert("row_sums".to_string(), f64_arr(v.row_sums.iter().map(|&s| s as f64)));
        linears.insert(id.to_string(), Json::Obj(e));
    }

    let mut transforms = BTreeMap::new();
    for (name, t) in &qc.transforms {
        anyhow::ensure!(
            t.as_slice().iter().all(|v| v.is_finite()),
            "refusing to save transform {name}: non-finite entry"
        );
        let mut e = BTreeMap::new();
        e.insert("rows".to_string(), Json::Num(t.rows() as f64));
        e.insert("cols".to_string(), Json::Num(t.cols() as f64));
        e.insert("data".to_string(), f64_arr(t.as_slice().iter().copied()));
        transforms.insert(name.clone(), Json::Obj(e));
    }

    let mut acts = BTreeMap::new();
    for g in ALL_GROUPS {
        let a = qc.act_for(g);
        acts.insert(g.key().to_string(), scheme_json(a.scheme, Some(a.clip_ratio)));
    }

    let mut rep = BTreeMap::new();
    rep.insert("mean_sqnr_db".to_string(), metric_json(report.mean_sqnr_db));
    rep.insert("act_clip".to_string(), metric_json(report.act_clip));
    rep.insert(
        "plan".to_string(),
        Json::Arr(
            report
                .plan
                .iter()
                .map(|(k, v)| Json::Arr(vec![Json::Str(k.clone()), Json::Str(v.clone())]))
                .collect(),
        ),
    );

    let mut codes = BTreeMap::new();
    codes.insert("file".to_string(), Json::Str(CODES_FILE.to_string()));
    codes.insert("bytes".to_string(), Json::Num(blob.len() as f64));
    codes.insert("fnv64".to_string(), Json::Str(format!("{:016x}", fnv1a64(&blob))));

    let mut root = BTreeMap::new();
    root.insert("format".to_string(), Json::Str(ARTIFACT_FORMAT.to_string()));
    root.insert("version".to_string(), Json::Num(ARTIFACT_VERSION as f64));
    root.insert("codes".to_string(), Json::Obj(codes));
    root.insert("acts".to_string(), Json::Obj(acts));
    root.insert(
        "kv_act".to_string(),
        scheme_json(qc.kv_act.scheme, Some(qc.kv_act.clip_ratio)),
    );
    root.insert("report".to_string(), Json::Obj(rep));
    root.insert("transforms".to_string(), Json::Obj(transforms));
    root.insert("linears".to_string(), Json::Obj(linears));

    // Self-checksum over the canonical dump (without the checksum key),
    // so manifest corruption — not just blob corruption — is caught.
    // Wrap/unwrap instead of cloning: the manifest tree holds every
    // transform matrix, so a deep clone would double peak memory here.
    let wrapped = Json::Obj(root);
    let canonical = wrapped.dump();
    let Json::Obj(mut root) = wrapped else { unreachable!() };
    root.insert(
        MANIFEST_FNV_KEY.to_string(),
        Json::Str(format!("{:016x}", fnv1a64(canonical.as_bytes()))),
    );

    // Temp-write + rename both files, manifest last: a kill mid-save can
    // leave stray `.tmp` files but never a manifest naming missing or
    // partial data.
    write_atomic(&dir.join(CODES_FILE), &blob)?;
    write_atomic(&dir.join(MANIFEST_FILE), Json::Obj(root).dump().as_bytes())?;
    Ok(())
}

fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    Ok(())
}

/// Load an artifact saved by [`save_artifact`], validating it against
/// the serving `model` (shapes, coverage) and its own checksum/version.
/// `QPanels` are rebuilt per linear, so the returned config serves at
/// full speed immediately.
pub fn load_artifact(dir: &Path, model: &NativeModel) -> Result<QuantConfig> {
    load_artifact_with(dir, model, &Chaos::off())
}

/// Crash-only boot: retry [`load_artifact_with`] up to `attempts` times
/// with doubling backoff (capped at 5 s). A worker racing a deployer's
/// atomic rename, or reading through flaky storage, self-heals here;
/// a genuinely corrupt artifact still returns the last typed error so
/// the caller can fall back to recalibration.
pub fn load_artifact_retry(
    dir: &Path,
    model: &NativeModel,
    attempts: usize,
    backoff: std::time::Duration,
    chaos: &Chaos,
) -> Result<QuantConfig> {
    let attempts = attempts.max(1);
    let mut wait = backoff;
    let mut last = None;
    for attempt in 1..=attempts {
        match load_artifact_with(dir, model, chaos) {
            Ok(qc) => return Ok(qc),
            Err(e) => {
                if attempt < attempts {
                    eprintln!("artifact load attempt {attempt}/{attempts} failed ({e:#}); retrying in {wait:?}");
                    std::thread::sleep(wait);
                    wait = (wait * 2).min(std::time::Duration::from_secs(5));
                }
                last = Some(e);
            }
        }
    }
    Err(last.expect("at least one attempt ran").context(format!(
        "artifact at {} unreadable after {attempts} attempts",
        dir.display()
    )))
}

/// [`load_artifact`] with a chaos seam: a planned [`ArtifactFault`]
/// mangles the freshly read bytes *before* validation, exactly as disk
/// corruption would. With `Chaos::off()` this is `load_artifact`.
pub fn load_artifact_with(dir: &Path, model: &NativeModel, chaos: &Chaos) -> Result<QuantConfig> {
    let fault = chaos.artifact_fault();
    let mpath = dir.join(MANIFEST_FILE);
    let mut mbytes = std::fs::read(&mpath)
        .with_context(|| format!("reading artifact manifest {}", mpath.display()))?;
    if let Some(ArtifactFault::FlipManifestByte(p)) = fault {
        if !mbytes.is_empty() {
            let p = p % mbytes.len();
            mbytes[p] ^= 0xFF;
        }
    }
    let text = String::from_utf8(mbytes).context("artifact manifest is not valid UTF-8")?;
    let mut j = Json::parse(&text).context("parsing artifact manifest")?;

    let format = j.at("format")?.as_str()?;
    anyhow::ensure!(format == ARTIFACT_FORMAT, "not a catquant artifact (format {format:?})");
    let version = j.at("version")?.as_usize()?;
    anyhow::ensure!(
        version == ARTIFACT_VERSION,
        "unsupported artifact version {version} (this build reads version {ARTIFACT_VERSION})"
    );

    // Manifest self-check: re-dump the parsed manifest without the
    // checksum key (parse→dump is byte-stable for saved manifests) and
    // compare. Catches corrupted scales/zero-points/transform entries,
    // which the blob checksum cannot see. The key is *removed* from the
    // owned tree (nothing below reads it) rather than cloning the whole
    // manifest — it holds every transform matrix.
    let want_manifest_fnv = j.at(MANIFEST_FNV_KEY)?.as_str()?.to_string();
    if let Json::Obj(m) = &mut j {
        m.remove(MANIFEST_FNV_KEY);
    }
    let got_manifest_fnv = format!("{:016x}", fnv1a64(j.dump().as_bytes()));
    anyhow::ensure!(
        got_manifest_fnv == want_manifest_fnv,
        "artifact manifest corrupted: checksum {got_manifest_fnv} != recorded {want_manifest_fnv}"
    );

    let codes_meta = j.at("codes")?;
    let blob_path = dir.join(codes_meta.at("file")?.as_str()?);
    let mut blob = std::fs::read(&blob_path)
        .with_context(|| format!("reading artifact blob {}", blob_path.display()))?;
    match fault {
        Some(ArtifactFault::FlipBlobByte(p)) if !blob.is_empty() => {
            let p = p % blob.len();
            blob[p] ^= 0xFF;
        }
        Some(ArtifactFault::TruncateBlob(len)) => blob.truncate(len.min(blob.len())),
        _ => {}
    }
    let blob = blob;
    let want_bytes = codes_meta.at("bytes")?.as_usize()?;
    anyhow::ensure!(
        blob.len() == want_bytes,
        "artifact blob truncated: {} bytes on disk, manifest says {want_bytes}",
        blob.len()
    );
    let want_fnv = codes_meta.at("fnv64")?.as_str()?;
    let got_fnv = format!("{:016x}", fnv1a64(&blob));
    anyhow::ensure!(
        got_fnv == want_fnv,
        "artifact blob corrupted: checksum {got_fnv} != manifest {want_fnv}"
    );

    let mut acts = HashMap::new();
    let acts_j = j.at("acts")?;
    for g in ALL_GROUPS {
        let entry = acts_j
            .at(g.key())
            .with_context(|| format!("artifact missing activation cfg for group {}", g.key()))?;
        acts.insert(g, parse_act(entry)?);
    }
    let kv_act = parse_act(j.at("kv_act")?).context("parsing kv_act")?;

    // Transforms: validated against the model's transform spec.
    let spec: HashMap<String, Vec<usize>> = model.cfg.transform_spec().into_iter().collect();
    let mut transforms = HashMap::new();
    for (name, entry) in j.at("transforms")?.as_obj()? {
        let rows = entry.at("rows")?.as_usize()?;
        let cols = entry.at("cols")?.as_usize()?;
        let Some(shape) = spec.get(name) else {
            bail!("artifact transform {name} is not in the model's transform spec");
        };
        anyhow::ensure!(
            shape[..] == [rows, cols],
            "transform {name}: artifact shape {rows}x{cols} != model spec {shape:?}"
        );
        let data =
            parse_f64_arr(entry.at("data")?, rows * cols, &format!("transform {name} data"))?;
        transforms.insert(name.clone(), Mat::from_vec(rows, cols, data));
    }
    for name in spec.keys() {
        anyhow::ensure!(transforms.contains_key(name), "artifact missing transform {name}");
    }

    let mut linears = HashMap::new();
    for (key, entry) in j.at("linears")?.as_obj()? {
        let id = LinearId::parse(key)
            .with_context(|| format!("artifact linear {key} is not a known linear id"))?;
        let rows = entry.at("rows")?.as_usize()?;
        let cols = entry.at("cols")?.as_usize()?;
        let w = model
            .params
            .get(key)
            .with_context(|| format!("serving model has no parameter {key}"))?;
        anyhow::ensure!(
            w.rows() == rows && w.cols() == cols,
            "linear {key}: artifact shape {rows}x{cols} != model {}x{}",
            w.rows(),
            w.cols()
        );
        let scheme_j = entry.at("scheme")?;
        let bits = scheme_j.at("bits")?.as_usize()? as u32;
        anyhow::ensure!(
            (1..=24).contains(&bits),
            "linear {key}: bits {bits} out of range"
        );
        let scheme = if scheme_j.at("symmetric")?.as_bool()? {
            QScheme::sym(bits)
        } else {
            QScheme::asym(bits)
        };
        let offset = entry.at("offset")?.as_usize()?;
        let len = entry.at("len")?.as_usize()?;
        anyhow::ensure!(
            offset.checked_add(len).is_some_and(|end| end <= blob.len()),
            "linear {key}: blob slice {offset}+{len} exceeds blob length {} (truncated?)",
            blob.len()
        );
        let scales = parse_f64_arr(entry.at("scales")?, rows, &format!("{key} scales"))?;
        let zps: Vec<i32> = parse_f64_arr(entry.at("zps")?, rows, &format!("{key} zps"))?
            .into_iter()
            .map(|v| v as i32)
            .collect();
        let row_sums: Vec<i64> =
            parse_f64_arr(entry.at("row_sums")?, rows, &format!("{key} row_sums"))?
                .into_iter()
                .map(|v| v as i64)
                .collect();
        let tensor = QuantizedTensor::from_parts(
            rows,
            cols,
            scheme,
            &blob[offset..offset + len],
            scales,
            zps,
            row_sums,
        )
        .with_context(|| format!("rebuilding packed codes for {key}"))?;
        linears.insert(id, QuantizedLinear::new(tensor));
    }

    // Coverage: every linear of the serving model must be present.
    for block in 0..model.cfg.n_layers {
        for g in ALL_GROUPS {
            for &lin in g.linears() {
                let id = LinearId::new(block, lin);
                anyhow::ensure!(
                    linears.contains_key(&id),
                    "artifact missing packed weights for {id}"
                );
            }
        }
    }

    Ok(QuantConfig { acts, kv_act, transforms, linears })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_vector() {
        // Known FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
    }

    #[test]
    fn act_json_roundtrip() {
        for (scheme, clip) in [
            (QScheme::asym(4), 1.0),
            (QScheme::sym(8), 0.9),
            (QScheme::asym(16), 0.85),
        ] {
            let j = scheme_json(scheme, Some(clip));
            let text = j.dump();
            let back = parse_act(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.scheme, scheme);
            assert_eq!(back.clip_ratio, clip);
        }
    }
}
