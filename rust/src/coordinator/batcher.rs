//! Dynamic batching policy.
//!
//! The classic continuous-serving tradeoff: wait a little to fill a batch
//! (throughput) but never longer than `max_wait` (latency). The batcher is
//! engine-agnostic and fully testable without a model — the property
//! tests in `rust/tests/coordinator_props.rs` drive it with synthetic
//! arrivals.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherCfg {
    /// Hard cap on batch size (the compiled graph's batch dimension).
    pub max_batch: usize,
    /// Max time the first request in a batch may wait for company.
    pub max_wait: Duration,
}

impl Default for BatcherCfg {
    fn default() -> Self {
        BatcherCfg { max_batch: 4, max_wait: Duration::from_millis(20) }
    }
}

/// Pulls items off a channel according to the batching policy.
pub struct DynamicBatcher<T> {
    rx: Receiver<T>,
    cfg: BatcherCfg,
}

impl<T> DynamicBatcher<T> {
    pub fn new(rx: Receiver<T>, cfg: BatcherCfg) -> Self {
        assert!(cfg.max_batch >= 1);
        DynamicBatcher { rx, cfg }
    }

    /// Block for the next batch. Returns `None` when the channel is
    /// closed and drained (shutdown).
    pub fn next_batch(&self) -> Option<Vec<T>> {
        // Block indefinitely for the first item.
        let first = self.rx.recv().ok()?;
        let mut batch = vec![first];
        let deadline = Instant::now() + self.cfg.max_wait;
        while batch.len() < self.cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(item) => batch.push(item),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn batches_capped_at_max() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = DynamicBatcher::new(rx, BatcherCfg { max_batch: 4, max_wait: Duration::from_millis(5) });
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(b.next_batch().unwrap(), vec![4, 5, 6, 7]);
        assert_eq!(b.next_batch().unwrap(), vec![8, 9]);
    }

    #[test]
    fn waits_at_most_max_wait() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let b = DynamicBatcher::new(rx, BatcherCfg { max_batch: 8, max_wait: Duration::from_millis(30) });
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        let waited = t0.elapsed();
        assert_eq!(batch, vec![1]);
        assert!(waited >= Duration::from_millis(25), "returned too early: {waited:?}");
        assert!(waited < Duration::from_millis(300), "waited too long: {waited:?}");
    }

    #[test]
    fn shutdown_returns_none() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let b = DynamicBatcher::new(rx, BatcherCfg::default());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn deadline_exactly_elapsed_returns_immediately() {
        // max_wait = 0 means the deadline is already reached (`now >=
        // deadline`) when the fill loop starts: the batcher must return
        // the first item alone even with more items already queued, and
        // must not spin or panic on the zero-length timeout.
        let (tx, rx) = channel();
        for i in 0..3 {
            tx.send(i).unwrap();
        }
        let b = DynamicBatcher::new(rx, BatcherCfg { max_batch: 4, max_wait: Duration::ZERO });
        let t0 = Instant::now();
        assert_eq!(b.next_batch().unwrap(), vec![0]);
        assert_eq!(b.next_batch().unwrap(), vec![1]);
        assert_eq!(b.next_batch().unwrap(), vec![2]);
        assert!(t0.elapsed() < Duration::from_millis(100), "zero wait must not block");
    }

    #[test]
    fn disconnect_mid_batch_flushes_partial_batch_early() {
        // The producer hangs up while a batch is still filling: the
        // batcher must return what it has immediately instead of sitting
        // out the remaining window, and the following call reports
        // shutdown.
        let (tx, rx) = channel();
        tx.send(0).unwrap();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            tx.send(1).unwrap();
            // tx dropped here — mid-batch disconnect.
        });
        let b = DynamicBatcher::new(
            rx,
            BatcherCfg { max_batch: 8, max_wait: Duration::from_secs(10) },
        );
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        let waited = t0.elapsed();
        assert_eq!(batch, vec![0, 1]);
        assert!(
            waited < Duration::from_millis(1500),
            "disconnect should flush early, waited {waited:?}"
        );
        assert!(b.next_batch().is_none(), "drained + disconnected ⇒ shutdown");
        handle.join().unwrap();
    }

    #[test]
    fn late_arrivals_join_within_window() {
        let (tx, rx) = channel();
        tx.send(0).unwrap();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            tx.send(1).unwrap();
            std::thread::sleep(Duration::from_millis(200));
            drop(tx);
        });
        let b = DynamicBatcher::new(
            rx,
            BatcherCfg { max_batch: 4, max_wait: Duration::from_millis(100) },
        );
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![0, 1]);
        handle.join().unwrap();
    }
}
