//! Native batched prefill + KV-cache decode generator.
//!
//! The first *runnable* serving engine for the coordinator: PJRT is an
//! offline stub in this environment, so [`NativeGenerator`] drives the
//! pure-Rust model instead — full-sequence prefill per prompt (fanned out
//! across the worker pool), then batched single-token decode steps over
//! shared linear-group kernels. FP serving uses raw weights; quantized
//! serving executes the PTQ pipeline's packed integer codes end to end,
//! including a packed (low-bit) KV cache.
//!
//! Cost per generated token is O(T·d) (one decode step) instead of the
//! O(T²·d) full-prefix recompute a naive loop pays — see PERF.md's
//! decode section for measured numbers.

use super::generate::{
    sample_index, AdmitOutcome, EngineStats, GenEngine, PoolStats, SamplingCfg, StepEngine,
};
use crate::linalg::{par, Rng};
use crate::model::{KvCache, KvPagePool, KvPoolCfg, NativeModel, PrefixCache, QuantConfig};
use crate::runtime::chaos::Chaos;
use anyhow::Result;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Instant;

/// One in-flight (or finished-awaiting-collection) sequence of the
/// step-granular serving path.
struct StepSeq {
    /// The fitted prompt (kept for re-prefill on resume).
    prompt: Vec<u8>,
    /// Generated tokens so far (first one sampled at admit).
    out: Vec<u8>,
    max_new: usize,
    /// The already-sampled token the next decode step feeds.
    next: u8,
    /// `None` while preempted (pages reclaimed) or after collection.
    cache: Option<KvCache>,
    /// Per-sequence sampling stream (seeded from the engine seed and the
    /// sequence id), so draws never depend on which other sequences
    /// happen to share a step — sampled outputs are schedule-independent.
    rng: Rng,
    done: bool,
}

/// Native prefill+decode generator (FP or packed-quantized).
pub struct NativeGenerator {
    model: NativeModel,
    qc: Option<QuantConfig>,
    sampling: SamplingCfg,
    rng: Rng,
    max_batch: usize,
    stats: EngineStats,
    /// Page pool for step-granular serving (unbounded unless configured
    /// via [`Self::with_serve_pool`]).
    pool: KvPagePool,
    /// Prompt-prefix page sharing (off unless configured).
    prefix: Option<PrefixCache>,
    /// Sequence slab; ids are indices (never reused within an engine).
    seqs: Vec<StepSeq>,
    /// Running sequence indices in admission order — preemption evicts
    /// from the back, so FCFS service order is preserved.
    running: Vec<usize>,
    /// Preempted ids not yet drained by the scheduler.
    preempted_out: Vec<u64>,
    /// Quarantined ids (reproduced a decode panic alone) not yet drained
    /// via [`StepEngine::take_failed`].
    failed_out: Vec<u64>,
    /// Deterministic fault injection (off by default, zero-cost).
    chaos: Chaos,
}

impl NativeGenerator {
    /// FP serving.
    pub fn fp(model: NativeModel, max_batch: usize, sampling: SamplingCfg) -> NativeGenerator {
        Self::new(model, None, max_batch, sampling)
    }

    /// Quantized serving: packed weight codes × per-token activation
    /// codes through the integer kernels, packed KV cache.
    pub fn quant(
        model: NativeModel,
        qc: QuantConfig,
        max_batch: usize,
        sampling: SamplingCfg,
    ) -> NativeGenerator {
        Self::new(model, Some(qc), max_batch, sampling)
    }

    /// Quantized serving from a saved artifact
    /// ([`crate::runtime::load_artifact`]): the production boot path —
    /// prebuilt transforms + packed codes load in milliseconds instead
    /// of re-running calibration + GPTQ, and serve bit-exactly like the
    /// in-memory build they were saved from.
    pub fn quant_from_artifact(
        model: NativeModel,
        dir: &std::path::Path,
        max_batch: usize,
        sampling: SamplingCfg,
    ) -> Result<NativeGenerator> {
        let qc = crate::runtime::load_artifact(dir, &model)?;
        Ok(Self::new(model, Some(qc), max_batch, sampling))
    }

    fn new(
        model: NativeModel,
        qc: Option<QuantConfig>,
        max_batch: usize,
        sampling: SamplingCfg,
    ) -> NativeGenerator {
        assert!(max_batch >= 1);
        NativeGenerator {
            model,
            qc,
            sampling,
            rng: Rng::new(sampling.seed ^ 0x5A113),
            max_batch,
            stats: EngineStats::default(),
            pool: KvPagePool::unbounded(),
            prefix: None,
            seqs: Vec::new(),
            running: Vec::new(),
            preempted_out: Vec::new(),
            failed_out: Vec::new(),
            chaos: Chaos::off(),
        }
    }

    /// Serve KV from a bounded page pool, optionally sharing prompt-prefix
    /// pages across sequences — the continuous-batching configuration.
    /// Affects the step-granular ([`StepEngine`]) path; `generate_batch`
    /// keeps per-call unbounded caches.
    pub fn with_serve_pool(mut self, cfg: KvPoolCfg, prefix_sharing: bool) -> Self {
        self.pool = KvPagePool::new(cfg);
        self.prefix = if prefix_sharing {
            Some(PrefixCache::new(cfg.page_rows, 2 * self.model.cfg.n_layers))
        } else {
            None
        };
        self
    }

    /// Inject a deterministic fault plan (see [`crate::runtime::chaos`]):
    /// planned KV-page allocation failures and decode-step panics fire at
    /// exact counters. Call after [`Self::with_serve_pool`] — the pool is
    /// re-armed with the same budget plus the fault plan.
    pub fn with_chaos(mut self, chaos: Chaos) -> Self {
        self.pool = self.pool.with_chaos(chaos.clone());
        self.chaos = chaos;
        self
    }

    /// Handle onto the serving page pool (shared state): lets harnesses
    /// assert page accounting from outside the engine, e.g. that every
    /// page returns to the pool after a drain.
    pub fn serve_pool(&self) -> KvPagePool {
        self.pool.clone()
    }

    /// Clamp a prompt so at least one generated token fits under the
    /// positional budget (counting the truncation — capacity pressure is
    /// surfaced, not swallowed); an empty prompt becomes a single BOS
    /// token. Owns the prompt so the common in-budget case moves it.
    fn fit_owned(&mut self, p: Vec<u8>) -> Vec<u8> {
        let max_prompt = self.model.cfg.seq - 1;
        if p.is_empty() {
            vec![0]
        } else if p.len() > max_prompt {
            self.stats.truncated_prompts += 1;
            p[p.len() - max_prompt..].to_vec()
        } else {
            p
        }
    }

    fn sample(&mut self, logits: &[f64]) -> u8 {
        sample_index(logits, self.sampling.temperature, &mut self.rng) as u8
    }

    /// A fresh cache on the serving pool, mode matching the engine path.
    fn new_cache(&self) -> KvCache {
        match &self.qc {
            None => KvCache::fp_in(&self.model.cfg, &self.pool),
            Some(q) => {
                KvCache::packed_in(&self.model.cfg, q.kv_act.scheme, q.kv_act.clip_ratio, &self.pool)
            }
        }
    }

    /// Per-sequence sampling stream, seeded from the engine seed and the
    /// request's stable key — not the engine-local slot index — so the
    /// same request admitted on any replica draws identically.
    fn seq_rng(&self, key: u64) -> Rng {
        Rng::new(self.sampling.seed ^ key.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0x5A117)
    }

    /// Build a cache holding `toks` (prefix-hit pages + prefill of the
    /// rest), reserving pages up front and evicting idle prefix entries
    /// under pressure. Returns the cache and the last-row logits, or
    /// `None` when the pool has no capacity (left exactly as found —
    /// dropping the partial cache releases anything reserved).
    fn build_cache(&mut self, toks: &[u8]) -> Option<(KvCache, crate::linalg::Mat)> {
        let mut cache = self.new_cache();
        let mut start = 0usize;
        if let Some(trie) = self.prefix.as_mut() {
            if let Some(hit) = trie.lookup(toks) {
                start = hit.matched;
                cache.seed_prefix(hit);
            }
        }
        let suffix = toks.len() - start;
        while !cache.reserve_tokens(suffix) {
            let evicted = match self.prefix.as_mut() {
                Some(t) => t.evict_lru(1),
                None => 0,
            };
            if evicted == 0 {
                return None;
            }
        }
        let t0 = Instant::now();
        let logits = self.model.prefill_into(&toks[start..], self.qc.as_ref(), &mut cache);
        self.stats.prefill_time += t0.elapsed();
        self.stats.prefill_tokens += suffix as u64;
        Some((cache, logits))
    }

    /// Reclaim a running sequence's pages; it re-prefills on resume.
    fn preempt(&mut self, idx: usize) {
        self.seqs[idx].cache = None;
        self.running.retain(|&r| r != idx);
        self.preempted_out.push(idx as u64);
    }

    /// Rebuild a cache dropped when a sibling group's decode panicked:
    /// re-prefill `prompt + out[..n-1]` (the rows the cache held) plus
    /// one reserved row for the pending step. Bit-exact — `next` is
    /// already sampled, so no RNG is consumed, exactly like resume.
    fn rebuild_cache(&mut self, idx: usize) -> bool {
        let s = &self.seqs[idx];
        let mut toks = s.prompt.clone();
        toks.extend_from_slice(&s.out[..s.out.len() - 1]);
        let Some((mut cache, _logits)) = self.build_cache(&toks) else {
            return false;
        };
        if !cache.reserve_tokens(1) {
            return false;
        }
        self.seqs[idx].cache = Some(cache);
        true
    }

    /// Decode one batched step for `idxs`, isolating panics: the group
    /// runs under `catch_unwind`; on a panic the group's caches are
    /// poisoned (dropped, pages released) and the group is bisected until
    /// the offender decodes alone — it is quarantined (terminal, surfaced
    /// via [`StepEngine::take_failed`]) and every other sequence retries
    /// bit-exactly via re-prefill. Transient panics (ones that do not
    /// reproduce) cost only the retry.
    fn decode_group(&mut self, idxs: &[usize], step_no: u64, finished: &mut Vec<u64>) {
        // Restore caches lost to a poisoned sibling group; a sequence the
        // pool cannot re-seat right now is preempted, not lost.
        let mut group: Vec<usize> = Vec::with_capacity(idxs.len());
        for &idx in idxs {
            if self.seqs[idx].cache.is_some() || self.rebuild_cache(idx) {
                group.push(idx);
            } else {
                self.preempt(idx);
            }
        }
        if group.is_empty() {
            return;
        }
        let toks: Vec<u8> = group.iter().map(|&i| self.seqs[i].next).collect();
        let ids: Vec<u64> = group.iter().map(|&i| i as u64).collect();
        let mut taken: Vec<KvCache> =
            group.iter().map(|&i| self.seqs[i].cache.take().expect("present above")).collect();
        let t0 = Instant::now();
        let stepped = {
            let (chaos, model, qc) = (&self.chaos, &self.model, self.qc.as_ref());
            catch_unwind(AssertUnwindSafe(|| {
                chaos.on_decode(step_no, &ids);
                let mut refs: Vec<&mut KvCache> = taken.iter_mut().collect();
                model.decode_step(&mut refs, &toks, qc)
            }))
        };
        self.stats.decode_time += t0.elapsed();
        match stepped {
            Ok(logits) => {
                self.stats.decode_tokens += group.len() as u64;
                for (r, (&idx, cache)) in group.iter().zip(taken).enumerate() {
                    let s = &mut self.seqs[idx];
                    let tok =
                        sample_index(logits.row(r), self.sampling.temperature, &mut s.rng) as u8;
                    s.out.push(tok);
                    s.next = tok;
                    let room = cache.has_room();
                    s.cache = Some(cache);
                    if s.out.len() >= s.max_new || !room {
                        s.done = true;
                        finished.push(idx as u64);
                    }
                }
            }
            Err(_) => {
                self.stats.step_panics += 1;
                // Mid-forward state is untrustworthy: poison the group's
                // caches (pages return to the pool on drop).
                drop(taken);
                if group.len() == 1 {
                    let idx = group[0];
                    self.stats.quarantined += 1;
                    self.running.retain(|&r| r != idx);
                    let s = &mut self.seqs[idx];
                    s.done = true;
                    self.failed_out.push(idx as u64);
                } else {
                    let mid = group.len() / 2;
                    let (left, right) = (group[..mid].to_vec(), group[mid..].to_vec());
                    self.decode_group(&left, step_no, finished);
                    self.decode_group(&right, step_no, finished);
                }
            }
        }
    }
}

impl GenEngine for NativeGenerator {
    fn generate_batch(&mut self, prompts: &[Vec<u8>], max_new: usize) -> Result<Vec<Vec<u8>>> {
        anyhow::ensure!(!prompts.is_empty() && prompts.len() <= self.max_batch);
        let real = prompts.len();
        if max_new == 0 {
            return Ok(vec![Vec::new(); real]);
        }

        // Prefill: one full-sequence pass per prompt, fanned out across
        // the worker pool (each inner forward then stays serial — one
        // level of parallelism, sequence-granular).
        let mut fitted: Vec<Vec<u8>> = Vec::with_capacity(real);
        for p in prompts {
            fitted.push(self.fit_owned(p.clone()));
        }
        let prompt_tokens: u64 = fitted.iter().map(|p| p.len() as u64).sum();
        let t0 = Instant::now();
        let (model, qc) = (&self.model, self.qc.as_ref());
        let prefilled: Vec<(crate::linalg::Mat, KvCache)> =
            par::par_map(fitted, par::num_threads(), |p| model.prefill(&p, qc));
        self.stats.prefill_time += t0.elapsed();
        self.stats.prefill_tokens += prompt_tokens;

        let mut caches: Vec<KvCache> = Vec::with_capacity(real);
        let mut results: Vec<Vec<u8>> = vec![Vec::with_capacity(max_new); real];
        let mut next: Vec<u8> = Vec::with_capacity(real);
        for (b, (logits, cache)) in prefilled.into_iter().enumerate() {
            let tok = self.sample(logits.row(0));
            results[b].push(tok);
            next.push(tok);
            caches.push(cache);
        }

        // Decode: batched single-token steps; sequences at positional
        // capacity drop out, the rest keep batching. The timer starts
        // after first-token sampling so decode_time covers exactly the
        // work decode_tokens counts.
        let t1 = Instant::now();
        for _ in 1..max_new {
            let room: Vec<bool> = caches.iter().map(|c| c.has_room()).collect();
            let idx: Vec<usize> = (0..real).filter(|&b| room[b]).collect();
            if idx.is_empty() {
                break;
            }
            let toks: Vec<u8> = idx.iter().map(|&b| next[b]).collect();
            let mut refs: Vec<&mut KvCache> = caches
                .iter_mut()
                .enumerate()
                .filter(|(b, _)| room[*b])
                .map(|(_, c)| c)
                .collect();
            let logits = self.model.decode_step(&mut refs, &toks, self.qc.as_ref());
            for (r, &b) in idx.iter().enumerate() {
                let tok = self.sample(logits.row(r));
                results[b].push(tok);
                next[b] = tok;
            }
            self.stats.decode_tokens += idx.len() as u64;
        }
        self.stats.decode_time += t1.elapsed();
        Ok(results)
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn take_stats(&mut self) -> EngineStats {
        std::mem::take(&mut self.stats)
    }
}

impl StepEngine for NativeGenerator {
    fn admit(&mut self, prompt: Vec<u8>, max_new: usize, key: u64) -> Result<AdmitOutcome> {
        if self.running.len() >= self.max_batch {
            return Ok(AdmitOutcome::NoCapacity(prompt));
        }
        let fitted = self.fit_owned(prompt);
        let Some((cache, logits)) = self.build_cache(&fitted) else {
            // Hand the *fitted* prompt back: a retry won't double-count
            // the truncation, and the queued copy shrinks to what will
            // actually be served.
            return Ok(AdmitOutcome::NoCapacity(fitted));
        };
        if let Some(trie) = self.prefix.as_mut() {
            trie.insert(&fitted, |s, c| cache.stream_page(s, c));
        }
        let id = self.seqs.len() as u64;
        let mut rng = self.seq_rng(key);
        let tok = sample_index(logits.row(0), self.sampling.temperature, &mut rng) as u8;
        let done = max_new <= 1 || !cache.has_room();
        self.seqs.push(StepSeq {
            prompt: fitted,
            out: vec![tok],
            max_new: max_new.max(1),
            next: tok,
            cache: Some(cache),
            rng,
            done,
        });
        self.running.push(id as usize);
        Ok(AdmitOutcome::Admitted(id))
    }

    fn step(&mut self) -> Result<Vec<u64>> {
        // Sequences that finished at admit time (or last step) leave
        // before the batch forms — leaving is individual, never gated on
        // neighbours.
        let mut finished = Vec::new();
        let seqs = &self.seqs;
        self.running.retain(|&i| {
            if seqs[i].done {
                finished.push(i as u64);
                false
            } else {
                true
            }
        });
        if self.running.is_empty() {
            return Ok(finished);
        }
        // Reserve this step's page per sequence; under a refused budget,
        // evict idle prefix entries first, then preempt the
        // most-recently-admitted sequence (FCFS-preserving LRU: the
        // newest arrival has waited least and re-prefills cheapest via
        // the prefix cache).
        let mut active = self.running.clone();
        let mut i = 0;
        while i < active.len() {
            let idx = active[i];
            if self.seqs[idx].cache.as_mut().expect("running seq has a cache").reserve_tokens(1) {
                i += 1;
                continue;
            }
            if let Some(t) = self.prefix.as_mut() {
                if t.evict_lru(1) > 0 {
                    continue;
                }
            }
            if active.len() == 1 {
                // Sole survivor and the pool still refuses one row: the
                // pool is smaller than one sequence — finish with what it
                // has rather than livelock.
                self.seqs[idx].done = true;
                self.running.retain(|&r| r != idx);
                finished.push(idx as u64);
                return Ok(finished);
            }
            let victim = *active.last().unwrap();
            self.preempt(victim);
            active.pop();
        }
        // Decode the surviving batch under panic isolation: caches move
        // out of the slab for the duration of the step (simultaneous
        // &mut borrows), then return — unless the group panics, in which
        // case `decode_group` bisects to the offender.
        let step_no = self.chaos.next_step();
        self.decode_group(&active, step_no, &mut finished);
        let seqs = &self.seqs;
        self.running.retain(|&i| !seqs[i].done);
        Ok(finished)
    }

    fn take_output(&mut self, id: u64) -> Option<Vec<u8>> {
        let idx = id as usize;
        let s = self.seqs.get_mut(idx)?;
        s.done = true;
        s.cache = None;
        s.prompt = Vec::new();
        self.running.retain(|&r| r != idx);
        Some(std::mem::take(&mut s.out))
    }

    fn take_preempted(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.preempted_out)
    }

    fn take_failed(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.failed_out)
    }

    fn resume(&mut self, id: u64) -> Result<bool> {
        let idx = id as usize;
        if self.running.len() >= self.max_batch {
            return Ok(false);
        }
        let s = &self.seqs[idx];
        assert!(!s.done && s.cache.is_none(), "resume target must be preempted");
        // The cache held prompt + out[..n-1] rows at preemption (the last
        // sampled token was drawn but not yet fed). Re-prefill exactly
        // those rows — prefix pages usually cover the prompt, so this is
        // cheap — and discard the logits: `next` was already drawn, so
        // resume consumes no RNG and sampling is preemption-independent.
        let mut toks = s.prompt.clone();
        toks.extend_from_slice(&s.out[..s.out.len() - 1]);
        let Some((cache, _logits)) = self.build_cache(&toks) else {
            return Ok(false);
        };
        self.seqs[idx].cache = Some(cache);
        self.running.push(idx);
        Ok(true)
    }

    fn running(&self) -> usize {
        self.running.len()
    }

    fn max_concurrent(&self) -> usize {
        self.max_batch
    }

    fn pool_stats(&self) -> PoolStats {
        PoolStats {
            live_bytes: self.pool.live_bytes(),
            peak_bytes: self.pool.peak_bytes(),
            budget_bytes: self.pool.budget_bytes(),
            prefix_hits: self.prefix.as_ref().map(|t| t.hits()).unwrap_or(0),
            prefix_lookups: self.prefix.as_ref().map(|t| t.lookups()).unwrap_or(0),
        }
    }

    fn take_stats(&mut self) -> EngineStats {
        std::mem::take(&mut self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn tiny() -> NativeModel {
        let cfg = ModelConfig {
            name: "t".into(),
            d: 32,
            n_layers: 2,
            n_heads: 4,
            ff: 64,
            seq: 16,
            vocab: 256,
        };
        NativeModel::init_random(cfg, 11)
    }

    #[test]
    fn generates_requested_lengths() {
        let mut g = NativeGenerator::fp(tiny(), 4, SamplingCfg::default());
        let out = g
            .generate_batch(&[vec![1, 2, 3], vec![7], vec![4, 5, 6, 7, 8]], 5)
            .unwrap();
        assert_eq!(out.len(), 3);
        for o in &out {
            assert_eq!(o.len(), 5);
        }
        let stats = GenEngine::take_stats(&mut g);
        assert_eq!(stats.prefill_tokens, 9);
        // 3 sequences × 4 decode steps (first token comes from prefill).
        assert_eq!(stats.decode_tokens, 12);
        assert_eq!(GenEngine::take_stats(&mut g).prefill_tokens, 0, "stats drained");
    }

    #[test]
    fn greedy_matches_full_forward_argmax() {
        // Greedy decode through the cache must reproduce the token path
        // a full-recompute greedy loop takes (FP decode is bit-exact).
        let model = tiny();
        let prompt = vec![3u8, 1, 4];
        let max_new = 6;
        let mut seq = prompt.clone();
        let mut want = Vec::new();
        for _ in 0..max_new {
            let logits = model.forward(&seq);
            let last = logits.row(logits.rows() - 1);
            // First-max argmax, the same tie rule as the sampler's.
            let mut tok = 0usize;
            for (i, &v) in last.iter().enumerate() {
                if v > last[tok] {
                    tok = i;
                }
            }
            want.push(tok as u8);
            seq.push(tok as u8);
        }
        let mut g = NativeGenerator::fp(tiny(), 2, SamplingCfg::default());
        let out = g.generate_batch(&[prompt], max_new).unwrap();
        assert_eq!(out[0], want);
    }

    #[test]
    fn capacity_caps_generation() {
        // seq=16, prompt=14: positions 14 and 15 accept generated
        // tokens, plus one final prediction at full context —
        // `seq − prompt + 1` tokens, no matter how many were asked for.
        let mut g = NativeGenerator::fp(tiny(), 2, SamplingCfg::default());
        let out = g.generate_batch(&[vec![1u8; 14]], 10).unwrap();
        assert_eq!(out[0].len(), 3);
    }

    #[test]
    fn step_engine_matches_per_sequence_reference() {
        // Greedy continuous decode with a mid-decode join must produce,
        // per sequence, exactly the tokens a solo generate_batch run
        // produces — join/leave cannot move a bit.
        let sampling = SamplingCfg::default();
        let prompts: [&[u8]; 3] = [&[3, 1, 4], &[7, 7], &[1, 2, 3, 4, 5]];
        let max_news = [6usize, 3, 4];
        let mut want = Vec::new();
        for (p, &mn) in prompts.iter().zip(&max_news) {
            let mut r = NativeGenerator::fp(tiny(), 1, sampling);
            want.push(r.generate_batch(&[p.to_vec()], mn).unwrap().remove(0));
        }
        let mut g = NativeGenerator::fp(tiny(), 4, sampling)
            .with_serve_pool(KvPoolCfg { page_rows: 4, budget_bytes: usize::MAX }, true);
        assert!(matches!(g.admit(prompts[0].to_vec(), max_news[0], 0).unwrap(), AdmitOutcome::Admitted(0)));
        assert!(matches!(g.admit(prompts[1].to_vec(), max_news[1], 1).unwrap(), AdmitOutcome::Admitted(1)));
        let mut outs: Vec<Option<Vec<u8>>> = vec![None; 3];
        for step in 0..32 {
            if step == 1 {
                // Joins while the first two are mid-decode.
                assert!(matches!(
                    g.admit(prompts[2].to_vec(), max_news[2], 2).unwrap(),
                    AdmitOutcome::Admitted(2)
                ));
            }
            for id in g.step().unwrap() {
                outs[id as usize] = Some(g.take_output(id).unwrap());
            }
            if outs.iter().all(|o| o.is_some()) {
                break;
            }
        }
        for (o, w) in outs.iter().zip(&want) {
            assert_eq!(o.as_ref().unwrap(), w);
        }
    }

    #[test]
    fn preemption_and_resume_are_bit_exact_and_budgeted() {
        let sampling = SamplingCfg::default();
        let p0 = vec![1u8, 2, 3, 4, 5];
        let p1 = vec![9u8, 8, 7];
        let mn = 8;
        let w0 = NativeGenerator::fp(tiny(), 1, sampling)
            .generate_batch(&[p0.clone()], mn)
            .unwrap()
            .remove(0);
        let w1 = NativeGenerator::fp(tiny(), 1, sampling)
            .generate_batch(&[p1.clone()], mn)
            .unwrap()
            .remove(0);
        // 4-row f64 pages at d=32 are 1 KiB; each sequence peaks at 16
        // pages (4 streams × 4 pages), so a 20-page budget admits both
        // but cannot hold both fully grown — preemption must kick in.
        let cfgp = KvPoolCfg { page_rows: 4, budget_bytes: 20 * 1024 };
        let mut g = NativeGenerator::fp(tiny(), 4, sampling).with_serve_pool(cfgp, false);
        assert!(matches!(g.admit(p0.clone(), mn, 0).unwrap(), AdmitOutcome::Admitted(0)));
        assert!(matches!(g.admit(p1.clone(), mn, 1).unwrap(), AdmitOutcome::Admitted(1)));
        let mut outs: [Option<Vec<u8>>; 2] = [None, None];
        let mut waiting: Vec<u64> = Vec::new();
        let mut preemptions = 0usize;
        for _ in 0..64 {
            if outs.iter().all(|o| o.is_some()) {
                break;
            }
            waiting.retain(|&id| !g.resume(id).unwrap());
            for id in g.step().unwrap() {
                outs[id as usize] = Some(g.take_output(id).unwrap());
            }
            let newly = g.take_preempted();
            preemptions += newly.len();
            waiting.extend(newly);
            let ps = g.pool_stats();
            assert!(ps.live_bytes <= ps.budget_bytes, "budget exceeded");
            assert!(ps.peak_bytes <= ps.budget_bytes, "budget exceeded at peak");
        }
        assert!(preemptions > 0, "budget was sized to force preemption");
        assert_eq!(outs[0].as_ref().unwrap(), &w0, "survivor diverged");
        assert_eq!(outs[1].as_ref().unwrap(), &w1, "preempted+resumed sequence diverged");
    }

    #[test]
    fn prefix_sharing_skips_shared_prefill() {
        let sampling = SamplingCfg::default();
        let shared: Vec<u8> = (1..=8).collect();
        let mut a = shared.clone();
        a.push(42);
        let mut b = shared.clone();
        b.push(17);
        let mut g = NativeGenerator::fp(tiny(), 4, sampling)
            .with_serve_pool(KvPoolCfg { page_rows: 4, budget_bytes: usize::MAX }, true);
        assert!(matches!(g.admit(a, 2, 0).unwrap(), AdmitOutcome::Admitted(0)));
        assert_eq!(StepEngine::take_stats(&mut g).prefill_tokens, 9);
        assert!(matches!(g.admit(b.clone(), 2, 1).unwrap(), AdmitOutcome::Admitted(1)));
        // 8 shared tokens (two full 4-row chunks) come from the trie;
        // only the divergent tail prefills.
        assert_eq!(StepEngine::take_stats(&mut g).prefill_tokens, 1);
        let ps = g.pool_stats();
        assert_eq!((ps.prefix_hits, ps.prefix_lookups), (1, 2));
        // Shared pages must not change what gets generated.
        let want = NativeGenerator::fp(tiny(), 1, sampling)
            .generate_batch(&[b], 2)
            .unwrap()
            .remove(0);
        let mut got = None;
        while g.running() > 0 {
            for id in g.step().unwrap() {
                let out = g.take_output(id).unwrap();
                if id == 1 {
                    got = Some(out);
                }
            }
        }
        assert_eq!(got.unwrap(), want);
    }

    #[test]
    fn temperature_sampling_is_seed_deterministic() {
        // Same seed + same batch → identical continuations, regardless
        // of worker count (prefill fan-out preserves order and the RNG
        // is only touched on the coordinator thread).
        let sampling = SamplingCfg { temperature: 0.9, seed: 5 };
        let prompts = [vec![2u8, 7, 1], vec![9, 9], vec![1]];
        let mut a = NativeGenerator::fp(tiny(), 4, sampling);
        let mut b = NativeGenerator::fp(tiny(), 4, sampling);
        assert_eq!(
            a.generate_batch(&prompts, 4).unwrap(),
            b.generate_batch(&prompts, 4).unwrap()
        );
    }
}
