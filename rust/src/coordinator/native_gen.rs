//! Native batched prefill + KV-cache decode generator.
//!
//! The first *runnable* serving engine for the coordinator: PJRT is an
//! offline stub in this environment, so [`NativeGenerator`] drives the
//! pure-Rust model instead — full-sequence prefill per prompt (fanned out
//! across the worker pool), then batched single-token decode steps over
//! shared linear-group kernels. FP serving uses raw weights; quantized
//! serving executes the PTQ pipeline's packed integer codes end to end,
//! including a packed (low-bit) KV cache.
//!
//! Cost per generated token is O(T·d) (one decode step) instead of the
//! O(T²·d) full-prefix recompute a naive loop pays — see PERF.md's
//! decode section for measured numbers.

use super::generate::{sample_index, EngineStats, GenEngine, SamplingCfg};
use crate::linalg::{par, Rng};
use crate::model::{KvCache, NativeModel, QuantConfig};
use anyhow::Result;
use std::time::Instant;

/// Native prefill+decode generator (FP or packed-quantized).
pub struct NativeGenerator {
    model: NativeModel,
    qc: Option<QuantConfig>,
    sampling: SamplingCfg,
    rng: Rng,
    max_batch: usize,
    stats: EngineStats,
}

impl NativeGenerator {
    /// FP serving.
    pub fn fp(model: NativeModel, max_batch: usize, sampling: SamplingCfg) -> NativeGenerator {
        Self::new(model, None, max_batch, sampling)
    }

    /// Quantized serving: packed weight codes × per-token activation
    /// codes through the integer kernels, packed KV cache.
    pub fn quant(
        model: NativeModel,
        qc: QuantConfig,
        max_batch: usize,
        sampling: SamplingCfg,
    ) -> NativeGenerator {
        Self::new(model, Some(qc), max_batch, sampling)
    }

    /// Quantized serving from a saved artifact
    /// ([`crate::runtime::load_artifact`]): the production boot path —
    /// prebuilt transforms + packed codes load in milliseconds instead
    /// of re-running calibration + GPTQ, and serve bit-exactly like the
    /// in-memory build they were saved from.
    pub fn quant_from_artifact(
        model: NativeModel,
        dir: &std::path::Path,
        max_batch: usize,
        sampling: SamplingCfg,
    ) -> Result<NativeGenerator> {
        let qc = crate::runtime::load_artifact(dir, &model)?;
        Ok(Self::new(model, Some(qc), max_batch, sampling))
    }

    fn new(
        model: NativeModel,
        qc: Option<QuantConfig>,
        max_batch: usize,
        sampling: SamplingCfg,
    ) -> NativeGenerator {
        assert!(max_batch >= 1);
        NativeGenerator {
            model,
            qc,
            sampling,
            rng: Rng::new(sampling.seed ^ 0x5A113),
            max_batch,
            stats: EngineStats::default(),
        }
    }

    /// Clamp a prompt so at least one generated token fits under the
    /// positional budget; an empty prompt becomes a single BOS token.
    fn fit_prompt(&self, p: &[u8]) -> Vec<u8> {
        let max_prompt = self.model.cfg.seq - 1;
        if p.is_empty() {
            vec![0]
        } else if p.len() > max_prompt {
            p[p.len() - max_prompt..].to_vec()
        } else {
            p.to_vec()
        }
    }

    fn sample(&mut self, logits: &[f64]) -> u8 {
        sample_index(logits, self.sampling.temperature, &mut self.rng) as u8
    }
}

impl GenEngine for NativeGenerator {
    fn generate_batch(&mut self, prompts: &[Vec<u8>], max_new: usize) -> Result<Vec<Vec<u8>>> {
        anyhow::ensure!(!prompts.is_empty() && prompts.len() <= self.max_batch);
        let real = prompts.len();
        if max_new == 0 {
            return Ok(vec![Vec::new(); real]);
        }

        // Prefill: one full-sequence pass per prompt, fanned out across
        // the worker pool (each inner forward then stays serial — one
        // level of parallelism, sequence-granular).
        let fitted: Vec<Vec<u8>> = prompts.iter().map(|p| self.fit_prompt(p)).collect();
        let prompt_tokens: u64 = fitted.iter().map(|p| p.len() as u64).sum();
        let t0 = Instant::now();
        let (model, qc) = (&self.model, self.qc.as_ref());
        let prefilled: Vec<(crate::linalg::Mat, KvCache)> =
            par::par_map(fitted, par::num_threads(), |p| model.prefill(&p, qc));
        self.stats.prefill_time += t0.elapsed();
        self.stats.prefill_tokens += prompt_tokens;

        let mut caches: Vec<KvCache> = Vec::with_capacity(real);
        let mut results: Vec<Vec<u8>> = vec![Vec::with_capacity(max_new); real];
        let mut next: Vec<u8> = Vec::with_capacity(real);
        for (b, (logits, cache)) in prefilled.into_iter().enumerate() {
            let tok = self.sample(logits.row(0));
            results[b].push(tok);
            next.push(tok);
            caches.push(cache);
        }

        // Decode: batched single-token steps; sequences at positional
        // capacity drop out, the rest keep batching. The timer starts
        // after first-token sampling so decode_time covers exactly the
        // work decode_tokens counts.
        let t1 = Instant::now();
        for _ in 1..max_new {
            let room: Vec<bool> = caches.iter().map(|c| c.has_room()).collect();
            let idx: Vec<usize> = (0..real).filter(|&b| room[b]).collect();
            if idx.is_empty() {
                break;
            }
            let toks: Vec<u8> = idx.iter().map(|&b| next[b]).collect();
            let mut refs: Vec<&mut KvCache> = caches
                .iter_mut()
                .enumerate()
                .filter(|(b, _)| room[*b])
                .map(|(_, c)| c)
                .collect();
            let logits = self.model.decode_step(&mut refs, &toks, self.qc.as_ref());
            for (r, &b) in idx.iter().enumerate() {
                let tok = self.sample(logits.row(r));
                results[b].push(tok);
                next[b] = tok;
            }
            self.stats.decode_tokens += idx.len() as u64;
        }
        self.stats.decode_time += t1.elapsed();
        Ok(results)
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn take_stats(&mut self) -> EngineStats {
        std::mem::take(&mut self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn tiny() -> NativeModel {
        let cfg = ModelConfig {
            name: "t".into(),
            d: 32,
            n_layers: 2,
            n_heads: 4,
            ff: 64,
            seq: 16,
            vocab: 256,
        };
        NativeModel::init_random(cfg, 11)
    }

    #[test]
    fn generates_requested_lengths() {
        let mut g = NativeGenerator::fp(tiny(), 4, SamplingCfg::default());
        let out = g
            .generate_batch(&[vec![1, 2, 3], vec![7], vec![4, 5, 6, 7, 8]], 5)
            .unwrap();
        assert_eq!(out.len(), 3);
        for o in &out {
            assert_eq!(o.len(), 5);
        }
        let stats = g.take_stats();
        assert_eq!(stats.prefill_tokens, 9);
        // 3 sequences × 4 decode steps (first token comes from prefill).
        assert_eq!(stats.decode_tokens, 12);
        assert_eq!(g.take_stats().prefill_tokens, 0, "stats drained");
    }

    #[test]
    fn greedy_matches_full_forward_argmax() {
        // Greedy decode through the cache must reproduce the token path
        // a full-recompute greedy loop takes (FP decode is bit-exact).
        let model = tiny();
        let prompt = vec![3u8, 1, 4];
        let max_new = 6;
        let mut seq = prompt.clone();
        let mut want = Vec::new();
        for _ in 0..max_new {
            let logits = model.forward(&seq);
            let last = logits.row(logits.rows() - 1);
            // First-max argmax, the same tie rule as the sampler's.
            let mut tok = 0usize;
            for (i, &v) in last.iter().enumerate() {
                if v > last[tok] {
                    tok = i;
                }
            }
            want.push(tok as u8);
            seq.push(tok as u8);
        }
        let mut g = NativeGenerator::fp(tiny(), 2, SamplingCfg::default());
        let out = g.generate_batch(&[prompt], max_new).unwrap();
        assert_eq!(out[0], want);
    }

    #[test]
    fn capacity_caps_generation() {
        // seq=16, prompt=14: positions 14 and 15 accept generated
        // tokens, plus one final prediction at full context —
        // `seq − prompt + 1` tokens, no matter how many were asked for.
        let mut g = NativeGenerator::fp(tiny(), 2, SamplingCfg::default());
        let out = g.generate_batch(&[vec![1u8; 14]], 10).unwrap();
        assert_eq!(out[0].len(), 3);
    }

    #[test]
    fn temperature_sampling_is_seed_deterministic() {
        // Same seed + same batch → identical continuations, regardless
        // of worker count (prefill fan-out preserves order and the RNG
        // is only touched on the coordinator thread).
        let sampling = SamplingCfg { temperature: 0.9, seed: 5 };
        let prompts = [vec![2u8, 7, 1], vec![9, 9], vec![1]];
        let mut a = NativeGenerator::fp(tiny(), 4, sampling);
        let mut b = NativeGenerator::fp(tiny(), 4, sampling);
        assert_eq!(
            a.generate_batch(&prompts, 4).unwrap(),
            b.generate_batch(&prompts, 4).unwrap()
        );
    }
}
