//! Continuous-batching scheduler: the in-flight replacement for the
//! one-shot batch loop.
//!
//! Each [`Scheduler::tick`] sheds expired work, resumes what it can,
//! admits from the queue up to the engine's slot cap and the page-pool
//! watermark, runs one batched decode step, and replies to whatever
//! finished — so sequences join mid-decode and leave individually at
//! their own `max_new` instead of idling until the slowest member of a
//! static batch drains.
//!
//! Backpressure is two-level: a bounded wait queue (`max_queue`, overflow
//! rejected immediately) and an admission watermark on page-pool
//! occupancy (new prefills stop while the pool is nearly full, leaving
//! headroom for running sequences to grow). When growth still exhausts
//! the budget, the engine preempts (newest first) and the scheduler
//! resumes the victims front-first once pages free up. A liveness rule
//! guarantees ticks always make progress: with an empty engine, a
//! preempted sequence that cannot resume finishes with the tokens it has,
//! and a queued request that cannot admit is rejected rather than wedging
//! the queue.
//!
//! Failure handling: requests carry optional deadlines — expired queued
//! requests are shed before admission, expired running sequences are
//! cancelled at tick granularity (pages released, partial output
//! returned). The decode step runs under `catch_unwind`; a panic that
//! escapes the engine's own isolation (or a step error) fails the
//! in-flight set and reports [`Tick::EngineFailed`] so the supervisor
//! can respawn via [`Scheduler::replace_engine`] — the queue survives.

use super::metrics::lock_recover;
use super::server::{respond_plan, ServePlan};
use super::{AdmitOutcome, GenRequest, GenStatus, ServeMetrics, StepEngine};
use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Continuous-serving policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct ContinuousCfg {
    /// Bounded wait queue: requests arriving past this depth are rejected
    /// immediately ([`GenStatus::Rejected`]).
    pub max_queue: usize,
    /// Stop admitting new sequences while page-pool occupancy is at or
    /// above this fraction, reserving the remainder for in-flight growth.
    pub admit_watermark: f64,
    /// Initial delay before respawning a lost engine; doubles per
    /// consecutive failure up to [`Self::respawn_backoff_cap`].
    pub respawn_backoff: Duration,
    /// Upper bound on the respawn delay.
    pub respawn_backoff_cap: Duration,
}

impl Default for ContinuousCfg {
    fn default() -> Self {
        ContinuousCfg {
            max_queue: 256,
            admit_watermark: 0.9,
            respawn_backoff: Duration::from_millis(10),
            respawn_backoff_cap: Duration::from_secs(1),
        }
    }
}

/// What a [`Scheduler::tick`] did to the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tick {
    /// Normal round; the engine is healthy.
    Ok,
    /// The decode step panicked past the engine's own isolation (or
    /// returned an error): in-flight sequences were failed, the engine
    /// is unusable, and the caller must [`Scheduler::replace_engine`]
    /// before ticking again. Queued requests are preserved.
    EngineFailed,
}

/// Drives a [`StepEngine`] one batched token at a time.
pub struct Scheduler {
    engine: Box<dyn StepEngine>,
    cfg: ContinuousCfg,
    queue: VecDeque<GenRequest>,
    /// Engine sequence id → the request it serves (present while running
    /// *or* preempted).
    inflight: HashMap<u64, GenRequest>,
    /// Preempted ids awaiting capacity, oldest first.
    preempted: VecDeque<u64>,
    metrics: Arc<Mutex<ServeMetrics>>,
    started: Instant,
    draining: bool,
    /// Serving plan stamped on every response this scheduler produces
    /// (a brownout pool runs a second scheduler with a degraded plan).
    plan: ServePlan,
}

impl Scheduler {
    pub fn new(
        engine: Box<dyn StepEngine>,
        cfg: ContinuousCfg,
        metrics: Arc<Mutex<ServeMetrics>>,
    ) -> Scheduler {
        Scheduler {
            engine,
            cfg,
            queue: VecDeque::new(),
            inflight: HashMap::new(),
            preempted: VecDeque::new(),
            metrics,
            started: Instant::now(),
            draining: false,
            plan: ServePlan::Full,
        }
    }

    /// Label every response from this scheduler with `plan`.
    pub fn with_plan(mut self, plan: ServePlan) -> Scheduler {
        self.plan = plan;
        self
    }

    /// Accept or reject an incoming request (bounded-queue backpressure,
    /// drain mode, and dead-on-arrival deadlines).
    pub fn enqueue(&mut self, req: GenRequest) {
        let now = Instant::now();
        if req.expired(now) {
            let mut met = lock_recover(&self.metrics);
            met.expired += 1;
            met.shed_wait.record(now - req.enqueued);
            respond_plan(&req, Vec::new(), 0, GenStatus::Expired, self.plan);
            return;
        }
        if self.draining || self.queue.len() >= self.cfg.max_queue {
            lock_recover(&self.metrics).rejected += 1;
            respond_plan(&req, Vec::new(), 0, GenStatus::Rejected, self.plan);
            return;
        }
        self.queue.push_back(req);
    }

    /// Nothing queued, running, or preempted.
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.inflight.is_empty()
    }

    /// Enter drain mode: queued-but-unadmitted requests get terminal
    /// rejections now, admission stops, and only the in-flight set keeps
    /// ticking to completion (or deadline). Idempotent.
    pub fn begin_drain(&mut self) {
        self.draining = true;
        if self.queue.is_empty() {
            return;
        }
        let mut met = lock_recover(&self.metrics);
        for req in self.queue.drain(..) {
            met.rejected += 1;
            respond_plan(&req, Vec::new(), 0, GenStatus::Rejected, self.plan);
        }
    }

    /// Terminate everything with a terminal response: queued requests are
    /// rejected, in-flight sequences failed. For non-recoverable errors —
    /// clients must never hang.
    pub fn abort(&mut self) {
        self.begin_drain();
        let n = self.fail_inflight();
        lock_recover(&self.metrics).failed += n;
    }

    /// Swap in a fresh engine after [`Tick::EngineFailed`]. The failed
    /// tick already gave every in-flight request a terminal response, so
    /// the replacement starts from the surviving queue only.
    pub fn replace_engine(&mut self, engine: Box<dyn StepEngine>) {
        debug_assert!(self.inflight.is_empty(), "replace_engine with live sequences");
        self.engine = engine;
    }

    /// Fail every in-flight request (engine state is unknown — no partial
    /// output can be trusted). Returns how many were failed.
    fn fail_inflight(&mut self) -> u64 {
        let n = self.inflight.len() as u64;
        for (_, req) in self.inflight.drain() {
            respond_plan(&req, Vec::new(), 0, GenStatus::Failed, self.plan);
        }
        self.preempted.clear();
        n
    }

    /// Silently drop a request by its *request* id: no response is sent
    /// and no metric recorded. Used by the replica router to cancel the
    /// losing arm of a hedged request — the winner already answered the
    /// client, so the loser must vanish without a second terminal.
    /// Returns false if the id is unknown (already finished or never
    /// routed here).
    pub fn cancel(&mut self, req_id: u64) -> bool {
        if let Some(pos) = self.queue.iter().position(|r| r.id == req_id) {
            self.queue.remove(pos);
            return true;
        }
        let eid = self.inflight.iter().find(|(_, r)| r.id == req_id).map(|(&id, _)| id);
        if let Some(id) = eid {
            self.inflight.remove(&id);
            // Frees the sequence's pages; the tokens are discarded.
            let _ = self.engine.take_output(id);
            self.preempted.retain(|&p| p != id);
            return true;
        }
        false
    }

    /// Hand back every queued-but-unadmitted request so the caller can
    /// reroute it (circuit-breaker open: the queue must not starve
    /// behind a dead engine). In-flight work is untouched.
    pub fn take_queue(&mut self) -> Vec<GenRequest> {
        self.queue.drain(..).collect()
    }

    /// Queued-but-unadmitted depth (excludes in-flight).
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Page-pool occupancy in [0, 1]; 0 when the budget is unbounded.
    pub fn occupancy(&self) -> f64 {
        let ps = self.engine.pool_stats();
        if ps.budget_bytes == 0 || ps.budget_bytes == usize::MAX {
            return 0.0;
        }
        ps.live_bytes as f64 / ps.budget_bytes as f64
    }

    /// One scheduling round: shed/cancel expired → resume → admit → step
    /// → reply → account.
    pub fn tick(&mut self) -> Result<Tick> {
        // Deadline shedding, queue first: expired waiters leave before
        // they can consume an admission slot.
        let now = Instant::now();
        let mut shed: Vec<GenRequest> = Vec::new();
        if self.queue.iter().any(|r| r.expired(now)) {
            let (expired, keep): (Vec<_>, Vec<_>) =
                self.queue.drain(..).partition(|r| r.expired(now));
            self.queue = keep.into();
            shed = expired;
        }

        // Deadline cancellation, in-flight: past-deadline sequences stop
        // at tick granularity; their pages free immediately and the
        // caller gets the bit-exact prefix generated so far.
        let over: Vec<u64> = self
            .inflight
            .iter()
            .filter(|(_, r)| r.expired(now))
            .map(|(&id, _)| id)
            .collect();
        let mut cancelled: Vec<(GenRequest, Vec<u8>)> = Vec::new();
        for id in over {
            if let Some(req) = self.inflight.remove(&id) {
                let tokens = self.engine.take_output(id).unwrap_or_default();
                self.preempted.retain(|&p| p != id);
                cancelled.push((req, tokens));
            }
        }

        // Resume preempted sequences front-first (FCFS among victims);
        // stop at the first that still lacks capacity to keep ordering.
        let mut resumed = 0usize;
        while let Some(&id) = self.preempted.front() {
            if !self.engine.resume(id)? {
                break;
            }
            self.preempted.pop_front();
            resumed += 1;
        }

        // Admit from the queue while slots and pages allow. An empty
        // engine bypasses the watermark: occupancy held by shared prefix
        // pages alone must not wedge an idle server.
        let mut admitted = 0usize;
        let mut ttfts: Vec<Duration> = Vec::new();
        while !self.queue.is_empty()
            && self.engine.running() < self.engine.max_concurrent()
            && (self.engine.running() == 0 || self.occupancy() < self.cfg.admit_watermark)
        {
            let mut req = self.queue.pop_front().expect("queue non-empty");
            let prompt = std::mem::take(&mut req.prompt);
            match self.engine.admit(prompt, req.max_new, req.key)? {
                AdmitOutcome::Admitted(id) => {
                    // TTFT: queueing wait + this request's own prefill +
                    // first sample, all inside `admit`.
                    ttfts.push(req.enqueued.elapsed());
                    self.inflight.insert(id, req);
                    admitted += 1;
                }
                AdmitOutcome::NoCapacity(p) => {
                    req.prompt = p;
                    self.queue.push_front(req);
                    break;
                }
            }
        }

        // The decode step is the panic frontier: engines isolate and
        // quarantine what they can (surfacing it via `take_failed`), but
        // a panic that escapes here means the engine itself is gone.
        let bsz = self.engine.running();
        let stepped = if bsz > 0 {
            catch_unwind(AssertUnwindSafe(|| self.engine.step()))
        } else {
            Ok(Ok(Vec::new()))
        };
        let finished = match stepped {
            Ok(Ok(f)) => f,
            Ok(Err(e)) => {
                eprintln!("engine step failed: {e:#}");
                return self.tick_engine_failed(shed, cancelled, now);
            }
            Err(_) => {
                eprintln!("engine step panicked; failing in-flight sequences");
                return self.tick_engine_failed(shed, cancelled, now);
            }
        };

        let mut done: Vec<(GenRequest, Vec<u8>)> = Vec::new();
        for id in finished {
            if let Some(req) = self.inflight.remove(&id) {
                let tokens = self.engine.take_output(id).unwrap_or_default();
                done.push((req, tokens));
            }
        }

        // Sequences the engine quarantined via its own panic isolation:
        // terminal failures, partial output returned for diagnosis.
        let mut failed: Vec<(GenRequest, Vec<u8>)> = Vec::new();
        for id in self.engine.take_failed() {
            if let Some(req) = self.inflight.remove(&id) {
                let tokens = self.engine.take_output(id).unwrap_or_default();
                self.preempted.retain(|&p| p != id);
                failed.push((req, tokens));
            }
        }

        let newly = self.engine.take_preempted();
        let n_preempted = newly.len() as u64;
        self.preempted.extend(newly);

        // Liveness: a tick that did nothing with an empty engine would
        // repeat forever. Retire one blocked head: a preempted sequence
        // finishes with the tokens it already generated; a queued request
        // that cannot ever admit (e.g. needs more pages than exist) is
        // rejected.
        let mut forced_rejects = 0u64;
        if resumed == 0 && admitted == 0 && bsz == 0 && self.engine.running() == 0 {
            if let Some(id) = self.preempted.pop_front() {
                if let Some(req) = self.inflight.remove(&id) {
                    let tokens = self.engine.take_output(id).unwrap_or_default();
                    done.push((req, tokens));
                }
            } else if let Some(req) = self.queue.pop_front() {
                forced_rejects = 1;
                respond_plan(&req, Vec::new(), 0, GenStatus::Rejected, self.plan);
            }
        }

        let ps = self.engine.pool_stats();
        let stats = self.engine.take_stats();
        let mut met = lock_recover(&self.metrics);
        Self::record_shed(&mut met, &shed, &cancelled, now, self.plan);
        for t in ttfts {
            met.ttft.record(t);
        }
        for (req, tokens) in done {
            let latency = req.enqueued.elapsed();
            let tokens: Vec<u8> = tokens.into_iter().take(req.max_new).collect();
            met.requests += 1;
            met.tokens_out += tokens.len() as u64;
            met.request_latency.record(latency);
            if self.plan == ServePlan::Degraded {
                met.brownout_served += 1;
            }
            let _ = req.reply.send(super::GenResponse {
                id: req.id,
                tokens,
                latency,
                batch_size: bsz,
                status: GenStatus::Ok,
                plan: self.plan,
            });
        }
        for (req, tokens) in failed {
            met.failed += 1;
            let tokens: Vec<u8> = tokens.into_iter().take(req.max_new).collect();
            respond_plan(&req, tokens, bsz, GenStatus::Failed, self.plan);
        }
        met.preemptions += n_preempted;
        met.rejected += forced_rejects;
        met.queue_depth.push(self.queue.len());
        if bsz > 0 {
            met.batch_sizes.push(bsz);
        }
        met.kv_live_bytes = ps.live_bytes;
        met.kv_peak_bytes = ps.peak_bytes;
        met.kv_budget_bytes = ps.budget_bytes;
        met.prefix_hits = ps.prefix_hits;
        met.prefix_lookups = ps.prefix_lookups;
        met.engine.accumulate(&stats);
        met.elapsed = self.started.elapsed();
        Ok(Tick::Ok)
    }

    /// Common exit for a tick that lost the engine: deliver this tick's
    /// shed/cancelled responses, fail the in-flight set, keep the queue.
    fn tick_engine_failed(
        &mut self,
        shed: Vec<GenRequest>,
        cancelled: Vec<(GenRequest, Vec<u8>)>,
        now: Instant,
    ) -> Result<Tick> {
        let n_failed = self.fail_inflight();
        let mut met = lock_recover(&self.metrics);
        Self::record_shed(&mut met, &shed, &cancelled, now, self.plan);
        met.failed += n_failed;
        met.elapsed = self.started.elapsed();
        Ok(Tick::EngineFailed)
    }

    /// Deliver + account deadline sheds (queued) and cancellations
    /// (in-flight) under an already-held metrics lock.
    fn record_shed(
        met: &mut ServeMetrics,
        shed: &[GenRequest],
        cancelled: &[(GenRequest, Vec<u8>)],
        now: Instant,
        plan: ServePlan,
    ) {
        for req in shed {
            met.expired += 1;
            met.shed_wait.record(now - req.enqueued);
            respond_plan(req, Vec::new(), 0, GenStatus::Expired, plan);
        }
        for (req, tokens) in cancelled {
            met.cancelled += 1;
            met.shed_wait.record(now - req.enqueued);
            let tokens: Vec<u8> = tokens.iter().cloned().take(req.max_new).collect();
            met.tokens_out += tokens.len() as u64;
            respond_plan(req, tokens, 0, GenStatus::Expired, plan);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PoolStats;

    /// Scriptable step engine: each step appends one `id as u8` token to
    /// every running sequence; a sequence finishes at its own `max_new`.
    struct MockEngine {
        slots: usize,
        /// Longest admissible prompt (models the page budget).
        admit_cap: usize,
        /// Preempt every running sequence on the first `step` call.
        preempt_on_first_step: bool,
        allow_resume: bool,
        did_preempt: bool,
        /// Panic inside `step` on the Nth call (1-based).
        panic_on_step: Option<usize>,
        /// Quarantine this sequence id on the first step (models engine
        /// panic isolation surfacing via `take_failed`).
        quarantine_on_first_step: Option<u64>,
        steps: usize,
        running: Vec<u64>,
        seqs: HashMap<u64, (Vec<u8>, usize)>,
        pending_preempt: Vec<u64>,
        pending_failed: Vec<u64>,
        next_id: u64,
    }

    impl MockEngine {
        fn new(slots: usize) -> MockEngine {
            MockEngine {
                slots,
                admit_cap: usize::MAX,
                preempt_on_first_step: false,
                allow_resume: true,
                did_preempt: false,
                panic_on_step: None,
                quarantine_on_first_step: None,
                steps: 0,
                running: Vec::new(),
                seqs: HashMap::new(),
                pending_preempt: Vec::new(),
                pending_failed: Vec::new(),
                next_id: 0,
            }
        }
    }

    impl StepEngine for MockEngine {
        fn admit(&mut self, prompt: Vec<u8>, max_new: usize, _key: u64) -> Result<AdmitOutcome> {
            if self.running.len() >= self.slots || prompt.len() > self.admit_cap {
                return Ok(AdmitOutcome::NoCapacity(prompt));
            }
            let id = self.next_id;
            self.next_id += 1;
            self.seqs.insert(id, (vec![id as u8], max_new.max(1)));
            self.running.push(id);
            Ok(AdmitOutcome::Admitted(id))
        }

        fn step(&mut self) -> Result<Vec<u64>> {
            self.steps += 1;
            if self.panic_on_step == Some(self.steps) {
                panic!("scripted step panic");
            }
            if self.preempt_on_first_step && !self.did_preempt {
                self.did_preempt = true;
                self.pending_preempt.append(&mut self.running);
                return Ok(Vec::new());
            }
            if let Some(bad) = self.quarantine_on_first_step.take() {
                if self.running.contains(&bad) {
                    self.running.retain(|&r| r != bad);
                    self.pending_failed.push(bad);
                }
            }
            let mut finished = Vec::new();
            for &id in &self.running {
                let (out, max_new) = self.seqs.get_mut(&id).unwrap();
                if out.len() < *max_new {
                    out.push(id as u8);
                }
                if out.len() >= *max_new {
                    finished.push(id);
                }
            }
            self.running.retain(|id| !finished.contains(id));
            Ok(finished)
        }

        fn take_output(&mut self, id: u64) -> Option<Vec<u8>> {
            self.running.retain(|&r| r != id);
            self.seqs.remove(&id).map(|(out, _)| out)
        }

        fn take_preempted(&mut self) -> Vec<u64> {
            std::mem::take(&mut self.pending_preempt)
        }

        fn take_failed(&mut self) -> Vec<u64> {
            std::mem::take(&mut self.pending_failed)
        }

        fn resume(&mut self, id: u64) -> Result<bool> {
            if !self.allow_resume || self.running.len() >= self.slots {
                return Ok(false);
            }
            self.running.push(id);
            Ok(true)
        }

        fn running(&self) -> usize {
            self.running.len()
        }

        fn max_concurrent(&self) -> usize {
            self.slots
        }

        fn pool_stats(&self) -> PoolStats {
            PoolStats::default()
        }
    }

    fn drain(sched: &mut Scheduler) {
        let mut guard = 0;
        while !sched.idle() {
            assert_eq!(sched.tick().unwrap(), Tick::Ok);
            guard += 1;
            assert!(guard < 1000, "scheduler failed to drain");
        }
    }

    #[test]
    fn sequences_join_and_leave_individually() {
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let mut sched = Scheduler::new(
            Box::new(MockEngine::new(4)),
            ContinuousCfg::default(),
            metrics.clone(),
        );
        // Different max_new: each leaves at its own length, none waits
        // for the batch-wide max.
        let mut rxs = Vec::new();
        for (i, max_new) in [5usize, 1, 3].iter().enumerate() {
            let (req, rx) = GenRequest::new(i as u64, vec![7; 2], *max_new);
            sched.enqueue(req);
            rxs.push((rx, *max_new));
        }
        drain(&mut sched);
        for (rx, max_new) in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.is_ok());
            assert_eq!(resp.tokens.len(), max_new);
        }
        let met = metrics.lock().unwrap();
        assert_eq!(met.requests, 3);
        assert_eq!(met.tokens_out, 9);
        assert_eq!(met.rejected, 0);
        // The short sequence left while the long one kept running, so
        // batch size varied across ticks.
        assert!(met.batch_sizes.iter().any(|&b| b == 3), "{:?}", met.batch_sizes);
        assert!(met.batch_sizes.iter().any(|&b| b < 3), "{:?}", met.batch_sizes);
        assert_eq!(met.ttft.count(), 3);
    }

    #[test]
    fn bounded_queue_rejects_overflow() {
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let mut sched = Scheduler::new(
            Box::new(MockEngine::new(1)),
            ContinuousCfg { max_queue: 1, ..Default::default() },
            metrics.clone(),
        );
        let (a, rxa) = GenRequest::new(0, vec![1], 2);
        let (b, rxb) = GenRequest::new(1, vec![2], 2);
        sched.enqueue(a);
        sched.enqueue(b); // queue full → rejected before any tick
        let rb = rxb.recv().unwrap();
        assert!(rb.rejected());
        assert!(rb.tokens.is_empty());
        drain(&mut sched);
        assert!(rxa.recv().unwrap().is_ok());
        let met = metrics.lock().unwrap();
        assert_eq!(met.rejected, 1);
        assert_eq!(met.requests, 1);
    }

    #[test]
    fn unservable_request_rejected_not_wedged() {
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let mut engine = MockEngine::new(2);
        engine.admit_cap = 4; // prompts longer than 4 can never fit
        let mut sched =
            Scheduler::new(Box::new(engine), ContinuousCfg::default(), metrics.clone());
        let (bad, rx_bad) = GenRequest::new(0, vec![9; 100], 3);
        let (ok, rx_ok) = GenRequest::new(1, vec![9; 2], 2);
        sched.enqueue(bad);
        sched.enqueue(ok);
        drain(&mut sched);
        assert!(rx_bad.recv().unwrap().rejected());
        let resp = rx_ok.recv().unwrap();
        assert!(resp.is_ok());
        assert_eq!(resp.tokens.len(), 2);
        assert_eq!(metrics.lock().unwrap().rejected, 1);
    }

    #[test]
    fn unresumable_preempted_sequence_finishes_with_partial_output() {
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let mut engine = MockEngine::new(2);
        engine.preempt_on_first_step = true;
        engine.allow_resume = false;
        let mut sched =
            Scheduler::new(Box::new(engine), ContinuousCfg::default(), metrics.clone());
        let (req, rx) = GenRequest::new(0, vec![3; 2], 5);
        sched.enqueue(req);
        drain(&mut sched);
        let resp = rx.recv().unwrap();
        // Finished with what it had: the first token from admit, not the
        // full five, and not a rejection.
        assert!(resp.is_ok());
        assert_eq!(resp.tokens.len(), 1);
        let met = metrics.lock().unwrap();
        assert_eq!(met.preemptions, 1);
        assert_eq!(met.requests, 1);
    }

    #[test]
    fn preempted_sequences_resume_and_complete() {
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let mut engine = MockEngine::new(2);
        engine.preempt_on_first_step = true; // resume allowed (default)
        let mut sched =
            Scheduler::new(Box::new(engine), ContinuousCfg::default(), metrics.clone());
        let (req, rx) = GenRequest::new(0, vec![3; 2], 4);
        sched.enqueue(req);
        drain(&mut sched);
        let resp = rx.recv().unwrap();
        assert!(resp.is_ok());
        assert_eq!(resp.tokens.len(), 4);
        assert_eq!(metrics.lock().unwrap().preemptions, 1);
    }

    #[test]
    fn expired_queued_request_is_shed_before_admission() {
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let mut sched = Scheduler::new(
            Box::new(MockEngine::new(1)),
            ContinuousCfg::default(),
            metrics.clone(),
        );
        // Slot-starved: `long` occupies the single slot, `dead` waits
        // with an already-past deadline and must be shed, never admitted.
        let (long, rx_long) = GenRequest::new(0, vec![1], 3);
        let (dead, rx_dead) =
            GenRequest::with_deadline(1, vec![2], 3, Instant::now() - Duration::from_millis(1));
        sched.enqueue(long);
        sched.tick().unwrap(); // admits `long`
        sched.enqueue(dead);
        drain(&mut sched);
        assert_eq!(rx_dead.recv().unwrap().status, GenStatus::Expired);
        assert!(rx_long.recv().unwrap().is_ok());
        let met = metrics.lock().unwrap();
        assert_eq!(met.expired, 1);
        assert_eq!(met.requests, 1);
        assert_eq!(met.shed_wait.count(), 1);
    }

    #[test]
    fn expired_inflight_sequence_is_cancelled_with_partial_output() {
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let mut sched = Scheduler::new(
            Box::new(MockEngine::new(2)),
            ContinuousCfg::default(),
            metrics.clone(),
        );
        let (req, rx) =
            GenRequest::with_deadline(0, vec![1], 100, Instant::now() + Duration::from_millis(20));
        sched.enqueue(req);
        sched.tick().unwrap(); // admitted, running
        std::thread::sleep(Duration::from_millis(30));
        sched.tick().unwrap(); // past deadline → cancelled this tick
        assert!(sched.idle(), "cancelled sequence must leave the scheduler");
        let resp = rx.recv().unwrap();
        assert_eq!(resp.status, GenStatus::Expired);
        // Partial output: at least the first token from admit, well short
        // of the requested 100.
        assert!(!resp.tokens.is_empty());
        assert!(resp.tokens.len() < 100);
        let met = metrics.lock().unwrap();
        assert_eq!(met.cancelled, 1);
        assert_eq!(met.requests, 0);
    }

    #[test]
    fn drain_rejects_queued_and_completes_inflight() {
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let mut sched = Scheduler::new(
            Box::new(MockEngine::new(1)),
            ContinuousCfg::default(),
            metrics.clone(),
        );
        let (a, rxa) = GenRequest::new(0, vec![1], 3);
        let (b, rxb) = GenRequest::new(1, vec![2], 3);
        sched.enqueue(a);
        sched.enqueue(b);
        sched.tick().unwrap(); // one slot: `a` admitted, `b` queued
        sched.begin_drain();
        // Queued request gets its terminal rejection immediately…
        assert!(rxb.recv().unwrap().rejected());
        // …and a post-drain submit is rejected too.
        let (c, rxc) = GenRequest::new(2, vec![3], 1);
        sched.enqueue(c);
        assert!(rxc.recv().unwrap().rejected());
        // …while the in-flight sequence runs to its full completion.
        drain(&mut sched);
        let ra = rxa.recv().unwrap();
        assert!(ra.is_ok());
        assert_eq!(ra.tokens.len(), 3);
        let met = metrics.lock().unwrap();
        assert_eq!(met.requests, 1);
        assert_eq!(met.rejected, 2);
    }

    #[test]
    fn step_panic_fails_inflight_preserves_queue() {
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let mut engine = MockEngine::new(1);
        engine.panic_on_step = Some(1);
        let mut sched =
            Scheduler::new(Box::new(engine), ContinuousCfg::default(), metrics.clone());
        let (a, rxa) = GenRequest::new(0, vec![1], 2);
        let (b, rxb) = GenRequest::new(1, vec![2], 2);
        sched.enqueue(a);
        sched.enqueue(b);
        // One slot: `a` admits then the step panics.
        assert_eq!(sched.tick().unwrap(), Tick::EngineFailed);
        let ra = rxa.recv().unwrap();
        assert_eq!(ra.status, GenStatus::Failed);
        // `b` survived in the queue; a replacement engine serves it.
        sched.replace_engine(Box::new(MockEngine::new(1)));
        drain(&mut sched);
        assert!(rxb.recv().unwrap().is_ok());
        let met = metrics.lock().unwrap();
        assert_eq!(met.failed, 1);
        assert_eq!(met.requests, 1);
    }

    #[test]
    fn engine_quarantine_surfaces_as_failed_response() {
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let mut engine = MockEngine::new(2);
        engine.quarantine_on_first_step = Some(0);
        let mut sched =
            Scheduler::new(Box::new(engine), ContinuousCfg::default(), metrics.clone());
        let (a, rxa) = GenRequest::new(0, vec![1], 3);
        let (b, rxb) = GenRequest::new(1, vec![2], 3);
        sched.enqueue(a);
        sched.enqueue(b);
        drain(&mut sched);
        // Sequence 0 was quarantined by the engine's own isolation: a
        // terminal failure carrying its partial output.
        let ra = rxa.recv().unwrap();
        assert_eq!(ra.status, GenStatus::Failed);
        assert!(!ra.tokens.is_empty());
        // Its batch-mate is untouched.
        let rb = rxb.recv().unwrap();
        assert!(rb.is_ok());
        assert_eq!(rb.tokens.len(), 3);
        let met = metrics.lock().unwrap();
        assert_eq!(met.failed, 1);
        assert_eq!(met.requests, 1);
    }

    #[test]
    fn abort_terminates_everything() {
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let mut sched = Scheduler::new(
            Box::new(MockEngine::new(1)),
            ContinuousCfg::default(),
            metrics.clone(),
        );
        let (a, rxa) = GenRequest::new(0, vec![1], 5);
        let (b, rxb) = GenRequest::new(1, vec![2], 5);
        sched.enqueue(a);
        sched.enqueue(b);
        sched.tick().unwrap(); // `a` in flight, `b` queued
        sched.abort();
        assert!(sched.idle());
        assert_eq!(rxa.recv().unwrap().status, GenStatus::Failed);
        assert_eq!(rxb.recv().unwrap().status, GenStatus::Rejected);
    }
}
