//! Continuous-batching scheduler: the in-flight replacement for the
//! one-shot batch loop.
//!
//! Each [`Scheduler::tick`] resumes what it can, admits from the queue up
//! to the engine's slot cap and the page-pool watermark, runs one batched
//! decode step, and replies to whatever finished — so sequences join
//! mid-decode and leave individually at their own `max_new` instead of
//! idling until the slowest member of a static batch drains.
//!
//! Backpressure is two-level: a bounded wait queue (`max_queue`, overflow
//! rejected immediately) and an admission watermark on page-pool
//! occupancy (new prefills stop while the pool is nearly full, leaving
//! headroom for running sequences to grow). When growth still exhausts
//! the budget, the engine preempts (newest first) and the scheduler
//! resumes the victims front-first once pages free up. A liveness rule
//! guarantees ticks always make progress: with an empty engine, a
//! preempted sequence that cannot resume finishes with the tokens it has,
//! and a queued request that cannot admit is rejected rather than wedging
//! the queue.

use super::{AdmitOutcome, GenRequest, GenResponse, ServeMetrics, StepEngine};
use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Continuous-serving policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct ContinuousCfg {
    /// Bounded wait queue: requests arriving past this depth are rejected
    /// immediately (`GenResponse::rejected`).
    pub max_queue: usize,
    /// Stop admitting new sequences while page-pool occupancy is at or
    /// above this fraction, reserving the remainder for in-flight growth.
    pub admit_watermark: f64,
}

impl Default for ContinuousCfg {
    fn default() -> Self {
        ContinuousCfg { max_queue: 256, admit_watermark: 0.9 }
    }
}

/// Drives a [`StepEngine`] one batched token at a time.
pub struct Scheduler {
    engine: Box<dyn StepEngine>,
    cfg: ContinuousCfg,
    queue: VecDeque<GenRequest>,
    /// Engine sequence id → the request it serves (present while running
    /// *or* preempted).
    inflight: HashMap<u64, GenRequest>,
    /// Preempted ids awaiting capacity, oldest first.
    preempted: VecDeque<u64>,
    metrics: Arc<Mutex<ServeMetrics>>,
    started: Instant,
}

impl Scheduler {
    pub fn new(
        engine: Box<dyn StepEngine>,
        cfg: ContinuousCfg,
        metrics: Arc<Mutex<ServeMetrics>>,
    ) -> Scheduler {
        Scheduler {
            engine,
            cfg,
            queue: VecDeque::new(),
            inflight: HashMap::new(),
            preempted: VecDeque::new(),
            metrics,
            started: Instant::now(),
        }
    }

    /// Accept or reject an incoming request (bounded-queue backpressure).
    pub fn enqueue(&mut self, req: GenRequest) {
        if self.queue.len() >= self.cfg.max_queue {
            self.metrics.lock().unwrap().rejected += 1;
            let _ = req.reply.send(GenResponse {
                id: req.id,
                tokens: Vec::new(),
                latency: req.enqueued.elapsed(),
                batch_size: 0,
                rejected: true,
            });
            return;
        }
        self.queue.push_back(req);
    }

    /// Nothing queued, running, or preempted.
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.inflight.is_empty()
    }

    fn occupancy(&self) -> f64 {
        let ps = self.engine.pool_stats();
        if ps.budget_bytes == 0 || ps.budget_bytes == usize::MAX {
            return 0.0;
        }
        ps.live_bytes as f64 / ps.budget_bytes as f64
    }

    /// One scheduling round: resume → admit → step → reply → account.
    pub fn tick(&mut self) -> Result<()> {
        // Resume preempted sequences front-first (FCFS among victims);
        // stop at the first that still lacks capacity to keep ordering.
        let mut resumed = 0usize;
        while let Some(&id) = self.preempted.front() {
            if !self.engine.resume(id)? {
                break;
            }
            self.preempted.pop_front();
            resumed += 1;
        }

        // Admit from the queue while slots and pages allow. An empty
        // engine bypasses the watermark: occupancy held by shared prefix
        // pages alone must not wedge an idle server.
        let mut admitted = 0usize;
        let mut ttfts: Vec<Duration> = Vec::new();
        while !self.queue.is_empty()
            && self.engine.running() < self.engine.max_concurrent()
            && (self.engine.running() == 0 || self.occupancy() < self.cfg.admit_watermark)
        {
            let mut req = self.queue.pop_front().expect("queue non-empty");
            let prompt = std::mem::take(&mut req.prompt);
            match self.engine.admit(prompt, req.max_new)? {
                AdmitOutcome::Admitted(id) => {
                    // TTFT: queueing wait + this request's own prefill +
                    // first sample, all inside `admit`.
                    ttfts.push(req.enqueued.elapsed());
                    self.inflight.insert(id, req);
                    admitted += 1;
                }
                AdmitOutcome::NoCapacity(p) => {
                    req.prompt = p;
                    self.queue.push_front(req);
                    break;
                }
            }
        }

        let bsz = self.engine.running();
        let finished = if bsz > 0 { self.engine.step()? } else { Vec::new() };

        let mut done: Vec<(GenRequest, Vec<u8>)> = Vec::new();
        for id in finished {
            if let Some(req) = self.inflight.remove(&id) {
                let tokens = self.engine.take_output(id).unwrap_or_default();
                done.push((req, tokens));
            }
        }

        let newly = self.engine.take_preempted();
        let n_preempted = newly.len() as u64;
        self.preempted.extend(newly);

        // Liveness: a tick that did nothing with an empty engine would
        // repeat forever. Retire one blocked head: a preempted sequence
        // finishes with the tokens it already generated; a queued request
        // that cannot ever admit (e.g. needs more pages than exist) is
        // rejected.
        let mut forced_rejects = 0u64;
        if resumed == 0 && admitted == 0 && bsz == 0 && self.engine.running() == 0 {
            if let Some(id) = self.preempted.pop_front() {
                if let Some(req) = self.inflight.remove(&id) {
                    let tokens = self.engine.take_output(id).unwrap_or_default();
                    done.push((req, tokens));
                }
            } else if let Some(req) = self.queue.pop_front() {
                forced_rejects = 1;
                let _ = req.reply.send(GenResponse {
                    id: req.id,
                    tokens: Vec::new(),
                    latency: req.enqueued.elapsed(),
                    batch_size: 0,
                    rejected: true,
                });
            }
        }

        let ps = self.engine.pool_stats();
        let stats = self.engine.take_stats();
        let mut met = self.metrics.lock().unwrap();
        for t in ttfts {
            met.ttft.record(t);
        }
        for (req, tokens) in done {
            let latency = req.enqueued.elapsed();
            let tokens: Vec<u8> = tokens.into_iter().take(req.max_new).collect();
            met.requests += 1;
            met.tokens_out += tokens.len() as u64;
            met.request_latency.record(latency);
            let _ = req.reply.send(GenResponse {
                id: req.id,
                tokens,
                latency,
                batch_size: bsz,
                rejected: false,
            });
        }
        met.preemptions += n_preempted;
        met.rejected += forced_rejects;
        met.queue_depth.push(self.queue.len());
        if bsz > 0 {
            met.batch_sizes.push(bsz);
        }
        met.kv_live_bytes = ps.live_bytes;
        met.kv_peak_bytes = ps.peak_bytes;
        met.kv_budget_bytes = ps.budget_bytes;
        met.prefix_hits = ps.prefix_hits;
        met.prefix_lookups = ps.prefix_lookups;
        met.engine.accumulate(&stats);
        met.elapsed = self.started.elapsed();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PoolStats;

    /// Scriptable step engine: each step appends one `id as u8` token to
    /// every running sequence; a sequence finishes at its own `max_new`.
    struct MockEngine {
        slots: usize,
        /// Longest admissible prompt (models the page budget).
        admit_cap: usize,
        /// Preempt every running sequence on the first `step` call.
        preempt_on_first_step: bool,
        allow_resume: bool,
        did_preempt: bool,
        running: Vec<u64>,
        seqs: HashMap<u64, (Vec<u8>, usize)>,
        pending_preempt: Vec<u64>,
        next_id: u64,
    }

    impl MockEngine {
        fn new(slots: usize) -> MockEngine {
            MockEngine {
                slots,
                admit_cap: usize::MAX,
                preempt_on_first_step: false,
                allow_resume: true,
                did_preempt: false,
                running: Vec::new(),
                seqs: HashMap::new(),
                pending_preempt: Vec::new(),
                next_id: 0,
            }
        }
    }

    impl StepEngine for MockEngine {
        fn admit(&mut self, prompt: Vec<u8>, max_new: usize) -> Result<AdmitOutcome> {
            if self.running.len() >= self.slots || prompt.len() > self.admit_cap {
                return Ok(AdmitOutcome::NoCapacity(prompt));
            }
            let id = self.next_id;
            self.next_id += 1;
            self.seqs.insert(id, (vec![id as u8], max_new.max(1)));
            self.running.push(id);
            Ok(AdmitOutcome::Admitted(id))
        }

        fn step(&mut self) -> Result<Vec<u64>> {
            if self.preempt_on_first_step && !self.did_preempt {
                self.did_preempt = true;
                self.pending_preempt.append(&mut self.running);
                return Ok(Vec::new());
            }
            let mut finished = Vec::new();
            for &id in &self.running {
                let (out, max_new) = self.seqs.get_mut(&id).unwrap();
                if out.len() < *max_new {
                    out.push(id as u8);
                }
                if out.len() >= *max_new {
                    finished.push(id);
                }
            }
            self.running.retain(|id| !finished.contains(id));
            Ok(finished)
        }

        fn take_output(&mut self, id: u64) -> Option<Vec<u8>> {
            self.running.retain(|&r| r != id);
            self.seqs.remove(&id).map(|(out, _)| out)
        }

        fn take_preempted(&mut self) -> Vec<u64> {
            std::mem::take(&mut self.pending_preempt)
        }

        fn resume(&mut self, id: u64) -> Result<bool> {
            if !self.allow_resume || self.running.len() >= self.slots {
                return Ok(false);
            }
            self.running.push(id);
            Ok(true)
        }

        fn running(&self) -> usize {
            self.running.len()
        }

        fn max_concurrent(&self) -> usize {
            self.slots
        }

        fn pool_stats(&self) -> PoolStats {
            PoolStats::default()
        }
    }

    fn drain(sched: &mut Scheduler) {
        let mut guard = 0;
        while !sched.idle() {
            sched.tick().unwrap();
            guard += 1;
            assert!(guard < 1000, "scheduler failed to drain");
        }
    }

    #[test]
    fn sequences_join_and_leave_individually() {
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let mut sched = Scheduler::new(
            Box::new(MockEngine::new(4)),
            ContinuousCfg::default(),
            metrics.clone(),
        );
        // Different max_new: each leaves at its own length, none waits
        // for the batch-wide max.
        let mut rxs = Vec::new();
        for (i, max_new) in [5usize, 1, 3].iter().enumerate() {
            let (req, rx) = GenRequest::new(i as u64, vec![7; 2], *max_new);
            sched.enqueue(req);
            rxs.push((rx, *max_new));
        }
        drain(&mut sched);
        for (rx, max_new) in rxs {
            let resp = rx.recv().unwrap();
            assert!(!resp.rejected);
            assert_eq!(resp.tokens.len(), max_new);
        }
        let met = metrics.lock().unwrap();
        assert_eq!(met.requests, 3);
        assert_eq!(met.tokens_out, 9);
        assert_eq!(met.rejected, 0);
        // The short sequence left while the long one kept running, so
        // batch size varied across ticks.
        assert!(met.batch_sizes.iter().any(|&b| b == 3), "{:?}", met.batch_sizes);
        assert!(met.batch_sizes.iter().any(|&b| b < 3), "{:?}", met.batch_sizes);
        assert_eq!(met.ttft.count(), 3);
    }

    #[test]
    fn bounded_queue_rejects_overflow() {
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let mut sched = Scheduler::new(
            Box::new(MockEngine::new(1)),
            ContinuousCfg { max_queue: 1, ..Default::default() },
            metrics.clone(),
        );
        let (a, rxa) = GenRequest::new(0, vec![1], 2);
        let (b, rxb) = GenRequest::new(1, vec![2], 2);
        sched.enqueue(a);
        sched.enqueue(b); // queue full → rejected before any tick
        let rb = rxb.recv().unwrap();
        assert!(rb.rejected);
        assert!(rb.tokens.is_empty());
        drain(&mut sched);
        assert!(!rxa.recv().unwrap().rejected);
        let met = metrics.lock().unwrap();
        assert_eq!(met.rejected, 1);
        assert_eq!(met.requests, 1);
    }

    #[test]
    fn unservable_request_rejected_not_wedged() {
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let mut engine = MockEngine::new(2);
        engine.admit_cap = 4; // prompts longer than 4 can never fit
        let mut sched =
            Scheduler::new(Box::new(engine), ContinuousCfg::default(), metrics.clone());
        let (bad, rx_bad) = GenRequest::new(0, vec![9; 100], 3);
        let (ok, rx_ok) = GenRequest::new(1, vec![9; 2], 2);
        sched.enqueue(bad);
        sched.enqueue(ok);
        drain(&mut sched);
        assert!(rx_bad.recv().unwrap().rejected);
        let resp = rx_ok.recv().unwrap();
        assert!(!resp.rejected);
        assert_eq!(resp.tokens.len(), 2);
        assert_eq!(metrics.lock().unwrap().rejected, 1);
    }

    #[test]
    fn unresumable_preempted_sequence_finishes_with_partial_output() {
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let mut engine = MockEngine::new(2);
        engine.preempt_on_first_step = true;
        engine.allow_resume = false;
        let mut sched =
            Scheduler::new(Box::new(engine), ContinuousCfg::default(), metrics.clone());
        let (req, rx) = GenRequest::new(0, vec![3; 2], 5);
        sched.enqueue(req);
        drain(&mut sched);
        let resp = rx.recv().unwrap();
        // Finished with what it had: the first token from admit, not the
        // full five, and not a rejection.
        assert!(!resp.rejected);
        assert_eq!(resp.tokens.len(), 1);
        let met = metrics.lock().unwrap();
        assert_eq!(met.preemptions, 1);
        assert_eq!(met.requests, 1);
    }

    #[test]
    fn preempted_sequences_resume_and_complete() {
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let mut engine = MockEngine::new(2);
        engine.preempt_on_first_step = true; // resume allowed (default)
        let mut sched =
            Scheduler::new(Box::new(engine), ContinuousCfg::default(), metrics.clone());
        let (req, rx) = GenRequest::new(0, vec![3; 2], 4);
        sched.enqueue(req);
        drain(&mut sched);
        let resp = rx.recv().unwrap();
        assert!(!resp.rejected);
        assert_eq!(resp.tokens.len(), 4);
        assert_eq!(metrics.lock().unwrap().preemptions, 1);
    }
}
