//! Serving metrics: latency histograms + throughput counters, with the
//! prefill/decode phase split the serving benchmark reports.

use super::EngineStats;
use std::time::Duration;

/// Log-bucketed latency histogram (microsecond resolution, ~7% buckets).
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Vec<u64>,
    counts: Vec<u64>,
    total: u64,
    sum_us: u64,
}

impl Histogram {
    pub fn new() -> Histogram {
        // 1 µs → ~100 s, multiplicative step 1.25.
        let mut buckets = Vec::new();
        let mut b = 1.0_f64;
        while b < 1e8 {
            buckets.push(b as u64);
            b *= 1.25;
        }
        let n = buckets.len();
        Histogram { buckets, counts: vec![0; n + 1], total: 0, sum_us: 0 }
    }

    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = self.buckets.partition_point(|&b| b <= us);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum_us += us;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> Duration {
        if self.total == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.total)
    }

    /// Upper bound of the bucket containing quantile `q`.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.total == 0 {
            return Duration::ZERO;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                let us = if i < self.buckets.len() { self.buckets[i] } else { u64::MAX / 2 };
                return Duration::from_micros(us);
            }
        }
        Duration::from_micros(*self.buckets.last().unwrap())
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub request_latency: Histogram,
    /// Time-to-first-token per request: queueing/batching wait + the
    /// serving batch's prefill phase.
    pub ttft: Histogram,
    pub batch_sizes: Vec<usize>,
    pub tokens_out: u64,
    pub requests: u64,
    pub elapsed: Duration,
    /// Accumulated engine phase split (prefill vs decode).
    pub engine: EngineStats,
}

impl ServeMetrics {
    pub fn throughput_tok_s(&self) -> f64 {
        if self.elapsed.is_zero() {
            return 0.0;
        }
        self.tokens_out as f64 / self.elapsed.as_secs_f64()
    }

    /// Steady-state decode rate: tokens produced by incremental decode
    /// steps over the time spent in them (excludes prefill, so this is
    /// the flat per-token cost the KV cache buys).
    pub fn decode_tok_s(&self) -> f64 {
        if self.engine.decode_time.is_zero() {
            return 0.0;
        }
        self.engine.decode_tokens as f64 / self.engine.decode_time.as_secs_f64()
    }

    /// Prompt-ingestion rate during prefill.
    pub fn prefill_tok_s(&self) -> f64 {
        if self.engine.prefill_time.is_zero() {
            return 0.0;
        }
        self.engine.prefill_tokens as f64 / self.engine.prefill_time.as_secs_f64()
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} tokens={} throughput={:.1} tok/s decode={:.1} tok/s prefill={:.1} tok/s \
             mean_batch={:.2} ttft_p50={:?} p50={:?} p95={:?} mean={:?}",
            self.requests,
            self.tokens_out,
            self.throughput_tok_s(),
            self.decode_tok_s(),
            self.prefill_tok_s(),
            self.mean_batch(),
            self.ttft.quantile(0.5),
            self.request_latency.quantile(0.5),
            self.request_latency.quantile(0.95),
            self.request_latency.mean(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_ordered() {
        let mut h = Histogram::new();
        for ms in 1..=100u64 {
            h.record(Duration::from_millis(ms));
        }
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        assert!(p50 <= p95);
        assert!(p50 >= Duration::from_millis(35) && p50 <= Duration::from_millis(70), "{p50:?}");
        assert!(p95 >= Duration::from_millis(80), "{p95:?}");
    }

    #[test]
    fn mean_exact() {
        let mut h = Histogram::new();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(300));
        assert_eq!(h.mean(), Duration::from_micros(200));
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn throughput_math() {
        let m = ServeMetrics {
            tokens_out: 500,
            elapsed: Duration::from_secs(2),
            ..Default::default()
        };
        assert!((m.throughput_tok_s() - 250.0).abs() < 1e-9);
    }

    #[test]
    fn phase_split_rates() {
        let m = ServeMetrics {
            engine: EngineStats {
                prefill_time: Duration::from_millis(500),
                decode_time: Duration::from_secs(2),
                prefill_tokens: 1000,
                decode_tokens: 300,
            },
            ..Default::default()
        };
        assert!((m.decode_tok_s() - 150.0).abs() < 1e-9);
        assert!((m.prefill_tok_s() - 2000.0).abs() < 1e-9);
        // Zero-phase engines report zero rates, not NaN.
        let z = ServeMetrics::default();
        assert_eq!(z.decode_tok_s(), 0.0);
        assert_eq!(z.prefill_tok_s(), 0.0);
    }
}
