//! Serving metrics: latency histograms + throughput counters, with the
//! prefill/decode phase split the serving benchmark reports.

use super::EngineStats;
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// Lock a metrics mutex, recovering from poisoning. A panicked worker
/// (contained or not) must never take metrics reporting down with it —
/// counters are plain data, valid regardless of where a writer died.
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Log-bucketed latency histogram (microsecond resolution, ~7% buckets).
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Vec<u64>,
    counts: Vec<u64>,
    total: u64,
    sum_us: u64,
    /// Largest recorded value — caps quantile estimates, so the overflow
    /// bucket reports a real latency instead of a sentinel.
    max_us: u64,
}

impl Histogram {
    pub fn new() -> Histogram {
        // 1 µs → ~100 s, multiplicative step 1.25.
        let mut buckets = Vec::new();
        let mut b = 1.0_f64;
        while b < 1e8 {
            buckets.push(b as u64);
            b *= 1.25;
        }
        let n = buckets.len();
        Histogram { buckets, counts: vec![0; n + 1], total: 0, sum_us: 0, max_us: 0 }
    }

    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = self.buckets.partition_point(|&b| b <= us);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    /// Fold another histogram into this one. All histograms share the
    /// same constructed bucket layout, so merging is element-wise — the
    /// per-replica metrics path merges into a fleet view without ever
    /// sharing (or contending on) a single lock.
    pub fn merge(&mut self, other: &Histogram) {
        debug_assert_eq!(self.buckets.len(), other.buckets.len());
        for (c, oc) in self.counts.iter_mut().zip(&other.counts) {
            *c += oc;
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> Duration {
        if self.total == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.total)
    }

    /// Largest recorded value.
    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Upper bound of the bucket containing quantile `q`, clamped to the
    /// max recorded value — samples past the last bucket report that real
    /// maximum rather than a sentinel.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.total == 0 {
            return Duration::ZERO;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                let us = if i < self.buckets.len() { self.buckets[i] } else { self.max_us };
                return Duration::from_micros(us.min(self.max_us));
            }
        }
        Duration::from_micros(self.max_us)
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub request_latency: Histogram,
    /// Time-to-first-token per request: queueing/batching wait + the
    /// serving batch's prefill phase.
    pub ttft: Histogram,
    pub batch_sizes: Vec<usize>,
    pub tokens_out: u64,
    pub requests: u64,
    pub elapsed: Duration,
    /// Accumulated engine phase split (prefill vs decode).
    pub engine: EngineStats,
    /// Scheduler queue depth sampled once per tick (continuous path).
    pub queue_depth: Vec<usize>,
    /// Requests refused by backpressure (queue cap or unservable size),
    /// shutdown drain, or a dead worker.
    pub rejected: u64,
    /// Requests shed from the queue because their deadline passed before
    /// admission.
    pub expired: u64,
    /// In-flight sequences cancelled at tick granularity because their
    /// deadline passed mid-decode (KV pages released immediately;
    /// partial tokens are returned).
    pub cancelled: u64,
    /// Requests terminated by an engine failure: quarantined by panic
    /// isolation, or in flight when the engine was lost and respawned.
    pub failed: u64,
    /// Engine respawns after a poisoned step (capped exponential
    /// backoff between attempts).
    pub respawns: u64,
    /// Queue wait of deadline-shed requests — how long doomed work sat
    /// before the scheduler gave up on it.
    pub shed_wait: Histogram,
    /// Sequences evicted under page-budget pressure (each re-prefills on
    /// resume).
    pub preemptions: u64,
    /// KV page-pool gauges (live/peak/budget bytes; budget `usize::MAX`
    /// means unbounded).
    pub kv_live_bytes: usize,
    pub kv_peak_bytes: usize,
    pub kv_budget_bytes: usize,
    /// Prompt-prefix cache counters.
    pub prefix_hits: u64,
    pub prefix_lookups: u64,
    /// Straggler requests duplicated onto a second replica by the
    /// replicated router's hedging policy.
    pub hedges_fired: u64,
    /// Hedged requests whose *duplicate* finished first (the primary was
    /// cancelled). Bit-exactness makes either winner equivalent.
    pub hedges_won: u64,
    /// Requests served by a degraded (lower-bit) brownout plan.
    pub brownout_served: u64,
    /// Replica circuit-breaker transitions to open (K consecutive
    /// failed/overdue ticks).
    pub breaker_opens: u64,
}

impl ServeMetrics {
    /// Fold another replica's metrics into this one, producing a
    /// fleet-wide view. Each replica records into its own
    /// `Arc<Mutex<ServeMetrics>>`; aggregation happens only at report
    /// time, so N replicas never contend on one lock.
    ///
    /// Counters and histograms add; pool gauges add too (each replica
    /// owns a disjoint pool, so fleet live/peak/budget are sums, with
    /// any unbounded pool making the fleet budget unbounded); `elapsed`
    /// takes the max (replicas run concurrently, not back to back).
    pub fn merge(&mut self, other: &ServeMetrics) {
        self.request_latency.merge(&other.request_latency);
        self.ttft.merge(&other.ttft);
        self.shed_wait.merge(&other.shed_wait);
        self.batch_sizes.extend_from_slice(&other.batch_sizes);
        self.queue_depth.extend_from_slice(&other.queue_depth);
        self.tokens_out += other.tokens_out;
        self.requests += other.requests;
        self.elapsed = self.elapsed.max(other.elapsed);
        self.engine.accumulate(&other.engine);
        self.rejected += other.rejected;
        self.expired += other.expired;
        self.cancelled += other.cancelled;
        self.failed += other.failed;
        self.respawns += other.respawns;
        self.preemptions += other.preemptions;
        self.kv_live_bytes += other.kv_live_bytes;
        self.kv_peak_bytes = self.kv_peak_bytes.saturating_add(other.kv_peak_bytes);
        self.kv_budget_bytes = if self.kv_budget_bytes == usize::MAX
            || other.kv_budget_bytes == usize::MAX
        {
            usize::MAX
        } else {
            self.kv_budget_bytes.saturating_add(other.kv_budget_bytes)
        };
        self.prefix_hits += other.prefix_hits;
        self.prefix_lookups += other.prefix_lookups;
        self.hedges_fired += other.hedges_fired;
        self.hedges_won += other.hedges_won;
        self.brownout_served += other.brownout_served;
        self.breaker_opens += other.breaker_opens;
    }

    pub fn throughput_tok_s(&self) -> f64 {
        if self.elapsed.is_zero() {
            return 0.0;
        }
        self.tokens_out as f64 / self.elapsed.as_secs_f64()
    }

    /// Steady-state decode rate: tokens produced by incremental decode
    /// steps over the time spent in them (excludes prefill, so this is
    /// the flat per-token cost the KV cache buys).
    pub fn decode_tok_s(&self) -> f64 {
        if self.engine.decode_time.is_zero() {
            return 0.0;
        }
        self.engine.decode_tokens as f64 / self.engine.decode_time.as_secs_f64()
    }

    /// Prompt-ingestion rate during prefill.
    pub fn prefill_tok_s(&self) -> f64 {
        if self.engine.prefill_time.is_zero() {
            return 0.0;
        }
        self.engine.prefill_tokens as f64 / self.engine.prefill_time.as_secs_f64()
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    pub fn mean_queue_depth(&self) -> f64 {
        if self.queue_depth.is_empty() {
            return 0.0;
        }
        self.queue_depth.iter().sum::<usize>() as f64 / self.queue_depth.len() as f64
    }

    pub fn max_queue_depth(&self) -> usize {
        self.queue_depth.iter().copied().max().unwrap_or(0)
    }

    /// `kv_live / kv_budget` (0.0 when unbounded).
    pub fn kv_occupancy(&self) -> f64 {
        if self.kv_budget_bytes == 0 || self.kv_budget_bytes == usize::MAX {
            return 0.0;
        }
        self.kv_live_bytes as f64 / self.kv_budget_bytes as f64
    }

    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            return 0.0;
        }
        self.prefix_hits as f64 / self.prefix_lookups as f64
    }

    pub fn summary(&self) -> String {
        let budget = if self.kv_budget_bytes == usize::MAX {
            "inf".to_string()
        } else {
            format!("{}", self.kv_budget_bytes)
        };
        format!(
            "requests={} tokens={} throughput={:.1} tok/s decode={:.1} tok/s prefill={:.1} tok/s \
             mean_batch={:.2} ttft_p50={:?} p50={:?} p95={:?} p99={:?} mean={:?}\n\
             queue_mean={:.2} queue_max={} kv_live={}B kv_peak={}B kv_budget={}B \
             kv_occupancy={:.1}% prefix_hit_rate={:.1}% preemptions={} rejected={} truncated={} \
             expired={} cancelled={} failed={} respawns={} shed_wait_p50={:?} \
             hedges_fired={} hedges_won={} brownout_served={} breaker_opens={}",
            self.requests,
            self.tokens_out,
            self.throughput_tok_s(),
            self.decode_tok_s(),
            self.prefill_tok_s(),
            self.mean_batch(),
            self.ttft.quantile(0.5),
            self.request_latency.quantile(0.5),
            self.request_latency.quantile(0.95),
            self.request_latency.quantile(0.99),
            self.request_latency.mean(),
            self.mean_queue_depth(),
            self.max_queue_depth(),
            self.kv_live_bytes,
            self.kv_peak_bytes,
            budget,
            self.kv_occupancy() * 100.0,
            self.prefix_hit_rate() * 100.0,
            self.preemptions,
            self.rejected,
            self.engine.truncated_prompts,
            self.expired,
            self.cancelled,
            self.failed,
            self.respawns,
            self.shed_wait.quantile(0.5),
            self.hedges_fired,
            self.hedges_won,
            self.brownout_served,
            self.breaker_opens,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_ordered() {
        let mut h = Histogram::new();
        for ms in 1..=100u64 {
            h.record(Duration::from_millis(ms));
        }
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        assert!(p50 <= p95);
        assert!(p50 >= Duration::from_millis(35) && p50 <= Duration::from_millis(70), "{p50:?}");
        assert!(p95 >= Duration::from_millis(80), "{p95:?}");
    }

    #[test]
    fn mean_exact() {
        let mut h = Histogram::new();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(300));
        assert_eq!(h.mean(), Duration::from_micros(200));
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn overflow_bucket_clamps_to_max_recorded() {
        // A sample past the last bucket (~100 s) used to report the
        // u64::MAX/2 sentinel; it must report the real max instead.
        let mut h = Histogram::new();
        let big = Duration::from_secs(200);
        h.record(big);
        assert_eq!(h.quantile(0.99), big);
        assert_eq!(h.max(), big);
        // Mixed: the overflow sample caps, in-range quantiles clamp to
        // the max rather than a bucket bound above it.
        let mut h = Histogram::new();
        h.record(Duration::from_micros(3));
        h.record(big);
        assert_eq!(h.quantile(1.0), big);
        assert!(h.quantile(0.25) <= Duration::from_micros(4));
    }

    #[test]
    fn histogram_merge_matches_recording_into_one() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut one = Histogram::new();
        for ms in 1..=60u64 {
            a.record(Duration::from_millis(ms));
            one.record(Duration::from_millis(ms));
        }
        for ms in 40..=100u64 {
            b.record(Duration::from_millis(ms));
            one.record(Duration::from_millis(ms));
        }
        a.merge(&b);
        assert_eq!(a.count(), one.count());
        assert_eq!(a.mean(), one.mean());
        assert_eq!(a.max(), one.max());
        for q in [0.5, 0.9, 0.99] {
            assert_eq!(a.quantile(q), one.quantile(q), "q={q} diverged after merge");
        }
    }

    #[test]
    fn serve_metrics_merge_aggregates_fleet_view() {
        let mut r0 = ServeMetrics {
            requests: 3,
            tokens_out: 30,
            rejected: 1,
            hedges_fired: 2,
            hedges_won: 1,
            kv_live_bytes: 100,
            kv_budget_bytes: 1000,
            elapsed: Duration::from_secs(2),
            queue_depth: vec![1, 2],
            ..Default::default()
        };
        r0.request_latency.record(Duration::from_millis(5));
        let mut r1 = ServeMetrics {
            requests: 4,
            tokens_out: 40,
            brownout_served: 2,
            breaker_opens: 1,
            kv_live_bytes: 200,
            kv_budget_bytes: 1000,
            elapsed: Duration::from_secs(3),
            queue_depth: vec![4],
            ..Default::default()
        };
        r1.request_latency.record(Duration::from_millis(9));
        r0.merge(&r1);
        assert_eq!(r0.requests, 7);
        assert_eq!(r0.tokens_out, 70);
        assert_eq!(r0.rejected, 1);
        assert_eq!(r0.hedges_fired, 2);
        assert_eq!(r0.hedges_won, 1);
        assert_eq!(r0.brownout_served, 2);
        assert_eq!(r0.breaker_opens, 1);
        assert_eq!(r0.kv_live_bytes, 300);
        assert_eq!(r0.kv_budget_bytes, 2000);
        assert_eq!(r0.elapsed, Duration::from_secs(3), "elapsed is max, not sum");
        assert_eq!(r0.request_latency.count(), 2);
        assert_eq!(r0.max_queue_depth(), 4);
        // Any unbounded member pool makes the fleet budget unbounded.
        let unbounded = ServeMetrics { kv_budget_bytes: usize::MAX, ..Default::default() };
        r0.merge(&unbounded);
        assert_eq!(r0.kv_budget_bytes, usize::MAX);
        let s = r0.summary();
        for needle in
            ["hedges_fired=2", "hedges_won=1", "brownout_served=2", "breaker_opens=1"]
        {
            assert!(s.contains(needle), "summary missing {needle}: {s}");
        }
    }

    #[test]
    fn summary_surfaces_serving_gauges() {
        let m = ServeMetrics {
            queue_depth: vec![0, 3, 1],
            rejected: 2,
            preemptions: 4,
            kv_live_bytes: 512,
            kv_peak_bytes: 1024,
            kv_budget_bytes: 2048,
            prefix_hits: 3,
            prefix_lookups: 4,
            expired: 5,
            cancelled: 6,
            failed: 1,
            respawns: 2,
            engine: EngineStats { truncated_prompts: 7, ..Default::default() },
            ..Default::default()
        };
        assert!((m.mean_queue_depth() - 4.0 / 3.0).abs() < 1e-9);
        assert_eq!(m.max_queue_depth(), 3);
        assert!((m.kv_occupancy() - 0.25).abs() < 1e-9);
        assert!((m.prefix_hit_rate() - 0.75).abs() < 1e-9);
        let s = m.summary();
        for needle in [
            "p99=",
            "queue_max=3",
            "kv_live=512B",
            "preemptions=4",
            "rejected=2",
            "truncated=7",
            "expired=5",
            "cancelled=6",
            "failed=1",
            "respawns=2",
            "shed_wait_p50=",
        ] {
            assert!(s.contains(needle), "summary missing {needle}: {s}");
        }
        // Unbounded pools print an inf budget, not usize::MAX.
        let z = ServeMetrics { kv_budget_bytes: usize::MAX, ..Default::default() };
        assert!(z.summary().contains("kv_budget=infB"));
        assert_eq!(z.kv_occupancy(), 0.0);
    }

    #[test]
    fn throughput_math() {
        let m = ServeMetrics {
            tokens_out: 500,
            elapsed: Duration::from_secs(2),
            ..Default::default()
        };
        assert!((m.throughput_tok_s() - 250.0).abs() < 1e-9);
    }

    #[test]
    fn phase_split_rates() {
        let m = ServeMetrics {
            engine: EngineStats {
                prefill_time: Duration::from_millis(500),
                decode_time: Duration::from_secs(2),
                prefill_tokens: 1000,
                decode_tokens: 300,
                ..Default::default()
            },
            ..Default::default()
        };
        assert!((m.decode_tok_s() - 150.0).abs() < 1e-9);
        assert!((m.prefill_tok_s() - 2000.0).abs() < 1e-9);
        // Zero-phase engines report zero rates, not NaN.
        let z = ServeMetrics::default();
        assert_eq!(z.decode_tok_s(), 0.0);
        assert_eq!(z.prefill_tok_s(), 0.0);
    }
}
