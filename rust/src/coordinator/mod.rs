//! The serving coordinator (L3 request path).
//!
//! vLLM-router-shaped, sized to this testbed: clients submit generation
//! requests; a dynamic batcher groups them under a max-batch/max-wait
//! policy; a worker thread drives the batched prefill+decode executables
//! through PJRT ([`PjrtGenerator`]); responses flow back over per-request
//! channels with latency metrics recorded.
//!
//! No tokio in this environment (offline vendor set) — the runtime is
//! `std::thread` + `mpsc`, which for a single-host, CPU-bound serving
//! loop is the honest design anyway: one worker owns the engine and the
//! batcher is the only coordination point.
//!
//! Two engines implement [`GenEngine`]: [`NativeGenerator`] (pure-Rust
//! batched prefill + KV-cache decode, FP or packed-integer — the
//! runnable path in this offline environment) and [`PjrtGenerator`]
//! (AOT-compiled graphs when a PJRT runtime is present).

mod batcher;
mod generate;
mod metrics;
mod native_gen;
mod replica;
mod scheduler;
mod server;

pub use batcher::{BatcherCfg, DynamicBatcher};
pub use generate::{
    AdmitOutcome, EngineStats, GenEngine, PjrtGenerator, PoolStats, SamplingCfg, StepEngine,
};
pub use metrics::{Histogram, ServeMetrics};
pub use native_gen::NativeGenerator;
pub use replica::{BrownoutCfg, ReplicaCfg, ReplicaPool};
pub use scheduler::{ContinuousCfg, Scheduler, Tick};
pub use server::{Coordinator, GenRequest, GenResponse, GenStatus, ServePlan};
