//! Replicated serving: N independent engine replicas behind one router.
//!
//! Each replica is a worker thread owning its own [`Scheduler`] (and KV
//! budget); the router thread load-balances admissions round-robin and
//! tracks per-replica health with a circuit breaker — K consecutive
//! failed or overdue ticks open it, queued work is handed back and
//! rerouted to healthy replicas, and a half-open probe (one real
//! request) closes it again under capped exponential backoff. This
//! generalizes the single-engine factory-respawn of PR 8: the worker
//! still respawns its own engine locally, while the router steers
//! traffic away until a probe proves the replacement healthy.
//!
//! Two policies ride on top:
//!
//! **Hedged requests** — a request still unfinished `hedge_after` after
//! submission is duplicated onto a second healthy replica with the same
//! sampling-stream key. First terminal response wins and is forwarded to
//! the client; the loser is cancelled (its pages freed) without a second
//! terminal. This is safe *because* outputs are bit-exact and
//! schedule-independent (per-sequence RNG is keyed by the request, not
//! the engine slot): winner and loser compute identical tokens, so which
//! arm wins is unobservable in the payload.
//!
//! **Precision brownout** — when a replica's queue depth or KV occupancy
//! stays above a watermark for `engage_ticks` consecutive ticks, new
//! admissions route to a second scheduler running a degraded lower-bit
//! plan from the same artifact directory; responses record the serving
//! plan ([`ServePlan`]). Hysteresis (`release_ticks` below the
//! watermark) restores full precision. Under overload the paper's
//! concentration/alignment SQNR budget becomes the shed valve: quality
//! degrades measurably instead of requests being rejected.
//!
//! Exactly-one-terminal is preserved end to end: every client submission
//! maps to a router *entry*; internal per-replica requests ("arms")
//! report back over a private channel, and only the first terminal arm
//! reaches the client. Failed/rejected arms are retried on another
//! replica up to `max_retries` before the failure is forwarded.

use super::metrics::lock_recover;
use super::scheduler::Tick;
use super::server::{respond_plan, ServePlan};
use super::{
    ContinuousCfg, GenRequest, GenResponse, GenStatus, Scheduler, ServeMetrics, StepEngine,
};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Precision-brownout policy (off when [`ReplicaCfg::brownout`] is
/// `None`).
#[derive(Clone, Copy, Debug)]
pub struct BrownoutCfg {
    /// Pressure watermark in `[0, 1]`; pressure is the max of queue
    /// depth / `max_queue` and KV pool occupancy.
    pub watermark: f64,
    /// Consecutive ticks at/above the watermark before new admissions
    /// shift to the degraded plan.
    pub engage_ticks: u32,
    /// Consecutive ticks below the watermark before full precision is
    /// restored (hysteresis — strictly more than a single good tick, so
    /// the plan doesn't flap at the boundary).
    pub release_ticks: u32,
}

impl Default for BrownoutCfg {
    fn default() -> Self {
        BrownoutCfg { watermark: 0.75, engage_ticks: 4, release_ticks: 8 }
    }
}

/// Replicated-serving policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct ReplicaCfg {
    /// Number of engine replicas (worker threads), each with its own
    /// scheduler and KV budget.
    pub replicas: usize,
    /// Per-replica scheduler policy (queue bound, admission watermark,
    /// local respawn backoff).
    pub scheduler: ContinuousCfg,
    /// Consecutive failed/overdue ticks before the replica's circuit
    /// breaker opens.
    pub breaker_threshold: u32,
    /// Initial open-breaker backoff before a half-open probe is allowed;
    /// doubles per re-open up to [`Self::probe_backoff_cap`].
    pub probe_backoff: Duration,
    /// Upper bound on the probe backoff.
    pub probe_backoff_cap: Duration,
    /// A tick slower than this counts as a breaker strike (stragglers
    /// and livelocks look identical to failures from the router's seat).
    /// `None` disables timeout strikes.
    pub tick_timeout: Option<Duration>,
    /// Duplicate a request onto a second replica once it has been
    /// outstanding this long (derive it from a measured p95/p99).
    /// `None` disables hedging.
    pub hedge_after: Option<Duration>,
    /// How many times a failed/rejected arm is retried on another
    /// replica before the failure is forwarded to the client.
    pub max_retries: u32,
    /// Precision-brownout policy; `None` serves the full plan always.
    pub brownout: Option<BrownoutCfg>,
}

impl Default for ReplicaCfg {
    fn default() -> Self {
        ReplicaCfg {
            replicas: 2,
            scheduler: ContinuousCfg::default(),
            breaker_threshold: 3,
            probe_backoff: Duration::from_millis(10),
            probe_backoff_cap: Duration::from_secs(1),
            tick_timeout: None,
            hedge_after: None,
            max_retries: 1,
            brownout: None,
        }
    }
}

/// Commands the router sends a replica worker.
enum RepCmd {
    /// Admit (or queue) an internal request.
    Enqueue(GenRequest),
    /// Silently drop an internal request by id (hedge loser): pages
    /// freed, no response sent.
    Cancel(u64),
    /// Breaker opened: hand every queued-but-unadmitted request back for
    /// rerouting ([`RouterMsg::GaveBack`]). In-flight work keeps ticking.
    TakeQueue,
    /// Stop admitting, reject the queue, finish in-flight work.
    Drain,
}

/// Everything the router reacts to, over a single channel (std mpsc has
/// no `select`, so completions are forwarded into this stream too).
enum RouterMsg {
    /// A client submission (reply sender goes to the client).
    Submit(GenRequest),
    /// An internal arm reached a terminal state (`resp.id` is the
    /// internal arm id).
    Done(GenResponse),
    /// A replica tick failed or overran `tick_timeout`.
    Strike { replica: usize },
    /// First good tick after one or more strikes.
    Healthy { replica: usize },
    /// Queue handed back by a replica after [`RepCmd::TakeQueue`].
    GaveBack(Vec<GenRequest>),
    /// Begin pool-wide drain.
    Drain,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BreakerState {
    Closed,
    /// No traffic until `until`; then half-open.
    Open { until: Instant },
    /// One probe request allowed; its terminal decides open vs closed.
    HalfOpen { probing: Option<u64> },
}

struct Breaker {
    state: BreakerState,
    strikes: u32,
    backoff: Duration,
}

/// One in-flight arm of an entry: which replica holds which internal id.
struct Arm {
    replica: usize,
    internal: u64,
    hedge: bool,
}

/// Router-side record of one client request.
struct Entry {
    reply: Sender<GenResponse>,
    prompt: Vec<u8>,
    max_new: usize,
    deadline: Option<Instant>,
    /// Sampling-stream key shared by every arm — the bit-exactness
    /// anchor that makes hedging and retries payload-invisible.
    key: u64,
    enqueued: Instant,
    arms: Vec<Arm>,
    retries_left: u32,
    hedged: bool,
}

type EngineFactory = Arc<dyn Fn(usize, ServePlan) -> Box<dyn StepEngine> + Send + Sync>;

/// Client handle to the replicated pool. [`ReplicaPool::shutdown`] (and
/// drop) drains gracefully: in-flight requests finish, queued ones get
/// terminal rejections, and all threads are joined.
pub struct ReplicaPool {
    router_tx: Option<Sender<RouterMsg>>,
    router: Option<JoinHandle<()>>,
    forwarder: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    replica_metrics: Vec<Arc<Mutex<ServeMetrics>>>,
    router_metrics: Arc<Mutex<ServeMetrics>>,
}

impl ReplicaPool {
    /// Start `cfg.replicas` engine replicas plus the router.
    ///
    /// The factory runs on each worker thread (engines are not `Send`)
    /// and is called again on local respawn after an engine loss; the
    /// [`ServePlan`] argument selects the full or brownout plan.
    pub fn start<F>(make_engine: F, cfg: ReplicaCfg) -> ReplicaPool
    where
        F: Fn(usize, ServePlan) -> Box<dyn StepEngine> + Send + Sync + 'static,
    {
        let n = cfg.replicas.max(1);
        let make: EngineFactory = Arc::new(make_engine);
        let (router_tx, router_rx) = channel::<RouterMsg>();
        let (done_tx, done_rx) = channel::<GenResponse>();
        let replica_metrics: Vec<Arc<Mutex<ServeMetrics>>> =
            (0..n).map(|_| Arc::new(Mutex::new(ServeMetrics::default()))).collect();
        let router_metrics = Arc::new(Mutex::new(ServeMetrics::default()));

        let mut cmd_txs = Vec::with_capacity(n);
        let mut workers = Vec::with_capacity(n);
        for r in 0..n {
            let (cmd_tx, cmd_rx) = channel::<RepCmd>();
            cmd_txs.push(cmd_tx);
            let make = make.clone();
            let rtx = router_tx.clone();
            let met = replica_metrics[r].clone();
            workers.push(std::thread::spawn(move || run_replica(r, cmd_rx, rtx, make, cfg, met)));
        }

        // Forwarder: pump internal completions into the router's single
        // message stream (no `select` over two receivers in std mpsc).
        let fwd_tx = router_tx.clone();
        let forwarder = std::thread::spawn(move || {
            while let Ok(resp) = done_rx.recv() {
                if fwd_tx.send(RouterMsg::Done(resp)).is_err() {
                    break;
                }
            }
        });

        let rm = router_metrics.clone();
        let router = std::thread::spawn(move || {
            Router {
                cfg,
                cmd_txs,
                done_tx,
                metrics: rm,
                entries: HashMap::new(),
                arm_owner: HashMap::new(),
                breakers: (0..n)
                    .map(|_| Breaker {
                        state: BreakerState::Closed,
                        strikes: 0,
                        backoff: cfg.probe_backoff,
                    })
                    .collect(),
                next_internal: 0,
                rr: 0,
                draining: false,
            }
            .run(router_rx);
        });

        ReplicaPool {
            router_tx: Some(router_tx),
            router: Some(router),
            forwarder: Some(forwarder),
            workers,
            next_id: AtomicU64::new(0),
            replica_metrics,
            router_metrics,
        }
    }

    /// Submit a request; the receiver yields exactly one terminal
    /// [`GenResponse`]. After shutdown the response is an immediate
    /// clean rejection.
    pub fn submit(&self, prompt: Vec<u8>, max_new: usize) -> Receiver<GenResponse> {
        self.submit_with_deadline(prompt, max_new, None)
    }

    /// [`Self::submit`] with a serve-by deadline relative to now.
    pub fn submit_with_deadline(
        &self,
        prompt: Vec<u8>,
        max_new: usize,
        deadline: Option<Duration>,
    ) -> Receiver<GenResponse> {
        let (reply, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let now = Instant::now();
        let req = GenRequest {
            id,
            prompt,
            max_new,
            deadline: deadline.map(|d| now + d),
            key: id,
            enqueued: now,
            reply,
        };
        let undeliverable = match &self.router_tx {
            Some(tx) => tx.send(RouterMsg::Submit(req)).err().map(|e| match e.0 {
                RouterMsg::Submit(r) => r,
                _ => unreachable!("send returns what it was given"),
            }),
            None => Some(req),
        };
        if let Some(req) = undeliverable {
            lock_recover(&self.router_metrics).rejected += 1;
            respond_plan(&req, Vec::new(), 0, GenStatus::Rejected, ServePlan::Full);
        }
        rx
    }

    /// Per-replica metric snapshots, in replica order.
    pub fn replica_metrics(&self) -> Vec<ServeMetrics> {
        self.replica_metrics.iter().map(|m| lock_recover(m).clone()).collect()
    }

    /// Fleet-wide view: router counters (hedges, breaker opens, router
    /// rejections) merged with every replica's metrics. Each replica
    /// records into its own lock; aggregation happens only here, at
    /// report time.
    pub fn metrics(&self) -> ServeMetrics {
        let mut fleet = lock_recover(&self.router_metrics).clone();
        for m in &self.replica_metrics {
            let snap = lock_recover(m).clone();
            fleet.merge(&snap);
        }
        fleet
    }

    /// Per-replica summary lines plus the fleet roll-up.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        for (r, m) in self.replica_metrics().into_iter().enumerate() {
            s.push_str(&format!("r{r}: {}\n", m.summary()));
        }
        s.push_str(&format!("fleet: {}", self.metrics().summary()));
        s
    }

    fn halt(&mut self) {
        if let Some(tx) = self.router_tx.take() {
            let _ = tx.send(RouterMsg::Drain);
        }
        if let Some(r) = self.router.take() {
            let _ = r.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(f) = self.forwarder.take() {
            let _ = f.join();
        }
    }

    /// Graceful drain: stop admission, reject queued requests, let
    /// in-flight sequences finish (or hit their deadline), join every
    /// thread, and return the final fleet metrics.
    pub fn shutdown(&mut self) -> ServeMetrics {
        self.halt();
        self.metrics()
    }
}

impl Drop for ReplicaPool {
    fn drop(&mut self) {
        self.halt();
    }
}

/// The router: owns the entry table, the breakers, and all routing,
/// hedging, and retry policy. Single-threaded over one message stream.
struct Router {
    cfg: ReplicaCfg,
    cmd_txs: Vec<Sender<RepCmd>>,
    /// Master clone source for internal arms' reply senders.
    done_tx: Sender<GenResponse>,
    metrics: Arc<Mutex<ServeMetrics>>,
    /// Client id → entry.
    entries: HashMap<u64, Entry>,
    /// Internal arm id → client id.
    arm_owner: HashMap<u64, u64>,
    breakers: Vec<Breaker>,
    next_internal: u64,
    /// Round-robin cursor.
    rr: usize,
    draining: bool,
}

impl Router {
    fn run(mut self, rx: Receiver<RouterMsg>) {
        loop {
            self.service_timers();
            if self.draining && self.entries.is_empty() {
                break;
            }
            let msg = match self.next_deadline() {
                None => match rx.recv() {
                    Ok(m) => Some(m),
                    Err(_) => break,
                },
                Some(d) => {
                    let wait = d.saturating_duration_since(Instant::now());
                    match rx.recv_timeout(wait) {
                        Ok(m) => Some(m),
                        Err(RecvTimeoutError::Timeout) => None,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
            };
            if let Some(m) = msg {
                self.handle(m);
            }
            // Drain whatever else is pending before recomputing timers.
            while let Ok(m) = rx.try_recv() {
                self.handle(m);
            }
        }
        // Exiting drops cmd_txs: workers finish their drain and exit.
    }

    fn handle(&mut self, msg: RouterMsg) {
        match msg {
            RouterMsg::Submit(req) => self.submit(req),
            RouterMsg::Done(resp) => self.done(resp),
            RouterMsg::Strike { replica } => self.strike(replica),
            RouterMsg::Healthy { replica } => self.healthy(replica),
            RouterMsg::GaveBack(reqs) => self.gave_back(reqs),
            RouterMsg::Drain => {
                self.draining = true;
                for tx in &self.cmd_txs {
                    let _ = tx.send(RepCmd::Drain);
                }
            }
        }
    }

    /// Next healthy replica, round-robin; falls back to probing one
    /// half-open replica when nothing is closed (if `allow_probe`).
    fn route(&mut self, avoid: Option<usize>, allow_probe: bool) -> Option<usize> {
        let n = self.cmd_txs.len();
        for i in 0..n {
            let r = (self.rr + i) % n;
            if Some(r) == avoid {
                continue;
            }
            if self.breakers[r].state == BreakerState::Closed {
                self.rr = (r + 1) % n;
                return Some(r);
            }
        }
        if allow_probe {
            for r in 0..n {
                if Some(r) == avoid {
                    continue;
                }
                if self.breakers[r].state == (BreakerState::HalfOpen { probing: None }) {
                    return Some(r);
                }
            }
        }
        None
    }

    /// Create a new internal arm for `client_id` on `replica` and send
    /// it. Returns false (with all bookkeeping undone) if the worker is
    /// gone.
    fn spawn_arm(&mut self, client_id: u64, replica: usize, hedge: bool) -> bool {
        let internal = self.next_internal;
        self.next_internal += 1;
        let req = {
            let Some(e) = self.entries.get_mut(&client_id) else { return false };
            e.arms.push(Arm { replica, internal, hedge });
            GenRequest {
                id: internal,
                prompt: e.prompt.clone(),
                max_new: e.max_new,
                deadline: e.deadline,
                key: e.key,
                enqueued: e.enqueued,
                reply: self.done_tx.clone(),
            }
        };
        self.arm_owner.insert(internal, client_id);
        if let BreakerState::HalfOpen { probing: probing @ None } =
            &mut self.breakers[replica].state
        {
            *probing = Some(internal);
        }
        if self.cmd_txs[replica].send(RepCmd::Enqueue(req)).is_ok() {
            return true;
        }
        // Worker thread is gone — undo and let the caller fall back.
        self.arm_owner.remove(&internal);
        if let Some(e) = self.entries.get_mut(&client_id) {
            e.arms.retain(|a| a.internal != internal);
        }
        if let BreakerState::HalfOpen { probing } = &mut self.breakers[replica].state {
            if *probing == Some(internal) {
                *probing = None;
            }
        }
        false
    }

    fn submit(&mut self, req: GenRequest) {
        if self.draining {
            lock_recover(&self.metrics).rejected += 1;
            respond_plan(&req, Vec::new(), 0, GenStatus::Rejected, ServePlan::Full);
            return;
        }
        let client_id = req.id;
        let entry = Entry {
            reply: req.reply,
            prompt: req.prompt,
            max_new: req.max_new,
            deadline: req.deadline,
            key: req.key,
            enqueued: req.enqueued,
            arms: Vec::new(),
            retries_left: self.cfg.max_retries,
            hedged: false,
        };
        self.entries.insert(client_id, entry);
        let routed = self.route(None, true);
        let sent = match routed {
            Some(r) => self.spawn_arm(client_id, r, false),
            None => false,
        };
        if !sent {
            // Whole fleet open (or dead): terminal rejection now rather
            // than an unbounded router-side queue.
            if let Some(entry) = self.entries.remove(&client_id) {
                lock_recover(&self.metrics).rejected += 1;
                let _ = entry.reply.send(GenResponse {
                    id: client_id,
                    tokens: Vec::new(),
                    latency: entry.enqueued.elapsed(),
                    batch_size: 0,
                    status: GenStatus::Rejected,
                    plan: ServePlan::Full,
                });
            }
        }
    }

    fn done(&mut self, resp: GenResponse) {
        let Some(&client_id) = self.arm_owner.get(&resp.id) else {
            // A cancelled loser that raced its cancellation, or an
            // already-resolved entry — nothing is waiting for it.
            return;
        };
        // Probe verdict first: any terminal from the probing arm proves
        // the scheduler answered; only Failed means the engine is still
        // dying.
        let replica = self
            .entries
            .get(&client_id)
            .and_then(|e| e.arms.iter().find(|a| a.internal == resp.id))
            .map(|a| a.replica);
        if let Some(r) = replica {
            if let BreakerState::HalfOpen { probing: Some(p) } = self.breakers[r].state {
                if p == resp.id {
                    if resp.status == GenStatus::Failed {
                        self.open_breaker(r);
                    } else {
                        self.close_breaker(r);
                    }
                }
            }
        }
        match resp.status {
            GenStatus::Ok | GenStatus::Expired => self.win_arm(client_id, resp),
            GenStatus::Rejected | GenStatus::Failed => {
                let internal = resp.id;
                self.fail_arm(client_id, internal, resp);
            }
        }
    }

    /// First terminal wins: forward to the client under the client id,
    /// cancel every other arm silently.
    fn win_arm(&mut self, client_id: u64, resp: GenResponse) {
        self.arm_owner.remove(&resp.id);
        let Some(mut entry) = self.entries.remove(&client_id) else { return };
        let won_by_hedge =
            entry.arms.iter().find(|a| a.internal == resp.id).is_some_and(|a| a.hedge);
        if won_by_hedge && resp.status == GenStatus::Ok {
            lock_recover(&self.metrics).hedges_won += 1;
        }
        for arm in entry.arms.drain(..) {
            if arm.internal != resp.id {
                self.arm_owner.remove(&arm.internal);
                // A cancelled loser produces no terminal: if it was
                // someone's probe, free the probe slot or the breaker
                // wedges half-open forever.
                self.clear_probe(arm.internal);
                let _ = self.cmd_txs[arm.replica].send(RepCmd::Cancel(arm.internal));
            }
        }
        let mut out = resp;
        out.id = client_id;
        let _ = entry.reply.send(out);
    }

    /// A failed/rejected arm: if a hedge sibling is still racing, drop
    /// this arm quietly; otherwise retry on another replica while
    /// retries remain, else forward the failure.
    fn fail_arm(&mut self, client_id: u64, internal: u64, resp: GenResponse) {
        self.arm_owner.remove(&internal);
        // Arms synthesized dead (reroute failure) never reach `done`'s
        // probe verdict — release any probe slot they held.
        self.clear_probe(internal);
        let arms_left = match self.entries.get_mut(&client_id) {
            None => return,
            Some(e) => {
                e.arms.retain(|a| a.internal != internal);
                e.arms.len()
            }
        };
        if arms_left > 0 {
            return;
        }
        let can_retry =
            !self.draining && self.entries.get(&client_id).is_some_and(|e| e.retries_left > 0);
        if can_retry {
            if let Some(e) = self.entries.get_mut(&client_id) {
                e.retries_left -= 1;
            }
            if let Some(r) = self.route(None, true) {
                if self.spawn_arm(client_id, r, false) {
                    return;
                }
            }
        }
        if let Some(entry) = self.entries.remove(&client_id) {
            let mut out = resp;
            out.id = client_id;
            let _ = entry.reply.send(out);
        }
    }

    fn strike(&mut self, replica: usize) {
        match self.breakers[replica].state {
            BreakerState::Closed => {
                self.breakers[replica].strikes += 1;
                if self.breakers[replica].strikes >= self.cfg.breaker_threshold {
                    self.open_breaker(replica);
                }
            }
            BreakerState::HalfOpen { .. } => self.open_breaker(replica),
            BreakerState::Open { .. } => {}
        }
    }

    fn healthy(&mut self, replica: usize) {
        match self.breakers[replica].state {
            BreakerState::Closed => {
                self.breakers[replica].strikes = 0;
                self.breakers[replica].backoff = self.cfg.probe_backoff;
            }
            BreakerState::HalfOpen { .. } => self.close_breaker(replica),
            // Stale pre-open event; the probe decides reopening.
            BreakerState::Open { .. } => {}
        }
    }

    fn open_breaker(&mut self, replica: usize) {
        let b = &mut self.breakers[replica];
        b.state = BreakerState::Open { until: Instant::now() + b.backoff };
        b.backoff = (b.backoff * 2).min(self.cfg.probe_backoff_cap);
        b.strikes = 0;
        lock_recover(&self.metrics).breaker_opens += 1;
        // Queued work must not starve behind a dead engine: the worker
        // hands it back and `gave_back` reroutes it (no retry consumed).
        let _ = self.cmd_txs[replica].send(RepCmd::TakeQueue);
    }

    /// Forget that `internal` was probing any breaker (the arm died
    /// without a terminal), so the next request can probe instead.
    fn clear_probe(&mut self, internal: u64) {
        for b in &mut self.breakers {
            if let BreakerState::HalfOpen { probing } = &mut b.state {
                if *probing == Some(internal) {
                    *probing = None;
                }
            }
        }
    }

    fn close_breaker(&mut self, replica: usize) {
        let b = &mut self.breakers[replica];
        b.state = BreakerState::Closed;
        b.strikes = 0;
        b.backoff = self.cfg.probe_backoff;
    }

    /// Reroute queue contents handed back by an opened breaker. The
    /// internal request moves replicas as-is (same internal id, same
    /// reply sender) — this is a reroute, not a retry.
    fn gave_back(&mut self, reqs: Vec<GenRequest>) {
        for req in reqs {
            let internal = req.id;
            let Some(&client_id) = self.arm_owner.get(&internal) else { continue };
            match self.route(None, true) {
                Some(r) => {
                    if let Some(e) = self.entries.get_mut(&client_id) {
                        if let Some(a) = e.arms.iter_mut().find(|a| a.internal == internal) {
                            a.replica = r;
                        }
                    }
                    if let BreakerState::HalfOpen { probing: probing @ None } =
                        &mut self.breakers[r].state
                    {
                        *probing = Some(internal);
                    }
                    if self.cmd_txs[r].send(RepCmd::Enqueue(req)).is_err() {
                        let resp = GenResponse {
                            id: internal,
                            tokens: Vec::new(),
                            latency: Duration::ZERO,
                            batch_size: 0,
                            status: GenStatus::Failed,
                            plan: ServePlan::Full,
                        };
                        self.fail_arm(client_id, internal, resp);
                    }
                }
                None => {
                    let resp = GenResponse {
                        id: internal,
                        tokens: Vec::new(),
                        latency: req.enqueued.elapsed(),
                        batch_size: 0,
                        status: GenStatus::Rejected,
                        plan: ServePlan::Full,
                    };
                    self.fail_arm(client_id, internal, resp);
                }
            }
        }
    }

    /// Time-driven transitions: open breakers whose backoff expired
    /// become half-open, and overdue single-arm entries hedge.
    fn service_timers(&mut self) {
        let now = Instant::now();
        for b in &mut self.breakers {
            if let BreakerState::Open { until } = b.state {
                if now >= until {
                    b.state = BreakerState::HalfOpen { probing: None };
                }
            }
        }
        let Some(hedge_after) = self.cfg.hedge_after else { return };
        if self.draining {
            return;
        }
        let due: Vec<u64> = self
            .entries
            .iter()
            .filter(|(_, e)| !e.hedged && e.arms.len() == 1 && e.enqueued.elapsed() >= hedge_after)
            .map(|(&id, _)| id)
            .collect();
        for client_id in due {
            let avoid = self
                .entries
                .get(&client_id)
                .and_then(|e| e.arms.first())
                .map(|a| a.replica);
            if let Some(e) = self.entries.get_mut(&client_id) {
                // One hedge attempt per request, whether or not a second
                // replica is available right now — never an unbounded
                // duplicate storm.
                e.hedged = true;
            }
            if let Some(r) = self.route(avoid, false) {
                if self.spawn_arm(client_id, r, true) {
                    lock_recover(&self.metrics).hedges_fired += 1;
                }
            }
        }
    }

    /// Earliest instant something time-driven happens: a breaker probe
    /// window opening or a hedge falling due.
    fn next_deadline(&self) -> Option<Instant> {
        let mut d: Option<Instant> = None;
        for b in &self.breakers {
            if let BreakerState::Open { until } = b.state {
                d = Some(d.map_or(until, |cur| cur.min(until)));
            }
        }
        if let Some(hedge_after) = self.cfg.hedge_after {
            if !self.draining {
                for e in self.entries.values() {
                    if !e.hedged && e.arms.len() == 1 {
                        let t = e.enqueued + hedge_after;
                        d = Some(d.map_or(t, |cur| cur.min(t)));
                    }
                }
            }
        }
        d
    }
}

/// One replica worker: owns the primary scheduler (and, under brownout,
/// a degraded-plan sibling), ticks them, reports health, and respawns
/// its own engine locally on loss.
fn run_replica(
    replica: usize,
    cmd_rx: Receiver<RepCmd>,
    router_tx: Sender<RouterMsg>,
    make: EngineFactory,
    cfg: ReplicaCfg,
    metrics: Arc<Mutex<ServeMetrics>>,
) {
    let mut primary =
        Scheduler::new(make(replica, ServePlan::Full), cfg.scheduler, metrics.clone());
    let mut degraded: Option<Scheduler> = None;
    let mut open = true;
    let mut draining = false;
    let mut backoff = cfg.scheduler.respawn_backoff;
    // Brownout hysteresis state.
    let mut engaged = false;
    let mut above = 0u32;
    let mut below = 0u32;
    // Health-event dedup: strikes every bad tick, one Healthy after.
    let mut striking = false;
    let both_idle = |p: &Scheduler, d: &Option<Scheduler>| {
        p.idle()
            && match d {
                Some(d) => d.idle(),
                None => true,
            }
    };
    loop {
        if draining {
            primary.begin_drain();
            if let Some(d) = degraded.as_mut() {
                d.begin_drain();
            }
        }
        let idle = both_idle(&primary, &degraded);
        if !open && idle {
            break;
        }
        let mut cmds: Vec<RepCmd> = Vec::new();
        if open && idle {
            // Nothing to tick: block for the next command.
            match cmd_rx.recv() {
                Ok(c) => cmds.push(c),
                Err(_) => open = false,
            }
        }
        while open {
            match cmd_rx.try_recv() {
                Ok(c) => cmds.push(c),
                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                Err(std::sync::mpsc::TryRecvError::Disconnected) => open = false,
            }
        }
        for cmd in cmds {
            match cmd {
                RepCmd::Enqueue(req) => {
                    if engaged {
                        if degraded.is_none() {
                            degraded = Some(
                                Scheduler::new(
                                    make(replica, ServePlan::Degraded),
                                    cfg.scheduler,
                                    metrics.clone(),
                                )
                                .with_plan(ServePlan::Degraded),
                            );
                        }
                        degraded.as_mut().expect("just created").enqueue(req);
                    } else {
                        primary.enqueue(req);
                    }
                }
                RepCmd::Cancel(id) => {
                    if !primary.cancel(id) {
                        if let Some(d) = degraded.as_mut() {
                            d.cancel(id);
                        }
                    }
                }
                RepCmd::TakeQueue => {
                    let mut reqs = primary.take_queue();
                    if let Some(d) = degraded.as_mut() {
                        reqs.extend(d.take_queue());
                    }
                    let _ = router_tx.send(RouterMsg::GaveBack(reqs));
                }
                RepCmd::Drain => draining = true,
            }
        }
        let idle = both_idle(&primary, &degraded);
        if idle {
            if !open {
                break;
            }
            continue;
        }
        let t0 = Instant::now();
        let mut tick_failed = false;
        if !primary.idle() {
            tick_failed |= tick_one(
                &mut primary,
                ServePlan::Full,
                replica,
                &make,
                &cfg,
                &metrics,
                &mut backoff,
            );
        }
        if let Some(d) = degraded.as_mut() {
            if !d.idle() {
                tick_failed |= tick_one(
                    d,
                    ServePlan::Degraded,
                    replica,
                    &make,
                    &cfg,
                    &metrics,
                    &mut backoff,
                );
            }
        }
        let slow = cfg.tick_timeout.is_some_and(|t| t0.elapsed() > t);
        if tick_failed || slow {
            striking = true;
            let _ = router_tx.send(RouterMsg::Strike { replica });
        } else if striking {
            striking = false;
            let _ = router_tx.send(RouterMsg::Healthy { replica });
        }
        // Brownout pressure: max of queue fill and KV occupancy, with
        // engage/release tick hysteresis.
        if let Some(b) = cfg.brownout {
            let qlen = primary.queue_len()
                + degraded.as_ref().map_or(0, |d| d.queue_len());
            let qfrac = if cfg.scheduler.max_queue == 0 {
                0.0
            } else {
                qlen as f64 / cfg.scheduler.max_queue as f64
            };
            let occ = primary
                .occupancy()
                .max(degraded.as_ref().map_or(0.0, |d| d.occupancy()));
            let pressure = qfrac.max(occ);
            if pressure >= b.watermark {
                above += 1;
                below = 0;
                if !engaged && above >= b.engage_ticks {
                    engaged = true;
                }
            } else {
                below += 1;
                above = 0;
                if engaged && below >= b.release_ticks {
                    engaged = false;
                }
            }
        }
    }
}

/// Tick one scheduler, handling engine loss with local respawn under
/// capped backoff (mirrors `Coordinator::start_continuous`). Returns
/// true if the tick counts as a breaker strike.
fn tick_one(
    sched: &mut Scheduler,
    plan: ServePlan,
    replica: usize,
    make: &EngineFactory,
    cfg: &ReplicaCfg,
    metrics: &Arc<Mutex<ServeMetrics>>,
    backoff: &mut Duration,
) -> bool {
    match sched.tick() {
        Ok(Tick::Ok) => {
            *backoff = cfg.scheduler.respawn_backoff;
            false
        }
        Ok(Tick::EngineFailed) => {
            // The tick already failed in-flight requests; the queue
            // survives for the replacement engine.
            std::thread::sleep(*backoff);
            *backoff = (*backoff * 2).min(cfg.scheduler.respawn_backoff_cap);
            sched.replace_engine(make(replica, plan));
            lock_recover(metrics).respawns += 1;
            true
        }
        Err(e) => {
            // Non-recoverable scheduler error: terminate everything with
            // clean responses, then start over with a fresh engine.
            eprintln!("replica {replica} scheduler failed: {e:#}");
            sched.abort();
            std::thread::sleep(*backoff);
            *backoff = (*backoff * 2).min(cfg.scheduler.respawn_backoff_cap);
            sched.replace_engine(make(replica, plan));
            lock_recover(metrics).respawns += 1;
            true
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{AdmitOutcome, PoolStats};
    use anyhow::Result;

    /// Deterministic step engine: emits `(key % 251) + step` bytes so
    /// outputs depend only on the request key — replica- and
    /// schedule-independent, like the real engine.
    struct KeyedEcho {
        slots: usize,
        /// Fail the whole engine on the Nth step() call of this
        /// *instance* (respawns get a fresh count).
        die_on_step: Option<usize>,
        steps: usize,
        running: Vec<u64>,
        seqs: HashMap<u64, (u64, Vec<u8>, usize)>,
        next: u64,
    }

    impl KeyedEcho {
        fn new(slots: usize) -> KeyedEcho {
            KeyedEcho {
                slots,
                die_on_step: None,
                steps: 0,
                running: Vec::new(),
                seqs: HashMap::new(),
                next: 0,
            }
        }
    }

    impl StepEngine for KeyedEcho {
        fn admit(&mut self, prompt: Vec<u8>, max_new: usize, key: u64) -> Result<AdmitOutcome> {
            if self.running.len() >= self.slots {
                return Ok(AdmitOutcome::NoCapacity(prompt));
            }
            let id = self.next;
            self.next += 1;
            self.seqs.insert(id, (key, vec![(key % 251) as u8], max_new.max(1)));
            self.running.push(id);
            Ok(AdmitOutcome::Admitted(id))
        }

        fn step(&mut self) -> Result<Vec<u64>> {
            self.steps += 1;
            if self.die_on_step == Some(self.steps) {
                anyhow::bail!("scripted engine death");
            }
            let mut finished = Vec::new();
            for &id in &self.running {
                let (key, out, max_new) = self.seqs.get_mut(&id).unwrap();
                if out.len() < *max_new {
                    let step = out.len() as u64;
                    out.push(((*key % 251) + step) as u8);
                }
                if out.len() >= *max_new {
                    finished.push(id);
                }
            }
            self.running.retain(|id| !finished.contains(id));
            Ok(finished)
        }

        fn take_output(&mut self, id: u64) -> Option<Vec<u8>> {
            self.running.retain(|&r| r != id);
            self.seqs.remove(&id).map(|(_, out, _)| out)
        }

        fn take_preempted(&mut self) -> Vec<u64> {
            Vec::new()
        }

        fn take_failed(&mut self) -> Vec<u64> {
            Vec::new()
        }

        fn resume(&mut self, _id: u64) -> Result<bool> {
            Ok(false)
        }

        fn running(&self) -> usize {
            self.running.len()
        }

        fn max_concurrent(&self) -> usize {
            self.slots
        }

        fn pool_stats(&self) -> PoolStats {
            PoolStats::default()
        }
    }

    fn expected(key: u64, max_new: usize) -> Vec<u8> {
        (0..max_new.max(1) as u64).map(|s| ((key % 251) + s) as u8).collect()
    }

    #[test]
    fn replicated_pool_serves_and_aggregates() {
        let mut pool = ReplicaPool::start(
            |_r, _plan| Box::new(KeyedEcho::new(4)) as Box<dyn StepEngine>,
            ReplicaCfg { replicas: 3, ..Default::default() },
        );
        let rxs: Vec<_> = (0..9).map(|_| pool.submit(vec![1, 2], 5)).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert!(resp.is_ok(), "request {i}: {:?}", resp.status);
            assert_eq!(resp.plan, ServePlan::Full);
            assert_eq!(resp.tokens, expected(i as u64, 5), "request {i} diverged");
        }
        let fleet = pool.shutdown();
        assert_eq!(fleet.requests, 9);
        assert_eq!(fleet.tokens_out, 45);
        assert_eq!(fleet.failed, 0);
        assert_eq!(fleet.breaker_opens, 0);
    }

    #[test]
    fn engine_death_retries_on_another_replica() {
        // Replica 0's first engine dies on its first step; every request
        // must still reach Ok (local respawn + router retry), and the
        // payload is key-determined so the retry is bit-identical.
        let died = Arc::new(AtomicU64::new(0));
        let d2 = died.clone();
        let mut pool = ReplicaPool::start(
            move |r, _plan| {
                let mut e = KeyedEcho::new(4);
                if r == 0 && d2.fetch_add(1, Ordering::SeqCst) == 0 {
                    e.die_on_step = Some(1);
                }
                Box::new(e) as Box<dyn StepEngine>
            },
            ReplicaCfg { replicas: 2, breaker_threshold: 1, ..Default::default() },
        );
        let rxs: Vec<_> = (0..6).map(|_| pool.submit(vec![7], 4)).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert!(resp.is_ok(), "request {i}: {:?}", resp.status);
            assert_eq!(resp.tokens, expected(i as u64, 4), "request {i} diverged");
        }
        let fleet = pool.shutdown();
        assert_eq!(fleet.requests, 6);
        assert!(fleet.respawns >= 1, "dead engine must respawn locally");
    }

    #[test]
    fn submit_after_shutdown_rejects_cleanly() {
        let mut pool = ReplicaPool::start(
            |_r, _plan| Box::new(KeyedEcho::new(2)) as Box<dyn StepEngine>,
            ReplicaCfg { replicas: 2, ..Default::default() },
        );
        pool.shutdown();
        let rx = pool.submit(vec![1], 3);
        let resp = rx.recv().unwrap();
        assert!(resp.rejected());
    }

    #[test]
    fn hedge_duplicates_straggler_and_first_terminal_wins() {
        // Replica 0 is slow (sleeps every step); with a tiny hedge delay
        // every request routed there gets duplicated onto replica 1 and
        // the client still sees exactly one Ok with the key-determined
        // payload.
        let mut pool = ReplicaPool::start(
            |r, _plan| {
                let delay_ms = if r == 0 { 30 } else { 0 };
                Box::new(SlowEcho { inner: KeyedEcho::new(4), delay_ms }) as Box<dyn StepEngine>
            },
            ReplicaCfg {
                replicas: 2,
                hedge_after: Some(Duration::from_millis(5)),
                ..Default::default()
            },
        );
        let rxs: Vec<_> = (0..4).map(|_| pool.submit(vec![3], 3)).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert!(resp.is_ok(), "request {i}: {:?}", resp.status);
            assert_eq!(resp.tokens, expected(i as u64, 3), "hedged request {i} diverged");
        }
        let fleet = pool.shutdown();
        assert!(fleet.hedges_fired >= 1, "slow replica must trigger hedging");
    }

    /// KeyedEcho with a per-step sleep — a straggler replica.
    struct SlowEcho {
        inner: KeyedEcho,
        delay_ms: u64,
    }

    impl StepEngine for SlowEcho {
        fn admit(&mut self, prompt: Vec<u8>, max_new: usize, key: u64) -> Result<AdmitOutcome> {
            self.inner.admit(prompt, max_new, key)
        }

        fn step(&mut self) -> Result<Vec<u64>> {
            if self.delay_ms > 0 {
                std::thread::sleep(Duration::from_millis(self.delay_ms));
            }
            self.inner.step()
        }

        fn take_output(&mut self, id: u64) -> Option<Vec<u8>> {
            self.inner.take_output(id)
        }

        fn take_preempted(&mut self) -> Vec<u64> {
            self.inner.take_preempted()
        }

        fn take_failed(&mut self) -> Vec<u64> {
            self.inner.take_failed()
        }

        fn resume(&mut self, id: u64) -> Result<bool> {
            self.inner.resume(id)
        }

        fn running(&self) -> usize {
            self.inner.running()
        }

        fn max_concurrent(&self) -> usize {
            self.inner.max_concurrent()
        }

        fn pool_stats(&self) -> PoolStats {
            self.inner.pool_stats()
        }
    }
}
