//! The coordinator: request intake → dynamic batcher → worker → responses.
//!
//! Failure is a first-class input here: requests carry optional
//! deadlines (shed from the queue, cancelled mid-decode), the worker
//! isolates engine panics with `catch_unwind` + bisect (one poisoned
//! request cannot take down its batch-mates), a lost engine is
//! respawned with capped exponential backoff, and shutdown drains
//! in-flight work while giving queued requests terminal rejections —
//! every submitted request receives exactly one terminal
//! [`GenResponse`], whatever faults occur.

use super::metrics::lock_recover;
use super::scheduler::Tick;
use super::{
    BatcherCfg, ContinuousCfg, DynamicBatcher, GenEngine, Scheduler, ServeMetrics, StepEngine,
};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A generation request.
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new: usize,
    /// Serve-by time: queued requests past it are shed before admission,
    /// running sequences past it are cancelled at tick granularity.
    pub deadline: Option<Instant>,
    /// Stable sampling-stream key (defaults to `id`). Engines seed a
    /// request's per-sequence RNG from this — never from an engine-local
    /// slot index — so a request replayed or hedged onto a *different*
    /// replica samples the identical token stream. The replicated router
    /// gives a hedge duplicate its primary's key for exactly that reason.
    pub(crate) key: u64,
    pub(crate) enqueued: Instant,
    pub(crate) reply: Sender<GenResponse>,
}

impl GenRequest {
    /// Build a request plus its reply receiver directly, bypassing a
    /// [`Coordinator`] — for driving a [`Scheduler`] deterministically
    /// on the current thread (the chaos property suite does this).
    pub fn new(id: u64, prompt: Vec<u8>, max_new: usize) -> (GenRequest, Receiver<GenResponse>) {
        let (reply, rx) = channel();
        (
            GenRequest {
                id,
                prompt,
                max_new,
                deadline: None,
                key: id,
                enqueued: Instant::now(),
                reply,
            },
            rx,
        )
    }

    /// [`Self::new`] with a serve-by deadline.
    pub fn with_deadline(
        id: u64,
        prompt: Vec<u8>,
        max_new: usize,
        deadline: Instant,
    ) -> (GenRequest, Receiver<GenResponse>) {
        let (req, rx) = Self::new(id, prompt, max_new);
        (GenRequest { deadline: Some(deadline), ..req }, rx)
    }

    pub(crate) fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// How a request terminated. Every submitted request reaches exactly
/// one of these.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GenStatus {
    /// Served to completion; `tokens` is the full output.
    Ok,
    /// Refused — backpressure (bounded queue overflow, unservable
    /// size), shutdown drain, or a dead worker. `tokens` is empty.
    Rejected,
    /// Deadline passed before completion. `tokens` holds whatever was
    /// generated before cancellation (a bit-exact prefix of the full
    /// output), possibly nothing.
    Expired,
    /// Lost to an engine failure: the request was quarantined by panic
    /// isolation, or was in flight when the engine died.
    Failed,
}

/// Which precision plan served a request. Under sustained overload the
/// replicated serving layer routes new admissions to a degraded
/// lower-bit plan built from the same artifact directory (precision
/// brownout) instead of shedding them; every response records which plan
/// produced its tokens so clients and benchmarks can account for the
/// quality trade.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ServePlan {
    /// The full-precision (configured) plan.
    #[default]
    Full,
    /// The lower-bit brownout fallback plan.
    Degraded,
}

impl ServePlan {
    pub fn label(&self) -> &'static str {
        match self {
            ServePlan::Full => "full",
            ServePlan::Degraded => "degraded",
        }
    }
}

/// A generation response.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    pub tokens: Vec<u8>,
    pub latency: std::time::Duration,
    pub batch_size: usize,
    /// Terminal state; see [`GenStatus`].
    pub status: GenStatus,
    /// Which precision plan served this request (see [`ServePlan`]).
    pub plan: ServePlan,
}

impl GenResponse {
    pub fn is_ok(&self) -> bool {
        self.status == GenStatus::Ok
    }

    /// Refused without serving (see [`GenStatus::Rejected`]).
    pub fn rejected(&self) -> bool {
        self.status == GenStatus::Rejected
    }
}

pub(crate) fn respond(req: &GenRequest, tokens: Vec<u8>, batch_size: usize, status: GenStatus) {
    respond_plan(req, tokens, batch_size, status, ServePlan::Full);
}

pub(crate) fn respond_plan(
    req: &GenRequest,
    tokens: Vec<u8>,
    batch_size: usize,
    status: GenStatus,
    plan: ServePlan,
) {
    let _ = req.reply.send(GenResponse {
        id: req.id,
        tokens,
        latency: req.enqueued.elapsed(),
        batch_size,
        status,
        plan,
    });
}

/// Client handle + worker thread. [`Coordinator::shutdown`] (and drop)
/// drains gracefully: admission stops, queued requests get terminal
/// rejections, in-flight sequences run to completion (or deadline), and
/// the worker is joined.
pub struct Coordinator {
    tx: Option<Sender<GenRequest>>,
    worker: Option<std::thread::JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
    metrics: Arc<Mutex<ServeMetrics>>,
    /// Raised by shutdown/drop; the worker switches to drain mode.
    drain: Arc<AtomicBool>,
}

/// Bisecting panic isolation for the static batch path: run
/// `generate_batch` under `catch_unwind`; on a panic, respawn the
/// engine (after the current backoff, which doubles, capped) and split
/// the chunk until the offender is alone — it fails, the rest serve.
/// `None` entries mark failed prompts; order matches `prompts`.
fn gen_isolated(
    engine: &mut Box<dyn GenEngine>,
    make: &mut dyn FnMut() -> Box<dyn GenEngine>,
    prompts: &[Vec<u8>],
    max_new: usize,
    backoff: &mut Duration,
    backoff_cap: Duration,
    respawns: &mut u64,
) -> Vec<Option<Vec<u8>>> {
    match catch_unwind(AssertUnwindSafe(|| engine.generate_batch(prompts, max_new))) {
        Ok(Ok(outs)) => outs.into_iter().map(Some).collect(),
        Ok(Err(e)) => {
            eprintln!("generation failed: {e:#}");
            vec![None; prompts.len()]
        }
        Err(_) => {
            // The engine's internal state is unknown — replace it.
            std::thread::sleep(*backoff);
            *backoff = (*backoff * 2).min(backoff_cap);
            *engine = make();
            *respawns += 1;
            if prompts.len() == 1 {
                return vec![None];
            }
            let mid = prompts.len() / 2;
            let mut left = gen_isolated(
                engine,
                make,
                &prompts[..mid],
                max_new,
                backoff,
                backoff_cap,
                respawns,
            );
            left.extend(gen_isolated(
                engine,
                make,
                &prompts[mid..],
                max_new,
                backoff,
                backoff_cap,
                respawns,
            ));
            left
        }
    }
}

const STATIC_RESPAWN_BACKOFF: Duration = Duration::from_millis(5);
const STATIC_RESPAWN_BACKOFF_CAP: Duration = Duration::from_millis(500);

impl Coordinator {
    /// Start the serving loop on a worker thread.
    ///
    /// Takes a *factory* rather than an engine: PJRT handles are not
    /// `Send`, so the engine is constructed on the worker thread and
    /// never crosses a thread boundary. The factory is `FnMut` because
    /// supervision calls it again to respawn the engine after a
    /// contained panic. Production factories should restore prebuilt
    /// quantization state via the artifact constructors
    /// ([`super::NativeGenerator::quant_from_artifact`] /
    /// [`super::PjrtGenerator::quant_from_artifact`]) — loading packed
    /// codes is milliseconds, so worker (re)starts don't re-run
    /// calibration or GPTQ.
    pub fn start<F>(mut make_engine: F, cfg: BatcherCfg) -> Coordinator
    where
        F: FnMut() -> Box<dyn GenEngine> + Send + 'static,
    {
        let (tx, rx) = channel::<GenRequest>();
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let m2 = metrics.clone();
        let drain = Arc::new(AtomicBool::new(false));
        let drain2 = drain.clone();
        let worker = std::thread::spawn(move || {
            let mut engine = make_engine();
            let started = Instant::now();
            let mut backoff = STATIC_RESPAWN_BACKOFF;
            let batcher = DynamicBatcher::new(rx, cfg);
            while let Some(batch) = batcher.next_batch() {
                // Drain mode (shutdown/drop raised the flag): whatever is
                // still queued gets a terminal rejection, not service.
                if drain2.load(Ordering::SeqCst) {
                    let mut met = lock_recover(&m2);
                    met.rejected += batch.len() as u64;
                    for req in &batch {
                        respond(req, Vec::new(), 0, GenStatus::Rejected);
                    }
                    continue;
                }
                // Deadline shedding: a batch member whose serve-by time
                // already passed is expired up front, not generated for.
                let now = Instant::now();
                let (expired, mut batch): (Vec<_>, Vec<_>) =
                    batch.into_iter().partition(|r| r.expired(now));
                if !expired.is_empty() {
                    let mut met = lock_recover(&m2);
                    met.expired += expired.len() as u64;
                    for req in &expired {
                        met.shed_wait.record(now - req.enqueued);
                        respond(req, Vec::new(), 0, GenStatus::Expired);
                    }
                }
                if batch.is_empty() {
                    continue;
                }
                let bsz = batch.len();
                let max_new = batch.iter().map(|r| r.max_new).max().unwrap_or(0);
                // Move the prompts out — requests only carry them in, so
                // serving a batch needn't duplicate every prompt buffer.
                let prompts: Vec<Vec<u8>> =
                    batch.iter_mut().map(|r| std::mem::take(&mut r.prompt)).collect();
                // The graph batch width may be smaller than the batch the
                // policy admitted; chunk. Stats drain per chunk so TTFT
                // can charge each request its own chunk's start offset
                // (which includes earlier chunks' full generation) plus
                // that chunk's prefill — not a summed batch prefill.
                let chunk = engine.max_batch();
                let mut outputs: Vec<Option<Vec<u8>>> = Vec::with_capacity(bsz);
                let mut chunk_stats: Vec<(Instant, super::EngineStats)> = Vec::new();
                let mut respawns = 0u64;
                for c in prompts.chunks(chunk) {
                    let c_start = Instant::now();
                    outputs.extend(gen_isolated(
                        &mut engine,
                        &mut make_engine,
                        c,
                        max_new,
                        &mut backoff,
                        STATIC_RESPAWN_BACKOFF_CAP,
                        &mut respawns,
                    ));
                    chunk_stats.push((c_start, engine.take_stats()));
                }
                if respawns == 0 {
                    backoff = STATIC_RESPAWN_BACKOFF;
                }
                let now = Instant::now();
                let mut met = lock_recover(&m2);
                met.respawns += respawns;
                met.batch_sizes.push(bsz);
                for (_, s) in &chunk_stats {
                    met.engine.accumulate(s);
                }
                for (ri, (req, tokens)) in batch.into_iter().zip(outputs).enumerate() {
                    let Some(tokens) = tokens else {
                        met.failed += 1;
                        respond(&req, Vec::new(), bsz, GenStatus::Failed);
                        continue;
                    };
                    let latency = now - req.enqueued;
                    met.requests += 1;
                    met.tokens_out += tokens.len().min(req.max_new) as u64;
                    met.request_latency.record(latency);
                    // Time-to-first-token ≈ wait until this request's
                    // chunk started + that chunk's prefill phase
                    // (engines that don't split phases report zero
                    // prefill, so this degrades to the wait alone).
                    let (c_start, c_stats) = chunk_stats[ri / chunk];
                    met.ttft.record(c_start - req.enqueued + c_stats.prefill_time);
                    let _ = req.reply.send(GenResponse {
                        id: req.id,
                        tokens: tokens.into_iter().take(req.max_new).collect(),
                        latency,
                        batch_size: bsz,
                        status: GenStatus::Ok,
                        plan: ServePlan::Full,
                    });
                }
                met.elapsed = now - started;
            }
        });
        Coordinator {
            tx: Some(tx),
            worker: Some(worker),
            next_id: std::sync::atomic::AtomicU64::new(0),
            metrics,
            drain,
        }
    }

    /// Start the continuous-batching serving loop on a worker thread.
    ///
    /// Unlike [`Coordinator::start`], requests do not wait for a batch to
    /// form or for batch-mates to finish: the worker drains the intake
    /// channel into a [`Scheduler`] and ticks it — sequences join the
    /// running batch mid-decode and leave individually at their own
    /// `max_new`. Backpressure (bounded queue + page-pool admission
    /// watermark) can refuse requests; check [`GenResponse::status`].
    ///
    /// Supervision: a tick that loses the engine (a panic that escaped
    /// the engine's own isolation, or a step error) fails the in-flight
    /// sequences, then the factory is called again to respawn the
    /// engine after a capped exponential backoff
    /// ([`ContinuousCfg::respawn_backoff`]); queued requests survive and
    /// are served by the replacement.
    pub fn start_continuous<F>(mut make_engine: F, cfg: ContinuousCfg) -> Coordinator
    where
        F: FnMut() -> Box<dyn StepEngine> + Send + 'static,
    {
        let (tx, rx) = channel::<GenRequest>();
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let m2 = metrics.clone();
        let drain = Arc::new(AtomicBool::new(false));
        let drain2 = drain.clone();
        let worker = std::thread::spawn(move || {
            let m3 = m2.clone();
            let mut sched = Scheduler::new(make_engine(), cfg, m2);
            let mut open = true;
            let mut backoff = cfg.respawn_backoff;
            loop {
                if drain2.load(Ordering::SeqCst) {
                    sched.begin_drain(); // idempotent
                }
                if !open && sched.idle() {
                    break;
                }
                if open && sched.idle() && !drain2.load(Ordering::SeqCst) {
                    // Nothing to do: block for the next request instead
                    // of spinning.
                    match rx.recv() {
                        Ok(r) => sched.enqueue(r),
                        Err(_) => open = false,
                    }
                }
                // Drain whatever else arrived so this tick sees the full
                // queue (join happens at tick granularity).
                while open {
                    match rx.try_recv() {
                        Ok(r) => sched.enqueue(r),
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => open = false,
                    }
                }
                if sched.idle() {
                    if !open {
                        break;
                    }
                    continue;
                }
                match sched.tick() {
                    Ok(Tick::Ok) => backoff = cfg.respawn_backoff,
                    Ok(Tick::EngineFailed) => {
                        // In-flight state died with the engine (tick
                        // already failed those requests); queued work
                        // survives for the replacement.
                        std::thread::sleep(backoff);
                        backoff = (backoff * 2).min(cfg.respawn_backoff_cap);
                        sched.replace_engine(make_engine());
                        lock_recover(&m3).respawns += 1;
                    }
                    Err(e) => {
                        // Non-recoverable scheduler error: terminate
                        // everything cleanly rather than hanging clients.
                        eprintln!("continuous serving failed: {e:#}");
                        sched.abort();
                        break;
                    }
                }
            }
        });
        Coordinator {
            tx: Some(tx),
            worker: Some(worker),
            next_id: std::sync::atomic::AtomicU64::new(0),
            metrics,
            drain,
        }
    }

    /// Submit a request; the receiver yields the response when served.
    /// After shutdown — or if the worker died — the response is an
    /// immediate clean rejection, never a panic.
    pub fn submit(&self, prompt: Vec<u8>, max_new: usize) -> Receiver<GenResponse> {
        self.submit_with_deadline(prompt, max_new, None)
    }

    /// [`Self::submit`] with a serve-by deadline relative to now. The
    /// scheduler sheds the request if it is still queued at the
    /// deadline, and cancels it at the next tick if it is mid-decode
    /// (returning the tokens generated so far).
    pub fn submit_with_deadline(
        &self,
        prompt: Vec<u8>,
        max_new: usize,
        deadline: Option<Duration>,
    ) -> Receiver<GenResponse> {
        let (reply, rx) = channel();
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let now = Instant::now();
        let req = GenRequest {
            id,
            prompt,
            max_new,
            deadline: deadline.map(|d| now + d),
            key: id,
            enqueued: now,
            reply,
        };
        let undeliverable = match &self.tx {
            Some(tx) => tx.send(req).err().map(|e| e.0),
            None => Some(req),
        };
        if let Some(req) = undeliverable {
            lock_recover(&self.metrics).rejected += 1;
            respond(&req, Vec::new(), 0, GenStatus::Rejected);
        }
        rx
    }

    /// Snapshot of the metrics.
    pub fn metrics(&self) -> ServeMetrics {
        lock_recover(&self.metrics).clone()
    }

    /// Graceful drain: stop admission, give queued-but-unadmitted
    /// requests terminal rejections, let in-flight sequences run to
    /// completion (or their deadline), and join the worker. Subsequent
    /// [`Self::submit`] calls are cleanly rejected.
    pub fn shutdown(&mut self) -> ServeMetrics {
        self.drain.store(true, Ordering::SeqCst);
        self.tx.take(); // close the queue
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        lock_recover(&self.metrics).clone()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.drain.store(true, Ordering::SeqCst);
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::Result;

    /// Echo engine: returns the prompt reversed, capped at max_new.
    struct EchoEngine {
        batch: usize,
        calls: Arc<Mutex<Vec<usize>>>,
    }

    impl GenEngine for EchoEngine {
        fn generate_batch(&mut self, prompts: &[Vec<u8>], max_new: usize) -> Result<Vec<Vec<u8>>> {
            self.calls.lock().unwrap().push(prompts.len());
            Ok(prompts
                .iter()
                .map(|p| p.iter().rev().cloned().take(max_new).collect())
                .collect())
        }

        fn max_batch(&self) -> usize {
            self.batch
        }
    }

    #[test]
    fn serves_and_answers() {
        let calls = Arc::new(Mutex::new(Vec::new()));
        let mut coord = Coordinator::start(
            move || Box::new(EchoEngine { batch: 4, calls: calls.clone() }) as Box<dyn GenEngine>,
            BatcherCfg::default(),
        );
        let rx = coord.submit(vec![1, 2, 3], 2);
        let resp = rx.recv().unwrap();
        assert_eq!(resp.tokens, vec![3, 2]);
        assert!(resp.is_ok());
        let met = coord.shutdown();
        assert_eq!(met.requests, 1);
        assert_eq!(met.tokens_out, 2);
    }

    #[test]
    fn batches_concurrent_requests() {
        let calls = Arc::new(Mutex::new(Vec::new()));
        let mut coord = Coordinator::start(
            move || Box::new(EchoEngine { batch: 8, calls: calls.clone() }) as Box<dyn GenEngine>,
            BatcherCfg { max_batch: 8, max_wait: std::time::Duration::from_millis(50) },
        );
        let rxs: Vec<_> = (0..6).map(|i| coord.submit(vec![i as u8], 1)).collect();
        let resps: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        assert_eq!(resps.len(), 6);
        let met = coord.shutdown();
        assert_eq!(met.requests, 6);
        // At least one multi-request batch formed.
        assert!(met.batch_sizes.iter().any(|&b| b > 1), "{:?}", met.batch_sizes);
    }

    #[test]
    fn oversize_batches_chunked_to_engine_width() {
        let calls = Arc::new(Mutex::new(Vec::new()));
        let c2 = calls.clone();
        let mut coord = Coordinator::start(
            move || Box::new(EchoEngine { batch: 2, calls: c2.clone() }) as Box<dyn GenEngine>,
            BatcherCfg { max_batch: 5, max_wait: std::time::Duration::from_millis(60) },
        );
        let rxs: Vec<_> = (0..5).map(|i| coord.submit(vec![i as u8; 3], 3)).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        coord.shutdown();
        let seen = calls.lock().unwrap();
        assert!(seen.iter().all(|&c| c <= 2), "engine saw oversize chunk: {seen:?}");
    }

    #[test]
    fn engine_phase_stats_reach_metrics() {
        use crate::coordinator::EngineStats;
        use std::time::Duration;

        /// Engine reporting a fixed phase split per chunk, counting calls.
        struct StatEngine {
            calls: Arc<Mutex<usize>>,
        }
        impl GenEngine for StatEngine {
            fn generate_batch(
                &mut self,
                prompts: &[Vec<u8>],
                max_new: usize,
            ) -> Result<Vec<Vec<u8>>> {
                *self.calls.lock().unwrap() += 1;
                Ok(prompts.iter().map(|_| vec![1; max_new]).collect())
            }
            fn max_batch(&self) -> usize {
                // Width 2 so a 3-request batch splits into two chunks —
                // TTFT/stat accounting must hold per chunk.
                2
            }
            fn take_stats(&mut self) -> EngineStats {
                EngineStats {
                    prefill_time: Duration::from_millis(10),
                    decode_time: Duration::from_millis(20),
                    prefill_tokens: 5,
                    decode_tokens: 7,
                    ..Default::default()
                }
            }
        }

        let calls = Arc::new(Mutex::new(0usize));
        let c2 = calls.clone();
        let mut coord = Coordinator::start(
            move || Box::new(StatEngine { calls: c2.clone() }) as Box<dyn GenEngine>,
            BatcherCfg::default(),
        );
        let rxs: Vec<_> = (0..3).map(|_| coord.submit(vec![1, 2], 2)).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let met = coord.shutdown();
        let chunks = *calls.lock().unwrap() as u64;
        assert!(chunks >= 2, "3 requests through width-2 chunks: {chunks}");
        // One stats report per engine chunk, accumulated.
        assert_eq!(met.engine.prefill_tokens, 5 * chunks);
        assert_eq!(met.engine.decode_tokens, 7 * chunks);
        assert_eq!(met.engine.prefill_time, Duration::from_millis(10) * chunks as u32);
        // Every request records a TTFT that includes its chunk's prefill.
        assert_eq!(met.ttft.count(), met.requests);
        assert!(met.ttft.quantile(0.5) >= Duration::from_millis(10));
        assert!(met.decode_tok_s() > 0.0);
    }

    #[test]
    fn continuous_serves_and_answers() {
        use crate::coordinator::ContinuousCfg;

        let coord = Coordinator::start_continuous(
            || Box::new(StepEcho::new(2)) as Box<dyn StepEngine>,
            ContinuousCfg::default(),
        );
        // 4 requests through 2 slots: the scheduler queues the overflow
        // and admits as slots free, mid-decode of whoever is running.
        let rxs: Vec<_> = (0..4u8).map(|i| coord.submit(vec![10 + i, 20 + i, 30], 2)).collect();
        let mut coord = coord;
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert!(resp.is_ok());
            assert_eq!(resp.tokens, vec![10 + i as u8, 20 + i as u8]);
        }
        let met = coord.shutdown();
        assert_eq!(met.requests, 4);
        assert_eq!(met.tokens_out, 8);
        assert_eq!(met.rejected, 0);
        assert!(!met.queue_depth.is_empty());
    }

    /// Step engine echoing prompt bytes back one per step.
    struct StepEcho {
        seqs: std::collections::HashMap<u64, (Vec<u8>, Vec<u8>, usize)>,
        running: Vec<u64>,
        next_id: u64,
        slots: usize,
    }

    impl StepEcho {
        fn new(slots: usize) -> StepEcho {
            StepEcho { seqs: Default::default(), running: Vec::new(), next_id: 0, slots }
        }
    }

    impl StepEngine for StepEcho {
        fn admit(
            &mut self,
            prompt: Vec<u8>,
            max_new: usize,
            _key: u64,
        ) -> Result<super::super::AdmitOutcome> {
            use super::super::AdmitOutcome;
            if self.running.len() >= self.max_concurrent() {
                return Ok(AdmitOutcome::NoCapacity(prompt));
            }
            let id = self.next_id;
            self.next_id += 1;
            let mut remaining = prompt;
            remaining.reverse();
            let first = remaining.pop().unwrap_or(0);
            self.seqs.insert(id, (remaining, vec![first], max_new.max(1)));
            self.running.push(id);
            Ok(AdmitOutcome::Admitted(id))
        }
        fn step(&mut self) -> Result<Vec<u64>> {
            let mut fin = Vec::new();
            for &id in &self.running {
                let (rem, out, max_new) = self.seqs.get_mut(&id).unwrap();
                if out.len() < *max_new {
                    out.push(rem.pop().unwrap_or(0));
                }
                if out.len() >= *max_new {
                    fin.push(id);
                }
            }
            self.running.retain(|id| !fin.contains(id));
            Ok(fin)
        }
        fn take_output(&mut self, id: u64) -> Option<Vec<u8>> {
            self.running.retain(|&r| r != id);
            self.seqs.remove(&id).map(|(_, out, _)| out)
        }
        fn take_preempted(&mut self) -> Vec<u64> {
            Vec::new()
        }
        fn resume(&mut self, _id: u64) -> Result<bool> {
            Ok(false)
        }
        fn running(&self) -> usize {
            self.running.len()
        }
        fn max_concurrent(&self) -> usize {
            self.slots
        }
        fn pool_stats(&self) -> super::super::PoolStats {
            super::super::PoolStats::default()
        }
    }

    #[test]
    fn shutdown_drains() {
        // Every submitted request gets exactly one terminal response
        // across a shutdown race: either served (bit-exact echo) or a
        // clean rejection from the drain — never a hang or a panic.
        let calls = Arc::new(Mutex::new(Vec::new()));
        let mut coord = Coordinator::start(
            move || Box::new(EchoEngine { batch: 4, calls: calls.clone() }) as Box<dyn GenEngine>,
            BatcherCfg::default(),
        );
        let rxs: Vec<_> = (0..3).map(|_| coord.submit(vec![9, 9], 1)).collect();
        let met = coord.shutdown();
        let mut served = 0u64;
        let mut rejected = 0u64;
        for rx in rxs {
            let resp = rx.recv().expect("exactly one terminal response");
            match resp.status {
                GenStatus::Ok => {
                    assert_eq!(resp.tokens, vec![9]);
                    served += 1;
                }
                GenStatus::Rejected => {
                    assert!(resp.tokens.is_empty());
                    rejected += 1;
                }
                other => panic!("unexpected terminal state {other:?}"),
            }
        }
        assert_eq!(served + rejected, 3);
        assert_eq!(met.requests, served);
        assert_eq!(met.rejected, rejected);
    }

    #[test]
    fn submit_after_shutdown_is_cleanly_rejected() {
        let calls = Arc::new(Mutex::new(Vec::new()));
        let mut coord = Coordinator::start(
            move || Box::new(EchoEngine { batch: 4, calls: calls.clone() }) as Box<dyn GenEngine>,
            BatcherCfg::default(),
        );
        coord.shutdown();
        let rx = coord.submit(vec![1, 2, 3], 2);
        let resp = rx.recv().expect("rejection must still be delivered");
        assert_eq!(resp.status, GenStatus::Rejected);
        assert!(resp.tokens.is_empty());
        assert!(coord.metrics().rejected >= 1);
    }

    #[test]
    fn static_panic_quarantines_offender_and_respawns() {
        /// Panics whenever a poison prompt is in the batch.
        struct PoisonEngine {
            calls: Arc<Mutex<Vec<usize>>>,
        }
        impl GenEngine for PoisonEngine {
            fn generate_batch(
                &mut self,
                prompts: &[Vec<u8>],
                max_new: usize,
            ) -> Result<Vec<Vec<u8>>> {
                self.calls.lock().unwrap().push(prompts.len());
                if prompts.iter().any(|p| p == &[66u8]) {
                    panic!("poison prompt");
                }
                Ok(prompts.iter().map(|p| p.iter().cloned().take(max_new).collect()).collect())
            }
            fn max_batch(&self) -> usize {
                8
            }
        }

        let calls = Arc::new(Mutex::new(Vec::new()));
        let spawned = Arc::new(Mutex::new(0usize));
        let (c2, s2) = (calls.clone(), spawned.clone());
        let mut coord = Coordinator::start(
            move || {
                *s2.lock().unwrap() += 1;
                Box::new(PoisonEngine { calls: c2.clone() }) as Box<dyn GenEngine>
            },
            BatcherCfg { max_batch: 8, max_wait: std::time::Duration::from_millis(50) },
        );
        let prompts: Vec<Vec<u8>> = vec![vec![1], vec![66], vec![2], vec![3]];
        let rxs: Vec<_> = prompts.iter().map(|p| coord.submit(p.clone(), 4)).collect();
        let resps: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        for (p, resp) in prompts.iter().zip(&resps) {
            if p == &[66u8] {
                assert_eq!(resp.status, GenStatus::Failed, "poison request must fail");
                assert!(resp.tokens.is_empty());
            } else {
                assert!(resp.is_ok(), "batch-mates must be served: {:?}", resp.status);
                assert_eq!(&resp.tokens, p);
            }
        }
        let met = coord.shutdown();
        assert_eq!(met.failed, 1);
        assert!(met.respawns >= 1, "a panicked engine must be respawned");
        assert!(*spawned.lock().unwrap() >= 2, "factory must be called again");
    }

    #[test]
    fn continuous_engine_loss_fails_inflight_and_respawn_serves_queue() {
        /// First engine instance panics on its first step; replacements
        /// behave (StepEcho).
        struct PanicStep;
        impl StepEngine for PanicStep {
            fn admit(
                &mut self,
                _prompt: Vec<u8>,
                _max_new: usize,
                _key: u64,
            ) -> Result<super::super::AdmitOutcome> {
                Ok(super::super::AdmitOutcome::Admitted(0))
            }
            fn step(&mut self) -> Result<Vec<u64>> {
                panic!("engine lost");
            }
            fn take_output(&mut self, _id: u64) -> Option<Vec<u8>> {
                None
            }
            fn take_preempted(&mut self) -> Vec<u64> {
                Vec::new()
            }
            fn resume(&mut self, _id: u64) -> Result<bool> {
                Ok(false)
            }
            fn running(&self) -> usize {
                1
            }
            fn max_concurrent(&self) -> usize {
                1
            }
            fn pool_stats(&self) -> super::super::PoolStats {
                super::super::PoolStats::default()
            }
        }

        let spawned = Arc::new(Mutex::new(0usize));
        let s2 = spawned.clone();
        let coord = Coordinator::start_continuous(
            move || {
                let n = {
                    let mut g = s2.lock().unwrap();
                    *g += 1;
                    *g
                };
                if n == 1 {
                    Box::new(PanicStep) as Box<dyn StepEngine>
                } else {
                    Box::new(StepEcho::new(2)) as Box<dyn StepEngine>
                }
            },
            ContinuousCfg {
                respawn_backoff: Duration::from_millis(1),
                ..Default::default()
            },
        );
        let rx0 = coord.submit(vec![1, 2, 3], 2);
        let r0 = rx0.recv().unwrap();
        assert_eq!(r0.status, GenStatus::Failed, "in-flight at engine loss fails");
        // The respawned engine serves new work.
        let rx1 = coord.submit(vec![7, 8, 9], 2);
        let r1 = rx1.recv().unwrap();
        assert!(r1.is_ok(), "respawned engine must serve: {:?}", r1.status);
        assert_eq!(r1.tokens, vec![7, 8]);
        let mut coord = coord;
        let met = coord.shutdown();
        assert_eq!(met.failed, 1);
        assert_eq!(met.respawns, 1);
        assert!(*spawned.lock().unwrap() >= 2);
    }
}
