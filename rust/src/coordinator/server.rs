//! The coordinator: request intake → dynamic batcher → worker → responses.

use super::{BatcherCfg, ContinuousCfg, DynamicBatcher, GenEngine, Scheduler, ServeMetrics, StepEngine};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// A generation request.
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new: usize,
    pub(crate) enqueued: Instant,
    pub(crate) reply: Sender<GenResponse>,
}

#[cfg(test)]
impl GenRequest {
    /// Build a request plus its reply receiver directly, bypassing a
    /// [`Coordinator`] — for driving a [`Scheduler`] in unit tests.
    pub(crate) fn new(id: u64, prompt: Vec<u8>, max_new: usize) -> (GenRequest, Receiver<GenResponse>) {
        let (reply, rx) = channel();
        (GenRequest { id, prompt, max_new, enqueued: Instant::now(), reply }, rx)
    }
}

/// A generation response.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    pub tokens: Vec<u8>,
    pub latency: std::time::Duration,
    pub batch_size: usize,
    /// Refused by backpressure (bounded queue overflow, or a request the
    /// engine can never serve); `tokens` is empty.
    pub rejected: bool,
}

/// Client handle + worker thread. Dropping the handle (or calling
/// [`Coordinator::shutdown`]) stops the worker after the queue drains.
pub struct Coordinator {
    tx: Option<Sender<GenRequest>>,
    worker: Option<std::thread::JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
    metrics: Arc<Mutex<ServeMetrics>>,
}

impl Coordinator {
    /// Start the serving loop on a worker thread.
    ///
    /// Takes a *factory* rather than an engine: PJRT handles are not
    /// `Send`, so the engine is constructed on the worker thread and
    /// never crosses a thread boundary. Production factories should
    /// restore prebuilt quantization state via the artifact constructors
    /// ([`super::NativeGenerator::quant_from_artifact`] /
    /// [`super::PjrtGenerator::quant_from_artifact`]) — loading packed
    /// codes is milliseconds, so worker (re)starts don't re-run
    /// calibration or GPTQ.
    pub fn start<F>(make_engine: F, cfg: BatcherCfg) -> Coordinator
    where
        F: FnOnce() -> Box<dyn GenEngine> + Send + 'static,
    {
        let (tx, rx) = channel::<GenRequest>();
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let m2 = metrics.clone();
        let worker = std::thread::spawn(move || {
            let mut engine = make_engine();
            let started = Instant::now();
            let batcher = DynamicBatcher::new(rx, cfg);
            while let Some(mut batch) = batcher.next_batch() {
                let bsz = batch.len();
                let max_new = batch.iter().map(|r| r.max_new).max().unwrap_or(0);
                // Move the prompts out — requests only carry them in, so
                // serving a batch needn't duplicate every prompt buffer.
                let prompts: Vec<Vec<u8>> =
                    batch.iter_mut().map(|r| std::mem::take(&mut r.prompt)).collect();
                // The graph batch width may be smaller than the batch the
                // policy admitted; chunk. Stats drain per chunk so TTFT
                // can charge each request its own chunk's start offset
                // (which includes earlier chunks' full generation) plus
                // that chunk's prefill — not a summed batch prefill.
                let chunk = engine.max_batch();
                let mut outputs: Vec<Vec<u8>> = Vec::with_capacity(bsz);
                let mut chunk_stats: Vec<(Instant, super::EngineStats)> = Vec::new();
                for c in prompts.chunks(chunk) {
                    let c_start = Instant::now();
                    match engine.generate_batch(c, max_new) {
                        Ok(mut o) => outputs.append(&mut o),
                        Err(e) => {
                            eprintln!("generation failed: {e:#}");
                            outputs.extend(std::iter::repeat_with(Vec::new).take(c.len()));
                        }
                    }
                    chunk_stats.push((c_start, engine.take_stats()));
                }
                let now = Instant::now();
                let mut met = m2.lock().unwrap();
                met.batch_sizes.push(bsz);
                for (_, s) in &chunk_stats {
                    met.engine.accumulate(s);
                }
                for (ri, (req, tokens)) in batch.into_iter().zip(outputs).enumerate() {
                    let latency = now - req.enqueued;
                    met.requests += 1;
                    met.tokens_out += tokens.len().min(req.max_new) as u64;
                    met.request_latency.record(latency);
                    // Time-to-first-token ≈ wait until this request's
                    // chunk started + that chunk's prefill phase
                    // (engines that don't split phases report zero
                    // prefill, so this degrades to the wait alone).
                    let (c_start, c_stats) = chunk_stats[ri / chunk];
                    met.ttft.record(c_start - req.enqueued + c_stats.prefill_time);
                    let _ = req.reply.send(GenResponse {
                        id: req.id,
                        tokens: tokens.into_iter().take(req.max_new).collect(),
                        latency,
                        batch_size: bsz,
                        rejected: false,
                    });
                }
                met.elapsed = now - started;
            }
        });
        Coordinator {
            tx: Some(tx),
            worker: Some(worker),
            next_id: std::sync::atomic::AtomicU64::new(0),
            metrics,
        }
    }

    /// Start the continuous-batching serving loop on a worker thread.
    ///
    /// Unlike [`Coordinator::start`], requests do not wait for a batch to
    /// form or for batch-mates to finish: the worker drains the intake
    /// channel into a [`Scheduler`] and ticks it — sequences join the
    /// running batch mid-decode and leave individually at their own
    /// `max_new`. Backpressure (bounded queue + page-pool admission
    /// watermark) can refuse requests; check [`GenResponse::rejected`].
    pub fn start_continuous<F>(make_engine: F, cfg: ContinuousCfg) -> Coordinator
    where
        F: FnOnce() -> Box<dyn StepEngine> + Send + 'static,
    {
        let (tx, rx) = channel::<GenRequest>();
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let m2 = metrics.clone();
        let worker = std::thread::spawn(move || {
            let mut sched = Scheduler::new(make_engine(), cfg, m2);
            let mut open = true;
            while open || !sched.idle() {
                if open && sched.idle() {
                    // Nothing to do: block for the next request instead
                    // of spinning.
                    match rx.recv() {
                        Ok(r) => sched.enqueue(r),
                        Err(_) => open = false,
                    }
                }
                // Drain whatever else arrived so this tick sees the full
                // queue (join happens at tick granularity).
                while open {
                    match rx.try_recv() {
                        Ok(r) => sched.enqueue(r),
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => open = false,
                    }
                }
                if sched.idle() {
                    continue;
                }
                if let Err(e) = sched.tick() {
                    eprintln!("continuous serving failed: {e:#}");
                    break;
                }
            }
        });
        Coordinator {
            tx: Some(tx),
            worker: Some(worker),
            next_id: std::sync::atomic::AtomicU64::new(0),
            metrics,
        }
    }

    /// Submit a request; the receiver yields the response when served.
    pub fn submit(&self, prompt: Vec<u8>, max_new: usize) -> Receiver<GenResponse> {
        let (reply, rx) = channel();
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let req = GenRequest { id, prompt, max_new, enqueued: Instant::now(), reply };
        self.tx.as_ref().expect("coordinator running").send(req).expect("worker alive");
        rx
    }

    /// Snapshot of the metrics.
    pub fn metrics(&self) -> ServeMetrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Drain and stop the worker.
    pub fn shutdown(mut self) -> ServeMetrics {
        self.tx.take(); // close the queue
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.metrics.lock().unwrap().clone()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::Result;

    /// Echo engine: returns the prompt reversed, capped at max_new.
    struct EchoEngine {
        batch: usize,
        calls: Arc<Mutex<Vec<usize>>>,
    }

    impl GenEngine for EchoEngine {
        fn generate_batch(&mut self, prompts: &[Vec<u8>], max_new: usize) -> Result<Vec<Vec<u8>>> {
            self.calls.lock().unwrap().push(prompts.len());
            Ok(prompts
                .iter()
                .map(|p| p.iter().rev().cloned().take(max_new).collect())
                .collect())
        }

        fn max_batch(&self) -> usize {
            self.batch
        }
    }

    #[test]
    fn serves_and_answers() {
        let calls = Arc::new(Mutex::new(Vec::new()));
        let engine = EchoEngine { batch: 4, calls: calls.clone() };
        let coord = Coordinator::start(move || Box::new(engine) as Box<dyn GenEngine>, BatcherCfg::default());
        let rx = coord.submit(vec![1, 2, 3], 2);
        let resp = rx.recv().unwrap();
        assert_eq!(resp.tokens, vec![3, 2]);
        let met = coord.shutdown();
        assert_eq!(met.requests, 1);
        assert_eq!(met.tokens_out, 2);
    }

    #[test]
    fn batches_concurrent_requests() {
        let calls = Arc::new(Mutex::new(Vec::new()));
        let engine = EchoEngine { batch: 8, calls: calls.clone() };
        let coord = Coordinator::start(
            move || Box::new(engine) as Box<dyn GenEngine>,
            BatcherCfg { max_batch: 8, max_wait: std::time::Duration::from_millis(50) },
        );
        let rxs: Vec<_> = (0..6).map(|i| coord.submit(vec![i as u8], 1)).collect();
        let resps: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        assert_eq!(resps.len(), 6);
        let met = coord.shutdown();
        assert_eq!(met.requests, 6);
        // At least one multi-request batch formed.
        assert!(met.batch_sizes.iter().any(|&b| b > 1), "{:?}", met.batch_sizes);
    }

    #[test]
    fn oversize_batches_chunked_to_engine_width() {
        let calls = Arc::new(Mutex::new(Vec::new()));
        let engine = EchoEngine { batch: 2, calls: calls.clone() };
        let coord = Coordinator::start(
            move || Box::new(engine) as Box<dyn GenEngine>,
            BatcherCfg { max_batch: 5, max_wait: std::time::Duration::from_millis(60) },
        );
        let rxs: Vec<_> = (0..5).map(|i| coord.submit(vec![i as u8; 3], 3)).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        coord.shutdown();
        let seen = calls.lock().unwrap();
        assert!(seen.iter().all(|&c| c <= 2), "engine saw oversize chunk: {seen:?}");
    }

    #[test]
    fn engine_phase_stats_reach_metrics() {
        use crate::coordinator::EngineStats;
        use std::time::Duration;

        /// Engine reporting a fixed phase split per chunk, counting calls.
        struct StatEngine {
            calls: Arc<Mutex<usize>>,
        }
        impl GenEngine for StatEngine {
            fn generate_batch(
                &mut self,
                prompts: &[Vec<u8>],
                max_new: usize,
            ) -> Result<Vec<Vec<u8>>> {
                *self.calls.lock().unwrap() += 1;
                Ok(prompts.iter().map(|_| vec![1; max_new]).collect())
            }
            fn max_batch(&self) -> usize {
                // Width 2 so a 3-request batch splits into two chunks —
                // TTFT/stat accounting must hold per chunk.
                2
            }
            fn take_stats(&mut self) -> EngineStats {
                EngineStats {
                    prefill_time: Duration::from_millis(10),
                    decode_time: Duration::from_millis(20),
                    prefill_tokens: 5,
                    decode_tokens: 7,
                    ..Default::default()
                }
            }
        }

        let calls = Arc::new(Mutex::new(0usize));
        let c2 = calls.clone();
        let coord = Coordinator::start(
            move || Box::new(StatEngine { calls: c2 }) as Box<dyn GenEngine>,
            BatcherCfg::default(),
        );
        let rxs: Vec<_> = (0..3).map(|_| coord.submit(vec![1, 2], 2)).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let met = coord.shutdown();
        let chunks = *calls.lock().unwrap() as u64;
        assert!(chunks >= 2, "3 requests through width-2 chunks: {chunks}");
        // One stats report per engine chunk, accumulated.
        assert_eq!(met.engine.prefill_tokens, 5 * chunks);
        assert_eq!(met.engine.decode_tokens, 7 * chunks);
        assert_eq!(met.engine.prefill_time, Duration::from_millis(10) * chunks as u32);
        // Every request records a TTFT that includes its chunk's prefill.
        assert_eq!(met.ttft.count(), met.requests);
        assert!(met.ttft.quantile(0.5) >= Duration::from_millis(10));
        assert!(met.decode_tok_s() > 0.0);
    }

    #[test]
    fn continuous_serves_and_answers() {
        use crate::coordinator::{AdmitOutcome, ContinuousCfg, PoolStats, StepEngine};

        /// Step engine echoing prompt bytes back one per step, 2 slots.
        struct StepEcho {
            seqs: std::collections::HashMap<u64, (Vec<u8>, Vec<u8>, usize)>,
            running: Vec<u64>,
            next_id: u64,
        }
        impl StepEngine for StepEcho {
            fn admit(&mut self, prompt: Vec<u8>, max_new: usize) -> Result<AdmitOutcome> {
                if self.running.len() >= self.max_concurrent() {
                    return Ok(AdmitOutcome::NoCapacity(prompt));
                }
                let id = self.next_id;
                self.next_id += 1;
                let mut remaining = prompt;
                remaining.reverse();
                let first = remaining.pop().unwrap_or(0);
                self.seqs.insert(id, (remaining, vec![first], max_new.max(1)));
                self.running.push(id);
                Ok(AdmitOutcome::Admitted(id))
            }
            fn step(&mut self) -> Result<Vec<u64>> {
                let mut fin = Vec::new();
                for &id in &self.running {
                    let (rem, out, max_new) = self.seqs.get_mut(&id).unwrap();
                    if out.len() < *max_new {
                        out.push(rem.pop().unwrap_or(0));
                    }
                    if out.len() >= *max_new {
                        fin.push(id);
                    }
                }
                self.running.retain(|id| !fin.contains(id));
                Ok(fin)
            }
            fn take_output(&mut self, id: u64) -> Option<Vec<u8>> {
                self.running.retain(|&r| r != id);
                self.seqs.remove(&id).map(|(_, out, _)| out)
            }
            fn take_preempted(&mut self) -> Vec<u64> {
                Vec::new()
            }
            fn resume(&mut self, _id: u64) -> Result<bool> {
                Ok(false)
            }
            fn running(&self) -> usize {
                self.running.len()
            }
            fn max_concurrent(&self) -> usize {
                2
            }
            fn pool_stats(&self) -> PoolStats {
                PoolStats::default()
            }
        }

        let coord = Coordinator::start_continuous(
            || {
                Box::new(StepEcho {
                    seqs: Default::default(),
                    running: Vec::new(),
                    next_id: 0,
                }) as Box<dyn StepEngine>
            },
            ContinuousCfg::default(),
        );
        // 4 requests through 2 slots: the scheduler queues the overflow
        // and admits as slots free, mid-decode of whoever is running.
        let rxs: Vec<_> =
            (0..4u8).map(|i| coord.submit(vec![10 + i, 20 + i, 30], 2)).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert!(!resp.rejected);
            assert_eq!(resp.tokens, vec![10 + i as u8, 20 + i as u8]);
        }
        let met = coord.shutdown();
        assert_eq!(met.requests, 4);
        assert_eq!(met.tokens_out, 8);
        assert_eq!(met.rejected, 0);
        assert!(!met.queue_depth.is_empty());
    }

    #[test]
    fn shutdown_drains() {
        let calls = Arc::new(Mutex::new(Vec::new()));
        let engine = EchoEngine { batch: 4, calls };
        let coord = Coordinator::start(move || Box::new(engine) as Box<dyn GenEngine>, BatcherCfg::default());
        let rxs: Vec<_> = (0..3).map(|_| coord.submit(vec![9, 9], 1)).collect();
        let met = coord.shutdown();
        assert_eq!(met.requests, 3);
        for rx in rxs {
            assert!(rx.recv().is_ok());
        }
    }
}
