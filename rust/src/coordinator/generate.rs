//! Batched generation engines.
//!
//! [`PjrtGenerator`] is the production path: batched prefill + KV-cache
//! decode through the AOT-compiled executables, FP or quantized (the
//! quantized variant takes the PTQ pipeline's [`QuantConfig`] products as
//! runtime arguments — serving a CAT-W4A4 model is just a different
//! `ArgPack`).

use crate::linalg::Rng;
use crate::model::QuantConfig;
use crate::runtime::{token_literal, ArgPack, DevicePack, PjrtEngine};
use anyhow::Result;

/// Sampling policy for generation.
#[derive(Clone, Copy, Debug)]
pub struct SamplingCfg {
    /// 0.0 = greedy; otherwise softmax temperature.
    pub temperature: f64,
    pub seed: u64,
}

impl Default for SamplingCfg {
    fn default() -> Self {
        SamplingCfg { temperature: 0.0, seed: 0 }
    }
}

/// Phase-split accounting for one or more `generate_batch` calls:
/// prefill (prompt ingestion, the time-to-first-token cost) vs decode
/// (steady-state token production).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// Wall time spent in prefill.
    pub prefill_time: std::time::Duration,
    /// Wall time spent in the decode loop (including sampling).
    pub decode_time: std::time::Duration,
    /// Prompt tokens ingested by prefill.
    pub prefill_tokens: u64,
    /// Tokens produced by incremental decode steps.
    pub decode_tokens: u64,
    /// Prompts clamped to the positional budget before serving — a
    /// capacity-pressure signal, not an error (the tail of the prompt is
    /// served).
    pub truncated_prompts: u64,
    /// Decode panics caught and contained by the engine (each triggers
    /// a bisect-and-retry pass; none escapes to the worker).
    pub step_panics: u64,
    /// Sequences quarantined because they reproduced a panic alone —
    /// each is reported as failed exactly once via `take_failed`.
    pub quarantined: u64,
}

impl EngineStats {
    pub fn accumulate(&mut self, other: &EngineStats) {
        self.prefill_time += other.prefill_time;
        self.decode_time += other.decode_time;
        self.prefill_tokens += other.prefill_tokens;
        self.decode_tokens += other.decode_tokens;
        self.truncated_prompts += other.truncated_prompts;
        self.step_panics += other.step_panics;
        self.quarantined += other.quarantined;
    }
}

/// KV-pool and prefix-cache gauges a [`StepEngine`] reports every tick —
/// the observability feed for `ServeMetrics` (page occupancy, prefix hit
/// rate) and the admission controller's watermark input.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    pub live_bytes: usize,
    pub peak_bytes: usize,
    /// `usize::MAX` means unbounded.
    pub budget_bytes: usize,
    pub prefix_hits: u64,
    pub prefix_lookups: u64,
}

/// What [`StepEngine::admit`] did with a prompt.
pub enum AdmitOutcome {
    /// Prefill ran and the first token is sampled; the id names the
    /// sequence in every later `step`/`take_output` call.
    Admitted(u64),
    /// No slot or page capacity right now — the prompt is handed back
    /// untouched so the caller can retry without having cloned it.
    NoCapacity(Vec<u8>),
}

/// A step-granular generation engine: sequences join mid-decode, advance
/// one token per [`Self::step`], and leave individually — the seam the
/// continuous-batching scheduler drives, replacing the
/// `generate_batch`-only API where every member waits for the slowest.
///
/// Like [`GenEngine`], implementations need not be `Send`; the
/// coordinator constructs them on the worker thread via a factory.
pub trait StepEngine {
    /// Try to admit a sequence: prefill its prompt (possibly reusing
    /// shared prefix pages) and sample its first token.
    ///
    /// `key` is the request's stable sampling-stream key: engines that
    /// sample must seed the sequence's RNG from it (never from an
    /// engine-local slot index), so the same request admitted on *any*
    /// replica — including a hedged duplicate — draws the identical
    /// stream. Together with schedule-independent draws this makes
    /// replicated outputs bit-exact regardless of routing.
    fn admit(&mut self, prompt: Vec<u8>, max_new: usize, key: u64) -> Result<AdmitOutcome>;

    /// One batched decode step over every running sequence. Returns the
    /// ids that finished (their own `max_new` or positional capacity) —
    /// collect them with [`Self::take_output`].
    fn step(&mut self) -> Result<Vec<u64>>;

    /// Take a sequence's generated tokens, releasing its KV pages. Also
    /// valid on a preempted sequence (finish-with-what-it-has).
    fn take_output(&mut self, id: u64) -> Option<Vec<u8>>;

    /// Ids preempted (pages reclaimed) since the last call; each either
    /// resumes via [`Self::resume`] or is finished via
    /// [`Self::take_output`].
    fn take_preempted(&mut self) -> Vec<u64>;

    /// Re-prefill a preempted sequence and rejoin the running batch;
    /// `false` when there is still no capacity. Resuming consumes no RNG,
    /// so sampled outputs are independent of preemption timing.
    fn resume(&mut self, id: u64) -> Result<bool>;

    /// Sequences currently running (admitted, not finished/preempted).
    fn running(&self) -> usize;

    /// Hard cap on concurrently running sequences.
    fn max_concurrent(&self) -> usize;

    /// Current pool/prefix gauges.
    fn pool_stats(&self) -> PoolStats;

    /// Drain phase accounting (see [`GenEngine::take_stats`]).
    fn take_stats(&mut self) -> EngineStats {
        EngineStats::default()
    }

    /// Ids quarantined by panic isolation since the last call — each is
    /// terminal (the request failed; partial tokens, if any, are still
    /// available via [`Self::take_output`]). Engines without panic
    /// isolation never report any.
    fn take_failed(&mut self) -> Vec<u64> {
        Vec::new()
    }
}

/// A batched generator: prompts in, continuations out.
///
/// Not `Send`: PJRT engines hold raw C handles, so the coordinator
/// constructs the engine on its worker thread via a factory.
pub trait GenEngine {
    /// Generate `max_new` tokens for each prompt. Prompts are padded /
    /// truncated to the engine's prompt length internally.
    fn generate_batch(&mut self, prompts: &[Vec<u8>], max_new: usize) -> Result<Vec<Vec<u8>>>;

    /// The fixed batch width of the underlying executable.
    fn max_batch(&self) -> usize;

    /// Drain the prefill/decode accounting accumulated since the last
    /// call. Engines without phase instrumentation report zeros.
    fn take_stats(&mut self) -> EngineStats {
        EngineStats::default()
    }
}

/// Sample one token index from a logits row: greedy argmax at
/// `temperature <= 0`, otherwise softmax sampling at the given
/// temperature. Shared by the PJRT and native generators so both draw
/// identically from the same RNG stream.
pub(crate) fn sample_index(logits: &[f64], temperature: f64, rng: &mut Rng) -> usize {
    if temperature <= 0.0 {
        let mut best = 0;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        return best;
    }
    let max = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let weights: Vec<f64> = logits.iter().map(|&v| ((v - max) / temperature).exp()).collect();
    let total: f64 = weights.iter().sum();
    let mut u = rng.uniform() * total;
    for (i, w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return i;
        }
    }
    logits.len() - 1
}

/// PJRT prefill+decode generator.
pub struct PjrtGenerator {
    engine: std::rc::Rc<PjrtEngine>,
    model: String,
    prefill_graph: String,
    decode_graph: String,
    pack: DevicePack,
    prompt_len: usize,
    batch: usize,
    seq_max: usize,
    vocab: usize,
    sampling: SamplingCfg,
    rng: Rng,
    bos: u8,
}

impl PjrtGenerator {
    /// FP serving.
    pub fn fp(
        engine: std::rc::Rc<PjrtEngine>,
        model: &str,
        params: &std::collections::HashMap<String, crate::linalg::Mat>,
        sampling: SamplingCfg,
    ) -> Result<PjrtGenerator> {
        let entry = engine.manifest().model(model)?.clone();
        let pack = ArgPack::fp(&entry, params)?;
        Self::new(engine, model, "prefill_fp", "decode_fp", pack, sampling)
    }

    /// Quantized serving (W?A4 graphs + pipeline products).
    ///
    /// The compiled `*_a4` graphs quantize activations at a *baked-in*
    /// uniform A4, so `qc` must be uniform asymmetric 4-bit — mixed or
    /// non-A4 plans are rejected (by [`ArgPack::quant`], the shared
    /// seam) rather than served with numerics that match neither the
    /// plan nor the native engine; use [`super::NativeGenerator`] for
    /// those.
    pub fn quant(
        engine: std::rc::Rc<PjrtEngine>,
        model: &str,
        params: &std::collections::HashMap<String, crate::linalg::Mat>,
        qc: &QuantConfig,
        sampling: SamplingCfg,
    ) -> Result<PjrtGenerator> {
        let entry = engine.manifest().model(model)?.clone();
        let pack = ArgPack::quant(&entry, params, qc)?;
        Self::new(engine, model, "prefill_a4", "decode_a4", pack, sampling)
    }

    /// Quantized serving from a saved artifact
    /// ([`crate::runtime::load_artifact`]): loads prebuilt transforms +
    /// packed codes (validated against `native`) instead of re-running
    /// the pipeline at boot, then packs them for the compiled graphs.
    pub fn quant_from_artifact(
        engine: std::rc::Rc<PjrtEngine>,
        model: &str,
        native: &crate::model::NativeModel,
        dir: &std::path::Path,
        sampling: SamplingCfg,
    ) -> Result<PjrtGenerator> {
        let qc = crate::runtime::load_artifact(dir, native)?;
        Self::quant(engine, model, &native.params, &qc, sampling)
    }

    fn new(
        engine: std::rc::Rc<PjrtEngine>,
        model: &str,
        prefill_graph: &str,
        decode_graph: &str,
        pack: ArgPack,
        sampling: SamplingCfg,
    ) -> Result<PjrtGenerator> {
        let m = engine.manifest().model(model)?;
        let cfg = &m.config;
        // §Perf: weights/transforms live on device across the whole
        // serving session — only tokens/pos/kv cross the host boundary.
        let pack = engine.device_pack(pack)?;
        Ok(PjrtGenerator {
            model: model.to_string(),
            prefill_graph: prefill_graph.to_string(),
            decode_graph: decode_graph.to_string(),
            pack,
            prompt_len: engine.manifest().prompt_len,
            batch: engine.manifest().serve_batch,
            seq_max: cfg.seq,
            vocab: cfg.vocab,
            sampling,
            rng: Rng::new(sampling.seed ^ 0x5A111),
            engine,
            bos: 0,
        })
    }

    /// Left-pad/truncate a prompt to exactly `prompt_len`.
    fn fit_prompt(&self, p: &[u8]) -> Vec<u8> {
        let pl = self.prompt_len;
        if p.len() >= pl {
            p[p.len() - pl..].to_vec()
        } else {
            let mut out = vec![self.bos; pl - p.len()];
            out.extend_from_slice(p);
            out
        }
    }

    fn sample_row(&mut self, logits: &[f32]) -> u8 {
        // Greedy path stays allocation-free (no RNG draw, no f64 bridge).
        if self.sampling.temperature <= 0.0 {
            let mut best = 0;
            for (i, &v) in logits.iter().enumerate() {
                if v > logits[best] {
                    best = i;
                }
            }
            return best as u8;
        }
        let row: Vec<f64> = logits.iter().map(|&v| v as f64).collect();
        sample_index(&row, self.sampling.temperature, &mut self.rng) as u8
    }
}

impl GenEngine for PjrtGenerator {
    fn generate_batch(&mut self, prompts: &[Vec<u8>], max_new: usize) -> Result<Vec<Vec<u8>>> {
        anyhow::ensure!(!prompts.is_empty() && prompts.len() <= self.batch);
        let real = prompts.len();
        // Pad the batch with copies of the last prompt (fixed-shape graph).
        let mut padded: Vec<Vec<u8>> =
            prompts.iter().map(|p| self.fit_prompt(p)).collect();
        while padded.len() < self.batch {
            padded.push(padded[real - 1].clone());
        }

        let tok = token_literal(&padded, self.prompt_len)?;
        let mut out =
            self.engine.run_b(&self.model, &self.prefill_graph, &[&tok], &self.pack)?;
        let mut vc = out.remove(2);
        let mut kc = out.remove(1);
        let mut logits = out.remove(0).to_vec::<f32>()?;

        let budget = max_new.min(self.seq_max - self.prompt_len);
        let mut results: Vec<Vec<u8>> = vec![Vec::new(); real];
        for step in 0..budget {
            // Sample next tokens for *real* rows only: pad rows must not
            // consume RNG draws, or sampled outputs would depend on how
            // full the batch happens to be. Pad rows feed a fixed token
            // to keep the decode graph's shape.
            let mut next: Vec<Vec<u8>> = (0..real)
                .map(|b| {
                    let row = &logits[b * self.vocab..(b + 1) * self.vocab];
                    vec![self.sample_row(row)]
                })
                .collect();
            while next.len() < self.batch {
                next.push(vec![self.bos]);
            }
            for (b, r) in results.iter_mut().enumerate() {
                r.push(next[b][0]);
            }
            if step + 1 == budget {
                break;
            }
            let ntok = token_literal(&next, 1)?;
            let pos = xla::Literal::vec1(&[(self.prompt_len + step) as i32]);
            let mut dout = self.engine.run_b(
                &self.model,
                &self.decode_graph,
                &[&ntok, &pos, &kc, &vc],
                &self.pack,
            )?;
            vc = dout.remove(2);
            kc = dout.remove(1);
            logits = dout.remove(0).to_vec::<f32>()?;
        }
        Ok(results)
    }

    fn max_batch(&self) -> usize {
        self.batch
    }
}
