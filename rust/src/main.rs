//! catquant CLI — the L3 leader entrypoint.
//!
//! ```text
//! catquant info
//! catquant exp fig2|fig3|fig4|fig5|fig6|table1|ablations [--models tiny,small] [--seed N] [--seeds N] [--quick]
//! catquant quantize --model small --transform cat [--wquant gptq] [--save-artifact DIR]
//! catquant plan --budget-mb N | --budget-kb N | --latency-us F
//!               [--objective sqnr|ppl-proxy] [--bits 2,3,4,6,8] [--recipes a,b,c] [--wquant rtn|gptq]
//!               [--model small | --synthetic] [--cat-block K] [--seed N] [--save-artifact DIR]
//! catquant eval --model small --transform cat [--wquant rtn] [--windows N]
//! catquant serve --model small --mode fp|cat-w4a4 [--engine pjrt|native] [--artifact DIR] [--requests N] [--max-new N]
//!                [--continuous] [--kv-budget-mb N] [--page-rows N] [--prefix-sharing true|false] [--max-queue N] [--admit-watermark F]
//!                [--deadline-ms N] [--chaos SPEC]
//!                [--replicas N] [--hedge-ms N] [--brownout-bits B] [--brownout-watermark F]
//! ```
//!
//! Argument parsing is hand-rolled: the offline vendor set has no clap.

use anyhow::{bail, Context, Result};
use catquant::calib::Corpus;
use catquant::coordinator::{
    BatcherCfg, BrownoutCfg, ContinuousCfg, Coordinator, GenEngine, NativeGenerator,
    PjrtGenerator, ReplicaCfg, ReplicaPool, SamplingCfg, ServePlan, StepEngine,
};
use catquant::eval::{perplexity, zero_shot_suite, PjrtLogits};
use catquant::experiments as exp;
use catquant::model::KvPoolCfg;
use catquant::pipeline::{build_quant_config, PipelineCfg, WeightQuantizer};
use catquant::runtime::{
    brownout_dir, load_artifact_retry, save_artifact, Chaos, Manifest, PjrtEngine,
};
use catquant::transforms::TransformKind;
use std::collections::HashMap;
use std::rc::Rc;

/// Tiny flag parser: positionals plus `--key value` pairs.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse() -> Args {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut it = std::env::args().skip(1).peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    it.next().unwrap()
                } else {
                    "true".to_string()
                };
                flags.insert(key.to_string(), val);
            } else {
                positional.push(a);
            }
        }
        Args { positional, flags }
    }

    fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn usize_flag(&self, key: &str, default: usize) -> usize {
        self.flag(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn u64_flag(&self, key: &str, default: u64) -> u64 {
        self.flag(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn parse_kind(name: &str) -> Result<TransformKind> {
    let lower = name.to_lowercase();
    // Registry names first (the canonical spellings), then CLI aliases.
    if let Some(kind) = TransformKind::from_name(&lower) {
        return Ok(kind);
    }
    Ok(match lower.as_str() {
        "none" => TransformKind::None,
        "sq" => TransformKind::SmoothQuant,
        "hadamard" => TransformKind::QuaRot,
        "cat" | "catblock" => TransformKind::CatBlock,
        "cat-trained" | "cattrained" => TransformKind::CatBlockTrained,
        "flatquant" => TransformKind::FlatQuant,
        other => bail!("unknown transform {other}"),
    })
}

fn parse_wquant(name: &str) -> Result<WeightQuantizer> {
    WeightQuantizer::from_name(&name.to_lowercase())
        .with_context(|| format!("unknown weight quantizer {name}"))
}

fn main() -> Result<()> {
    let args = Args::parse();
    // `plan --synthetic` must run without prebuilt artifacts (it is the
    // hermetic CI smoke), so the plan command loads the manifest lazily
    // itself instead of relying on the eager load below.
    if args.positional.first().map(|s| s.as_str()) == Some("plan") {
        return cmd_plan(&args);
    }
    let manifest = Manifest::load(&Manifest::default_dir()).context(
        "loading artifact manifest (run `make artifacts` to build corpus/weights/graphs)",
    )?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("info") => cmd_info(&manifest),
        Some("exp") => cmd_exp(&manifest, &args),
        Some("quantize") => cmd_quantize(&manifest, &args),
        Some("eval") => cmd_eval(&manifest, &args),
        Some("serve") => cmd_serve(&manifest, &args),
        _ => {
            eprintln!(
                "usage: catquant <info|exp|quantize|plan|eval|serve> [...]\n(see README / crate docs)"
            );
            Ok(())
        }
    }
}

/// `catquant plan`: search for the best per-group (recipe, bits) plan
/// under a byte or latency budget, print the decision table and the
/// searched-vs-uniform comparison, optionally save the built artifact.
fn cmd_plan(args: &Args) -> Result<()> {
    use catquant::pipeline::{
        best_uniform_plan, measured_plan_sqnr_db, search_plan, Budget, Objective, PlannerCfg,
    };

    let budget = if let Some(mb) = args.flag("budget-mb") {
        let mb: f64 = mb.parse().context("parsing --budget-mb")?;
        Budget::Size { max_bytes: (mb * 1024.0 * 1024.0) as usize }
    } else if let Some(kb) = args.flag("budget-kb") {
        let kb: f64 = kb.parse().context("parsing --budget-kb")?;
        Budget::Size { max_bytes: (kb * 1024.0) as usize }
    } else if let Some(us) = args.flag("latency-us") {
        let us: f64 = us.parse().context("parsing --latency-us")?;
        Budget::Latency { max_us_per_tok: us }
    } else {
        bail!("plan needs a budget: --budget-mb N, --budget-kb N, or --latency-us F");
    };
    let mut cfg = PlannerCfg::new(budget);
    cfg.seed = args.u64_flag("seed", 0);
    cfg.quantizer = parse_wquant(args.flag("wquant").unwrap_or("rtn"))?;
    cfg.cat_block = args.usize_flag("cat-block", cfg.cat_block);
    if let Some(o) = args.flag("objective") {
        cfg.objective = Objective::from_name(o)
            .with_context(|| format!("unknown --objective {o} (want sqnr or ppl-proxy)"))?;
    }
    if let Some(b) = args.flag("bits") {
        cfg.weight_bits = b
            .split(',')
            .map(|s| s.trim().parse::<u32>().with_context(|| format!("parsing --bits item {s:?}")))
            .collect::<Result<_>>()?;
    }
    if let Some(r) = args.flag("recipes") {
        cfg.recipes = r.split(',').map(|s| s.trim().to_string()).collect();
    }

    // Model + calibration: --synthetic builds a tiny random model with a
    // seeded calibration set (the hermetic CI path); otherwise load the
    // zoo model from the artifact manifest.
    let (model, calib) = if args.flag("synthetic").is_some() {
        let mcfg = catquant::model::ModelConfig {
            name: "synthetic".into(),
            d: 32,
            n_layers: 2,
            n_heads: 4,
            ff: 64,
            seq: 16,
            vocab: 256,
        };
        let model = catquant::model::NativeModel::init_random(mcfg, cfg.seed ^ 0x51);
        let mut rng = catquant::linalg::Rng::new(cfg.seed ^ 5);
        let seqs: Vec<Vec<u8>> =
            (0..8).map(|_| (0..16).map(|_| rng.below(256) as u8).collect()).collect();
        let calib = catquant::calib::calibrate(&model, &seqs, 256, cfg.seed);
        (model, calib)
    } else {
        let manifest = Manifest::load(&Manifest::default_dir()).context(
            "loading artifact manifest (run `make artifacts`, or use --synthetic)",
        )?;
        let zoo = exp::load_zoo(&manifest, args.flag("model").unwrap_or("small"), cfg.seed)?;
        (zoo.model, zoo.calib)
    };

    let t0 = std::time::Instant::now();
    let planned = search_plan(&model, &calib, &cfg)?;
    let search_s = t0.elapsed().as_secs_f64();
    println!(
        "searched {} recipes x {} bit-widths over {} groups in {search_s:.1}s (objective={})",
        if cfg.recipes.is_empty() {
            catquant::transforms::recipe_names().len()
        } else {
            cfg.recipes.len()
        },
        cfg.weight_bits.len(),
        planned.decisions.len(),
        cfg.objective.name(),
    );
    let rows: Vec<Vec<String>> = planned
        .decisions
        .iter()
        .map(|d| {
            vec![
                d.group.key().to_string(),
                d.cell.recipe.clone(),
                format!("W{}A{}", d.cell.w_bits, d.cell.a_bits),
                d.cell.bytes.to_string(),
                format!("{:.1}", d.cell.score_db),
            ]
        })
        .collect();
    exp::print_table(&["group", "recipe", "bits", "bytes", "approx dB"], &rows);
    println!(
        "  budget: {} B, planned: {} B, total approx: {:.1} dB",
        planned.budget_bytes, planned.total_bytes, planned.score_db
    );

    // Searched vs uniform, on *measured* SQNR over the calibration set.
    let (qc, rep) = planned.build(&model, &calib)?;
    let mut cmp = vec![vec![
        "searched".to_string(),
        qc.packed_bytes().to_string(),
        format!("{:.2}", measured_plan_sqnr_db(&model, &calib, &qc)),
    ]];
    for base in ["identity", "cat-block"] {
        if let Some((b, up)) = best_uniform_plan(&model, &cfg, base) {
            let (uqc, _) = build_quant_config(&model, &calib, &up)?;
            cmp.push(vec![
                format!("uniform {base} W{b}"),
                uqc.packed_bytes().to_string(),
                format!("{:.2}", measured_plan_sqnr_db(&model, &calib, &uqc)),
            ]);
        }
    }
    exp::print_table(&["plan", "packed bytes", "measured dB"], &cmp);

    if let Some(dir) = args.flag("save-artifact") {
        let dir = std::path::Path::new(dir);
        save_artifact(&qc, &rep, dir)?;
        println!(
            "  artifact saved to {} (search provenance echoed in the manifest; \
             serve with `catquant serve --engine native --artifact ...`)",
            dir.display()
        );
    }
    Ok(())
}

fn cmd_info(manifest: &Manifest) -> Result<()> {
    println!("artifacts: {}", manifest.dir.display());
    println!(
        "corpus: train={} eval={} vocab={}",
        manifest.corpus_train.display(),
        manifest.corpus_eval.display(),
        manifest.vocab
    );
    for (name, m) in &manifest.models {
        println!(
            "model {name}: d={} L={} heads={} ff={} seq={} params={} graphs=[{}]",
            m.config.d,
            m.config.n_layers,
            m.config.n_heads,
            m.config.ff,
            m.config.seq,
            m.config.n_params(),
            m.graphs.keys().cloned().collect::<Vec<_>>().join(",")
        );
    }
    Ok(())
}

fn cmd_exp(manifest: &Manifest, args: &Args) -> Result<()> {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let models_s = args.flag("models").unwrap_or("tiny,small");
    let models: Vec<&str> = models_s.split(',').collect();
    let seed = args.u64_flag("seed", 0);
    match which {
        "fig2" => {
            exp::run_fig2(manifest, &models, seed)?;
        }
        "fig3" => {
            exp::run_fig3(manifest, models.first().copied().unwrap_or("small"), seed)?;
        }
        "fig4" => {
            exp::run_fig4(manifest, &models, seed)?;
        }
        "fig5" => {
            exp::run_fig5(manifest, &models, seed)?;
        }
        "fig6" => {
            exp::run_fig6(manifest, &models, seed)?;
        }
        "ablations" => {
            exp::run_ablations(manifest, models.first().copied().unwrap_or("small"), seed)?;
        }
        "table1" => {
            let mut opts = if args.flag("quick").is_some() {
                exp::Table1Opts::quick()
            } else {
                exp::Table1Opts::default()
            };
            if let Some(m) = args.flag("models") {
                opts.models = m.split(',').map(|s| s.to_string()).collect();
            }
            opts.seeds = args.u64_flag("seeds", opts.seeds);
            opts.eval_windows = args.usize_flag("windows", opts.eval_windows);
            opts.task_items = args.usize_flag("items", opts.task_items);
            exp::run_table1(manifest, &opts)?;
        }
        "all" => {
            exp::run_fig2(manifest, &models, seed)?;
            exp::run_fig3(manifest, models.first().copied().unwrap_or("small"), seed)?;
            exp::run_fig4(manifest, &models, seed)?;
            exp::run_fig5(manifest, &models, seed)?;
            exp::run_fig6(manifest, &models, seed)?;
        }
        other => bail!("unknown experiment {other}"),
    }
    Ok(())
}

fn cmd_quantize(manifest: &Manifest, args: &Args) -> Result<()> {
    let model = args.flag("model").unwrap_or("small");
    let kind = parse_kind(args.flag("transform").unwrap_or("cat"))?;
    let wq = parse_wquant(args.flag("wquant").unwrap_or("rtn"))?;
    let seed = args.u64_flag("seed", 0);
    let zoo = exp::load_zoo(manifest, model, seed)?;
    let t0 = std::time::Instant::now();
    let (qc, rep) =
        build_quant_config(&zoo.model, &zoo.calib, &PipelineCfg::w4a4(kind, wq, seed).plan())?;
    println!(
        "quantized {model} with {} + {} in {:.1}s",
        kind.name(),
        wq.name(),
        t0.elapsed().as_secs_f64()
    );
    println!("  mean layer SQNR (approx): {:.1} dB", rep.mean_sqnr_db);
    println!("  activation clip ratio:    {:.2}", rep.act_clip);
    println!(
        "  transforms: {}  packed linears: {} ({:.1} KiB packed weight storage)",
        qc.transforms.len(),
        qc.linears.len(),
        qc.packed_bytes() as f64 / 1024.0
    );
    if let Some((name, ms)) = rep
        .transform_ms
        .iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    {
        println!("  slowest transform build: {name} ({ms:.1} ms)");
    }
    if let Some(dir) = args.flag("save-artifact") {
        let dir = std::path::Path::new(dir);
        let t0 = std::time::Instant::now();
        save_artifact(&qc, &rep, dir)?;
        println!(
            "  artifact saved to {} in {:.0} ms (serve with `catquant serve --engine native --artifact ...`)",
            dir.display(),
            t0.elapsed().as_secs_f64() * 1e3
        );
    }
    Ok(())
}

fn cmd_eval(manifest: &Manifest, args: &Args) -> Result<()> {
    let model = args.flag("model").unwrap_or("small");
    let kind_s = args.flag("transform").unwrap_or("cat");
    let wq = parse_wquant(args.flag("wquant").unwrap_or("rtn"))?;
    let seed = args.u64_flag("seed", 0);
    let n_windows = args.usize_flag("windows", 24);
    let items = args.usize_flag("items", 12);

    let engine = Rc::new(PjrtEngine::new(manifest.clone())?);
    let entry = manifest.model(model)?;
    let corpus = Corpus::load(&manifest.corpus_eval)?;
    let windows = corpus.eval_windows(n_windows, entry.config.seq);
    let zoo = exp::load_zoo(manifest, model, seed)?;

    if kind_s == "fp" {
        let eng = PjrtLogits::fp(engine, model, &zoo.model.params)?;
        let ppl = perplexity(&eng, &windows)?;
        let tasks = zero_shot_suite(&eng, &corpus, items, seed)?;
        report_eval(model, "FP", ppl, &tasks);
        return Ok(());
    }
    let kind = parse_kind(kind_s)?;
    let (qc, _) =
        build_quant_config(&zoo.model, &zoo.calib, &PipelineCfg::w4a4(kind, wq, seed).plan())?;
    let eng = PjrtLogits::quant(engine, model, &zoo.model.params, &qc, 4)?;
    let ppl = perplexity(&eng, &windows)?;
    let tasks = zero_shot_suite(&eng, &corpus, items, seed)?;
    report_eval(model, kind.name(), ppl, &tasks);
    Ok(())
}

fn report_eval(model: &str, label: &str, ppl: f64, tasks: &[catquant::eval::TaskResult]) {
    println!("model={model} config={label}");
    println!("  perplexity: {ppl:.3}");
    for t in tasks {
        println!("  task {:<10} acc {:.1}%", t.name, 100.0 * t.accuracy);
    }
    let mean = 100.0 * tasks.iter().map(|t| t.accuracy).sum::<f64>() / tasks.len() as f64;
    println!("  0-shot avg: {mean:.1}%");
}

/// Quantization state for native serving: a prebuilt artifact boots in
/// milliseconds; a missing/stale one falls back to a fresh cat-block
/// W4A4 build (saved back when an artifact dir was given and empty). The
/// on-disk artifact is the user's — never overwritten. Crash-only boot:
/// a transiently unreadable artifact is retried with backoff before the
/// recalibration fallback kicks in.
fn native_quant_config(
    manifest: &Manifest,
    model: &str,
    native: &catquant::model::NativeModel,
    artifact: Option<&std::path::Path>,
    seed: u64,
    chaos: &Chaos,
    bits: Option<u32>,
) -> catquant::model::QuantConfig {
    // A brownout (degraded) plan lives in a bit-width-keyed subdirectory
    // of the same artifact dir, so full and degraded builds share one
    // location and neither clobbers the other.
    let degraded_dir = bits.and_then(|b| artifact.map(|d| brownout_dir(d, b)));
    let artifact = degraded_dir.as_deref().or(artifact);
    if let Some(dir) = artifact {
        if dir.join("artifact.json").exists() {
            let t0 = std::time::Instant::now();
            match load_artifact_retry(dir, native, 3, std::time::Duration::from_millis(50), chaos)
            {
                Ok(qc) => {
                    eprintln!(
                        "[serve] loaded artifact {} in {:.0} ms (no calibration run)",
                        dir.display(),
                        t0.elapsed().as_secs_f64() * 1e3
                    );
                    return qc;
                }
                Err(e) => {
                    eprintln!(
                        "[serve] artifact {} unusable ({e}); serving a fresh \
                         cat-block W4A4 build (artifact left untouched)",
                        dir.display()
                    );
                }
            }
        }
    }
    let zoo = exp::load_zoo(manifest, model, seed).expect("zoo");
    let mut cfg = PipelineCfg::w4a4(TransformKind::CatBlock, WeightQuantizer::Rtn, seed);
    if let Some(b) = bits {
        cfg.bits_w = b;
        cfg.bits_a = b;
    }
    let (qc, rep) = build_quant_config(&zoo.model, &zoo.calib, &cfg.plan()).expect("pipeline");
    if let Some(dir) = artifact {
        if !dir.join("artifact.json").exists() {
            save_artifact(&qc, &rep, dir).expect("save artifact");
            eprintln!("[serve] built + saved artifact to {}", dir.display());
        }
    }
    qc
}

fn cmd_serve(manifest: &Manifest, args: &Args) -> Result<()> {
    let model = args.flag("model").unwrap_or("small").to_string();
    let mode = args.flag("mode").unwrap_or("fp").to_string();
    let engine_kind = args.flag("engine").unwrap_or("pjrt").to_string();
    let artifact = args.flag("artifact").map(std::path::PathBuf::from);
    let n_requests = args.usize_flag("requests", 16);
    let max_new = args.usize_flag("max-new", 24);
    let temperature: f64 = args
        .flag("temperature")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.8);
    let seed = args.u64_flag("seed", 0);
    // Continuous-batching knobs (native engine only).
    let continuous = args.flag("continuous").map(|v| v != "false").unwrap_or(false);
    let page_rows = args.usize_flag("page-rows", catquant::model::DEFAULT_PAGE_ROWS);
    let kv_budget_mb = args.usize_flag("kv-budget-mb", 64);
    let prefix_sharing = args.flag("prefix-sharing").map(|v| v != "false").unwrap_or(true);
    let max_queue = args.usize_flag("max-queue", 256);
    let admit_watermark: f64 =
        args.flag("admit-watermark").and_then(|v| v.parse().ok()).unwrap_or(0.9);
    // Per-request serve-by deadline (0/absent = none).
    let deadline = match args.u64_flag("deadline-ms", 0) {
        0 => None,
        ms => Some(std::time::Duration::from_millis(ms)),
    };
    // Deterministic fault injection: --chaos SPEC wins over CATQUANT_CHAOS.
    let chaos = match args.flag("chaos") {
        Some(spec) => Chaos::parse(spec)?,
        None => Chaos::from_env()?,
    };
    anyhow::ensure!(
        engine_kind == "pjrt" || engine_kind == "native",
        "unknown --engine {engine_kind} (expected pjrt or native)"
    );
    anyhow::ensure!(
        !(mode == "fp" && artifact.is_some()),
        "--artifact has no effect with --mode fp; drop the flag or pick a quantized mode"
    );
    anyhow::ensure!(
        !continuous || engine_kind == "native",
        "--continuous requires --engine native (the step-granular path)"
    );

    // Replicated-serving knobs: N health-checked replicas, hedged
    // stragglers, precision brownout under overload. Any of them routes
    // through the replica pool (native step engines only).
    let replicas = args.usize_flag("replicas", 1);
    let hedge_after = match args.u64_flag("hedge-ms", 0) {
        0 => None,
        ms => Some(std::time::Duration::from_millis(ms)),
    };
    let brownout_bits = args.usize_flag("brownout-bits", 0) as u32;
    let brownout_watermark: f64 =
        args.flag("brownout-watermark").and_then(|v| v.parse().ok()).unwrap_or(0.75);
    let replicated = replicas > 1 || hedge_after.is_some() || brownout_bits > 0;
    anyhow::ensure!(
        !replicated || engine_kind == "native",
        "--replicas/--hedge-ms/--brownout-bits require --engine native"
    );
    anyhow::ensure!(
        brownout_bits == 0 || (1..=8).contains(&brownout_bits),
        "--brownout-bits must be 1..=8"
    );
    anyhow::ensure!(
        brownout_bits == 0 || mode != "fp",
        "--brownout-bits needs a quantized --mode (fp has no lower-precision fallback)"
    );
    if replicated {
        return serve_replicated(
            manifest,
            args,
            ServeReplicatedCfg {
                model,
                mode,
                artifact,
                n_requests,
                max_new,
                temperature,
                seed,
                page_rows,
                kv_budget_mb,
                prefix_sharing,
                max_queue,
                admit_watermark,
                deadline,
                replicas,
                hedge_after,
                brownout_bits,
                brownout_watermark,
            },
        );
    }

    let manifest2 = manifest.clone();
    let model2 = model.clone();
    let mode2 = mode.clone();
    let batcher_cfg = BatcherCfg::default();
    let max_batch = batcher_cfg.max_batch;
    let mut coord = if continuous {
        let pool_cfg = KvPoolCfg { page_rows, budget_bytes: kv_budget_mb << 20 };
        let artifact2 = artifact.clone();
        let chaos2 = chaos.clone();
        Coordinator::start_continuous(
            move || {
                let sampling = SamplingCfg { temperature, seed };
                let native = exp::load_model(&manifest2, &model2).expect("model");
                let g = if mode2 == "fp" {
                    NativeGenerator::fp(native, max_batch, sampling)
                } else {
                    let qc = native_quant_config(
                        &manifest2,
                        &model2,
                        &native,
                        artifact2.as_deref(),
                        seed,
                        &chaos2,
                        None,
                    );
                    NativeGenerator::quant(native, qc, max_batch, sampling)
                };
                Box::new(g.with_serve_pool(pool_cfg, prefix_sharing).with_chaos(chaos2.clone()))
                    as Box<dyn StepEngine>
            },
            ContinuousCfg { max_queue, admit_watermark, ..Default::default() },
        )
    } else {
        let chaos2 = chaos.clone();
        Coordinator::start(
            move || {
                let sampling = SamplingCfg { temperature, seed };
                // Weights load without a calibration pass; only a pipeline
                // (re)build pays calibration — the cost artifacts exist to
                // keep off the boot path.
                let native = exp::load_model(&manifest2, &model2).expect("model");
                let gen: Box<dyn GenEngine> = match (engine_kind.as_str(), mode2 == "fp") {
                    ("native", true) => {
                        Box::new(NativeGenerator::fp(native, max_batch, sampling))
                    }
                    ("native", false) => {
                        let qc = native_quant_config(
                            &manifest2,
                            &model2,
                            &native,
                            artifact.as_deref(),
                            seed,
                            &chaos2,
                            None,
                        );
                        Box::new(NativeGenerator::quant(native, qc, max_batch, sampling))
                    }
                    (_, true) => {
                        let engine =
                            Rc::new(PjrtEngine::new(manifest2.clone()).expect("engine"));
                        Box::new(
                            PjrtGenerator::fp(engine, &model2, &native.params, sampling)
                                .expect("generator"),
                        )
                    }
                    (_, false) => {
                        let engine =
                            Rc::new(PjrtEngine::new(manifest2.clone()).expect("engine"));
                        let qc = native_quant_config(
                            &manifest2,
                            &model2,
                            &native,
                            artifact.as_deref(),
                            seed,
                            &chaos2,
                            None,
                        );
                        Box::new(
                            PjrtGenerator::quant(engine, &model2, &native.params, &qc, sampling)
                                .expect("generator"),
                        )
                    }
                };
                gen
            },
            batcher_cfg,
        )
    };

    // Open-loop synthetic client: prompts drawn from the eval corpus.
    let corpus = Corpus::load(&manifest.corpus_eval)?;
    let prompts = corpus.sample_sequences(n_requests, manifest.prompt_len, seed ^ 0xC11E17);
    let sched = if continuous { "continuous" } else { "static" };
    println!(
        "serving {n_requests} requests (model={model} mode={mode} max_new={max_new} scheduler={sched}) ..."
    );
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = prompts
        .into_iter()
        .map(|p| coord.submit_with_deadline(p, max_new, deadline))
        .collect();
    let mut rejected = 0usize;
    let mut expired = 0usize;
    let mut failed = 0usize;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv()?;
        match resp.status {
            catquant::coordinator::GenStatus::Ok => {}
            catquant::coordinator::GenStatus::Rejected => {
                rejected += 1;
                continue;
            }
            catquant::coordinator::GenStatus::Expired => {
                expired += 1;
                continue;
            }
            catquant::coordinator::GenStatus::Failed => {
                failed += 1;
                continue;
            }
        }
        if i < 3 {
            println!(
                "  req {i}: {} tokens in {:?} (batch={}) -> {:?}...",
                resp.tokens.len(),
                resp.latency,
                resp.batch_size,
                &resp.tokens[..resp.tokens.len().min(8)]
            );
        }
    }
    if rejected > 0 {
        println!("  {rejected} requests rejected by backpressure");
    }
    if expired > 0 {
        println!("  {expired} requests expired at their deadline");
    }
    if failed > 0 {
        println!("  {failed} requests lost to engine failures");
    }
    let wall = t0.elapsed();
    let metrics = coord.shutdown();
    println!("wall time: {wall:?}");
    println!("{}", metrics.summary());
    Ok(())
}

/// Parsed knobs for the replicated serve path (one struct so the
/// hand-rolled CLI doesn't thread seventeen positional parameters).
struct ServeReplicatedCfg {
    model: String,
    mode: String,
    artifact: Option<std::path::PathBuf>,
    n_requests: usize,
    max_new: usize,
    temperature: f64,
    seed: u64,
    page_rows: usize,
    kv_budget_mb: usize,
    prefix_sharing: bool,
    max_queue: usize,
    admit_watermark: f64,
    deadline: Option<std::time::Duration>,
    replicas: usize,
    hedge_after: Option<std::time::Duration>,
    brownout_bits: u32,
    brownout_watermark: f64,
}

/// Serve through the replicated pool: health-checked replicas, hedged
/// stragglers, precision brownout. Native step engines only.
fn serve_replicated(manifest: &Manifest, args: &Args, cfg: ServeReplicatedCfg) -> Result<()> {
    let ServeReplicatedCfg {
        model,
        mode,
        artifact,
        n_requests,
        max_new,
        temperature,
        seed,
        page_rows,
        kv_budget_mb,
        prefix_sharing,
        max_queue,
        admit_watermark,
        deadline,
        replicas,
        hedge_after,
        brownout_bits,
        brownout_watermark,
    } = cfg;
    // One chaos handle per replica, created up front and shared across
    // that replica's respawns (one-shot faults stay one-shot). Scoped
    // clauses (`panic_seq@r1=...`) bind to their replica here; `--chaos`
    // parses strictly, the env var leniently (warn + skip bad clauses).
    let chaos_handles: Vec<Chaos> = (0..replicas.max(1))
        .map(|r| match args.flag("chaos") {
            Some(spec) => Chaos::parse_scoped(spec, Some(r)),
            None => match std::env::var("CATQUANT_CHAOS") {
                Ok(s) if !s.trim().is_empty() => Ok(Chaos::parse_lenient(&s, Some(r))),
                _ => Ok(Chaos::off()),
            },
        })
        .collect::<Result<_>>()?;

    let pool_cfg = KvPoolCfg { page_rows, budget_bytes: kv_budget_mb << 20 };
    let max_batch = BatcherCfg::default().max_batch;
    let rep_cfg = ReplicaCfg {
        replicas,
        scheduler: ContinuousCfg { max_queue, admit_watermark, ..Default::default() },
        hedge_after,
        brownout: (brownout_bits > 0).then(|| BrownoutCfg {
            watermark: brownout_watermark,
            ..Default::default()
        }),
        ..Default::default()
    };
    let manifest2 = manifest.clone();
    let model2 = model.clone();
    let mode2 = mode.clone();
    let mut pool = ReplicaPool::start(
        move |r, plan| {
            let sampling = SamplingCfg { temperature, seed };
            let native = exp::load_model(&manifest2, &model2).expect("model");
            let chaos = chaos_handles[r].clone();
            let g = if mode2 == "fp" {
                NativeGenerator::fp(native, max_batch, sampling)
            } else {
                let bits = match plan {
                    ServePlan::Degraded => Some(brownout_bits),
                    ServePlan::Full => None,
                };
                let qc = native_quant_config(
                    &manifest2,
                    &model2,
                    &native,
                    artifact.as_deref(),
                    seed,
                    &chaos,
                    bits,
                );
                NativeGenerator::quant(native, qc, max_batch, sampling)
            };
            Box::new(g.with_serve_pool(pool_cfg, prefix_sharing).with_chaos(chaos))
                as Box<dyn StepEngine>
        },
        rep_cfg,
    );

    let corpus = Corpus::load(&manifest.corpus_eval)?;
    let prompts = corpus.sample_sequences(n_requests, manifest.prompt_len, seed ^ 0xC11E17);
    println!(
        "serving {n_requests} requests (model={model} mode={mode} max_new={max_new} \
         replicas={replicas} hedge_ms={} brownout_bits={brownout_bits}) ...",
        hedge_after.map_or(0, |d| d.as_millis())
    );
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = prompts
        .into_iter()
        .map(|p| pool.submit_with_deadline(p, max_new, deadline))
        .collect();
    let (mut rejected, mut expired, mut failed, mut degraded) = (0usize, 0usize, 0usize, 0usize);
    for rx in rxs {
        let resp = rx.recv()?;
        if resp.is_ok() && resp.plan == ServePlan::Degraded {
            degraded += 1;
        }
        match resp.status {
            catquant::coordinator::GenStatus::Ok => {}
            catquant::coordinator::GenStatus::Rejected => rejected += 1,
            catquant::coordinator::GenStatus::Expired => expired += 1,
            catquant::coordinator::GenStatus::Failed => failed += 1,
        }
    }
    if rejected > 0 {
        println!("  {rejected} requests rejected by backpressure");
    }
    if expired > 0 {
        println!("  {expired} requests expired at their deadline");
    }
    if failed > 0 {
        println!("  {failed} requests lost to engine failures");
    }
    if degraded > 0 {
        println!("  {degraded} requests served on the brownout plan");
    }
    let wall = t0.elapsed();
    pool.shutdown();
    println!("wall time: {wall:?}");
    println!("{}", pool.summary());
    Ok(())
}
