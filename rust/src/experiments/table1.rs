//! Table 1: end-to-end W4A4 comparison — perplexity + zero-shot accuracy,
//! models × {RTN, GPTQ} × transforms, mean ± std over seeds.
//!
//! Each cell runs the full pipeline (calibrate → transform → quantize)
//! and evaluates through the AOT-compiled PJRT graphs — the same
//! serving-path executables, so the numbers measure what a deployment
//! would see.

use super::common::{load_zoo, mean_std, print_table};
use crate::calib::Corpus;
use crate::eval::{perplexity, zero_shot_suite, PjrtLogits, SeqLogits};
use crate::pipeline::{build_quant_config, PipelineCfg, WeightQuantizer};
use crate::runtime::{Manifest, PjrtEngine};
use crate::transforms::TransformKind;
use anyhow::Result;
use std::rc::Rc;

/// One Table 1 cell (already aggregated over seeds).
#[derive(Clone, Debug)]
pub struct Table1Cell {
    pub model: String,
    pub quantizer: &'static str,
    pub transform: String,
    pub ppl_mean: f64,
    pub ppl_std: f64,
    pub acc_mean: f64,
    pub acc_std: f64,
}

/// Grid options.
#[derive(Clone, Debug)]
pub struct Table1Opts {
    pub models: Vec<String>,
    pub seeds: u64,
    pub eval_windows: usize,
    pub task_items: usize,
    pub quantizers: Vec<WeightQuantizer>,
}

impl Default for Table1Opts {
    fn default() -> Self {
        Table1Opts {
            models: vec!["tiny".into(), "small".into(), "base".into()],
            seeds: 4,
            eval_windows: 24,
            task_items: 12,
            quantizers: vec![WeightQuantizer::Rtn, WeightQuantizer::Gptq],
        }
    }
}

impl Table1Opts {
    pub fn quick() -> Table1Opts {
        Table1Opts {
            models: vec!["tiny".into(), "small".into()],
            seeds: 2,
            eval_windows: 8,
            task_items: 6,
            quantizers: vec![WeightQuantizer::Rtn],
        }
    }
}

pub fn run_table1(manifest: &Manifest, opts: &Table1Opts) -> Result<Vec<Table1Cell>> {
    let engine = Rc::new(PjrtEngine::new(manifest.clone())?);
    let eval_corpus = Corpus::load(&manifest.corpus_eval)?;
    let mut cells = Vec::new();

    for mname in &opts.models {
        let entry = manifest.model(mname)?;
        let windows = eval_corpus.eval_windows(opts.eval_windows, entry.config.seq);
        eprintln!("[table1] model {mname}: FP reference ...");

        // FP row (seed-independent).
        let zoo0 = load_zoo(manifest, mname, 0)?;
        let fp_engine = PjrtLogits::fp(engine.clone(), mname, &zoo0.model.params)?;
        let fp_ppl = perplexity(&fp_engine, &windows)?;
        let fp_acc = mean_acc(&fp_engine, &eval_corpus, opts.task_items, 0)?;
        cells.push(Table1Cell {
            model: mname.clone(),
            quantizer: "—",
            transform: "FP".into(),
            ppl_mean: fp_ppl,
            ppl_std: 0.0,
            acc_mean: fp_acc,
            acc_std: 0.0,
        });

        for &wq in &opts.quantizers {
            for &kind in TransformKind::table1_rows() {
                let mut ppls = Vec::new();
                let mut accs = Vec::new();
                for seed in 0..opts.seeds {
                    // Seed affects calibration draw + rotation seeds.
                    let zoo = if seed == 0 {
                        None // reuse zoo0 below
                    } else {
                        Some(load_zoo(manifest, mname, seed)?)
                    };
                    let z = zoo.as_ref().unwrap_or(&zoo0);
                    let (qc, _rep) = build_quant_config(
                        &z.model,
                        &z.calib,
                        &PipelineCfg::w4a4(kind, wq, seed).plan(),
                    )?;
                    let qeng =
                        PjrtLogits::quant(engine.clone(), mname, &z.model.params, &qc, 4)?;
                    ppls.push(perplexity(&qeng, &windows)?);
                    accs.push(mean_acc(&qeng, &eval_corpus, opts.task_items, seed)?);
                }
                let (pm, ps) = mean_std(&ppls);
                let (am, asd) = mean_std(&accs);
                eprintln!(
                    "[table1] {mname} {} {}: ppl {pm:.2}±{ps:.2} acc {am:.1}±{asd:.1}",
                    wq.name(),
                    kind.name()
                );
                cells.push(Table1Cell {
                    model: mname.clone(),
                    quantizer: wq.name(),
                    transform: kind.name().into(),
                    ppl_mean: pm,
                    ppl_std: ps,
                    acc_mean: am,
                    acc_std: asd,
                });
            }
        }
    }
    print_table1(&cells);
    Ok(cells)
}

/// Average zero-shot accuracy (%) across the six tasks — the same items
/// for every config at a given seed (paired, like a fixed benchmark).
fn mean_acc(
    engine: &dyn SeqLogits,
    corpus: &Corpus,
    items: usize,
    seed: u64,
) -> Result<f64> {
    let res = zero_shot_suite(engine, corpus, items, seed ^ 0x7A5)?;
    Ok(100.0 * res.iter().map(|r| r.accuracy).sum::<f64>() / res.len() as f64)
}

fn print_table1(cells: &[Table1Cell]) {
    println!("\n== Table 1: W4A4 perplexity (↓) and 0-shot accuracy (↑) ==");
    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            vec![
                c.model.clone(),
                c.quantizer.to_string(),
                c.transform.clone(),
                format!("{:.2}±{:.2}", c.ppl_mean, c.ppl_std),
                format!("{:.1}±{:.1}", c.acc_mean, c.acc_std),
            ]
        })
        .collect();
    print_table(&["model", "wquant", "transform", "ppl", "0-shot avg %"], &rows);
}
