//! Figure 3: the activation-SQNR × weight-SQNR plane with iso-joint-SQNR
//! structure.
//!
//! For each layer of one model, measure `SQNR(Wx̃)` (activation-only) and
//! `SQNR(W̃x)` (weight-only) at bit widths {4, 6, 8} each, and report how
//! the joint SQNR follows the harmonic sum — including the paper's
//! observation that raising the bit width of the *better* side barely
//! moves the joint (the `r(x,W) < 1` regime).

use super::common::{load_layers, load_zoo, mean_std, print_table};
use crate::quant::{ActQuantCfg, QScheme, WeightQuantCfg};
use crate::runtime::Manifest;
use crate::sqnr::{db, measured_sqnr_act_only, measured_sqnr_joint, measured_sqnr_weight_only};
use anyhow::Result;

pub fn run_fig3(manifest: &Manifest, model: &str, seed: u64) -> Result<()> {
    let zoo = load_zoo(manifest, model, seed)?;
    let layers = load_layers(&zoo);
    println!("\n== Figure 3: activation vs weight SQNR plane ({model}) ==");

    let bit_grid = [4u32, 6, 8];
    let mut rows = Vec::new();
    // Per (ba, bw): mean over layers of act-only, weight-only, joint.
    for &ba in &bit_grid {
        for &bw in &bit_grid {
            let act = ActQuantCfg { scheme: QScheme::asym(ba), clip_ratio: 1.0 };
            let wq = WeightQuantCfg::minmax(bw);
            let mut a_dbs = Vec::new();
            let mut w_dbs = Vec::new();
            let mut j_dbs = Vec::new();
            for l in &layers {
                a_dbs.push(db(measured_sqnr_act_only(&l.x, &l.w, act)));
                w_dbs.push(db(measured_sqnr_weight_only(&l.x, &l.w, wq)));
                j_dbs.push(db(measured_sqnr_joint(&l.x, &l.w, act, wq)));
            }
            let (am, _) = mean_std(&a_dbs);
            let (wm, _) = mean_std(&w_dbs);
            let (jm, _) = mean_std(&j_dbs);
            rows.push(vec![
                format!("W{bw}A{ba}"),
                format!("{am:.1}"),
                format!("{wm:.1}"),
                format!("{jm:.1}"),
            ]);
        }
    }
    print_table(&["bits", "act-only dB", "weight-only dB", "joint dB"], &rows);

    // Paper §2.1: +4 weight bits ⇒ ≈ +24 dB horizontal shift.
    let act = ActQuantCfg { scheme: QScheme::asym(8), clip_ratio: 1.0 };
    let mut shifts = Vec::new();
    for l in &layers {
        let w4 = db(measured_sqnr_weight_only(&l.x, &l.w, WeightQuantCfg::minmax(4)));
        let w8 = db(measured_sqnr_weight_only(&l.x, &l.w, WeightQuantCfg::minmax(8)));
        shifts.push(w8 - w4);
        let _ = act;
    }
    let (sm, ss) = mean_std(&shifts);
    println!(
        "[fig3] weight-only shift for +4 bits: {sm:.1} ± {ss:.1} dB (paper: ≈24 dB)"
    );
    Ok(())
}
