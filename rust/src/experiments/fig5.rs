//! Figure 5: alignment per layer group under transforms, against the
//! achievable optimum (paper eq. 9).
//!
//! Expected shape: rotations (QuaRot) change nothing — exactly zero dB;
//! channel scaling helps a little on some layers; block CAT closes most
//! of the gap to the optimum; the full-rank CAT M̂ attains it.

use super::common::{load_zoo, mean_std, print_table};
use crate::linalg::Mat;
use crate::model::ALL_GROUPS;
use crate::pipeline::group_transform;
use crate::quant::{ActQuantCfg, QScheme, WeightQuantCfg};
use crate::runtime::Manifest;
use crate::sqnr::{alignment_data, db, max_alignment};
use crate::transforms::TransformKind;
use anyhow::Result;

/// One (group, transform) alignment measurement.
#[derive(Clone, Debug)]
pub struct Fig5Row {
    pub layer: String,
    pub transform: TransformKind,
    pub alignment_db: f64,
    pub max_alignment_db: f64,
}

const KINDS: [TransformKind; 5] = [
    TransformKind::None,
    TransformKind::SmoothQuant,
    TransformKind::QuaRot,
    TransformKind::CatBlock,
    TransformKind::CatOptimal,
];

pub fn run_fig5(manifest: &Manifest, models: &[&str], seed: u64) -> Result<Vec<Fig5Row>> {
    let act = ActQuantCfg { scheme: QScheme::asym(4), clip_ratio: 1.0 };
    let wq = WeightQuantCfg::minmax(4);
    let mut rows = Vec::new();
    for mname in models {
        let zoo = load_zoo(manifest, mname, seed)?;
        let cfg = &zoo.model.cfg;
        for block in 0..cfg.n_layers {
            for g in ALL_GROUPS {
                let stats = zoo.calib.sigma(&g.t_name(block));
                let x = stats.sample();
                let sigma_x = stats.sigma();
                let ws: Vec<&Mat> = g
                    .linears()
                    .iter()
                    .map(|lin| &zoo.model.params[&format!("blocks.{block}.{lin}")])
                    .collect();
                // Stack the group weights: alignment of the shared input
                // against the concatenated output heads (paper treats
                // shared-input layers as one multi-head linear).
                let w_all = vstack(&ws);
                let a_max = db(max_alignment(&sigma_x, &w_all));
                for kind in KINDS {
                    let t = group_transform(kind, &x, &sigma_x, &ws, act, wq, 128, seed);
                    let xt = t.apply_acts(&x);
                    let wt = t.fuse_weights(&w_all);
                    rows.push(Fig5Row {
                        layer: format!("{}.{}.{}", cfg.name, block, g.label()),
                        transform: kind,
                        alignment_db: db(alignment_data(&xt, &wt)),
                        max_alignment_db: a_max,
                    });
                }
            }
        }
    }
    print_fig5(&rows);
    Ok(rows)
}

fn vstack(ws: &[&Mat]) -> Mat {
    let cols = ws[0].cols();
    let rows: usize = ws.iter().map(|w| w.rows()).sum();
    let mut out = Mat::zeros(rows, cols);
    let mut r = 0;
    for w in ws {
        out.set_block(r, 0, w);
        r += w.rows();
    }
    out
}

fn print_fig5(rows: &[Fig5Row]) {
    println!("\n== Figure 5: alignment under transforms (dB; optimum = achievable) ==");
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.layer.clone(),
                r.transform.name().into(),
                format!("{:.2}", r.alignment_db),
                format!("{:.2}", r.max_alignment_db),
                format!("{:.2}", r.max_alignment_db - r.alignment_db),
            ]
        })
        .collect();
    print_table(&["layer group", "transform", "A dB", "A* dB", "headroom dB"], &table);

    println!("\n[fig5] per-transform mean headroom to optimum (lower = better):");
    for kind in KINDS {
        let sel: Vec<f64> = rows
            .iter()
            .filter(|r| r.transform == kind)
            .map(|r| r.max_alignment_db - r.alignment_db)
            .collect();
        let (m, s) = mean_std(&sel);
        println!("  {:<22} {:>6.2} ± {:.2} dB", kind.name(), m, s);
    }
    // Invariance check (paper eq. 4): QuaRot == None per layer.
    let mut max_dev: f64 = 0.0;
    let nones: Vec<&Fig5Row> =
        rows.iter().filter(|r| r.transform == TransformKind::None).collect();
    for n in &nones {
        if let Some(q) = rows
            .iter()
            .find(|r| r.transform == TransformKind::QuaRot && r.layer == n.layer)
        {
            max_dev = max_dev.max((q.alignment_db - n.alignment_db).abs());
        }
    }
    println!("[fig5] rotation alignment-invariance: max |Δ| = {max_dev:.4} dB (should be ≈0)");
}
