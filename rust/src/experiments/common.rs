//! Shared experiment plumbing: load the model zoo, calibrate, and expose
//! per-linear-layer (x, W) pairs.

use crate::calib::{calibrate, CalibStats, Corpus};
use crate::linalg::Mat;
use crate::model::{NativeModel, ALL_GROUPS};
use crate::runtime::Manifest;
use anyhow::Result;

/// Number of calibration sequences (matches the paper's 128).
pub const CALIB_SEQS: usize = 128;
/// Row budget retained per group for data-driven objectives.
pub const CALIB_SAMPLE_ROWS: usize = 2048;

/// A loaded + calibrated model.
pub struct ZooModel {
    pub model: NativeModel,
    pub calib: CalibStats,
}

/// One linear layer's analysis bundle.
pub struct LayerData {
    /// e.g. `small.blocks.2.down_proj`.
    pub name: String,
    /// Short layer kind, e.g. `down_proj`.
    pub kind: String,
    /// Group input sample (`tokens × d`, pre-transform).
    pub x: Mat,
    /// `Σ_x` of the group input.
    pub sigma_x: Mat,
    /// The weight (`out × d`).
    pub w: Mat,
}

/// Load one model's weights *without* running the calibration pass —
/// what artifact-booting servers and FP serving need (calibration is
/// exactly the startup cost artifacts exist to skip).
pub fn load_model(manifest: &Manifest, name: &str) -> Result<NativeModel> {
    let entry = manifest.model(name)?;
    NativeModel::from_catw(entry.config.clone(), &entry.weights)
}

/// Load one model and run the calibration pass.
pub fn load_zoo(manifest: &Manifest, name: &str, seed: u64) -> Result<ZooModel> {
    let entry = manifest.model(name)?;
    let model = load_model(manifest, name)?;
    let corpus = Corpus::load(&manifest.corpus_train)?;
    let seqs = corpus.sample_sequences(CALIB_SEQS, entry.config.seq, seed ^ 0xCA11B);
    let calib = calibrate(&model, &seqs, CALIB_SAMPLE_ROWS, seed);
    Ok(ZooModel { model, calib })
}

/// Flatten a calibrated model into per-linear-layer analysis bundles.
pub fn load_layers(zoo: &ZooModel) -> Vec<LayerData> {
    let cfg = &zoo.model.cfg;
    let mut out = Vec::new();
    for block in 0..cfg.n_layers {
        for g in ALL_GROUPS {
            let stats = zoo.calib.sigma(&g.t_name(block));
            let x = stats.sample();
            let sigma_x = stats.sigma();
            for lin in g.linears() {
                let pname = format!("blocks.{block}.{lin}");
                out.push(LayerData {
                    name: format!("{}.{}", cfg.name, pname),
                    kind: lin.to_string(),
                    x: x.clone(),
                    sigma_x: sigma_x.clone(),
                    w: zoo.model.params[&pname].clone(),
                });
            }
        }
    }
    out
}

/// Markdown-ish table printer used by every generator.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, c) in r.iter().enumerate() {
            widths[i] = widths[i].max(c.len());
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
        }
        println!("{s}");
    };
    line(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    println!(
        "|{}|",
        widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
    );
    for r in rows {
        line(r);
    }
}

/// mean ± std over replicate values.
pub fn mean_std(values: &[f64]) -> (f64, f64) {
    let n = values.len() as f64;
    let mean = values.iter().sum::<f64>() / n;
    if values.len() < 2 {
        return (mean, 0.0);
    }
    let var = values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}
