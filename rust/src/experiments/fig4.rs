//! Figure 4: concentration distributions of weights and activations under
//! different transforms, with Normal/Laplace reference levels.
//!
//! Expected shape (paper §3): raw activations sit at or below the Laplace
//! line (heavy tails / outliers); channel scaling improves activation
//! concentration at the cost of weight concentration; Hadamard and CAT
//! push both toward the Gaussian reference.

use super::common::{load_zoo, mean_std, print_table};
use crate::model::ALL_GROUPS;
use crate::pipeline::group_transform;
use crate::quant::{ActQuantCfg, QScheme, WeightQuantCfg};
use crate::runtime::Manifest;
use crate::sqnr::{concentration_act, concentration_weights, db, laplace_concentration, normal_concentration};
use crate::transforms::TransformKind;
use anyhow::Result;

/// One (model, group, transform) measurement.
#[derive(Clone, Debug)]
pub struct Fig4Row {
    pub layer: String,
    pub transform: TransformKind,
    pub c_act_db: f64,
    pub c_w_db: f64,
    pub normal_ref_db: f64,
    pub laplace_ref_db: f64,
}

const KINDS: [TransformKind; 4] = [
    TransformKind::None,
    TransformKind::SmoothQuant,
    TransformKind::QuaRot,
    TransformKind::CatBlock,
];

pub fn run_fig4(manifest: &Manifest, models: &[&str], seed: u64) -> Result<Vec<Fig4Row>> {
    let act = ActQuantCfg { scheme: QScheme::asym(4), clip_ratio: 1.0 };
    let wq = WeightQuantCfg::minmax(4);
    let mut rows = Vec::new();
    for mname in models {
        let zoo = load_zoo(manifest, mname, seed)?;
        let cfg = &zoo.model.cfg;
        for block in 0..cfg.n_layers {
            for g in ALL_GROUPS {
                let stats = zoo.calib.sigma(&g.t_name(block));
                let x = stats.sample();
                let sigma_x = stats.sigma();
                let ws: Vec<&crate::linalg::Mat> = g
                    .linears()
                    .iter()
                    .map(|lin| &zoo.model.params[&format!("blocks.{block}.{lin}")])
                    .collect();
                let d = g.dim(cfg);
                let n_ref = db(normal_concentration(d, act.scheme, 1024, 7));
                let l_ref = db(laplace_concentration(d, act.scheme, 1024, 7));
                for kind in KINDS {
                    let t = group_transform(kind, &x, &sigma_x, &ws, act, wq, 128, seed);
                    let xt = t.apply_acts(&x);
                    let mut ca = db(concentration_act(&xt, act));
                    // Average weight concentration across the group.
                    let mut cws = Vec::new();
                    for w in &ws {
                        cws.push(db(concentration_weights(&t.fuse_weights(w), wq)));
                    }
                    if !ca.is_finite() {
                        ca = 60.0;
                    }
                    rows.push(Fig4Row {
                        layer: format!("{}.{}.{}", cfg.name, block, g.label()),
                        transform: kind,
                        c_act_db: ca,
                        c_w_db: cws.iter().sum::<f64>() / cws.len() as f64,
                        normal_ref_db: n_ref,
                        laplace_ref_db: l_ref,
                    });
                }
            }
        }
    }
    print_fig4(&rows);
    Ok(rows)
}

fn print_fig4(rows: &[Fig4Row]) {
    println!("\n== Figure 4: concentration under transforms (dB; higher = better) ==");
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.layer.clone(),
                r.transform.name().into(),
                format!("{:.1}", r.c_act_db),
                format!("{:.1}", r.c_w_db),
                format!("{:.1}", r.normal_ref_db),
                format!("{:.1}", r.laplace_ref_db),
            ]
        })
        .collect();
    print_table(
        &["layer group", "transform", "C(x) dB", "C(W) dB", "Normal ref", "Laplace ref"],
        &table,
    );
    println!("\n[fig4] per-transform means:");
    for kind in KINDS {
        let sel: Vec<&Fig4Row> = rows.iter().filter(|r| r.transform == kind).collect();
        let (ca, _) = mean_std(&sel.iter().map(|r| r.c_act_db).collect::<Vec<_>>());
        let (cw, _) = mean_std(&sel.iter().map(|r| r.c_w_db).collect::<Vec<_>>());
        println!("  {:<22} C(x) {:>6.1} dB   C(W) {:>6.1} dB", kind.name(), ca, cw);
    }
}
