//! Figure 2: empirical verification of the Theorem 2.4 approximation.
//!
//! For every linear layer of the loaded models, at W4A4 / W4A8 / W8A8,
//! with and without a Hadamard transform, plot (print) measured joint
//! SQNR against the closed-form approximation. The paper's claim: the two
//! agree for almost all layers in the 5–50 dB band.

use super::common::{load_layers, load_zoo, print_table};
use crate::linalg::{hadamard_matrix, is_pow2, random_orthogonal, Rng};
use crate::quant::{ActQuantCfg, QScheme, WeightQuantCfg};
use crate::runtime::Manifest;
use crate::sqnr::{approx_sqnr_joint, db, measured_sqnr_joint};
use crate::transforms::Transform;
use anyhow::Result;

/// One scatter point.
#[derive(Clone, Debug)]
pub struct Fig2Point {
    pub layer: String,
    pub bits: (u32, u32),
    pub hadamard: bool,
    pub measured_db: f64,
    pub approx_db: f64,
}

pub fn run_fig2(manifest: &Manifest, models: &[&str], seed: u64) -> Result<Vec<Fig2Point>> {
    let mut points = Vec::new();
    for mname in models {
        let zoo = load_zoo(manifest, mname, seed)?;
        let layers = load_layers(&zoo);
        for layer in &layers {
            let d = layer.x.cols();
            let h = if is_pow2(d) {
                Transform::orthogonal("H", hadamard_matrix(d))
            } else {
                let mut rng = Rng::new(seed);
                Transform::orthogonal("R", random_orthogonal(d, &mut rng))
            };
            for &(ba, bw) in &[(4u32, 4u32), (8, 4), (8, 8)] {
                let act = ActQuantCfg { scheme: QScheme::asym(ba), clip_ratio: 1.0 };
                let wq = WeightQuantCfg::minmax(bw);
                for (hadamard, x, w) in [
                    (false, layer.x.clone(), layer.w.clone()),
                    (true, h.apply_acts(&layer.x), h.fuse_weights(&layer.w)),
                ] {
                    points.push(Fig2Point {
                        layer: layer.name.clone(),
                        bits: (bw, ba),
                        hadamard,
                        measured_db: db(measured_sqnr_joint(&x, &w, act, wq)),
                        approx_db: db(approx_sqnr_joint(&x, &w, act, wq)),
                    });
                }
            }
        }
    }
    print_fig2(&points);
    Ok(points)
}

fn print_fig2(points: &[Fig2Point]) {
    println!("\n== Figure 2: Theorem 2.4 approximation vs measured SQNR ==");
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.layer.clone(),
                format!("W{}A{}", p.bits.0, p.bits.1),
                if p.hadamard { "yes" } else { "no" }.into(),
                format!("{:.2}", p.measured_db),
                format!("{:.2}", p.approx_db),
                format!("{:+.2}", p.approx_db - p.measured_db),
            ]
        })
        .collect();
    print_table(
        &["layer", "bits", "hadamard", "measured dB", "approx dB", "err dB"],
        &rows,
    );
    // The figure's headline statistic.
    let in_band: Vec<&Fig2Point> =
        points.iter().filter(|p| p.measured_db > 5.0 && p.measured_db < 50.0).collect();
    let mean_abs: f64 = in_band
        .iter()
        .map(|p| (p.approx_db - p.measured_db).abs())
        .sum::<f64>()
        / in_band.len().max(1) as f64;
    let within3 = in_band
        .iter()
        .filter(|p| (p.approx_db - p.measured_db).abs() < 3.0)
        .count();
    println!(
        "\n[fig2] {} points in 5–50 dB band: mean |err| = {:.2} dB, {}/{} within 3 dB",
        in_band.len(),
        mean_abs,
        within3,
        in_band.len()
    );
}

/// Aggregate accuracy statistic for tests/benches.
#[allow(dead_code)]
pub fn fig2_mean_abs_err(points: &[Fig2Point]) -> f64 {
    let in_band: Vec<&Fig2Point> =
        points.iter().filter(|p| p.measured_db > 5.0 && p.measured_db < 50.0).collect();
    in_band.iter().map(|p| (p.approx_db - p.measured_db).abs()).sum::<f64>()
        / in_band.len().max(1) as f64
}
