//! Experiment generators: one module per paper figure/table.
//!
//! Each generator prints the figure's series/rows to stdout (the format
//! EXPERIMENTS.md records) and returns structured data so the criterion-
//! style benches in `benches/` can re-run them programmatically.

mod ablations;
mod common;
mod fig2;
mod fig3;
mod fig4;
mod fig5;
mod fig6;
mod table1;

pub use ablations::run_ablations;
pub use common::{load_layers, load_model, load_zoo, print_table, LayerData, ZooModel};
pub use fig2::{run_fig2, Fig2Point};
pub use fig3::run_fig3;
pub use fig4::{run_fig4, Fig4Row};
pub use fig5::{run_fig5, Fig5Row};
pub use fig6::{run_fig6, Fig6Row};
pub use table1::{run_table1, Table1Cell, Table1Opts};
