//! Figure 6: joint SQNR per layer group — transformed W4A4 vs W6A6.
//!
//! The headline: CAT-transformed W4A4 rivals (often exceeds) untransformed
//! W6A6, with the biggest wins on the MLP groups.

use super::common::{load_zoo, mean_std, print_table};
use crate::linalg::Mat;
use crate::model::ALL_GROUPS;
use crate::pipeline::group_transform;
use crate::quant::{ActQuantCfg, QScheme, WeightQuantCfg};
use crate::runtime::Manifest;
use crate::sqnr::{db, measured_sqnr_joint};
use crate::transforms::TransformKind;
use anyhow::Result;

/// One layer group's SQNR series.
#[derive(Clone, Debug)]
pub struct Fig6Row {
    pub layer: String,
    /// (transform label, W4A4 SQNR dB).
    pub w4a4: Vec<(TransformKind, f64)>,
    /// Untransformed W6A6 reference (the purple line).
    pub w6a6_none_db: f64,
}

const KINDS: [TransformKind; 4] = [
    TransformKind::None,
    TransformKind::SmoothQuant,
    TransformKind::QuaRot,
    TransformKind::CatBlock,
];

pub fn run_fig6(manifest: &Manifest, models: &[&str], seed: u64) -> Result<Vec<Fig6Row>> {
    let act4 = ActQuantCfg { scheme: QScheme::asym(4), clip_ratio: 1.0 };
    let wq4 = WeightQuantCfg::minmax(4);
    let act6 = ActQuantCfg { scheme: QScheme::asym(6), clip_ratio: 1.0 };
    let wq6 = WeightQuantCfg::minmax(6);
    let mut rows = Vec::new();
    for mname in models {
        let zoo = load_zoo(manifest, mname, seed)?;
        let cfg = &zoo.model.cfg;
        for block in 0..cfg.n_layers {
            for g in ALL_GROUPS {
                let stats = zoo.calib.sigma(&g.t_name(block));
                let x = stats.sample();
                let sigma_x = stats.sigma();
                let ws: Vec<&Mat> = g
                    .linears()
                    .iter()
                    .map(|lin| &zoo.model.params[&format!("blocks.{block}.{lin}")])
                    .collect();
                let mut series = Vec::new();
                for kind in KINDS {
                    let t = group_transform(kind, &x, &sigma_x, &ws, act4, wq4, 128, seed);
                    let xt = t.apply_acts(&x);
                    // Mean over the group's linears.
                    let mut dbs = Vec::new();
                    for w in &ws {
                        let wt = t.fuse_weights(w);
                        dbs.push(db(measured_sqnr_joint(&xt, &wt, act4, wq4)));
                    }
                    series.push((kind, dbs.iter().sum::<f64>() / dbs.len() as f64));
                }
                let mut ref_dbs = Vec::new();
                for w in &ws {
                    ref_dbs.push(db(measured_sqnr_joint(&x, w, act6, wq6)));
                }
                rows.push(Fig6Row {
                    layer: format!("{}.{}.{}", cfg.name, block, g.label()),
                    w4a4: series,
                    w6a6_none_db: ref_dbs.iter().sum::<f64>() / ref_dbs.len() as f64,
                });
            }
        }
    }
    // The synthetic pathological suite: the regime the paper's headline
    // (CAT W4A4 ≥ None W6A6) lives in. The trained zoo's layers are
    // benign (≈2 dB alignment headroom — Figure 5), so the crossover
    // needs ≥12 dB of combined headroom, which these layers have.
    for layer in crate::calib::synth_suite(128, 4096, seed ^ 0x5717) {
        let sigma_x = crate::linalg::syrk_at_a(&layer.x).scale(1.0 / layer.x.rows() as f64);
        let sigma_w = crate::linalg::syrk_at_a(&layer.w);
        let mut series = Vec::new();
        for kind in KINDS {
            let ws = [&layer.w];
            let t = match kind {
                TransformKind::CatBlock => crate::transforms::cat_block(&sigma_x, &sigma_w, 32, seed),
                _ => group_transform(kind, &layer.x, &sigma_x, &ws, act4, wq4, 32, seed),
            };
            let xt = t.apply_acts(&layer.x);
            let wt = t.fuse_weights(&layer.w);
            series.push((kind, db(measured_sqnr_joint(&xt, &wt, act4, wq4))));
        }
        rows.push(Fig6Row {
            layer: format!("synth.{}", layer.name),
            w4a4: series,
            w6a6_none_db: db(measured_sqnr_joint(&layer.x, &layer.w, act6, wq6)),
        });
    }
    print_fig6(&rows);
    Ok(rows)
}

fn print_fig6(rows: &[Fig6Row]) {
    println!("\n== Figure 6: joint SQNR at W4A4 under transforms vs W6A6 (dB) ==");
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mut cells = vec![r.layer.clone()];
            for (_, v) in &r.w4a4 {
                cells.push(format!("{v:.1}"));
            }
            cells.push(format!("{:.1}", r.w6a6_none_db));
            cells
        })
        .collect();
    print_table(
        &["layer group", "identity", "smoothquant", "quarot", "cat-block", "W6A6 identity"],
        &table,
    );

    println!("\n[fig6] per-transform mean W4A4 SQNR:");
    for (i, kind) in KINDS.iter().enumerate() {
        let vals: Vec<f64> = rows.iter().map(|r| r.w4a4[i].1).collect();
        let (m, s) = mean_std(&vals);
        println!("  {:<22} {:>6.1} ± {:.1} dB", kind.name(), m, s);
    }
    let w66: Vec<f64> = rows.iter().map(|r| r.w6a6_none_db).collect();
    let (m, s) = mean_std(&w66);
    println!("  {:<22} {:>6.1} ± {:.1} dB", "W6A6 None (ref)", m, s);
    let cat_beats = rows
        .iter()
        .filter(|r| r.w4a4.iter().find(|(k, _)| *k == TransformKind::CatBlock).unwrap().1 >= r.w6a6_none_db)
        .count();
    println!(
        "[fig6] CAT(block) W4A4 ≥ None W6A6 on {}/{} layer groups",
        cat_beats,
        rows.len()
    );
}
