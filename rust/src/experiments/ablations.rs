//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **CAT block size `k`** — alignment/SQNR vs transform cost
//!    (the paper's accuracy–efficiency knob, §4).
//! 2. **Calibration-set size** — robustness of the Σ-based transforms.
//! 3. **RHT seed sensitivity** — the spread that motivates SpinQuant.
//! 4. **Channel permutation** — the paper's §7 future-work item
//!    ([`crate::transforms::permuted_cat_block`]).
//! 5. **Dynamic vs static activation ranges** — Lemma 2.2's `r(x)` choice.

use super::common::{load_zoo, mean_std, print_table};
use crate::calib::{calibrate, Corpus};
use crate::linalg::{matmul_a_bt, Mat};
use crate::model::ALL_GROUPS;
use crate::pipeline::group_transform;
use crate::quant::{
    percentile_range, quantize_activations_static, quantize_weights_rtn, ActQuantCfg, QScheme,
    WeightQuantCfg,
};
use crate::runtime::Manifest;
use crate::sqnr::{alignment_data, db, measured_sqnr_joint};
use crate::transforms::{cat_block, permuted_cat_block, TransformKind};
use anyhow::Result;

pub fn run_ablations(manifest: &Manifest, model: &str, seed: u64) -> Result<()> {
    let zoo = load_zoo(manifest, model, seed)?;
    let cfg = zoo.model.cfg.clone();
    let act = ActQuantCfg { scheme: QScheme::asym(4), clip_ratio: 1.0 };
    let wq = WeightQuantCfg::minmax(4);

    // Collect the group bundles once.
    struct G {
        _name: String,
        x: Mat,
        sigma_x: Mat,
        ws: Vec<Mat>,
    }
    let mut groups = Vec::new();
    for block in 0..cfg.n_layers {
        for g in ALL_GROUPS {
            let stats = zoo.calib.sigma(&g.t_name(block));
            groups.push(G {
                _name: format!("{block}.{}", g.label()),
                x: stats.sample(),
                sigma_x: stats.sigma(),
                ws: g
                    .linears()
                    .iter()
                    .map(|lin| zoo.model.params[&format!("blocks.{block}.{lin}")].clone())
                    .collect(),
            });
        }
    }
    let mean_sqnr = |t_of: &dyn Fn(&G) -> crate::transforms::Transform| -> (f64, f64) {
        let mut dbs = Vec::new();
        let t0 = std::time::Instant::now();
        for g in &groups {
            let t = t_of(g);
            let xt = t.apply_acts(&g.x);
            for w in &g.ws {
                let wt = t.fuse_weights(w);
                dbs.push(db(measured_sqnr_joint(&xt, &wt, act, wq)));
            }
        }
        (mean_std(&dbs).0, t0.elapsed().as_secs_f64())
    };

    // ---- 1. block size sweep -------------------------------------------
    println!("\n== Ablation 1: CAT block size k ({model}, W4A4) ==");
    let mut rows = Vec::new();
    for k in [1usize, 8, 32, 128, 512] {
        let (sq, secs) = mean_sqnr(&|g: &G| {
            let sigma_w = sum_wtw(&g.ws);
            cat_block(&g.sigma_x, &sigma_w, k.min(g.sigma_x.rows()), seed)
        });
        rows.push(vec![
            format!("k={k}"),
            format!("{sq:.2}"),
            format!("{:.2}", secs),
        ]);
    }
    print_table(&["block size", "mean joint SQNR dB", "build time s"], &rows);

    // ---- 2. calibration size -------------------------------------------
    println!("\n== Ablation 2: calibration-set size (CAT block k=128) ==");
    let corpus = Corpus::load(&manifest.corpus_train)?;
    let mut rows = Vec::new();
    for n_seqs in [4usize, 16, 64, 128] {
        let seqs = corpus.sample_sequences(n_seqs, cfg.seq, seed ^ 0xCA11B);
        let calib = calibrate(&zoo.model, &seqs, 2048, seed);
        let mut dbs = Vec::new();
        for block in 0..cfg.n_layers {
            for g in ALL_GROUPS {
                let stats = calib.sigma(&g.t_name(block));
                let sigma_small = stats.sigma();
                let ws: Vec<Mat> = g
                    .linears()
                    .iter()
                    .map(|lin| zoo.model.params[&format!("blocks.{block}.{lin}")].clone())
                    .collect();
                let t = cat_block(&sigma_small, &sum_wtw(&ws), 128, seed);
                // Score on the FULL calibration sample (held-out wrt the
                // small draw) for an honest estimate.
                let full = zoo.calib.sigma(&g.t_name(block)).sample();
                let xt = t.apply_acts(&full);
                for w in &ws {
                    dbs.push(db(measured_sqnr_joint(&xt, &t.fuse_weights(w), act, wq)));
                }
            }
        }
        rows.push(vec![format!("{n_seqs} seqs"), format!("{:.2}", mean_std(&dbs).0)]);
    }
    print_table(&["calibration", "mean joint SQNR dB"], &rows);

    // ---- 3. RHT seed sensitivity ---------------------------------------
    println!("\n== Ablation 3: randomized-Hadamard seed spread (QuaRot) ==");
    let mut per_seed = Vec::new();
    for s in 0..16u64 {
        let (sq, _) = mean_sqnr(&|g: &G| {
            let ws_ref: Vec<&Mat> = g.ws.iter().collect();
            group_transform(
                TransformKind::QuaRot,
                &g.x,
                &g.sigma_x,
                &ws_ref,
                act,
                wq,
                128,
                s,
            )
        });
        per_seed.push(sq);
    }
    let (m, sd) = mean_std(&per_seed);
    let lo = per_seed.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = per_seed.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    println!(
        "16 seeds: mean {m:.2} dB, std {sd:.2} dB, range [{lo:.2}, {hi:.2}] dB\n\
         (nonzero spread is SpinQuant's motivation for rotation selection)"
    );

    // ---- 4. permutation (paper §7 future work) -------------------------
    println!("\n== Ablation 4: channel permutation + block CAT ==");
    let mut rows = Vec::new();
    for k in [8usize, 32] {
        let (plain, _) = mean_sqnr(&|g: &G| {
            cat_block(&g.sigma_x, &sum_wtw(&g.ws), k, seed)
        });
        let (perm, _) = mean_sqnr(&|g: &G| {
            permuted_cat_block(&g.sigma_x, &sum_wtw(&g.ws), k, seed)
        });
        // Alignment-only comparison too.
        let mut a_plain = Vec::new();
        let mut a_perm = Vec::new();
        for g in &groups {
            let tp = cat_block(&g.sigma_x, &sum_wtw(&g.ws), k, seed);
            let tq = permuted_cat_block(&g.sigma_x, &sum_wtw(&g.ws), k, seed);
            let w_all = vstack(&g.ws);
            a_plain.push(db(alignment_data(&tp.apply_acts(&g.x), &tp.fuse_weights(&w_all))));
            a_perm.push(db(alignment_data(&tq.apply_acts(&g.x), &tq.fuse_weights(&w_all))));
        }
        rows.push(vec![
            format!("k={k}"),
            format!("{plain:.2}"),
            format!("{perm:.2}"),
            format!("{:.2}", mean_std(&a_plain).0),
            format!("{:.2}", mean_std(&a_perm).0),
        ]);
    }
    print_table(
        &["block", "SQNR plain dB", "SQNR perm dB", "align plain dB", "align perm dB"],
        &rows,
    );

    // ---- 5. dynamic vs static activation ranges ------------------------
    println!("\n== Ablation 5: dynamic per-token vs static activation ranges (A4) ==");
    let mut rows = Vec::new();
    for (label, pct) in [("static minmax", 1.0), ("static p99.9", 0.999), ("static p99", 0.99)] {
        let mut dbs = Vec::new();
        for g in &groups {
            for w in &g.ws {
                let (lo, hi) = percentile_range(&g.x, pct);
                let (xq, _) = quantize_activations_static(&g.x, lo, hi, act.scheme);
                let wqd = quantize_weights_rtn(w, wq).deq();
                let y = matmul_a_bt(&g.x, w);
                let yq = matmul_a_bt(&xq, &wqd);
                let noise = y.sub(&yq).fro_norm2();
                dbs.push(db(y.fro_norm2() / noise.max(1e-30)));
            }
        }
        rows.push(vec![label.to_string(), format!("{:.2}", mean_std(&dbs).0)]);
    }
    let mut dyn_dbs = Vec::new();
    for g in &groups {
        for w in &g.ws {
            dyn_dbs.push(db(measured_sqnr_joint(&g.x, w, act, wq)));
        }
    }
    rows.push(vec!["dynamic per-token".into(), format!("{:.2}", mean_std(&dyn_dbs).0)]);
    print_table(&["activation ranges", "mean joint SQNR dB"], &rows);
    Ok(())
}

fn sum_wtw(ws: &[Mat]) -> Mat {
    let d = ws[0].cols();
    let mut s = Mat::zeros(d, d);
    for w in ws {
        s.add_in_place(&crate::linalg::syrk_at_a(w));
    }
    s
}

fn vstack(ws: &[Mat]) -> Mat {
    let cols = ws[0].cols();
    let rows: usize = ws.iter().map(|w| w.rows()).sum();
    let mut out = Mat::zeros(rows, cols);
    let mut r = 0;
    for w in ws {
        out.set_block(r, 0, w);
        r += w.rows();
    }
    out
}
