//! Integer-domain matmul kernels over packed quantized tensors.
//!
//! [`qmatmul_a_bt`] is the serving-path analogue of
//! [`matmul_a_bt`](super::matmul_a_bt): per-token × per-output-channel dot
//! products, but over *integer codes* with i32/i64 accumulation and the
//! affine correction
//!
//! ```text
//! y[t, o] = s_x·s_w·(Σ q_x·q_w − zp_x·Σ q_w − zp_w·Σ q_x + k·zp_x·zp_w)
//! ```
//!
//! which is exact in integer arithmetic, so the packed path reproduces the
//! dense fake-quant f64 path to fp rounding (the parity suite in
//! `rust/tests/quant_parity_props.rs` pins this at 1e-9 relative).
//!
//! The kernel is a dispatcher like its f64 siblings: above
//! [`par::PAR_MIN_FMA`](super::par::PAR_MIN_FMA) the output rows fan out
//! across the scoped worker pool. Integer accumulation is exact, so the
//! result is bit-identical at any worker count — a stronger guarantee than
//! the f64 kernels need row-partitioning for.
//!
//! Storage layouts are produced by `crate::quant::QuantizedTensor`; this
//! module only borrows them through [`QMatView`] so `linalg` stays below
//! `quant` in the crate layering.

use super::matmul::{transpose_ct_into, GEMV_MAX_ROWS};
use super::{par, simd, Mat};

/// Packed integer codes of one row-quantized matrix.
#[derive(Clone, Copy)]
pub enum QCodes<'a> {
    /// Two 4-bit codes per byte (low nibble = even column); each row is
    /// padded to a whole byte, so the row stride is `cols.div_ceil(2)`.
    Nibble(&'a [u8]),
    /// One code per byte (bit widths 5–8, centered so they fit `i8`).
    Byte(&'a [i8]),
    /// Raw wide codes (bit widths above 8 — analysis configs only).
    Wide(&'a [i32]),
}

/// Borrowed view of a packed row-quantized matrix: integer codes plus the
/// per-row affine grid. `zps` live in *stored-code* space (the packer may
/// bias codes to fit the physical container; scale/zero-point are biased
/// with them, so `value = (code − zp)·scale` always holds).
#[derive(Clone, Copy)]
pub struct QMatView<'a> {
    pub rows: usize,
    pub cols: usize,
    pub codes: QCodes<'a>,
    /// Per-row scale.
    pub scales: &'a [f64],
    /// Per-row zero point in stored-code space (integral).
    pub zps: &'a [i32],
    /// Per-row sum of stored codes (precomputed for the affine correction).
    pub row_sums: &'a [i64],
}

impl QMatView<'_> {
    fn fits_i16(&self) -> bool {
        // Nibble codes are 0..=15 and Byte codes are −128..=127; Wide
        // codes (bits > 8) may not fit.
        !matches!(self.codes, QCodes::Wide(_))
    }

    /// Unpack row `i` into `out` (`cols` wide).
    pub fn unpack_row_i32(&self, i: usize, out: &mut [i32]) {
        debug_assert_eq!(out.len(), self.cols);
        match self.codes {
            QCodes::Nibble(data) => {
                let stride = self.cols.div_ceil(2);
                let row = &data[i * stride..(i + 1) * stride];
                // Two codes per byte: pair output elements with source
                // bytes so the loop carries no per-element parity branch.
                let mut pairs = out.chunks_exact_mut(2);
                for (o2, &b) in (&mut pairs).zip(row) {
                    o2[0] = (b & 0x0F) as i32;
                    o2[1] = (b >> 4) as i32;
                }
                if let [last] = pairs.into_remainder() {
                    *last = (row[self.cols / 2] & 0x0F) as i32;
                }
            }
            QCodes::Byte(data) => {
                let row = &data[i * self.cols..(i + 1) * self.cols];
                for (o, &v) in out.iter_mut().zip(row) {
                    *o = v as i32;
                }
            }
            QCodes::Wide(data) => {
                out.copy_from_slice(&data[i * self.cols..(i + 1) * self.cols]);
            }
        }
    }

    /// Unpack row `i` into an `i16` buffer (callers must have checked
    /// [`fits_i16`](Self::fits_i16)).
    fn unpack_row_i16(&self, i: usize, out: &mut [i16]) {
        debug_assert_eq!(out.len(), self.cols);
        match self.codes {
            QCodes::Nibble(data) => {
                let stride = self.cols.div_ceil(2);
                let row = &data[i * stride..(i + 1) * stride];
                // Branch-free two-codes-per-byte loop (see unpack_row_i32).
                let mut pairs = out.chunks_exact_mut(2);
                for (o2, &b) in (&mut pairs).zip(row) {
                    o2[0] = (b & 0x0F) as i16;
                    o2[1] = (b >> 4) as i16;
                }
                if let [last] = pairs.into_remainder() {
                    *last = (row[self.cols / 2] & 0x0F) as i16;
                }
            }
            QCodes::Byte(data) => {
                let row = &data[i * self.cols..(i + 1) * self.cols];
                for (o, &v) in out.iter_mut().zip(row) {
                    *o = v as i16;
                }
            }
            QCodes::Wide(_) => unreachable!("wide codes do not fit i16"),
        }
    }
}

/// Upper bound on `k` for the i16/i32 fast path: stored codes are at most
/// 128 in magnitude (nibble ≤ 15, centered byte ≤ 128), so each i16
/// product is ≤ 2^14 and every dispatchable [`super::simd`] path keeps
/// its i32 lane accumulators in range at `k ≤ 2^19` — scalar and NEON
/// lanes see `k/8` products (≤ k·2^11 = 2^30), AVX2 `madd` lanes `k/16`
/// pair-sums of ≤ 2^15 (= 2^30), AVX-512 `k/32` pair-sums (= 2^29); all
/// ≤ 2^30 < `i32::MAX` with 2× margin, on any ISA. The boundary test in
/// `rust/tests/kernel_tile_props.rs` drives ±max-code vectors at exactly
/// this `k` through every supported path.
pub const MAX_I16_PATH_COLS: usize = 1 << 19;

/// Persistent unpacked panels of a *static* packed operand (weights):
/// the codes of every row unpacked **once** into the contiguous
/// row-major layout the kernels consume (`i16` when the fast path
/// applies, `i32` otherwise). [`qmatmul_a_bt_panels`] then skips the
/// per-call `n×k` unpack that dominates small-batch (decode/prefill)
/// calls — integer accumulation is exact, so the panel path is
/// bit-identical to [`qmatmul_a_bt`].
///
/// Built by `QuantizedTensor::panels()` /
/// `model::QuantizedLinear::new`; ~4× the nibble-packed bytes at W4 —
/// a deliberate memory-for-latency trade on serving weights.
#[derive(Clone)]
pub struct QPanels {
    rows: usize,
    cols: usize,
    data: QPanelData,
}

#[derive(Clone)]
enum QPanelData {
    I16(Vec<i16>),
    I32(Vec<i32>),
}

impl QPanels {
    /// Unpack every row of `v` once into the kernel layout.
    pub fn from_view(v: &QMatView) -> QPanels {
        let (rows, cols) = (v.rows, v.cols);
        let data = if v.fits_i16() && cols <= MAX_I16_PATH_COLS {
            let mut d = vec![0i16; rows * cols];
            if cols > 0 {
                for (j, row) in d.chunks_exact_mut(cols).enumerate() {
                    v.unpack_row_i16(j, row);
                }
            }
            QPanelData::I16(d)
        } else {
            let mut d = vec![0i32; rows * cols];
            if cols > 0 {
                for (j, row) in d.chunks_exact_mut(cols).enumerate() {
                    v.unpack_row_i32(j, row);
                }
            }
            QPanelData::I32(d)
        };
        QPanels { rows, cols, data }
    }

    /// Bytes held by the unpacked panels.
    pub fn bytes(&self) -> usize {
        match &self.data {
            QPanelData::I16(d) => d.len() * std::mem::size_of::<i16>(),
            QPanelData::I32(d) => d.len() * std::mem::size_of::<i32>(),
        }
    }
}

/// `C = X · Wᵀ` over packed integer codes with the affine correction
/// applied per `(token, output-channel)` pair. Dispatches to the worker
/// pool above the [`par::PAR_MIN_FMA`] threshold; integer accumulation is
/// exact, so worker count — and which partitioning the shape selects —
/// never changes the result.
pub fn qmatmul_a_bt(x: &QMatView, w: &QMatView) -> Mat {
    let work = x.rows.saturating_mul(x.cols).saturating_mul(w.rows);
    if x.rows < GEMV_MAX_ROWS && w.rows > x.rows {
        let threads = par::threads_for(work, w.rows);
        return qmatmul_small_m(x, w, threads);
    }
    let threads = par::threads_for(work, x.rows);
    qmatmul_a_bt_t(x, w, threads)
}

/// Serial reference for [`qmatmul_a_bt`] (benches, parity property tests).
pub fn qmatmul_a_bt_serial(x: &QMatView, w: &QMatView) -> Mat {
    qmatmul_a_bt_t(x, w, 1)
}

/// `C = X · Wᵀ` over `W`'s **persistent** unpacked panels
/// ([`QPanels::from_view`], built once at weight-load time): skips the
/// per-call `n×k` weight unpack entirely. Integer accumulation is exact,
/// so results are bit-identical to [`qmatmul_a_bt`] on the same views.
///
/// The one mixed case — wide (>8-bit) activations over `i16` panels —
/// falls back to the unpack-per-call wide kernel; it only arises in
/// analysis configs.
pub fn qmatmul_a_bt_panels(x: &QMatView, w: &QMatView, wp: &QPanels) -> Mat {
    assert_eq!(x.cols, w.cols, "qmatmul_a_bt shape mismatch");
    assert!(
        wp.rows == w.rows && wp.cols == w.cols,
        "panels do not match the weight view ({}×{} vs {}×{})",
        wp.rows,
        wp.cols,
        w.rows,
        w.cols
    );
    if matches!(wp.data, QPanelData::I16(_)) && !x.fits_i16() {
        return qmatmul_a_bt(x, w);
    }
    let work = x.rows.saturating_mul(x.cols).saturating_mul(w.rows);
    if x.rows < GEMV_MAX_ROWS && w.rows > x.rows {
        let threads = par::threads_for(work, w.rows);
        return qmatmul_small_m_panels(x, w, wp, threads);
    }
    let threads = par::threads_for(work, x.rows);
    let (m, n) = (x.rows, w.rows);
    let mut c = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return c;
    }
    match &wp.data {
        QPanelData::I16(wd) => {
            par::par_rows(c.as_mut_slice(), n, threads, |r0, out| {
                qmatmul_rows_i16(x, w, wd, r0, out)
            });
        }
        QPanelData::I32(wd) => {
            par::par_rows(c.as_mut_slice(), n, threads, |r0, out| {
                qmatmul_rows_wide(x, w, wd, r0, out)
            });
        }
    }
    c
}

/// Decode/GEMV shape over persistent panels: activations unpack once
/// into thread-local scratch, weight rows are read straight from the
/// panels (zero per-step unpack, zero per-step allocation). Per-element
/// math matches [`qmatmul_small_m`] exactly.
fn qmatmul_small_m_panels(x: &QMatView, w: &QMatView, wp: &QPanels, threads: usize) -> Mat {
    let (m, k, n) = (x.rows, x.cols, w.rows);
    let mut c = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return c;
    }
    par::with_scratch_f64(n * m, |ct| {
        match &wp.data {
            QPanelData::I16(wd) => par::with_scratch_i16(m * k, |xbuf| {
                for i in 0..m {
                    x.unpack_row_i16(i, &mut xbuf[i * k..(i + 1) * k]);
                }
                let xbuf = &*xbuf;
                par::par_rows(ct, m, threads, |j0, out| {
                    for (jj, orow) in out.chunks_mut(m).enumerate() {
                        let j = j0 + jj;
                        let wrow = &wd[j * k..(j + 1) * k];
                        let (sw, zw, sumw) = (w.scales[j], w.zps[j] as i64, w.row_sums[j]);
                        for (i, o) in orow.iter_mut().enumerate() {
                            let dot = qdot_i16(&xbuf[i * k..(i + 1) * k], wrow);
                            let zx = x.zps[i] as i64;
                            let corr = dot - zx * sumw - zw * x.row_sums[i] + (k as i64) * zx * zw;
                            *o = x.scales[i] * sw * corr as f64;
                        }
                    }
                });
            }),
            QPanelData::I32(wd) => par::with_scratch_i32(m * k, |xbuf| {
                for i in 0..m {
                    x.unpack_row_i32(i, &mut xbuf[i * k..(i + 1) * k]);
                }
                let xbuf = &*xbuf;
                par::par_rows(ct, m, threads, |j0, out| {
                    for (jj, orow) in out.chunks_mut(m).enumerate() {
                        let j = j0 + jj;
                        let wrow = &wd[j * k..(j + 1) * k];
                        let (sw, zw, sumw) = (w.scales[j], w.zps[j] as i64, w.row_sums[j]);
                        for (i, o) in orow.iter_mut().enumerate() {
                            let mut dot = 0i64;
                            for (&a, &b) in xbuf[i * k..(i + 1) * k].iter().zip(wrow) {
                                dot += a as i64 * b as i64;
                            }
                            let zx = x.zps[i] as i64;
                            let corr = dot - zx * sumw - zw * x.row_sums[i] + (k as i64) * zx * zw;
                            *o = x.scales[i] * sw * corr as f64;
                        }
                    }
                });
            }),
        }
        transpose_ct_into(ct, m, &mut c);
    });
    c
}

fn qmatmul_a_bt_t(x: &QMatView, w: &QMatView, threads: usize) -> Mat {
    assert_eq!(x.cols, w.cols, "qmatmul_a_bt shape mismatch");
    let (m, k, n) = (x.rows, x.cols, w.rows);
    let mut c = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return c;
    }
    if x.fits_i16() && w.fits_i16() && k <= MAX_I16_PATH_COLS {
        // Unpack W once (i16 is 4× smaller than the f64 it replaces and
        // amortized over all `m` tokens), then fan output rows out.
        let mut wbuf = vec![0i16; n * k];
        for j in 0..n {
            w.unpack_row_i16(j, &mut wbuf[j * k..(j + 1) * k]);
        }
        par::par_rows(c.as_mut_slice(), n, threads, |r0, out| {
            qmatmul_rows_i16(x, w, &wbuf, r0, out)
        });
    } else {
        let mut wbuf = vec![0i32; n * k];
        for j in 0..n {
            w.unpack_row_i32(j, &mut wbuf[j * k..(j + 1) * k]);
        }
        par::par_rows(c.as_mut_slice(), n, threads, |r0, out| {
            qmatmul_rows_wide(x, w, &wbuf, r0, out)
        });
    }
    c
}

/// Decode/GEMV kernel: `m` is tiny (a decode batch), `n` is a full
/// weight's output channels. Activations unpack once up front; weight
/// rows unpack into a per-worker `k`-wide tile that stays hot in L1 and
/// is consumed immediately — once per step, not once per output row of a
/// materialized `n×k` buffer. Work partitions over output channels via
/// the transposed output (`Cᵀ` rows are contiguous), then transposes
/// back. Per-element math is identical to the row-partitioned path.
fn qmatmul_small_m(x: &QMatView, w: &QMatView, threads: usize) -> Mat {
    assert_eq!(x.cols, w.cols, "qmatmul_a_bt shape mismatch");
    let (m, k, n) = (x.rows, x.cols, w.rows);
    let mut c = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return c;
    }
    let mut ct = vec![0.0f64; n * m];
    if x.fits_i16() && w.fits_i16() && k <= MAX_I16_PATH_COLS {
        let mut xbuf = vec![0i16; m * k];
        for i in 0..m {
            x.unpack_row_i16(i, &mut xbuf[i * k..(i + 1) * k]);
        }
        par::par_rows(&mut ct, m, threads, |j0, out| {
            let mut wrow = vec![0i16; k];
            for (jj, orow) in out.chunks_mut(m).enumerate() {
                let j = j0 + jj;
                w.unpack_row_i16(j, &mut wrow);
                let (sw, zw, sumw) = (w.scales[j], w.zps[j] as i64, w.row_sums[j]);
                for (i, o) in orow.iter_mut().enumerate() {
                    let dot = qdot_i16(&xbuf[i * k..(i + 1) * k], &wrow);
                    let zx = x.zps[i] as i64;
                    let corr = dot - zx * sumw - zw * x.row_sums[i] + (k as i64) * zx * zw;
                    *o = x.scales[i] * sw * corr as f64;
                }
            }
        });
    } else {
        let mut xbuf = vec![0i32; m * k];
        for i in 0..m {
            x.unpack_row_i32(i, &mut xbuf[i * k..(i + 1) * k]);
        }
        par::par_rows(&mut ct, m, threads, |j0, out| {
            let mut wrow = vec![0i32; k];
            for (jj, orow) in out.chunks_mut(m).enumerate() {
                let j = j0 + jj;
                w.unpack_row_i32(j, &mut wrow);
                let (sw, zw, sumw) = (w.scales[j], w.zps[j] as i64, w.row_sums[j]);
                for (i, o) in orow.iter_mut().enumerate() {
                    let mut dot = 0i64;
                    for (&a, &b) in xbuf[i * k..(i + 1) * k].iter().zip(&wrow) {
                        dot += a as i64 * b as i64;
                    }
                    let zx = x.zps[i] as i64;
                    let corr = dot - zx * sumw - zw * x.row_sums[i] + (k as i64) * zx * zw;
                    *o = x.scales[i] * sw * corr as f64;
                }
            }
        });
    }
    transpose_ct_into(&ct, m, &mut c);
    c
}

/// Output rows `r0..` of the fast path: i16 codes, i32 lane accumulators.
fn qmatmul_rows_i16(x: &QMatView, w: &QMatView, wbuf: &[i16], r0: usize, out: &mut [f64]) {
    if out.is_empty() {
        return;
    }
    let (k, n) = (x.cols, w.rows);
    let rows = out.len() / n;
    let mut xbuf = vec![0i16; k];
    for i in 0..rows {
        let xi = r0 + i;
        x.unpack_row_i16(xi, &mut xbuf);
        let sx = x.scales[xi];
        let zx = x.zps[xi] as i64;
        let sumx = x.row_sums[xi];
        let crow = &mut out[i * n..(i + 1) * n];
        for (j, cj) in crow.iter_mut().enumerate() {
            let dot = qdot_i16(&xbuf, &wbuf[j * k..(j + 1) * k]);
            let zw = w.zps[j] as i64;
            let corr = dot - zx * w.row_sums[j] - zw * sumx + (k as i64) * zx * zw;
            *cj = sx * w.scales[j] * corr as f64;
        }
    }
}

/// Output rows `r0..` of the wide path: i32 codes, i64 products (exact for
/// any bit width ≤ 24).
fn qmatmul_rows_wide(x: &QMatView, w: &QMatView, wbuf: &[i32], r0: usize, out: &mut [f64]) {
    if out.is_empty() {
        return;
    }
    let (k, n) = (x.cols, w.rows);
    let rows = out.len() / n;
    let mut xbuf = vec![0i32; k];
    for i in 0..rows {
        let xi = r0 + i;
        x.unpack_row_i32(xi, &mut xbuf);
        let sx = x.scales[xi];
        let zx = x.zps[xi] as i64;
        let sumx = x.row_sums[xi];
        let crow = &mut out[i * n..(i + 1) * n];
        for (j, cj) in crow.iter_mut().enumerate() {
            let wrow = &wbuf[j * k..(j + 1) * k];
            let mut dot = 0i64;
            for (&a, &b) in xbuf.iter().zip(wrow) {
                dot += a as i64 * b as i64;
            }
            let zw = w.zps[j] as i64;
            let corr = dot - zx * w.row_sums[j] - zw * sumx + (k as i64) * zx * zw;
            *cj = sx * w.scales[j] * corr as f64;
        }
    }
}

/// i16×i16→i32-lane→i64 dot product, dispatched across the runtime ISA
/// paths in [`super::simd`] (AVX-512/AVX2 `madd_epi16`, NEON `vmlal`,
/// the eight-lane scalar reference). Integer accumulation is exact, so
/// the path choice can never change a result — see `simd`'s module docs
/// for the per-ISA overflow bounds behind [`MAX_I16_PATH_COLS`].
#[inline]
fn qdot_i16(a: &[i16], b: &[i16]) -> i64 {
    simd::qdot_i16(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nibble_unpack_handles_odd_cols() {
        // Codes 1..=5 packed low-nibble-first; the 5th code sits in the
        // low nibble of a padded final byte.
        let data = [0x21u8, 0x43, 0x05];
        let scales = [1.0];
        let zps = [0];
        let sums = [15i64];
        let v = QMatView {
            rows: 1,
            cols: 5,
            codes: QCodes::Nibble(&data),
            scales: &scales,
            zps: &zps,
            row_sums: &sums,
        };
        let mut out = [0i32; 5];
        v.unpack_row_i32(0, &mut out);
        assert_eq!(out, [1, 2, 3, 4, 5]);
        let mut out16 = [0i16; 5];
        v.unpack_row_i16(0, &mut out16);
        assert_eq!(out16, [1, 2, 3, 4, 5]);
    }

    #[test]
    fn qmatmul_matches_dequantized_f64_reference() {
        // 2×3 codes on each side, with non-trivial scales and zero points.
        let xc: [i8; 6] = [1, -2, 3, 0, 4, -1];
        let wc: [i8; 6] = [2, 1, 0, -3, 2, 2];
        let xs = [0.5, 0.25];
        let ws = [2.0, 1.5];
        let xz = [1i32, 0];
        let wz = [0i32, -1];
        let xsum = [2i64, 3];
        let wsum = [3i64, 1];
        let x = QMatView {
            rows: 2,
            cols: 3,
            codes: QCodes::Byte(&xc),
            scales: &xs,
            zps: &xz,
            row_sums: &xsum,
        };
        let w = QMatView {
            rows: 2,
            cols: 3,
            codes: QCodes::Byte(&wc),
            scales: &ws,
            zps: &wz,
            row_sums: &wsum,
        };
        let c = qmatmul_a_bt(&x, &w);
        for i in 0..2 {
            for j in 0..2 {
                let mut want = 0.0;
                for l in 0..3 {
                    let xv = (xc[i * 3 + l] as i32 - xz[i]) as f64 * xs[i];
                    let wv = (wc[j * 3 + l] as i32 - wz[j]) as f64 * ws[j];
                    want += xv * wv;
                }
                assert!(
                    (c[(i, j)] - want).abs() < 1e-12,
                    "({i},{j}): {} vs {want}",
                    c[(i, j)]
                );
            }
        }
    }

    #[test]
    fn wide_and_i16_paths_agree() {
        // Same logical codes through Byte (fast path) and Wide (exact
        // path) storage must produce identical results.
        let codes_b: Vec<i8> = (0..24).map(|v| (v % 11) - 5).collect();
        let codes_w: Vec<i32> = codes_b.iter().map(|&v| v as i32).collect();
        let scales = [0.5, 0.75, 1.25];
        let zps = [1i32, -2, 0];
        let sums: Vec<i64> = (0..3)
            .map(|i| codes_b[i * 8..(i + 1) * 8].iter().map(|&v| v as i64).sum())
            .collect();
        let mk = |byte: bool| QMatView {
            rows: 3,
            cols: 8,
            codes: if byte { QCodes::Byte(&codes_b) } else { QCodes::Wide(&codes_w) },
            scales: &scales,
            zps: &zps,
            row_sums: &sums,
        };
        let fast = qmatmul_a_bt(&mk(true), &mk(true));
        let wide = qmatmul_a_bt(&mk(false), &mk(false));
        assert_eq!(fast.max_abs_diff(&wide), 0.0);
    }

    #[test]
    fn small_m_path_matches_row_path_bit_exactly() {
        // Decode shapes (few tokens, many output channels) route through
        // qmatmul_small_m; the per-element math is shared, so both
        // partitionings must agree exactly — nibble and byte stores,
        // odd k (padded nibble tails), m = 1 (pure GEMV) included.
        let mut rng = crate::linalg::Rng::new(9);
        for (m, k, n) in [(1usize, 33usize, 96usize), (4, 48, 64), (7, 19, 40)] {
            for bits in [4u32, 8, 12] {
                let x = Mat::from_fn(m, k, |_, _| rng.normal());
                let w = Mat::from_fn(n, k, |_, _| rng.normal() * 0.1);
                let scheme = crate::quant::QScheme::asym(bits);
                let xp = crate::quant::QuantizedTensor::quantize_acts(&x, scheme, 1.0);
                let wp = crate::quant::QuantizedTensor::quantize_acts(&w, scheme, 1.0);
                let small = qmatmul_small_m(&xp.view(), &wp.view(), 3);
                let rows = qmatmul_a_bt_serial(&xp.view(), &wp.view());
                assert_eq!(small.max_abs_diff(&rows), 0.0, "{m}x{k}x{n} bits {bits}");
                // And the dispatcher picks the small path for this shape.
                assert_eq!(qmatmul_a_bt(&xp.view(), &wp.view()).max_abs_diff(&rows), 0.0);
            }
        }
    }

    #[test]
    fn panels_path_matches_unpack_per_call_exactly() {
        // Persistent panels must be a pure layout change: both the
        // small-m (decode) and row-partitioned shapes, every store type,
        // odd dims. Integer accumulation is exact, so equality is 0.0.
        let mut rng = crate::linalg::Rng::new(11);
        for (m, k, n) in [(1usize, 33usize, 96usize), (4, 48, 64), (40, 19, 24)] {
            for bits in [4u32, 8, 12] {
                let x = Mat::from_fn(m, k, |_, _| rng.normal());
                let w = Mat::from_fn(n, k, |_, _| rng.normal() * 0.1);
                let scheme = crate::quant::QScheme::asym(bits);
                let xp = crate::quant::QuantizedTensor::quantize_acts(&x, scheme, 1.0);
                let wpk = crate::quant::QuantizedTensor::quantize_acts(&w, scheme, 1.0);
                let panels = QPanels::from_view(&wpk.view());
                let got = qmatmul_a_bt_panels(&xp.view(), &wpk.view(), &panels);
                let want = qmatmul_a_bt(&xp.view(), &wpk.view());
                assert_eq!(got.max_abs_diff(&want), 0.0, "{m}x{k}x{n} bits {bits}");
            }
        }
    }

    #[test]
    fn qdot_matches_naive() {
        // The per-ISA suites live in super::simd; this pins the local
        // wrapper the kernels actually call.
        let a: Vec<i16> = (0..37).map(|v| (v * 7 % 19) - 9).collect();
        let b: Vec<i16> = (0..37).map(|v| (v * 5 % 23) - 11).collect();
        let naive: i64 = a.iter().zip(&b).map(|(&x, &y)| x as i64 * y as i64).sum();
        assert_eq!(qdot_i16(&a, &b), naive);
    }

    #[test]
    fn nibble_unpack_even_cols_has_no_tail() {
        // Even cols: the chunked two-codes-per-byte loop consumes the
        // whole row with an empty remainder.
        let data = [0x21u8, 0x43];
        let scales = [1.0];
        let zps = [0];
        let sums = [10i64];
        let v = QMatView {
            rows: 1,
            cols: 4,
            codes: QCodes::Nibble(&data),
            scales: &scales,
            zps: &zps,
            row_sums: &sums,
        };
        let mut out = [0i32; 4];
        v.unpack_row_i32(0, &mut out);
        assert_eq!(out, [1, 2, 3, 4]);
        let mut out16 = [0i16; 4];
        v.unpack_row_i16(0, &mut out16);
        assert_eq!(out16, [1, 2, 3, 4]);
    }
}
