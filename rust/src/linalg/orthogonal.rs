//! Random orthogonal matrices.
//!
//! Haar-distributed rotations via QR (Householder) of a Gaussian matrix
//! with the sign correction of Mezzadri (2007). These are the "rotation"
//! transforms of QuaRot/SpinQuant in their unstructured form; the paper
//! proves they cannot change alignment (eq. 4), which our property tests
//! verify numerically.

use super::{Mat, Rng};

/// Haar-random orthogonal `n×n` matrix.
pub fn random_orthogonal(n: usize, rng: &mut Rng) -> Mat {
    let mut a = Mat::from_fn(n, n, |_, _| rng.normal());
    // Householder QR, accumulating Q explicitly.
    let mut q = Mat::eye(n);
    let mut v = vec![0.0; n];
    for k in 0..n {
        // Householder vector for column k below the diagonal.
        let mut norm2 = 0.0;
        for i in k..n {
            norm2 += a[(i, k)] * a[(i, k)];
        }
        let norm = norm2.sqrt();
        if norm < 1e-300 {
            continue;
        }
        let alpha = if a[(k, k)] >= 0.0 { -norm } else { norm };
        let mut vnorm2 = 0.0;
        for i in k..n {
            v[i] = a[(i, k)];
            if i == k {
                v[i] -= alpha;
            }
            vnorm2 += v[i] * v[i];
        }
        if vnorm2 < 1e-300 {
            continue;
        }
        let beta = 2.0 / vnorm2;
        // A ← (I - β v vᵀ) A  (rows k..n, cols k..n)
        for j in k..n {
            let mut dot = 0.0;
            for i in k..n {
                dot += v[i] * a[(i, j)];
            }
            let f = beta * dot;
            for i in k..n {
                a[(i, j)] -= f * v[i];
            }
        }
        // Q ← Q (I - β v vᵀ)  (all rows, cols k..n)
        for i in 0..n {
            let mut dot = 0.0;
            for j in k..n {
                dot += q[(i, j)] * v[j];
            }
            let f = beta * dot;
            for j in k..n {
                q[(i, j)] -= f * v[j];
            }
        }
    }
    // Sign correction: multiply column j of Q by sign(R_jj) so the
    // distribution is exactly Haar.
    for j in 0..n {
        let s = if a[(j, j)] >= 0.0 { 1.0 } else { -1.0 };
        if s < 0.0 {
            for i in 0..n {
                q[(i, j)] = -q[(i, j)];
            }
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_at_b};

    #[test]
    fn orthogonality() {
        for n in [3usize, 8, 33, 64] {
            let mut rng = Rng::new(n as u64);
            let q = random_orthogonal(n, &mut rng);
            let qtq = matmul_at_b(&q, &q);
            assert!(
                qtq.max_abs_diff(&Mat::eye(n)) < 1e-10,
                "n={n} diff={}",
                qtq.max_abs_diff(&Mat::eye(n))
            );
        }
    }

    #[test]
    fn preserves_norm() {
        let mut rng = Rng::new(7);
        let q = random_orthogonal(16, &mut rng);
        let x: Vec<f64> = (0..16).map(|i| (i as f64).sin()).collect();
        let y = crate::linalg::matvec(&q, &x);
        let nx: f64 = x.iter().map(|v| v * v).sum();
        let ny: f64 = y.iter().map(|v| v * v).sum();
        assert!((nx - ny).abs() < 1e-10);
    }

    #[test]
    fn different_seeds_give_different_rotations() {
        let a = random_orthogonal(8, &mut Rng::new(1));
        let b = random_orthogonal(8, &mut Rng::new(2));
        assert!(a.max_abs_diff(&b) > 0.1);
    }

    #[test]
    fn composition_is_orthogonal() {
        let mut rng = Rng::new(11);
        let a = random_orthogonal(12, &mut rng);
        let b = random_orthogonal(12, &mut rng);
        let c = matmul(&a, &b);
        assert!(matmul_at_b(&c, &c).max_abs_diff(&Mat::eye(12)) < 1e-10);
    }
}
