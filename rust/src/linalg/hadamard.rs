//! Walsh–Hadamard transforms.
//!
//! The paper (following QuaRot / QuIP#) uses Hadamard rotations to spread
//! outlier channels: `H/√d` is orthogonal, so it leaves alignment invariant
//! (paper eq. 4) while pushing per-channel distributions toward Normal by
//! the central limit theorem (paper §3).
//!
//! We provide the `O(d log d)` in-place fast transform (the form the L1
//! Pallas kernel mirrors) and dense matrix constructors for fusing into
//! weights. Dimensions must be powers of two — the model zoo is designed
//! that way (see DESIGN.md §3).

use super::{Mat, Rng};

/// `true` if `n` is a power of two (and nonzero).
#[inline]
pub fn is_pow2(n: usize) -> bool {
    n != 0 && n & (n - 1) == 0
}

/// In-place fast Walsh–Hadamard transform, normalized by `1/√n` so the
/// overall operator is orthogonal. `data.len()` must be a power of two.
pub fn fwht_inplace(data: &mut [f64]) {
    let n = data.len();
    assert!(is_pow2(n), "FWHT length must be a power of two, got {n}");
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let x = data[j];
                let y = data[j + h];
                data[j] = x + y;
                data[j + h] = x - y;
            }
            i += h * 2;
        }
        h *= 2;
    }
    let scale = 1.0 / (n as f64).sqrt();
    for v in data.iter_mut() {
        *v *= scale;
    }
}

/// Dense normalized Hadamard matrix `H/√n` (Sylvester construction).
pub fn hadamard_matrix(n: usize) -> Mat {
    assert!(is_pow2(n), "Hadamard size must be a power of two, got {n}");
    let mut h = Mat::zeros(n, n);
    h[(0, 0)] = 1.0;
    let mut size = 1;
    while size < n {
        for i in 0..size {
            for j in 0..size {
                let v = h[(i, j)];
                h[(i, j + size)] = v;
                h[(i + size, j)] = v;
                h[(i + size, j + size)] = -v;
            }
        }
        size *= 2;
    }
    h.scale(1.0 / (n as f64).sqrt())
}

/// Randomized Hadamard: `H · diag(s)` with random signs `s ∈ {±1}ⁿ`
/// (the RHT of QuaRot; different seeds give different rotations, which is
/// what SpinQuant's seed sensitivity is about).
pub fn randomized_hadamard(n: usize, rng: &mut Rng) -> Mat {
    let h = hadamard_matrix(n);
    let signs: Vec<f64> = (0..n).map(|_| rng.sign()).collect();
    // H · diag(s): scale columns.
    Mat::from_fn(n, n, |i, j| h[(i, j)] * signs[j])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul_at_b, matvec};

    #[test]
    fn fwht_matches_dense() {
        for n in [2usize, 4, 8, 32, 128] {
            let h = hadamard_matrix(n);
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).sin()).collect();
            let dense = matvec(&h, &x);
            let mut fast = x.clone();
            fwht_inplace(&mut fast);
            for i in 0..n {
                assert!((dense[i] - fast[i]).abs() < 1e-10, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn hadamard_is_orthogonal() {
        for n in [2usize, 16, 64] {
            let h = hadamard_matrix(n);
            let hth = matmul_at_b(&h, &h);
            assert!(hth.max_abs_diff(&Mat::eye(n)) < 1e-11);
        }
    }

    #[test]
    fn fwht_involution() {
        // Normalized FWHT is its own inverse.
        let n = 64;
        let x: Vec<f64> = (0..n).map(|i| (i as f64).cos()).collect();
        let mut y = x.clone();
        fwht_inplace(&mut y);
        fwht_inplace(&mut y);
        for i in 0..n {
            assert!((x[i] - y[i]).abs() < 1e-11);
        }
    }

    #[test]
    fn randomized_hadamard_orthogonal() {
        let mut rng = Rng::new(99);
        let q = randomized_hadamard(32, &mut rng);
        let qtq = matmul_at_b(&q, &q);
        assert!(qtq.max_abs_diff(&Mat::eye(32)) < 1e-11);
    }

    #[test]
    fn randomized_hadamard_varies_with_seed() {
        let a = randomized_hadamard(16, &mut Rng::new(1));
        let b = randomized_hadamard(16, &mut Rng::new(2));
        assert!(a.max_abs_diff(&b) > 0.1);
    }

    #[test]
    fn fwht_spreads_spike() {
        // A single spike becomes perfectly flat — the outlier-spreading
        // mechanism the paper attributes to Hadamard transforms.
        let n = 128;
        let mut x = vec![0.0; n];
        x[17] = 1.0;
        fwht_inplace(&mut x);
        let expect = 1.0 / (n as f64).sqrt();
        for v in &x {
            assert!((v.abs() - expect).abs() < 1e-12);
        }
    }

    #[test]
    #[should_panic]
    fn non_pow2_panics() {
        let mut x = vec![0.0; 24];
        fwht_inplace(&mut x);
    }
}
