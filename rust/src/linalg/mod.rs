//! Dense linear-algebra substrate.
//!
//! Everything the paper's math needs, implemented from scratch:
//! a dense row-major matrix type, blocked matrix multiplication, Cholesky,
//! a cyclic-Jacobi symmetric eigendecomposition, SPD matrix functions
//! (square root, inverse square root, powers), the **matrix geometric
//! mean** `A # B = A^{1/2} (A^{-1/2} B A^{-1/2})^{1/2} A^{1/2}`
//! (Pusz & Woronowicz, 1975) that defines the paper's alignment-optimal
//! transform (eq. 7), fast Walsh–Hadamard transforms, random orthogonal
//! matrices, and a deterministic PRNG.
//!
//! Analysis math runs in `f64`; the model substrate uses `f32` tensors
//! (see [`crate::model::tensor`]).
//!
//! The matmul kernels are 4×8 **register-tiled** micro-kernels (one
//! accumulator per output element, ascending-`k` order, right operand
//! packed into contiguous panels — see `matmul`'s module docs) and
//! *dispatchers*: large problems run on the scoped thread pool in
//! [`par`] (worker count via `CATQUANT_THREADS`), small ones stay on the
//! serial kernels (`*_serial`, also exported as the bit-exact reference
//! for benches and property tests). [`syrk_at_a`] computes the
//! covariance self-product `XᵀX` at half the FLOPs (upper triangle +
//! mirror, bit-identical to `matmul_at_b(x, x)`). See PERF.md.
//!
//! [`qmatmul_a_bt`] is the integer sibling: packed quantized codes in,
//! i32/i64-accumulated dot products plus the affine correction out —
//! the serving path's true low-bit kernel (see [`qkernel`](self)).
//!
//! The innermost micro-kernels — the i16 dot and the 4×8 f64 tiles —
//! dispatch at runtime across explicit AVX-512/AVX2/NEON `std::arch`
//! paths ([`simd`], `CATQUANT_SIMD` knob); every path is bit-identical
//! to the always-compiled scalar reference, so ISA choice is a pure
//! speed decision that the exactness properties above never see.

mod chol;
mod eigen;
mod funcs;
mod hadamard;
mod mat;
mod matmul;
mod orthogonal;
pub mod par;
mod qkernel;
mod rng;
pub mod simd;

pub use chol::Cholesky;
pub use eigen::{eigh, Eigh};
pub use funcs::{geometric_mean, spd_inv, spd_inv_sqrt, spd_pow, spd_sqrt};
pub use hadamard::{fwht_inplace, hadamard_matrix, is_pow2, randomized_hadamard};
pub use mat::Mat;
pub use matmul::{
    matmul, matmul_a_bt, matmul_a_bt_cached, matmul_a_bt_serial, matmul_at_b,
    matmul_at_b_serial, matmul_serial, matmul_serial_ref, matvec, matvec_serial, syrk_at_a,
};
pub use orthogonal::random_orthogonal;
pub use qkernel::{
    qmatmul_a_bt, qmatmul_a_bt_panels, qmatmul_a_bt_serial, QCodes, QMatView, QPanels,
    MAX_I16_PATH_COLS,
};
pub use rng::Rng;
