//! Matrix functions on symmetric positive-(semi)definite matrices, and the
//! matrix geometric mean that defines the paper's alignment-optimal
//! transform.
//!
//! All functions go through the spectral decomposition ([`super::eigh`]),
//! with eigenvalues clamped at a relative floor so that nearly-singular
//! covariance estimates (e.g. from a small calibration set) stay usable —
//! the same role the paper's damping plays.

use super::{eigh, matmul, Mat};

/// Relative eigenvalue floor for SPD matrix functions.
const EIG_FLOOR_REL: f64 = 1e-12;

/// `A^p` for symmetric PSD `A` via the spectral decomposition, clamping
/// eigenvalues at `max_eig · EIG_FLOOR_REL`.
pub fn spd_pow(a: &Mat, p: f64) -> Mat {
    let e = eigh(a);
    let max_eig = e.values.iter().fold(0.0_f64, |m, &v| m.max(v.abs())).max(1e-300);
    let floor = max_eig * EIG_FLOOR_REL;
    let powd: Vec<f64> = e.values.iter().map(|&v| v.max(floor).powf(p)).collect();
    // V diag(λ^p) Vᵀ
    let n = a.rows();
    let mut vl = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            vl[(i, j)] = e.vectors[(i, j)] * powd[j];
        }
    }
    matmul(&vl, &e.vectors.transpose())
}

/// Symmetric PSD square root `A^{1/2}`.
pub fn spd_sqrt(a: &Mat) -> Mat {
    spd_pow(a, 0.5)
}

/// Symmetric PSD inverse square root `A^{-1/2}`.
pub fn spd_inv_sqrt(a: &Mat) -> Mat {
    spd_pow(a, -0.5)
}

/// Symmetric PSD inverse `A^{-1}` (spectral, clamped).
pub fn spd_inv(a: &Mat) -> Mat {
    spd_pow(a, -1.0)
}

/// Matrix geometric mean `A # B = A^{1/2} (A^{-1/2} B A^{-1/2})^{1/2} A^{1/2}`
/// (Pusz & Woronowicz, 1975).
///
/// This is the closed form behind the paper's eq. 7: the alignment-optimal
/// transform is `M̂ = (Σ_w # Σ_x⁻¹)^{1/2}`. Key properties (tested below):
/// `A # A = A`, `A # B = B # A`, and for commuting operands
/// `A # B = (AB)^{1/2}`.
pub fn geometric_mean(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "geometric_mean shape mismatch");
    let a_half = spd_sqrt(a);
    let a_ihalf = spd_inv_sqrt(a);
    let mut inner = matmul(&matmul(&a_ihalf, b), &a_ihalf);
    inner.symmetrize();
    let inner_half = spd_sqrt(&inner);
    let mut out = matmul(&matmul(&a_half, &inner_half), &a_half);
    out.symmetrize();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{syrk_at_a, Rng};

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let g = Mat::from_fn(n + 8, n, |_, _| rng.normal());
        let mut s = syrk_at_a(&g).scale(1.0 / (n + 8) as f64);
        s.add_diag(0.05);
        s
    }

    #[test]
    fn sqrt_squares_back() {
        let a = random_spd(20, 1);
        let r = spd_sqrt(&a);
        assert!(matmul(&r, &r).max_abs_diff(&a) < 1e-8);
    }

    #[test]
    fn inv_sqrt_whitens() {
        let a = random_spd(16, 2);
        let w = spd_inv_sqrt(&a);
        let white = matmul(&matmul(&w, &a), &w);
        assert!(white.max_abs_diff(&Mat::eye(16)) < 1e-8);
    }

    #[test]
    fn inv_is_inverse() {
        let a = random_spd(14, 3);
        assert!(matmul(&a, &spd_inv(&a)).max_abs_diff(&Mat::eye(14)) < 1e-8);
    }

    #[test]
    fn pow_composes() {
        let a = random_spd(10, 4);
        let p1 = spd_pow(&a, 0.3);
        let p2 = spd_pow(&a, 0.7);
        assert!(matmul(&p1, &p2).max_abs_diff(&a) < 1e-8);
    }

    #[test]
    fn geomean_idempotent() {
        let a = random_spd(12, 5);
        assert!(geometric_mean(&a, &a).max_abs_diff(&a) < 1e-7);
    }

    #[test]
    fn geomean_symmetric_in_arguments() {
        let a = random_spd(10, 6);
        let b = random_spd(10, 7);
        let ab = geometric_mean(&a, &b);
        let ba = geometric_mean(&b, &a);
        assert!(ab.max_abs_diff(&ba) < 1e-7, "diff {}", ab.max_abs_diff(&ba));
    }

    #[test]
    fn geomean_of_identity_is_sqrt() {
        let a = random_spd(9, 8);
        let g = geometric_mean(&a, &Mat::eye(9));
        assert!(g.max_abs_diff(&spd_sqrt(&a)) < 1e-8);
    }

    #[test]
    fn geomean_diagonal_case() {
        // For diagonal matrices the geometric mean is elementwise sqrt(ab).
        let a = Mat::diag(&[1.0, 4.0, 9.0]);
        let b = Mat::diag(&[4.0, 1.0, 16.0]);
        let g = geometric_mean(&a, &b);
        let want = Mat::diag(&[2.0, 2.0, 12.0]);
        assert!(g.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn geomean_satisfies_riccati() {
        // G = A # B is the unique SPD solution of G A⁻¹ G = B.
        let a = random_spd(8, 9);
        let b = random_spd(8, 10);
        let g = geometric_mean(&a, &b);
        let lhs = matmul(&matmul(&g, &spd_inv(&a)), &g);
        assert!(lhs.max_abs_diff(&b) < 1e-6, "diff {}", lhs.max_abs_diff(&b));
    }
}
