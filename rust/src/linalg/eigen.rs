//! Symmetric eigendecomposition via cyclic Jacobi rotations.
//!
//! Jacobi is slower than Householder+QL asymptotically but is simple,
//! numerically robust, and produces orthogonal eigenvectors to machine
//! precision — which matters because the transform builders chain several
//! matrix functions (inverse square roots, geometric means) and error
//! compounds. Each sweep is `O(n³)`; convergence is quadratic and a
//! handful of sweeps suffice. CAT's block transforms only need `k×k`
//! eigendecompositions (k ≤ 128), where Jacobi is effectively free.

use super::Mat;

/// Result of [`eigh`]: `A = V · diag(λ) · Vᵀ`.
pub struct Eigh {
    /// Eigenvalues in ascending order.
    pub values: Vec<f64>,
    /// Eigenvectors as *columns* of `V`, in the same order.
    pub vectors: Mat,
}

/// Eigendecomposition of a symmetric matrix via cyclic Jacobi.
///
/// The input is assumed symmetric; only its upper triangle is read after
/// the initial copy. Panics on non-square input.
pub fn eigh(a: &Mat) -> Eigh {
    assert!(a.is_square(), "eigh needs a square matrix");
    let n = a.rows();
    let mut m = a.clone();
    m.symmetrize();
    let mut v = Mat::eye(n);

    if n <= 1 {
        return Eigh { values: (0..n).map(|i| m[(i, i)]).collect(), vectors: v };
    }

    let max_sweeps = 64;
    let mut tp = vec![0.0f64; n];
    let mut tq = vec![0.0f64; n];
    for _sweep in 0..max_sweeps {
        // Off-diagonal magnitude.
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        let scale = m.fro_norm2().max(1e-300);
        if off / scale < 1e-26 {
            break;
        }

        for p in 0..n - 1 {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                // Stable rotation computation (Golub & Van Loan §8.5).
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;

                // Two-sided update exploiting symmetry (§Perf): compute
                // the new rows p and q with one contiguous pass (the
                // right-multiplication only affects the (p,p),(p,q),(q,q)
                // entries, fixed explicitly), then mirror into the two
                // columns. This replaces the old full row+column sweeps —
                // half the strided traffic.
                {
                    // Contiguous combine of rows p and q into scratch.
                    let rp = m.row(p);
                    let rq = m.row(q);
                    for k in 0..n {
                        let a = rp[k];
                        let b = rq[k];
                        tp[k] = c * a - s * b;
                        tq[k] = s * a + c * b;
                    }
                    tp[p] = c * c * app - 2.0 * s * c * apq + s * s * aqq;
                    tq[q] = s * s * app + 2.0 * s * c * apq + c * c * aqq;
                    tp[q] = 0.0;
                    tq[p] = 0.0;
                    m.row_mut(p).copy_from_slice(&tp);
                    m.row_mut(q).copy_from_slice(&tq);
                    // Mirror into the two columns (symmetry).
                    for k in 0..n {
                        if k != p && k != q {
                            m[(k, p)] = tp[k];
                            m[(k, q)] = tq[k];
                        }
                    }
                }
                // Accumulate eigenvectors, stored transposed (rows =
                // eigenvectors) so this is a contiguous row-pair combine.
                {
                    let (left, right) = v.as_mut_slice().split_at_mut(q * n);
                    let vp = &mut left[p * n..p * n + n];
                    let vq = &mut right[..n];
                    for k in 0..n {
                        let a = vp[k];
                        let b = vq[k];
                        vp[k] = c * a - s * b;
                        vq[k] = s * a + c * b;
                    }
                }
            }
        }
    }

    // Extract and sort ascending. `v` holds eigenvectors as *rows*;
    // transpose into the column convention on output.
    let mut idx: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    idx.sort_by(|&i, &j| diag[i].partial_cmp(&diag[j]).unwrap());
    let values: Vec<f64> = idx.iter().map(|&i| diag[i]).collect();
    let vectors = Mat::from_fn(n, n, |r, c| v[(idx[c], r)]);
    Eigh { values, vectors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_a_bt, matmul_at_b, Rng};

    fn random_sym(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut m = Mat::from_fn(n, n, |_, _| rng.normal());
        m.symmetrize();
        m
    }

    #[test]
    fn reconstruction() {
        let a = random_sym(24, 1);
        let e = eigh(&a);
        let lam = Mat::diag(&e.values);
        let rec = matmul(&matmul(&e.vectors, &lam), &e.vectors.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-9, "diff {}", rec.max_abs_diff(&a));
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let a = random_sym(17, 2);
        let e = eigh(&a);
        let vtv = matmul_at_b(&e.vectors, &e.vectors);
        assert!(vtv.max_abs_diff(&Mat::eye(17)) < 1e-11);
    }

    #[test]
    fn values_sorted_ascending() {
        let a = random_sym(12, 3);
        let e = eigh(&a);
        for w in e.values.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
    }

    #[test]
    fn diagonal_matrix_eigvals() {
        let a = Mat::diag(&[3.0, -1.0, 2.0, 0.5]);
        let e = eigh(&a);
        let want = [-1.0, 0.5, 2.0, 3.0];
        for (got, want) in e.values.iter().zip(want) {
            assert!((got - want).abs() < 1e-12);
        }
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let a = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let e = eigh(&a);
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn spd_eigenvalues_positive() {
        let mut rng = Rng::new(4);
        let g = Mat::from_fn(40, 32, |_, _| rng.normal());
        let s = matmul_at_b(&g, &g);
        let e = eigh(&s);
        assert!(e.values.iter().all(|&v| v > -1e-9));
    }

    #[test]
    fn trace_preserved() {
        let a = random_sym(15, 5);
        let e = eigh(&a);
        let tr: f64 = e.values.iter().sum();
        assert!((tr - a.trace()).abs() < 1e-9);
    }

    #[test]
    fn large_matrix_accuracy() {
        // The size CAT's full-rank alignment optimum needs (d=256 layers).
        let a = random_sym(128, 6);
        let e = eigh(&a);
        let lam = Mat::diag(&e.values);
        let rec = matmul_a_bt(&matmul(&e.vectors, &lam), &e.vectors);
        assert!(rec.max_abs_diff(&a) < 1e-8);
    }
}
