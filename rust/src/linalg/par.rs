//! Dependency-free parallel execution layer.
//!
//! Everything compute-heavy in the crate funnels through the four
//! dispatchers in [`super::matmul`]; this module supplies their threaded
//! halves plus a generic task fan-out ([`par_map`]) used by the pipeline
//! orchestrator, GPTQ, attention, and batched eval.
//!
//! Design constraints (see PERF.md):
//!
//! * **No dependencies.** Workers are `std::thread::scope` threads, so
//!   borrowed inputs (`&Mat`) flow in without `Arc` or `'static` bounds.
//! * **Determinism.** Kernels partition *output rows* only; each row is
//!   accumulated in the exact serial order, so parallel results are
//!   bit-identical to the serial reference at any worker count. Since
//!   PR 6 the same holds across instruction sets: the [`super::simd`]
//!   micro-kernel paths (AVX-512/AVX2/NEON/scalar, `CATQUANT_SIMD`) all
//!   preserve each element's single ascending-`k` accumulator, so worker
//!   count × ISA is a pure speed matrix — every cell bit-identical.
//! * **Serial fallback.** Below [`PAR_MIN_FMA`] fused multiply-adds the
//!   spawn cost (tens of µs) outweighs the win and dispatchers stay on
//!   the serial kernels.
//!
//! Worker count: `CATQUANT_THREADS` env var if set (clamped to 1..=256),
//! else the OS-reported parallelism (no `num_cpus` crate needed), else
//! 4. Coarse compute-bound fan-outs — per-(block,group) pipeline builds,
//! per-sequence eval forwards — pass [`num_threads`] to [`par_map`]
//! directly and scale with cores. Jobs that stream shared matrices —
//! the matmul kernels, GPTQ rows, attention heads — size themselves via
//! [`threads_for`], which adds the [`KERNEL_MAX_THREADS`] bandwidth cap
//! and the [`PAR_MIN_FMA`] serial-fallback gate.

use super::matmul::{
    matmul_a_bt_ct_rows, matmul_a_bt_ct_rows_panel, matmul_a_bt_rows, matmul_at_b_rows,
    matmul_rows, matvec_rows, syrk_rows, transpose_ct_into,
};
use super::Mat;
use std::cell::Cell;
use std::sync::{mpsc, Mutex, OnceLock};

// ---------------------------------------------------------------------
// Thread-local scratch workspace
// ---------------------------------------------------------------------
//
// The decode/forward hot loops call small GEMV-shaped kernels thousands
// of times per generated token; allocating panel/unpack/Cᵀ buffers per
// call was a measurable slice of each step. Each thread keeps one
// reusable buffer per element type instead. Contents are *arbitrary* on
// entry (stale data from the previous borrow) — callers must overwrite
// every element they read back. A nested borrow (kernel inside a kernel
// on one thread) falls back to a fresh allocation and restores the outer
// buffer on exit, so the scheme is reentrant-safe.

thread_local! {
    static SCRATCH_F64: Cell<Vec<f64>> = const { Cell::new(Vec::new()) };
    static SCRATCH_I16: Cell<Vec<i16>> = const { Cell::new(Vec::new()) };
    static SCRATCH_I32: Cell<Vec<i32>> = const { Cell::new(Vec::new()) };
}

/// Largest buffer (bytes) a thread keeps cached between `with_scratch_*`
/// calls. Decode-loop buffers (panels, small-batch Cᵀ staging, code
/// unpacks) sit far below this and get full reuse; a rare huge request
/// is served by a one-off allocation that is dropped on exit instead of
/// staying pinned in a long-lived server thread's TLS forever.
const MAX_CACHED_SCRATCH_BYTES: usize = 1 << 20;

macro_rules! with_scratch_impl {
    ($name:ident, $cell:ident, $ty:ty, $zero:expr) => {
        /// Run `f` over this thread's reusable scratch, grown to `len`.
        /// Contents are arbitrary on entry; callers must overwrite every
        /// element they read back.
        pub(crate) fn $name<R>(len: usize, f: impl FnOnce(&mut [$ty]) -> R) -> R {
            let mut buf = $cell.with(|c| c.take());
            if buf.len() < len {
                buf.resize(len, $zero);
            }
            let r = f(&mut buf[..len]);
            if buf.len() * std::mem::size_of::<$ty>() <= MAX_CACHED_SCRATCH_BYTES {
                $cell.with(|c| c.set(buf));
            }
            r
        }
    };
}

with_scratch_impl!(with_scratch_f64, SCRATCH_F64, f64, 0.0);
with_scratch_impl!(with_scratch_i16, SCRATCH_I16, i16, 0);
with_scratch_impl!(with_scratch_i32, SCRATCH_I32, i32, 0);

thread_local! {
    /// True while this thread is executing inside a parallel worker.
    /// Nested fan-outs (a kernel inside a `par_map` job inside another
    /// `par_map` job) then stay serial, so one level of parallelism uses
    /// the machine instead of multiplying thread counts per level.
    static IN_WORKER: Cell<bool> = const { Cell::new(false) };
}

fn in_worker() -> bool {
    IN_WORKER.with(|c| c.get())
}

/// Marks the current thread as a worker for its lifetime scope; restores
/// the previous state on drop (the calling thread can double as a
/// worker and return to top-level afterwards).
struct WorkerGuard {
    prev: bool,
}

impl WorkerGuard {
    fn enter() -> WorkerGuard {
        WorkerGuard { prev: IN_WORKER.with(|c| c.replace(true)) }
    }
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        IN_WORKER.with(|c| c.set(prev));
    }
}

/// Minimum fused multiply-adds before the dispatchers go parallel.
/// 4 Mi FMA ≈ a 160³ matmul ≈ 2–4 ms serial — roughly 30× the cost of
/// spawning a scoped worker set, so the crossover has safety margin.
pub const PAR_MIN_FMA: usize = 4 * 1024 * 1024;

/// Worker cap applied by [`threads_for`]: jobs that sweep shared
/// matrices (matmul rows, GPTQ rows, attention heads) saturate memory
/// bandwidth around here on typical hosts. Coarse task fan-outs
/// ([`par_map`] with [`num_threads`]) are compute-bound and uncapped.
pub const KERNEL_MAX_THREADS: usize = 8;

/// The configured worker count (resolved once per process).
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("CATQUANT_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                return n.clamp(1, 256);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(256)
    })
}

/// Worker count for a *kernel* of `work_fma` fused multiply-adds
/// splittable into `parts` independent pieces: 1 (stay serial) below
/// the threshold or when already inside a parallel worker, otherwise
/// `num_threads()` capped by [`KERNEL_MAX_THREADS`] and `parts`.
pub fn threads_for(work_fma: usize, parts: usize) -> usize {
    if in_worker() || work_fma < PAR_MIN_FMA || parts <= 1 {
        1
    } else {
        num_threads().min(KERNEL_MAX_THREADS).min(parts).max(1)
    }
}

/// Partition a row-major `rows × cols` buffer into contiguous row chunks
/// and run `f(first_row, chunk)` on each: one scoped worker per chunk
/// except the last, which the calling thread computes itself (one fewer
/// spawn per kernel call, and the caller's core is never idle).
pub(crate) fn par_rows(
    data: &mut [f64],
    cols: usize,
    threads: usize,
    f: impl Fn(usize, &mut [f64]) + Sync,
) {
    if data.is_empty() {
        return;
    }
    let rows = data.len() / cols;
    let t = if in_worker() { 1 } else { threads.min(rows).max(1) };
    if t <= 1 {
        f(0, data);
        return;
    }
    let chunk_rows = rows.div_ceil(t);
    let mut chunks: Vec<(usize, &mut [f64])> =
        data.chunks_mut(chunk_rows * cols).enumerate().collect();
    let tail = chunks.pop();
    std::thread::scope(|s| {
        for (ci, chunk) in chunks {
            let f = &f;
            s.spawn(move || {
                let _guard = WorkerGuard::enter();
                f(ci * chunk_rows, chunk);
            });
        }
        if let Some((ci, chunk)) = tail {
            let _guard = WorkerGuard::enter();
            f(ci * chunk_rows, chunk);
        }
    });
}

/// Threaded `C = A · B` (callers: use the dispatching [`super::matmul`]).
pub fn matmul_mt(a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    let mut c = Mat::zeros(a.rows(), b.cols());
    let cols = b.cols();
    par_rows(c.as_mut_slice(), cols, threads, |r0, out| matmul_rows(a, b, r0, out));
    c
}

/// Threaded `C = Aᵀ · B`.
pub fn matmul_at_b_mt(a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b shape mismatch");
    let mut c = Mat::zeros(a.cols(), b.cols());
    let cols = b.cols();
    par_rows(c.as_mut_slice(), cols, threads, |r0, out| matmul_at_b_rows(a, b, r0, out));
    c
}

/// Threaded `C = A · Bᵀ`.
pub fn matmul_a_bt_mt(a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt shape mismatch");
    let mut c = Mat::zeros(a.rows(), b.rows());
    let cols = b.rows();
    par_rows(c.as_mut_slice(), cols, threads, |r0, out| matmul_a_bt_rows(a, b, r0, out));
    c
}

/// Threaded `C = A · Bᵀ` partitioned over `B`'s rows (output channels)
/// — the decode/GEMV shape where `A` has only a handful of rows and
/// row-partitioning `C` would leave workers idle. Computes `Cᵀ` in
/// contiguous chunks, then transposes; each element is the same serial
/// `dot`, so results are bit-identical to [`matmul_a_bt_mt`].
pub fn matmul_a_bt_ct_mt(a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt shape mismatch");
    let (m, n) = (a.rows(), b.rows());
    let mut c = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return c;
    }
    with_scratch_f64(n * m, |ct| {
        par_rows(ct, m, threads, |j0, out| matmul_a_bt_ct_rows(a, b, j0, out));
        transpose_ct_into(ct, m, &mut c);
    });
    c
}

/// [`matmul_a_bt_ct_mt`] over `b`'s lazily built persistent packed
/// panels ([`Mat::bt_panels`]) — the decode fast path for *static* right
/// operands (weights, transforms): no per-call packing, contiguous
/// panel lanes in the inner loop. Bit-identical to every other
/// `A · Bᵀ` partitioning.
pub fn matmul_a_bt_ct_panels_mt(a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt shape mismatch");
    let (m, n) = (a.rows(), b.rows());
    let mut c = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return c;
    }
    let bp = b.bt_panels();
    with_scratch_f64(n * m, |ct| {
        par_rows(ct, m, threads, |j0, out| matmul_a_bt_ct_rows_panel(a, bp, j0, out));
        transpose_ct_into(ct, m, &mut c);
    });
    c
}

/// Threaded upper-triangle rows of `Σ = AᵀA` into `c` (callers:
/// [`super::syrk_at_a`](super::syrk_at_a), which mirrors afterwards).
///
/// Row `i` of the triangle costs ~`(m − i)` FMAs per `k` step, so equal
/// row counts would hand the first worker ~half the work; chunk
/// boundaries instead balance cumulative triangle *area*. Each row is
/// still computed whole by one worker in the serial order, so the
/// partitioning never changes a bit of the result.
pub(crate) fn syrk_mt(a: &Mat, threads: usize, c: &mut Mat) {
    let m = a.cols();
    let t = if in_worker() { 1 } else { threads.min(m).max(1) };
    if t <= 1 {
        syrk_rows(a, 0, c.as_mut_slice());
        return;
    }
    // bounds[ci] = first row of chunk ci; chunk ci covers rows where the
    // cumulative weight Σ(m − i) first reaches fraction ci/t of the total.
    let total = (m as u64) * (m as u64 + 1) / 2;
    let mut bounds = vec![m; t + 1];
    bounds[0] = 0;
    let mut acc = 0u64;
    let mut ci = 1;
    for i in 0..m {
        acc += (m - i) as u64;
        if ci < t && acc * (t as u64) >= total * (ci as u64) {
            bounds[ci] = i + 1;
            ci += 1;
        }
    }
    let data = c.as_mut_slice();
    std::thread::scope(|s| {
        let mut rest = data;
        let mut first = None;
        for ci in 0..t {
            let (lo, hi) = (bounds[ci], bounds[ci + 1]);
            let (chunk, tail) = rest.split_at_mut((hi - lo) * m);
            rest = tail;
            if ci == 0 {
                // The heaviest chunk runs on the calling thread (one
                // fewer spawn, and the caller's core is never idle).
                first = Some((lo, chunk));
            } else if !chunk.is_empty() {
                s.spawn(move || {
                    let _guard = WorkerGuard::enter();
                    syrk_rows(a, lo, chunk);
                });
            }
        }
        if let Some((lo, chunk)) = first {
            let _guard = WorkerGuard::enter();
            syrk_rows(a, lo, chunk);
        }
    });
}

/// Threaded `y = A · x`.
pub fn matvec_mt(a: &Mat, x: &[f64], threads: usize) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    let mut y = vec![0.0; a.rows()];
    par_rows(&mut y, 1, threads, |r0, out| matvec_rows(a, x, r0, out));
    y
}

/// Order-preserving parallel map over owned items.
///
/// Workers pull from a shared queue (so uneven item costs balance) and
/// results come back in input order. The calling thread doubles as one
/// of the workers. With `threads <= 1`, fewer than two items, or when
/// already inside a parallel worker (nested fan-out) this degrades to a
/// plain serial map — callers can pass [`threads_for`] and get the
/// fallback for free. A panicking `f` propagates after all workers join
/// (scoped-thread semantics).
pub fn par_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let t = if in_worker() { 1 } else { threads.min(n).max(1) };
    if t <= 1 {
        return items.into_iter().map(f).collect();
    }
    let queue = Mutex::new(items.into_iter().enumerate());
    let (tx, rx) = mpsc::channel();
    std::thread::scope(|s| {
        for _ in 0..t - 1 {
            let tx = tx.clone();
            let queue = &queue;
            let f = &f;
            s.spawn(move || drain_queue(queue, f, &tx));
        }
        drain_queue(&queue, &f, &tx);
    });
    drop(tx);
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    for (i, r) in rx {
        out[i] = Some(r);
    }
    out.into_iter().map(|o| o.expect("par_map lost an item")).collect()
}

/// One `par_map` worker: pull items until the queue runs dry, sending
/// `(index, result)` pairs back. Marks the thread as a worker so nested
/// fan-outs inside `f` stay serial.
fn drain_queue<T, R, F>(
    queue: &Mutex<std::iter::Enumerate<std::vec::IntoIter<T>>>,
    f: &F,
    tx: &mpsc::Sender<(usize, R)>,
) where
    F: Fn(T) -> R,
{
    let _guard = WorkerGuard::enter();
    loop {
        let next = queue.lock().unwrap().next();
        match next {
            Some((i, item)) => {
                let _ = tx.send((i, f(item)));
            }
            None => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul_a_bt_serial, matmul_at_b_serial, matmul_serial, Rng};

    fn random(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(rows, cols, |_, _| rng.normal())
    }

    #[test]
    fn threads_for_stays_serial_below_threshold() {
        assert_eq!(threads_for(PAR_MIN_FMA - 1, 64), 1);
        assert_eq!(threads_for(PAR_MIN_FMA, 1), 1);
        let t = threads_for(PAR_MIN_FMA, 3);
        assert!((1..=3).contains(&t));
    }

    #[test]
    fn num_threads_is_sane() {
        let n = num_threads();
        assert!((1..=256).contains(&n), "num_threads {n}");
    }

    #[test]
    fn mt_kernels_match_serial_exactly() {
        let a = random(37, 53, 1);
        let b = random(53, 29, 2);
        for t in [1, 2, 5, 8] {
            assert_eq!(
                matmul_mt(&a, &b, t).max_abs_diff(&matmul_serial(&a, &b)),
                0.0,
                "matmul_mt t={t}"
            );
        }
        let x = random(64, 37, 3);
        let y = random(64, 41, 4);
        for t in [2, 7] {
            assert_eq!(
                matmul_at_b_mt(&x, &y, t).max_abs_diff(&matmul_at_b_serial(&x, &y)),
                0.0
            );
        }
        let w = random(23, 53, 5);
        assert_eq!(
            matmul_a_bt_mt(&a, &w, 3).max_abs_diff(&matmul_a_bt_serial(&a, &w)),
            0.0
        );
    }

    #[test]
    fn colpart_a_bt_matches_rowpart_exactly() {
        // The decode/GEMV partitioning (over B's rows) must agree with
        // both the row-partitioned and serial kernels bit-for-bit, for
        // any worker count — including single-row A (pure GEMV).
        for m in [1usize, 3, 7] {
            let a = random(m, 67, 20 + m as u64);
            let b = random(143, 67, 30 + m as u64);
            let want = matmul_a_bt_serial(&a, &b);
            for t in [1, 2, 5, 8] {
                assert_eq!(
                    matmul_a_bt_ct_mt(&a, &b, t).max_abs_diff(&want),
                    0.0,
                    "m={m} t={t}"
                );
                assert_eq!(
                    matmul_a_bt_ct_panels_mt(&a, &b, t).max_abs_diff(&want),
                    0.0,
                    "panels m={m} t={t}"
                );
            }
            assert_eq!(matmul_a_bt_mt(&a, &b, 4).max_abs_diff(&want), 0.0);
        }
    }

    #[test]
    fn par_map_preserves_order_and_items() {
        let items: Vec<usize> = (0..100).collect();
        let got = par_map(items, 8, |i| i * 3);
        let want: Vec<usize> = (0..100).map(|i| i * 3).collect();
        assert_eq!(got, want);
        // Serial degenerate cases.
        assert_eq!(par_map(vec![7usize], 8, |i| i + 1), vec![8]);
        assert_eq!(par_map(Vec::<usize>::new(), 4, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn nested_fanouts_serialize() {
        // Inside a par_map worker, further fan-outs must stay serial —
        // one level of parallelism, not a multiplicative thread storm.
        let inner: Vec<usize> =
            par_map((0..4).collect(), 4, |_| threads_for(PAR_MIN_FMA * 2, 64));
        assert_eq!(inner, vec![1, 1, 1, 1]);
        // The calling thread (which doubled as a worker) is restored to
        // top level afterwards.
        assert!(threads_for(PAR_MIN_FMA * 2, 64) >= 1);
        assert!(!super::in_worker());
    }

    #[test]
    fn par_map_balances_uneven_work() {
        // Items with wildly different costs still come back in order.
        let items: Vec<usize> = (0..16).collect();
        let got = par_map(items, 4, |i| {
            let mut acc = 0.0f64;
            let iters = if i % 4 == 0 { 20_000 } else { 10 };
            for k in 0..iters {
                acc += (k as f64).sqrt();
            }
            (i, acc > -1.0)
        });
        for (i, (gi, ok)) in got.iter().enumerate() {
            assert_eq!(*gi, i);
            assert!(ok);
        }
    }
}
