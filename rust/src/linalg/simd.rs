//! Runtime-dispatched SIMD micro-kernels (`std::arch`) for the two
//! hottest inner loops: the i16×i16→i32 quantized dot ([`qdot_i16`])
//! and the 4×8 f64 register tiles ([`mk4`]/[`mk1`]/[`tile4x8_strided`]).
//!
//! # Dispatch
//!
//! The active ISA is resolved once per process from the `CATQUANT_SIMD`
//! env knob (`auto|avx512|avx2|neon|scalar`, default `auto`) and runtime
//! feature detection (`is_x86_feature_detected!`); `auto` picks the best
//! supported path (AVX-512 > AVX2 > NEON > scalar). Benches and tests
//! can flip the path in-process via [`set_active`] (scalar-vs-SIMD A/Bs
//! share one binary) or call the `*_with` variants to pin an ISA per
//! call without touching global state. The scalar kernels are always
//! compiled and are the reference every SIMD path must match.
//!
//! # Bit-exactness
//!
//! Every path here is **bit-identical** to the scalar reference
//! (`kernel_tile_props` pins this at `== 0.0`):
//!
//! - The f64 kernels vectorize *across the NR=8 output columns*: each
//!   SIMD lane holds a different output element's single accumulator and
//!   `k` still walks in ascending order, so each element sees exactly
//!   the scalar sequence of operations. The multiplies and adds are kept
//!   **unfused** (`mul_pd` + `add_pd`, never `fmadd`): the scalar kernel
//!   `acc += x·b` rounds twice per step, and a fused FMA would round
//!   once — a different result. The speedup comes from lane width, not
//!   fusion.
//! - The integer dot is exact in any association (no rounding), so
//!   `madd_epi16`-style pairwise grouping is free to differ from the
//!   scalar 8-lane split.
//!
//! # Overflow safety (`qdot_i16`)
//!
//! Stored codes are ≤ 128 in magnitude, so each i16 product is ≤ 2^14.
//! At the fast-path bound `k = MAX_I16_PATH_COLS = 2^19`
//! (see [`super::qkernel`]), the per-lane i32 accumulators stay in
//! range on every path:
//!
//! | path    | lanes | products/lane/step | lane bound at k = 2^19 |
//! |---------|-------|--------------------|------------------------|
//! | scalar  | 8×i32 | 1 (≤ 2^14)         | k/8·2^14 = 2^30        |
//! | AVX2    | 8×i32 | 2 (madd, ≤ 2^15)   | k/16·2^15 = 2^30       |
//! | AVX-512 | 16×i32| 2 (madd, ≤ 2^15)   | k/32·2^15 = 2^29       |
//! | NEON    | 2×4×i32| 1 (vmlal, ≤ 2^14) | k/8·2^14 = 2^30        |
//!
//! All ≤ 2^30 < `i32::MAX` with 2× margin; lane totals then widen to
//! i64. The boundary property test in `kernel_tile_props` drives
//! ±max-code vectors at exactly `k = 2^19` through every supported ISA.

use super::matmul::{MR, NR};
use std::sync::atomic::{AtomicU8, Ordering};

/// Instruction-set paths the kernels can dispatch to.
#[repr(u8)]
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Isa {
    /// Portable reference kernels (always available, always compiled).
    Scalar = 0,
    /// aarch64 NEON (`vmlal` integer widening MLA, 2-wide f64 lanes).
    Neon = 1,
    /// x86-64 AVX2 (`_mm256_madd_epi16`, 4-wide f64 lanes).
    Avx2 = 2,
    /// x86-64 AVX-512 F+BW (`_mm512_madd_epi16`, 8-wide f64 lanes).
    Avx512 = 3,
}

impl Isa {
    /// Every ISA, worst to best (iteration order for tests/benches).
    pub const ALL: [Isa; 4] = [Isa::Scalar, Isa::Neon, Isa::Avx2, Isa::Avx512];

    /// The `CATQUANT_SIMD` spelling of this ISA.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Neon => "neon",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
        }
    }

    fn from_u8(v: u8) -> Isa {
        match v {
            0 => Isa::Scalar,
            1 => Isa::Neon,
            2 => Isa::Avx2,
            3 => Isa::Avx512,
            _ => unreachable!("invalid Isa discriminant {v}"),
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn x86_avx2() -> bool {
    is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn x86_avx2() -> bool {
    false
}

#[cfg(target_arch = "x86_64")]
fn x86_avx512() -> bool {
    is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx512bw")
}

#[cfg(not(target_arch = "x86_64"))]
fn x86_avx512() -> bool {
    false
}

fn arm_neon() -> bool {
    // NEON is a mandatory aarch64 feature; no runtime probe needed.
    cfg!(target_arch = "aarch64")
}

/// Whether this host can execute `isa`'s kernels.
pub fn supported(isa: Isa) -> bool {
    match isa {
        Isa::Scalar => true,
        Isa::Neon => arm_neon(),
        Isa::Avx2 => x86_avx2(),
        Isa::Avx512 => x86_avx512(),
    }
}

/// Best ISA this host supports (what `CATQUANT_SIMD=auto` resolves to).
pub fn detected() -> Isa {
    if supported(Isa::Avx512) {
        Isa::Avx512
    } else if supported(Isa::Avx2) {
        Isa::Avx2
    } else if supported(Isa::Neon) {
        Isa::Neon
    } else {
        Isa::Scalar
    }
}

const UNRESOLVED: u8 = u8::MAX;

/// Resolved-once active ISA ([`UNRESOLVED`] until first use; benches may
/// overwrite it via [`set_active`]).
static ACTIVE: AtomicU8 = AtomicU8::new(UNRESOLVED);

fn resolve_from_env() -> Isa {
    let Ok(raw) = std::env::var("CATQUANT_SIMD") else {
        return detected();
    };
    let req = raw.trim().to_ascii_lowercase();
    let want = match req.as_str() {
        "" | "auto" => return detected(),
        "scalar" => Isa::Scalar,
        "neon" => Isa::Neon,
        "avx2" => Isa::Avx2,
        "avx512" => Isa::Avx512,
        other => {
            eprintln!(
                "CATQUANT_SIMD={other:?}: unknown (want auto|avx512|avx2|neon|scalar); \
                 using {}",
                detected().name()
            );
            return detected();
        }
    };
    if supported(want) {
        want
    } else {
        eprintln!(
            "CATQUANT_SIMD={}: not supported on this host; using {}",
            want.name(),
            detected().name()
        );
        detected()
    }
}

/// The ISA the dispatching kernels currently use. Resolved from
/// `CATQUANT_SIMD` + feature detection on first call, then cached.
pub fn active() -> Isa {
    let v = ACTIVE.load(Ordering::Relaxed);
    if v != UNRESOLVED {
        return Isa::from_u8(v);
    }
    let isa = resolve_from_env();
    ACTIVE.store(isa as u8, Ordering::Relaxed);
    isa
}

/// Force the active ISA (benches A/B scalar vs SIMD in one process;
/// tests pin paths). Returns `false` — and changes nothing — if the
/// host can't execute `isa`. Every path is bit-identical, so flipping
/// this mid-computation can never change a result, only its speed.
pub fn set_active(isa: Isa) -> bool {
    if !supported(isa) {
        return false;
    }
    ACTIVE.store(isa as u8, Ordering::Relaxed);
    true
}

// ---------------------------------------------------------------------
// qdot_i16 — i16×i16→i32-lane→i64 dot product
// ---------------------------------------------------------------------

/// Dispatching i16 dot product (see module docs for the per-ISA
/// overflow bounds). Integer accumulation is exact, so every path
/// returns the same value.
#[inline]
pub fn qdot_i16(a: &[i16], b: &[i16]) -> i64 {
    qdot_i16_with(active(), a, b)
}

/// [`qdot_i16`] on an explicit ISA (`isa` must be [`supported`]) —
/// per-ISA tests and benches without global state.
pub fn qdot_i16_with(isa: Isa, a: &[i16], b: &[i16]) -> i64 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert!(supported(isa));
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::qdot_i16_avx2(a, b) },
        #[cfg(target_arch = "x86_64")]
        Isa::Avx512 => unsafe { x86::qdot_i16_avx512(a, b) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { arm::qdot_i16_neon(a, b) },
        _ => qdot_i16_scalar(a, b),
    }
}

/// Eight-lane scalar reference (the pre-SIMD kernel, kept verbatim).
/// Independent accumulators break the dependency chain so LLVM can emit
/// SIMD integer lanes even at the default target; unlike f64, integer
/// addition is associative, so the lane split cannot perturb the result.
fn qdot_i16_scalar(a: &[i16], b: &[i16]) -> i64 {
    let mut acc = [0i32; 8];
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for (l, s) in acc.iter_mut().enumerate() {
            *s += xa[l] as i32 * xb[l] as i32;
        }
    }
    let mut tail = 0i32;
    for (&x, &y) in ra.iter().zip(rb) {
        tail += x as i32 * y as i32;
    }
    acc.iter().map(|&v| v as i64).sum::<i64>() + tail as i64
}

// ---------------------------------------------------------------------
// f64 register-tile micro-kernels
// ---------------------------------------------------------------------

/// 4×NR register-tile micro-kernel over a packed panel:
/// `acc[r][c] += Σ_kk a_r[kk] · panel[kk·NR + c]`, `kk` ascending.
/// Dispatching wrapper; all paths bit-identical (see module docs).
#[inline]
pub(crate) fn mk4(
    a0: &[f64],
    a1: &[f64],
    a2: &[f64],
    a3: &[f64],
    panel: &[f64],
    acc: &mut [[f64; NR]; MR],
) {
    mk4_with(active(), a0, a1, a2, a3, panel, acc)
}

/// [`mk4`] on an explicit ISA (tests pin paths without global state).
pub(crate) fn mk4_with(
    isa: Isa,
    a0: &[f64],
    a1: &[f64],
    a2: &[f64],
    a3: &[f64],
    panel: &[f64],
    acc: &mut [[f64; NR]; MR],
) {
    debug_assert_eq!(panel.len() % NR, 0);
    debug_assert_eq!(a0.len(), panel.len() / NR);
    debug_assert!(supported(isa));
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::mk4_avx2(a0, a1, a2, a3, panel, acc) },
        #[cfg(target_arch = "x86_64")]
        Isa::Avx512 => unsafe { x86::mk4_avx512(a0, a1, a2, a3, panel, acc) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { arm::mk4_neon(a0, a1, a2, a3, panel, acc) },
        _ => mk4_scalar(a0, a1, a2, a3, panel, acc),
    }
}

/// Single-row variant of [`mk4`] (tile-height remainders): NR
/// independent accumulator chains, `kk` ascending.
#[inline]
pub(crate) fn mk1(a0: &[f64], panel: &[f64], acc: &mut [f64; NR]) {
    mk1_with(active(), a0, panel, acc)
}

/// [`mk1`] on an explicit ISA.
pub(crate) fn mk1_with(isa: Isa, a0: &[f64], panel: &[f64], acc: &mut [f64; NR]) {
    debug_assert_eq!(a0.len(), panel.len() / NR);
    debug_assert!(supported(isa));
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::mk1_avx2(a0, panel, acc) },
        #[cfg(target_arch = "x86_64")]
        Isa::Avx512 => unsafe { x86::mk1_avx512(a0, panel, acc) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { arm::mk1_neon(a0, panel, acc) },
        _ => mk1_scalar(a0, panel, acc),
    }
}

/// Full MR×NR tile over *strided* row-major operands (the
/// `matmul_at_b` / `syrk` shape, where both operands are read as row
/// slices instead of packed panels):
/// `acc[r][c] += Σ_{kk∈[k0,k1)} ad[kk·astride + a0 + r] · bd[kk·bstride + b0 + c]`.
/// Callers guarantee the full tile is in range (`a0 + MR ≤ astride`,
/// `b0 + NR ≤ bstride`).
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn tile4x8_strided(
    ad: &[f64],
    astride: usize,
    a0: usize,
    bd: &[f64],
    bstride: usize,
    b0: usize,
    k0: usize,
    k1: usize,
    acc: &mut [[f64; NR]; MR],
) {
    tile4x8_strided_with(active(), ad, astride, a0, bd, bstride, b0, k0, k1, acc)
}

/// [`tile4x8_strided`] on an explicit ISA.
#[allow(clippy::too_many_arguments)]
pub(crate) fn tile4x8_strided_with(
    isa: Isa,
    ad: &[f64],
    astride: usize,
    a0: usize,
    bd: &[f64],
    bstride: usize,
    b0: usize,
    k0: usize,
    k1: usize,
    acc: &mut [[f64; NR]; MR],
) {
    debug_assert!(supported(isa));
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::tile4x8_avx2(ad, astride, a0, bd, bstride, b0, k0, k1, acc) },
        #[cfg(target_arch = "x86_64")]
        Isa::Avx512 => unsafe {
            x86::tile4x8_avx512(ad, astride, a0, bd, bstride, b0, k0, k1, acc)
        },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { arm::tile4x8_neon(ad, astride, a0, bd, bstride, b0, k0, k1, acc) },
        _ => tile4x8_scalar(ad, astride, a0, bd, bstride, b0, k0, k1, acc),
    }
}

/// Scalar reference for [`mk4`] (the pre-SIMD kernel, kept verbatim).
fn mk4_scalar(
    a0: &[f64],
    a1: &[f64],
    a2: &[f64],
    a3: &[f64],
    panel: &[f64],
    acc: &mut [[f64; NR]; MR],
) {
    for (kk, brow) in panel.chunks_exact(NR).enumerate() {
        // Fixed-size view: compile-time length, so the c-loop fully
        // unrolls and bounds checks vanish.
        let brow: &[f64; NR] = brow.try_into().unwrap();
        let x = [a0[kk], a1[kk], a2[kk], a3[kk]];
        for (r, xr) in x.iter().enumerate() {
            for (c, &bv) in brow.iter().enumerate() {
                acc[r][c] += xr * bv;
            }
        }
    }
}

/// Scalar reference for [`mk1`].
fn mk1_scalar(a0: &[f64], panel: &[f64], acc: &mut [f64; NR]) {
    for (kk, brow) in panel.chunks_exact(NR).enumerate() {
        let brow: &[f64; NR] = brow.try_into().unwrap();
        let x = a0[kk];
        for (c, &bv) in brow.iter().enumerate() {
            acc[c] += x * bv;
        }
    }
}

/// Scalar reference for [`tile4x8_strided`] (the inner loop
/// `matmul_at_b_rows`/`syrk_rows` ran inline before the dispatch seam).
#[allow(clippy::too_many_arguments)]
fn tile4x8_scalar(
    ad: &[f64],
    astride: usize,
    a0: usize,
    bd: &[f64],
    bstride: usize,
    b0: usize,
    k0: usize,
    k1: usize,
    acc: &mut [[f64; NR]; MR],
) {
    for kk in k0..k1 {
        let ap: &[f64; MR] = (&ad[kk * astride + a0..kk * astride + a0 + MR]).try_into().unwrap();
        let bp: &[f64; NR] = (&bd[kk * bstride + b0..kk * bstride + b0 + NR]).try_into().unwrap();
        for (accr, &x) in acc.iter_mut().zip(ap) {
            for (av, &bv) in accr.iter_mut().zip(bp) {
                *av += x * bv;
            }
        }
    }
}

// ---------------------------------------------------------------------
// x86-64: AVX2 / AVX-512
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    #![allow(clippy::too_many_arguments)]

    use super::super::matmul::{MR, NR};
    use std::arch::x86_64::*;

    /// `madd_epi16` pairwise dot: 16 i16 per step into 8 i32 lanes.
    /// Each madd lane is a sum of two ≤2^14 products (≤2^15); k/16
    /// steps keep lanes ≤ k·2^11 — in range through k = 2^19.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn qdot_i16_avx2(a: &[i16], b: &[i16]) -> i64 {
        let n = a.len().min(b.len());
        let chunks = n / 16;
        let mut acc = _mm256_setzero_si256();
        for i in 0..chunks {
            let va = _mm256_loadu_si256(a.as_ptr().add(i * 16) as *const __m256i);
            let vb = _mm256_loadu_si256(b.as_ptr().add(i * 16) as *const __m256i);
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(va, vb));
        }
        let lanes: [i32; 8] = std::mem::transmute(acc);
        let mut total: i64 = lanes.iter().map(|&v| v as i64).sum();
        for i in chunks * 16..n {
            total += a[i] as i64 * b[i] as i64;
        }
        total
    }

    /// 32 i16 per step into 16 i32 lanes (lanes ≤ k·2^10).
    #[target_feature(enable = "avx512f,avx512bw")]
    pub(super) unsafe fn qdot_i16_avx512(a: &[i16], b: &[i16]) -> i64 {
        let n = a.len().min(b.len());
        let chunks = n / 32;
        let mut acc = _mm512_setzero_si512();
        for i in 0..chunks {
            let va = _mm512_loadu_epi16(a.as_ptr().add(i * 32));
            let vb = _mm512_loadu_epi16(b.as_ptr().add(i * 32));
            acc = _mm512_add_epi32(acc, _mm512_madd_epi16(va, vb));
        }
        let lanes: [i32; 16] = std::mem::transmute(acc);
        let mut total: i64 = lanes.iter().map(|&v| v as i64).sum();
        for i in chunks * 32..n {
            total += a[i] as i64 * b[i] as i64;
        }
        total
    }

    // The f64 kernels below keep multiply and add unfused (`mul_pd` +
    // `add_pd`, never `fmadd`): the scalar reference rounds twice per
    // step, and bit-exactness is part of the kernel contract.

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn mk4_avx2(
        a0: &[f64],
        a1: &[f64],
        a2: &[f64],
        a3: &[f64],
        panel: &[f64],
        acc: &mut [[f64; NR]; MR],
    ) {
        let a = [a0, a1, a2, a3];
        let mut va = [[_mm256_setzero_pd(); 2]; MR];
        for (vr, accr) in va.iter_mut().zip(acc.iter()) {
            vr[0] = _mm256_loadu_pd(accr.as_ptr());
            vr[1] = _mm256_loadu_pd(accr.as_ptr().add(4));
        }
        for (kk, brow) in panel.chunks_exact(NR).enumerate() {
            let bl = _mm256_loadu_pd(brow.as_ptr());
            let bh = _mm256_loadu_pd(brow.as_ptr().add(4));
            for (vr, ar) in va.iter_mut().zip(&a) {
                let x = _mm256_set1_pd(ar[kk]);
                vr[0] = _mm256_add_pd(vr[0], _mm256_mul_pd(x, bl));
                vr[1] = _mm256_add_pd(vr[1], _mm256_mul_pd(x, bh));
            }
        }
        for (accr, vr) in acc.iter_mut().zip(&va) {
            _mm256_storeu_pd(accr.as_mut_ptr(), vr[0]);
            _mm256_storeu_pd(accr.as_mut_ptr().add(4), vr[1]);
        }
    }

    #[target_feature(enable = "avx512f")]
    pub(super) unsafe fn mk4_avx512(
        a0: &[f64],
        a1: &[f64],
        a2: &[f64],
        a3: &[f64],
        panel: &[f64],
        acc: &mut [[f64; NR]; MR],
    ) {
        let a = [a0, a1, a2, a3];
        let mut va = [_mm512_setzero_pd(); MR];
        for (vr, accr) in va.iter_mut().zip(acc.iter()) {
            *vr = _mm512_loadu_pd(accr.as_ptr());
        }
        for (kk, brow) in panel.chunks_exact(NR).enumerate() {
            let bv = _mm512_loadu_pd(brow.as_ptr());
            for (vr, ar) in va.iter_mut().zip(&a) {
                let x = _mm512_set1_pd(ar[kk]);
                *vr = _mm512_add_pd(*vr, _mm512_mul_pd(x, bv));
            }
        }
        for (accr, vr) in acc.iter_mut().zip(&va) {
            _mm512_storeu_pd(accr.as_mut_ptr(), *vr);
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn mk1_avx2(a0: &[f64], panel: &[f64], acc: &mut [f64; NR]) {
        let mut vl = _mm256_loadu_pd(acc.as_ptr());
        let mut vh = _mm256_loadu_pd(acc.as_ptr().add(4));
        for (kk, brow) in panel.chunks_exact(NR).enumerate() {
            let x = _mm256_set1_pd(a0[kk]);
            vl = _mm256_add_pd(vl, _mm256_mul_pd(x, _mm256_loadu_pd(brow.as_ptr())));
            vh = _mm256_add_pd(vh, _mm256_mul_pd(x, _mm256_loadu_pd(brow.as_ptr().add(4))));
        }
        _mm256_storeu_pd(acc.as_mut_ptr(), vl);
        _mm256_storeu_pd(acc.as_mut_ptr().add(4), vh);
    }

    #[target_feature(enable = "avx512f")]
    pub(super) unsafe fn mk1_avx512(a0: &[f64], panel: &[f64], acc: &mut [f64; NR]) {
        let mut v = _mm512_loadu_pd(acc.as_ptr());
        for (kk, brow) in panel.chunks_exact(NR).enumerate() {
            let x = _mm512_set1_pd(a0[kk]);
            v = _mm512_add_pd(v, _mm512_mul_pd(x, _mm512_loadu_pd(brow.as_ptr())));
        }
        _mm512_storeu_pd(acc.as_mut_ptr(), v);
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn tile4x8_avx2(
        ad: &[f64],
        astride: usize,
        a0: usize,
        bd: &[f64],
        bstride: usize,
        b0: usize,
        k0: usize,
        k1: usize,
        acc: &mut [[f64; NR]; MR],
    ) {
        let mut va = [[_mm256_setzero_pd(); 2]; MR];
        for (vr, accr) in va.iter_mut().zip(acc.iter()) {
            vr[0] = _mm256_loadu_pd(accr.as_ptr());
            vr[1] = _mm256_loadu_pd(accr.as_ptr().add(4));
        }
        for kk in k0..k1 {
            let ap = &ad[kk * astride + a0..kk * astride + a0 + MR];
            let bp = &bd[kk * bstride + b0..kk * bstride + b0 + NR];
            let bl = _mm256_loadu_pd(bp.as_ptr());
            let bh = _mm256_loadu_pd(bp.as_ptr().add(4));
            for (vr, &x) in va.iter_mut().zip(ap) {
                let xv = _mm256_set1_pd(x);
                vr[0] = _mm256_add_pd(vr[0], _mm256_mul_pd(xv, bl));
                vr[1] = _mm256_add_pd(vr[1], _mm256_mul_pd(xv, bh));
            }
        }
        for (accr, vr) in acc.iter_mut().zip(&va) {
            _mm256_storeu_pd(accr.as_mut_ptr(), vr[0]);
            _mm256_storeu_pd(accr.as_mut_ptr().add(4), vr[1]);
        }
    }

    #[target_feature(enable = "avx512f")]
    pub(super) unsafe fn tile4x8_avx512(
        ad: &[f64],
        astride: usize,
        a0: usize,
        bd: &[f64],
        bstride: usize,
        b0: usize,
        k0: usize,
        k1: usize,
        acc: &mut [[f64; NR]; MR],
    ) {
        let mut va = [_mm512_setzero_pd(); MR];
        for (vr, accr) in va.iter_mut().zip(acc.iter()) {
            *vr = _mm512_loadu_pd(accr.as_ptr());
        }
        for kk in k0..k1 {
            let ap = &ad[kk * astride + a0..kk * astride + a0 + MR];
            let bp = &bd[kk * bstride + b0..kk * bstride + b0 + NR];
            let bv = _mm512_loadu_pd(bp.as_ptr());
            for (vr, &x) in va.iter_mut().zip(ap) {
                *vr = _mm512_add_pd(*vr, _mm512_mul_pd(_mm512_set1_pd(x), bv));
            }
        }
        for (accr, vr) in acc.iter_mut().zip(&va) {
            _mm512_storeu_pd(accr.as_mut_ptr(), *vr);
        }
    }
}

// ---------------------------------------------------------------------
// aarch64: NEON
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod arm {
    #![allow(clippy::too_many_arguments)]

    use super::super::matmul::{MR, NR};
    use std::arch::aarch64::*;

    /// `vmlal` widening MLA: 8 i16 per step into 2×4 i32 lanes (each
    /// lane one ≤2^14 product per step — the scalar bound exactly).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn qdot_i16_neon(a: &[i16], b: &[i16]) -> i64 {
        let n = a.len().min(b.len());
        let chunks = n / 8;
        let mut lo = vdupq_n_s32(0);
        let mut hi = vdupq_n_s32(0);
        for i in 0..chunks {
            let va = vld1q_s16(a.as_ptr().add(i * 8));
            let vb = vld1q_s16(b.as_ptr().add(i * 8));
            lo = vmlal_s16(lo, vget_low_s16(va), vget_low_s16(vb));
            hi = vmlal_high_s16(hi, va, vb);
        }
        let mut total = vaddlvq_s32(lo) + vaddlvq_s32(hi);
        for i in chunks * 8..n {
            total += a[i] as i64 * b[i] as i64;
        }
        total
    }

    // f64 kernels: unfused `vmulq` + `vaddq` (never `vfmaq`) — the
    // scalar reference rounds twice per step and bit-exactness is part
    // of the kernel contract.

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn mk4_neon(
        a0: &[f64],
        a1: &[f64],
        a2: &[f64],
        a3: &[f64],
        panel: &[f64],
        acc: &mut [[f64; NR]; MR],
    ) {
        let a = [a0, a1, a2, a3];
        let mut va = [[vdupq_n_f64(0.0); 4]; MR];
        for (vr, accr) in va.iter_mut().zip(acc.iter()) {
            for (q, vq) in vr.iter_mut().enumerate() {
                *vq = vld1q_f64(accr.as_ptr().add(q * 2));
            }
        }
        for (kk, brow) in panel.chunks_exact(NR).enumerate() {
            let mut b = [vdupq_n_f64(0.0); 4];
            for (q, bq) in b.iter_mut().enumerate() {
                *bq = vld1q_f64(brow.as_ptr().add(q * 2));
            }
            for (vr, ar) in va.iter_mut().zip(&a) {
                let x = vdupq_n_f64(ar[kk]);
                for (vq, &bq) in vr.iter_mut().zip(&b) {
                    *vq = vaddq_f64(*vq, vmulq_f64(x, bq));
                }
            }
        }
        for (accr, vr) in acc.iter_mut().zip(&va) {
            for (q, vq) in vr.iter().enumerate() {
                vst1q_f64(accr.as_mut_ptr().add(q * 2), *vq);
            }
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn mk1_neon(a0: &[f64], panel: &[f64], acc: &mut [f64; NR]) {
        let mut va = [vdupq_n_f64(0.0); 4];
        for (q, vq) in va.iter_mut().enumerate() {
            *vq = vld1q_f64(acc.as_ptr().add(q * 2));
        }
        for (kk, brow) in panel.chunks_exact(NR).enumerate() {
            let x = vdupq_n_f64(a0[kk]);
            for (q, vq) in va.iter_mut().enumerate() {
                let bq = vld1q_f64(brow.as_ptr().add(q * 2));
                *vq = vaddq_f64(*vq, vmulq_f64(x, bq));
            }
        }
        for (q, vq) in va.iter().enumerate() {
            vst1q_f64(acc.as_mut_ptr().add(q * 2), *vq);
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn tile4x8_neon(
        ad: &[f64],
        astride: usize,
        a0: usize,
        bd: &[f64],
        bstride: usize,
        b0: usize,
        k0: usize,
        k1: usize,
        acc: &mut [[f64; NR]; MR],
    ) {
        let mut va = [[vdupq_n_f64(0.0); 4]; MR];
        for (vr, accr) in va.iter_mut().zip(acc.iter()) {
            for (q, vq) in vr.iter_mut().enumerate() {
                *vq = vld1q_f64(accr.as_ptr().add(q * 2));
            }
        }
        for kk in k0..k1 {
            let ap = &ad[kk * astride + a0..kk * astride + a0 + MR];
            let bp = &bd[kk * bstride + b0..kk * bstride + b0 + NR];
            let mut b = [vdupq_n_f64(0.0); 4];
            for (q, bq) in b.iter_mut().enumerate() {
                *bq = vld1q_f64(bp.as_ptr().add(q * 2));
            }
            for (vr, &x) in va.iter_mut().zip(ap) {
                let xv = vdupq_n_f64(x);
                for (vq, &bq) in vr.iter_mut().zip(&b) {
                    *vq = vaddq_f64(*vq, vmulq_f64(xv, bq));
                }
            }
        }
        for (accr, vr) in acc.iter_mut().zip(&va) {
            for (q, vq) in vr.iter().enumerate() {
                vst1q_f64(accr.as_mut_ptr().add(q * 2), *vq);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    fn supported_isas() -> Vec<Isa> {
        Isa::ALL.into_iter().filter(|&i| supported(i)).collect()
    }

    #[test]
    fn active_is_supported_and_settable() {
        assert!(supported(active()));
        assert!(supported(detected()));
        let prev = active();
        assert!(set_active(Isa::Scalar));
        assert_eq!(active(), Isa::Scalar);
        assert!(set_active(prev));
        assert_eq!(active(), prev);
    }

    #[test]
    fn qdot_every_isa_matches_naive() {
        // Lengths straddle every chunk width (8/16/32) and their tails.
        let mut rng = Rng::new(42);
        for len in [0usize, 1, 7, 8, 15, 16, 17, 31, 32, 33, 63, 257] {
            let a: Vec<i16> = (0..len).map(|_| (rng.below(257) as i16) - 128).collect();
            let b: Vec<i16> = (0..len).map(|_| (rng.below(257) as i16) - 128).collect();
            let naive: i64 = a.iter().zip(&b).map(|(&x, &y)| x as i64 * y as i64).sum();
            for isa in supported_isas() {
                assert_eq!(qdot_i16_with(isa, &a, &b), naive, "{} len {len}", isa.name());
            }
        }
    }

    #[test]
    fn qdot_every_isa_survives_adversarial_max_codes() {
        // ±max-magnitude stored codes: every product is +2^14, the
        // worst case for the i32 lane accumulators. A long-but-cheap
        // smoke here; the full k = MAX_I16_PATH_COLS boundary proof
        // lives in rust/tests/kernel_tile_props.rs.
        let k = 1 << 14;
        let a = vec![-128i16; k];
        let b = vec![-128i16; k];
        let want = (k as i64) << 14;
        for isa in supported_isas() {
            assert_eq!(qdot_i16_with(isa, &a, &b), want, "{}", isa.name());
        }
    }

    #[test]
    fn f64_kernels_every_isa_bit_equal_to_scalar() {
        // k straddles the chunk loop (0/1/odd/KC-ish); accumulators
        // start non-zero to exercise the load/accumulate/store path.
        let mut rng = Rng::new(7);
        for k in [0usize, 1, 3, 8, 37, 256] {
            let a: Vec<Vec<f64>> =
                (0..MR).map(|_| (0..k).map(|_| rng.normal()).collect()).collect();
            let panel: Vec<f64> = (0..k * NR).map(|_| rng.normal()).collect();
            let init: Vec<f64> = (0..MR * NR).map(|_| rng.normal()).collect();

            let mut want4 = [[0.0; NR]; MR];
            for (r, row) in want4.iter_mut().enumerate() {
                row.copy_from_slice(&init[r * NR..(r + 1) * NR]);
            }
            let mut want1 = [0.0; NR];
            want1.copy_from_slice(&init[..NR]);
            mk4_scalar(&a[0], &a[1], &a[2], &a[3], &panel, &mut want4);
            mk1_scalar(&a[0], &panel, &mut want1);

            for isa in supported_isas() {
                let mut got4 = [[0.0; NR]; MR];
                for (r, row) in got4.iter_mut().enumerate() {
                    row.copy_from_slice(&init[r * NR..(r + 1) * NR]);
                }
                mk4_with(isa, &a[0], &a[1], &a[2], &a[3], &panel, &mut got4);
                assert_eq!(got4, want4, "mk4 {} k={k}", isa.name());

                let mut got1 = [0.0; NR];
                got1.copy_from_slice(&init[..NR]);
                mk1_with(isa, &a[0], &panel, &mut got1);
                assert_eq!(got1, want1, "mk1 {} k={k}", isa.name());
            }
        }
    }

    #[test]
    fn strided_tile_every_isa_bit_equal_to_scalar() {
        let mut rng = Rng::new(11);
        let (astride, bstride) = (9, 13);
        for (k0, k1) in [(0usize, 5usize), (2, 2), (0, 256), (100, 301)] {
            let ad: Vec<f64> = (0..k1 * astride).map(|_| rng.normal()).collect();
            let bd: Vec<f64> = (0..k1 * bstride).map(|_| rng.normal()).collect();
            let init: Vec<f64> = (0..MR * NR).map(|_| rng.normal()).collect();
            for (a0, b0) in [(0usize, 0usize), (5, 5), (3, 1)] {
                let mut want = [[0.0; NR]; MR];
                for (r, row) in want.iter_mut().enumerate() {
                    row.copy_from_slice(&init[r * NR..(r + 1) * NR]);
                }
                tile4x8_scalar(&ad, astride, a0, &bd, bstride, b0, k0, k1, &mut want);
                for isa in supported_isas() {
                    let mut got = [[0.0; NR]; MR];
                    for (r, row) in got.iter_mut().enumerate() {
                        row.copy_from_slice(&init[r * NR..(r + 1) * NR]);
                    }
                    tile4x8_strided_with(isa, &ad, astride, a0, &bd, bstride, b0, k0, k1, &mut got);
                    assert_eq!(got, want, "tile {} k=[{k0},{k1})", isa.name());
                }
            }
        }
    }

    #[test]
    fn qdot_dispatcher_matches_naive() {
        let a: Vec<i16> = (0..37).map(|v| (v * 7 % 19) - 9).collect();
        let b: Vec<i16> = (0..37).map(|v| (v * 5 % 23) - 11).collect();
        let naive: i64 = a.iter().zip(&b).map(|(&x, &y)| x as i64 * y as i64).sum();
        assert_eq!(qdot_i16(&a, &b), naive);
    }
}
