//! Dense row-major `f64` matrix.

use super::matmul::BtPanels;
use std::fmt;
use std::ops::{Index, IndexMut};
use std::sync::OnceLock;

/// Dense row-major matrix of `f64`.
///
/// This is the analysis workhorse: covariance matrices, transforms,
/// quantizer inputs. It is deliberately simple — contiguous storage,
/// explicit loops — so the hot paths ([`crate::linalg::matmul`],
/// [`crate::linalg::eigh`]) stay easy to profile and optimize.
///
/// Matrices used repeatedly as the right operand of GEMV-shaped
/// `A · Bᵀ` products (weights, transforms on the decode path) lazily
/// cache a packed-panel copy of themselves behind a `OnceLock`
/// ([`Self::bt_panels`]); every `&mut` accessor invalidates it, so a
/// stale panel can never be read.
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
    /// Lazily packed `A·Bᵀ` panels (see `matmul::BtPanels`). Not part of
    /// the value: cleared on clone and on any mutable access.
    bt_cache: OnceLock<BtPanels>,
}

impl Clone for Mat {
    fn clone(&self) -> Mat {
        Mat::new_raw(self.rows, self.cols, self.data.clone())
    }
}

impl PartialEq for Mat {
    fn eq(&self, other: &Mat) -> bool {
        self.rows == other.rows && self.cols == other.cols && self.data == other.data
    }
}

impl Mat {
    /// Internal constructor (fresh, empty panel cache).
    #[inline]
    fn new_raw(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        Mat { rows, cols, data, bt_cache: OnceLock::new() }
    }

    /// Drop the cached panels — called by every `&mut` accessor so a
    /// mutated matrix can never serve stale packed data.
    #[inline]
    fn touch(&mut self) {
        self.bt_cache.take();
    }

    /// This matrix's rows packed into `NR`-wide panels for the
    /// GEMV-shaped `A · Bᵀ` kernel, built once on first use (see
    /// `linalg::par::matmul_a_bt_ct_panels_mt`).
    pub(crate) fn bt_panels(&self) -> &BtPanels {
        self.bt_cache.get_or_init(|| BtPanels::pack(self))
    }

    /// Bytes held by the packed-panel cache (0 until first GEMV use).
    pub fn panel_cache_bytes(&self) -> usize {
        self.bt_cache.get().map_or(0, |p| p.bytes())
    }

    /// Zero matrix of shape `rows × cols`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat::new_raw(rows, cols, vec![0.0; rows * cols])
    }

    /// Identity matrix of size `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a generator `f(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat::new_raw(rows, cols, data)
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat::new_raw(rows, cols, data)
    }

    /// Diagonal matrix from a vector.
    pub fn diag(d: &[f64]) -> Self {
        let n = d.len();
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = d[i];
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Borrow the underlying row-major buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutably borrow the underlying row-major buffer.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        self.touch();
        &mut self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        self.touch();
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy column `j` out.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Elementwise map into a new matrix.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Mat {
        Mat::new_raw(self.rows, self.cols, self.data.iter().map(|&v| f(v)).collect())
    }

    /// `self + other`.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Mat::new_raw(self.rows, self.cols, data)
    }

    /// `self += other` without allocating (streaming accumulators).
    pub fn add_in_place(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.touch();
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// `self - other`.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat::new_raw(self.rows, self.cols, data)
    }

    /// `self * s` (scalar).
    pub fn scale(&self, s: f64) -> Mat {
        self.map(|v| v * s)
    }

    /// Add `eps` to the diagonal in place (ridge / damping).
    pub fn add_diag(&mut self, eps: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += eps;
        }
    }

    /// Squared Frobenius norm `‖A‖_F²`.
    pub fn fro_norm2(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum()
    }

    /// Trace.
    pub fn trace(&self) -> f64 {
        assert!(self.is_square());
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0_f64, |m, &v| m.max(v.abs()))
    }

    /// Symmetrize in place: `A ← (A + Aᵀ)/2`. Guards eigendecomposition
    /// against numerically asymmetric covariance estimates.
    pub fn symmetrize(&mut self) {
        assert!(self.is_square());
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let m = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = m;
                self[(j, i)] = m;
            }
        }
    }

    /// Extract the square block `A[r0..r0+k, c0..c0+k]`.
    pub fn block(&self, r0: usize, c0: usize, k_rows: usize, k_cols: usize) -> Mat {
        assert!(r0 + k_rows <= self.rows && c0 + k_cols <= self.cols);
        Mat::from_fn(k_rows, k_cols, |i, j| self[(r0 + i, c0 + j)])
    }

    /// Write `b` into the block at `(r0, c0)`.
    pub fn set_block(&mut self, r0: usize, c0: usize, b: &Mat) {
        assert!(r0 + b.rows <= self.rows && c0 + b.cols <= self.cols);
        for i in 0..b.rows {
            for j in 0..b.cols {
                self[(r0 + i, c0 + j)] = b[(i, j)];
            }
        }
    }

    /// Maximum elementwise absolute difference to `other`.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0_f64, |m, (a, b)| m.max((a - b).abs()))
    }

    /// Cast to an `f32` row-major buffer (for the model substrate / PJRT).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    /// Build from an `f32` row-major buffer.
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat::new_raw(rows, cols, data.iter().map(|&v| v as f64).collect())
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.touch();
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}×{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        let show_c = self.cols.min(8);
        for i in 0..show_r {
            write!(f, "  ")?;
            for j in 0..show_c {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > show_c { "…" } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip() {
        let mut m = Mat::zeros(3, 4);
        m[(2, 3)] = 7.5;
        assert_eq!(m[(2, 3)], 7.5);
        assert_eq!(m.row(2)[3], 7.5);
    }

    #[test]
    fn transpose_involution() {
        let m = Mat::from_fn(3, 5, |i, j| (i * 7 + j) as f64);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn block_roundtrip() {
        let m = Mat::from_fn(6, 6, |i, j| (i * 6 + j) as f64);
        let b = m.block(2, 3, 2, 3);
        assert_eq!(b[(0, 0)], m[(2, 3)]);
        let mut z = Mat::zeros(6, 6);
        z.set_block(2, 3, &b);
        assert_eq!(z[(3, 5)], m[(3, 5)]);
    }

    #[test]
    fn symmetrize_fixes_asymmetry() {
        let mut m = Mat::from_fn(3, 3, |i, j| if i < j { 1.0 } else { 0.0 });
        m.symmetrize();
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(m[(i, j)], m[(j, i)]);
            }
        }
    }

    #[test]
    fn add_in_place_matches_add() {
        let a = Mat::from_fn(4, 5, |i, j| (i * 5 + j) as f64);
        let b = Mat::from_fn(4, 5, |i, j| (i as f64) - (j as f64) * 0.5);
        let want = a.add(&b);
        let mut got = a.clone();
        got.add_in_place(&b);
        assert_eq!(got, want);
    }

    #[test]
    fn trace_and_fro() {
        let m = Mat::diag(&[1.0, 2.0, 3.0]);
        assert_eq!(m.trace(), 6.0);
        assert_eq!(m.fro_norm2(), 14.0);
    }
}
