//! Register-tiled matrix multiplication with threaded dispatch.
//!
//! Since PR 4 the `f64` kernels are 4×8 *register-tiled* micro-kernels:
//! each output tile keeps one accumulator per element in registers and
//! walks `k` sequentially, with the right-hand operand packed into
//! contiguous `NR`-wide panels per `KC` k-block (`matmul`,
//! `matmul_a_bt`) or read as contiguous row slices (`matmul_at_b`,
//! [`syrk_at_a`]). The per-element accumulation order is *ascending `k`*
//! everywhere — identical to the naive triple loop and to the pre-tiling
//! AXPY kernel (retained as [`matmul_serial_ref`], the perf baseline CI's
//! perf-smoke job gates against) — so tiling is a pure-speed change:
//! tiled, reference and naive results are bit-equal, and the serial /
//! parallel / GEMV-partitioned variants of one kernel agree exactly.
//!
//! Every public kernel here is a *dispatcher*: below
//! [`par::PAR_MIN_FMA`](super::par::PAR_MIN_FMA) fused multiply-adds it
//! runs the serial kernel inline; above it, output rows are partitioned
//! across a scoped thread pool ([`super::par`]). The split is over output
//! rows only and each element keeps the ascending-`k` accumulation
//! order, so serial and parallel results are bit-identical — the
//! property tests in `rust/tests/linalg_par_props.rs` and
//! `rust/tests/kernel_tile_props.rs` pin this at exactly `0.0`.
//!
//! The old `if aik == 0.0 { continue; }` zero-skip branches are gone:
//! on dense data they were a mispredicted branch per FMA and made kernel
//! timing data-dependent (no sparse fast path is retained — the bench
//! showed no shape in the pipeline where it paid; see PERF.md).
//!
//! Since PR 6 the micro-kernels themselves live behind the
//! [`super::simd`] dispatch seam: `mk4`/`mk1` (packed panels) and the
//! strided full-tile kernel (`matmul_at_b`/`syrk`) pick an explicit
//! AVX-512/AVX2/NEON path at runtime (`CATQUANT_SIMD` knob), with the
//! scalar kernels retained as the always-compiled reference. The SIMD
//! paths vectorize across the NR output columns with unfused mul+add,
//! so each element keeps its single ascending-`k` accumulator and every
//! path stays bit-identical — the loops in this file are unchanged in
//! meaning, only the innermost tile bodies moved.

use super::{par, simd, Mat};

const KC: usize = 256; // k-panel kept hot in L1/L2

/// Register-tile height (output rows per micro-kernel call).
pub(crate) const MR: usize = 4;

/// Register-tile width (output columns per micro-kernel call; one packed
/// panel lane).
pub(crate) const NR: usize = 8;

// ---------------------------------------------------------------------
// Persistent packed panels for `C = A · Bᵀ` right-hand operands
// ---------------------------------------------------------------------

/// `B`'s rows packed into zero-padded `NR`-channel panels for the
/// GEMV-shaped `A · Bᵀ` kernel: panel `p` holds channels
/// `p·NR .. p·NR + NR`, laid out `panel[kk·NR + c] = b[p·NR + c][kk]`
/// so the micro-kernel's inner loop reads one contiguous `NR`-wide lane
/// per `k` step.
///
/// Static operands (model weights, transforms) build this **once** —
/// lazily, behind [`Mat::bt_panels`]'s `OnceLock` — and every decode
/// step reuses it; packing per call would cost as much as the GEMV
/// itself at batch 1. The packed values are exact copies, so the panel
/// path is bit-identical to the unpacked one.
#[derive(Clone)]
pub(crate) struct BtPanels {
    k: usize,
    n: usize,
    data: Vec<f64>,
}

impl BtPanels {
    pub(crate) fn pack(b: &Mat) -> BtPanels {
        let (n, k) = (b.rows(), b.cols());
        let npanels = n.div_ceil(NR);
        let mut data = vec![0.0f64; npanels * k * NR];
        if k > 0 {
            for (p, pan) in data.chunks_exact_mut(k * NR).enumerate() {
                let w = NR.min(n - p * NR);
                for c in 0..w {
                    let brow = b.row(p * NR + c);
                    for (kk, &v) in brow.iter().enumerate() {
                        pan[kk * NR + c] = v;
                    }
                }
            }
        }
        BtPanels { k, n, data }
    }

    /// Panel `p` (length `k·NR`).
    #[inline]
    pub(crate) fn panel(&self, p: usize) -> &[f64] {
        &self.data[p * self.k * NR..(p + 1) * self.k * NR]
    }

    pub(crate) fn k(&self) -> usize {
        self.k
    }

    pub(crate) fn n(&self) -> usize {
        self.n
    }

    /// Bytes held by the packed panels.
    pub(crate) fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f64>()
    }
}

// ---------------------------------------------------------------------
// Micro-kernels (shared by matmul / matmul_a_bt / the panel GEMV path)
// ---------------------------------------------------------------------
//
// The 4×NR panel micro-kernels (`simd::mk4`/`simd::mk1`) and the strided
// full-tile kernel (`simd::tile4x8_strided`) live in `super::simd` since
// PR 6: one accumulator per output element, `kk` ascending, dispatched
// at runtime across AVX-512/AVX2/NEON/scalar — all bit-identical.

/// Load the `w`-wide live part of an output tile into `acc` (the k-block
/// loop stores and reloads partial sums; an f64 round-trip through memory
/// is exact, so blocking never perturbs the ascending-`k` order).
#[inline]
fn load_acc(out: &[f64], n: usize, i0: usize, j0: usize, w: usize, acc: &mut [[f64; NR]; MR]) {
    for (r, accr) in acc.iter_mut().enumerate() {
        let base = (i0 + r) * n + j0;
        accr[..w].copy_from_slice(&out[base..base + w]);
    }
}

/// Store the `w`-wide live part of an output tile back (pad lanes are
/// never written).
#[inline]
fn store_acc(out: &mut [f64], n: usize, i0: usize, j0: usize, w: usize, acc: &[[f64; NR]; MR]) {
    for (r, accr) in acc.iter().enumerate() {
        let base = (i0 + r) * n + j0;
        out[base..base + w].copy_from_slice(&accr[..w]);
    }
}

/// Pack `panel[(kk−k0)·NR + c] = b[kk][j0 + c]` (the `C = A·B` layout),
/// zero-padding columns past `b.cols()`.
fn pack_cols(b: &Mat, k0: usize, k1: usize, j0: usize, panel: &mut [f64]) {
    let w = NR.min(b.cols() - j0);
    for (kk, prow) in (k0..k1).zip(panel.chunks_exact_mut(NR)) {
        let brow = &b.row(kk)[j0..j0 + w];
        prow[..w].copy_from_slice(brow);
        for p in prow[w..].iter_mut() {
            *p = 0.0;
        }
    }
}

/// Pack `panel[(kk−k0)·NR + c] = b[j0 + c][kk]` (the `C = A·Bᵀ` layout:
/// NR weight rows interleaved), zero-padding rows past `b.rows()`.
fn pack_rows(b: &Mat, k0: usize, k1: usize, j0: usize, panel: &mut [f64]) {
    let w = NR.min(b.rows() - j0);
    if w < NR {
        for prow in panel.chunks_exact_mut(NR) {
            for p in prow[w..].iter_mut() {
                *p = 0.0;
            }
        }
    }
    for c in 0..w {
        let brow = &b.row(j0 + c)[k0..k1];
        for (kk, &v) in brow.iter().enumerate() {
            panel[kk * NR + c] = v;
        }
    }
}

/// Shared tiled-GEMM row kernel: output rows `r0 ..` of a product whose
/// right operand packs into `NR`-wide panels via `pack` (`pack_cols` for
/// `A·B`, `pack_rows` for `A·Bᵀ`). `n` is the output width.
fn gemm_tiled_rows(
    a: &Mat,
    b: &Mat,
    n: usize,
    pack: fn(&Mat, usize, usize, usize, &mut [f64]),
    r0: usize,
    out: &mut [f64],
) {
    if out.is_empty() {
        return;
    }
    let k = a.cols();
    let rows = out.len() / n;
    let i_main = rows - rows % MR;
    par::with_scratch_f64(KC * NR, |scratch| {
        for k0 in (0..k).step_by(KC) {
            let k1 = (k0 + KC).min(k);
            let panel = &mut scratch[..(k1 - k0) * NR];
            let mut j0 = 0;
            while j0 < n {
                let w = NR.min(n - j0);
                pack(b, k0, k1, j0, panel);
                let mut i0 = 0;
                while i0 < i_main {
                    let mut acc = [[0.0f64; NR]; MR];
                    load_acc(out, n, i0, j0, w, &mut acc);
                    simd::mk4(
                        &a.row(r0 + i0)[k0..k1],
                        &a.row(r0 + i0 + 1)[k0..k1],
                        &a.row(r0 + i0 + 2)[k0..k1],
                        &a.row(r0 + i0 + 3)[k0..k1],
                        panel,
                        &mut acc,
                    );
                    store_acc(out, n, i0, j0, w, &acc);
                    i0 += MR;
                }
                for i in i_main..rows {
                    let mut acc = [0.0f64; NR];
                    acc[..w].copy_from_slice(&out[i * n + j0..i * n + j0 + w]);
                    simd::mk1(&a.row(r0 + i)[k0..k1], panel, &mut acc);
                    out[i * n + j0..i * n + j0 + w].copy_from_slice(&acc[..w]);
                }
                j0 += w;
            }
        }
    });
}

/// Compute output rows `r0 .. r0 + out.len()/b.cols()` of `C = A · B`
/// into `out` (row-major, zero-initialized). Shared by the serial and
/// parallel paths so both accumulate in the same order.
pub(crate) fn matmul_rows(a: &Mat, b: &Mat, r0: usize, out: &mut [f64]) {
    gemm_tiled_rows(a, b, b.cols(), pack_cols, r0, out);
}

/// Output rows of `C = A · Bᵀ` (row `r0 + i` of `A` dotted with every row
/// of `B`), register-tiled over packed weight-row panels.
pub(crate) fn matmul_a_bt_rows(a: &Mat, b: &Mat, r0: usize, out: &mut [f64]) {
    gemm_tiled_rows(a, b, b.rows(), pack_rows, r0, out);
}

/// Output rows of `C = Aᵀ · B`: row `i` of `C` is column `r0 + i` of `A`
/// against all of `B`. Both operands are read as contiguous row slices
/// per `kk` (no packing needed); full tiles run the register
/// micro-kernel, remainders accumulate in place — every element in
/// ascending-`kk` order.
pub(crate) fn matmul_at_b_rows(a: &Mat, b: &Mat, r0: usize, out: &mut [f64]) {
    if out.is_empty() {
        return;
    }
    let (k, n) = (a.rows(), b.cols());
    let m = a.cols();
    let rows = out.len() / n;
    let i_main = rows - rows % MR;
    let j_main = n - n % NR;
    let ad = a.as_slice();
    let bd = b.as_slice();
    for k0 in (0..k).step_by(KC) {
        let k1 = (k0 + KC).min(k);
        // Full MR×NR register tiles.
        let mut i0 = 0;
        while i0 < i_main {
            let c0 = r0 + i0;
            let mut j0 = 0;
            while j0 < j_main {
                let mut acc = [[0.0f64; NR]; MR];
                load_acc(out, n, i0, j0, NR, &mut acc);
                simd::tile4x8_strided(ad, m, c0, bd, n, j0, k0, k1, &mut acc);
                store_acc(out, n, i0, j0, NR, &acc);
                j0 += NR;
            }
            i0 += MR;
        }
        // Tile-height remainder: AXPY across the full width.
        for i in i_main..rows {
            let gi = r0 + i;
            let crow = &mut out[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let x = ad[kk * m + gi];
                let brow = &bd[kk * n..(kk + 1) * n];
                for (cj, &bv) in crow.iter_mut().zip(brow) {
                    *cj += x * bv;
                }
            }
        }
        // Tile-width remainder for the main rows.
        if j_main < n {
            for kk in k0..k1 {
                let arow = &ad[kk * m..(kk + 1) * m];
                let brow = &bd[kk * n..(kk + 1) * n];
                for i in 0..i_main {
                    let x = arow[r0 + i];
                    let crow = &mut out[i * n..(i + 1) * n];
                    for (cj, &bv) in crow[j_main..].iter_mut().zip(&brow[j_main..]) {
                        *cj += x * bv;
                    }
                }
            }
        }
    }
}

/// Output rows `r0 ..` of the symmetric product `Σ = AᵀA`, upper
/// triangle only (panel-aligned: the handful of lower-triangle elements
/// inside the diagonal-straddling tile are computed too — their values
/// are the symmetric ones, and [`syrk_at_a`]'s mirror pass overwrites
/// them with bit-identical copies). Per-element math and order match
/// [`matmul_at_b_rows`] exactly.
pub(crate) fn syrk_rows(a: &Mat, r0: usize, out: &mut [f64]) {
    if out.is_empty() {
        return;
    }
    let (k, m) = (a.rows(), a.cols());
    let rows = out.len() / m;
    let ad = a.as_slice();
    for k0 in (0..k).step_by(KC) {
        let k1 = (k0 + KC).min(k);
        let mut i0 = 0;
        while i0 < rows {
            let mr = MR.min(rows - i0);
            let gi = r0 + i0;
            let mut j0 = (gi / NR) * NR;
            while j0 < m {
                let w = NR.min(m - j0);
                if mr == MR && w == NR {
                    let mut acc = [[0.0f64; NR]; MR];
                    load_acc(out, m, i0, j0, NR, &mut acc);
                    simd::tile4x8_strided(ad, m, gi, ad, m, j0, k0, k1, &mut acc);
                    store_acc(out, m, i0, j0, NR, &acc);
                } else {
                    for kk in k0..k1 {
                        let arow = &ad[kk * m..(kk + 1) * m];
                        for r in 0..mr {
                            let x = arow[gi + r];
                            let crow = &mut out[(i0 + r) * m + j0..(i0 + r) * m + j0 + w];
                            for (cj, &b) in crow.iter_mut().zip(&arow[j0..j0 + w]) {
                                *cj += x * b;
                            }
                        }
                    }
                }
                j0 += w;
            }
            i0 += mr;
        }
    }
}

/// Rows `j0 ..` of `Cᵀ` for the decode/GEMV shape of `C = A · Bᵀ`: row
/// `j` of `Cᵀ` is `b.row(j)` against every row of `A`, each element a
/// single ascending-`k` accumulator — the exact per-element order of
/// [`matmul_a_bt_rows`], so the two partitionings are bit-identical.
/// Channels process in NR-wide groups (NR independent accumulator
/// chains per activation row).
pub(crate) fn matmul_a_bt_ct_rows(a: &Mat, b: &Mat, j0: usize, out: &mut [f64]) {
    let m = a.rows();
    if m == 0 || out.is_empty() {
        return;
    }
    let k = a.cols();
    let nchunk = out.len() / m;
    let mut jj = 0;
    while jj < nchunk {
        let w = NR.min(nchunk - jj);
        // Pad lanes repeat channel 0: computed, never stored.
        let mut brs: [&[f64]; NR] = [b.row(j0 + jj); NR];
        for (c, slot) in brs.iter_mut().enumerate().take(w) {
            *slot = b.row(j0 + jj + c);
        }
        for i in 0..m {
            let arow = a.row(i);
            let mut acc = [0.0f64; NR];
            for (kk, &x) in arow.iter().enumerate().take(k) {
                for (av, br) in acc.iter_mut().zip(&brs) {
                    *av += x * br[kk];
                }
            }
            for (c, &av) in acc.iter().enumerate().take(w) {
                out[(jj + c) * m + i] = av;
            }
        }
        jj += w;
    }
}

/// [`matmul_a_bt_ct_rows`] over pre-packed persistent panels
/// ([`BtPanels`]): the per-`k` loads become contiguous `NR`-wide lanes
/// and no packing happens per call. Bit-identical to the unpacked path
/// (the panels hold exact copies, per-element order is unchanged).
pub(crate) fn matmul_a_bt_ct_rows_panel(a: &Mat, bp: &BtPanels, j0: usize, out: &mut [f64]) {
    let m = a.rows();
    if m == 0 || out.is_empty() {
        return;
    }
    debug_assert_eq!(a.cols(), bp.k());
    let nchunk = out.len() / m;
    let i_main = m - m % MR;
    let mut j = j0; // absolute output channel
    let jend = (j0 + nchunk).min(bp.n());
    while j < jend {
        let p = j / NR;
        let cend = ((p + 1) * NR).min(jend);
        let pan = bp.panel(p);
        let c_lo = j - p * NR;
        let width = cend - j;
        let mut i0 = 0;
        while i0 < i_main {
            let mut acc = [[0.0f64; NR]; MR];
            simd::mk4(a.row(i0), a.row(i0 + 1), a.row(i0 + 2), a.row(i0 + 3), pan, &mut acc);
            for (r, accr) in acc.iter().enumerate() {
                for c in 0..width {
                    out[(j - j0 + c) * m + i0 + r] = accr[c_lo + c];
                }
            }
            i0 += MR;
        }
        for i in i_main..m {
            let mut acc = [0.0f64; NR];
            simd::mk1(a.row(i), pan, &mut acc);
            for c in 0..width {
                out[(j - j0 + c) * m + i] = acc[c_lo + c];
            }
        }
        j = cend;
    }
}

/// Scatter a contiguous `Cᵀ` buffer (`n` rows of `m` entries, one per
/// output channel) back into `C` (`m × n`). Shared by the f64 and
/// integer GEMV-shaped kernels.
pub(crate) fn transpose_ct_into(ct: &[f64], m: usize, c: &mut Mat) {
    let n = c.cols();
    // One slice borrow (= one panel-cache invalidation), not n·m
    // per-element `IndexMut` calls in the decode hot loop.
    let data = c.as_mut_slice();
    for (j, crow) in ct.chunks(m).enumerate() {
        for (i, &v) in crow.iter().enumerate() {
            data[i * n + j] = v;
        }
    }
}

/// Output entries `r0 .. r0 + out.len()` of `y = A · x`.
pub(crate) fn matvec_rows(a: &Mat, x: &[f64], r0: usize, out: &mut [f64]) {
    for (i, y) in out.iter_mut().enumerate() {
        *y = dot(a.row(r0 + i), x);
    }
}

fn assert_matmul_shapes(a: &Mat, b: &Mat) {
    assert_eq!(
        a.cols(),
        b.rows(),
        "matmul shape mismatch: {}×{} · {}×{}",
        a.rows(),
        a.cols(),
        b.rows(),
        b.cols()
    );
}

/// `C = A · B`. Dispatches to the parallel kernel above the size
/// threshold (see [`super::par`]).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_matmul_shapes(a, b);
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let threads = par::threads_for(m.saturating_mul(k).saturating_mul(n), m);
    if threads > 1 {
        par::matmul_mt(a, b, threads)
    } else {
        matmul_serial(a, b)
    }
}

/// `C = A · B` on the current thread (the parallel kernels' reference).
pub fn matmul_serial(a: &Mat, b: &Mat) -> Mat {
    assert_matmul_shapes(a, b);
    let mut c = Mat::zeros(a.rows(), b.cols());
    matmul_rows(a, b, 0, c.as_mut_slice());
    c
}

/// The pre-tiling serial `C = A · B` kernel (i-k-j AXPY with k-blocking),
/// retained as the perf baseline: `benches/linalg_hot.rs` A/Bs the tiled
/// kernel against it and CI's perf-smoke job fails if tiling ever stops
/// paying. Per-element accumulation is ascending `k`, same as the tiled
/// kernel, so the two are bit-equal (asserted in
/// `rust/tests/kernel_tile_props.rs`).
pub fn matmul_serial_ref(a: &Mat, b: &Mat) -> Mat {
    assert_matmul_shapes(a, b);
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Mat::zeros(m, n);
    let out = c.as_mut_slice();
    for k0 in (0..k).step_by(KC) {
        let k1 = (k0 + KC).min(k);
        for i in 0..m {
            let arow = a.row(i);
            let crow = &mut out[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let x = arow[kk];
                for (cj, &bv) in crow.iter_mut().zip(b.row(kk)) {
                    *cj += x * bv;
                }
            }
        }
    }
    c
}

/// `C = Aᵀ · B` without materializing the transpose.
///
/// Used for covariance-style products over *distinct* operands; the
/// self-product `Σ = XᵀX` has the cheaper [`syrk_at_a`].
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b shape mismatch");
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let threads = par::threads_for(k.saturating_mul(m).saturating_mul(n), m);
    if threads > 1 {
        par::matmul_at_b_mt(a, b, threads)
    } else {
        matmul_at_b_serial(a, b)
    }
}

/// Serial `C = Aᵀ · B`.
pub fn matmul_at_b_serial(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b shape mismatch");
    let mut c = Mat::zeros(a.cols(), b.cols());
    matmul_at_b_rows(a, b, 0, c.as_mut_slice());
    c
}

/// Symmetric self-product `Σ = AᵀA` (the covariance accumulation shape:
/// `A` is `tokens × dim`). Computes the upper triangle only and mirrors
/// it — half the FLOPs of `matmul_at_b(a, a)` — and is **bit-identical**
/// to it: upper-triangle elements accumulate in the same ascending-`k`
/// order, and `Σ[j][i] = Σ[i][j]` holds exactly in f64 (products
/// commute, sums share an order).
pub fn syrk_at_a(a: &Mat) -> Mat {
    let (k, m) = (a.rows(), a.cols());
    let mut c = Mat::zeros(m, m);
    // ~Half the FMAs of the full rectangular product.
    let work = k.saturating_mul(m).saturating_mul(m) / 2;
    let threads = par::threads_for(work, m);
    if threads > 1 {
        par::syrk_mt(a, threads, &mut c);
    } else {
        syrk_rows(a, 0, c.as_mut_slice());
    }
    // Mirror the upper triangle into the lower (single slice borrow —
    // no per-element cache invalidation).
    let data = c.as_mut_slice();
    for i in 0..m {
        for j in (i + 1)..m {
            data[j * m + i] = data[i * m + j];
        }
    }
    c
}

/// Four-accumulator dot product.
///
/// A naive `acc += a[i]*b[i]` loop cannot be auto-vectorized (FP addition
/// is not associative, and Rust does not reorder it), so it runs at ~1
/// FLOP/cycle. Splitting the reduction across four independent
/// accumulators both breaks the dependency chain and lets LLVM emit SIMD
/// lanes — the §Perf pass measured ~3–4× on this. Still used by
/// [`matvec`]; the matmul kernels moved to register tiles (which get the
/// same independence from 32 per-element accumulators without changing
/// any element's accumulation order).
#[inline]
pub(crate) fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let ca = a.chunks_exact(4);
    let cb = b.chunks_exact(4);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        acc[0] += xa[0] * xb[0];
        acc[1] += xa[1] * xb[1];
        acc[2] += xa[2] * xb[2];
        acc[3] += xa[3] * xb[3];
    }
    let mut tail = 0.0;
    for (x, y) in ra.iter().zip(rb) {
        tail += x * y;
    }
    (acc[0] + acc[2]) + (acc[1] + acc[3]) + tail
}

/// Below this many activation rows, `A · Bᵀ` is a decode/GEMV shape:
/// partitioning output *rows* caps the worker count at `m` (1 for
/// single-token decode), so the dispatcher partitions over `B`'s rows
/// (output channels) instead.
pub(crate) const GEMV_MAX_ROWS: usize = 32;

/// `C = A · Bᵀ` without materializing the transpose.
///
/// This is the layout of a linear layer (`x · Wᵀ` with `W: out×in`),
/// register-tiled over packed weight-row panels.
pub fn matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt shape mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let work = m.saturating_mul(k).saturating_mul(n);
    if m < GEMV_MAX_ROWS && n > m {
        // Channel-partitioned Cᵀ kernel at any worker count: it reads B
        // rows directly, while the row kernel's per-call panel packing
        // would cost as much as the GEMV itself at tiny m. Bit-identical
        // either way.
        let threads = par::threads_for(work, n);
        return par::matmul_a_bt_ct_mt(a, b, threads);
    }
    let threads = par::threads_for(work, m);
    if threads > 1 {
        par::matmul_a_bt_mt(a, b, threads)
    } else {
        matmul_a_bt_serial(a, b)
    }
}

/// [`matmul_a_bt`] for **static** right operands (model weights,
/// transforms): the GEMV/decode shape (`m < 32 ≤ n`) runs over `b`'s
/// persistent packed panels, built lazily once behind a `OnceLock`
/// ([`Mat::bt_panels`]) and reused by every subsequent call — packing
/// per call would cost as much as the batch-1 GEMV itself. Results are
/// bit-identical to [`matmul_a_bt`]; mutating `b` through any `&mut`
/// accessor invalidates its cache.
pub fn matmul_a_bt_cached(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt shape mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    if m > 0 && m < GEMV_MAX_ROWS && n > m {
        let work = m.saturating_mul(k).saturating_mul(n);
        let threads = par::threads_for(work, n);
        return par::matmul_a_bt_ct_panels_mt(a, b, threads);
    }
    matmul_a_bt(a, b)
}

/// Serial `C = A · Bᵀ`.
pub fn matmul_a_bt_serial(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt shape mismatch");
    let mut c = Mat::zeros(a.rows(), b.rows());
    matmul_a_bt_rows(a, b, 0, c.as_mut_slice());
    c
}

/// `y = A · x`.
pub fn matvec(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    let threads = par::threads_for(a.rows().saturating_mul(a.cols()), a.rows());
    if threads > 1 {
        par::matvec_mt(a, x, threads)
    } else {
        matvec_serial(a, x)
    }
}

/// Serial `y = A · x`.
pub fn matvec_serial(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    let mut y = vec![0.0; a.rows()];
    matvec_rows(a, x, 0, &mut y);
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    fn random(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(rows, cols, |_, _| rng.normal())
    }

    #[test]
    fn matmul_matches_naive() {
        // Tiled kernels keep each element's ascending-k order, so they
        // match the naive triple loop *bit-exactly*.
        let a = random(13, 29, 1);
        let b = random(29, 17, 2);
        let c = matmul(&a, &b);
        assert_eq!(c.max_abs_diff(&naive(&a, &b)), 0.0);
    }

    #[test]
    fn matmul_blocked_over_kc_boundary() {
        let a = random(4, KC + 37, 3);
        let b = random(KC + 37, 5, 4);
        assert_eq!(matmul(&a, &b).max_abs_diff(&naive(&a, &b)), 0.0);
    }

    #[test]
    fn reference_kernel_matches_tiled_exactly() {
        for (m, k, n) in [(7usize, 33usize, 9usize), (12, KC + 5, 11), (33, 64, 40)] {
            let a = random(m, k, 40 + m as u64);
            let b = random(k, n, 50 + n as u64);
            assert_eq!(
                matmul_serial_ref(&a, &b).max_abs_diff(&matmul_serial(&a, &b)),
                0.0,
                "{m}×{k}×{n}"
            );
        }
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let a = random(31, 9, 5);
        let b = random(31, 11, 6);
        let c = matmul_at_b(&a, &b);
        assert_eq!(c.max_abs_diff(&matmul(&a.transpose(), &b)), 0.0);
    }

    #[test]
    fn a_bt_matches_explicit_transpose() {
        let a = random(12, 21, 7);
        let b = random(15, 21, 8);
        let c = matmul_a_bt(&a, &b);
        assert_eq!(c.max_abs_diff(&matmul(&a, &b.transpose())), 0.0);
    }

    #[test]
    fn syrk_matches_at_b_self_product_exactly() {
        for (k, m) in [(5usize, 3usize), (40, 17), (300, 33), (64, NR * 3), (7, 1)] {
            let a = random(k, m, 60 + m as u64);
            assert_eq!(
                syrk_at_a(&a).max_abs_diff(&matmul_at_b(&a, &a)),
                0.0,
                "syrk {k}×{m}"
            );
        }
    }

    #[test]
    fn cached_a_bt_matches_uncached_and_survives_mutation() {
        let a = random(3, 40, 9);
        let mut b = random(70, 40, 10);
        let want = matmul_a_bt(&a, &b);
        assert_eq!(matmul_a_bt_cached(&a, &b).max_abs_diff(&want), 0.0);
        // Mutating b must invalidate the panel cache.
        b[(5, 7)] += 1.25;
        let want2 = matmul_a_bt(&a, &b);
        assert!(want2.max_abs_diff(&want) > 0.0);
        assert_eq!(matmul_a_bt_cached(&a, &b).max_abs_diff(&want2), 0.0);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = random(9, 14, 9);
        let x: Vec<f64> = (0..14).map(|i| i as f64 * 0.3 - 2.0).collect();
        let xm = Mat::from_vec(14, 1, x.clone());
        let y = matvec(&a, &x);
        let ym = matmul(&a, &xm);
        for i in 0..9 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn identity_is_neutral() {
        let a = random(8, 8, 10);
        assert!(matmul(&a, &Mat::eye(8)).max_abs_diff(&a) < 1e-15);
        assert!(matmul(&Mat::eye(8), &a).max_abs_diff(&a) < 1e-15);
    }

    #[test]
    fn dispatcher_crosses_parallel_threshold_consistently() {
        // 192³ ≈ 7.1 M FMA is above PAR_MIN_FMA, so `matmul` takes the
        // threaded path (whenever >1 worker is available) and must agree
        // with the serial reference *exactly* — the fan-out partitions
        // output rows only and every element keeps its ascending-k
        // accumulation order (PERF.md's bit-identical claim).
        let a = random(192, 192, 11);
        let b = random(192, 192, 12);
        assert_eq!(matmul(&a, &b).max_abs_diff(&matmul_serial(&a, &b)), 0.0);
        assert_eq!(matmul_at_b(&a, &b).max_abs_diff(&matmul_at_b_serial(&a, &b)), 0.0);
        assert_eq!(matmul_a_bt(&a, &b).max_abs_diff(&matmul_a_bt_serial(&a, &b)), 0.0);
    }
}
