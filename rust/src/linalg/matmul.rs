//! Blocked matrix multiplication with threaded dispatch.
//!
//! The `f64` analysis path uses a straightforward i-k-j loop order (the
//! inner loop is a contiguous AXPY over the output row, which LLVM
//! auto-vectorizes) with k-blocking for cache reuse. This is the hot path
//! of covariance estimation, GPTQ and the transform builders; see
//! `benches/linalg_hot.rs` and PERF.md.
//!
//! Every public kernel here is a *dispatcher*: below
//! [`par::PAR_MIN_FMA`](super::par::PAR_MIN_FMA) fused multiply-adds it
//! runs the serial kernel inline; above it, output rows are partitioned
//! across a scoped thread pool ([`super::par`]). The split is over output
//! rows only and each row keeps the exact serial accumulation order, so
//! serial and parallel results are bit-identical — the property tests in
//! `rust/tests/linalg_par_props.rs` pin this down.

use super::{par, Mat};

const KC: usize = 256; // k-panel kept hot in L1/L2

/// Compute output rows `r0 .. r0 + out.len()/b.cols()` of `C = A · B`
/// into `out` (row-major, zero-initialized). Shared by the serial and
/// parallel paths so both accumulate in the same order.
pub(crate) fn matmul_rows(a: &Mat, b: &Mat, r0: usize, out: &mut [f64]) {
    if out.is_empty() {
        return;
    }
    let (k, n) = (a.cols(), b.cols());
    let rows = out.len() / n;
    for k0 in (0..k).step_by(KC) {
        let k1 = (k0 + KC).min(k);
        for i in 0..rows {
            let arow = a.row(r0 + i);
            let crow = &mut out[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = b.row(kk);
                // contiguous AXPY: c[i, :] += a[i, k] * b[k, :]
                for j in 0..n {
                    crow[j] += aik * brow[j];
                }
            }
        }
    }
}

/// Output rows of `C = Aᵀ · B`: row `i` of `C` is column `r0 + i` of `A`
/// against all of `B`, accumulated in the serial `kk` order.
pub(crate) fn matmul_at_b_rows(a: &Mat, b: &Mat, r0: usize, out: &mut [f64]) {
    if out.is_empty() {
        return;
    }
    let (k, n) = (a.rows(), b.cols());
    let rows = out.len() / n;
    for kk in 0..k {
        let arow = a.row(kk);
        let brow = b.row(kk);
        for i in 0..rows {
            let aik = arow[r0 + i];
            if aik == 0.0 {
                continue;
            }
            let crow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
}

/// Output rows of `C = A · Bᵀ` (row `r0 + i` of `A` dotted with every row
/// of `B`).
pub(crate) fn matmul_a_bt_rows(a: &Mat, b: &Mat, r0: usize, out: &mut [f64]) {
    if out.is_empty() {
        return;
    }
    let n = b.rows();
    let rows = out.len() / n;
    for i in 0..rows {
        let arow = a.row(r0 + i);
        let crow = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            crow[j] = dot(arow, b.row(j));
        }
    }
}

/// Rows `j0 ..` of `Cᵀ` for the decode/GEMV shape of `C = A · Bᵀ`: row
/// `j` of `Cᵀ` is `b.row(j)` dotted with every row of `A`. Each output
/// element is the same `dot` as [`matmul_a_bt_rows`] computes, so the
/// two partitionings are bit-identical.
pub(crate) fn matmul_a_bt_ct_rows(a: &Mat, b: &Mat, j0: usize, out: &mut [f64]) {
    let m = a.rows();
    for (jj, orow) in out.chunks_mut(m).enumerate() {
        let brow = b.row(j0 + jj);
        for (i, o) in orow.iter_mut().enumerate() {
            *o = dot(a.row(i), brow);
        }
    }
}

/// Scatter a contiguous `Cᵀ` buffer (`n` rows of `m` entries, one per
/// output channel) back into `C` (`m × n`). Shared by the f64 and
/// integer GEMV-shaped kernels.
pub(crate) fn transpose_ct_into(ct: &[f64], m: usize, c: &mut Mat) {
    for (j, crow) in ct.chunks(m).enumerate() {
        for (i, &v) in crow.iter().enumerate() {
            c[(i, j)] = v;
        }
    }
}

/// Output entries `r0 .. r0 + out.len()` of `y = A · x`.
pub(crate) fn matvec_rows(a: &Mat, x: &[f64], r0: usize, out: &mut [f64]) {
    for (i, y) in out.iter_mut().enumerate() {
        *y = dot(a.row(r0 + i), x);
    }
}

fn assert_matmul_shapes(a: &Mat, b: &Mat) {
    assert_eq!(
        a.cols(),
        b.rows(),
        "matmul shape mismatch: {}×{} · {}×{}",
        a.rows(),
        a.cols(),
        b.rows(),
        b.cols()
    );
}

/// `C = A · B`. Dispatches to the parallel kernel above the size
/// threshold (see [`super::par`]).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_matmul_shapes(a, b);
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let threads = par::threads_for(m.saturating_mul(k).saturating_mul(n), m);
    if threads > 1 {
        par::matmul_mt(a, b, threads)
    } else {
        matmul_serial(a, b)
    }
}

/// `C = A · B` on the current thread (the parallel kernels' reference).
pub fn matmul_serial(a: &Mat, b: &Mat) -> Mat {
    assert_matmul_shapes(a, b);
    let mut c = Mat::zeros(a.rows(), b.cols());
    matmul_rows(a, b, 0, c.as_mut_slice());
    c
}

/// `C = Aᵀ · B` without materializing the transpose.
///
/// Used for covariance accumulation `Σ = Xᵀ X` where `X` is
/// `tokens × dim` (tall-skinny).
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b shape mismatch");
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let threads = par::threads_for(k.saturating_mul(m).saturating_mul(n), m);
    if threads > 1 {
        par::matmul_at_b_mt(a, b, threads)
    } else {
        matmul_at_b_serial(a, b)
    }
}

/// Serial `C = Aᵀ · B`.
pub fn matmul_at_b_serial(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b shape mismatch");
    let mut c = Mat::zeros(a.cols(), b.cols());
    matmul_at_b_rows(a, b, 0, c.as_mut_slice());
    c
}

/// Four-accumulator dot product.
///
/// A naive `acc += a[i]*b[i]` loop cannot be auto-vectorized (FP addition
/// is not associative, and Rust does not reorder it), so it runs at ~1
/// FLOP/cycle. Splitting the reduction across four independent
/// accumulators both breaks the dependency chain and lets LLVM emit SIMD
/// lanes — the §Perf pass measured ~3–4× on this, the forward/eval hot
/// path. (The summation-order change perturbs results at the 1e-16
/// relative level only.)
#[inline]
pub(crate) fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let ca = a.chunks_exact(4);
    let cb = b.chunks_exact(4);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        acc[0] += xa[0] * xb[0];
        acc[1] += xa[1] * xb[1];
        acc[2] += xa[2] * xb[2];
        acc[3] += xa[3] * xb[3];
    }
    let mut tail = 0.0;
    for (x, y) in ra.iter().zip(rb) {
        tail += x * y;
    }
    (acc[0] + acc[2]) + (acc[1] + acc[3]) + tail
}

/// Below this many activation rows, `A · Bᵀ` is a decode/GEMV shape:
/// partitioning output *rows* caps the worker count at `m` (1 for
/// single-token decode), so the dispatcher partitions over `B`'s rows
/// (output channels) instead.
pub(crate) const GEMV_MAX_ROWS: usize = 32;

/// `C = A · Bᵀ` without materializing the transpose.
///
/// This is the layout of a linear layer (`x · Wᵀ` with `W: out×in`),
/// and the inner loop is a dot product over contiguous rows of both
/// operands.
pub fn matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt shape mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let work = m.saturating_mul(k).saturating_mul(n);
    if m < GEMV_MAX_ROWS && n > m {
        let threads = par::threads_for(work, n);
        if threads > 1 {
            return par::matmul_a_bt_ct_mt(a, b, threads);
        }
        return matmul_a_bt_serial(a, b);
    }
    let threads = par::threads_for(work, m);
    if threads > 1 {
        par::matmul_a_bt_mt(a, b, threads)
    } else {
        matmul_a_bt_serial(a, b)
    }
}

/// Serial `C = A · Bᵀ`.
pub fn matmul_a_bt_serial(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt shape mismatch");
    let mut c = Mat::zeros(a.rows(), b.rows());
    matmul_a_bt_rows(a, b, 0, c.as_mut_slice());
    c
}

/// `y = A · x`.
pub fn matvec(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    let threads = par::threads_for(a.rows().saturating_mul(a.cols()), a.rows());
    if threads > 1 {
        par::matvec_mt(a, x, threads)
    } else {
        matvec_serial(a, x)
    }
}

/// Serial `y = A · x`.
pub fn matvec_serial(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    let mut y = vec![0.0; a.rows()];
    matvec_rows(a, x, 0, &mut y);
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    fn random(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(rows, cols, |_, _| rng.normal())
    }

    #[test]
    fn matmul_matches_naive() {
        let a = random(13, 29, 1);
        let b = random(29, 17, 2);
        let c = matmul(&a, &b);
        assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-12);
    }

    #[test]
    fn matmul_blocked_over_kc_boundary() {
        let a = random(4, KC + 37, 3);
        let b = random(KC + 37, 5, 4);
        assert!(matmul(&a, &b).max_abs_diff(&naive(&a, &b)) < 1e-10);
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let a = random(31, 9, 5);
        let b = random(31, 11, 6);
        let c = matmul_at_b(&a, &b);
        assert!(c.max_abs_diff(&matmul(&a.transpose(), &b)) < 1e-12);
    }

    #[test]
    fn a_bt_matches_explicit_transpose() {
        let a = random(12, 21, 7);
        let b = random(15, 21, 8);
        let c = matmul_a_bt(&a, &b);
        assert!(c.max_abs_diff(&matmul(&a, &b.transpose())) < 1e-12);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = random(9, 14, 9);
        let x: Vec<f64> = (0..14).map(|i| i as f64 * 0.3 - 2.0).collect();
        let xm = Mat::from_vec(14, 1, x.clone());
        let y = matvec(&a, &x);
        let ym = matmul(&a, &xm);
        for i in 0..9 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn identity_is_neutral() {
        let a = random(8, 8, 10);
        assert!(matmul(&a, &Mat::eye(8)).max_abs_diff(&a) < 1e-15);
        assert!(matmul(&Mat::eye(8), &a).max_abs_diff(&a) < 1e-15);
    }

    #[test]
    fn dispatcher_crosses_parallel_threshold_consistently() {
        // 192³ ≈ 7.1 M FMA is above PAR_MIN_FMA, so `matmul` takes the
        // threaded path (whenever >1 worker is available) and must agree
        // with the serial reference exactly.
        let a = random(192, 192, 11);
        let b = random(192, 192, 12);
        assert!(matmul(&a, &b).max_abs_diff(&matmul_serial(&a, &b)) < 1e-12);
        assert!(matmul_at_b(&a, &b).max_abs_diff(&matmul_at_b_serial(&a, &b)) < 1e-12);
        assert!(matmul_a_bt(&a, &b).max_abs_diff(&matmul_a_bt_serial(&a, &b)) < 1e-12);
    }
}
