//! Deterministic pseudo-random number generation.
//!
//! A small PCG-XSH-RR 64/32 generator (O'Neill, 2014) plus the sampling
//! helpers the calibration suite needs (normal, Laplace, Student-t for
//! heavy-tailed activations). No external dependencies; fully
//! reproducible across platforms for a given seed — experiment tables in
//! `EXPERIMENTS.md` cite seeds.

/// Deterministic PCG-based random number generator.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// Cached second Box–Muller normal sample.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a seed (any value, including 0).
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng { state: 0, inc: (seed << 1) | 1, spare_normal: None };
        rng.state = rng.state.wrapping_add(seed ^ 0x9E37_79B9_7F4A_7C15);
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive an independent stream (for per-layer / per-seed replication).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let s = self.next_u64() ^ tag.wrapping_mul(0xD6E8_FEB8_6659_FD93);
        Rng::new(s)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Random sign, ±1 with equal probability.
    #[inline]
    pub fn sign(&mut self) -> f64 {
        if self.next_u32() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Rejection-free polar-less Box–Muller; guard u1 > 0.
        let mut u1 = self.uniform();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Laplace(0, b) sample — the paper's reference heavy-ish tail.
    pub fn laplace(&mut self, b: f64) -> f64 {
        let u = self.uniform() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }

    /// Student-t with `nu` degrees of freedom — models the severe
    /// activation outliers the paper reports (worse-than-Laplace region
    /// of Figure 4).
    pub fn student_t(&mut self, nu: usize) -> f64 {
        debug_assert!(nu >= 1);
        let z = self.normal();
        let mut chi2 = 0.0;
        for _ in 0..nu {
            let g = self.normal();
            chi2 += g * g;
        }
        z / (chi2 / nu as f64).sqrt()
    }

    /// Fill a slice with i.i.d. standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Random permutation of `0..n` (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            p.swap(i, j);
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn laplace_variance() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let b = 1.5;
        let mut s2 = 0.0;
        for _ in 0..n {
            let z = r.laplace(b);
            s2 += z * z;
        }
        // Var = 2 b^2
        let var = s2 / n as f64;
        assert!((var - 2.0 * b * b).abs() < 0.15, "var {var}");
    }

    #[test]
    fn student_t_heavier_tail_than_normal() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let thresh = 4.0;
        let t_exceed = (0..n).filter(|_| r.student_t(3).abs() > thresh).count();
        let n_exceed = (0..n).filter(|_| r.normal().abs() > thresh).count();
        assert!(t_exceed > 10 * n_exceed.max(1) / 2, "t {t_exceed} vs n {n_exceed}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(9);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn fork_streams_are_independent_of_parent_consumption() {
        let mut a = Rng::new(1234);
        let mut f1 = a.fork(1);
        let x: Vec<u64> = (0..8).map(|_| f1.next_u64()).collect();
        // Same fork tag from the same parent state reproduces.
        let mut b = Rng::new(1234);
        let mut f2 = b.fork(1);
        let y: Vec<u64> = (0..8).map(|_| f2.next_u64()).collect();
        assert_eq!(x, y);
    }
}
