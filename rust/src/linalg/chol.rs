//! Cholesky decomposition of symmetric positive-definite matrices.
//!
//! Used by GPTQ (inverse-Hessian factor), SPD inversion, and the
//! transform builders' numerical safeguards.

use super::Mat;

/// Lower-triangular Cholesky factor `L` with `A = L Lᵀ`.
pub struct Cholesky {
    l: Mat,
}

impl Cholesky {
    /// Factor an SPD matrix. Returns `None` if a non-positive pivot is
    /// encountered (matrix not positive definite to working precision).
    pub fn new(a: &Mat) -> Option<Cholesky> {
        assert!(a.is_square(), "Cholesky needs a square matrix");
        let n = a.rows();
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = a[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if s <= 0.0 {
                        return None;
                    }
                    l[(i, i)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Some(Cholesky { l })
    }

    /// Factor with escalating diagonal damping until the matrix becomes
    /// positive definite. Returns the factor and the damping actually used.
    /// This mirrors GPTQ's `percdamp` treatment of rank-deficient Hessians.
    pub fn new_damped(a: &Mat, base_damp: f64) -> (Cholesky, f64) {
        let n = a.rows();
        let mean_diag = (0..n).map(|i| a[(i, i)]).sum::<f64>() / n as f64;
        let mut damp = base_damp * mean_diag.max(1e-12);
        loop {
            let mut m = a.clone();
            m.add_diag(damp);
            if let Some(c) = Cholesky::new(&m) {
                return (c, damp);
            }
            damp *= 10.0;
            assert!(damp.is_finite(), "Cholesky damping diverged");
        }
    }

    /// The lower-triangular factor `L`.
    pub fn l(&self) -> &Mat {
        &self.l
    }

    /// Solve `A x = b` via forward + back substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(b.len(), n);
        // L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l[(i, k)] * y[k];
            }
            y[i] = s / self.l[(i, i)];
        }
        // Lᵀ x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in (i + 1)..n {
                s -= self.l[(k, i)] * x[k];
            }
            x[i] = s / self.l[(i, i)];
        }
        x
    }

    /// Full inverse `A⁻¹` (column-by-column solve).
    pub fn inverse(&self) -> Mat {
        let n = self.l.rows();
        let mut inv = Mat::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let x = self.solve(&e);
            for i in 0..n {
                inv[(i, j)] = x[i];
            }
            e[j] = 0.0;
        }
        inv
    }

    /// Upper-triangular Cholesky factor of the *inverse*, `U` with
    /// `A⁻¹ = Uᵀ U` — the factor GPTQ iterates over.
    pub fn inverse_upper_factor(&self) -> Mat {
        // A⁻¹ = L⁻ᵀ L⁻¹; its upper Cholesky-like factor used by GPTQ is
        // obtained from the Cholesky of the explicit inverse.
        let inv = self.inverse();
        let c = Cholesky::new_damped(&inv, 1e-12).0;
        c.l.transpose()
    }

    /// `log det A = 2 Σ log L_ii`.
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_at_b, Rng};

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let g = Mat::from_fn(n + 4, n, |_, _| rng.normal());
        let mut s = matmul_at_b(&g, &g);
        s.add_diag(0.5);
        s
    }

    #[test]
    fn factor_reconstructs() {
        let a = random_spd(16, 1);
        let c = Cholesky::new(&a).unwrap();
        let rec = matmul(c.l(), &c.l().transpose());
        assert!(rec.max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn solve_matches_direct() {
        let a = random_spd(12, 2);
        let c = Cholesky::new(&a).unwrap();
        let b: Vec<f64> = (0..12).map(|i| (i as f64).sin()).collect();
        let x = c.solve(&b);
        let ax = crate::linalg::matvec(&a, &x);
        for i in 0..12 {
            assert!((ax[i] - b[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn inverse_is_inverse() {
        let a = random_spd(10, 3);
        let inv = Cholesky::new(&a).unwrap().inverse();
        let prod = matmul(&a, &inv);
        assert!(prod.max_abs_diff(&Mat::eye(10)) < 1e-8);
    }

    #[test]
    fn non_spd_rejected() {
        let mut a = Mat::eye(4);
        a[(2, 2)] = -1.0;
        assert!(Cholesky::new(&a).is_none());
    }

    #[test]
    fn damped_recovers_semidefinite() {
        // Rank-1 PSD matrix: plain Cholesky fails, damped succeeds.
        let v = [1.0, 2.0, 3.0];
        let a = Mat::from_fn(3, 3, |i, j| v[i] * v[j]);
        assert!(Cholesky::new(&a).is_none());
        let (c, damp) = Cholesky::new_damped(&a, 0.01);
        assert!(damp > 0.0);
        let rec = matmul(c.l(), &c.l().transpose());
        // Reconstruction is within the damping.
        assert!(rec.max_abs_diff(&a) < damp * 2.0 + 1e-9);
    }

    #[test]
    fn inverse_upper_factor_reconstructs_inverse() {
        let a = random_spd(8, 5);
        let c = Cholesky::new(&a).unwrap();
        let u = c.inverse_upper_factor();
        let rec = matmul_at_b(&u, &u); // Uᵀ U
        assert!(rec.max_abs_diff(&c.inverse()) < 1e-7);
    }
}
