//! Function-preserving linear transforms (paper §3–4).
//!
//! A transform `T` rewrites a linear layer `Wx = (WT⁻¹)(Tx)` before
//! quantization (paper eq. 5). The zoo:
//!
//! | builder | paper name | improves |
//! |---|---|---|
//! | [`Transform::identity`] | no transform | — |
//! | [`smooth_quant_scale`] | SmoothQuant (Xiao et al.) | activation concentration (at weight cost) |
//! | [`Transform::hadamard`] / [`Transform::randomized_hadamard`] | QuaRot (Ashkboos et al.) | concentration only — provably alignment-invariant |
//! | [`seed_search_rotation`] | SpinQuant (substitute, see DESIGN.md §3) | concentration via rotation selection |
//! | [`cat_optimal`] | CAT, full-rank M̂ (eq. 7) | alignment (optimally) + concentration via H |
//! | [`cat_block`] | **CAT (block)** — the paper's method | alignment + concentration at block-diagonal cost |
//! | [`kronecker_cat`] | FlatQuant substitute (Sun et al.) | both, via Kronecker-factored transform |

mod cat;
mod kronecker;
mod permuted;
mod rotation;
mod scaling;
mod transform;

pub use cat::{cat_block, cat_block_raw, cat_m_hat, cat_optimal};
pub use kronecker::{kronecker_cat, kronecker_factor_dims, partial_trace_factors};
pub use permuted::{correlation_ordering, permuted_cat_block};
pub use rotation::seed_search_rotation;
pub use scaling::{smooth_quant_scale, diag_align_scale};
pub use transform::Transform;

/// Which transform family to build — the experiment grid's axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransformKind {
    None,
    SmoothQuant,
    QuaRot,
    SpinQuant,
    CatBlock,
    CatBlockTrained,
    FlatQuant,
    CatOptimal,
    /// Paper §7 future work: channel permutation + block CAT
    /// (implemented in [`permuted_cat_block`]; see the ablation exp).
    CatBlockPermuted,
}

impl TransformKind {
    pub fn label(&self) -> &'static str {
        match self {
            TransformKind::None => "None",
            TransformKind::SmoothQuant => "SmoothQuant",
            TransformKind::QuaRot => "QuaRot",
            TransformKind::SpinQuant => "SpinQuant",
            TransformKind::CatBlock => "CAT (block)",
            TransformKind::CatBlockTrained => "CAT (block) w/ train",
            TransformKind::FlatQuant => "FlatQuant",
            TransformKind::CatOptimal => "CAT (optimal)",
            TransformKind::CatBlockPermuted => "CAT (perm-block)",
        }
    }

    /// All Table 1 rows, in the paper's order.
    pub fn table1_rows() -> &'static [TransformKind] {
        &[
            TransformKind::None,
            TransformKind::SmoothQuant,
            TransformKind::QuaRot,
            TransformKind::CatBlock,
            TransformKind::SpinQuant,
            TransformKind::FlatQuant,
            TransformKind::CatBlockTrained,
        ]
    }
}
