//! Function-preserving linear transforms (paper §3–4).
//!
//! A transform `T` rewrites a linear layer `Wx = (WT⁻¹)(Tx)` before
//! quantization (paper eq. 5). The zoo:
//!
//! | builder | paper name | improves |
//! |---|---|---|
//! | [`Transform::identity`] | no transform | — |
//! | [`smooth_quant_scale`] | SmoothQuant (Xiao et al.) | activation concentration (at weight cost) |
//! | [`Transform::hadamard`] / [`Transform::randomized_hadamard`] | QuaRot (Ashkboos et al.) | concentration only — provably alignment-invariant |
//! | [`seed_search_rotation`] | SpinQuant (substitute, see DESIGN.md §3) | concentration via rotation selection |
//! | [`cat_optimal`] | CAT, full-rank M̂ (eq. 7) | alignment (optimally) + concentration via H |
//! | [`cat_block`] | **CAT (block)** — the paper's method | alignment + concentration at block-diagonal cost |
//! | [`kronecker_cat`] | FlatQuant substitute (Sun et al.) | both, via Kronecker-factored transform |
//! | [`wush_adaptive`] | WUSH substitute (adaptive per-block) | alignment + per-block randomized concentration |
//! | [`fpt_merged`] | FPTQuant substitute (merged, zero-cost) | alignment via permutation + diagonal scale |

mod adaptive;
mod cat;
mod kronecker;
mod permuted;
mod recipe;
mod rotation;
mod scaling;
mod transform;

pub use adaptive::{fpt_merged, wush_adaptive};
pub use cat::{cat_block, cat_block_raw, cat_m_hat, cat_optimal};
pub use kronecker::{kronecker_cat, kronecker_factor_dims, partial_trace_factors};
pub use permuted::{correlation_ordering, permuted_cat_block};
pub use recipe::{
    has_recipe, recipe, recipe_names, register_fn_recipe, register_recipe, RecipeCtx, RecipeRef,
    TransformRecipe,
};
pub use rotation::seed_search_rotation;
pub use scaling::{diag_align_scale, smooth_quant_scale};
pub use transform::Transform;

/// The built-in transform families — the closed enum the experiment grid
/// iterates over. Each variant maps onto one registry recipe name
/// ([`Self::name`]); the open end of the axis is the registry itself
/// ([`register_recipe`]), which plans address by name directly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransformKind {
    None,
    SmoothQuant,
    QuaRot,
    SpinQuant,
    CatBlock,
    CatBlockTrained,
    FlatQuant,
    CatOptimal,
    /// Paper §7 future work: channel permutation + block CAT
    /// (implemented in [`permuted_cat_block`]; see the ablation exp).
    CatBlockPermuted,
}

impl TransformKind {
    /// The registry recipe name — the one string table for transform
    /// identity, shared by plans, tables, logs, and the CLI.
    pub fn name(&self) -> &'static str {
        match self {
            TransformKind::None => "identity",
            TransformKind::SmoothQuant => "smoothquant",
            TransformKind::QuaRot => "quarot",
            TransformKind::SpinQuant => "spinquant",
            TransformKind::CatBlock => "cat-block",
            TransformKind::CatBlockTrained => "cat-block-trained",
            TransformKind::FlatQuant => "kronecker",
            TransformKind::CatOptimal => "cat-optimal",
            TransformKind::CatBlockPermuted => "cat-block-permuted",
        }
    }

    /// Inverse of [`Self::name`] (exact registry names only; CLI aliases
    /// live in the CLI).
    pub fn from_name(name: &str) -> Option<TransformKind> {
        Self::all().iter().copied().find(|k| k.name() == name)
    }

    /// Every built-in kind.
    pub fn all() -> &'static [TransformKind] {
        &[
            TransformKind::None,
            TransformKind::SmoothQuant,
            TransformKind::QuaRot,
            TransformKind::SpinQuant,
            TransformKind::CatBlock,
            TransformKind::CatBlockTrained,
            TransformKind::FlatQuant,
            TransformKind::CatOptimal,
            TransformKind::CatBlockPermuted,
        ]
    }

    /// All Table 1 rows, in the paper's order.
    pub fn table1_rows() -> &'static [TransformKind] {
        &[
            TransformKind::None,
            TransformKind::SmoothQuant,
            TransformKind::QuaRot,
            TransformKind::CatBlock,
            TransformKind::SpinQuant,
            TransformKind::FlatQuant,
            TransformKind::CatBlockTrained,
        ]
    }
}

impl std::fmt::Display for TransformKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod kind_tests {
    use super::TransformKind;

    #[test]
    fn every_kind_has_a_registered_recipe() {
        for &k in TransformKind::all() {
            assert!(super::has_recipe(k.name()), "{k:?} → {} unregistered", k.name());
            assert_eq!(TransformKind::from_name(k.name()), Some(k));
        }
        assert_eq!(TransformKind::from_name("no-such-recipe"), None);
    }
}
