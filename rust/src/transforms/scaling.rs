//! Channel-wise scaling transforms.
//!
//! SmoothQuant (Xiao et al., 2024): `T = Diag(1/s)` with
//! `s_i = max|x_i|^α / max_j|w_{ji}|^{1−α}` — shifts activation outliers
//! into the weights. The paper (§3) reads this as trading activation
//! concentration against weight concentration, with a small alignment
//! side-effect; CAT with block size 1 ([`diag_align_scale`]) is the
//! alignment-optimal member of the same family.

use super::Transform;
use crate::linalg::Mat;

/// SmoothQuant channel scaling from calibration data.
///
/// `x`: `tokens × d` calibration activations; `ws`: the weight matrices
/// (`out × d`) sharing this input; `alpha`: migration strength (paper uses
/// the original 0.5 default).
pub fn smooth_quant_scale(x: &Mat, ws: &[&Mat], alpha: f64) -> Transform {
    let d = x.cols();
    let mut act_max = vec![0.0_f64; d];
    for t in 0..x.rows() {
        for (j, &v) in x.row(t).iter().enumerate() {
            act_max[j] = act_max[j].max(v.abs());
        }
    }
    let mut w_max = vec![0.0_f64; d];
    for w in ws {
        assert_eq!(w.cols(), d);
        for i in 0..w.rows() {
            for (j, &v) in w.row(i).iter().enumerate() {
                w_max[j] = w_max[j].max(v.abs());
            }
        }
    }
    let m: Vec<f64> = (0..d)
        .map(|j| {
            // s_j = a^α / w^{1−α}; transform multiplies x by 1/s.
            let a = act_max[j].max(1e-8);
            let w = w_max[j].max(1e-8);
            let s = a.powf(alpha) / w.powf(1.0 - alpha);
            1.0 / s.max(1e-8)
        })
        .collect();
    Transform::diagonal(format!("smoothquant(α={alpha})"), &m)
}

/// CAT with block size 1 (paper §4): the *alignment-optimal diagonal*,
/// `m_i = ( (Σ_w)_{ii} / (Σ_x)_{ii} )^{1/4}` — the diagonal case of
/// `M̂ = (Σ_w # Σ_x⁻¹)^{1/2}`.
pub fn diag_align_scale(sigma_x: &Mat, sigma_w: &Mat) -> Transform {
    let d = sigma_x.rows();
    assert_eq!(sigma_w.rows(), d);
    let m: Vec<f64> = (0..d)
        .map(|i| {
            let sw = sigma_w[(i, i)].max(1e-12);
            let sx = sigma_x[(i, i)].max(1e-12);
            (sw / sx).powf(0.25)
        })
        .collect();
    Transform::diagonal("cat(k=1)", &m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul_at_b, Rng};
    use crate::quant::{ActQuantCfg, QScheme, WeightQuantCfg};
    use crate::sqnr::{alignment_data, concentration_act, concentration_weights};

    /// Calibration-like data with outlier channels.
    fn outlier_data(tokens: usize, d: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let mut x = Mat::from_fn(tokens, d, |_, _| rng.normal());
        for t in 0..tokens {
            x[(t, 3)] *= 30.0; // persistent outlier channel
            x[(t, 11 % d)] *= 12.0;
        }
        let w = Mat::from_fn(d / 2, d, |_, _| rng.normal() * 0.05);
        (x, w)
    }

    #[test]
    fn smoothquant_moves_outliers_into_weights() {
        let (x, w) = outlier_data(256, 32, 1);
        let t = smooth_quant_scale(&x, &[&w], 0.5);
        let act = ActQuantCfg { scheme: QScheme::asym(4), clip_ratio: 1.0 };
        let wq = WeightQuantCfg::minmax(4);
        let ca_before = concentration_act(&x, act);
        let cw_before = concentration_weights(&w, wq);
        let ca_after = concentration_act(&t.apply_acts(&x), act);
        let cw_after = concentration_weights(&t.fuse_weights(&w), wq);
        assert!(ca_after > ca_before, "activation concentration should improve");
        assert!(cw_after < cw_before, "weight concentration should degrade (Fig 4)");
    }

    #[test]
    fn diag_align_improves_alignment_on_anisotropic_data() {
        let d = 24;
        let mut rng = Rng::new(2);
        // Strongly anisotropic activations, weights uncorrelated with them.
        let scales: Vec<f64> = (0..d).map(|j| (4.0_f64).powf(j as f64 / d as f64)).collect();
        let x = Mat::from_fn(2000, d, |_, j| rng.normal() * scales[j]);
        let w = Mat::from_fn(12, d, |_, j| rng.normal() / scales[j]);
        let sigma_x = matmul_at_b(&x, &x).scale(1.0 / 2000.0);
        let sigma_w = matmul_at_b(&w, &w);
        let t = diag_align_scale(&sigma_x, &sigma_w);
        let a0 = alignment_data(&x, &w);
        let a1 = alignment_data(&t.apply_acts(&x), &t.fuse_weights(&w));
        assert!(a1 > a0, "alignment {a0} -> {a1} should improve");
    }

    #[test]
    fn alpha_zero_ignores_activations() {
        let (x, w) = outlier_data(64, 16, 3);
        let t = smooth_quant_scale(&x, &[&w], 0.0);
        // α=0 ⇒ s_i = 1/max|w_i| — depends only on weights.
        let mut w2 = x.clone(); // reuse shape; different "activations"
        for v in w2.as_mut_slice() {
            *v *= 5.0;
        }
        let t2 = smooth_quant_scale(&w2, &[&w], 0.0);
        assert!(t.matrix().max_abs_diff(t2.matrix()) < 1e-12);
    }

    #[test]
    fn function_preserved() {
        let (x, w) = outlier_data(64, 16, 4);
        let t = smooth_quant_scale(&x, &[&w], 0.5);
        let y = crate::linalg::matmul_a_bt(&x, &w);
        let y2 = crate::linalg::matmul_a_bt(&t.apply_acts(&x), &t.fuse_weights(&w));
        assert!(y.max_abs_diff(&y2) < 1e-8);
    }
}
