//! Rotation transforms with seed search — the SpinQuant substitute.
//!
//! SpinQuant (Liu et al., 2024) observes that different randomized-Hadamard
//! seeds give widely varying accuracy and trains rotations by gradient
//! descent. Without GPU training, we reproduce the *rotation-selection*
//! effect directly: draw `n_seeds` randomized Hadamard (or Haar) rotations
//! and keep the one maximizing the Theorem 2.4 SQNR approximation on
//! calibration data (substitution documented in DESIGN.md §3).
//!
//! Because rotations cannot change alignment (paper eq. 4), this can only
//! improve the concentration terms — exactly the paper's point about the
//! limits of rotation-based methods.

use super::Transform;
use crate::linalg::{is_pow2, random_orthogonal, randomized_hadamard, Mat, Rng};
use crate::quant::{ActQuantCfg, WeightQuantCfg};
use crate::sqnr::approx_sqnr_joint;

/// Search `n_seeds` rotations, score each by the Thm 2.4 approximation of
/// the post-transform joint SQNR (summed over the weight matrices sharing
/// this input), return the best.
pub fn seed_search_rotation(
    x: &Mat,
    ws: &[&Mat],
    act: ActQuantCfg,
    wq: WeightQuantCfg,
    n_seeds: u64,
    base_seed: u64,
) -> Transform {
    let d = x.cols();
    let mut best: Option<(f64, Transform)> = None;
    for s in 0..n_seeds {
        let mut rng = Rng::new(base_seed.wrapping_add(s).wrapping_mul(0x9E3779B97F4A7C15));
        let q = if is_pow2(d) {
            randomized_hadamard(d, &mut rng)
        } else {
            random_orthogonal(d, &mut rng)
        };
        let t = Transform::orthogonal(format!("spinquant(seed={s})"), q);
        let xt = t.apply_acts(x);
        let mut score = 0.0;
        for w in ws {
            let wt = t.fuse_weights(w);
            score += approx_sqnr_joint(&xt, &wt, act, wq).ln();
        }
        if best.as_ref().map(|(b, _)| score > *b).unwrap_or(true) {
            best = Some((score, t));
        }
    }
    best.expect("n_seeds must be ≥ 1").1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::quant::QScheme;
    use crate::sqnr::alignment_data;

    fn data(seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let d = 32;
        let mut x = Mat::from_fn(128, d, |_, _| rng.student_t(3));
        for t in 0..x.rows() {
            x[(t, 5)] *= 20.0;
        }
        let w = Mat::from_fn(16, d, |_, _| rng.normal() * 0.1);
        (x, w)
    }

    fn cfgs() -> (ActQuantCfg, WeightQuantCfg) {
        (ActQuantCfg { scheme: QScheme::asym(4), clip_ratio: 1.0 }, WeightQuantCfg::minmax(4))
    }

    #[test]
    fn seed_search_at_least_as_good_as_first_seed() {
        let (x, w) = data(1);
        let (act, wq) = cfgs();
        let t1 = seed_search_rotation(&x, &[&w], act, wq, 1, 0);
        let t8 = seed_search_rotation(&x, &[&w], act, wq, 8, 0);
        let score = |t: &Transform| {
            approx_sqnr_joint(&t.apply_acts(&x), &t.fuse_weights(&w), act, wq)
        };
        assert!(score(&t8) >= score(&t1) * 0.999);
    }

    #[test]
    fn rotations_leave_alignment_invariant() {
        // The paper's central negative result for rotation methods.
        let (x, w) = data(2);
        let (act, wq) = cfgs();
        let t = seed_search_rotation(&x, &[&w], act, wq, 4, 7);
        let a0 = alignment_data(&x, &w);
        let a1 = alignment_data(&t.apply_acts(&x), &t.fuse_weights(&w));
        assert!((a0 - a1).abs() < 1e-9, "rotation changed alignment: {a0} vs {a1}");
    }

    #[test]
    fn improves_concentration_on_outlier_data() {
        use crate::sqnr::concentration_act;
        let (x, w) = data(3);
        let (act, wq) = cfgs();
        let t = seed_search_rotation(&x, &[&w], act, wq, 4, 0);
        let c0 = concentration_act(&x, act);
        let c1 = concentration_act(&t.apply_acts(&x), act);
        assert!(c1 > c0 * 1.5, "rotation should spread outliers: {c0} -> {c1}");
    }

    #[test]
    fn non_pow2_dims_fall_back_to_haar() {
        let mut rng = Rng::new(4);
        let d = 24; // not a power of two
        let x = Mat::from_fn(64, d, |_, _| rng.normal());
        let w = Mat::from_fn(8, d, |_, _| rng.normal());
        let (act, wq) = cfgs();
        let t = seed_search_rotation(&x, &[&w], act, wq, 2, 0);
        assert!(t.inversion_error() < 1e-9);
    }
}
