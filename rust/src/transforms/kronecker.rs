//! Kronecker-factored transforms — the FlatQuant substitute.
//!
//! FlatQuant (Sun et al., 2025) parameterizes the transform as a Kronecker
//! product `T = T₁ ⊗ T₂` of two small invertible matrices (cost
//! `O(d(d₁+d₂))` online instead of `O(d²)`) and trains the factors.
//! Offline-training-free substitute (DESIGN.md §3): build each factor as a
//! CAT geometric-mean optimum on the *partial-trace* statistics of its
//! axis, i.e. the best Kronecker-structured approximation of the CAT
//! objective, then (optionally) refine by coordinate descent on the
//! Theorem 2.4 SQNR proxy.

use super::{cat_m_hat, Transform};
use crate::linalg::{spd_inv, Mat};

/// Split `d` into factor dims `d₁·d₂ = d` with `d₁ ≤ d₂` as balanced as
/// possible (FlatQuant's setting).
pub fn kronecker_factor_dims(d: usize) -> (usize, usize) {
    let mut best = (1, d);
    let mut i = 1;
    while i * i <= d {
        if d % i == 0 {
            best = (i, d / i);
        }
        i += 1;
    }
    best
}

/// Partial traces of a `d×d` PSD matrix over a `d₁×d₂` index split
/// (`i = i₁·d₂ + i₂`): returns `(Σ₁, Σ₂)` with
/// `Σ₁[i₁,j₁] = (1/d₂)·Σ_{i₂} Σ[i₁d₂+i₂, j₁d₂+i₂]` and symmetrically for
/// `Σ₂`. These are the axis-wise statistics the Kronecker factors see.
pub fn partial_trace_factors(sigma: &Mat, d1: usize, d2: usize) -> (Mat, Mat) {
    assert_eq!(sigma.rows(), d1 * d2);
    let mut s1 = Mat::zeros(d1, d1);
    for i1 in 0..d1 {
        for j1 in 0..d1 {
            let mut acc = 0.0;
            for i2 in 0..d2 {
                acc += sigma[(i1 * d2 + i2, j1 * d2 + i2)];
            }
            s1[(i1, j1)] = acc / d2 as f64;
        }
    }
    let mut s2 = Mat::zeros(d2, d2);
    for i2 in 0..d2 {
        for j2 in 0..d2 {
            let mut acc = 0.0;
            for i1 in 0..d1 {
                acc += sigma[(i1 * d2 + i2, i1 * d2 + j2)];
            }
            s2[(i2, j2)] = acc / d1 as f64;
        }
    }
    s1.symmetrize();
    s2.symmetrize();
    (s1, s2)
}

/// Dense Kronecker product `A ⊗ B`.
fn kron(a: &Mat, b: &Mat) -> Mat {
    let (ar, ac) = (a.rows(), a.cols());
    let (br, bc) = (b.rows(), b.cols());
    Mat::from_fn(ar * br, ac * bc, |i, j| a[(i / br, j / bc)] * b[(i % br, j % bc)])
}

/// FlatQuant-style transform: `T = (H₁·M₁) ⊗ (H₂·M₂)` with each `Mᵢ` the
/// CAT optimum of its axis statistics and `Hᵢ` the axis Hadamard/rotation.
///
/// `sigma_x`, `sigma_w`: full `d×d` statistics (as for [`cat_m_hat`]).
pub fn kronecker_cat(sigma_x: &Mat, sigma_w: &Mat, seed: u64) -> Transform {
    let d = sigma_x.rows();
    let (d1, d2) = kronecker_factor_dims(d);
    if d1 == 1 {
        // d prime: degenerate split, fall back to diagonal + rotation.
        return super::cat_block(sigma_x, sigma_w, 1, seed);
    }
    let (sx1, sx2) = partial_trace_factors(sigma_x, d1, d2);
    let (sw1, sw2) = partial_trace_factors(sigma_w, d1, d2);
    let m1 = cat_m_hat(&sx1, &sw1);
    let m2 = cat_m_hat(&sx2, &sw2);
    let h1 = rotation_factor(d1, seed);
    let h2 = rotation_factor(d2, seed ^ 0x5EED);
    let f1 = crate::linalg::matmul(&h1, &m1);
    let f2 = crate::linalg::matmul(&h2, &m2);
    let f1_inv = crate::linalg::matmul(&spd_inv(&m1), &h1.transpose());
    let f2_inv = crate::linalg::matmul(&spd_inv(&m2), &h2.transpose());
    Transform::new(
        format!("flatquant({d1}×{d2})"),
        kron(&f1, &f2),
        kron(&f1_inv, &f2_inv),
    )
}

fn rotation_factor(d: usize, seed: u64) -> Mat {
    if crate::linalg::is_pow2(d) {
        crate::linalg::hadamard_matrix(d)
    } else {
        let mut rng = crate::linalg::Rng::new(seed);
        crate::linalg::random_orthogonal(d, &mut rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_a_bt, matmul_at_b, Rng};
    use crate::quant::{ActQuantCfg, QScheme, WeightQuantCfg};
    use crate::sqnr::{alignment_data, approx_sqnr_joint};

    #[test]
    fn factor_dims_balanced() {
        assert_eq!(kronecker_factor_dims(64), (8, 8));
        assert_eq!(kronecker_factor_dims(128), (8, 16));
        assert_eq!(kronecker_factor_dims(12), (3, 4));
        assert_eq!(kronecker_factor_dims(7), (1, 7));
    }

    #[test]
    fn kron_matches_definition() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::eye(2);
        let k = kron(&a, &b);
        assert_eq!(k.rows(), 4);
        assert_eq!(k[(0, 0)], 1.0);
        assert_eq!(k[(1, 1)], 1.0);
        assert_eq!(k[(0, 2)], 2.0);
        assert_eq!(k[(2, 0)], 3.0);
        assert_eq!(k[(3, 3)], 4.0);
    }

    #[test]
    fn partial_trace_of_kron_recovers_factors() {
        // Σ = A ⊗ B ⇒ partial traces ∝ A·mean(diag B) and B·mean(diag A).
        let a = Mat::from_vec(2, 2, vec![2.0, 0.5, 0.5, 1.0]);
        let b = Mat::from_vec(3, 3, vec![1.0, 0.2, 0.0, 0.2, 3.0, 0.1, 0.0, 0.1, 2.0]);
        let s = kron(&a, &b);
        let (s1, s2) = partial_trace_factors(&s, 2, 3);
        let tb = b.trace() / 3.0;
        let ta = a.trace() / 2.0;
        assert!(s1.max_abs_diff(&a.scale(tb)) < 1e-12);
        assert!(s2.max_abs_diff(&b.scale(ta)) < 1e-12);
    }

    fn kron_structured_layer(d1: usize, d2: usize, seed: u64) -> (Mat, Mat) {
        // Activations with Kronecker-ish covariance so the factored
        // transform has signal to exploit.
        let d = d1 * d2;
        let mut rng = Rng::new(seed);
        let a1 = Mat::from_fn(d1, d1, |_, _| rng.normal());
        let a2 = Mat::from_fn(d2, d2, |_, _| rng.normal() * 0.5);
        let mix = kron(&a1, &a2);
        let z = Mat::from_fn(30 * d, d, |_, _| rng.normal());
        let x = matmul(&z, &mix.transpose());
        let w = Mat::from_fn(d, d, |i, j| rng.normal() * (3.0_f64).powf(((i * j) % d) as f64 / d as f64) * 0.01);
        (x, w)
    }

    #[test]
    fn function_preserved() {
        let (x, w) = kron_structured_layer(4, 8, 1);
        let sigma_x = matmul_at_b(&x, &x).scale(1.0 / x.rows() as f64);
        let sigma_w = matmul_at_b(&w, &w);
        let t = kronecker_cat(&sigma_x, &sigma_w, 0);
        let y = matmul_a_bt(&x, &w);
        let y2 = matmul_a_bt(&t.apply_acts(&x), &t.fuse_weights(&w));
        let rel = y.max_abs_diff(&y2) / y.max_abs();
        assert!(rel < 1e-6, "rel {rel}");
    }

    #[test]
    fn improves_over_identity() {
        let (x, w) = kron_structured_layer(4, 8, 2);
        let sigma_x = matmul_at_b(&x, &x).scale(1.0 / x.rows() as f64);
        let sigma_w = matmul_at_b(&w, &w);
        let act = ActQuantCfg { scheme: QScheme::asym(4), clip_ratio: 1.0 };
        let wq = WeightQuantCfg::minmax(4);
        let t = kronecker_cat(&sigma_x, &sigma_w, 0);
        let s0 = approx_sqnr_joint(&x, &w, act, wq);
        let s1 = approx_sqnr_joint(&t.apply_acts(&x), &t.fuse_weights(&w), act, wq);
        assert!(s1 > s0, "flatquant should beat identity: {s0} vs {s1}");
    }

    #[test]
    fn improves_alignment_unlike_rotations() {
        let (x, w) = kron_structured_layer(4, 8, 3);
        let sigma_x = matmul_at_b(&x, &x).scale(1.0 / x.rows() as f64);
        let sigma_w = matmul_at_b(&w, &w);
        let t = kronecker_cat(&sigma_x, &sigma_w, 0);
        let a0 = alignment_data(&x, &w);
        let a1 = alignment_data(&t.apply_acts(&x), &t.fuse_weights(&w));
        assert!(a1 > a0, "kronecker CAT should improve alignment: {a0} -> {a1}");
    }
}
