//! Permuted block CAT — the paper's future-work direction (§7:
//! "adding mergeable rotations or permutations that can improve the
//! block-diagonal approximation").
//!
//! A block-diagonal M̂ can only exploit correlation structure *inside*
//! each k-block. A channel permutation `P` (free at inference: it fuses
//! into the surrounding weights exactly like the transform itself) can
//! first gather strongly-interacting channels into the same block. We
//! order channels by their loading on the principal eigenvector of the
//! blended correlation matrix `|corr(Σ_x)| + |corr(Σ_w)|` — a spectral
//! seriation heuristic that places correlated channels contiguously —
//! then build the usual block CAT in the permuted basis:
//!
//! `T = H · M̂_block(P Σ P ᵀ) · P`.

use super::{cat_block_raw, Transform};
use crate::linalg::{eigh, hadamard_matrix, is_pow2, random_orthogonal, Mat, Rng};

/// Channel ordering from spectral seriation of the blended correlations.
pub fn correlation_ordering(sigma_x: &Mat, sigma_w: &Mat) -> Vec<usize> {
    let d = sigma_x.rows();
    let mut blend = Mat::zeros(d, d);
    let dx: Vec<f64> = (0..d).map(|i| sigma_x[(i, i)].max(1e-12).sqrt()).collect();
    let dw: Vec<f64> = (0..d).map(|i| sigma_w[(i, i)].max(1e-12).sqrt()).collect();
    for i in 0..d {
        for j in 0..d {
            let cx = (sigma_x[(i, j)] / (dx[i] * dx[j])).abs();
            let cw = (sigma_w[(i, j)] / (dw[i] * dw[j])).abs();
            blend[(i, j)] = cx + cw;
        }
    }
    blend.symmetrize();
    let e = eigh(&blend);
    // Principal eigenvector = last column (ascending order).
    let v = e.vectors.col(d - 1);
    let mut idx: Vec<usize> = (0..d).collect();
    idx.sort_by(|&a, &b| v[a].partial_cmp(&v[b]).unwrap());
    idx
}

/// Dense permutation matrix `P` with `(Px)_i = x_{perm[i]}`.
pub(super) fn permutation_matrix(perm: &[usize]) -> Mat {
    let d = perm.len();
    let mut p = Mat::zeros(d, d);
    for (i, &src) in perm.iter().enumerate() {
        p[(i, src)] = 1.0;
    }
    p
}

/// Permuted block CAT: `T = H · M̂_block^k(permuted stats) · P`.
pub fn permuted_cat_block(sigma_x: &Mat, sigma_w: &Mat, k: usize, seed: u64) -> Transform {
    let d = sigma_x.rows();
    let perm = correlation_ordering(sigma_x, sigma_w);
    let p = Transform::orthogonal("P", permutation_matrix(&perm));
    let sx_p = p.conjugate_sigma(sigma_x);
    let sw_p = p.conjugate_sigma(sigma_w);
    let blocks = cat_block_raw(&sx_p, &sw_p, k.min(d));
    let h = if is_pow2(d) {
        Transform::orthogonal("H", hadamard_matrix(d))
    } else {
        let mut rng = Rng::new(seed ^ 0x9E12);
        Transform::orthogonal("R", random_orthogonal(d, &mut rng))
    };
    let t = p.then(&blocks).then(&h);
    Transform::new(format!("cat-perm-block(k={})", k.min(d)), t.matrix().clone(), t.inverse_matrix().clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_at_b};
    use crate::sqnr::{alignment_data, max_alignment};

    /// Structure where correlated channel *pairs* are scattered far
    /// apart: channel i and i+d/2 are strongly coupled. Plain block CAT
    /// with k = 2 can never see a pair; a permutation can.
    fn scattered_pairs(d: usize, tokens: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let half = d / 2;
        let mut x = Mat::zeros(tokens, d);
        for t in 0..tokens {
            for i in 0..half {
                let z = rng.normal() * (1.0 + 9.0 * (i as f64) / half as f64);
                let noise = rng.normal() * 0.05;
                x[(t, i)] = z;
                x[(t, i + half)] = -z + noise; // anti-correlated partner
            }
        }
        let w = Mat::from_fn(d, d, |r, c| {
            // Weights read each pair's *sum* (small signal) — alignment
            // is poor unless the transform can rotate within the pair.
            let base = rng.normal() * 0.01;
            if c < half && (r % half) == c {
                base + 1.0
            } else if c >= half && (r % half) == c - half {
                base + 1.0
            } else {
                base
            }
        });
        (x, w)
    }

    #[test]
    fn ordering_is_a_permutation() {
        let mut rng = Rng::new(1);
        let g = Mat::from_fn(40, 16, |_, _| rng.normal());
        let s = matmul_at_b(&g, &g);
        let perm = correlation_ordering(&s, &s);
        let mut seen = vec![false; 16];
        for &i in &perm {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn permutation_matrix_is_orthogonal() {
        let p = permutation_matrix(&[2, 0, 3, 1]);
        let ptp = matmul(&p.transpose(), &p);
        assert!(ptp.max_abs_diff(&Mat::eye(4)) < 1e-12);
    }

    #[test]
    fn function_preserved() {
        let (x, w) = scattered_pairs(16, 400, 2);
        let sigma_x = matmul_at_b(&x, &x).scale(1.0 / 400.0);
        let sigma_w = matmul_at_b(&w, &w);
        let t = permuted_cat_block(&sigma_x, &sigma_w, 4, 0);
        let y = crate::linalg::matmul_a_bt(&x, &w);
        let y2 = crate::linalg::matmul_a_bt(&t.apply_acts(&x), &t.fuse_weights(&w));
        assert!(y.max_abs_diff(&y2) / y.max_abs() < 1e-6);
    }

    #[test]
    fn permutation_gathers_scattered_pairs() {
        // The seriation must place partner channels (i, i+half) in the
        // same k=2 neighborhood for most pairs.
        let d = 16;
        let (x, _w) = scattered_pairs(d, 2000, 3);
        let sigma_x = matmul_at_b(&x, &x).scale(1.0 / 2000.0);
        let perm = correlation_ordering(&sigma_x, &Mat::eye(d));
        let pos: Vec<usize> = {
            let mut p = vec![0; d];
            for (slot, &ch) in perm.iter().enumerate() {
                p[ch] = slot;
            }
            p
        };
        let half = d / 2;
        let adjacent = (0..half)
            .filter(|&i| pos[i].abs_diff(pos[i + half]) == 1)
            .count();
        assert!(
            adjacent >= half - 2,
            "only {adjacent}/{half} pairs adjacent after seriation"
        );
    }

    #[test]
    fn permuted_beats_plain_block_cat_on_scattered_structure() {
        let d = 16;
        let (x, w) = scattered_pairs(d, 2000, 4);
        let sigma_x = matmul_at_b(&x, &x).scale(1.0 / 2000.0);
        let sigma_w = matmul_at_b(&w, &w);
        let k = 2;
        let plain = super::super::cat_block(&sigma_x, &sigma_w, k, 0);
        let perm = permuted_cat_block(&sigma_x, &sigma_w, k, 0);
        let a = |t: &Transform| alignment_data(&t.apply_acts(&x), &t.fuse_weights(&w));
        let a_plain = a(&plain);
        let a_perm = a(&perm);
        let a_opt = max_alignment(&sigma_x, &w);
        assert!(
            a_perm > a_plain * 1.5,
            "permutation should help: plain {a_plain:.5} perm {a_perm:.5} opt {a_opt:.5}"
        );
    }
}
