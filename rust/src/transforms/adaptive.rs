//! Adaptive transform recipes from the post-CAT literature, registered
//! as planner search candidates.
//!
//! * [`wush_adaptive`] — a WUSH-style near-optimal adaptive transform:
//!   per-block alignment-optimal `M̂` (the same calibration-covariance
//!   geometric mean CAT uses) composed with a **per-block randomized**
//!   concentration rotation drawn from the calibration seed. Where
//!   CAT (block) applies one fixed global Hadamard, this keeps the whole
//!   transform block-diagonal and adapts the rotation per block — each
//!   block gets its own sign-randomized Hadamard (Haar rotation on
//!   non-power-of-two tails), so worst-case activation directions can't
//!   line up with the fixed Hadamard rows across blocks.
//! * [`fpt_merged`] — an FPTQuant-style merged function-preserving
//!   transform: a channel permutation composed with a diagonal alignment
//!   scale. Both factors fold into the adjacent linear ops exactly
//!   (one nonzero per row), so the merged transform costs nothing at
//!   inference while still buying alignment from second-order stats.
//!
//! Both fit from `(Σ_x, Σ_w)` only — no extra calibration forwards —
//! and are registered in the builtin recipe registry as
//! `wush-adaptive` and `fpt-merged`, which makes them visible to the
//! planner's search over `(group × bits × recipe)` cells.

use super::cat::cat_m_hat;
use super::permuted::permutation_matrix;
use super::{correlation_ordering, diag_align_scale, Transform};
use crate::linalg::{is_pow2, matmul, random_orthogonal, randomized_hadamard, spd_inv, Mat, Rng};

/// WUSH-style adaptive block transform: `Diag(R_1·M̂_1, …, R_n·M̂_n)`
/// with per-block geometric-mean optima `M̂_b` and per-block seeded
/// randomized rotations `R_b`.
///
/// The inverse is assembled analytically per block (`M̂_b⁻¹·R_bᵀ`), so
/// fusing into weights never inverts the full `d × d` matrix.
pub fn wush_adaptive(sigma_x: &Mat, sigma_w: &Mat, k: usize, seed: u64) -> Transform {
    let d = sigma_x.rows();
    assert_eq!(sigma_w.rows(), d, "Σ_w / Σ_x dim mismatch");
    let k = k.clamp(1, d);
    let mut m = Mat::zeros(d, d);
    let mut m_inv = Mat::zeros(d, d);
    let mut rng = Rng::new(seed ^ 0x5755_5348); // "WUSH"
    let mut start = 0;
    while start < d {
        let kb = k.min(d - start);
        let sx_b = sigma_x.block(start, start, kb, kb);
        let sw_b = sigma_w.block(start, start, kb, kb);
        let mb = cat_m_hat(&sx_b, &sw_b);
        let r = if is_pow2(kb) {
            randomized_hadamard(kb, &mut rng)
        } else {
            random_orthogonal(kb, &mut rng)
        };
        m.set_block(start, start, &matmul(&r, &mb));
        m_inv.set_block(start, start, &matmul(&spd_inv(&mb), &r.transpose()));
        start += kb;
    }
    Transform::new(format!("wush-adaptive(k={k})"), m, m_inv)
}

/// FPTQuant-style merged function-preserving transform:
/// `T = D_align · P` — correlation-seriation permutation, then the
/// diagonal `(Σ_w,ii / Σ_x,ii)^{1/4}` alignment scale in the permuted
/// basis. `T` has exactly one nonzero per row, so it merges into the
/// surrounding weights with zero runtime cost.
pub fn fpt_merged(sigma_x: &Mat, sigma_w: &Mat) -> Transform {
    let perm = correlation_ordering(sigma_x, sigma_w);
    let p = Transform::orthogonal("P", permutation_matrix(&perm));
    let sx_p = p.conjugate_sigma(sigma_x);
    let sw_p = p.conjugate_sigma(sigma_w);
    let scale = diag_align_scale(&sx_p, &sw_p);
    let t = p.then(&scale);
    Transform::new("fpt-merged", t.matrix().clone(), t.inverse_matrix().clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::syrk_at_a;
    use crate::sqnr::{alignment_data, max_alignment, sample_sigma};

    /// Anisotropic, correlated activations + weights with mismatched
    /// principal directions (same regime as the CAT tests).
    fn hard_layer(d: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let tokens = 40 * d;
        let a = Mat::from_fn(d, d, |i, j| {
            rng.normal() * (6.0_f64).powf(-(((i + j) % d) as f64) / d as f64)
        });
        let z = Mat::from_fn(tokens, d, |_, _| rng.normal());
        let x = matmul(&z, &a.transpose());
        let w = Mat::from_fn(d / 2, d, |i, j| {
            rng.normal() * (5.0_f64).powf(((i + 2 * j) % d) as f64 / d as f64) * 0.01
        });
        (x, w)
    }

    fn stats(x: &Mat, w: &Mat) -> (Mat, Mat) {
        (sample_sigma(x), syrk_at_a(w))
    }

    #[test]
    fn wush_preserves_function() {
        let (x, w) = hard_layer(16, 31);
        let (sx, sw) = stats(&x, &w);
        let t = wush_adaptive(&sx, &sw, 4, 7);
        let y = matmul(&x, &w.transpose());
        let yt = matmul(&t.apply_acts(&x), &t.fuse_weights(&w).transpose());
        let diff = y.sub(&yt).fro_norm2().sqrt() / y.fro_norm2().sqrt();
        assert!(diff < 1e-8, "wush must be function-preserving, diff {diff}");
    }

    #[test]
    fn wush_full_block_achieves_max_alignment() {
        // At k = d the per-block M̂ is the global optimum and the rotation
        // is alignment-free, so A(t) ≈ A_max (paper eq. 9).
        let (x, w) = hard_layer(16, 32);
        let (sx, sw) = stats(&x, &w);
        let t = wush_adaptive(&sx, &sw, 16, 0);
        let a_t = alignment_data(&t.apply_acts(&x), &t.fuse_weights(&w));
        let a_max = max_alignment(&sx, &w);
        assert!(
            (a_t - a_max).abs() / a_max < 0.02,
            "wush(k=d) alignment {a_t} vs max {a_max}"
        );
    }

    #[test]
    fn wush_improves_alignment_over_identity_at_small_k() {
        let (x, w) = hard_layer(16, 33);
        let (sx, sw) = stats(&x, &w);
        let a0 = alignment_data(&x, &w);
        let t = wush_adaptive(&sx, &sw, 4, 0);
        let a_t = alignment_data(&t.apply_acts(&x), &t.fuse_weights(&w));
        assert!(a_t > a0, "wush(k=4) alignment {a_t} must beat identity {a0}");
    }

    #[test]
    fn wush_is_seeded_and_block_diagonal() {
        let (x, w) = hard_layer(16, 34);
        let (sx, sw) = stats(&x, &w);
        let t0 = wush_adaptive(&sx, &sw, 4, 0);
        let t0b = wush_adaptive(&sx, &sw, 4, 0);
        let t1 = wush_adaptive(&sx, &sw, 4, 1);
        // Deterministic per seed…
        assert_eq!(t0.matrix().sub(t0b.matrix()).fro_norm2(), 0.0);
        // …different across seeds (the per-block rotation is randomized)…
        assert!(t0.matrix().sub(t1.matrix()).fro_norm2() > 1e-12);
        // …and block-diagonal: no mass outside the 4×4 diagonal blocks.
        let m = t0.matrix();
        for i in 0..16 {
            for j in 0..16 {
                if i / 4 != j / 4 {
                    assert_eq!(m[(i, j)], 0.0, "off-block mass at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn fpt_preserves_function() {
        let (x, w) = hard_layer(12, 35);
        let (sx, sw) = stats(&x, &w);
        let t = fpt_merged(&sx, &sw);
        let y = matmul(&x, &w.transpose());
        let yt = matmul(&t.apply_acts(&x), &t.fuse_weights(&w).transpose());
        let diff = y.sub(&yt).fro_norm2().sqrt() / y.fro_norm2().sqrt();
        assert!(diff < 1e-8, "fpt must be function-preserving, diff {diff}");
    }

    #[test]
    fn fpt_is_mergeable_one_nonzero_per_row() {
        // The zero-runtime-cost claim: T = D·P has exactly one nonzero
        // per row (and per column), so it folds into adjacent weights.
        let (x, w) = hard_layer(12, 36);
        let (sx, sw) = stats(&x, &w);
        let t = fpt_merged(&sx, &sw);
        let m = t.matrix();
        for i in 0..12 {
            let nz = (0..12).filter(|&j| m[(i, j)] != 0.0).count();
            assert_eq!(nz, 1, "row {i} has {nz} nonzeros");
        }
        for j in 0..12 {
            let nz = (0..12).filter(|&i| m[(i, j)] != 0.0).count();
            assert_eq!(nz, 1, "col {j} has {nz} nonzeros");
        }
    }

    #[test]
    fn fpt_improves_alignment_on_mismatched_scales() {
        // Diagonal alignment scaling is the k=1 optimum: on data with
        // mismatched per-channel scales it must improve alignment.
        let (x, w) = hard_layer(12, 37);
        let (sx, sw) = stats(&x, &w);
        let a0 = alignment_data(&x, &w);
        let t = fpt_merged(&sx, &sw);
        let a_t = alignment_data(&t.apply_acts(&x), &t.fuse_weights(&w));
        assert!(a_t > a0, "fpt alignment {a_t} must beat identity {a0}");
    }
}
