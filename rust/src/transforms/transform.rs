//! The invertible linear transform type.

use crate::linalg::{matmul, matmul_a_bt, spd_inv, Mat};

/// An invertible transform `T` applied as `x' = Tx`, `W' = WT⁻¹`
/// (paper eq. 5). Stores both directions explicitly so fusion into model
/// weights never solves a system on the hot path.
#[derive(Clone)]
pub struct Transform {
    pub name: String,
    t: Mat,
    t_inv: Mat,
}

impl Transform {
    /// Wrap an explicit pair, validating `T·T⁻¹ ≈ I`.
    pub fn new(name: impl Into<String>, t: Mat, t_inv: Mat) -> Transform {
        debug_assert!(t.is_square() && t_inv.is_square());
        let tr = Transform { name: name.into(), t, t_inv };
        debug_assert!(
            tr.inversion_error() < 1e-6,
            "{}: T·T⁻¹ deviates from I by {}",
            tr.name,
            tr.inversion_error()
        );
        tr
    }

    /// The identity transform (the "None" baseline).
    pub fn identity(d: usize) -> Transform {
        Transform { name: "identity".into(), t: Mat::eye(d), t_inv: Mat::eye(d) }
    }

    /// An orthogonal transform: `T⁻¹ = Tᵀ`, no inversion needed.
    pub fn orthogonal(name: impl Into<String>, q: Mat) -> Transform {
        let t_inv = q.transpose();
        Transform { name: name.into(), t: q, t_inv }
    }

    /// A diagonal transform from per-channel multipliers `m` (`x'_i = m_i·x_i`).
    pub fn diagonal(name: impl Into<String>, m: &[f64]) -> Transform {
        let inv: Vec<f64> = m
            .iter()
            .map(|&v| {
                assert!(v != 0.0 && v.is_finite(), "singular diagonal transform");
                1.0 / v
            })
            .collect();
        Transform { name: name.into(), t: Mat::diag(m), t_inv: Mat::diag(&inv) }
    }

    /// A symmetric positive-definite transform (CAT's M̂): inverse via
    /// clamped spectral inversion.
    pub fn spd(name: impl Into<String>, m: Mat) -> Transform {
        let inv = spd_inv(&m);
        Transform { name: name.into(), t: m, t_inv: inv }
    }

    /// Compose: apply `self` first, then `outer` — `T = T_outer · T_self`.
    pub fn then(&self, outer: &Transform) -> Transform {
        Transform {
            name: format!("{}∘{}", outer.name, self.name),
            t: matmul(&outer.t, &self.t),
            t_inv: matmul(&self.t_inv, &outer.t_inv),
        }
    }

    pub fn dim(&self) -> usize {
        self.t.rows()
    }

    pub fn matrix(&self) -> &Mat {
        &self.t
    }

    pub fn inverse_matrix(&self) -> &Mat {
        &self.t_inv
    }

    /// Transform activations: rows of `x` (`tokens × d`) become `Tx`,
    /// i.e. `X' = X·Tᵀ`.
    pub fn apply_acts(&self, x: &Mat) -> Mat {
        matmul_a_bt(x, &self.t)
    }

    /// Fuse into a weight matrix (`out × d`): `W' = W·T⁻¹`.
    pub fn fuse_weights(&self, w: &Mat) -> Mat {
        matmul(w, &self.t_inv)
    }

    /// Conjugate an activation autocorrelation: `Σ' = T·Σ·Tᵀ`.
    ///
    /// Kept as two `matmul`s (not the transpose-free `matmul_a_bt`): the
    /// kernels' accumulation orders differ in the low bits, and serial
    /// runs must stay bit-identical to the pre-parallel-layer baseline.
    pub fn conjugate_sigma(&self, sigma: &Mat) -> Mat {
        let mut s = matmul(&matmul(&self.t, sigma), &self.t.transpose());
        s.symmetrize();
        s
    }

    /// `max|T·T⁻¹ − I|` — numerical health check.
    pub fn inversion_error(&self) -> f64 {
        matmul(&self.t, &self.t_inv).max_abs_diff(&Mat::eye(self.dim()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{random_orthogonal, Rng};

    #[test]
    fn function_preservation() {
        // (WT⁻¹)(Tx) == Wx for any invertible T.
        let d = 16;
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(40, d, |_, _| rng.normal());
        let w = Mat::from_fn(8, d, |_, _| rng.normal());
        let q = random_orthogonal(d, &mut rng);
        let t = Transform::orthogonal("rot", q);
        let y = matmul_a_bt(&x, &w);
        let y2 = matmul_a_bt(&t.apply_acts(&x), &t.fuse_weights(&w));
        assert!(y.max_abs_diff(&y2) < 1e-9);
    }

    #[test]
    fn composition_order() {
        let d = 8;
        let mut rng = Rng::new(2);
        let a = Transform::orthogonal("a", random_orthogonal(d, &mut rng));
        let m: Vec<f64> = (0..d).map(|i| 1.0 + i as f64).collect();
        let b = Transform::diagonal("b", &m);
        let c = a.then(&b); // b·a
        let x = Mat::from_fn(5, d, |_, _| rng.normal());
        let want = b.apply_acts(&a.apply_acts(&x));
        let got = c.apply_acts(&x);
        assert!(want.max_abs_diff(&got) < 1e-9);
        assert!(c.inversion_error() < 1e-9);
    }

    #[test]
    fn diagonal_roundtrip() {
        let m = [2.0, -0.5, 4.0];
        let t = Transform::diagonal("d", &m);
        assert!(t.inversion_error() < 1e-12);
    }

    #[test]
    fn conjugate_sigma_matches_data() {
        let d = 12;
        let mut rng = Rng::new(3);
        let x = Mat::from_fn(500, d, |_, _| rng.normal() * (1.0 + rng.uniform()));
        let q = random_orthogonal(d, &mut rng);
        let t = Transform::orthogonal("rot", q);
        let sigma = crate::linalg::matmul_at_b(&x, &x).scale(1.0 / 500.0);
        let sigma_t = t.conjugate_sigma(&sigma);
        let xt = t.apply_acts(&x);
        let sigma_direct = crate::linalg::matmul_at_b(&xt, &xt).scale(1.0 / 500.0);
        assert!(sigma_t.max_abs_diff(&sigma_direct) < 1e-9);
    }

    #[test]
    #[should_panic]
    fn singular_diagonal_rejected() {
        Transform::diagonal("bad", &[1.0, 0.0, 2.0]);
    }
}
