//! Transform *recipes*: named, registrable builders of [`Transform`]s.
//!
//! The paper treats the transform as one independent axis of the SQNR
//! objective; this module makes that axis open. A [`TransformRecipe`]
//! knows how to fit a transform from one layer group's calibration
//! statistics ([`RecipeCtx`]), and the process-wide registry maps recipe
//! *names* (the strings a [`crate::pipeline::QuantPlan`] carries) to
//! recipe objects. Every built-in transform of the zoo is pre-registered;
//! external code can add its own with [`register_recipe`] (or the
//! closure shorthand [`register_fn_recipe`]) without touching this crate
//! — the adaptive-transform space WUSH/FPTQuant explore plugs in here.

use super::Transform;
use crate::linalg::Mat;
use crate::quant::{ActQuantCfg, WeightQuantCfg};
use std::collections::HashMap;
use std::sync::{Arc, OnceLock, RwLock};

/// Everything a recipe may draw on to fit one layer group's transform.
///
/// All statistics are *pre-transform*: the calibration pass's row
/// subsample and autocorrelation of the group input, plus the group's
/// weight matrices and their summed Gram matrix.
pub struct RecipeCtx<'a> {
    /// Row subsample of the group input (`tokens × d`).
    pub x_sample: &'a Mat,
    /// Group-input autocorrelation `Σ_x = E[xxᵀ]` (`d × d`).
    pub sigma_x: &'a Mat,
    /// The group's weight matrices (`out × d` each).
    pub ws: &'a [&'a Mat],
    /// `Σ_w = Σ WᵀW` over the group's weights (`d × d`).
    pub sigma_w: &'a Mat,
    /// Activation quantization the transform will be judged under.
    pub act: ActQuantCfg,
    /// Weight quantization the transform will be judged under.
    pub wq: WeightQuantCfg,
    /// CAT block size `k` (recipes clamp to the group dim themselves).
    pub cat_block: usize,
    /// Per-group seed (already block-tweaked by the pipeline).
    pub seed: u64,
}

impl RecipeCtx<'_> {
    /// Input dimensionality of the group.
    pub fn dim(&self) -> usize {
        self.sigma_x.rows()
    }
}

/// A named transform builder. Implementations must be `Send + Sync`:
/// the pipeline fans group builds out across the worker pool.
pub trait TransformRecipe: Send + Sync {
    /// Registry name (what a plan's `.transform(name)` refers to).
    fn name(&self) -> &str;
    /// Fit a transform for one layer group.
    fn fit(&self, ctx: &RecipeCtx) -> Transform;
}

/// Shared handle to a registered recipe.
pub type RecipeRef = Arc<dyn TransformRecipe>;

/// A recipe defined by a closure — the shorthand external code and tests
/// use to register custom transforms.
struct FnRecipe<F: Fn(&RecipeCtx) -> Transform + Send + Sync> {
    name: String,
    f: F,
}

impl<F: Fn(&RecipeCtx) -> Transform + Send + Sync> TransformRecipe for FnRecipe<F> {
    fn name(&self) -> &str {
        &self.name
    }

    fn fit(&self, ctx: &RecipeCtx) -> Transform {
        (self.f)(ctx)
    }
}

fn registry() -> &'static RwLock<HashMap<String, RecipeRef>> {
    static REGISTRY: OnceLock<RwLock<HashMap<String, RecipeRef>>> = OnceLock::new();
    REGISTRY.get_or_init(|| RwLock::new(builtin_recipes()))
}

/// Register (or replace) a recipe under its own name.
pub fn register_recipe(recipe: RecipeRef) {
    let name = recipe.name().to_string();
    registry().write().unwrap().insert(name, recipe);
}

/// Register a closure as a recipe under `name`.
pub fn register_fn_recipe(
    name: impl Into<String>,
    f: impl Fn(&RecipeCtx) -> Transform + Send + Sync + 'static,
) {
    register_recipe(Arc::new(FnRecipe { name: name.into(), f }));
}

/// Look up a recipe by name.
pub fn recipe(name: &str) -> Option<RecipeRef> {
    registry().read().unwrap().get(name).cloned()
}

/// Whether `name` is registered (what plan validation checks).
pub fn has_recipe(name: &str) -> bool {
    registry().read().unwrap().contains_key(name)
}

/// All registered recipe names, sorted.
pub fn recipe_names() -> Vec<String> {
    let mut names: Vec<String> = registry().read().unwrap().keys().cloned().collect();
    names.sort();
    names
}

/// The built-in zoo, registered on first registry access. Names are the
/// single source of truth for transform labels — `TransformKind::name`
/// maps the closed enum onto them.
fn builtin_recipes() -> HashMap<String, RecipeRef> {
    let builtins: Vec<RecipeRef> = vec![
        Arc::new(FnRecipe {
            name: "identity".into(),
            f: |ctx: &RecipeCtx| Transform::identity(ctx.dim()),
        }),
        Arc::new(FnRecipe {
            name: "smoothquant".into(),
            f: |ctx: &RecipeCtx| super::smooth_quant_scale(ctx.x_sample, ctx.ws, 0.5),
        }),
        Arc::new(FnRecipe {
            name: "quarot".into(),
            // One fixed randomized Hadamard (seeded but unsearched).
            f: |ctx: &RecipeCtx| {
                let d = ctx.dim();
                let mut rng = crate::linalg::Rng::new(ctx.seed ^ 0x9A407);
                if crate::linalg::is_pow2(d) {
                    Transform::orthogonal(
                        "quarot",
                        crate::linalg::randomized_hadamard(d, &mut rng),
                    )
                } else {
                    Transform::orthogonal("quarot", crate::linalg::random_orthogonal(d, &mut rng))
                }
            },
        }),
        Arc::new(FnRecipe {
            name: "spinquant".into(),
            f: |ctx: &RecipeCtx| {
                super::seed_search_rotation(ctx.x_sample, ctx.ws, ctx.act, ctx.wq, 8, ctx.seed)
            },
        }),
        Arc::new(FnRecipe {
            name: "cat-block".into(),
            f: |ctx: &RecipeCtx| {
                super::cat_block(ctx.sigma_x, ctx.sigma_w, ctx.cat_block.min(ctx.dim()), ctx.seed)
            },
        }),
        // Same fit as cat-block; the *trained* part (learnable activation
        // clipping) is a plan-level post-pass in the pipeline, not a
        // property of the transform itself.
        Arc::new(FnRecipe {
            name: "cat-block-trained".into(),
            f: |ctx: &RecipeCtx| {
                super::cat_block(ctx.sigma_x, ctx.sigma_w, ctx.cat_block.min(ctx.dim()), ctx.seed)
            },
        }),
        Arc::new(FnRecipe {
            name: "kronecker".into(),
            f: |ctx: &RecipeCtx| super::kronecker_cat(ctx.sigma_x, ctx.sigma_w, ctx.seed),
        }),
        Arc::new(FnRecipe {
            name: "cat-optimal".into(),
            f: |ctx: &RecipeCtx| super::cat_optimal(ctx.sigma_x, ctx.sigma_w, ctx.seed),
        }),
        Arc::new(FnRecipe {
            name: "cat-block-permuted".into(),
            f: |ctx: &RecipeCtx| {
                super::permuted_cat_block(
                    ctx.sigma_x,
                    ctx.sigma_w,
                    ctx.cat_block.min(ctx.dim()),
                    ctx.seed,
                )
            },
        }),
        Arc::new(FnRecipe {
            name: "wush-adaptive".into(),
            f: |ctx: &RecipeCtx| {
                super::wush_adaptive(
                    ctx.sigma_x,
                    ctx.sigma_w,
                    ctx.cat_block.min(ctx.dim()),
                    ctx.seed,
                )
            },
        }),
        Arc::new(FnRecipe {
            name: "fpt-merged".into(),
            f: |ctx: &RecipeCtx| super::fpt_merged(ctx.sigma_x, ctx.sigma_w),
        }),
    ];
    builtins.into_iter().map(|r| (r.name().to_string(), r)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{syrk_at_a, Rng};
    use crate::quant::QScheme;

    fn ctx_fixture(d: usize, seed: u64) -> (Mat, Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(64, d, |_, _| rng.normal());
        let w = Mat::from_fn(d, d, |_, _| rng.normal() * 0.05);
        let sigma_x = syrk_at_a(&x).scale(1.0 / 64.0);
        let sigma_w = syrk_at_a(&w);
        (x, w, sigma_x, sigma_w)
    }

    #[test]
    fn builtins_are_registered() {
        for name in [
            "identity",
            "smoothquant",
            "quarot",
            "spinquant",
            "cat-block",
            "cat-block-trained",
            "kronecker",
            "cat-optimal",
            "cat-block-permuted",
            "wush-adaptive",
            "fpt-merged",
        ] {
            assert!(has_recipe(name), "missing builtin {name}");
        }
        let names = recipe_names();
        assert!(names.len() >= 11);
        assert!(names.windows(2).all(|w| w[0] < w[1]), "sorted, deduped");
    }

    #[test]
    fn builtin_fit_matches_direct_builder() {
        let (x, w, sigma_x, sigma_w) = ctx_fixture(16, 3);
        let ws = [&w];
        let ctx = RecipeCtx {
            x_sample: &x,
            sigma_x: &sigma_x,
            ws: &ws,
            sigma_w: &sigma_w,
            act: ActQuantCfg { scheme: QScheme::asym(4), clip_ratio: 1.0 },
            wq: WeightQuantCfg::minmax(4),
            cat_block: 8,
            seed: 5,
        };
        let via_registry = recipe("cat-block").unwrap().fit(&ctx);
        let direct = super::super::cat_block(&sigma_x, &sigma_w, 8, 5);
        assert_eq!(via_registry.matrix().max_abs_diff(direct.matrix()), 0.0);
        let ident = recipe("identity").unwrap().fit(&ctx);
        assert_eq!(ident.matrix().max_abs_diff(&Mat::eye(16)), 0.0);
    }

    #[test]
    fn external_recipes_register_and_fit() {
        register_fn_recipe("test-double", |ctx: &RecipeCtx| {
            Transform::diagonal("test-double", &vec![2.0; ctx.dim()])
        });
        assert!(has_recipe("test-double"));
        let (x, w, sigma_x, sigma_w) = ctx_fixture(8, 4);
        let ws = [&w];
        let ctx = RecipeCtx {
            x_sample: &x,
            sigma_x: &sigma_x,
            ws: &ws,
            sigma_w: &sigma_w,
            act: ActQuantCfg { scheme: QScheme::asym(4), clip_ratio: 1.0 },
            wq: WeightQuantCfg::minmax(4),
            cat_block: 4,
            seed: 0,
        };
        let t = recipe("test-double").unwrap().fit(&ctx);
        assert_eq!(t.matrix()[(0, 0)], 2.0);
        assert!(t.inversion_error() < 1e-12);
    }
}
