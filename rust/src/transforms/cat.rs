//! Concentration-Alignment Transforms (paper §4).
//!
//! * [`cat_m_hat`] — the alignment-optimal full-rank transform
//!   `M̂ = (Σ_w # Σ_x⁻¹)^{1/2}` (eq. 7), `#` the matrix geometric mean.
//! * [`cat_optimal`] — `T̂ = H·M̂`: compose with a Hadamard for
//!   concentration (step 2 of the paper's recipe; alignment is
//!   rotation-invariant so H is free).
//! * [`cat_block`] — the practical **CAT (block)**: block-diagonal M̂ with
//!   per-block geometric means (eq. 10), default `k = 128`.

use super::Transform;
use crate::linalg::{
    geometric_mean, hadamard_matrix, is_pow2, random_orthogonal, spd_inv, spd_sqrt, Mat, Rng,
};

/// The alignment-optimal transform `M̂ = (Σ_w # Σ_x⁻¹)^{1/2}` (eq. 7).
///
/// `sigma_x = E[xxᵀ]` from calibration; `sigma_w = Σ WᵀW` summed over the
/// weight matrices sharing this input. Both get a small relative ridge so
/// ill-conditioned calibration estimates stay invertible.
pub fn cat_m_hat(sigma_x: &Mat, sigma_w: &Mat) -> Mat {
    let d = sigma_x.rows();
    assert_eq!(sigma_w.rows(), d, "Σ_w / Σ_x dim mismatch");
    let mut sx = sigma_x.clone();
    let mut sw = sigma_w.clone();
    ridge(&mut sx);
    ridge(&mut sw);
    let g = geometric_mean(&sw, &spd_inv(&sx));
    spd_sqrt(&g)
}

fn ridge(s: &mut Mat) {
    let d = s.rows();
    let mean_diag = (0..d).map(|i| s[(i, i)]).sum::<f64>() / d as f64;
    s.add_diag(1e-6 * mean_diag.max(1e-12));
    s.symmetrize();
}

/// Full CAT: `T̂ = H·M̂` (alignment-optimal, concentration via Hadamard).
/// Falls back to a Haar rotation when `d` is not a power of two.
pub fn cat_optimal(sigma_x: &Mat, sigma_w: &Mat, seed: u64) -> Transform {
    let d = sigma_x.rows();
    let m = cat_m_hat(sigma_x, sigma_w);
    let m_t = Transform::spd("cat-M̂", m);
    m_t.then(&concentration_rotation(d, seed))
}

/// Block-diagonal M̂ (no Hadamard): `Diag(M̂_1 … M̂_{d/k})`, each block the
/// geometric-mean optimum on its own coordinates (paper eq. 10's
/// `M̂ᵏ_block`). Exposed separately for the Figure 5 ablation.
pub fn cat_block_raw(sigma_x: &Mat, sigma_w: &Mat, k: usize) -> Transform {
    let d = sigma_x.rows();
    assert!(k >= 1 && k <= d);
    let mut m = Mat::zeros(d, d);
    let mut m_inv = Mat::zeros(d, d);
    let mut start = 0;
    while start < d {
        let kb = k.min(d - start);
        let sx_b = sigma_x.block(start, start, kb, kb);
        let sw_b = sigma_w.block(start, start, kb, kb);
        let mb = cat_m_hat(&sx_b, &sw_b);
        m.set_block(start, start, &mb);
        m_inv.set_block(start, start, &spd_inv(&mb));
        start += kb;
    }
    Transform::new(format!("cat-block(k={k})"), m, m_inv)
}

/// **CAT (block)** — the paper's practical method (eq. 10):
/// `T̂ᵏ_block = H · M̂ᵏ_block`, default `k = 128`.
pub fn cat_block(sigma_x: &Mat, sigma_w: &Mat, k: usize, seed: u64) -> Transform {
    let d = sigma_x.rows();
    cat_block_raw(sigma_x, sigma_w, k).then(&concentration_rotation(d, seed))
}

/// The concentration rotation H (Hadamard when possible, Haar otherwise).
fn concentration_rotation(d: usize, seed: u64) -> Transform {
    if is_pow2(d) {
        Transform::orthogonal("H", hadamard_matrix(d))
    } else {
        let mut rng = Rng::new(seed ^ 0x48414441);
        Transform::orthogonal("R", random_orthogonal(d, &mut rng))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, syrk_at_a, Rng};
    use crate::quant::{ActQuantCfg, QScheme, WeightQuantCfg};
    use crate::sqnr::{
        alignment_data, approx_sqnr_joint, concentration_act, max_alignment,
    };

    /// Anisotropic, correlated activations + weights with mismatched
    /// principal directions — the regime where alignment is poor.
    fn hard_layer(d: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let tokens = 40 * d;
        // Correlated x: x = z · Aᵀ with random A and spread spectrum.
        let a = Mat::from_fn(d, d, |i, j| {
            rng.normal() * (6.0_f64).powf(-(((i + j) % d) as f64) / d as f64)
        });
        let z = Mat::from_fn(tokens, d, |_, _| rng.normal());
        let x = matmul(&z, &a.transpose());
        let w = Mat::from_fn(d / 2, d, |i, j| {
            rng.normal() * (5.0_f64).powf(((i + 2 * j) % d) as f64 / d as f64) * 0.01
        });
        (x, w)
    }

    fn stats(x: &Mat, w: &Mat) -> (Mat, Mat) {
        let sigma_x = syrk_at_a(x).scale(1.0 / x.rows() as f64);
        let sigma_w = syrk_at_a(w);
        (sigma_x, sigma_w)
    }

    #[test]
    fn m_hat_achieves_max_alignment() {
        // The heart of the paper: M̂ attains the eq. 9 optimum.
        let (x, w) = hard_layer(16, 1);
        let (sigma_x, sigma_w) = stats(&x, &w);
        let m = cat_m_hat(&sigma_x, &sigma_w);
        let t = Transform::spd("m̂", m);
        let a_after = alignment_data(&t.apply_acts(&x), &t.fuse_weights(&w));
        let a_max = max_alignment(&sigma_x, &w);
        assert!(
            (a_after - a_max).abs() / a_max < 0.02,
            "M̂ alignment {a_after} vs optimum {a_max}"
        );
    }

    #[test]
    fn m_hat_satisfies_eq_8_fixed_point() {
        // M̂ Σ_x M̂ = M̂⁻¹ Σ_w M̂⁻¹: both sides map to the same matrix.
        let (x, w) = hard_layer(12, 2);
        let (sigma_x, sigma_w) = stats(&x, &w);
        let m = cat_m_hat(&sigma_x, &sigma_w);
        let mi = spd_inv(&m);
        let lhs = matmul(&matmul(&m, &sigma_x), &m);
        let rhs = matmul(&matmul(&mi, &sigma_w), &mi);
        let rel = lhs.max_abs_diff(&rhs) / lhs.max_abs().max(1e-12);
        // Tolerance: the builder applies a 1e-6 relative ridge to both
        // statistics before the geometric mean.
        assert!(rel < 2e-3, "eq. 8 violated, rel err {rel}");
    }

    #[test]
    fn hadamard_composition_preserves_alignment() {
        // Step 2 of the CAT recipe is free for alignment.
        let (x, w) = hard_layer(16, 3);
        let (sigma_x, sigma_w) = stats(&x, &w);
        let m = Transform::spd("m̂", cat_m_hat(&sigma_x, &sigma_w));
        let full = cat_optimal(&sigma_x, &sigma_w, 0);
        let a_m = alignment_data(&m.apply_acts(&x), &m.fuse_weights(&w));
        let a_full = alignment_data(&full.apply_acts(&x), &full.fuse_weights(&w));
        assert!((a_m - a_full).abs() < 1e-9);
    }

    #[test]
    fn block_cat_interpolates_alignment() {
        // k=1 ≤ k=4 ≤ k=d alignment (larger blocks, closer to optimal).
        let d = 16;
        let (x, w) = hard_layer(d, 4);
        let (sigma_x, sigma_w) = stats(&x, &w);
        let a_of = |t: &Transform| alignment_data(&t.apply_acts(&x), &t.fuse_weights(&w));
        let a0 = alignment_data(&x, &w);
        let a1 = a_of(&cat_block_raw(&sigma_x, &sigma_w, 1));
        let a4 = a_of(&cat_block_raw(&sigma_x, &sigma_w, 4));
        let ad = a_of(&cat_block_raw(&sigma_x, &sigma_w, d));
        let amax = max_alignment(&sigma_x, &w);
        assert!(a1 >= a0 * 0.8, "k=1 should not destroy alignment: {a0} -> {a1}");
        assert!(ad >= a4 * 0.99 && a4 >= a1 * 0.9, "monotone-ish: {a1} {a4} {ad}");
        assert!((ad - amax).abs() / amax < 0.02, "full block = optimal");
    }

    #[test]
    fn cat_block_improves_joint_sqnr_over_hadamard() {
        // Figure 6's claim, on the hard synthetic layer.
        let d = 32;
        let (x, w) = hard_layer(d, 5);
        let (sigma_x, sigma_w) = stats(&x, &w);
        let act = ActQuantCfg { scheme: QScheme::asym(4), clip_ratio: 1.0 };
        let wq = WeightQuantCfg::minmax(4);
        let h = Transform::orthogonal("H", hadamard_matrix(d));
        let cat = cat_block(&sigma_x, &sigma_w, 8, 0);
        let s_h = approx_sqnr_joint(&h.apply_acts(&x), &h.fuse_weights(&w), act, wq);
        let s_cat = approx_sqnr_joint(&cat.apply_acts(&x), &cat.fuse_weights(&w), act, wq);
        assert!(
            s_cat > s_h,
            "CAT ({:.1} dB) should beat Hadamard ({:.1} dB)",
            10.0 * s_cat.log10(),
            10.0 * s_h.log10()
        );
    }

    #[test]
    fn cat_keeps_concentration_near_hadamard() {
        // Figure 4: CAT's Hadamard factor keeps channels near Gaussian.
        let d = 32;
        let (x, w) = hard_layer(d, 6);
        let (sigma_x, sigma_w) = stats(&x, &w);
        let act = ActQuantCfg { scheme: QScheme::asym(4), clip_ratio: 1.0 };
        let h = Transform::orthogonal("H", hadamard_matrix(d));
        let cat = cat_block(&sigma_x, &sigma_w, 8, 0);
        let c_h = concentration_act(&h.apply_acts(&x), act);
        let c_cat = concentration_act(&cat.apply_acts(&x), act);
        assert!(
            c_cat > c_h * 0.4,
            "CAT concentration {c_cat} far below Hadamard {c_h}"
        );
    }

    #[test]
    fn function_preserved_through_cat() {
        let d = 16;
        let (x, w) = hard_layer(d, 7);
        let (sigma_x, sigma_w) = stats(&x, &w);
        let t = cat_block(&sigma_x, &sigma_w, 4, 0);
        let y = crate::linalg::matmul_a_bt(&x, &w);
        let y2 = crate::linalg::matmul_a_bt(&t.apply_acts(&x), &t.fuse_weights(&w));
        let rel = y.max_abs_diff(&y2) / y.max_abs().max(1e-12);
        assert!(rel < 1e-6, "function not preserved, rel {rel}");
    }

    #[test]
    fn k1_matches_diag_align_scale() {
        let (x, w) = hard_layer(8, 8);
        let (sigma_x, sigma_w) = stats(&x, &w);
        let b1 = cat_block_raw(&sigma_x, &sigma_w, 1);
        let ds = super::super::diag_align_scale(&sigma_x, &sigma_w);
        let rel = b1.matrix().max_abs_diff(ds.matrix()) / ds.matrix().max_abs();
        assert!(rel < 1e-3, "k=1 block CAT should equal the diagonal optimum, rel {rel}");
    }
}
