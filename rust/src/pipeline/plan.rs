//! Quantization *plans*: the builder API the pipeline consumes.
//!
//! The paper treats the transform, the quantizer, and the bit-width as
//! independent axes of one SQNR objective; a [`QuantPlan`] exposes
//! exactly those axes, resolved **per layer group**: a base
//! configuration (`.transform(..)`, `.quantizer(..)`, `.bits(w, a)`,
//! `.weights(..)`, `.acts(..)`, `.cat_block(..)`) plus per-group
//! overrides (`.for_group(group, |g| ..)`), so mixed-precision runs
//! (attention W8A8 / MLP W4A4) and per-group transform choices are
//! first-class. Transforms are addressed by *registry name*
//! ([`crate::transforms::recipe`]), so externally registered recipes
//! plug in without touching the crate.
//!
//! [`QuantPlan::resolve`] validates the plan up front — bad bit-widths,
//! a zero CAT block, or an unregistered recipe produce a [`PlanError`]
//! naming the offending group instead of a panic mid-fan-out.
//!
//! [`PipelineCfg`] survives as a thin **deprecated** shim that lowers
//! into a uniform plan ([`PipelineCfg::plan`]) so the Table 1 / figure
//! experiment grids are unchanged.

use crate::model::{LayerGroup, ALL_GROUPS};
use crate::quant::{ActQuantCfg, QScheme, RangeEstimator, WeightQuantCfg};
use crate::transforms::{self, TransformKind};
use std::collections::HashMap;
use std::fmt;

/// Which weight quantization algorithm packs a group's weights
/// (Table 1's two blocks).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightQuantizer {
    Rtn,
    Gptq,
}

impl WeightQuantizer {
    /// Canonical name — the single string table, shared by tables, plan
    /// echoes, and the CLI.
    pub fn name(&self) -> &'static str {
        match self {
            WeightQuantizer::Rtn => "rtn",
            WeightQuantizer::Gptq => "gptq",
        }
    }

    /// Inverse of [`Self::name`].
    pub fn from_name(name: &str) -> Option<WeightQuantizer> {
        [WeightQuantizer::Rtn, WeightQuantizer::Gptq]
            .into_iter()
            .find(|q| q.name() == name)
    }
}

impl fmt::Display for WeightQuantizer {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Fully-resolved quantization settings for one layer group.
#[derive(Clone, Debug)]
pub struct GroupPlan {
    /// Transform recipe registry name.
    pub recipe: String,
    /// Weight quantization algorithm.
    pub quantizer: WeightQuantizer,
    /// Weight scheme + range estimation.
    pub weights: WeightQuantCfg,
    /// Activation scheme + clip.
    pub acts: ActQuantCfg,
    /// CAT block size `k` (clamped to the group dim by the recipes).
    pub cat_block: usize,
}

impl Default for GroupPlan {
    /// The paper's §6 setup at W4A4 with no transform: symmetric
    /// per-channel `L_{2.4}` weights, dynamic asymmetric per-token acts.
    fn default() -> GroupPlan {
        GroupPlan {
            recipe: "identity".into(),
            quantizer: WeightQuantizer::Rtn,
            weights: WeightQuantCfg {
                scheme: QScheme::sym(4),
                range: RangeEstimator::LpNorm { p: 2.4 },
            },
            acts: ActQuantCfg { scheme: QScheme::asym(4), clip_ratio: 1.0 },
            cat_block: 128,
        }
    }
}

impl GroupPlan {
    /// One-line human summary (plan echoes, artifact manifests).
    pub fn summary(&self) -> String {
        format!(
            "{} {} W{}A{} cat_block={} clip={}",
            self.recipe,
            self.quantizer,
            self.weights.scheme.bits,
            self.acts.scheme.bits,
            self.cat_block,
            self.acts.clip_ratio
        )
    }
}

/// Partial per-group settings collected by [`QuantPlan::for_group`] and
/// applied over the base at resolve time.
#[derive(Clone, Debug, Default)]
struct GroupOverride {
    recipe: Option<String>,
    quantizer: Option<WeightQuantizer>,
    weights: Option<WeightQuantCfg>,
    acts: Option<ActQuantCfg>,
    bits: Option<(u32, u32)>,
    cat_block: Option<usize>,
}

/// Scoped builder handed to [`QuantPlan::for_group`] closures — the same
/// knobs as the plan-level setters, recorded as a partial override.
#[derive(Debug, Default)]
pub struct GroupCfg {
    ov: GroupOverride,
}

impl GroupCfg {
    /// Use transform recipe `name` for this group.
    pub fn transform(mut self, name: impl Into<String>) -> GroupCfg {
        self.ov.recipe = Some(name.into());
        self
    }

    /// Weight quantization algorithm for this group.
    pub fn quantizer(mut self, q: WeightQuantizer) -> GroupCfg {
        self.ov.quantizer = Some(q);
        self
    }

    /// Full weight quantization config for this group.
    pub fn weights(mut self, w: WeightQuantCfg) -> GroupCfg {
        self.ov.weights = Some(w);
        self
    }

    /// Full activation quantization config for this group.
    pub fn acts(mut self, a: ActQuantCfg) -> GroupCfg {
        self.ov.acts = Some(a);
        self
    }

    /// Weight/activation bit-widths for this group (keeps each scheme's
    /// symmetry and the weight range estimator; applied after any
    /// `weights`/`acts` override).
    pub fn bits(mut self, w: u32, a: u32) -> GroupCfg {
        self.ov.bits = Some((w, a));
        self
    }

    /// CAT block size for this group.
    pub fn cat_block(mut self, k: usize) -> GroupCfg {
        self.ov.cat_block = Some(k);
        self
    }
}

/// What a plan failed validation on, naming the offending group.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PlanError {
    /// A bit-width of 0 or above 16.
    BadBits { group: LayerGroup, which: &'static str, bits: u32 },
    /// KV-cache bit-width of 0 or above 16.
    BadKvBits { bits: u32 },
    /// `cat_block` of 0.
    BadCatBlock { group: LayerGroup },
    /// A recipe name missing from the transform registry.
    UnknownRecipe { group: LayerGroup, name: String },
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanError::BadBits { group, which, bits } => write!(
                f,
                "group {}: {which} = {bits} out of range (want 1..=16)",
                group.key()
            ),
            PlanError::BadKvBits { bits } => {
                write!(f, "kv_acts bits = {bits} out of range (want 1..=16)")
            }
            PlanError::BadCatBlock { group } => {
                write!(f, "group {}: cat_block must be >= 1", group.key())
            }
            PlanError::UnknownRecipe { group, name } => write!(
                f,
                "group {}: transform recipe {name:?} is not registered (known: {})",
                group.key(),
                transforms::recipe_names().join(", ")
            ),
        }
    }
}

impl std::error::Error for PlanError {}

/// A validated plan: one concrete [`GroupPlan`] per layer group, the KV
/// grid, and the run seed. Produced by [`QuantPlan::resolve`]; consumed
/// by [`crate::pipeline::build_quant_config`].
#[derive(Clone, Debug)]
pub struct ResolvedPlan {
    pub groups: HashMap<LayerGroup, GroupPlan>,
    pub kv_act: ActQuantCfg,
    /// Whether `kv_act` was pinned explicitly (vs defaulted to the base
    /// activation config — the uniform-plan shape, which also inherits
    /// the trained clip).
    pub kv_explicit: bool,
    pub seed: u64,
}

impl ResolvedPlan {
    pub fn group(&self, g: LayerGroup) -> &GroupPlan {
        &self.groups[&g]
    }

    /// Per-group plan echo (`(group key, summary)` pairs in `ALL_GROUPS`
    /// order, plus the seed) — what the artifact manifest records.
    pub fn summary(&self) -> Vec<(String, String)> {
        let mut out: Vec<(String, String)> = ALL_GROUPS
            .into_iter()
            .map(|g| (g.key().to_string(), self.groups[&g].summary()))
            .collect();
        out.push((
            "kv".into(),
            format!(
                "A{} sym={} clip={}",
                self.kv_act.scheme.bits, self.kv_act.scheme.symmetric, self.kv_act.clip_ratio
            ),
        ));
        out.push(("seed".into(), self.seed.to_string()));
        out
    }
}

/// Builder for a quantization run: base settings plus per-group
/// overrides. See the module docs for the shape; `resolve()` (called by
/// the pipeline) validates and produces a [`ResolvedPlan`].
#[derive(Clone, Debug, Default)]
pub struct QuantPlan {
    base: GroupPlan,
    overrides: HashMap<LayerGroup, GroupOverride>,
    kv_acts: Option<ActQuantCfg>,
    seed: u64,
}

impl QuantPlan {
    /// A uniform W4A4 plan with no transform (see [`GroupPlan::default`]).
    pub fn new() -> QuantPlan {
        QuantPlan::default()
    }

    /// Base transform recipe (registry name).
    pub fn transform(mut self, name: impl Into<String>) -> QuantPlan {
        self.base.recipe = name.into();
        self
    }

    /// Base weight quantization algorithm.
    pub fn quantizer(mut self, q: WeightQuantizer) -> QuantPlan {
        self.base.quantizer = q;
        self
    }

    /// Base weight quantization config.
    pub fn weights(mut self, w: WeightQuantCfg) -> QuantPlan {
        self.base.weights = w;
        self
    }

    /// Base activation quantization config.
    pub fn acts(mut self, a: ActQuantCfg) -> QuantPlan {
        self.base.acts = a;
        self
    }

    /// Base weight/activation bit-widths (keeps each scheme's symmetry
    /// and the weight range estimator).
    pub fn bits(mut self, w: u32, a: u32) -> QuantPlan {
        self.base.weights.scheme.bits = w;
        self.base.acts.scheme.bits = a;
        self
    }

    /// Base CAT block size.
    pub fn cat_block(mut self, k: usize) -> QuantPlan {
        self.base.cat_block = k;
        self
    }

    /// Run seed: calibration subsampling and rotation draws — the
    /// replication axis of Table 1's ±std.
    pub fn seed(mut self, seed: u64) -> QuantPlan {
        self.seed = seed;
        self
    }

    /// Pin the KV-cache grid explicitly (defaults to the base activation
    /// config, which is the historical uniform behavior).
    pub fn kv_acts(mut self, a: ActQuantCfg) -> QuantPlan {
        self.kv_acts = Some(a);
        self
    }

    /// Override settings for one layer group. Overrides are partial —
    /// unset knobs fall through to the base at resolve time — and
    /// successive calls for the same group merge.
    ///
    /// ```ignore
    /// let plan = QuantPlan::new()
    ///     .transform("cat-block")
    ///     .bits(4, 4)
    ///     .for_group(LayerGroup::AttnIn, |g| g.bits(8, 8))
    ///     .for_group(LayerGroup::OIn, |g| g.bits(8, 8));
    /// ```
    pub fn for_group(
        mut self,
        group: LayerGroup,
        f: impl FnOnce(GroupCfg) -> GroupCfg,
    ) -> QuantPlan {
        let current = self.overrides.remove(&group).unwrap_or_default();
        let out = f(GroupCfg { ov: current });
        self.overrides.insert(group, out.ov);
        self
    }

    /// Validate and resolve into one concrete [`GroupPlan`] per group.
    pub fn resolve(&self) -> Result<ResolvedPlan, PlanError> {
        let mut groups = HashMap::new();
        for g in ALL_GROUPS {
            let mut gp = self.base.clone();
            if let Some(ov) = self.overrides.get(&g) {
                if let Some(w) = ov.weights {
                    gp.weights = w;
                }
                if let Some(a) = ov.acts {
                    gp.acts = a;
                }
                if let Some((bw, ba)) = ov.bits {
                    gp.weights.scheme.bits = bw;
                    gp.acts.scheme.bits = ba;
                }
                if let Some(q) = ov.quantizer {
                    gp.quantizer = q;
                }
                if let Some(k) = ov.cat_block {
                    gp.cat_block = k;
                }
                if let Some(r) = &ov.recipe {
                    gp.recipe = r.clone();
                }
            }
            validate_group(g, &gp)?;
            groups.insert(g, gp);
        }
        let kv_act = self.kv_acts.unwrap_or(self.base.acts);
        if !(1..=16).contains(&kv_act.scheme.bits) {
            return Err(PlanError::BadKvBits { bits: kv_act.scheme.bits });
        }
        Ok(ResolvedPlan {
            groups,
            kv_act,
            kv_explicit: self.kv_acts.is_some(),
            seed: self.seed,
        })
    }
}

fn validate_group(group: LayerGroup, gp: &GroupPlan) -> Result<(), PlanError> {
    for (which, bits) in
        [("bits_w", gp.weights.scheme.bits), ("bits_a", gp.acts.scheme.bits)]
    {
        if !(1..=16).contains(&bits) {
            return Err(PlanError::BadBits { group, which, bits });
        }
    }
    if gp.cat_block == 0 {
        return Err(PlanError::BadCatBlock { group });
    }
    if !transforms::has_recipe(&gp.recipe) {
        return Err(PlanError::UnknownRecipe { group, name: gp.recipe.clone() });
    }
    Ok(())
}

/// **Deprecated** flat configuration — one transform, one quantizer, one
/// global bit-width. Kept so the Table 1 / figure experiment grids read
/// unchanged; [`Self::plan`] lowers it into the uniform [`QuantPlan`] it
/// always was. New code should build a `QuantPlan` directly.
#[derive(Clone, Copy, Debug)]
pub struct PipelineCfg {
    pub kind: TransformKind,
    pub weight_quantizer: WeightQuantizer,
    pub bits_w: u32,
    pub bits_a: u32,
    /// CAT block size `k` (clamped to the group dim).
    pub cat_block: usize,
    /// Seed: controls calibration subsampling and rotation draws — the
    /// replication axis of Table 1's ±std.
    pub seed: u64,
}

impl PipelineCfg {
    pub fn w4a4(kind: TransformKind, wq: WeightQuantizer, seed: u64) -> PipelineCfg {
        PipelineCfg {
            kind,
            weight_quantizer: wq,
            bits_w: 4,
            bits_a: 4,
            cat_block: 128,
            seed,
        }
    }

    /// Lower into the equivalent uniform [`QuantPlan`].
    pub fn plan(&self) -> QuantPlan {
        QuantPlan::new()
            .transform(self.kind.name())
            .quantizer(self.weight_quantizer)
            .bits(self.bits_w, self.bits_a)
            .cat_block(self.cat_block)
            .seed(self.seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_plan_resolves_to_identical_groups() {
        let plan = QuantPlan::new()
            .transform("cat-block")
            .quantizer(WeightQuantizer::Gptq)
            .bits(4, 8)
            .cat_block(32)
            .seed(7);
        let r = plan.resolve().unwrap();
        assert_eq!(r.seed, 7);
        assert!(!r.kv_explicit);
        assert_eq!(r.kv_act.scheme.bits, 8);
        for g in ALL_GROUPS {
            let gp = r.group(g);
            assert_eq!(gp.recipe, "cat-block");
            assert_eq!(gp.quantizer, WeightQuantizer::Gptq);
            assert_eq!(gp.weights.scheme.bits, 4);
            assert!(gp.weights.scheme.symmetric);
            assert_eq!(gp.acts.scheme.bits, 8);
            assert!(!gp.acts.scheme.symmetric);
            assert_eq!(gp.cat_block, 32);
        }
    }

    #[test]
    fn for_group_overrides_are_partial_and_merge() {
        let plan = QuantPlan::new()
            .transform("cat-block")
            .bits(4, 4)
            .for_group(LayerGroup::AttnIn, |g| g.bits(8, 8))
            .for_group(LayerGroup::AttnIn, |g| g.transform("identity"))
            .for_group(LayerGroup::DownIn, |g| g.cat_block(16));
        let r = plan.resolve().unwrap();
        // Two for_group calls on AttnIn merged: both the bits and the
        // recipe override survive.
        let attn = r.group(LayerGroup::AttnIn);
        assert_eq!(attn.weights.scheme.bits, 8);
        assert_eq!(attn.recipe, "identity");
        // Unset knobs fall through to the base.
        assert_eq!(attn.quantizer, WeightQuantizer::Rtn);
        assert_eq!(attn.cat_block, 128);
        let down = r.group(LayerGroup::DownIn);
        assert_eq!(down.cat_block, 16);
        assert_eq!(down.recipe, "cat-block");
        assert_eq!(down.weights.scheme.bits, 4);
        // Untouched group is pure base.
        assert_eq!(r.group(LayerGroup::MlpIn).weights.scheme.bits, 4);
    }

    #[test]
    fn validation_rejects_bad_bits_naming_the_group() {
        let err = QuantPlan::new().bits(0, 4).resolve().unwrap_err();
        assert!(matches!(err, PlanError::BadBits { which: "bits_w", bits: 0, .. }), "{err}");
        let err = QuantPlan::new()
            .for_group(LayerGroup::MlpIn, |g| g.bits(4, 20))
            .resolve()
            .unwrap_err();
        match &err {
            PlanError::BadBits { group, which, bits } => {
                assert_eq!(*group, LayerGroup::MlpIn);
                assert_eq!(*which, "bits_a");
                assert_eq!(*bits, 20);
            }
            other => panic!("wrong error {other:?}"),
        }
        assert!(err.to_string().contains("mlp_in"), "{err}");
    }

    #[test]
    fn validation_rejects_zero_cat_block_and_unknown_recipe() {
        let err = QuantPlan::new().cat_block(0).resolve().unwrap_err();
        assert!(matches!(err, PlanError::BadCatBlock { .. }), "{err}");
        let err = QuantPlan::new().transform("no-such-recipe").resolve().unwrap_err();
        match &err {
            PlanError::UnknownRecipe { name, .. } => assert_eq!(name, "no-such-recipe"),
            other => panic!("wrong error {other:?}"),
        }
        assert!(err.to_string().contains("no-such-recipe"), "{err}");
        let err = QuantPlan::new()
            .kv_acts(ActQuantCfg { scheme: QScheme::asym(17), clip_ratio: 1.0 })
            .resolve()
            .unwrap_err();
        assert!(matches!(err, PlanError::BadKvBits { bits: 17 }), "{err}");
    }

    #[test]
    fn pipeline_cfg_lowers_to_the_same_uniform_plan() {
        let cfg = PipelineCfg::w4a4(TransformKind::CatBlock, WeightQuantizer::Gptq, 3);
        let r = cfg.plan().resolve().unwrap();
        assert_eq!(r.seed, 3);
        for g in ALL_GROUPS {
            let gp = r.group(g);
            assert_eq!(gp.recipe, "cat-block");
            assert_eq!(gp.quantizer, WeightQuantizer::Gptq);
            assert_eq!(gp.weights.scheme.bits, 4);
            assert_eq!(gp.acts.scheme.bits, 4);
            assert_eq!(gp.acts.clip_ratio, 1.0);
            assert_eq!(gp.cat_block, 128);
            assert!(matches!(gp.weights.range, RangeEstimator::LpNorm { .. }));
        }
    }

    #[test]
    fn summary_covers_all_groups() {
        let r = QuantPlan::new().resolve().unwrap();
        let s = r.summary();
        assert_eq!(s.len(), ALL_GROUPS.len() + 2);
        for (g, (key, line)) in ALL_GROUPS.into_iter().zip(&s) {
            assert_eq!(key, g.key());
            assert!(line.contains("identity"), "{line}");
        }
    }
}
