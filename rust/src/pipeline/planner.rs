//! SQNR-driven quantization planner: search over `(group × bit-width ×
//! recipe)` cells under a byte or latency budget.
//!
//! The paper's Theorem 2.4 decomposes a layer's SQNR into concentration
//! and alignment terms that depend only on calibration statistics — no
//! forward passes, no quantized eval. That makes it cheap enough to
//! *score every candidate cell* of a search space the rest of the repo
//! already exposes:
//!
//! * the **recipe axis** — every name in the open transform registry
//!   ([`crate::transforms::recipe`]), including externally registered
//!   recipes, which participate in search automatically;
//! * the **bit axis** — a candidate weight bit grid, with activation
//!   bits riding along as `max(w_bits, min_act_bits)`;
//! * the **group axis** — the four layer groups a
//!   [`QuantPlan`](super::QuantPlan) resolves independently.
//!
//! Scoring reuses the group covariance from calibration (the same
//! [`sum_gram`](super::build) + [`CalibStats`] pair the build consumes)
//! and the shared [`SqnrTerms`] assembly from `sqnr/measures.rs`, so the
//! planner's numbers are bit-identical to what
//! [`build_quant_config`](super::build_quant_config) reports for the
//! winning plan. One transform fit per `(block, group, recipe)` is the
//! expensive axis; alignment is computed once per linear and the bit
//! grid reuses it.
//!
//! Allocation solves "maximize Σ per-group utility s.t. Σ bytes ≤
//! budget". Per group, cells collapse to a byte **frontier** (best
//! utility per distinct byte cost — the packed nibble/byte/wide storage
//! gives ≤ 3 byte tiers per group), so exact enumeration over 4 groups
//! is ≤ `tiers⁴` combos — [`Solver::Exact`], the default, is optimal and
//! budget-monotone by construction. [`Solver::Greedy`] (marginal utility
//! per byte) is kept as the scalable fallback and is property-tested to
//! never beat the exact optimum.
//!
//! The winner is emitted as a plain [`QuantPlan`], so searched plans
//! flow through the existing `build_quant_config` → `save_artifact`
//! path and serve with **zero new serving code**; search provenance is
//! appended to the [`PipelineReport`] plan echo and lands in the
//! artifact manifest.

use super::build::{build_quant_config, sum_gram, PipelineReport};
use super::plan::{PlanError, QuantPlan, WeightQuantizer};
use crate::calib::CalibStats;
use crate::linalg::{matmul_a_bt, par, Mat};
use crate::model::{LayerGroup, LinearId, NativeModel, QuantConfig, ALL_GROUPS};
use crate::quant::{
    quantize_activations_per_token, ActQuantCfg, QScheme, QuantizedTensor, WeightQuantCfg,
};
use crate::sqnr::{alignment_data, concentration_act, concentration_weights, db, SqnrTerms};
use crate::transforms::{self, RecipeCtx};
use anyhow::{bail, Result};
use std::collections::HashMap;

/// What the planner is allowed to spend.
#[derive(Clone, Copy, Debug)]
pub enum Budget {
    /// Total packed weight bytes (codes + per-row metadata) across every
    /// quantized linear — exactly what
    /// [`QuantConfig::packed_bytes`](crate::model::QuantConfig::packed_bytes)
    /// reports for the built config.
    Size { max_bytes: usize },
    /// Decode-latency target per token. Quantized decode is
    /// weight-bandwidth bound (PERF.md §Quantized kernels), so this
    /// converts to a byte budget via [`PlannerCfg::bytes_per_us`].
    Latency { max_us_per_tok: f64 },
}

impl Budget {
    /// The byte budget this resolves to.
    pub fn to_bytes(self, bytes_per_us: f64) -> usize {
        match self {
            Budget::Size { max_bytes } => max_bytes,
            Budget::Latency { max_us_per_tok } => (max_us_per_tok * bytes_per_us) as usize,
        }
    }
}

/// What the search maximizes. Both are additive over groups.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// Σ per-group mean approx SQNR in dB (Theorem 2.4) — the paper's
    /// Table-1 metric.
    Sqnr,
    /// Minimize Σ per-group mean relative noise power `1/SQNR` — a
    /// perplexity proxy (output noise degrades logits roughly linearly,
    /// so total noise power tracks ppl better than mean dB, which can
    /// hide one catastrophic group behind three good ones).
    PplProxy,
}

impl Objective {
    /// Canonical CLI/provenance name.
    pub fn name(&self) -> &'static str {
        match self {
            Objective::Sqnr => "sqnr",
            Objective::PplProxy => "ppl-proxy",
        }
    }

    /// Inverse of [`Self::name`].
    pub fn from_name(name: &str) -> Option<Objective> {
        [Objective::Sqnr, Objective::PplProxy].into_iter().find(|o| o.name() == name)
    }
}

/// Which allocator turns scored cells into a plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    /// Exact enumeration over the per-group byte frontiers — optimal and
    /// budget-monotone; the default (4 groups × ≤3 byte tiers is tiny).
    Exact,
    /// Marginal-utility-per-byte greedy upgrades from the cheapest
    /// feasible plan — the scalable fallback, property-tested against
    /// the exact optimum.
    Greedy,
}

impl Solver {
    /// Canonical provenance name.
    pub fn name(&self) -> &'static str {
        match self {
            Solver::Exact => "exact",
            Solver::Greedy => "greedy",
        }
    }
}

/// Planner configuration: budget, objective, and the search space.
#[derive(Clone, Debug)]
pub struct PlannerCfg {
    pub budget: Budget,
    pub objective: Objective,
    pub solver: Solver,
    /// Candidate weight bit-widths (sorted + deduped at search time).
    pub weight_bits: Vec<u32>,
    /// Candidate recipe names; empty means *every registered recipe*
    /// (externally registered ones included), in sorted-name order.
    pub recipes: Vec<String>,
    /// Weight quantizer for the emitted plan (scoring is quantizer-
    /// agnostic: Theorem 2.4 bounds the rounding grid, not the rounder).
    pub quantizer: WeightQuantizer,
    /// CAT block size `k` handed to block recipes.
    pub cat_block: usize,
    /// Activation bits floor: each cell's act bits are
    /// `max(w_bits, min_act_bits)` (activations are free in the byte
    /// model — they're quantized dynamically — so never starve them
    /// below the floor).
    pub min_act_bits: u32,
    /// Plan seed (rotation draws; matches the build's per-block tweak).
    pub seed: u64,
    /// Bytes streamed per µs for [`Budget::Latency`]; default ≈ 1 GiB/s.
    pub bytes_per_us: f64,
}

impl PlannerCfg {
    pub fn new(budget: Budget) -> PlannerCfg {
        PlannerCfg {
            budget,
            objective: Objective::Sqnr,
            solver: Solver::Exact,
            weight_bits: vec![2, 3, 4, 6, 8],
            recipes: Vec::new(),
            quantizer: WeightQuantizer::Rtn,
            cat_block: 128,
            min_act_bits: 4,
            seed: 0,
            bytes_per_us: 1074.0,
        }
    }
}

/// One scored search cell: a `(recipe, bits)` choice for one group.
#[derive(Clone, Debug)]
pub struct PlanCell {
    pub recipe: String,
    pub w_bits: u32,
    pub a_bits: u32,
    /// Packed bytes this choice costs for the whole group, all blocks.
    pub bytes: usize,
    /// Mean per-linear approx SQNR in dB (Theorem 2.4).
    pub score_db: f64,
    /// Mean per-linear relative noise power `1/SQNR` (the ppl proxy).
    pub noise: f64,
}

impl PlanCell {
    fn utility(&self, obj: Objective) -> f64 {
        match obj {
            Objective::Sqnr => self.score_db,
            Objective::PplProxy => -self.noise,
        }
    }

    /// One-line summary (decision tables, artifact provenance).
    pub fn summary(&self) -> String {
        format!(
            "{} W{}A{} {:.2}dB {}B",
            self.recipe, self.w_bits, self.a_bits, self.score_db, self.bytes
        )
    }
}

/// The chosen cell for one group, in `ALL_GROUPS` order.
#[derive(Clone, Debug)]
pub struct PlanDecision {
    pub group: LayerGroup,
    pub cell: PlanCell,
}

/// A searched plan: the winning [`QuantPlan`] plus everything the search
/// knew when it chose it.
#[derive(Clone, Debug)]
pub struct PlannedQuant {
    /// The emitted plan — feed it to [`build_quant_config`] (or
    /// [`Self::build`], which also echoes provenance).
    pub plan: QuantPlan,
    /// Per-group winning cells, `ALL_GROUPS` order.
    pub decisions: Vec<PlanDecision>,
    /// Σ decision bytes — equals `QuantConfig::packed_bytes` post-build.
    pub total_bytes: usize,
    /// The resolved byte budget the search ran under.
    pub budget_bytes: usize,
    pub objective: Objective,
    /// Σ per-group utility under `objective`.
    pub utility: f64,
    /// Σ per-group mean approx dB (reported regardless of objective).
    pub score_db: f64,
    /// `planner.*` key/value pairs echoed into the artifact manifest.
    pub provenance: Vec<(String, String)>,
}

impl PlannedQuant {
    /// Build the searched plan and append the search provenance to the
    /// report's plan echo, so `save_artifact` records *why* the artifact
    /// looks the way it does.
    pub fn build(
        &self,
        model: &NativeModel,
        calib: &CalibStats,
    ) -> Result<(QuantConfig, PipelineReport)> {
        let (qc, mut rep) = build_quant_config(model, calib, &self.plan)?;
        rep.plan.extend(self.provenance.iter().cloned());
        Ok((qc, rep))
    }
}

/// Packed bytes of one group at `w_bits`, summed over every block's
/// linears — the exact [`QuantizedTensor::packed_bytes`] the built
/// config will report (codes + per-row scale/zero/sum metadata).
fn group_bytes(model: &NativeModel, g: LayerGroup, w_bits: u32) -> usize {
    let scheme = QScheme::sym(w_bits);
    let mut total = 0;
    for block in 0..model.cfg.n_layers {
        for &lin in g.linears() {
            let w = &model.params[&LinearId::new(block, lin).to_string()];
            total += QuantizedTensor::code_bytes_len(w.rows(), w.cols(), scheme)
                + w.rows() * (8 + 4 + 8);
        }
    }
    total
}

/// Byte cost of an arbitrary plan under the planner's byte model —
/// equals `QuantConfig::packed_bytes` after building it.
pub fn plan_bytes(model: &NativeModel, plan: &QuantPlan) -> Result<usize, PlanError> {
    let r = plan.resolve()?;
    Ok(ALL_GROUPS
        .iter()
        .map(|&g| group_bytes(model, g, r.group(g).weights.scheme.bits))
        .sum())
}

/// The best uniform-bits baseline under the same byte budget: the
/// largest candidate bit-width whose uniform plan fits, with `recipe` on
/// every group (the Table-1 comparison row). `None` if nothing fits.
pub fn best_uniform_plan(
    model: &NativeModel,
    cfg: &PlannerCfg,
    recipe: &str,
) -> Option<(u32, QuantPlan)> {
    let budget = cfg.budget.to_bytes(cfg.bytes_per_us);
    let mut bits = cfg.weight_bits.clone();
    bits.sort_unstable();
    bits.dedup();
    let total =
        |b: u32| ALL_GROUPS.iter().map(|&g| group_bytes(model, g, b)).sum::<usize>();
    let best = bits.into_iter().rev().find(|&b| total(b) <= budget)?;
    Some((
        best,
        QuantPlan::new()
            .transform(recipe)
            .quantizer(cfg.quantizer)
            .bits(best, best.max(cfg.min_act_bits))
            .cat_block(cfg.cat_block)
            .seed(cfg.seed),
    ))
}

/// Measured mean SQNR (dB) of a built config over the calibration
/// sample — the ground truth the approx scores predict. Runs the actual
/// per-token activation quantizer and the packed dequantized weights per
/// linear; zero-noise linears clamp at 300 dB so means stay finite.
pub fn measured_plan_sqnr_db(model: &NativeModel, calib: &CalibStats, qc: &QuantConfig) -> f64 {
    let mut acc = 0.0;
    let mut n = 0usize;
    for block in 0..model.cfg.n_layers {
        for g in ALL_GROUPS {
            let t_name = g.t_name(block);
            let x = calib.sigma(&t_name).sample();
            let xt = matmul_a_bt(&x, &qc.transforms[&t_name]);
            let act = qc.act_for(g);
            let (xq, _) = quantize_activations_per_token(&xt, act.scheme, act.clip_ratio);
            for &lin in g.linears() {
                let id = LinearId::new(block, lin);
                let w = &model.params[&id.to_string()];
                let y = matmul_a_bt(&x, w);
                let yq = matmul_a_bt(&xq, &qc.linears[&id].deq());
                let noise = y.sub(&yq).fro_norm2();
                acc += if noise == 0.0 { 300.0 } else { db(y.fro_norm2() / noise) };
                n += 1;
            }
        }
    }
    acc / n.max(1) as f64
}

/// Search for the best plan under `cfg`. Deterministic for a fixed
/// config: scoring fans out over the worker pool but merges in job
/// order, the frontier keeps the first-seen cell on utility ties, and
/// both solvers break ties toward the earlier enumeration point — so
/// re-runs and different `CATQUANT_THREADS` emit bit-identical plans.
pub fn search_plan(
    model: &NativeModel,
    calib: &CalibStats,
    cfg: &PlannerCfg,
) -> Result<PlannedQuant> {
    let mut bits = cfg.weight_bits.clone();
    bits.sort_unstable();
    bits.dedup();
    if bits.is_empty() {
        bail!("planner: weight_bits grid is empty");
    }
    for &b in &bits {
        if !(1..=16).contains(&b) {
            bail!("planner: weight bits {b} out of range (want 1..=16)");
        }
    }
    let recipes: Vec<String> = if cfg.recipes.is_empty() {
        transforms::recipe_names()
    } else {
        let mut r = cfg.recipes.clone();
        r.sort();
        r.dedup();
        for name in &r {
            if !transforms::has_recipe(name) {
                bail!(
                    "planner: transform recipe {name:?} is not registered (known: {})",
                    transforms::recipe_names().join(", ")
                );
            }
        }
        r
    };
    let budget_bytes = cfg.budget.to_bytes(cfg.bytes_per_us);

    // ---- Score every (block, group, recipe) cell family. -------------
    // One fit per family (the expensive axis); the bit grid reuses the
    // fitted transform, the per-linear alignment, and the per-act-bits
    // activation concentration. Recipes whose fit inspects the judged
    // quantizer (spinquant) are fitted once at the reference cfg below —
    // a deliberate approximation documented in PERF.md §Planner.
    let ref_act = ActQuantCfg { scheme: QScheme::asym(cfg.min_act_bits), clip_ratio: 1.0 };
    let ref_wq = WeightQuantCfg::rtn_default(4);
    let a_bits_of = |wb: u32| wb.max(cfg.min_act_bits);

    struct FamilyScore {
        g: LayerGroup,
        recipe_idx: usize,
        /// Per bits-grid index: (Σ per-linear dB, Σ per-linear 1/SQNR).
        per_bits: Vec<(f64, f64)>,
        linears: usize,
    }

    let n_recipes = recipes.len();
    let jobs: Vec<(usize, LayerGroup, usize)> = (0..model.cfg.n_layers)
        .flat_map(|block| {
            ALL_GROUPS
                .into_iter()
                .flat_map(move |g| (0..n_recipes).map(move |ri| (block, g, ri)))
        })
        .collect();

    let scored: Vec<FamilyScore> = par::par_map(jobs, par::num_threads(), |(block, g, ri)| {
        let t_name = g.t_name(block);
        let stats = calib.sigma(&t_name);
        let sigma_x = stats.sigma();
        let x_sample = stats.sample();
        let ids: Vec<LinearId> =
            g.linears().iter().map(|&lin| LinearId::new(block, lin)).collect();
        let ws: Vec<&Mat> = ids.iter().map(|id| &model.params[&id.to_string()]).collect();
        let sigma_w = sum_gram(sigma_x.rows(), &ws);
        let recipe = transforms::recipe(&recipes[ri])
            .unwrap_or_else(|| panic!("recipe {} vanished after validation", recipes[ri]));
        // Same per-block seed tweak as build_quant_config, so the built
        // artifact reuses exactly the transforms the search scored.
        let t = recipe.fit(&RecipeCtx {
            x_sample: &x_sample,
            sigma_x: &sigma_x,
            ws: &ws,
            sigma_w: &sigma_w,
            act: ref_act,
            wq: ref_wq,
            cat_block: cfg.cat_block,
            seed: cfg.seed.wrapping_add((block * 13) as u64),
        });
        let xt = t.apply_acts(&x_sample);
        // Activation concentration per distinct act bit-width.
        let mut c_acts: HashMap<u32, f64> = HashMap::new();
        for &wb in &bits {
            let ab = a_bits_of(wb);
            c_acts.entry(ab).or_insert_with(|| {
                concentration_act(
                    &xt,
                    ActQuantCfg { scheme: QScheme::asym(ab), clip_ratio: 1.0 },
                )
            });
        }
        let mut per_bits = vec![(0.0f64, 0.0f64); bits.len()];
        for w in &ws {
            let wf = t.fuse_weights(w);
            let align = alignment_data(&xt, &wf);
            for (bi, &wb) in bits.iter().enumerate() {
                let ab = a_bits_of(wb);
                let wqc = WeightQuantCfg::rtn_default(wb);
                let terms = SqnrTerms {
                    c_act: c_acts[&ab],
                    c_w: concentration_weights(&wf, wqc),
                    align,
                };
                let s = terms.joint(QScheme::asym(ab), wqc.scheme);
                per_bits[bi].0 += db(s);
                per_bits[bi].1 += 1.0 / s.max(1e-300);
            }
        }
        FamilyScore { g, recipe_idx: ri, per_bits, linears: ws.len() }
    });

    // Merge across blocks (job-ordered, so thread count can't matter).
    let gi_of = |g: LayerGroup| ALL_GROUPS.iter().position(|&x| x == g).unwrap();
    let mut sums = vec![vec![vec![(0.0f64, 0.0f64); bits.len()]; recipes.len()]; ALL_GROUPS.len()];
    let mut counts = vec![vec![0usize; recipes.len()]; ALL_GROUPS.len()];
    for fs in scored {
        let gi = gi_of(fs.g);
        for (bi, (s_db, s_noise)) in fs.per_bits.iter().enumerate() {
            sums[gi][fs.recipe_idx][bi].0 += s_db;
            sums[gi][fs.recipe_idx][bi].1 += s_noise;
        }
        counts[gi][fs.recipe_idx] += fs.linears;
    }

    // ---- Per-group byte frontiers: best cell per distinct byte cost. --
    let mut frontiers: Vec<Vec<PlanCell>> = Vec::with_capacity(ALL_GROUPS.len());
    for (gi, &g) in ALL_GROUPS.iter().enumerate() {
        let mut frontier: Vec<PlanCell> = Vec::new();
        for (bi, &wb) in bits.iter().enumerate() {
            let bytes = group_bytes(model, g, wb);
            for (ri, recipe) in recipes.iter().enumerate() {
                let n = counts[gi][ri].max(1) as f64;
                let cell = PlanCell {
                    recipe: recipe.clone(),
                    w_bits: wb,
                    a_bits: a_bits_of(wb),
                    bytes,
                    score_db: sums[gi][ri][bi].0 / n,
                    noise: sums[gi][ri][bi].1 / n,
                };
                match frontier.iter_mut().find(|c| c.bytes == bytes) {
                    Some(best) => {
                        // Strict > keeps the earliest (bits, recipe) on
                        // ties — deterministic across runs.
                        if cell.utility(cfg.objective) > best.utility(cfg.objective) {
                            *best = cell;
                        }
                    }
                    None => frontier.push(cell),
                }
            }
        }
        frontier.sort_by_key(|c| c.bytes);
        frontiers.push(frontier);
    }

    // ---- Allocate. ----------------------------------------------------
    let min_bytes: usize = frontiers.iter().map(|f| f[0].bytes).sum();
    let chosen = match cfg.solver {
        Solver::Exact => solve_exact(&frontiers, budget_bytes, cfg.objective),
        Solver::Greedy => solve_greedy(&frontiers, budget_bytes, cfg.objective),
    };
    let Some(chosen) = chosen else {
        bail!(
            "planner: budget {budget_bytes} B is below the cheapest feasible plan \
             ({min_bytes} B at W{} everywhere)",
            bits[0]
        );
    };

    let decisions: Vec<PlanDecision> = ALL_GROUPS
        .iter()
        .enumerate()
        .map(|(gi, &g)| PlanDecision { group: g, cell: frontiers[gi][chosen[gi]].clone() })
        .collect();
    let total_bytes: usize = decisions.iter().map(|d| d.cell.bytes).sum();
    let utility: f64 = decisions.iter().map(|d| d.cell.utility(cfg.objective)).sum();
    let score_db: f64 = decisions.iter().map(|d| d.cell.score_db).sum();

    // ---- Emit the winning QuantPlan + provenance. ---------------------
    let max_a_bits = decisions.iter().map(|d| d.cell.a_bits).max().unwrap();
    let mut plan = QuantPlan::new()
        .quantizer(cfg.quantizer)
        .cat_block(cfg.cat_block)
        .seed(cfg.seed)
        .kv_acts(ActQuantCfg { scheme: QScheme::asym(max_a_bits), clip_ratio: 1.0 });
    for d in &decisions {
        let (recipe, wb, ab) = (d.cell.recipe.clone(), d.cell.w_bits, d.cell.a_bits);
        plan = plan.for_group(d.group, |gc| gc.transform(recipe).bits(wb, ab));
    }

    let mut provenance = vec![
        ("planner.objective".to_string(), cfg.objective.name().to_string()),
        ("planner.solver".to_string(), cfg.solver.name().to_string()),
        ("planner.budget_bytes".to_string(), budget_bytes.to_string()),
        ("planner.total_bytes".to_string(), total_bytes.to_string()),
        ("planner.score_db".to_string(), format!("{score_db:.3}")),
        (
            "planner.bits_grid".to_string(),
            bits.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(","),
        ),
        ("planner.recipes".to_string(), recipes.join(",")),
        ("planner.seed".to_string(), cfg.seed.to_string()),
    ];
    for d in &decisions {
        provenance.push((format!("planner.{}", d.group.key()), d.cell.summary()));
    }

    Ok(PlannedQuant {
        plan,
        decisions,
        total_bytes,
        budget_bytes,
        objective: cfg.objective,
        utility,
        score_db,
        provenance,
    })
}

/// Exact enumeration over the frontier product. Optimal within budget;
/// monotone in budget (the feasible set only grows); ties break toward
/// the earliest enumeration point (strict `>`), so results are
/// deterministic.
fn solve_exact(frontiers: &[Vec<PlanCell>], budget: usize, obj: Objective) -> Option<Vec<usize>> {
    let n = frontiers.len();
    let mut idx = vec![0usize; n];
    let mut best: Option<(f64, Vec<usize>)> = None;
    loop {
        let bytes: usize = idx.iter().enumerate().map(|(gi, &i)| frontiers[gi][i].bytes).sum();
        if bytes <= budget {
            let u: f64 =
                idx.iter().enumerate().map(|(gi, &i)| frontiers[gi][i].utility(obj)).sum();
            if best.as_ref().is_none_or(|(bu, _)| u > *bu) {
                best = Some((u, idx.clone()));
            }
        }
        // Odometer increment over the frontier product.
        let mut g = 0;
        loop {
            if g == n {
                return best.map(|(_, i)| i);
            }
            idx[g] += 1;
            if idx[g] < frontiers[g].len() {
                break;
            }
            idx[g] = 0;
            g += 1;
        }
    }
}

/// Greedy marginal-utility-per-byte: start every group at its cheapest
/// tier, repeatedly apply the in-budget upgrade with the best
/// `Δutility/Δbytes` until none improves. Feasible whenever the exact
/// solver is; never better than it (property-tested).
fn solve_greedy(frontiers: &[Vec<PlanCell>], budget: usize, obj: Objective) -> Option<Vec<usize>> {
    let n = frontiers.len();
    let mut idx = vec![0usize; n];
    let total = |idx: &[usize]| -> usize {
        idx.iter().enumerate().map(|(gi, &i)| frontiers[gi][i].bytes).sum()
    };
    if total(&idx) > budget {
        return None;
    }
    loop {
        let cur = total(&idx);
        let mut best: Option<(f64, usize, usize)> = None;
        for (gi, frontier) in frontiers.iter().enumerate() {
            let i = idx[gi];
            for j in (i + 1)..frontier.len() {
                let extra = frontier[j].bytes - frontier[i].bytes;
                if cur + extra > budget {
                    break; // frontier is byte-sorted
                }
                let gain = frontier[j].utility(obj) - frontier[i].utility(obj);
                if gain <= 0.0 {
                    continue;
                }
                let rate = gain / extra.max(1) as f64;
                if best.as_ref().is_none_or(|(br, _, _)| rate > *br) {
                    best = Some((rate, gi, j));
                }
            }
        }
        match best {
            Some((_, gi, j)) => idx[gi] = j,
            None => return Some(idx),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(bytes: usize, score_db: f64) -> PlanCell {
        PlanCell {
            recipe: "identity".into(),
            w_bits: 4,
            a_bits: 4,
            bytes,
            score_db,
            noise: 1.0 / crate::sqnr::from_db(score_db),
        }
    }

    /// Two groups, three byte tiers each, with utilities shaped so the
    /// optimum is a *mixed* allocation.
    fn frontiers() -> Vec<Vec<PlanCell>> {
        vec![
            vec![cell(100, 10.0), cell(200, 30.0), cell(400, 34.0)],
            vec![cell(100, 12.0), cell(200, 14.0), cell(400, 15.0)],
        ]
    }

    #[test]
    fn exact_finds_the_mixed_optimum() {
        // Budget 300: best is upgrade group 0 (Δ20 dB) not group 1 (Δ2).
        let sol = solve_exact(&frontiers(), 300, Objective::Sqnr).unwrap();
        assert_eq!(sol, vec![1, 0]);
        // Budget 500: 400+100 (44 dB) beats 200+200 (44 dB)? Equal sums
        // tie — the earlier enumeration point wins deterministically.
        let sol = solve_exact(&frontiers(), 500, Objective::Sqnr).unwrap();
        let u: f64 = sol
            .iter()
            .enumerate()
            .map(|(gi, &i)| frontiers()[gi][i].utility(Objective::Sqnr))
            .sum();
        assert!((u - 44.0).abs() < 1e-12);
    }

    #[test]
    fn exact_is_budget_monotone() {
        let f = frontiers();
        let mut prev = f64::NEG_INFINITY;
        for budget in [200, 300, 400, 500, 600, 800, 1000] {
            let Some(sol) = solve_exact(&f, budget, Objective::Sqnr) else {
                continue;
            };
            let u: f64 =
                sol.iter().enumerate().map(|(gi, &i)| f[gi][i].utility(Objective::Sqnr)).sum();
            assert!(u >= prev - 1e-12, "budget {budget}: {u} < {prev}");
            prev = u;
        }
    }

    #[test]
    fn greedy_is_feasible_and_never_beats_exact() {
        let f = frontiers();
        for budget in [200, 300, 400, 500, 600, 800] {
            let g = solve_greedy(&f, budget, Objective::Sqnr).unwrap();
            let e = solve_exact(&f, budget, Objective::Sqnr).unwrap();
            let bytes = |s: &[usize]| -> usize {
                s.iter().enumerate().map(|(gi, &i)| f[gi][i].bytes).sum()
            };
            let util = |s: &[usize]| -> f64 {
                s.iter().enumerate().map(|(gi, &i)| f[gi][i].utility(Objective::Sqnr)).sum()
            };
            assert!(bytes(&g) <= budget);
            assert!(util(&g) <= util(&e) + 1e-12, "budget {budget}");
        }
    }

    #[test]
    fn infeasible_budget_is_none() {
        assert!(solve_exact(&frontiers(), 150, Objective::Sqnr).is_none());
        assert!(solve_greedy(&frontiers(), 150, Objective::Sqnr).is_none());
    }

    #[test]
    fn objective_names_round_trip() {
        for o in [Objective::Sqnr, Objective::PplProxy] {
            assert_eq!(Objective::from_name(o.name()), Some(o));
        }
        assert_eq!(Objective::from_name("nope"), None);
    }
}
