//! Pipeline implementation.
//!
//! Transform fitting + weight quantization is independent per
//! (block, group), so [`build_quant_config`] fans the per-group builds
//! out across the [`crate::linalg::par`] worker pool; result merging is
//! index-ordered, so reports and maps are identical to the serial build.

use crate::calib::CalibStats;
use crate::linalg::{par, syrk_at_a, Mat};
use crate::model::LayerGroup;
use crate::model::{NativeModel, QuantConfig, QuantizedLinear, ALL_GROUPS};
use crate::quant::{
    gptq_quantize, quantize_weights_rtn, ActQuantCfg, GptqConfig, QScheme, RangeEstimator,
    WeightQuantCfg,
};
use crate::sqnr::approx_sqnr_joint;
use crate::transforms::{
    cat_block, cat_optimal, kronecker_cat, seed_search_rotation, smooth_quant_scale, Transform,
    TransformKind,
};
use std::collections::HashMap;

/// Which weight quantizer a run uses (Table 1's two blocks).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightQuantizer {
    Rtn,
    Gptq,
}

impl WeightQuantizer {
    pub fn label(&self) -> &'static str {
        match self {
            WeightQuantizer::Rtn => "RTN",
            WeightQuantizer::Gptq => "GPTQ",
        }
    }
}

/// One experiment cell's configuration.
#[derive(Clone, Copy, Debug)]
pub struct PipelineCfg {
    pub kind: TransformKind,
    pub weight_quantizer: WeightQuantizer,
    pub bits_w: u32,
    pub bits_a: u32,
    /// CAT block size `k` (clamped to the group dim).
    pub cat_block: usize,
    /// Seed: controls calibration subsampling and rotation draws — the
    /// replication axis of Table 1's ±std.
    pub seed: u64,
}

impl PipelineCfg {
    pub fn w4a4(kind: TransformKind, wq: WeightQuantizer, seed: u64) -> PipelineCfg {
        PipelineCfg {
            kind,
            weight_quantizer: wq,
            bits_w: 4,
            bits_a: 4,
            cat_block: 128,
            seed,
        }
    }
}

/// What the pipeline reports per run (feeds EXPERIMENTS.md).
#[derive(Clone, Debug, Default)]
pub struct PipelineReport {
    /// Per-group (block, group label, transform build millis).
    pub transform_ms: Vec<(String, f64)>,
    /// Mean approx joint SQNR (dB) across block linears, after transform.
    pub mean_sqnr_db: f64,
    /// Chosen activation clip ratio (trained variants).
    pub act_clip: f64,
}

/// Build the transform for one layer group.
pub fn group_transform(
    kind: TransformKind,
    x_sample: &Mat,
    sigma_x: &Mat,
    ws: &[&Mat],
    act: ActQuantCfg,
    wq: WeightQuantCfg,
    cat_k: usize,
    seed: u64,
) -> Transform {
    let d = sigma_x.rows();
    let sigma_w = {
        let mut s = Mat::zeros(d, d);
        for w in ws {
            s.add_in_place(&syrk_at_a(w));
        }
        s
    };
    match kind {
        TransformKind::None => Transform::identity(d),
        TransformKind::SmoothQuant => smooth_quant_scale(x_sample, ws, 0.5),
        TransformKind::QuaRot => {
            // One fixed randomized Hadamard (seeded but unsearched).
            let mut rng = crate::linalg::Rng::new(seed ^ 0x9A407);
            if crate::linalg::is_pow2(d) {
                Transform::orthogonal("quarot", crate::linalg::randomized_hadamard(d, &mut rng))
            } else {
                Transform::orthogonal("quarot", crate::linalg::random_orthogonal(d, &mut rng))
            }
        }
        TransformKind::SpinQuant => seed_search_rotation(x_sample, ws, act, wq, 8, seed),
        TransformKind::CatBlock | TransformKind::CatBlockTrained => {
            cat_block(sigma_x, &sigma_w, cat_k.min(d), seed)
        }
        TransformKind::FlatQuant => kronecker_cat(sigma_x, &sigma_w, seed),
        TransformKind::CatOptimal => cat_optimal(sigma_x, &sigma_w, seed),
        TransformKind::CatBlockPermuted => {
            crate::transforms::permuted_cat_block(sigma_x, &sigma_w, cat_k.min(d), seed)
        }
    }
}

/// Run the full PTQ pipeline for one config.
pub fn build_quant_config(
    model: &NativeModel,
    calib: &CalibStats,
    cfg: PipelineCfg,
) -> (QuantConfig, PipelineReport) {
    let mcfg = &model.cfg;
    let act = ActQuantCfg { scheme: QScheme::asym(cfg.bits_a), clip_ratio: 1.0 };
    let wq = WeightQuantCfg {
        scheme: QScheme::sym(cfg.bits_w),
        range: RangeEstimator::LpNorm { p: 2.4 },
    };

    let mut transforms = HashMap::new();
    let mut linears = HashMap::new();
    let mut report = PipelineReport::default();
    let mut sqnr_acc = Vec::new();

    // One independent build job per (block, group); fanned out across the
    // worker pool and merged back in job order below.
    struct GroupBuild {
        t_name: String,
        timing: (String, f64),
        t_mat: Mat,
        weights: Vec<(String, QuantizedLinear)>,
        sqnrs: Vec<f64>,
    }

    let jobs: Vec<(usize, LayerGroup)> = (0..mcfg.n_layers)
        .flat_map(|block| ALL_GROUPS.into_iter().map(move |g| (block, g)))
        .collect();

    let built: Vec<GroupBuild> = par::par_map(jobs, par::num_threads(), |(block, g)| {
        let t_name = g.t_name(block);
        let stats = calib.sigma(&t_name);
        let sigma_x = stats.sigma();
        let x_sample = stats.sample();
        let ws: Vec<&Mat> = g
            .linears()
            .iter()
            .map(|lin| &model.params[&format!("blocks.{block}.{lin}")])
            .collect();

        let t0 = std::time::Instant::now();
        let t = group_transform(
            cfg.kind,
            &x_sample,
            &sigma_x,
            &ws,
            act,
            wq,
            cfg.cat_block,
            cfg.seed.wrapping_add((block * 13) as u64),
        );
        let timing = (format!("{block}.{}", g.label()), t0.elapsed().as_secs_f64() * 1e3);

        // Fuse + quantize each weight of the group.
        let xt_sample = t.apply_acts(&x_sample);
        let sigma_xt = t.conjugate_sigma(&sigma_x);
        let mut weights = Vec::new();
        let mut sqnrs = Vec::new();
        for lin in g.linears() {
            let name = format!("blocks.{block}.{lin}");
            let w = &model.params[&name];
            let w_fused = t.fuse_weights(w);
            let codes = match cfg.weight_quantizer {
                WeightQuantizer::Rtn => quantize_weights_rtn(&w_fused, wq).codes,
                WeightQuantizer::Gptq => {
                    gptq_quantize(&w_fused, &sigma_xt, wq, GptqConfig::default()).codes
                }
            };
            sqnrs.push(10.0 * approx_sqnr_joint(&xt_sample, &w_fused, act, wq).log10());
            weights.push((name, QuantizedLinear::new(codes)));
        }
        GroupBuild { t_name, timing, t_mat: t.matrix().clone(), weights, sqnrs }
    });

    for gb in built {
        report.transform_ms.push(gb.timing);
        sqnr_acc.extend(gb.sqnrs);
        for (name, ql) in gb.weights {
            linears.insert(name, ql);
        }
        transforms.insert(gb.t_name, gb.t_mat);
    }
    report.mean_sqnr_db = sqnr_acc.iter().sum::<f64>() / sqnr_acc.len().max(1) as f64;

    // "Trained" variants: learnable clipping — grid-search the activation
    // clip ratio maximizing the mean post-transform SQNR proxy (the
    // paper attributes most of the trained gain to learnable clipping).
    // The transformed sample and the dequantized fused weight are
    // computed once per (block, group, linear) — not once per clip
    // candidate — and each candidate's score accumulates in the same
    // order as the historical clip-outermost loop.
    let mut act_final = act;
    if cfg.kind == TransformKind::CatBlockTrained {
        const CLIPS: [f64; 5] = [1.0, 0.95, 0.9, 0.85, 0.8];
        let mut acc = [0.0f64; CLIPS.len()];
        let mut n = 0usize;
        for block in 0..mcfg.n_layers {
            for g in ALL_GROUPS {
                let t_name = g.t_name(block);
                let stats = calib.sigma(&t_name);
                let x = stats.sample();
                let xt = crate::linalg::matmul_a_bt(&x, &transforms[&t_name]);
                for lin in g.linears() {
                    let name = format!("blocks.{block}.{lin}");
                    let wf = linears[&name].deq();
                    for (ci, &clip) in CLIPS.iter().enumerate() {
                        let cand = ActQuantCfg { scheme: act.scheme, clip_ratio: clip };
                        acc[ci] += approx_sqnr_joint(&xt, &wf, cand, wq).ln();
                    }
                    n += 1;
                }
            }
        }
        let mut best = (f64::NEG_INFINITY, 1.0);
        for (ci, &clip) in CLIPS.iter().enumerate() {
            let score = acc[ci] / n as f64;
            if score > best.0 {
                best = (score, clip);
            }
        }
        act_final = ActQuantCfg { scheme: act.scheme, clip_ratio: best.1 };
        report.act_clip = best.1;
    } else {
        report.act_clip = 1.0;
    }

    (
        QuantConfig {
            act: act_final,
            weight_bits: cfg.bits_w,
            transforms,
            linears,
        },
        report,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::calibrate;
    use crate::model::ModelConfig;

    fn setup() -> (NativeModel, CalibStats) {
        let cfg = ModelConfig {
            name: "t".into(),
            d: 32,
            n_layers: 2,
            n_heads: 4,
            ff: 64,
            seq: 16,
            vocab: 256,
        };
        let model = NativeModel::init_random(cfg, 11);
        let mut rng = crate::linalg::Rng::new(5);
        let seqs: Vec<Vec<u8>> =
            (0..8).map(|_| (0..16).map(|_| rng.below(256) as u8).collect()).collect();
        let calib = calibrate(&model, &seqs, 256, 0);
        (model, calib)
    }

    #[test]
    fn every_kind_builds_and_preserves_function_at_high_bits() {
        let (model, calib) = setup();
        let toks: Vec<u8> = (0..12).map(|i| (i * 17) as u8).collect();
        let fp = model.forward(&toks);
        for kind in [
            TransformKind::None,
            TransformKind::SmoothQuant,
            TransformKind::QuaRot,
            TransformKind::SpinQuant,
            TransformKind::CatBlock,
            TransformKind::FlatQuant,
        ] {
            let pcfg = PipelineCfg {
                kind,
                weight_quantizer: WeightQuantizer::Rtn,
                bits_w: 12,
                bits_a: 12,
                cat_block: 8,
                seed: 0,
            };
            let (qc, _) = build_quant_config(&model, &calib, pcfg);
            let q = model.forward_quant(&toks, &qc);
            let rel = fp.max_abs_diff(&q) / fp.max_abs().max(1e-9);
            assert!(rel < 0.08, "{kind:?}: 12-bit run strayed {rel} from fp");
        }
    }

    #[test]
    fn cat_block_sqnr_beats_none_at_w4a4() {
        let (model, calib) = setup();
        let run = |kind| {
            let (_, rep) = build_quant_config(
                &model,
                &calib,
                PipelineCfg::w4a4(kind, WeightQuantizer::Rtn, 0),
            );
            rep.mean_sqnr_db
        };
        let none = run(TransformKind::None);
        let cat = run(TransformKind::CatBlock);
        assert!(cat > none, "CAT {cat:.1} dB should beat None {none:.1} dB");
    }

    #[test]
    fn trained_variant_picks_a_clip() {
        let (model, calib) = setup();
        let (qc, rep) = build_quant_config(
            &model,
            &calib,
            PipelineCfg::w4a4(TransformKind::CatBlockTrained, WeightQuantizer::Rtn, 0),
        );
        assert!(rep.act_clip > 0.7 && rep.act_clip <= 1.0);
        assert_eq!(qc.act.clip_ratio, rep.act_clip);
    }

    #[test]
    fn gptq_pipeline_runs() {
        let (model, calib) = setup();
        let (qc, _) = build_quant_config(
            &model,
            &calib,
            PipelineCfg::w4a4(TransformKind::CatBlock, WeightQuantizer::Gptq, 0),
        );
        assert_eq!(qc.linears.len(), 2 * 7);
        assert!(qc
            .linears
            .values()
            .all(|l| l.deq().as_slice().iter().all(|v| v.is_finite())));
    }

    #[test]
    fn seeds_change_rotations_but_not_identity() {
        let (model, calib) = setup();
        let build = |kind, seed| {
            build_quant_config(
                &model,
                &calib,
                PipelineCfg::w4a4(kind, WeightQuantizer::Rtn, seed),
            )
            .0
        };
        let a = build(TransformKind::QuaRot, 1);
        let b = build(TransformKind::QuaRot, 2);
        let key = "blocks.0.t_attn";
        assert!(a.transforms[key].max_abs_diff(&b.transforms[key]) > 0.05);
        let a = build(TransformKind::None, 1);
        let b = build(TransformKind::None, 2);
        assert_eq!(a.transforms[key], b.transforms[key]);
    }
}
