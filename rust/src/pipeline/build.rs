//! Pipeline implementation.
//!
//! Transform fitting + weight quantization is independent per
//! (block, group), so [`build_quant_config`] fans the per-group builds
//! out across the [`crate::linalg::par`] worker pool; result merging is
//! index-ordered, so reports and maps are identical to the serial build.
//!
//! The pipeline consumes a [`QuantPlan`](super::QuantPlan): per-group
//! transform recipes (resolved through the open registry in
//! [`crate::transforms::recipe`]), quantizer algorithms, and bit-widths.
//! Plan validation happens up front — the fan-out never sees an invalid
//! configuration.

use super::plan::{QuantPlan, ResolvedPlan, WeightQuantizer};
use crate::calib::CalibStats;
use crate::linalg::{par, syrk_at_a, Mat};
use crate::model::{LayerGroup, LinearId, NativeModel, QuantConfig, QuantizedLinear, ALL_GROUPS};
use crate::quant::{gptq_quantize, quantize_weights_rtn, ActQuantCfg, GptqConfig, WeightQuantCfg};
use crate::sqnr::approx_sqnr_joint;
use crate::transforms::{self, RecipeCtx, RecipeRef, Transform, TransformKind};
use anyhow::Result;
use std::collections::HashMap;

/// What the pipeline reports per run (feeds EXPERIMENTS.md and the
/// artifact manifest's plan echo).
#[derive(Clone, Debug, Default)]
pub struct PipelineReport {
    /// Per-group (block, group label, transform build millis).
    pub transform_ms: Vec<(String, f64)>,
    /// Mean approx joint SQNR (dB) across block linears, after transform.
    pub mean_sqnr_db: f64,
    /// Chosen activation clip ratio (trained variants).
    pub act_clip: f64,
    /// Resolved-plan echo: `(group key, summary)` pairs plus seed.
    pub plan: Vec<(String, String)>,
}

/// Build the transform for one layer group — the closed-enum convenience
/// wrapper over the recipe registry (the figure experiments' entrypoint;
/// plans address recipes by name directly).
#[allow(clippy::too_many_arguments)]
pub fn group_transform(
    kind: TransformKind,
    x_sample: &Mat,
    sigma_x: &Mat,
    ws: &[&Mat],
    act: ActQuantCfg,
    wq: WeightQuantCfg,
    cat_k: usize,
    seed: u64,
) -> Transform {
    let sigma_w = sum_gram(sigma_x.rows(), ws);
    let recipe = transforms::recipe(kind.name())
        .unwrap_or_else(|| panic!("builtin recipe {} missing from registry", kind.name()));
    recipe.fit(&RecipeCtx {
        x_sample,
        sigma_x,
        ws,
        sigma_w: &sigma_w,
        act,
        wq,
        cat_block: cat_k,
        seed,
    })
}

/// `Σ WᵀW` over the group's weights (shared with the planner's scorer,
/// so search-time and build-time recipe fits see identical stats).
pub(crate) fn sum_gram(d: usize, ws: &[&Mat]) -> Mat {
    let mut s = Mat::zeros(d, d);
    for w in ws {
        s.add_in_place(&syrk_at_a(w));
    }
    s
}

/// Run the full PTQ pipeline for one plan.
pub fn build_quant_config(
    model: &NativeModel,
    calib: &CalibStats,
    plan: &QuantPlan,
) -> Result<(QuantConfig, PipelineReport)> {
    let resolved = plan.resolve()?;
    Ok(build_resolved(model, calib, &resolved))
}

fn build_resolved(
    model: &NativeModel,
    calib: &CalibStats,
    resolved: &ResolvedPlan,
) -> (QuantConfig, PipelineReport) {
    let mcfg = &model.cfg;

    // Recipes fetched once per group (registry lock stays off the
    // fan-out hot path); plan validation guarantees presence.
    let recipes: HashMap<LayerGroup, RecipeRef> = ALL_GROUPS
        .into_iter()
        .map(|g| {
            let name = &resolved.group(g).recipe;
            let r = transforms::recipe(name)
                .unwrap_or_else(|| panic!("recipe {name} vanished after validation"));
            (g, r)
        })
        .collect();

    let mut transforms_map = HashMap::new();
    let mut linears = HashMap::new();
    let mut report = PipelineReport { plan: resolved.summary(), ..Default::default() };
    let mut sqnr_acc = Vec::new();

    // One independent build job per (block, group); fanned out across the
    // worker pool and merged back in job order below.
    struct GroupBuild {
        t_name: String,
        timing: (String, f64),
        t_mat: Mat,
        weights: Vec<(LinearId, QuantizedLinear)>,
        sqnrs: Vec<f64>,
    }

    let jobs: Vec<(usize, LayerGroup)> = (0..mcfg.n_layers)
        .flat_map(|block| ALL_GROUPS.into_iter().map(move |g| (block, g)))
        .collect();

    let built: Vec<GroupBuild> = par::par_map(jobs, par::num_threads(), |(block, g)| {
        let gp = resolved.group(g);
        let t_name = g.t_name(block);
        let stats = calib.sigma(&t_name);
        let sigma_x = stats.sigma();
        let x_sample = stats.sample();
        let ids: Vec<LinearId> =
            g.linears().iter().map(|&lin| LinearId::new(block, lin)).collect();
        let ws: Vec<&Mat> = ids.iter().map(|id| &model.params[&id.to_string()]).collect();
        let sigma_w = sum_gram(sigma_x.rows(), &ws);

        let t0 = std::time::Instant::now();
        let t = recipes[&g].fit(&RecipeCtx {
            x_sample: &x_sample,
            sigma_x: &sigma_x,
            ws: &ws,
            sigma_w: &sigma_w,
            act: gp.acts,
            wq: gp.weights,
            cat_block: gp.cat_block,
            seed: resolved.seed.wrapping_add((block * 13) as u64),
        });
        let timing = (format!("{block}.{}", g.label()), t0.elapsed().as_secs_f64() * 1e3);

        // Fuse + quantize each weight of the group.
        let xt_sample = t.apply_acts(&x_sample);
        let sigma_xt = t.conjugate_sigma(&sigma_x);
        let mut weights = Vec::new();
        let mut sqnrs = Vec::new();
        for (id, w) in ids.iter().zip(&ws) {
            let w_fused = t.fuse_weights(w);
            let codes = match gp.quantizer {
                WeightQuantizer::Rtn => quantize_weights_rtn(&w_fused, gp.weights).codes,
                WeightQuantizer::Gptq => {
                    gptq_quantize(&w_fused, &sigma_xt, gp.weights, GptqConfig::default()).codes
                }
            };
            sqnrs.push(
                10.0 * approx_sqnr_joint(&xt_sample, &w_fused, gp.acts, gp.weights).log10(),
            );
            weights.push((*id, QuantizedLinear::new(codes)));
        }
        GroupBuild { t_name, timing, t_mat: t.matrix().clone(), weights, sqnrs }
    });

    for gb in built {
        report.transform_ms.push(gb.timing);
        sqnr_acc.extend(gb.sqnrs);
        for (id, ql) in gb.weights {
            linears.insert(id, ql);
        }
        transforms_map.insert(gb.t_name, gb.t_mat);
    }
    report.mean_sqnr_db = sqnr_acc.iter().sum::<f64>() / sqnr_acc.len().max(1) as f64;

    // "Trained" variants: learnable clipping — grid-search the activation
    // clip ratio maximizing the mean post-transform SQNR proxy over the
    // groups whose recipe is the trained one (the paper attributes most
    // of the trained gain to learnable clipping). The transformed sample
    // and the dequantized fused weight are computed once per
    // (block, group, linear) — not once per clip candidate — and each
    // candidate's score accumulates in the same order as the historical
    // clip-outermost loop.
    let trained: Vec<LayerGroup> = ALL_GROUPS
        .into_iter()
        .filter(|g| resolved.group(*g).recipe == "cat-block-trained")
        .collect();
    let mut acts: HashMap<LayerGroup, ActQuantCfg> =
        ALL_GROUPS.into_iter().map(|g| (g, resolved.group(g).acts)).collect();
    let mut kv_act = resolved.kv_act;
    report.act_clip = 1.0;
    if !trained.is_empty() {
        const CLIPS: [f64; 5] = [1.0, 0.95, 0.9, 0.85, 0.8];
        let mut acc = [0.0f64; CLIPS.len()];
        let mut n = 0usize;
        for block in 0..mcfg.n_layers {
            for g in ALL_GROUPS {
                if !trained.contains(&g) {
                    continue;
                }
                let gp = resolved.group(g);
                let t_name = g.t_name(block);
                let stats = calib.sigma(&t_name);
                let x = stats.sample();
                let xt = crate::linalg::matmul_a_bt(&x, &transforms_map[&t_name]);
                for &lin in g.linears() {
                    let id = LinearId::new(block, lin);
                    let wf = linears[&id].deq();
                    for (ci, &clip) in CLIPS.iter().enumerate() {
                        let cand = ActQuantCfg { scheme: gp.acts.scheme, clip_ratio: clip };
                        acc[ci] += approx_sqnr_joint(&xt, &wf, cand, gp.weights).ln();
                    }
                    n += 1;
                }
            }
        }
        let mut best = (f64::NEG_INFINITY, 1.0);
        for (ci, &clip) in CLIPS.iter().enumerate() {
            let score = acc[ci] / n as f64;
            if score > best.0 {
                best = (score, clip);
            }
        }
        for &g in &trained {
            if let Some(a) = acts.get_mut(&g) {
                a.clip_ratio = best.1;
            }
        }
        // Uniform trained plans historically carried the trained clip
        // into the KV grid too; keep that unless the plan pinned kv_acts
        // explicitly (mixed plans leave the KV grid at its base clip).
        if !resolved.kv_explicit && trained.len() == ALL_GROUPS.len() {
            kv_act.clip_ratio = best.1;
        }
        report.act_clip = best.1;
        // Re-echo the plan with the *chosen* clip, so the artifact
        // manifest records what is actually served, not the pre-search
        // clip=1 placeholder.
        let mut echoed = resolved.clone();
        for &g in &trained {
            if let Some(gp) = echoed.groups.get_mut(&g) {
                gp.acts.clip_ratio = best.1;
            }
        }
        echoed.kv_act = kv_act;
        report.plan = echoed.summary();
    }

    (
        QuantConfig {
            acts,
            kv_act,
            transforms: transforms_map,
            linears,
        },
        report,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::calibrate;
    use crate::model::ModelConfig;
    use crate::pipeline::PipelineCfg;
    use crate::quant::QScheme;

    fn setup() -> (NativeModel, CalibStats) {
        let cfg = ModelConfig {
            name: "t".into(),
            d: 32,
            n_layers: 2,
            n_heads: 4,
            ff: 64,
            seq: 16,
            vocab: 256,
        };
        let model = NativeModel::init_random(cfg, 11);
        let mut rng = crate::linalg::Rng::new(5);
        let seqs: Vec<Vec<u8>> =
            (0..8).map(|_| (0..16).map(|_| rng.below(256) as u8).collect()).collect();
        let calib = calibrate(&model, &seqs, 256, 0);
        (model, calib)
    }

    #[test]
    fn every_kind_builds_and_preserves_function_at_high_bits() {
        let (model, calib) = setup();
        let toks: Vec<u8> = (0..12).map(|i| (i * 17) as u8).collect();
        let fp = model.forward(&toks);
        for kind in [
            TransformKind::None,
            TransformKind::SmoothQuant,
            TransformKind::QuaRot,
            TransformKind::SpinQuant,
            TransformKind::CatBlock,
            TransformKind::FlatQuant,
        ] {
            let pcfg = PipelineCfg {
                kind,
                weight_quantizer: WeightQuantizer::Rtn,
                bits_w: 12,
                bits_a: 12,
                cat_block: 8,
                seed: 0,
            };
            let (qc, _) = build_quant_config(&model, &calib, &pcfg.plan()).unwrap();
            let q = model.forward_quant(&toks, &qc);
            let rel = fp.max_abs_diff(&q) / fp.max_abs().max(1e-9);
            assert!(rel < 0.08, "{kind:?}: 12-bit run strayed {rel} from fp");
        }
    }

    #[test]
    fn cat_block_sqnr_beats_none_at_w4a4() {
        let (model, calib) = setup();
        let run = |kind| {
            let (_, rep) = build_quant_config(
                &model,
                &calib,
                &PipelineCfg::w4a4(kind, WeightQuantizer::Rtn, 0).plan(),
            )
            .unwrap();
            rep.mean_sqnr_db
        };
        let none = run(TransformKind::None);
        let cat = run(TransformKind::CatBlock);
        assert!(cat > none, "CAT {cat:.1} dB should beat None {none:.1} dB");
    }

    #[test]
    fn trained_variant_picks_a_clip() {
        let (model, calib) = setup();
        let (qc, rep) = build_quant_config(
            &model,
            &calib,
            &PipelineCfg::w4a4(TransformKind::CatBlockTrained, WeightQuantizer::Rtn, 0).plan(),
        )
        .unwrap();
        assert!(rep.act_clip > 0.7 && rep.act_clip <= 1.0);
        for g in ALL_GROUPS {
            assert_eq!(qc.act_for(g).clip_ratio, rep.act_clip);
        }
        // A uniform trained plan carries the clip into the KV grid.
        assert_eq!(qc.kv_act.clip_ratio, rep.act_clip);
    }

    #[test]
    fn gptq_pipeline_runs() {
        let (model, calib) = setup();
        let (qc, _) = build_quant_config(
            &model,
            &calib,
            &PipelineCfg::w4a4(TransformKind::CatBlock, WeightQuantizer::Gptq, 0).plan(),
        )
        .unwrap();
        assert_eq!(qc.linears.len(), 2 * 7);
        assert!(qc
            .linears
            .values()
            .all(|l| l.deq().as_slice().iter().all(|v| v.is_finite())));
    }

    #[test]
    fn seeds_change_rotations_but_not_identity() {
        let (model, calib) = setup();
        let build = |kind, seed| {
            build_quant_config(
                &model,
                &calib,
                &PipelineCfg::w4a4(kind, WeightQuantizer::Rtn, seed).plan(),
            )
            .unwrap()
            .0
        };
        let a = build(TransformKind::QuaRot, 1);
        let b = build(TransformKind::QuaRot, 2);
        let key = "blocks.0.t_attn";
        assert!(a.transforms[key].max_abs_diff(&b.transforms[key]) > 0.05);
        let a = build(TransformKind::None, 1);
        let b = build(TransformKind::None, 2);
        assert_eq!(a.transforms[key], b.transforms[key]);
    }

    #[test]
    fn mixed_precision_plan_builds_per_group() {
        // Attention W8A8 / MLP W4A4 with a per-group transform override —
        // the acceptance-criteria shape.
        let (model, calib) = setup();
        let plan = QuantPlan::new()
            .transform("cat-block")
            .bits(4, 4)
            .cat_block(8)
            .for_group(LayerGroup::AttnIn, |g| g.bits(8, 8))
            .for_group(LayerGroup::OIn, |g| g.bits(8, 8).transform("identity"));
        let (qc, rep) = build_quant_config(&model, &calib, &plan).unwrap();
        // Per-group weight bit-widths landed in the packed codes.
        let q_attn = &qc.linears[&LinearId::new(0, "q_proj")];
        let q_mlp = &qc.linears[&LinearId::new(0, "gate_proj")];
        assert_eq!(q_attn.weight.scheme().bits, 8);
        assert_eq!(q_mlp.weight.scheme().bits, 4);
        // Per-group activation grids.
        assert_eq!(qc.act_for(LayerGroup::AttnIn).scheme.bits, 8);
        assert_eq!(qc.act_for(LayerGroup::MlpIn).scheme.bits, 4);
        // The o-group override swapped its transform to the identity.
        assert_eq!(
            qc.transforms["blocks.0.t_o"].max_abs_diff(&Mat::eye(32)),
            0.0,
            "o-group transform should be the identity"
        );
        assert!(
            qc.transforms["blocks.0.t_mlp"].max_abs_diff(&Mat::eye(32)) > 0.0,
            "mlp group keeps cat-block"
        );
        // The mixed forward executes end to end.
        let toks: Vec<u8> = (0..10).map(|i| (i * 23) as u8).collect();
        let out = model.forward_quant(&toks, &qc);
        assert!(out.as_slice().iter().all(|v| v.is_finite()));
        // The plan echo names every group.
        assert_eq!(rep.plan.len(), ALL_GROUPS.len() + 2);
    }

    #[test]
    fn invalid_plans_error_before_the_fanout() {
        let (model, calib) = setup();
        for plan in [
            QuantPlan::new().bits(0, 4),
            QuantPlan::new().bits(4, 17),
            QuantPlan::new().cat_block(0),
            QuantPlan::new().transform("definitely-not-registered"),
        ] {
            let msg = match build_quant_config(&model, &calib, &plan) {
                Err(e) => e.to_string(),
                Ok(_) => panic!("plan should have been rejected"),
            };
            assert!(msg.contains("attn_in"), "error should name the group: {msg}");
        }
    }

    #[test]
    fn kv_acts_can_differ_from_group_acts() {
        let (model, calib) = setup();
        let plan = QuantPlan::new()
            .transform("identity")
            .bits(4, 4)
            .kv_acts(ActQuantCfg { scheme: QScheme::asym(8), clip_ratio: 1.0 });
        let (qc, _) = build_quant_config(&model, &calib, &plan).unwrap();
        assert_eq!(qc.kv_act.scheme.bits, 8);
        assert_eq!(qc.act_for(LayerGroup::AttnIn).scheme.bits, 4);
        let toks = [1u8, 2, 3, 4, 5];
        let out = model.forward_quant(&toks, &qc);
        assert!(out.as_slice().iter().all(|v| v.is_finite()));
    }
}
