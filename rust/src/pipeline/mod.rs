//! The post-training-quantization pipeline:
//! calibrate → build per-group transforms → fuse into weights → quantize
//! (RTN or GPTQ) → a [`QuantConfig`] both engines can execute.
//!
//! This is the L3 system the paper's §6 experiment grid drives: each
//! Table 1 cell is one [`PipelineCfg`] run.

mod build;

pub use build::{build_quant_config, group_transform, PipelineCfg, PipelineReport, WeightQuantizer};
