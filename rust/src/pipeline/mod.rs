//! The post-training-quantization pipeline:
//! calibrate → build per-group transforms → fuse into weights → quantize
//! (RTN or GPTQ) → a [`QuantConfig`](crate::model::QuantConfig) both
//! engines can execute (and the artifact layer can persist).
//!
//! This is the L3 system the paper's §6 experiment grid drives. Runs are
//! described by a [`QuantPlan`] — per-group transform recipes,
//! quantizers, and bit-widths; the legacy [`PipelineCfg`] lowers into a
//! uniform plan via [`PipelineCfg::plan`].

//! Since PR 10 the pipeline also *searches*: [`search_plan`] scores
//! every `(group × bit-width × recipe)` cell with the paper's SQNR
//! decomposition and solves the budgeted allocation, emitting a plain
//! [`QuantPlan`] that flows through the same build path.

mod build;
mod plan;
mod planner;

pub use build::{build_quant_config, group_transform, PipelineReport};
pub use plan::{
    GroupCfg, GroupPlan, PipelineCfg, PlanError, QuantPlan, ResolvedPlan, WeightQuantizer,
};
pub use planner::{
    best_uniform_plan, measured_plan_sqnr_db, plan_bytes, search_plan, Budget, Objective, PlanCell,
    PlanDecision, PlannedQuant, PlannerCfg, Solver,
};
