//! The post-training-quantization pipeline:
//! calibrate → build per-group transforms → fuse into weights → quantize
//! (RTN or GPTQ) → a [`QuantConfig`](crate::model::QuantConfig) both
//! engines can execute (and the artifact layer can persist).
//!
//! This is the L3 system the paper's §6 experiment grid drives. Runs are
//! described by a [`QuantPlan`] — per-group transform recipes,
//! quantizers, and bit-widths; the legacy [`PipelineCfg`] lowers into a
//! uniform plan via [`PipelineCfg::plan`].

mod build;
mod plan;

pub use build::{build_quant_config, group_transform, PipelineReport};
pub use plan::{
    GroupCfg, GroupPlan, PipelineCfg, PlanError, QuantPlan, ResolvedPlan, WeightQuantizer,
};
