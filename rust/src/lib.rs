//! # catquant
//!
//! A production-oriented reproduction of *"Dissecting Quantization Error:
//! A Concentration-Alignment Perspective"* (Federici et al., 2026).
//!
//! The crate implements the paper's full stack as a three-layer system:
//!
//! * **Layer 3 (this crate)** — the quantization *coordinator*: the
//!   post-training-quantization pipeline (calibrate → transform → quantize →
//!   evaluate), a batched serving loop, and every substrate the paper
//!   depends on (dense linear algebra, uniform quantizers, GPTQ, transform
//!   zoo, a Llama-style transformer, evaluation harnesses).
//! * **Layer 2** — a JAX transformer (`python/compile/model.py`) lowered
//!   once to HLO text and executed from Rust through PJRT
//!   ([`runtime::PjrtEngine`]). Weights are runtime arguments, so the Rust
//!   pipeline's products (fused transforms, fake-quantized weights) feed the
//!   compiled graph without recompilation.
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`) for the fused
//!   transform → dynamic-quantize → matmul hot path, verified against a
//!   pure-`jnp` oracle at build time.
//!
//! The scientific core is [`sqnr`] (the paper's Theorem 2.4 decomposition
//! into *concentration* and *alignment*) and [`transforms`] (SmoothQuant
//! scaling, Hadamard, rotations, and the paper's CAT family).

pub mod calib;
pub mod coordinator;
pub mod eval;
pub mod experiments;
pub mod linalg;
pub mod model;
pub mod pipeline;
pub mod quant;
pub mod runtime;
pub mod sqnr;
pub mod transforms;
