//! Streaming activation statistics.

use crate::linalg::{syrk_at_a, Mat, Rng};
use crate::model::{NativeModel, ProbeCapture, ALL_GROUPS};
use std::collections::HashMap;

/// Streaming second-moment accumulator + reservoir row subsample for one
/// layer group.
pub struct ActStats {
    dim: usize,
    sum_outer: Mat,
    count: usize,
    reservoir: Vec<Vec<f64>>,
    max_rows: usize,
    seen: usize,
    rng: Rng,
}

impl ActStats {
    pub fn new(dim: usize, max_rows: usize, seed: u64) -> ActStats {
        ActStats {
            dim,
            sum_outer: Mat::zeros(dim, dim),
            count: 0,
            reservoir: Vec::with_capacity(max_rows),
            max_rows,
            seen: 0,
            rng: Rng::new(seed ^ 0xACC),
        }
    }

    /// Fold in a `tokens × dim` activation block.
    pub fn update(&mut self, x: &Mat) {
        assert_eq!(x.cols(), self.dim);
        // `XᵀX` dispatches to the parallel kernels for big blocks; the
        // in-place fold avoids a d×d allocation per update.
        self.sum_outer.add_in_place(&syrk_at_a(x));
        self.count += x.rows();
        // Reservoir sampling keeps an unbiased row subsample.
        for t in 0..x.rows() {
            self.seen += 1;
            if self.reservoir.len() < self.max_rows {
                self.reservoir.push(x.row(t).to_vec());
            } else {
                let j = self.rng.below(self.seen);
                if j < self.max_rows {
                    self.reservoir[j] = x.row(t).to_vec();
                }
            }
        }
    }

    /// `Σ_x = E[xxᵀ]`.
    pub fn sigma(&self) -> Mat {
        assert!(self.count > 0, "no data");
        let mut s = self.sum_outer.scale(1.0 / self.count as f64);
        s.symmetrize();
        s
    }

    /// The retained row subsample as a matrix.
    pub fn sample(&self) -> Mat {
        assert!(!self.reservoir.is_empty(), "no data");
        let rows = self.reservoir.len();
        let mut m = Mat::zeros(rows, self.dim);
        for (i, r) in self.reservoir.iter().enumerate() {
            m.row_mut(i).copy_from_slice(r);
        }
        m
    }

    pub fn count(&self) -> usize {
        self.count
    }
}

/// Per-group calibration result: transform name (`blocks.i.t_*`) → stats.
pub struct CalibStats {
    pub stats: HashMap<String, ActStats>,
}

impl CalibStats {
    pub fn sigma(&self, t_name: &str) -> &ActStats {
        self.stats.get(t_name).unwrap_or_else(|| panic!("no calib stats for {t_name}"))
    }
}

/// Run the FP model over the calibration sequences, collecting `Σ_x` and a
/// row subsample for every transform group (the paper's 128-sequence
/// calibration pass).
pub fn calibrate(
    model: &NativeModel,
    seqs: &[Vec<u8>],
    max_sample_rows: usize,
    seed: u64,
) -> CalibStats {
    let cfg = &model.cfg;
    let mut probe = ProbeCapture::new(cfg.n_layers);
    for s in seqs {
        model.forward_probed(s, &mut probe);
    }
    let mut stats = HashMap::new();
    for i in 0..cfg.n_layers {
        for g in ALL_GROUPS {
            let parts = match g {
                crate::model::LayerGroup::AttnIn => &probe.attn_in[i],
                crate::model::LayerGroup::OIn => &probe.o_in[i],
                crate::model::LayerGroup::MlpIn => &probe.mlp_in[i],
                crate::model::LayerGroup::DownIn => &probe.down_in[i],
            };
            let mut st = ActStats::new(g.dim(cfg), max_sample_rows, seed ^ (i as u64) << 8);
            for p in parts {
                st.update(p);
            }
            stats.insert(g.t_name(i), st);
        }
    }
    CalibStats { stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    #[test]
    fn streaming_sigma_matches_batch() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(300, 8, |_, _| rng.normal());
        let mut st = ActStats::new(8, 64, 0);
        st.update(&x.block(0, 0, 100, 8));
        st.update(&x.block(100, 0, 120, 8));
        st.update(&x.block(220, 0, 80, 8));
        // Cross-check against the rectangular kernel (syrk_at_a is
        // bit-identical to it; keep the independent path here).
        let want = crate::linalg::matmul_at_b(&x, &x).scale(1.0 / 300.0);
        assert!(st.sigma().max_abs_diff(&want) < 1e-9);
        assert_eq!(st.count(), 300);
    }

    #[test]
    fn reservoir_bounded_and_sane() {
        let mut rng = Rng::new(2);
        let x = Mat::from_fn(1000, 4, |_, _| rng.normal());
        let mut st = ActStats::new(4, 50, 0);
        st.update(&x);
        let s = st.sample();
        assert_eq!(s.rows(), 50);
        // Reservoir rows come from the data (spot-check variance scale).
        let var = s.fro_norm2() / (50.0 * 4.0);
        assert!(var > 0.4 && var < 2.5, "var {var}");
    }

    #[test]
    fn calibrate_covers_all_groups() {
        let cfg = ModelConfig {
            name: "t".into(),
            d: 32,
            n_layers: 2,
            n_heads: 4,
            ff: 64,
            seq: 16,
            vocab: 256,
        };
        let model = NativeModel::init_random(cfg.clone(), 3);
        let seqs: Vec<Vec<u8>> = (0..3).map(|i| vec![(i * 7) as u8; 10]).collect();
        let calib = calibrate(&model, &seqs, 64, 0);
        assert_eq!(calib.stats.len(), 2 * 4);
        let st = calib.sigma("blocks.0.t_attn");
        assert_eq!(st.count(), 30);
        assert_eq!(st.sigma().rows(), 32);
        let st = calib.sigma("blocks.1.t_down");
        assert_eq!(st.sigma().rows(), 64);
    }
}
