//! Synthetic layer suites — controlled (x, W) pairs exercising the
//! distributional regimes the paper's figures live in.
//!
//! The trained model zoo gives *real* layer statistics; the synthetic
//! suite complements it with labeled, controllable pathologies: persistent
//! outlier channels (massive-activation style), heavy tails
//! (worse-than-Laplace, Figure 4's red region), correlated anisotropy
//! (the misalignment regime Figure 5 shows >10 dB of headroom in), and a
//! benign Gaussian control.

use crate::linalg::{matmul, Mat, Rng};

/// What pathology a synthetic layer exhibits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SynthSpec {
    /// Well-behaved isotropic Gaussian activations.
    Gaussian,
    /// A few channels carry persistent large-magnitude values.
    OutlierChannels,
    /// Student-t(3) heavy tails on every channel.
    HeavyTailed,
    /// Correlated activations with a spread spectrum, weights with
    /// mismatched principal directions (poor alignment).
    Misaligned,
    /// Outliers + misalignment (the down_proj-like worst case).
    Pathological,
}

impl SynthSpec {
    pub fn label(&self) -> &'static str {
        match self {
            SynthSpec::Gaussian => "gaussian",
            SynthSpec::OutlierChannels => "outlier_channels",
            SynthSpec::HeavyTailed => "heavy_tailed",
            SynthSpec::Misaligned => "misaligned",
            SynthSpec::Pathological => "pathological",
        }
    }

    pub fn all() -> &'static [SynthSpec] {
        &[
            SynthSpec::Gaussian,
            SynthSpec::OutlierChannels,
            SynthSpec::HeavyTailed,
            SynthSpec::Misaligned,
            SynthSpec::Pathological,
        ]
    }
}

/// A generated layer: activations `x` (`tokens × d`) and weights
/// (`out × d`).
pub struct SynthLayer {
    pub name: String,
    pub spec: SynthSpec,
    pub x: Mat,
    pub w: Mat,
}

/// Generate one synthetic layer.
pub fn synth_layer(spec: SynthSpec, d: usize, tokens: usize, seed: u64) -> SynthLayer {
    let mut rng = Rng::new(seed ^ 0x517E);
    let out = d;
    let (x, w) = match spec {
        SynthSpec::Gaussian => {
            let x = Mat::from_fn(tokens, d, |_, _| rng.normal());
            let w = Mat::from_fn(out, d, |_, _| rng.normal() * 0.05);
            (x, w)
        }
        SynthSpec::OutlierChannels => {
            let mut x = Mat::from_fn(tokens, d, |_, _| rng.normal());
            let k = (d / 32).max(1);
            for c in 0..k {
                let ch = (7 + 13 * c) % d;
                let gain = 25.0 + 10.0 * c as f64;
                for t in 0..tokens {
                    x[(t, ch)] *= gain;
                }
            }
            let w = Mat::from_fn(out, d, |_, _| rng.normal() * 0.05);
            (x, w)
        }
        SynthSpec::HeavyTailed => {
            let x = Mat::from_fn(tokens, d, |_, _| rng.student_t(3));
            let w = Mat::from_fn(out, d, |_, _| rng.laplace(0.04));
            (x, w)
        }
        SynthSpec::Misaligned => misaligned_pair(out, d, tokens, &mut rng),
        SynthSpec::Pathological => {
            let (mut x, w) = misaligned_pair(out, d, tokens, &mut rng);
            for t in 0..tokens {
                x[(t, 3 % d)] *= 20.0;
            }
            (x, w)
        }
    };
    SynthLayer { name: format!("{}(d={d})", spec.label()), spec, x, w }
}

/// Shared construction: an explicit eigenbasis `U` in which activations
/// are strong exactly where weights are weak. `x = z·diag(√λ)·Uᵀ` with a
/// geometric spectrum `λ_i = c^{i}`, and `W = G·diag(λ^{-1/2})·Uᵀ` — the
/// textbook worst case for the alignment term, mirroring the paper's
/// down_proj observations.
fn misaligned_pair(out: usize, d: usize, tokens: usize, rng: &mut Rng) -> (Mat, Mat) {
    let u = crate::linalg::random_orthogonal(d, rng);
    // Fixed total spectrum spread (λ_max/λ_min = 10^6) independent of d,
    // matching the eigenvalue dynamic range of LLM activation covariances.
    let sqrt_lam: Vec<f64> =
        (0..d).map(|i| 10f64.powf(3.0 * i as f64 / (d - 1).max(1) as f64)).collect();
    let z = Mat::from_fn(tokens, d, |_, _| rng.normal());
    let mut zs = z;
    for t in 0..tokens {
        let row = zs.row_mut(t);
        for (j, v) in row.iter_mut().enumerate() {
            *v *= sqrt_lam[j];
        }
    }
    let x = matmul(&zs, &u.transpose());
    let mut g = Mat::from_fn(out, d, |_, _| rng.normal() * 0.02);
    for i in 0..out {
        let row = g.row_mut(i);
        for (j, v) in row.iter_mut().enumerate() {
            *v /= sqrt_lam[j];
        }
    }
    let w = matmul(&g, &u.transpose());
    (x, w)
}

/// The full suite at one width.
pub fn synth_suite(d: usize, tokens: usize, seed: u64) -> Vec<SynthLayer> {
    SynthSpec::all()
        .iter()
        .enumerate()
        .map(|(i, &s)| synth_layer(s, d, tokens, seed.wrapping_add(i as u64 * 1009)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{ActQuantCfg, QScheme};
    use crate::sqnr::{alignment_data, concentration_act, max_alignment};

    #[test]
    fn outlier_layer_has_worse_concentration_than_gaussian() {
        let g = synth_layer(SynthSpec::Gaussian, 64, 512, 1);
        let o = synth_layer(SynthSpec::OutlierChannels, 64, 512, 1);
        let cfg = ActQuantCfg { scheme: QScheme::asym(4), clip_ratio: 1.0 };
        assert!(concentration_act(&o.x, cfg) < concentration_act(&g.x, cfg) * 0.3);
    }

    #[test]
    fn heavy_tailed_worse_than_gaussian() {
        let g = synth_layer(SynthSpec::Gaussian, 64, 512, 2);
        let h = synth_layer(SynthSpec::HeavyTailed, 64, 512, 2);
        let cfg = ActQuantCfg { scheme: QScheme::asym(4), clip_ratio: 1.0 };
        assert!(concentration_act(&h.x, cfg) < concentration_act(&g.x, cfg));
    }

    #[test]
    fn misaligned_layer_has_alignment_headroom() {
        let l = synth_layer(SynthSpec::Misaligned, 32, 2048, 3);
        let sigma = crate::linalg::syrk_at_a(&l.x).scale(1.0 / l.x.rows() as f64);
        let a = alignment_data(&l.x, &l.w);
        let amax = max_alignment(&sigma, &l.w);
        // Figure 5's point: ≥10 dB of headroom on misaligned layers.
        assert!(
            amax / a > 10.0,
            "expected ≥10 dB headroom, got {:.1} dB",
            10.0 * (amax / a).log10()
        );
    }

    #[test]
    fn suite_is_deterministic() {
        let a = synth_suite(32, 64, 9);
        let b = synth_suite(32, 64, 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.x, y.x);
            assert_eq!(x.w, y.w);
        }
    }
}
