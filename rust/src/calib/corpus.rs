//! Token-corpus access (raw uint8 streams written by
//! `python/compile/corpus.py`).

use anyhow::{Context, Result};
use std::path::Path;

/// A loaded token stream with deterministic sequence sampling.
pub struct Corpus {
    tokens: Vec<u8>,
}

impl Corpus {
    pub fn load(path: &Path) -> Result<Corpus> {
        let tokens =
            std::fs::read(path).with_context(|| format!("reading corpus {}", path.display()))?;
        anyhow::ensure!(!tokens.is_empty(), "empty corpus");
        Ok(Corpus { tokens })
    }

    pub fn from_tokens(tokens: Vec<u8>) -> Corpus {
        Corpus { tokens }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// `n` sequences of length `seq`, sampled at deterministic offsets
    /// (seeded) — the calibration-set draw.
    pub fn sample_sequences(&self, n: usize, seq: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = crate::linalg::Rng::new(seed ^ 0x5EC5);
        let max_start = self.tokens.len().saturating_sub(seq + 1);
        (0..n)
            .map(|_| {
                let start = rng.below(max_start.max(1));
                self.tokens[start..start + seq].to_vec()
            })
            .collect()
    }

    /// `n` non-overlapping evaluation windows of length `seq`, in order —
    /// the held-out perplexity set (same windows for every config).
    pub fn eval_windows(&self, n: usize, seq: usize) -> Vec<Vec<u8>> {
        let avail = self.tokens.len() / seq;
        (0..n.min(avail)).map(|i| self.tokens[i * seq..(i + 1) * seq].to_vec()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake() -> Corpus {
        Corpus::from_tokens((0..10_000u32).map(|i| (i % 251) as u8).collect())
    }

    #[test]
    fn sampling_is_deterministic() {
        let c = fake();
        assert_eq!(c.sample_sequences(4, 16, 7), c.sample_sequences(4, 16, 7));
        assert_ne!(c.sample_sequences(4, 16, 7), c.sample_sequences(4, 16, 8));
    }

    #[test]
    fn eval_windows_non_overlapping() {
        let c = fake();
        let w = c.eval_windows(5, 100);
        assert_eq!(w.len(), 5);
        assert_eq!(w[1][0], c.tokens[100]);
    }

    #[test]
    fn eval_windows_capped_by_length() {
        let c = fake();
        assert_eq!(c.eval_windows(1000, 128).len(), 10_000 / 128);
    }
}
