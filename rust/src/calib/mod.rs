//! Calibration: corpus access, activation-statistics collection, and the
//! synthetic layer suites used by the figure experiments.
//!
//! The paper calibrates on 128 sequences of the calibration corpus (§6);
//! [`calibrate`] runs the FP model over those sequences, streaming
//! per-group autocorrelations `Σ_x = E[xxᵀ]` and retaining a bounded row
//! subsample for the data-driven objectives (SmoothQuant maxima, seed
//! search, measured SQNR).

mod corpus;
mod stats;
mod synth;

pub use corpus::Corpus;
pub use stats::{calibrate, ActStats, CalibStats};
pub use synth::{synth_layer, synth_suite, SynthLayer, SynthSpec};
