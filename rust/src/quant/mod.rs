//! Uniform integer quantization.
//!
//! Implements the paper's quantization setup (§6): activations quantized
//! **dynamically per token, asymmetrically**; weights **per output channel,
//! symmetrically**, with `L_{2.4}` range estimation (following GPTQ) and an
//! optional learnable clip search. Both round-to-nearest (RTN) and GPTQ
//! weight quantizers are provided.
//!
//! Weight quantizers return *packed integer codes*
//! ([`QuantizedTensor`], nibble-packed for bits ≤ 4): the serving path
//! executes them directly through the integer kernel
//! ([`crate::linalg::qmatmul_a_bt`]), while [`QuantizedWeights::deq`]
//! reconstructs the historical fake-quant `f64` matrices bit-exactly for
//! the SQNR analysis ([`crate::sqnr`]) and the PJRT `ArgPack`. The
//! fake-quant activation helpers remain for analysis and as the parity
//! reference the packed path must match to fp rounding.

mod gptq;
mod packed;
mod range;
mod rtn;
mod scheme;
mod uniform;

pub use gptq::{gptq_quantize, GptqConfig};
pub use packed::QuantizedTensor;
pub use range::{lp_optimal_clip_sym, RangeEstimator};
pub use rtn::{quantize_weights_rtn, QuantizedWeights};
pub use scheme::{ActQuantCfg, QScheme, WeightQuantCfg};
pub use uniform::{
    fake_quant_asym, fake_quant_sym, percentile_range, quantize_activations_per_token,
    quantize_activations_static, AffineParams,
};
