//! Uniform integer quantization.
//!
//! Implements the paper's quantization setup (§6): activations quantized
//! **dynamically per token, asymmetrically**; weights **per output channel,
//! symmetrically**, with `L_{2.4}` range estimation (following GPTQ) and an
//! optional learnable clip search. Both round-to-nearest (RTN) and GPTQ
//! weight quantizers are provided.
//!
//! All quantizers are *fake-quant*: they return dequantized `f64` values on
//! the original scale, which is what the SQNR analysis ([`crate::sqnr`])
//! and the serving path (weights are runtime args to the compiled graph)
//! consume. Integer codes are available for storage-size accounting.

mod gptq;
mod range;
mod rtn;
mod scheme;
mod uniform;

pub use gptq::{gptq_quantize, GptqConfig};
pub use range::{lp_optimal_clip_sym, RangeEstimator};
pub use rtn::{quantize_weights_rtn, QuantizedWeights};
pub use scheme::{ActQuantCfg, QScheme, WeightQuantCfg};
pub use uniform::{
    fake_quant_asym, fake_quant_sym, percentile_range, quantize_activations_per_token,
    quantize_activations_static, AffineParams,
};
