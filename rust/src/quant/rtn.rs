//! Round-to-nearest weight quantization (per output channel, symmetric).

use super::{AffineParams, WeightQuantCfg};
use crate::linalg::Mat;

/// A fake-quantized weight matrix plus its per-row grids.
pub struct QuantizedWeights {
    /// Dequantized weights, same shape as the input (`out × in`).
    pub deq: Mat,
    /// Per-output-channel scale.
    pub scales: Vec<f64>,
    /// Per-output-channel quantization range `r(w_i)` (for `C(W)`).
    pub ranges: Vec<f64>,
}

/// RTN: independently round each output channel to its symmetric grid.
pub fn quantize_weights_rtn(w: &Mat, cfg: WeightQuantCfg) -> QuantizedWeights {
    let mut deq = Mat::zeros(w.rows(), w.cols());
    let mut scales = Vec::with_capacity(w.rows());
    let mut ranges = Vec::with_capacity(w.rows());
    for i in 0..w.rows() {
        let row = w.row(i);
        let absmax = cfg.range.resolve_sym(row, cfg.scheme);
        let p = AffineParams::symmetric(absmax, cfg.scheme);
        scales.push(p.scale);
        ranges.push(p.range());
        let orow = deq.row_mut(i);
        for (o, &v) in orow.iter_mut().zip(row) {
            *o = p.fake_quant(v);
        }
    }
    QuantizedWeights { deq, scales, ranges }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::quant::{QScheme, RangeEstimator};

    fn random_w(out: usize, inp: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(out, inp, |_, _| rng.normal() * 0.05)
    }

    #[test]
    fn rows_quantized_independently() {
        let mut w = random_w(4, 64, 1);
        // Blow up one row; others must be unaffected.
        for v in w.row_mut(2) {
            *v *= 100.0;
        }
        let q = quantize_weights_rtn(&w, WeightQuantCfg::minmax(4));
        assert!(q.scales[2] > 50.0 * q.scales[0]);
        // Row 0 error stays at its own scale.
        let err0: f64 = w
            .row(0)
            .iter()
            .zip(q.deq.row(0))
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(err0 <= q.scales[0] / 2.0 + 1e-12);
    }

    #[test]
    fn error_bounded_at_8_bits() {
        let w = random_w(16, 128, 2);
        let q = quantize_weights_rtn(&w, WeightQuantCfg::minmax(8));
        let rel = w.sub(&q.deq).fro_norm2() / w.fro_norm2();
        assert!(rel < 1e-4, "rel err {rel}");
    }

    #[test]
    fn lp_range_no_worse_than_minmax_l2() {
        let mut rng = Rng::new(3);
        let mut w = random_w(8, 256, 4);
        // Add outliers to a few rows.
        for i in 0..8 {
            let j = rng.below(256);
            w[(i, j)] = rng.sign() * 2.0;
        }
        let q_mm = quantize_weights_rtn(&w, WeightQuantCfg::minmax(4));
        let q_lp = quantize_weights_rtn(&w, WeightQuantCfg::rtn_default(4));
        let e_mm = w.sub(&q_mm.deq).fro_norm2();
        let e_lp = w.sub(&q_lp.deq).fro_norm2();
        // L2.4 optimizes a close proxy of L2; allow small slack.
        assert!(e_lp <= e_mm * 1.05, "lp {e_lp} vs mm {e_mm}");
    }

    #[test]
    fn ranges_are_twice_absmax_for_minmax() {
        let w = Mat::from_vec(1, 4, vec![0.5, -1.5, 1.0, 0.0]);
        let q = quantize_weights_rtn(
            &w,
            WeightQuantCfg { scheme: QScheme::sym(4), range: RangeEstimator::MinMax },
        );
        assert!((q.ranges[0] - 3.0).abs() < 1e-12); // 2 · max|w| = 3
    }
}
