//! Round-to-nearest weight quantization (per output channel, symmetric).

use super::{AffineParams, QuantizedTensor, WeightQuantCfg};
use crate::linalg::Mat;

/// A weight matrix quantized to packed integer codes plus its per-row
/// grids — the native output of RTN and GPTQ.
pub struct QuantizedWeights {
    /// Packed codes + per-output-channel scale/zero-point (`out × in`).
    pub codes: QuantizedTensor,
    /// Per-output-channel quantization range `r(w_i)` (for `C(W)`).
    pub ranges: Vec<f64>,
}

impl QuantizedWeights {
    /// Reconstruct the dequantized f64 matrix — bit-identical to the
    /// historical fake-quant output (parity reference, SQNR analysis,
    /// the PJRT `ArgPack`).
    pub fn deq(&self) -> Mat {
        self.codes.deq()
    }

    /// Per-output-channel scales.
    pub fn scales(&self) -> &[f64] {
        self.codes.scales()
    }
}

/// The per-output-channel symmetric grids for `w` under `cfg`.
pub(crate) fn row_grids(w: &Mat, cfg: WeightQuantCfg) -> Vec<AffineParams> {
    (0..w.rows())
        .map(|i| {
            let absmax = cfg.range.resolve_sym(w.row(i), cfg.scheme);
            AffineParams::symmetric(absmax, cfg.scheme)
        })
        .collect()
}

/// RTN: independently round each output channel to its symmetric grid,
/// returning packed integer codes.
pub fn quantize_weights_rtn(w: &Mat, cfg: WeightQuantCfg) -> QuantizedWeights {
    let params = row_grids(w, cfg);
    let ranges = params.iter().map(|p| p.range()).collect();
    let codes = QuantizedTensor::quantize_rows(w, cfg.scheme, &params);
    QuantizedWeights { codes, ranges }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::quant::{QScheme, RangeEstimator};

    fn random_w(out: usize, inp: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(out, inp, |_, _| rng.normal() * 0.05)
    }

    #[test]
    fn rows_quantized_independently() {
        let mut w = random_w(4, 64, 1);
        // Blow up one row; others must be unaffected.
        for v in w.row_mut(2) {
            *v *= 100.0;
        }
        let q = quantize_weights_rtn(&w, WeightQuantCfg::minmax(4));
        assert!(q.scales()[2] > 50.0 * q.scales()[0]);
        // Row 0 error stays at its own scale.
        let deq = q.deq();
        let err0: f64 = w
            .row(0)
            .iter()
            .zip(deq.row(0))
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(err0 <= q.scales()[0] / 2.0 + 1e-12);
    }

    #[test]
    fn error_bounded_at_8_bits() {
        let w = random_w(16, 128, 2);
        let q = quantize_weights_rtn(&w, WeightQuantCfg::minmax(8));
        let rel = w.sub(&q.deq()).fro_norm2() / w.fro_norm2();
        assert!(rel < 1e-4, "rel err {rel}");
    }

    #[test]
    fn lp_range_no_worse_than_minmax_l2() {
        let mut rng = Rng::new(3);
        let mut w = random_w(8, 256, 4);
        // Add outliers to a few rows.
        for i in 0..8 {
            let j = rng.below(256);
            w[(i, j)] = rng.sign() * 2.0;
        }
        let q_mm = quantize_weights_rtn(&w, WeightQuantCfg::minmax(4));
        let q_lp = quantize_weights_rtn(&w, WeightQuantCfg::rtn_default(4));
        let e_mm = w.sub(&q_mm.deq()).fro_norm2();
        let e_lp = w.sub(&q_lp.deq()).fro_norm2();
        // L2.4 optimizes a close proxy of L2; allow small slack.
        assert!(e_lp <= e_mm * 1.05, "lp {e_lp} vs mm {e_mm}");
    }

    #[test]
    fn ranges_are_twice_absmax_for_minmax() {
        let w = Mat::from_vec(1, 4, vec![0.5, -1.5, 1.0, 0.0]);
        let q = quantize_weights_rtn(
            &w,
            WeightQuantCfg { scheme: QScheme::sym(4), range: RangeEstimator::MinMax },
        );
        assert!((q.ranges[0] - 3.0).abs() < 1e-12); // 2 · max|w| = 3
    }
}
