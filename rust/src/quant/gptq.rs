//! GPTQ weight quantization (Frantar et al., 2022).
//!
//! Quantizes each weight column in turn and redistributes the induced
//! error onto the not-yet-quantized columns using the inverse Hessian
//! `H⁻¹` of the layer's least-squares objective, `H = 2 Σ_x + λI`.
//! We implement the Cholesky formulation: iterate over columns in natural
//! order using the upper Cholesky factor of `H⁻¹`, with lazy block
//! updates for cache efficiency.
//!
//! GPTQ is one of the paper's two weight-quantizer settings in Table 1
//! (the other is RTN); the paper's observation that *GPTQ helps rotation
//! baselines but not clip-trained methods* is reproduced in
//! `experiments::table1`.

use super::rtn::row_grids;
use super::{AffineParams, QuantizedTensor, QuantizedWeights, WeightQuantCfg};
use crate::linalg::{par, Cholesky, Mat};

/// GPTQ hyperparameters (defaults follow the reference implementation).
#[derive(Clone, Copy, Debug)]
pub struct GptqConfig {
    /// Relative diagonal damping (`percdamp`).
    pub damp: f64,
    /// Lazy-update block size.
    pub block_size: usize,
}

impl Default for GptqConfig {
    fn default() -> Self {
        GptqConfig { damp: 0.01, block_size: 128 }
    }
}

/// Quantize `w` (`out × in`) given the input autocorrelation
/// `sigma_x = E[xxᵀ]` (`in × in`) collected on calibration data.
pub fn gptq_quantize(
    w: &Mat,
    sigma_x: &Mat,
    cfg: WeightQuantCfg,
    gptq: GptqConfig,
) -> QuantizedWeights {
    let (rows, cols) = (w.rows(), w.cols());
    assert_eq!(sigma_x.rows(), cols, "Σ_x must be in_features × in_features");

    // H = 2 Σ_x (+ damping); dead columns (zero diagonal) get unit diag.
    let mut h = sigma_x.scale(2.0);
    for j in 0..cols {
        if h[(j, j)] <= 0.0 {
            h[(j, j)] = 1.0;
        }
    }
    let (chol, _damp) = Cholesky::new_damped(&h, gptq.damp);
    // Upper factor U with H⁻¹ = Uᵀ U; GPTQ iterates over its rows.
    let hinv_u = chol.inverse_upper_factor();

    // Per-row grids fixed up front from the (clipped) range estimator —
    // same range setting as RTN so the two settings are comparable.
    let params = row_grids(w, cfg);

    // Every output row carries its own grid and its own error flow (the
    // Hessian couples *columns*, not rows), so rows quantize
    // independently — the natural fan-out axis. Error-propagation work is
    // ~cols²/2 FMA per row; below the kernel threshold this stays serial.
    let bs = gptq.block_size.max(1);
    let work_fma = rows.saturating_mul(cols).saturating_mul(cols) / 2;
    let threads = par::threads_for(work_fma, rows);
    let code_rows: Vec<Vec<i32>> = par::par_map((0..rows).collect(), threads, |i| {
        gptq_quantize_row(w.row(i), &params[i], &hinv_u, bs)
    });

    let ranges = params.iter().map(|p| p.range()).collect();
    let codes = QuantizedTensor::from_code_rows(cols, cfg.scheme, &params, &code_rows);
    QuantizedWeights { codes, ranges }
}

/// GPTQ over one weight row: quantize column by column in natural order,
/// propagating error within the active block immediately and onto the
/// remaining columns lazily per block (cache efficiency). Identical
/// arithmetic order to the historical whole-matrix loop, so results are
/// independent of the fan-out. Returns the raw grid codes; the
/// dequantized value `(c − zp)·scale` is used internally for the error
/// flow, so packing loses nothing.
fn gptq_quantize_row(row: &[f64], p: &AffineParams, hinv_u: &Mat, bs: usize) -> Vec<i32> {
    let cols = row.len();
    let mut work = row.to_vec(); // columns get error-compensated in place
    let mut codes = vec![0i32; cols];
    let mut block_err = vec![0.0; bs];
    let mut b0 = 0;
    while b0 < cols {
        let b1 = (b0 + bs).min(cols);
        // In-block: quantize column by column, propagating error within
        // the block immediately.
        for j in b0..b1 {
            let d = hinv_u[(j, j)];
            let v = work[j];
            let c = p.quantize(v);
            let q = (c - p.zero_point) * p.scale; // == p.fake_quant(v)
            codes[j] = c as i32;
            let e = (v - q) / d;
            block_err[j - b0] = e;
            for k in (j + 1)..b1 {
                work[k] -= e * hinv_u[(j, k)];
            }
        }
        // Lazy update of all remaining columns with the accumulated block
        // error: w[b1:] -= e · U[b0:b1, b1:].
        if b1 < cols {
            for j in b0..b1 {
                let e = block_err[j - b0];
                if e == 0.0 {
                    continue;
                }
                for k in b1..cols {
                    work[k] -= e * hinv_u[(j, k)];
                }
            }
        }
        b0 = b1;
    }
    codes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul_a_bt, matmul_at_b, Mat, Rng};
    use crate::quant::{quantize_weights_rtn, WeightQuantCfg};

    /// Layer-output MSE under quantized weights for calibration data X
    /// (tokens × in): ‖XWᵀ − XŴᵀ‖².
    fn output_mse(x: &Mat, w: &Mat, wq: &Mat) -> f64 {
        let y = matmul_a_bt(x, w);
        let yq = matmul_a_bt(x, wq);
        y.sub(&yq).fro_norm2()
    }

    fn calib_data(tokens: usize, dim: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        // Correlated + anisotropic activations (x = z·Aᵀ): the cross-
        // channel Hessian structure is what GPTQ exploits over RTN.
        let a = Mat::from_fn(dim, dim, |i, j| {
            rng.normal() * (8.0_f64).powf(-(((i + j) % dim) as f64) / dim as f64)
        });
        let z = Mat::from_fn(tokens, dim, |_, _| rng.normal());
        crate::linalg::matmul(&z, &a.transpose())
    }

    #[test]
    fn gptq_beats_rtn_on_output_mse() {
        let dim = 64;
        let x = calib_data(256, dim, 1);
        let mut rng = Rng::new(2);
        let w = Mat::from_fn(32, dim, |_, _| rng.normal() * 0.1);
        let sigma = matmul_at_b(&x, &x).scale(1.0 / 256.0);

        let cfg = WeightQuantCfg::minmax(3);
        let rtn = quantize_weights_rtn(&w, cfg);
        let gptq = gptq_quantize(&w, &sigma, cfg, GptqConfig::default());

        let e_rtn = output_mse(&x, &w, &rtn.deq());
        let e_gptq = output_mse(&x, &w, &gptq.deq());
        assert!(
            e_gptq < e_rtn * 0.9,
            "GPTQ ({e_gptq:.4}) should beat RTN ({e_rtn:.4}) by >10%"
        );
    }

    #[test]
    fn gptq_outputs_live_on_row_grids() {
        let dim = 32;
        let x = calib_data(128, dim, 3);
        let mut rng = Rng::new(4);
        let w = Mat::from_fn(8, dim, |_, _| rng.normal());
        let sigma = matmul_at_b(&x, &x).scale(1.0 / 128.0);
        let cfg = WeightQuantCfg::minmax(4);
        let q = gptq_quantize(&w, &sigma, cfg, GptqConfig::default());
        let deq = q.deq();
        for i in 0..8 {
            let s = q.scales()[i];
            for &v in deq.row(i) {
                let code = v / s;
                assert!((code - code.round()).abs() < 1e-9, "off-grid value {v}");
                assert!(code.abs() <= 7.0 + 1e-9);
            }
        }
    }

    #[test]
    fn identity_hessian_reduces_to_rtn() {
        // With H ∝ I there is no cross-column interaction: GPTQ == RTN.
        let mut rng = Rng::new(5);
        let w = Mat::from_fn(6, 16, |_, _| rng.normal());
        let sigma = Mat::eye(16);
        let cfg = WeightQuantCfg::minmax(4);
        let q_gptq = gptq_quantize(&w, &sigma, cfg, GptqConfig::default());
        let q_rtn = quantize_weights_rtn(&w, cfg);
        assert!(q_gptq.deq().max_abs_diff(&q_rtn.deq()) < 1e-9);
    }

    #[test]
    fn block_size_does_not_change_result() {
        let dim = 48;
        let x = calib_data(96, dim, 6);
        let mut rng = Rng::new(7);
        let w = Mat::from_fn(10, dim, |_, _| rng.normal());
        let sigma = matmul_at_b(&x, &x).scale(1.0 / 96.0);
        let cfg = WeightQuantCfg::minmax(4);
        let q1 = gptq_quantize(&w, &sigma, cfg, GptqConfig { damp: 0.01, block_size: 8 });
        let q2 = gptq_quantize(&w, &sigma, cfg, GptqConfig { damp: 0.01, block_size: 48 });
        assert!(q1.deq().max_abs_diff(&q2.deq()) < 1e-9);
    }

    #[test]
    fn handles_rank_deficient_hessian() {
        // Fewer calibration tokens than dims: Σ_x is singular; damping
        // must keep the algorithm stable and still beat RTN.
        let dim = 64;
        let x = calib_data(16, dim, 8);
        let mut rng = Rng::new(9);
        let w = Mat::from_fn(16, dim, |_, _| rng.normal());
        let sigma = matmul_at_b(&x, &x).scale(1.0 / 16.0);
        let cfg = WeightQuantCfg::minmax(3);
        let q = gptq_quantize(&w, &sigma, cfg, GptqConfig::default());
        assert!(q.deq().as_slice().iter().all(|v| v.is_finite()));
        let rtn = quantize_weights_rtn(&w, cfg);
        assert!(output_mse(&x, &w, &q.deq()) <= output_mse(&x, &w, &rtn.deq()) * 1.001);
    }
}
