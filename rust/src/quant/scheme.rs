//! Quantization scheme descriptors.

/// A uniform integer quantization grid.
///
/// `N(b) = 2^b − 1` is the number of quantization *intervals* the paper's
/// bit-width term counts (Lemma 2.2/2.3): asymmetric quantization uses all
/// `2^b` codes (`2^b − 1` intervals); symmetric quantization uses the
/// zero-centered grid `{−(2^{b−1}−1), …, 2^{b−1}−1}`, also `2^b − 1`
/// intervals over the range `2·max|x|`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QScheme {
    /// Bit width `b`.
    pub bits: u32,
    /// Symmetric (zero-centered) vs asymmetric (min/max affine) grid.
    pub symmetric: bool,
}

impl QScheme {
    pub const fn sym(bits: u32) -> Self {
        QScheme { bits, symmetric: true }
    }

    pub const fn asym(bits: u32) -> Self {
        QScheme { bits, symmetric: false }
    }

    /// Number of quantization intervals `N(b) = 2^b − 1`.
    #[inline]
    pub fn n_intervals(&self) -> f64 {
        (1u64 << self.bits) as f64 - 1.0
    }

    /// Largest positive code on the symmetric grid, `2^{b−1} − 1`.
    #[inline]
    pub fn sym_qmax(&self) -> f64 {
        (1u64 << (self.bits - 1)) as f64 - 1.0
    }

    /// Largest code on the asymmetric grid, `2^b − 1`.
    #[inline]
    pub fn asym_qmax(&self) -> f64 {
        (1u64 << self.bits) as f64 - 1.0
    }
}

/// Activation quantization configuration (paper §6: dynamic, per-token,
/// asymmetric).
#[derive(Clone, Copy, Debug)]
pub struct ActQuantCfg {
    pub scheme: QScheme,
    /// Clip ratio applied to the dynamic range (1.0 = pure min/max).
    pub clip_ratio: f64,
}

impl ActQuantCfg {
    pub fn w4a4_default(bits: u32) -> Self {
        ActQuantCfg { scheme: QScheme::asym(bits), clip_ratio: 1.0 }
    }
}

/// Weight quantization configuration (paper §6: per-output-channel,
/// symmetric, `L_{2.4}` range estimation).
#[derive(Clone, Copy, Debug)]
pub struct WeightQuantCfg {
    pub scheme: QScheme,
    pub range: super::RangeEstimator,
}

impl WeightQuantCfg {
    pub fn rtn_default(bits: u32) -> Self {
        WeightQuantCfg { scheme: QScheme::sym(bits), range: super::RangeEstimator::LpNorm { p: 2.4 } }
    }

    pub fn minmax(bits: u32) -> Self {
        WeightQuantCfg { scheme: QScheme::sym(bits), range: super::RangeEstimator::MinMax }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interval_counts() {
        assert_eq!(QScheme::sym(4).n_intervals(), 15.0);
        assert_eq!(QScheme::asym(4).n_intervals(), 15.0);
        assert_eq!(QScheme::sym(8).n_intervals(), 255.0);
        assert_eq!(QScheme::sym(4).sym_qmax(), 7.0);
        assert_eq!(QScheme::asym(4).asym_qmax(), 15.0);
    }
}
