//! Core affine uniform quantizer.

use super::QScheme;
use crate::linalg::Mat;

/// Affine quantization parameters: `q = clamp(round(x/scale) + zp)`,
/// `deq = (q − zp)·scale`.
#[derive(Clone, Copy, Debug)]
pub struct AffineParams {
    pub scale: f64,
    pub zero_point: f64,
    pub qmin: f64,
    pub qmax: f64,
}

impl AffineParams {
    /// Parameters for a symmetric grid covering `[−absmax, absmax]`.
    pub fn symmetric(absmax: f64, scheme: QScheme) -> Self {
        debug_assert!(scheme.symmetric);
        let qmax = scheme.sym_qmax();
        let scale = if absmax > 0.0 { absmax / qmax } else { 1.0 };
        AffineParams { scale, zero_point: 0.0, qmin: -qmax, qmax }
    }

    /// Parameters for an asymmetric grid covering `[lo, hi]`.
    ///
    /// The range is extended to include zero (standard affine convention):
    /// otherwise the rounded zero-point clamps and the grid cannot reach
    /// the data.
    pub fn asymmetric(lo: f64, hi: f64, scheme: QScheme) -> Self {
        debug_assert!(!scheme.symmetric);
        let lo = lo.min(0.0);
        let hi = hi.max(0.0);
        let qmax = scheme.asym_qmax();
        let range = (hi - lo).max(0.0);
        let scale = if range > 0.0 { range / qmax } else { 1.0 };
        // Zero point rounded so that real zero is exactly representable
        // (standard affine quantizer convention).
        let zp = (-lo / scale).round().clamp(0.0, qmax);
        AffineParams { scale, zero_point: zp, qmin: 0.0, qmax }
    }

    /// Quantize one value to its integer code.
    #[inline]
    pub fn quantize(&self, x: f64) -> f64 {
        ((x / self.scale) + self.zero_point).round().clamp(self.qmin, self.qmax)
    }

    /// Fake-quantize one value (quantize then dequantize).
    #[inline]
    pub fn fake_quant(&self, x: f64) -> f64 {
        (self.quantize(x) - self.zero_point) * self.scale
    }

    /// The quantization range `r` this grid covers (the paper's `r(x)`).
    #[inline]
    pub fn range(&self) -> f64 {
        self.scale * (self.qmax - self.qmin)
    }
}

/// Fake-quantize a slice symmetrically with a dynamic abs-max range,
/// shrunk by `clip_ratio`.
pub fn fake_quant_sym(x: &[f64], scheme: QScheme, clip_ratio: f64) -> Vec<f64> {
    let absmax = x.iter().fold(0.0_f64, |m, &v| m.max(v.abs())) * clip_ratio;
    let p = AffineParams::symmetric(absmax, scheme);
    x.iter().map(|&v| p.fake_quant(v)).collect()
}

/// Fake-quantize a slice asymmetrically with a dynamic min/max range,
/// shrunk toward the midpoint by `clip_ratio`.
pub fn fake_quant_asym(x: &[f64], scheme: QScheme, clip_ratio: f64) -> Vec<f64> {
    let (mut lo, mut hi) = minmax(x);
    if clip_ratio < 1.0 {
        let mid = 0.5 * (lo + hi);
        lo = mid + (lo - mid) * clip_ratio;
        hi = mid + (hi - mid) * clip_ratio;
    }
    let p = AffineParams::asymmetric(lo, hi, scheme);
    x.iter().map(|&v| p.fake_quant(v)).collect()
}

/// Dynamic per-token (per-row) asymmetric fake quantization of an
/// activation matrix `tokens × d` — the paper's activation setup.
///
/// Returns the fake-quantized matrix and the per-token quantization range
/// `r(x)` (used by the concentration term `C(x)`).
pub fn quantize_activations_per_token(
    x: &Mat,
    scheme: QScheme,
    clip_ratio: f64,
) -> (Mat, Vec<f64>) {
    let mut out = Mat::zeros(x.rows(), x.cols());
    let mut ranges = Vec::with_capacity(x.rows());
    for t in 0..x.rows() {
        let row = x.row(t);
        let p = per_token_params(row, scheme, clip_ratio);
        ranges.push(p.range());
        let orow = out.row_mut(t);
        for (o, &v) in orow.iter_mut().zip(row) {
            *o = p.fake_quant(v);
        }
    }
    (out, ranges)
}

/// The dynamic grid for one activation row. Shared by the fake-quant path
/// above and the packed-code path ([`crate::quant::QuantizedTensor`]), so
/// both make identical range and rounding decisions — the foundation of
/// the integer/fake-quant parity invariant.
pub(crate) fn per_token_params(row: &[f64], scheme: QScheme, clip_ratio: f64) -> AffineParams {
    if scheme.symmetric {
        let absmax = row.iter().fold(0.0_f64, |m, &v| m.max(v.abs())) * clip_ratio;
        // Paper: r(x) = 2·max|x_i| for symmetric quantization.
        AffineParams::symmetric(absmax, scheme)
    } else {
        let (mut lo, mut hi) = minmax(row);
        if clip_ratio < 1.0 {
            let mid = 0.5 * (lo + hi);
            lo = mid + (lo - mid) * clip_ratio;
            hi = mid + (hi - mid) * clip_ratio;
        }
        AffineParams::asymmetric(lo, hi, scheme)
    }
}

/// *Static* asymmetric activation quantization: one calibrated `[lo, hi]`
/// range for every token (the paper's "static" option in Lemma 2.2, vs
/// the dynamic per-token default). Returns the fake-quantized matrix and
/// the (constant) range.
pub fn quantize_activations_static(
    x: &Mat,
    lo: f64,
    hi: f64,
    scheme: QScheme,
) -> (Mat, f64) {
    let p = if scheme.symmetric {
        AffineParams::symmetric(lo.abs().max(hi.abs()), scheme)
    } else {
        AffineParams::asymmetric(lo, hi, scheme)
    };
    let mut out = Mat::zeros(x.rows(), x.cols());
    for t in 0..x.rows() {
        let row = x.row(t);
        let orow = out.row_mut(t);
        for (o, &v) in orow.iter_mut().zip(row) {
            *o = p.fake_quant(v);
        }
    }
    (out, p.range())
}

/// Symmetric two-sided percentile range over all entries of a calibration
/// sample: `pct = 1.0` is min/max; `pct = 0.999` clips the extreme 0.1%
/// tails (standard static-range calibration).
pub fn percentile_range(x: &Mat, pct: f64) -> (f64, f64) {
    let n = x.as_slice().len();
    if n == 0 {
        return (0.0, 0.0);
    }
    // Two order-statistic selections (O(n) expected) instead of sorting
    // the whole calibration matrix (O(n log n)) on every call.
    let tail = (((1.0 - pct) * n as f64).floor() as usize).min(n - 1);
    let mut vals: Vec<f64> = x.as_slice().to_vec();
    let lo = *vals.select_nth_unstable_by(tail, f64::total_cmp).1;
    let hi = *vals.select_nth_unstable_by(n - 1 - tail, f64::total_cmp).1;
    (lo.min(0.0), hi.max(0.0))
}

#[inline]
pub(crate) fn minmax(x: &[f64]) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &v in x {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if !lo.is_finite() || !hi.is_finite() {
        (0.0, 0.0)
    } else {
        (lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    #[test]
    fn grid_points_are_exact() {
        // Values already on the grid survive fake-quant exactly.
        let s = QScheme::sym(4);
        let p = AffineParams::symmetric(7.0, s); // scale = 1
        for q in -7..=7 {
            assert_eq!(p.fake_quant(q as f64), q as f64);
        }
    }

    #[test]
    fn sym_error_bounded_by_half_scale() {
        let mut rng = Rng::new(1);
        let x: Vec<f64> = (0..1000).map(|_| rng.normal() * 3.0).collect();
        let s = QScheme::sym(6);
        let q = fake_quant_sym(&x, s, 1.0);
        let absmax = x.iter().fold(0.0_f64, |m, &v| m.max(v.abs()));
        let scale = absmax / s.sym_qmax();
        for (a, b) in x.iter().zip(&q) {
            assert!((a - b).abs() <= scale / 2.0 + 1e-12);
        }
    }

    #[test]
    fn asym_error_bounded_by_half_scale_no_clip() {
        let mut rng = Rng::new(2);
        let x: Vec<f64> = (0..1000).map(|_| rng.normal() + 5.0).collect();
        let s = QScheme::asym(6);
        let q = fake_quant_asym(&x, s, 1.0);
        let (lo, hi) = minmax(&x);
        // The quantizer extends the range to include zero.
        let scale = (hi.max(0.0) - lo.min(0.0)) / s.asym_qmax();
        for (a, b) in x.iter().zip(&q) {
            // +scale tolerance: zero-point rounding can shift the grid.
            assert!((a - b).abs() <= scale + 1e-12);
        }
    }

    #[test]
    fn asym_handles_shifted_data_better_than_sym() {
        // Post-ReLU-like data: all positive. Asymmetric halves the range.
        let mut rng = Rng::new(3);
        let x: Vec<f64> = (0..4000).map(|_| rng.normal().abs()).collect();
        let b = QScheme { bits: 4, symmetric: true };
        let qs = fake_quant_sym(&x, b, 1.0);
        let qa = fake_quant_asym(&x, QScheme::asym(4), 1.0);
        let err = |q: &[f64]| -> f64 { x.iter().zip(q).map(|(a, b)| (a - b) * (a - b)).sum() };
        assert!(err(&qa) < err(&qs));
    }

    #[test]
    fn higher_bits_reduce_error() {
        let mut rng = Rng::new(4);
        let x: Vec<f64> = (0..2000).map(|_| rng.laplace(1.0)).collect();
        let mut prev = f64::INFINITY;
        for bits in [2u32, 4, 6, 8] {
            let q = fake_quant_sym(&x, QScheme::sym(bits), 1.0);
            let err: f64 = x.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum();
            assert!(err < prev);
            prev = err;
        }
    }

    #[test]
    fn per_token_ranges_match_paper_definitions() {
        let x = Mat::from_vec(2, 4, vec![1.0, -3.0, 0.5, 2.0, 10.0, 0.0, -1.0, 4.0]);
        // Asymmetric: r = max − min per token.
        let (_, r_asym) =
            quantize_activations_per_token(&x, QScheme::asym(8), 1.0);
        assert!((r_asym[0] - 5.0).abs() < 1e-12);
        assert!((r_asym[1] - 11.0).abs() < 1e-12);
        // Symmetric: r = 2·max|x|.
        let (_, r_sym) = quantize_activations_per_token(&x, QScheme::sym(8), 1.0);
        assert!((r_sym[0] - 6.0).abs() < 1e-9);
        assert!((r_sym[1] - 20.0).abs() < 1e-9);
    }

    #[test]
    fn constant_zero_row_is_noop() {
        let x = Mat::zeros(1, 8);
        let (q, _) = quantize_activations_per_token(&x, QScheme::asym(4), 1.0);
        assert_eq!(q.as_slice(), x.as_slice());
    }

    #[test]
    fn static_quant_uses_fixed_range() {
        let x = Mat::from_vec(2, 3, vec![0.1, 0.5, -0.2, 5.0, -3.0, 0.0]);
        let (q, r) = quantize_activations_static(&x, -1.0, 1.0, QScheme::asym(8));
        assert!((r - 2.0).abs() < 1e-9);
        // Values outside the static range clip to it (±½ grid step from
        // zero-point rounding).
        let step = 2.0 / 255.0;
        assert!(q[(1, 0)] <= 1.0 + step);
        assert!(q[(1, 1)] >= -1.0 - step);
        // In-range values quantize with small error.
        assert!((q[(0, 1)] - 0.5).abs() < 0.01);
    }

    #[test]
    fn percentile_range_clips_tails() {
        let mut rng = Rng::new(7);
        let mut x = Mat::from_fn(64, 64, |_, _| rng.normal());
        x[(0, 0)] = 1000.0;
        let (_, hi_mm) = percentile_range(&x, 1.0);
        let (_, hi_99) = percentile_range(&x, 0.999);
        assert!(hi_mm >= 1000.0);
        assert!(hi_99 < 100.0, "0.999 percentile should drop the outlier: {hi_99}");
    }

    #[test]
    fn dynamic_beats_static_on_scale_varying_tokens() {
        // Tokens with wildly different scales: per-token (dynamic) ranges
        // must win — the reason the paper's setup quantizes dynamically.
        let mut rng = Rng::new(8);
        let x = Mat::from_fn(64, 32, |t, _| rng.normal() * (1.0 + t as f64));
        let s = QScheme::asym(4);
        let (qd, _) = quantize_activations_per_token(&x, s, 1.0);
        let (lo, hi) = percentile_range(&x, 1.0);
        let (qs, _) = quantize_activations_static(&x, lo, hi, s);
        let ed = x.sub(&qd).fro_norm2();
        let es = x.sub(&qs).fro_norm2();
        assert!(ed < es * 0.5, "dynamic {ed} vs static {es}");
    }

    #[test]
    fn idempotent_fake_quant() {
        let mut rng = Rng::new(5);
        let x: Vec<f64> = (0..256).map(|_| rng.normal()).collect();
        let q1 = fake_quant_sym(&x, QScheme::sym(4), 1.0);
        let q2 = fake_quant_sym(&q1, QScheme::sym(4), 1.0);
        for (a, b) in q1.iter().zip(&q2) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
