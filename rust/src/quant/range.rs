//! Quantization range estimation.
//!
//! The paper follows GPTQ and uses `L_{2.4}` range estimation for weights:
//! pick the clip ratio whose induced quantization error minimizes
//! `Σ|w − Q(w)|^p` with `p = 2.4`. We implement this as a golden-grid
//! search over clip ratios — the same "learnable weight clipping" machinery
//! the CAT-trained variant reuses with an SQNR objective.

use super::{AffineParams, QScheme};

/// How to set the quantization range of a weight row.
#[derive(Clone, Copy, Debug)]
pub enum RangeEstimator {
    /// Plain abs-max.
    MinMax,
    /// Minimize `Σ|w − Q(w)|^p` over a clip-ratio grid (GPTQ's `L_{2.4}`).
    LpNorm { p: f64 },
    /// Fixed clip ratio of the abs-max.
    FixedClip { ratio: f64 },
}

impl RangeEstimator {
    /// Resolve the symmetric range (`absmax` after clipping) for a row.
    pub fn resolve_sym(&self, w: &[f64], scheme: QScheme) -> f64 {
        let absmax = w.iter().fold(0.0_f64, |m, &v| m.max(v.abs()));
        match *self {
            RangeEstimator::MinMax => absmax,
            RangeEstimator::FixedClip { ratio } => absmax * ratio,
            RangeEstimator::LpNorm { p } => lp_optimal_clip_sym(w, scheme, p) * absmax,
        }
    }
}

/// Grid-search the clip ratio minimizing the `L_p` quantization error of a
/// symmetric quantizer. Returns the best ratio in `(0, 1]`.
pub fn lp_optimal_clip_sym(w: &[f64], scheme: QScheme, p: f64) -> f64 {
    let absmax = w.iter().fold(0.0_f64, |m, &v| m.max(v.abs()));
    if absmax == 0.0 {
        return 1.0;
    }
    let mut best_ratio = 1.0;
    let mut best_err = f64::INFINITY;
    // 50-point grid from 0.40 to 1.00 — matches common LWC search spans.
    const STEPS: usize = 50;
    for s in 0..=STEPS {
        let ratio = 0.40 + 0.60 * (s as f64 / STEPS as f64);
        let params = AffineParams::symmetric(absmax * ratio, scheme);
        let mut err = 0.0;
        for &v in w {
            err += (v - params.fake_quant(v)).abs().powf(p);
        }
        if err < best_err {
            best_err = err;
            best_ratio = ratio;
        }
    }
    best_ratio
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    fn l2_err(w: &[f64], absmax: f64, scheme: QScheme) -> f64 {
        let p = AffineParams::symmetric(absmax, scheme);
        w.iter().map(|&v| (v - p.fake_quant(v)).powi(2)).sum()
    }

    #[test]
    fn lp_clip_beats_minmax_on_outlier_data() {
        // Heavy-tailed weights: the grid-searched clip must be no worse
        // than min-max (ratio 1.0) and no worse than an arbitrary fixed
        // clip, in the L_p objective it optimizes.
        let mut rng = Rng::new(1);
        let mut w: Vec<f64> = (0..512).map(|_| rng.student_t(2)).collect();
        w[100] = 40.0;
        let scheme = QScheme::sym(4);
        let absmax = w.iter().fold(0.0_f64, |m, &v| m.max(v.abs()));
        let lp_err = |ratio: f64| -> f64 {
            let p = AffineParams::symmetric(absmax * ratio, scheme);
            w.iter().map(|&v| (v - p.fake_quant(v)).abs().powf(2.4)).sum()
        };
        let ratio = lp_optimal_clip_sym(&w, scheme, 2.4);
        assert!(ratio < 1.0, "heavy tails should induce some clipping, got {ratio}");
        assert!(lp_err(ratio) <= lp_err(1.0));
        assert!(lp_err(ratio) <= lp_err(0.7));
        // And the induced L2 error also improves over pure min-max.
        assert!(l2_err(&w, absmax * ratio, scheme) <= l2_err(&w, absmax, scheme));
    }

    #[test]
    fn lp_clip_near_one_for_uniform_data() {
        // No outliers: best clip should stay close to the full range.
        let mut rng = Rng::new(2);
        let w: Vec<f64> = (0..512).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        let ratio = lp_optimal_clip_sym(&w, QScheme::sym(4), 2.4);
        assert!(ratio > 0.85, "got {ratio}");
    }

    #[test]
    fn resolve_variants() {
        let w = [1.0, -2.0, 0.5];
        let s = QScheme::sym(8);
        assert_eq!(RangeEstimator::MinMax.resolve_sym(&w, s), 2.0);
        assert_eq!(RangeEstimator::FixedClip { ratio: 0.5 }.resolve_sym(&w, s), 1.0);
        let lp = RangeEstimator::LpNorm { p: 2.4 }.resolve_sym(&w, s);
        assert!(lp > 0.0 && lp <= 2.0);
    }

    #[test]
    fn zero_row_is_safe() {
        let w = [0.0; 16];
        assert_eq!(lp_optimal_clip_sym(&w, QScheme::sym(4), 2.4), 1.0);
    }
}
