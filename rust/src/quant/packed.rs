//! Packed integer tensor storage — true low-bit representation.
//!
//! A [`QuantizedTensor`] holds the *integer codes* of a row-quantized
//! matrix instead of dequantized f64 values: nibble-packed `u8` for bit
//! widths ≤ 4, one `i8` per code up to 8 bits, and raw `i32` codes above
//! that (analysis-only bit widths). Each row carries its affine grid
//! (scale, zero point) plus the precomputed code sum the integer kernel's
//! affine correction needs.
//!
//! A W4 weight therefore occupies ~1/16 of its f64 footprint, and the
//! serving path multiplies codes directly via
//! [`qmatmul_a_bt`](crate::linalg::qmatmul_a_bt) — no dequantized weight
//! matrices are materialized outside [`Self::deq`] (parity tests, the
//! PJRT `ArgPack`, SQNR analysis).
//!
//! Codes may be stored *biased* so they fit the physical container (e.g.
//! symmetric 4-bit codes −7..=7 are shifted to 0..=14 for nibble packing);
//! the per-row zero point is biased identically, so
//! `value = (stored − zp)·scale` holds verbatim and [`Self::deq`] is
//! bit-identical to the historical fake-quant output.

use super::uniform::per_token_params;
use super::{AffineParams, QScheme};
use crate::linalg::{Mat, QCodes, QMatView, QPanels};

/// Packed integer codes + per-row affine grids for one matrix.
#[derive(Clone)]
pub struct QuantizedTensor {
    rows: usize,
    cols: usize,
    scheme: QScheme,
    store: Store,
    /// Per-row scale.
    scales: Vec<f64>,
    /// Per-row zero point in stored-code space (integral).
    zps: Vec<i32>,
    /// Per-row sum of stored codes.
    row_sums: Vec<i64>,
}

#[derive(Clone)]
enum Store {
    /// Two codes per byte, low nibble = even column; row stride
    /// `cols.div_ceil(2)`.
    Nibble(Vec<u8>),
    /// One centered code per byte.
    Byte(Vec<i8>),
    /// Raw codes (bit widths above 8).
    Wide(Vec<i32>),
}

impl Store {
    fn new(scheme: QScheme, rows: usize, cols: usize) -> Store {
        if scheme.bits <= 4 {
            Store::Nibble(vec![0u8; rows * cols.div_ceil(2)])
        } else if scheme.bits <= 8 {
            Store::Byte(vec![0i8; rows * cols])
        } else {
            Store::Wide(vec![0i32; rows * cols])
        }
    }

    /// Append storage for one more row of `cols` codes (zero-filled, so
    /// nibble tail bytes stay clean), returning the new row index.
    fn grow_row(&mut self, cols: usize) -> usize {
        match self {
            Store::Nibble(d) => {
                let stride = cols.div_ceil(2);
                let i = d.len() / stride;
                d.resize(d.len() + stride, 0);
                i
            }
            Store::Byte(d) => {
                let i = d.len() / cols;
                d.resize(d.len() + cols, 0);
                i
            }
            Store::Wide(d) => {
                let i = d.len() / cols;
                d.resize(d.len() + cols, 0);
                i
            }
        }
    }

    fn pack_row(&mut self, i: usize, codes: &[i32]) {
        let cols = codes.len();
        match self {
            Store::Nibble(data) => {
                let stride = cols.div_ceil(2);
                let row = &mut data[i * stride..(i + 1) * stride];
                for (j, &c) in codes.iter().enumerate() {
                    debug_assert!((0..16).contains(&c), "nibble code {c} out of range");
                    let nib = (c as u8) & 0x0F;
                    if j % 2 == 0 {
                        row[j / 2] = nib;
                    } else {
                        row[j / 2] |= nib << 4;
                    }
                }
            }
            Store::Byte(data) => {
                let row = &mut data[i * cols..(i + 1) * cols];
                for (o, &c) in row.iter_mut().zip(codes) {
                    debug_assert!((-128..128).contains(&c), "byte code {c} out of range");
                    *o = c as i8;
                }
            }
            Store::Wide(data) => {
                data[i * cols..(i + 1) * cols].copy_from_slice(codes);
            }
        }
    }

    fn code_bytes(&self) -> usize {
        match self {
            Store::Nibble(d) => d.len(),
            Store::Byte(d) => d.len(),
            Store::Wide(d) => d.len() * std::mem::size_of::<i32>(),
        }
    }
}

/// Offset subtracted from raw grid codes before storage, chosen so the
/// stored codes fit the physical container. The zero point is biased by
/// the same amount, keeping `value = (stored − zp)·scale` exact.
fn storage_bias(scheme: QScheme) -> i32 {
    if scheme.bits <= 4 {
        // Nibble storage is unsigned: bias by qmin so codes land in 0..=15.
        if scheme.symmetric {
            -(scheme.sym_qmax() as i32)
        } else {
            0
        }
    } else if scheme.bits <= 8 {
        // i8 storage: symmetric codes (|q| ≤ 127) already fit; asymmetric
        // codes (0..=2^b−1) are centered by 2^{b−1}.
        if scheme.symmetric {
            0
        } else {
            1 << (scheme.bits - 1)
        }
    } else {
        0
    }
}

impl QuantizedTensor {
    /// Quantize each row of `m` on its grid `params[i]` and pack the codes.
    pub fn quantize_rows(m: &Mat, scheme: QScheme, params: &[AffineParams]) -> QuantizedTensor {
        assert_eq!(params.len(), m.rows(), "one grid per row");
        Self::build(m.rows(), m.cols(), scheme, params, |i, buf| {
            let p = &params[i];
            for (o, &v) in buf.iter_mut().zip(m.row(i)) {
                *o = p.quantize(v) as i32;
            }
        })
    }

    /// Pack pre-computed raw grid codes (one `Vec` per row, as produced
    /// by GPTQ's column sweep).
    pub fn from_code_rows(
        cols: usize,
        scheme: QScheme,
        params: &[AffineParams],
        code_rows: &[Vec<i32>],
    ) -> QuantizedTensor {
        assert_eq!(params.len(), code_rows.len(), "one grid per row");
        Self::build(code_rows.len(), cols, scheme, params, |i, buf| {
            buf.copy_from_slice(&code_rows[i])
        })
    }

    /// Dynamic per-token quantization straight to packed codes, using the
    /// exact same grids as
    /// [`quantize_activations_per_token`](super::quantize_activations_per_token)
    /// so the packed and fake-quant paths share every rounding decision.
    pub fn quantize_acts(x: &Mat, scheme: QScheme, clip_ratio: f64) -> QuantizedTensor {
        let params: Vec<AffineParams> = (0..x.rows())
            .map(|t| per_token_params(x.row(t), scheme, clip_ratio))
            .collect();
        Self::quantize_rows(x, scheme, &params)
    }

    fn build(
        rows: usize,
        cols: usize,
        scheme: QScheme,
        params: &[AffineParams],
        fill: impl Fn(usize, &mut [i32]),
    ) -> QuantizedTensor {
        debug_assert!(scheme.bits <= 24, "codes must fit i32 with margin");
        let bias = storage_bias(scheme);
        let mut store = Store::new(scheme, rows, cols);
        let mut scales = Vec::with_capacity(rows);
        let mut zps = Vec::with_capacity(rows);
        let mut row_sums = Vec::with_capacity(rows);
        let mut raw = vec![0i32; cols];
        for i in 0..rows {
            fill(i, &mut raw);
            let mut sum = 0i64;
            for v in raw.iter_mut() {
                *v -= bias;
                sum += *v as i64;
            }
            store.pack_row(i, &raw);
            let p = &params[i];
            scales.push(p.scale);
            zps.push(p.zero_point as i32 - bias);
            row_sums.push(sum);
        }
        QuantizedTensor { rows, cols, scheme, store, scales, zps, row_sums }
    }

    /// An empty row-growable tensor (the KV-cache decode path appends one
    /// packed token row per [`Self::push_row`]).
    pub fn empty(cols: usize, scheme: QScheme) -> QuantizedTensor {
        debug_assert!(scheme.bits <= 24, "codes must fit i32 with margin");
        QuantizedTensor {
            rows: 0,
            cols,
            scheme,
            store: Store::new(scheme, 0, cols),
            scales: Vec::new(),
            zps: Vec::new(),
            row_sums: Vec::new(),
        }
    }

    /// [`Self::empty`] with storage reserved for `rows_cap` rows — the
    /// KV cache pre-sizes to the model's positional budget so decode
    /// pushes never reallocate mid-generation.
    pub fn empty_with_capacity(cols: usize, scheme: QScheme, rows_cap: usize) -> QuantizedTensor {
        let mut t = Self::empty(cols, scheme);
        match &mut t.store {
            Store::Nibble(d) => d.reserve(rows_cap * cols.div_ceil(2)),
            Store::Byte(d) => d.reserve(rows_cap * cols),
            Store::Wide(d) => d.reserve(rows_cap * cols),
        }
        t.scales.reserve(rows_cap);
        t.zps.reserve(rows_cap);
        t.row_sums.reserve(rows_cap);
        t
    }

    /// Unpack the codes once into the kernel's persistent panel layout
    /// (see [`crate::linalg::qmatmul_a_bt_panels`]). Static operands
    /// (weights) build this at load time and skip every per-call unpack.
    pub fn panels(&self) -> QPanels {
        QPanels::from_view(&self.view())
    }

    /// Quantize one activation row on its dynamic per-token grid (the
    /// exact grid [`Self::quantize_acts`] would pick for this row) and
    /// append the packed codes. Row-local: existing rows are untouched,
    /// which is what makes cached decode codes stable as a sequence grows.
    pub fn push_row(&mut self, row: &[f64], clip_ratio: f64) {
        assert_eq!(row.len(), self.cols, "push_row width mismatch");
        let p = per_token_params(row, self.scheme, clip_ratio);
        let bias = storage_bias(self.scheme);
        let mut raw = vec![0i32; self.cols];
        let mut sum = 0i64;
        for (o, &v) in raw.iter_mut().zip(row) {
            *o = p.quantize(v) as i32 - bias;
            sum += *o as i64;
        }
        let i = self.store.grow_row(self.cols);
        debug_assert_eq!(i, self.rows);
        self.store.pack_row(i, &raw);
        self.scales.push(p.scale);
        self.zps.push(p.zero_point as i32 - bias);
        self.row_sums.push(sum);
        self.rows += 1;
    }

    /// Dequantize row `i` into `out` — same per-element math as
    /// [`Self::deq`], so the result is bit-identical to the fake-quant
    /// value of the original row.
    pub fn deq_row_into(&self, i: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.cols);
        let (s, z) = (self.scales[i], self.zps[i]);
        match &self.store {
            Store::Nibble(data) => {
                let stride = self.cols.div_ceil(2);
                let rowb = &data[i * stride..(i + 1) * stride];
                for (j, o) in out.iter_mut().enumerate() {
                    let b = rowb[j / 2];
                    let c = if j % 2 == 0 { (b & 0x0F) as i32 } else { (b >> 4) as i32 };
                    *o = (c - z) as f64 * s;
                }
            }
            Store::Byte(data) => {
                let rowb = &data[i * self.cols..(i + 1) * self.cols];
                for (o, &c) in out.iter_mut().zip(rowb) {
                    *o = (c as i32 - z) as f64 * s;
                }
            }
            Store::Wide(data) => {
                let rowb = &data[i * self.cols..(i + 1) * self.cols];
                for (o, &c) in out.iter_mut().zip(rowb) {
                    *o = (c - z) as f64 * s;
                }
            }
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn scheme(&self) -> QScheme {
        self.scheme
    }

    /// Per-row scales.
    pub fn scales(&self) -> &[f64] {
        &self.scales
    }

    /// Borrowed kernel view ([`crate::linalg::qmatmul_a_bt`] input).
    pub fn view(&self) -> QMatView<'_> {
        QMatView {
            rows: self.rows,
            cols: self.cols,
            codes: match &self.store {
                Store::Nibble(d) => QCodes::Nibble(d),
                Store::Byte(d) => QCodes::Byte(d),
                Store::Wide(d) => QCodes::Wide(d),
            },
            scales: &self.scales,
            zps: &self.zps,
            row_sums: &self.row_sums,
        }
    }

    /// Reconstruct the dequantized f64 matrix. Bit-identical to the
    /// historical fake-quant output: both compute `(q − zp)·scale` with
    /// one f64 rounding per element.
    pub fn deq(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        let view = self.view();
        let mut raw = vec![0i32; self.cols];
        for i in 0..self.rows {
            view.unpack_row_i32(i, &mut raw);
            let (s, z) = (self.scales[i], self.zps[i]);
            let orow = out.row_mut(i);
            for (o, &c) in orow.iter_mut().zip(&raw) {
                *o = (c - z) as f64 * s;
            }
        }
        out
    }

    /// Bytes held by the packed codes plus per-row metadata
    /// (scale f64 + zero point i32 + code sum i64).
    pub fn packed_bytes(&self) -> usize {
        self.store.code_bytes() + self.rows * (8 + 4 + 8)
    }

    /// Serialized length of [`Self::code_bytes_le`] for a `rows × cols`
    /// tensor on `scheme` — what the artifact loader validates blob
    /// slices against.
    pub fn code_bytes_len(rows: usize, cols: usize, scheme: QScheme) -> usize {
        if scheme.bits <= 4 {
            rows * cols.div_ceil(2)
        } else if scheme.bits <= 8 {
            rows * cols
        } else {
            rows * cols * std::mem::size_of::<i32>()
        }
    }

    /// The packed code store as little-endian bytes (the artifact blob
    /// payload). Nibble and byte stores serialize as-is; wide codes as
    /// i32 LE. Round-trips bit-exactly through [`Self::from_parts`].
    pub fn code_bytes_le(&self) -> Vec<u8> {
        match &self.store {
            Store::Nibble(d) => d.clone(),
            Store::Byte(d) => d.iter().map(|&v| v as u8).collect(),
            Store::Wide(d) => {
                let mut out = Vec::with_capacity(d.len() * 4);
                for &v in d {
                    out.extend_from_slice(&v.to_le_bytes());
                }
                out
            }
        }
    }

    /// Rebuild a tensor from serialized parts (the artifact loader).
    /// Validates every length; blob *integrity* (bit flips) is the
    /// caller's checksum's job.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        scheme: QScheme,
        code_bytes: &[u8],
        scales: Vec<f64>,
        zps: Vec<i32>,
        row_sums: Vec<i64>,
    ) -> anyhow::Result<QuantizedTensor> {
        anyhow::ensure!(
            (1..=24).contains(&scheme.bits),
            "unsupported bit width {}",
            scheme.bits
        );
        anyhow::ensure!(
            scales.len() == rows && zps.len() == rows && row_sums.len() == rows,
            "per-row metadata length mismatch: rows {rows} vs scales {} zps {} sums {}",
            scales.len(),
            zps.len(),
            row_sums.len()
        );
        let want = Self::code_bytes_len(rows, cols, scheme);
        anyhow::ensure!(
            code_bytes.len() == want,
            "code byte length mismatch: {} vs expected {want} ({rows}x{cols} @ {} bits)",
            code_bytes.len(),
            scheme.bits
        );
        let store = if scheme.bits <= 4 {
            Store::Nibble(code_bytes.to_vec())
        } else if scheme.bits <= 8 {
            Store::Byte(code_bytes.iter().map(|&b| b as i8).collect())
        } else {
            Store::Wide(
                code_bytes
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            )
        };
        Ok(QuantizedTensor { rows, cols, scheme, store, scales, zps, row_sums })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::quant::quantize_activations_per_token;

    fn random(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(rows, cols, |_, _| rng.normal())
    }

    #[test]
    fn deq_matches_fake_quant_exactly_across_stores() {
        // bits 4 → Nibble, 8 → Byte, 12 → Wide; sym and asym; odd cols.
        for bits in [2u32, 4, 8, 12] {
            for sym in [true, false] {
                let scheme =
                    if sym { QScheme::sym(bits) } else { QScheme::asym(bits) };
                let x = random(7, 33, 100 + bits as u64 + sym as u64);
                let (fq, _) = quantize_activations_per_token(&x, scheme, 1.0);
                let packed = QuantizedTensor::quantize_acts(&x, scheme, 1.0);
                assert_eq!(
                    packed.deq().max_abs_diff(&fq),
                    0.0,
                    "bits {bits} sym {sym}"
                );
            }
        }
    }

    #[test]
    fn nibble_storage_is_half_a_byte_per_code() {
        let x = random(16, 64, 3);
        let p4 = QuantizedTensor::quantize_acts(&x, QScheme::asym(4), 1.0);
        let p8 = QuantizedTensor::quantize_acts(&x, QScheme::asym(8), 1.0);
        let meta = 16 * (8 + 4 + 8);
        assert_eq!(p4.packed_bytes(), 16 * 32 + meta);
        assert_eq!(p8.packed_bytes(), 16 * 64 + meta);
    }

    #[test]
    fn odd_column_rows_pack_and_unpack() {
        let x = random(3, 5, 4);
        let p = QuantizedTensor::quantize_acts(&x, QScheme::asym(4), 1.0);
        let (fq, _) = quantize_activations_per_token(&x, QScheme::asym(4), 1.0);
        assert_eq!(p.deq().max_abs_diff(&fq), 0.0);
        let mut raw = vec![0i32; 5];
        let v = p.view();
        for i in 0..3 {
            v.unpack_row_i32(i, &mut raw);
            for &c in &raw {
                assert!((0..16).contains(&c));
            }
        }
    }

    #[test]
    fn pushed_rows_match_bulk_quantization() {
        // Growing row by row must reproduce the bulk quantizer exactly:
        // same grids, same stored codes, same metadata — across stores,
        // schemes, and odd widths (nibble tail bytes).
        for bits in [4u32, 8, 12] {
            for sym in [true, false] {
                let scheme = if sym { QScheme::sym(bits) } else { QScheme::asym(bits) };
                let x = random(6, 33, 42 + bits as u64 + sym as u64);
                let bulk = QuantizedTensor::quantize_acts(&x, scheme, 1.0);
                let mut grown = QuantizedTensor::empty(33, scheme);
                for t in 0..x.rows() {
                    grown.push_row(x.row(t), 1.0);
                }
                assert_eq!(grown.rows(), 6);
                assert_eq!(grown.deq().max_abs_diff(&bulk.deq()), 0.0, "bits {bits} sym {sym}");
                let (gv, bv) = (grown.view(), bulk.view());
                assert_eq!(gv.row_sums, bv.row_sums);
                assert_eq!(gv.zps, bv.zps);
            }
        }
    }

    #[test]
    fn deq_row_into_matches_full_deq() {
        // Every store type under both biased and unbiased grids: the
        // hand-rolled row decoder (kept allocation-free for the decode
        // hot loop) must track `deq` — which routes through the kernel's
        // unpack — exactly.
        let x = random(5, 17, 7);
        for scheme in [
            QScheme::asym(4),
            QScheme::sym(4),
            QScheme::asym(8),
            QScheme::sym(8),
            QScheme::asym(12),
        ] {
            let p = QuantizedTensor::quantize_acts(&x, scheme, 1.0);
            let full = p.deq();
            let mut buf = vec![0.0; 17];
            for i in 0..5 {
                p.deq_row_into(i, &mut buf);
                assert_eq!(buf, full.row(i), "row {i}");
            }
        }
    }

    #[test]
    fn serialized_parts_roundtrip_every_store() {
        // Nibble (4), Byte (8), Wide (12), sym and asym, odd widths.
        for bits in [2u32, 4, 8, 12] {
            for sym in [true, false] {
                let scheme = if sym { QScheme::sym(bits) } else { QScheme::asym(bits) };
                let x = random(6, 19, 500 + bits as u64 + sym as u64);
                let t = QuantizedTensor::quantize_acts(&x, scheme, 1.0);
                let bytes = t.code_bytes_le();
                assert_eq!(bytes.len(), QuantizedTensor::code_bytes_len(6, 19, scheme));
                let v = t.view();
                let back = QuantizedTensor::from_parts(
                    6,
                    19,
                    scheme,
                    &bytes,
                    t.scales().to_vec(),
                    v.zps.to_vec(),
                    v.row_sums.to_vec(),
                )
                .unwrap();
                assert_eq!(back.deq().max_abs_diff(&t.deq()), 0.0, "bits {bits} sym {sym}");
                assert_eq!(back.view().row_sums, t.view().row_sums);
            }
        }
    }

    #[test]
    fn from_parts_rejects_bad_lengths() {
        let x = random(4, 8, 77);
        let t = QuantizedTensor::quantize_acts(&x, QScheme::asym(4), 1.0);
        let bytes = t.code_bytes_le();
        let v = t.view();
        // Truncated codes.
        assert!(QuantizedTensor::from_parts(
            4,
            8,
            QScheme::asym(4),
            &bytes[..bytes.len() - 1],
            t.scales().to_vec(),
            v.zps.to_vec(),
            v.row_sums.to_vec(),
        )
        .is_err());
        // Short metadata.
        assert!(QuantizedTensor::from_parts(
            4,
            8,
            QScheme::asym(4),
            &bytes,
            t.scales()[..3].to_vec(),
            v.zps.to_vec(),
            v.row_sums.to_vec(),
        )
        .is_err());
    }

    #[test]
    fn row_sums_match_unpacked_codes() {
        let x = random(9, 17, 5);
        let p = QuantizedTensor::quantize_acts(&x, QScheme::sym(4), 1.0);
        let v = p.view();
        let mut raw = vec![0i32; 17];
        for i in 0..9 {
            v.unpack_row_i32(i, &mut raw);
            let sum: i64 = raw.iter().map(|&c| c as i64).sum();
            assert_eq!(sum, v.row_sums[i], "row {i}");
        }
    }
}
