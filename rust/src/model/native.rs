//! Pure-Rust transformer forward — the calibration/analysis engine and
//! the reference the PJRT parity tests trust.
//!
//! Mirrors `python/compile/model.py` op for op (RMSNorm ε, SiLU, causal
//! mask value, per-token KV fake-quant) so logits agree with the AOT
//! graphs to f32 precision.

use super::{ModelConfig, QuantConfig};
use crate::linalg::{matmul_a_bt, par, qmatmul_a_bt, Mat};
use crate::quant::{quantize_activations_per_token, QuantizedTensor};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;

const EPS: f64 = 1e-5;
const MASK_VALUE: f64 = -1e30;

/// Per-group activation capture for calibration (one entry per block).
#[derive(Default)]
pub struct ProbeCapture {
    pub attn_in: Vec<Vec<Mat>>,
    pub o_in: Vec<Vec<Mat>>,
    pub mlp_in: Vec<Vec<Mat>>,
    pub down_in: Vec<Vec<Mat>>,
}

impl ProbeCapture {
    pub fn new(n_layers: usize) -> Self {
        ProbeCapture {
            attn_in: vec![Vec::new(); n_layers],
            o_in: vec![Vec::new(); n_layers],
            mlp_in: vec![Vec::new(); n_layers],
            down_in: vec![Vec::new(); n_layers],
        }
    }

    /// Concatenate the captured row blocks of one group/block into a
    /// single `tokens × dim` matrix.
    pub fn concat(parts: &[Mat]) -> Mat {
        assert!(!parts.is_empty());
        let cols = parts[0].cols();
        let rows: usize = parts.iter().map(|m| m.rows()).sum();
        let mut out = Mat::zeros(rows, cols);
        let mut r = 0;
        for p in parts {
            out.set_block(r, 0, p);
            r += p.rows();
        }
        out
    }
}

/// The native model: config + f64 parameter matrices.
pub struct NativeModel {
    pub cfg: ModelConfig,
    pub params: HashMap<String, Mat>,
}

impl NativeModel {
    /// Load from a `.catw` artifact, validating shapes against the spec.
    pub fn from_catw(cfg: ModelConfig, path: &std::path::Path) -> Result<Self> {
        let tensors = super::load_catw(path)?;
        let mut params = HashMap::new();
        for (name, shape) in cfg.param_spec() {
            let t = tensors
                .get(&name)
                .with_context(|| format!("missing tensor {name} in {}", path.display()))?;
            if t.shape != shape && !(shape.len() == 1 && t.shape == vec![shape[0]]) {
                bail!("tensor {name}: shape {:?} != spec {:?}", t.shape, shape);
            }
            params.insert(name, t.to_mat());
        }
        Ok(NativeModel { cfg, params })
    }

    /// Random-initialized model (tests, benches).
    pub fn init_random(cfg: ModelConfig, seed: u64) -> Self {
        let mut rng = crate::linalg::Rng::new(seed);
        let mut params = HashMap::new();
        for (name, shape) in cfg.param_spec() {
            let m = if name.contains("ln") {
                Mat::from_fn(1, shape[0], |_, _| 1.0)
            } else if shape.len() == 1 {
                Mat::from_fn(1, shape[0], |_, _| rng.normal() * 0.02)
            } else {
                let fan_in = shape[1] as f64;
                Mat::from_fn(shape[0], shape[1], |_, _| rng.normal() / fan_in.sqrt())
            };
            params.insert(name, m);
        }
        NativeModel { cfg, params }
    }

    fn p(&self, name: &str) -> &Mat {
        self.params.get(name).unwrap_or_else(|| panic!("missing param {name}"))
    }

    /// Full-sequence FP forward: logits `[S, vocab]` for one sequence.
    pub fn forward(&self, tokens: &[u8]) -> Mat {
        self.forward_opts(tokens, None, None, None)
    }

    /// FP forward capturing per-group linear inputs into `probe`.
    pub fn forward_probed(&self, tokens: &[u8], probe: &mut ProbeCapture) -> Mat {
        self.forward_opts(tokens, None, None, Some(probe))
    }

    /// Quantized forward: transforms, then per-token activation codes ×
    /// packed weight codes through the integer kernel (no dequantized f64
    /// weight matrices touched).
    pub fn forward_quant(&self, tokens: &[u8], qc: &QuantConfig) -> Mat {
        self.forward_opts(tokens, Some(qc), None, None)
    }

    /// Reference fake-quant forward over pre-dequantized f64 weights
    /// (`qc.deq_weights()`): the parity baseline the packed path must
    /// match to fp rounding, and the dense side of the bench A/B.
    pub fn forward_quant_dense(
        &self,
        tokens: &[u8],
        qc: &QuantConfig,
        weights: &HashMap<String, Mat>,
    ) -> Mat {
        self.forward_opts(tokens, Some(qc), Some(weights), None)
    }

    fn forward_opts(
        &self,
        tokens: &[u8],
        qc: Option<&QuantConfig>,
        dense: Option<&HashMap<String, Mat>>,
        mut probe: Option<&mut ProbeCapture>,
    ) -> Mat {
        let cfg = &self.cfg;
        let s = tokens.len();
        assert!(s <= cfg.seq, "sequence too long");
        let tok_emb = self.p("tok_emb");
        let pos_emb = self.p("pos_emb");
        let mut x = Mat::zeros(s, cfg.d);
        for (t, &tok) in tokens.iter().enumerate() {
            for j in 0..cfg.d {
                x[(t, j)] = tok_emb[(tok as usize, j)] + pos_emb[(t, j)];
            }
        }
        for i in 0..cfg.n_layers {
            let pfx = format!("blocks.{i}.");
            let h = rmsnorm(&x, self.p(&format!("{pfx}ln1")));
            if let Some(pr) = probe.as_deref_mut() {
                pr.attn_in[i].push(h.clone());
            }
            let mut qkv = self
                .linear_group(&h, &pfx, &["q_proj", "k_proj", "v_proj"], "t_attn", qc, dense)
                .into_iter();
            let q = qkv.next().unwrap();
            let mut k = qkv.next().unwrap();
            let mut v = qkv.next().unwrap();
            if let Some(qc) = qc {
                k = kv_quant(&k, qc);
                v = kv_quant(&v, qc);
            }
            let att = causal_attention(&q, &k, &v, cfg.n_heads);
            if let Some(pr) = probe.as_deref_mut() {
                pr.o_in[i].push(att.clone());
            }
            let o =
                self.linear_group(&att, &pfx, &["o_proj"], "t_o", qc, dense).pop().unwrap();
            x = x.add(&o);
            let h = rmsnorm(&x, self.p(&format!("{pfx}ln2")));
            if let Some(pr) = probe.as_deref_mut() {
                pr.mlp_in[i].push(h.clone());
            }
            let mut gu = self
                .linear_group(&h, &pfx, &["gate_proj", "up_proj"], "t_mlp", qc, dense)
                .into_iter();
            let gate = gu.next().unwrap();
            let up = gu.next().unwrap();
            let mut hidden = Mat::zeros(s, cfg.ff);
            for t in 0..s {
                for j in 0..cfg.ff {
                    hidden[(t, j)] = silu(gate[(t, j)]) * up[(t, j)];
                }
            }
            if let Some(pr) = probe.as_deref_mut() {
                pr.down_in[i].push(hidden.clone());
            }
            let down = self
                .linear_group(&hidden, &pfx, &["down_proj"], "t_down", qc, dense)
                .pop()
                .unwrap();
            x = x.add(&down);
        }
        let x = rmsnorm(&x, self.p("ln_f"));
        matmul_a_bt(&x, self.p("lm_head"))
    }

    /// One group of (possibly transformed + quantized) linears. Layers in
    /// a group share their input, so the transform matmul and the
    /// per-token quantization happen once per group — not once per linear
    /// (q/k/v share one transformed+quantized activation). The quantized
    /// path produces integer codes for the packed i32-accumulate kernel;
    /// `dense` routes through the historical fake-quant f64 reference
    /// over pre-dequantized mats instead (parity tests, bench A/B).
    fn linear_group(
        &self,
        x: &Mat,
        pfx: &str,
        lins: &[&str],
        tshort: &str,
        qc: Option<&QuantConfig>,
        dense: Option<&HashMap<String, Mat>>,
    ) -> Vec<Mat> {
        let Some(qc) = qc else {
            return lins
                .iter()
                .map(|lin| matmul_a_bt(x, self.p(&format!("{pfx}{lin}"))))
                .collect();
        };
        let tname = format!("{pfx}{tshort}");
        let xt_store;
        let xin: &Mat = match qc.transforms.get(&tname) {
            Some(t) => {
                xt_store = matmul_a_bt(x, t); // X Tᵀ
                &xt_store
            }
            None => x,
        };
        match dense {
            Some(weights) => {
                let (xq, _) =
                    quantize_activations_per_token(xin, qc.act.scheme, qc.act.clip_ratio);
                lins.iter()
                    .map(|lin| {
                        let name = format!("{pfx}{lin}");
                        let w = weights
                            .get(&name)
                            .unwrap_or_else(|| panic!("missing dense weight {name}"));
                        matmul_a_bt(&xq, w)
                    })
                    .collect()
            }
            None => {
                let xq = QuantizedTensor::quantize_acts(xin, qc.act.scheme, qc.act.clip_ratio);
                lins.iter()
                    .map(|lin| {
                        let name = format!("{pfx}{lin}");
                        let ql = qc
                            .linears
                            .get(&name)
                            .unwrap_or_else(|| panic!("missing packed weight {name}"));
                        qmatmul_a_bt(&xq.view(), &ql.weight.view())
                    })
                    .collect()
            }
        }
    }
}

fn rmsnorm(x: &Mat, g: &Mat) -> Mat {
    let mut out = Mat::zeros(x.rows(), x.cols());
    for t in 0..x.rows() {
        let row = x.row(t);
        let ms = row.iter().map(|v| v * v).sum::<f64>() / row.len() as f64;
        let r = 1.0 / (ms + EPS).sqrt();
        let orow = out.row_mut(t);
        for j in 0..row.len() {
            orow[j] = row[j] * r * g[(0, j)];
        }
    }
    out
}

#[inline]
fn silu(v: f64) -> f64 {
    v / (1.0 + (-v).exp())
}

fn kv_quant(x: &Mat, qc: &QuantConfig) -> Mat {
    quantize_activations_per_token(x, qc.act.scheme, qc.act.clip_ratio).0
}

/// Numerically-stable softmax over a mutable row.
pub fn softmax_row(row: &mut [f64]) {
    let max = row.iter().fold(f64::NEG_INFINITY, |m, &v| m.max(v));
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in row.iter_mut() {
        *v /= sum;
    }
}

/// Multi-head causal attention over one sequence (`q,k,v: S×d`).
///
/// Heads are independent (disjoint output column blocks), so long
/// sequences fan heads out across the [`crate::linalg::par`] pool; the
/// per-head math is shared with the serial path, so worker count never
/// changes the result.
fn causal_attention(q: &Mat, k: &Mat, v: &Mat, n_heads: usize) -> Mat {
    let s = q.rows();
    let d = q.cols();
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f64).sqrt();
    let threads = par::threads_for(s.saturating_mul(s).saturating_mul(d), n_heads);
    let blocks: Vec<Vec<f64>> = par::par_map((0..n_heads).collect(), threads, |h| {
        attention_head(q, k, v, h * hd, hd, scale)
    });
    let mut out = Mat::zeros(s, d);
    for (h, blk) in blocks.iter().enumerate() {
        let c0 = h * hd;
        for t in 0..s {
            out.row_mut(t)[c0..c0 + hd].copy_from_slice(&blk[t * hd..(t + 1) * hd]);
        }
    }
    out
}

/// One attention head: the `S×hd` output block for columns
/// `c0 .. c0 + hd` (row-major).
fn attention_head(q: &Mat, k: &Mat, v: &Mat, c0: usize, hd: usize, scale: f64) -> Vec<f64> {
    let s = q.rows();
    let mut out = vec![0.0f64; s * hd];
    let mut scores = vec![0.0f64; s];
    for t in 0..s {
        // scores over keys 0..=t
        for (j, sc) in scores.iter_mut().enumerate().take(s) {
            if j <= t {
                let mut acc = 0.0;
                for c in c0..c0 + hd {
                    acc += q[(t, c)] * k[(j, c)];
                }
                *sc = acc * scale;
            } else {
                *sc = MASK_VALUE;
            }
        }
        softmax_row(&mut scores[..s]);
        let orow = &mut out[t * hd..(t + 1) * hd];
        for (j, &a) in scores.iter().enumerate().take(t + 1) {
            if a == 0.0 {
                continue;
            }
            for c in 0..hd {
                orow[c] += a * v[(j, c0 + c)];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::QuantConfig;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d: 32,
            n_layers: 2,
            n_heads: 4,
            ff: 64,
            seq: 16,
            vocab: 256,
        }
    }

    #[test]
    fn forward_shape_and_finite() {
        let m = NativeModel::init_random(tiny_cfg(), 1);
        let logits = m.forward(&[1, 2, 3, 4, 5]);
        assert_eq!(logits.rows(), 5);
        assert_eq!(logits.cols(), 256);
        assert!(logits.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality_native() {
        let m = NativeModel::init_random(tiny_cfg(), 2);
        let a = m.forward(&[5, 6, 7, 8, 9, 10]);
        let b = m.forward(&[5, 6, 7, 8, 250, 10]);
        for j in 0..256 {
            for t in 0..4 {
                assert!((a[(t, j)] - b[(t, j)]).abs() < 1e-12, "t={t} leaked");
            }
        }
        assert!((0..256).any(|j| (a[(4, j)] - b[(4, j)]).abs() > 1e-9));
    }

    #[test]
    fn probe_captures_all_groups() {
        let cfg = tiny_cfg();
        let m = NativeModel::init_random(cfg.clone(), 3);
        let mut probe = ProbeCapture::new(cfg.n_layers);
        m.forward_probed(&[1, 2, 3, 4], &mut probe);
        m.forward_probed(&[9, 8, 7], &mut probe);
        for i in 0..cfg.n_layers {
            let attn = ProbeCapture::concat(&probe.attn_in[i]);
            assert_eq!(attn.rows(), 7);
            assert_eq!(attn.cols(), cfg.d);
            let down = ProbeCapture::concat(&probe.down_in[i]);
            assert_eq!(down.cols(), cfg.ff);
        }
    }

    #[test]
    fn quant_identity_transform_high_bits_close_to_fp() {
        let cfg = tiny_cfg();
        let m = NativeModel::init_random(cfg.clone(), 4);
        let qc = QuantConfig::identity_for_test(&m, 12);
        let toks = [3u8, 1, 4, 1, 5, 9, 2, 6];
        let fp = m.forward(&toks);
        let q = m.forward_quant(&toks, &qc);
        let max_rel = fp.max_abs_diff(&q) / fp.max_abs().max(1e-9);
        assert!(max_rel < 0.05, "12-bit should be near-fp, rel {max_rel}");
    }

    #[test]
    fn quant_fewer_bits_more_error() {
        let cfg = tiny_cfg();
        let m = NativeModel::init_random(cfg.clone(), 5);
        let toks = [3u8, 1, 4, 1, 5, 9, 2, 6];
        let fp = m.forward(&toks);
        let mut prev = f64::INFINITY;
        for bits in [2u32, 4, 8] {
            let qc = QuantConfig::identity_for_test(&m, bits);
            let q = m.forward_quant(&toks, &qc);
            let err = fp.sub(&q).fro_norm2();
            assert!(err < prev, "bits {bits}: {err} !< {prev}");
            prev = err;
        }
    }

    #[test]
    fn packed_forward_matches_dense_reference() {
        // The core invariant: the integer path reproduces the fake-quant
        // f64 path to fp rounding (the affine identity is exact).
        let m = NativeModel::init_random(tiny_cfg(), 6);
        let toks = [1u8, 2, 3, 4, 5, 6, 7];
        for bits in [2u32, 4, 8] {
            let qc = QuantConfig::identity_for_test(&m, bits);
            let dense = m.forward_quant_dense(&toks, &qc, &qc.deq_weights());
            let packed = m.forward_quant(&toks, &qc);
            let rel = dense.max_abs_diff(&packed) / dense.max_abs().max(1e-30);
            assert!(rel < 1e-9, "bits {bits}: rel {rel}");
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut r = [1.0, 2.0, 3.0, -1e30];
        softmax_row(&mut r);
        assert!((r.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(r[3] < 1e-300);
    }
}
