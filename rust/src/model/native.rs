//! Pure-Rust transformer forward — the calibration/analysis engine and
//! the reference the PJRT parity tests trust.
//!
//! Mirrors `python/compile/model.py` op for op (RMSNorm ε, SiLU, causal
//! mask value, per-token KV fake-quant) so logits agree with the AOT
//! graphs to f32 precision.

use super::kvcache::{KvCache, LayerKv};
use super::{LayerGroup, LinearId, ModelConfig, QuantConfig};
use crate::linalg::{matmul_a_bt, matmul_a_bt_cached, par, qmatmul_a_bt_panels, Mat};
use crate::quant::{quantize_activations_per_token, QuantizedTensor};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;

const EPS: f64 = 1e-5;

/// Per-group activation capture for calibration (one entry per block).
#[derive(Default)]
pub struct ProbeCapture {
    pub attn_in: Vec<Vec<Mat>>,
    pub o_in: Vec<Vec<Mat>>,
    pub mlp_in: Vec<Vec<Mat>>,
    pub down_in: Vec<Vec<Mat>>,
}

impl ProbeCapture {
    pub fn new(n_layers: usize) -> Self {
        ProbeCapture {
            attn_in: vec![Vec::new(); n_layers],
            o_in: vec![Vec::new(); n_layers],
            mlp_in: vec![Vec::new(); n_layers],
            down_in: vec![Vec::new(); n_layers],
        }
    }

    /// Concatenate the captured row blocks of one group/block into a
    /// single `tokens × dim` matrix.
    pub fn concat(parts: &[Mat]) -> Mat {
        assert!(!parts.is_empty());
        let cols = parts[0].cols();
        let rows: usize = parts.iter().map(|m| m.rows()).sum();
        let mut out = Mat::zeros(rows, cols);
        let mut r = 0;
        for p in parts {
            out.set_block(r, 0, p);
            r += p.rows();
        }
        out
    }
}

/// The native model: config + f64 parameter matrices.
#[derive(Clone)]
pub struct NativeModel {
    pub cfg: ModelConfig,
    pub params: HashMap<String, Mat>,
}

impl NativeModel {
    /// Load from a `.catw` artifact, validating shapes against the spec.
    pub fn from_catw(cfg: ModelConfig, path: &std::path::Path) -> Result<Self> {
        let tensors = super::load_catw(path)?;
        let mut params = HashMap::new();
        for (name, shape) in cfg.param_spec() {
            let t = tensors
                .get(&name)
                .with_context(|| format!("missing tensor {name} in {}", path.display()))?;
            if t.shape != shape && !(shape.len() == 1 && t.shape == vec![shape[0]]) {
                bail!("tensor {name}: shape {:?} != spec {:?}", t.shape, shape);
            }
            params.insert(name, t.to_mat());
        }
        Ok(NativeModel { cfg, params })
    }

    /// Random-initialized model (tests, benches).
    pub fn init_random(cfg: ModelConfig, seed: u64) -> Self {
        let mut rng = crate::linalg::Rng::new(seed);
        let mut params = HashMap::new();
        for (name, shape) in cfg.param_spec() {
            let m = if name.contains("ln") {
                Mat::from_fn(1, shape[0], |_, _| 1.0)
            } else if shape.len() == 1 {
                Mat::from_fn(1, shape[0], |_, _| rng.normal() * 0.02)
            } else {
                let fan_in = shape[1] as f64;
                Mat::from_fn(shape[0], shape[1], |_, _| rng.normal() / fan_in.sqrt())
            };
            params.insert(name, m);
        }
        NativeModel { cfg, params }
    }

    fn p(&self, name: &str) -> &Mat {
        self.params.get(name).unwrap_or_else(|| panic!("missing param {name}"))
    }

    /// Total bytes held by the lazily built f64 panel caches on this
    /// model's parameters. FP decode builds one cache per GEMV-touched
    /// static weight (≈ one extra copy of each), so capacity planning
    /// for FP serving should budget roughly 2× weight bytes; this
    /// reports the live number (0 before the first decode).
    pub fn panel_cache_bytes(&self) -> usize {
        self.params.values().map(|m| m.panel_cache_bytes()).sum()
    }

    /// Full-sequence FP forward: logits `[S, vocab]` for one sequence.
    pub fn forward(&self, tokens: &[u8]) -> Mat {
        self.forward_opts(tokens, None, None, None)
    }

    /// FP forward capturing per-group linear inputs into `probe`.
    pub fn forward_probed(&self, tokens: &[u8], probe: &mut ProbeCapture) -> Mat {
        self.forward_opts(tokens, None, None, Some(probe))
    }

    /// Quantized forward: transforms, then per-token activation codes ×
    /// packed weight codes through the integer kernel (no dequantized f64
    /// weight matrices touched).
    pub fn forward_quant(&self, tokens: &[u8], qc: &QuantConfig) -> Mat {
        self.forward_opts(tokens, Some(qc), None, None)
    }

    /// Reference fake-quant forward over pre-dequantized f64 weights
    /// (`qc.deq_weights()`): the parity baseline the packed path must
    /// match to fp rounding, and the dense side of the bench A/B.
    pub fn forward_quant_dense(
        &self,
        tokens: &[u8],
        qc: &QuantConfig,
        weights: &HashMap<String, Mat>,
    ) -> Mat {
        self.forward_opts(tokens, Some(qc), Some(weights), None)
    }

    /// Prefill: run the prompt through the full-sequence path once,
    /// populating a fresh [`KvCache`] (FP rows, or packed per-token codes
    /// when `qc` is given), and return the *last-token* logits
    /// (`1 × vocab`) — the only row generation needs. The cached state
    /// makes each subsequent [`Self::decode_step`] O(T) instead of the
    /// O(T²) full-prefix recompute.
    pub fn prefill(&self, tokens: &[u8], qc: Option<&QuantConfig>) -> (Mat, KvCache) {
        assert!(!tokens.is_empty(), "prefill needs at least one token");
        let mut cache = match qc {
            None => KvCache::fp(&self.cfg),
            Some(qc) => KvCache::packed(&self.cfg, qc.kv_act.scheme, qc.kv_act.clip_ratio),
        };
        let logits = self.forward_impl(tokens, qc, None, None, Some(&mut cache), true);
        (logits, cache)
    }

    /// Continue prefilling an existing cache with `tokens` (positions
    /// `cache.len() ..`), returning the last-token logits (`1 × vocab`).
    ///
    /// This is the serving-path prefill: the cache may draw pages from a
    /// budgeted pool (capacity is reserved up front, so a refused budget
    /// fails here rather than mid-layer) and may already hold rows — a
    /// prefix-cache hit seeds the shared pages and only the unmatched
    /// prompt suffix runs through the model. Attention reads every K/V
    /// row back through the cache (the same read path as
    /// [`Self::decode_step`]), so FP and packed results are bit-identical
    /// to [`Self::prefill`] on the concatenated sequence.
    pub fn prefill_into(
        &self,
        tokens: &[u8],
        qc: Option<&QuantConfig>,
        cache: &mut KvCache,
    ) -> Mat {
        assert!(!tokens.is_empty(), "prefill needs at least one token");
        let cfg = &self.cfg;
        let s = tokens.len();
        let start = cache.len();
        assert!(start + s <= cfg.seq, "sequence too long");
        assert_eq!(cache.layers.len(), cfg.n_layers, "cache/model layer mismatch");
        assert_eq!(
            cache.is_packed(),
            qc.is_some(),
            "cache storage mode does not match the qc argument"
        );
        if let (Some((scheme, clip)), Some(qc)) = (cache.packed_grid(), qc) {
            assert!(
                scheme == qc.kv_act.scheme && clip == qc.kv_act.clip_ratio,
                "cache activation grid does not match qc.kv_act"
            );
        }
        assert!(
            cache.reserve_tokens(s),
            "KV page budget exhausted: admission control must reserve before prefill"
        );
        let tok_emb = self.p("tok_emb");
        let pos_emb = self.p("pos_emb");
        let mut x = Mat::zeros(s, cfg.d);
        for (t, &tok) in tokens.iter().enumerate() {
            for j in 0..cfg.d {
                x[(t, j)] = tok_emb[(tok as usize, j)] + pos_emb[(start + t, j)];
            }
        }
        let mut scores = vec![0.0f64; cfg.n_heads * (start + s)];
        let mut rowbuf = vec![0.0f64; cfg.d];
        let scale = 1.0 / (cfg.head_dim() as f64).sqrt();
        for i in 0..cfg.n_layers {
            let h = rmsnorm(&x, self.p(&format!("blocks.{i}.ln1")));
            let mut qkv =
                self.linear_group(&h, i, LayerGroup::AttnIn, qc, None).into_iter();
            let q = qkv.next().unwrap();
            let k = qkv.next().unwrap();
            let v = qkv.next().unwrap();
            let mut att = Mat::zeros(s, cfg.d);
            let lkv = &mut cache.layers[i];
            for t in 0..s {
                // Row `start + t` sees keys 0 ..= start + t: the cached
                // prefix (possibly shared prefix-hit pages) plus this
                // chunk's rows pushed so far.
                let t1 = start + t + 1;
                lkv.k.push(k.row(t));
                lkv.v.push(v.row(t));
                attention_decode(
                    q.row(t),
                    lkv,
                    t1,
                    cfg.n_heads,
                    scale,
                    &mut scores[..cfg.n_heads * t1],
                    &mut rowbuf,
                    att.row_mut(t),
                );
            }
            let o = self.linear_group(&att, i, LayerGroup::OIn, qc, None).pop().unwrap();
            x = x.add(&o);
            self.mlp_block(&mut x, i, qc, None, None);
        }
        cache.advance(s);
        let x = x.block(s - 1, 0, 1, cfg.d);
        let x = rmsnorm(&x, self.p("ln_f"));
        matmul_a_bt_cached(&x, self.p("lm_head"))
    }

    /// One incremental decode step for a batch of sequences: `next[b]` is
    /// appended to `caches[b]` at its current position, and the returned
    /// `B × vocab` logits predict each sequence's following token.
    ///
    /// All linear groups run over the `B × d` batch of last-token
    /// activations (one kernel call per group, sharing
    /// [`Self::linear_group`] with the full forward), while attention is
    /// a single-query pass per sequence over its cached K/V. FP results
    /// are bit-identical to the last row of [`Self::forward`] on the
    /// concatenated sequence; quantized results are bit-identical to
    /// [`Self::forward_quant`] (per-token grids are row-local, so cached
    /// codes never change as the sequence grows).
    pub fn decode_step(
        &self,
        caches: &mut [&mut KvCache],
        next: &[u8],
        qc: Option<&QuantConfig>,
    ) -> Mat {
        let b = caches.len();
        assert!(b > 0, "empty decode batch");
        assert_eq!(next.len(), b, "one next token per cache");
        let cfg = &self.cfg;
        for c in caches.iter() {
            assert!(c.has_room(), "kv cache at positional capacity");
            assert_eq!(c.layers.len(), cfg.n_layers, "cache/model layer mismatch");
            assert_eq!(
                c.is_packed(),
                qc.is_some(),
                "cache storage mode does not match the qc argument"
            );
            if let (Some((scheme, clip)), Some(qc)) = (c.packed_grid(), qc) {
                assert!(
                    scheme == qc.kv_act.scheme && clip == qc.kv_act.clip_ratio,
                    "cache activation grid does not match qc.kv_act"
                );
            }
        }
        for c in caches.iter_mut() {
            // Page capacity for this step's row — the scheduler preempts
            // sequences before stepping a batch the budget can't hold, so
            // this only fires on a mis-sized pool.
            assert!(
                c.reserve_tokens(1),
                "KV page budget exhausted mid-step: preempt before stepping"
            );
        }
        let tok_emb = self.p("tok_emb");
        let pos_emb = self.p("pos_emb");
        let mut x = Mat::zeros(b, cfg.d);
        for (bi, &tok) in next.iter().enumerate() {
            let pos = caches[bi].len();
            for j in 0..cfg.d {
                x[(bi, j)] = tok_emb[(tok as usize, j)] + pos_emb[(pos, j)];
            }
        }
        // Scratch reused across layers and sequences (no per-row allocs
        // in the step hot loop).
        let max_ctx = caches.iter().map(|c| c.len()).max().unwrap() + 1;
        let mut scores = vec![0.0f64; cfg.n_heads * max_ctx];
        let mut rowbuf = vec![0.0f64; cfg.d];
        let scale = 1.0 / (cfg.head_dim() as f64).sqrt();
        for i in 0..cfg.n_layers {
            let h = rmsnorm(&x, self.p(&format!("blocks.{i}.ln1")));
            let mut qkv =
                self.linear_group(&h, i, LayerGroup::AttnIn, qc, None).into_iter();
            let q = qkv.next().unwrap();
            let k = qkv.next().unwrap();
            let v = qkv.next().unwrap();
            let mut att = Mat::zeros(b, cfg.d);
            for bi in 0..b {
                let t1 = caches[bi].len() + 1;
                let lkv = &mut caches[bi].layers[i];
                // Packed caches quantize the pushed row on its per-token
                // grid; attention then reads every row — including this
                // one — back through the cache (deq = fake-quant).
                lkv.k.push(k.row(bi));
                lkv.v.push(v.row(bi));
                attention_decode(
                    q.row(bi),
                    lkv,
                    t1,
                    cfg.n_heads,
                    scale,
                    &mut scores[..cfg.n_heads * t1],
                    &mut rowbuf,
                    att.row_mut(bi),
                );
            }
            let o = self.linear_group(&att, i, LayerGroup::OIn, qc, None).pop().unwrap();
            x = x.add(&o);
            self.mlp_block(&mut x, i, qc, None, None);
        }
        for c in caches.iter_mut() {
            c.advance(1);
        }
        let xn = rmsnorm(&x, self.p("ln_f"));
        // Static weight + GEMV shape: the lm_head panel cache builds on
        // the first step and every later step reuses it.
        matmul_a_bt_cached(&xn, self.p("lm_head"))
    }

    fn forward_opts(
        &self,
        tokens: &[u8],
        qc: Option<&QuantConfig>,
        dense: Option<&HashMap<String, Mat>>,
        probe: Option<&mut ProbeCapture>,
    ) -> Mat {
        self.forward_impl(tokens, qc, dense, probe, None, false)
    }

    fn forward_impl(
        &self,
        tokens: &[u8],
        qc: Option<&QuantConfig>,
        dense: Option<&HashMap<String, Mat>>,
        mut probe: Option<&mut ProbeCapture>,
        mut cache: Option<&mut KvCache>,
        last_only: bool,
    ) -> Mat {
        let cfg = &self.cfg;
        let s = tokens.len();
        assert!(s <= cfg.seq, "sequence too long");
        let tok_emb = self.p("tok_emb");
        let pos_emb = self.p("pos_emb");
        let mut x = Mat::zeros(s, cfg.d);
        for (t, &tok) in tokens.iter().enumerate() {
            for j in 0..cfg.d {
                x[(t, j)] = tok_emb[(tok as usize, j)] + pos_emb[(t, j)];
            }
        }
        for i in 0..cfg.n_layers {
            let h = rmsnorm(&x, self.p(&format!("blocks.{i}.ln1")));
            if let Some(pr) = probe.as_deref_mut() {
                pr.attn_in[i].push(h.clone());
            }
            let mut qkv =
                self.linear_group(&h, i, LayerGroup::AttnIn, qc, dense).into_iter();
            let q = qkv.next().unwrap();
            let mut k = qkv.next().unwrap();
            let mut v = qkv.next().unwrap();
            if let Some(cache) = cache.as_deref_mut() {
                // Capture K/V while producing the values attention sees:
                // the raw rows (FP cache) or their per-token fake-quant
                // (packed cache) — bit-identical to the `kv_quant` path.
                let mut kq = Mat::zeros(s, cfg.d);
                let mut vq = Mat::zeros(s, cfg.d);
                let lkv = &mut cache.layers[i];
                for t in 0..s {
                    lkv.k.push_fake_quant(k.row(t), kq.row_mut(t));
                    lkv.v.push_fake_quant(v.row(t), vq.row_mut(t));
                }
                k = kq;
                v = vq;
            } else if let Some(qc) = qc {
                k = kv_quant(&k, qc);
                v = kv_quant(&v, qc);
            }
            let att = causal_attention(&q, &k, &v, cfg.n_heads);
            if let Some(pr) = probe.as_deref_mut() {
                pr.o_in[i].push(att.clone());
            }
            let o = self.linear_group(&att, i, LayerGroup::OIn, qc, dense).pop().unwrap();
            x = x.add(&o);
            let mlp_probe = probe
                .as_deref_mut()
                .map(|pr| (&mut pr.mlp_in[i], &mut pr.down_in[i]));
            self.mlp_block(&mut x, i, qc, dense, mlp_probe);
        }
        if let Some(cache) = cache {
            cache.advance(s);
        }
        // rmsnorm and lm_head are row-local, so projecting only the last
        // row (prefill) yields exactly the last row of the full logits.
        let x = if last_only { x.block(s - 1, 0, 1, cfg.d) } else { x };
        let x = rmsnorm(&x, self.p("ln_f"));
        matmul_a_bt_cached(&x, self.p("lm_head"))
    }

    /// The MLP half of one block, updating `x` in place:
    /// `x += down(silu(gate(h)) · up(h))` with `h = rmsnorm(x, ln2)`.
    /// Shared by the full forward and the decode step so the layer
    /// structure lives in one place; `probe` optionally captures the
    /// `mlp_in`/`down_in` calibration activations.
    fn mlp_block(
        &self,
        x: &mut Mat,
        block: usize,
        qc: Option<&QuantConfig>,
        dense: Option<&HashMap<String, Mat>>,
        probe: Option<(&mut Vec<Mat>, &mut Vec<Mat>)>,
    ) {
        let s = x.rows();
        let ff = self.cfg.ff;
        let (probe_h, probe_hidden) = match probe {
            Some((a, b)) => (Some(a), Some(b)),
            None => (None, None),
        };
        let h = rmsnorm(x, self.p(&format!("blocks.{block}.ln2")));
        if let Some(p) = probe_h {
            p.push(h.clone());
        }
        let mut gu = self.linear_group(&h, block, LayerGroup::MlpIn, qc, dense).into_iter();
        let gate = gu.next().unwrap();
        let up = gu.next().unwrap();
        let mut hidden = Mat::zeros(s, ff);
        for t in 0..s {
            for j in 0..ff {
                hidden[(t, j)] = silu(gate[(t, j)]) * up[(t, j)];
            }
        }
        if let Some(p) = probe_hidden {
            p.push(hidden.clone());
        }
        let down = self
            .linear_group(&hidden, block, LayerGroup::DownIn, qc, dense)
            .pop()
            .unwrap();
        x.add_in_place(&down);
    }

    /// One group of (possibly transformed + quantized) linears. Layers in
    /// a group share their input, so the transform matmul and the
    /// per-token quantization happen once per group — not once per linear
    /// (q/k/v share one transformed+quantized activation), on the
    /// *group's* activation grid (`qc.act_for`) — the seam mixed-precision
    /// plans execute through. The quantized path produces integer codes
    /// for the packed i32-accumulate kernel; `dense` routes through the
    /// historical fake-quant f64 reference over pre-dequantized mats
    /// instead (parity tests, bench A/B).
    fn linear_group(
        &self,
        x: &Mat,
        block: usize,
        group: LayerGroup,
        qc: Option<&QuantConfig>,
        dense: Option<&HashMap<String, Mat>>,
    ) -> Vec<Mat> {
        let lins = group.linears();
        // Model weights and transforms are static across calls, so the
        // cached dispatcher's persistent panels serve every decode step
        // (large prefill shapes fall through to the row-partitioned
        // kernel unchanged).
        let Some(qc) = qc else {
            return lins
                .iter()
                .map(|lin| matmul_a_bt_cached(x, self.p(&format!("blocks.{block}.{lin}"))))
                .collect();
        };
        let tname = group.t_name(block);
        let xt_store;
        let xin: &Mat = match qc.transforms.get(&tname) {
            Some(t) => {
                xt_store = matmul_a_bt_cached(x, t); // X Tᵀ
                &xt_store
            }
            None => x,
        };
        let act = qc.act_for(group);
        match dense {
            Some(weights) => {
                let (xq, _) =
                    quantize_activations_per_token(xin, act.scheme, act.clip_ratio);
                lins.iter()
                    .map(|&lin| {
                        let id = LinearId::new(block, lin);
                        let w = weights
                            .get(&id.to_string())
                            .unwrap_or_else(|| panic!("missing dense weight {id}"));
                        matmul_a_bt(&xq, w)
                    })
                    .collect()
            }
            None => {
                let xq = QuantizedTensor::quantize_acts(xin, act.scheme, act.clip_ratio);
                lins.iter()
                    .map(|&lin| {
                        let id = LinearId::new(block, lin);
                        let ql = qc
                            .linears
                            .get(&id)
                            .unwrap_or_else(|| panic!("missing packed weight {id}"));
                        qmatmul_a_bt_panels(&xq.view(), &ql.weight.view(), ql.panels())
                    })
                    .collect()
            }
        }
    }
}

fn rmsnorm(x: &Mat, g: &Mat) -> Mat {
    let mut out = Mat::zeros(x.rows(), x.cols());
    for t in 0..x.rows() {
        let row = x.row(t);
        let ms = row.iter().map(|v| v * v).sum::<f64>() / row.len() as f64;
        let r = 1.0 / (ms + EPS).sqrt();
        let orow = out.row_mut(t);
        for j in 0..row.len() {
            orow[j] = row[j] * r * g[(0, j)];
        }
    }
    out
}

#[inline]
fn silu(v: f64) -> f64 {
    v / (1.0 + (-v).exp())
}

fn kv_quant(x: &Mat, qc: &QuantConfig) -> Mat {
    quantize_activations_per_token(x, qc.kv_act.scheme, qc.kv_act.clip_ratio).0
}

/// Numerically-stable softmax over a mutable row.
pub fn softmax_row(row: &mut [f64]) {
    let max = row.iter().fold(f64::NEG_INFINITY, |m, &v| m.max(v));
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in row.iter_mut() {
        *v /= sum;
    }
}

/// Multi-head causal attention over one sequence (`q,k,v: S×d`).
///
/// Heads are independent (disjoint output column blocks), so long
/// sequences fan heads out across the [`crate::linalg::par`] pool; the
/// per-head math is shared with the serial path, so worker count never
/// changes the result.
fn causal_attention(q: &Mat, k: &Mat, v: &Mat, n_heads: usize) -> Mat {
    let s = q.rows();
    let d = q.cols();
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f64).sqrt();
    let threads = par::threads_for(s.saturating_mul(s).saturating_mul(d), n_heads);
    let blocks: Vec<Vec<f64>> = par::par_map((0..n_heads).collect(), threads, |h| {
        attention_head(q, k, v, h * hd, hd, scale)
    });
    let mut out = Mat::zeros(s, d);
    for (h, blk) in blocks.iter().enumerate() {
        let c0 = h * hd;
        for t in 0..s {
            out.row_mut(t)[c0..c0 + hd].copy_from_slice(&blk[t * hd..(t + 1) * hd]);
        }
    }
    out
}

/// One attention head: the `S×hd` output block for columns
/// `c0 .. c0 + hd` (row-major).
///
/// The score buffer is hoisted across rows, and row `t` touches only its
/// `t + 1` visible keys — no `S×S` masked-score pass. Softmax over the
/// visible prefix is bit-identical to softmax over a `−1e30`-masked full
/// row (`exp(mask − max)` underflows to exactly `0.0`), so this is a
/// pure-speed change.
fn attention_head(q: &Mat, k: &Mat, v: &Mat, c0: usize, hd: usize, scale: f64) -> Vec<f64> {
    let s = q.rows();
    let mut out = vec![0.0f64; s * hd];
    let mut scores = vec![0.0f64; s];
    for t in 0..s {
        // scores over keys 0..=t
        for (j, sc) in scores.iter_mut().enumerate().take(t + 1) {
            let mut acc = 0.0;
            for c in c0..c0 + hd {
                acc += q[(t, c)] * k[(j, c)];
            }
            *sc = acc * scale;
        }
        softmax_row(&mut scores[..t + 1]);
        let orow = &mut out[t * hd..(t + 1) * hd];
        for (j, &a) in scores.iter().enumerate().take(t + 1) {
            if a == 0.0 {
                continue;
            }
            for c in 0..hd {
                orow[c] += a * v[(j, c0 + c)];
            }
        }
    }
    out
}

/// Single-query attention for one decode step: `q` (one token's `d`-wide
/// query) against the `t1` cached K/V rows of `kv`, writing the `d`-wide
/// attention output into `out`.
///
/// Loops are ordered key-outer / head-inner so a packed cache dequantizes
/// each K/V row exactly once per step (into `rowbuf`); per-element
/// accumulation order matches [`attention_head`], so the result is
/// bit-identical to the full-sequence path's last row. `scores` is the
/// caller's reusable `n_heads·t1` buffer — this routine allocates nothing.
#[allow(clippy::too_many_arguments)]
fn attention_decode(
    q: &[f64],
    kv: &LayerKv,
    t1: usize,
    n_heads: usize,
    scale: f64,
    scores: &mut [f64],
    rowbuf: &mut [f64],
    out: &mut [f64],
) {
    let d = q.len();
    let hd = d / n_heads;
    debug_assert_eq!(scores.len(), n_heads * t1);
    for j in 0..t1 {
        let krow = kv.k.row(j, rowbuf);
        for h in 0..n_heads {
            let c0 = h * hd;
            let mut acc = 0.0;
            for c in c0..c0 + hd {
                acc += q[c] * krow[c];
            }
            scores[h * t1 + j] = acc * scale;
        }
    }
    for h in 0..n_heads {
        softmax_row(&mut scores[h * t1..(h + 1) * t1]);
    }
    for o in out.iter_mut() {
        *o = 0.0;
    }
    for j in 0..t1 {
        let vrow = kv.v.row(j, rowbuf);
        for h in 0..n_heads {
            let a = scores[h * t1 + j];
            if a == 0.0 {
                continue;
            }
            let c0 = h * hd;
            for c in 0..hd {
                out[c0 + c] += a * vrow[c0 + c];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::QuantConfig;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d: 32,
            n_layers: 2,
            n_heads: 4,
            ff: 64,
            seq: 16,
            vocab: 256,
        }
    }

    #[test]
    fn forward_shape_and_finite() {
        let m = NativeModel::init_random(tiny_cfg(), 1);
        let logits = m.forward(&[1, 2, 3, 4, 5]);
        assert_eq!(logits.rows(), 5);
        assert_eq!(logits.cols(), 256);
        assert!(logits.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality_native() {
        let m = NativeModel::init_random(tiny_cfg(), 2);
        let a = m.forward(&[5, 6, 7, 8, 9, 10]);
        let b = m.forward(&[5, 6, 7, 8, 250, 10]);
        for j in 0..256 {
            for t in 0..4 {
                assert!((a[(t, j)] - b[(t, j)]).abs() < 1e-12, "t={t} leaked");
            }
        }
        assert!((0..256).any(|j| (a[(4, j)] - b[(4, j)]).abs() > 1e-9));
    }

    #[test]
    fn probe_captures_all_groups() {
        let cfg = tiny_cfg();
        let m = NativeModel::init_random(cfg.clone(), 3);
        let mut probe = ProbeCapture::new(cfg.n_layers);
        m.forward_probed(&[1, 2, 3, 4], &mut probe);
        m.forward_probed(&[9, 8, 7], &mut probe);
        for i in 0..cfg.n_layers {
            let attn = ProbeCapture::concat(&probe.attn_in[i]);
            assert_eq!(attn.rows(), 7);
            assert_eq!(attn.cols(), cfg.d);
            let down = ProbeCapture::concat(&probe.down_in[i]);
            assert_eq!(down.cols(), cfg.ff);
        }
    }

    #[test]
    fn quant_identity_transform_high_bits_close_to_fp() {
        let cfg = tiny_cfg();
        let m = NativeModel::init_random(cfg.clone(), 4);
        let qc = QuantConfig::identity_for_test(&m, 12);
        let toks = [3u8, 1, 4, 1, 5, 9, 2, 6];
        let fp = m.forward(&toks);
        let q = m.forward_quant(&toks, &qc);
        let max_rel = fp.max_abs_diff(&q) / fp.max_abs().max(1e-9);
        assert!(max_rel < 0.05, "12-bit should be near-fp, rel {max_rel}");
    }

    #[test]
    fn quant_fewer_bits_more_error() {
        let cfg = tiny_cfg();
        let m = NativeModel::init_random(cfg.clone(), 5);
        let toks = [3u8, 1, 4, 1, 5, 9, 2, 6];
        let fp = m.forward(&toks);
        let mut prev = f64::INFINITY;
        for bits in [2u32, 4, 8] {
            let qc = QuantConfig::identity_for_test(&m, bits);
            let q = m.forward_quant(&toks, &qc);
            let err = fp.sub(&q).fro_norm2();
            assert!(err < prev, "bits {bits}: {err} !< {prev}");
            prev = err;
        }
    }

    #[test]
    fn packed_forward_matches_dense_reference() {
        // The core invariant: the integer path reproduces the fake-quant
        // f64 path to fp rounding (the affine identity is exact).
        let m = NativeModel::init_random(tiny_cfg(), 6);
        let toks = [1u8, 2, 3, 4, 5, 6, 7];
        for bits in [2u32, 4, 8] {
            let qc = QuantConfig::identity_for_test(&m, bits);
            let dense = m.forward_quant_dense(&toks, &qc, &qc.deq_weights());
            let packed = m.forward_quant(&toks, &qc);
            let rel = dense.max_abs_diff(&packed) / dense.max_abs().max(1e-30);
            assert!(rel < 1e-9, "bits {bits}: rel {rel}");
        }
    }

    #[test]
    fn chunked_prefill_matches_full() {
        // prefill_into over prompt chunks must be bit-identical to the
        // one-shot prefill path, FP and packed, including the decode
        // steps that follow — the invariant prefix sharing leans on.
        let cfg = tiny_cfg();
        let m = NativeModel::init_random(cfg.clone(), 7);
        let toks = [3u8, 1, 4, 1, 5, 9, 2, 6, 5, 3];
        let qc_owned = QuantConfig::identity_for_test(&m, 4);
        for qc in [None, Some(&qc_owned)] {
            let (full, mut fcache) = m.prefill(&toks, qc);
            let mut cache = match qc {
                None => KvCache::fp(&cfg),
                Some(q) => KvCache::packed(&cfg, q.kv_act.scheme, q.kv_act.clip_ratio),
            };
            let _ = m.prefill_into(&toks[..4], qc, &mut cache);
            let logits = m.prefill_into(&toks[4..], qc, &mut cache);
            assert_eq!(logits.max_abs_diff(&full), 0.0, "chunked prefill moved a bit");
            let a = m.decode_step(&mut [&mut fcache], &[7], qc);
            let b = m.decode_step(&mut [&mut cache], &[7], qc);
            assert_eq!(a.max_abs_diff(&b), 0.0, "decode diverged after chunked prefill");
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut r = [1.0, 2.0, 3.0, -1e30];
        softmax_row(&mut r);
        assert!((r.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(r[3] < 1e-300);
    }
}
