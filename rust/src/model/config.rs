//! Model configuration, mirroring `python/compile/model.py::Config`.

/// Architecture hyperparameters of one model-zoo member.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: String,
    pub d: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub ff: usize,
    pub seq: usize,
    pub vocab: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d / self.n_heads
    }

    /// The zoo defaults (kept in sync with `model.py::ZOO`; the manifest
    /// is authoritative at runtime).
    pub fn zoo(name: &str) -> Option<ModelConfig> {
        let (d, n_layers, n_heads, ff) = match name {
            "tiny" => (64, 2, 4, 128),
            "small" => (128, 4, 4, 256),
            "base" => (256, 6, 8, 512),
            _ => return None,
        };
        Some(ModelConfig {
            name: name.to_string(),
            d,
            n_layers,
            n_heads,
            ff,
            seq: 128,
            vocab: 256,
        })
    }

    /// Parameter names and shapes in the flat-argument order shared with
    /// the AOT graphs (must match `model.py::param_spec`).
    pub fn param_spec(&self) -> Vec<(String, Vec<usize>)> {
        let mut spec = vec![
            ("tok_emb".to_string(), vec![self.vocab, self.d]),
            ("pos_emb".to_string(), vec![self.seq, self.d]),
        ];
        for i in 0..self.n_layers {
            let p = format!("blocks.{i}.");
            spec.push((format!("{p}ln1"), vec![self.d]));
            spec.push((format!("{p}q_proj"), vec![self.d, self.d]));
            spec.push((format!("{p}k_proj"), vec![self.d, self.d]));
            spec.push((format!("{p}v_proj"), vec![self.d, self.d]));
            spec.push((format!("{p}o_proj"), vec![self.d, self.d]));
            spec.push((format!("{p}ln2"), vec![self.d]));
            spec.push((format!("{p}gate_proj"), vec![self.ff, self.d]));
            spec.push((format!("{p}up_proj"), vec![self.ff, self.d]));
            spec.push((format!("{p}down_proj"), vec![self.d, self.ff]));
        }
        spec.push(("ln_f".to_string(), vec![self.d]));
        spec.push(("lm_head".to_string(), vec![self.vocab, self.d]));
        spec
    }

    /// Transform names and shapes (must match `model.py::transform_spec`).
    pub fn transform_spec(&self) -> Vec<(String, Vec<usize>)> {
        let mut spec = Vec::new();
        for i in 0..self.n_layers {
            let p = format!("blocks.{i}.");
            spec.push((format!("{p}t_attn"), vec![self.d, self.d]));
            spec.push((format!("{p}t_o"), vec![self.d, self.d]));
            spec.push((format!("{p}t_mlp"), vec![self.d, self.d]));
            spec.push((format!("{p}t_down"), vec![self.ff, self.ff]));
        }
        spec
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.param_spec().iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_members_exist() {
        for name in ["tiny", "small", "base"] {
            let cfg = ModelConfig::zoo(name).unwrap();
            assert_eq!(cfg.d % cfg.n_heads, 0);
            assert!(cfg.d.is_power_of_two() && cfg.ff.is_power_of_two());
        }
        assert!(ModelConfig::zoo("llama-70b").is_none());
    }

    #[test]
    fn spec_counts() {
        let cfg = ModelConfig::zoo("base").unwrap();
        assert_eq!(cfg.param_spec().len(), 2 + 6 * 9 + 2);
        assert_eq!(cfg.transform_spec().len(), 6 * 4);
    }

    #[test]
    fn param_counts_plausible() {
        let tiny = ModelConfig::zoo("tiny").unwrap().n_params();
        let base = ModelConfig::zoo("base").unwrap().n_params();
        assert!(tiny > 50_000 && tiny < 500_000, "tiny {tiny}");
        assert!(base > 3_000_000 && base < 10_000_000, "base {base}");
    }
}
