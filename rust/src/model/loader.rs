//! CATW weight-artifact loader (format documented in
//! `python/compile/catw.py`).

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::Read;

/// One tensor from a `.catw` file.
#[derive(Clone, Debug)]
pub struct CatwTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl CatwTensor {
    /// View as an analysis matrix (1-D tensors become `1×n`).
    pub fn to_mat(&self) -> crate::linalg::Mat {
        let (r, c) = match self.shape.len() {
            1 => (1, self.shape[0]),
            2 => (self.shape[0], self.shape[1]),
            n => panic!("to_mat on {n}-d tensor"),
        };
        crate::linalg::Mat::from_f32(r, c, &self.data)
    }
}

/// Load every tensor in a `.catw` file.
pub fn load_catw(path: &std::path::Path) -> Result<HashMap<String, CatwTensor>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    parse_catw(&bytes)
}

fn parse_catw(bytes: &[u8]) -> Result<HashMap<String, CatwTensor>> {
    let mut r = bytes;
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != b"CATW" {
        bail!("bad magic {:?}", magic);
    }
    let version = read_u32(&mut r)?;
    if version != 1 {
        bail!("unsupported catw version {version}");
    }
    let n = read_u32(&mut r)? as usize;
    let mut out = HashMap::with_capacity(n);
    for _ in 0..n {
        let name_len = read_u32(&mut r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name not utf-8")?;
        let ndim = read_u32(&mut r)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u64(&mut r)? as usize);
        }
        let count: usize = shape.iter().product::<usize>().max(1);
        let mut data = vec![0f32; count];
        let mut buf = vec![0u8; count * 4];
        r.read_exact(&mut buf)?;
        for (i, ch) in buf.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
        }
        out.insert(name, CatwTensor { shape, data });
    }
    Ok(out)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth_catw() -> Vec<u8> {
        let mut b: Vec<u8> = Vec::new();
        b.extend(b"CATW");
        b.extend(1u32.to_le_bytes());
        b.extend(2u32.to_le_bytes());
        // tensor "a": shape [2,3]
        b.extend(1u32.to_le_bytes());
        b.extend(b"a");
        b.extend(2u32.to_le_bytes());
        b.extend(2u64.to_le_bytes());
        b.extend(3u64.to_le_bytes());
        for i in 0..6 {
            b.extend((i as f32).to_le_bytes());
        }
        // tensor "ln": shape [4]
        b.extend(2u32.to_le_bytes());
        b.extend(b"ln");
        b.extend(1u32.to_le_bytes());
        b.extend(4u64.to_le_bytes());
        for _ in 0..4 {
            b.extend(1.0f32.to_le_bytes());
        }
        b
    }

    #[test]
    fn parse_roundtrip() {
        let t = parse_catw(&synth_catw()).unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t["a"].shape, vec![2, 3]);
        assert_eq!(t["a"].data[5], 5.0);
        assert_eq!(t["ln"].shape, vec![4]);
        let m = t["a"].to_mat();
        assert_eq!(m.rows(), 2);
        assert_eq!(m[(1, 2)], 5.0);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut b = synth_catw();
        b[0] = b'X';
        assert!(parse_catw(&b).is_err());
    }

    #[test]
    fn truncated_rejected() {
        let b = synth_catw();
        assert!(parse_catw(&b[..b.len() - 3]).is_err());
    }
}
