//! Quantized-model configuration: transforms + packed integer weights.
//!
//! A [`QuantConfig`] is the output of the PTQ pipeline
//! ([`crate::pipeline`]) and the input to both engines. The native
//! forward executes the packed codes directly through the integer kernel;
//! the PJRT graphs still consume dense f32 runtime arguments, so the
//! `ArgPack` dequantizes once per pack build.
//!
//! Built configs are persistable: [`crate::runtime::save_artifact`] /
//! [`crate::runtime::load_artifact`] round-trip a `QuantConfig` through a
//! versioned on-disk artifact bit-exactly, so serving processes load in
//! milliseconds instead of re-running calibration + GPTQ at boot.

use super::{ModelConfig, NativeModel};
use crate::linalg::{Mat, QPanels};
use crate::quant::{quantize_weights_rtn, ActQuantCfg, QScheme, QuantizedTensor, WeightQuantCfg};
use std::collections::HashMap;
use std::fmt;

/// The four transform groups per block (layers sharing an input share a
/// transform — paper §3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LayerGroup {
    /// q/k/v projections (post-ln1 input).
    AttnIn,
    /// o projection (attention output).
    OIn,
    /// gate/up projections (post-ln2 input).
    MlpIn,
    /// down projection (SwiGLU hidden).
    DownIn,
}

pub const ALL_GROUPS: [LayerGroup; 4] =
    [LayerGroup::AttnIn, LayerGroup::OIn, LayerGroup::MlpIn, LayerGroup::DownIn];

impl LayerGroup {
    /// The transform parameter name for block `i`.
    pub fn t_name(&self, block: usize) -> String {
        let suffix = match self {
            LayerGroup::AttnIn => "t_attn",
            LayerGroup::OIn => "t_o",
            LayerGroup::MlpIn => "t_mlp",
            LayerGroup::DownIn => "t_down",
        };
        format!("blocks.{block}.{suffix}")
    }

    /// The linear layers consuming this group's input.
    pub fn linears(&self) -> &'static [&'static str] {
        match self {
            LayerGroup::AttnIn => &["q_proj", "k_proj", "v_proj"],
            LayerGroup::OIn => &["o_proj"],
            LayerGroup::MlpIn => &["gate_proj", "up_proj"],
            LayerGroup::DownIn => &["down_proj"],
        }
    }

    /// Input dimensionality of this group.
    pub fn dim(&self, cfg: &ModelConfig) -> usize {
        match self {
            LayerGroup::DownIn => cfg.ff,
            _ => cfg.d,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            LayerGroup::AttnIn => "qkv_proj",
            LayerGroup::OIn => "o_proj",
            LayerGroup::MlpIn => "gate_up_proj",
            LayerGroup::DownIn => "down_proj",
        }
    }

    /// Stable machine key (plan echoes, artifact manifests).
    pub fn key(&self) -> &'static str {
        match self {
            LayerGroup::AttnIn => "attn_in",
            LayerGroup::OIn => "o_in",
            LayerGroup::MlpIn => "mlp_in",
            LayerGroup::DownIn => "down_in",
        }
    }

    /// Inverse of [`Self::key`].
    pub fn from_key(key: &str) -> Option<LayerGroup> {
        ALL_GROUPS.into_iter().find(|g| g.key() == key)
    }
}

/// Canonical `(group, &'static name)` for a linear's short name.
fn canonical_linear(name: &str) -> Option<(LayerGroup, &'static str)> {
    for g in ALL_GROUPS {
        for &lin in g.linears() {
            if lin == name {
                return Some((g, lin));
            }
        }
    }
    None
}

/// Map a linear layer's short name to its input group.
pub fn group_of_linear(name: &str) -> LayerGroup {
    canonical_linear(name).unwrap_or_else(|| panic!("unknown linear {name}")).0
}

/// Typed identity of one quantized linear layer: which block, which
/// projection. Identity is `(block, name)` — the input group is fully
/// derivable from the name ([`Self::group`]), so it is not stored (no
/// way to construct an id whose group contradicts its name). The single
/// [`fmt::Display`] impl produces the parameter-store name
/// (`blocks.{block}.{name}`) that used to be rebuilt by `format!` at
/// every call site.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct LinearId {
    block: usize,
    name: &'static str,
}

impl LinearId {
    /// Build from a block index and a linear short name (`"q_proj"`…).
    /// Panics on unknown names — ids are only minted from the static
    /// group tables.
    pub fn new(block: usize, name: &str) -> LinearId {
        let (_, name) =
            canonical_linear(name).unwrap_or_else(|| panic!("unknown linear {name}"));
        LinearId { block, name }
    }

    /// Parse a parameter-store name (`"blocks.3.q_proj"`). Returns `None`
    /// for non-linear parameters (embeddings, norms, transforms).
    pub fn parse(param: &str) -> Option<LinearId> {
        let rest = param.strip_prefix("blocks.")?;
        let (block, name) = rest.split_once('.')?;
        let block: usize = block.parse().ok()?;
        let (_, name) = canonical_linear(name)?;
        Some(LinearId { block, name })
    }

    /// The transformer block this linear lives in.
    pub fn block(&self) -> usize {
        self.block
    }

    /// The linear's canonical short name (`"q_proj"`…).
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// The layer group whose input this linear consumes (derived).
    pub fn group(&self) -> LayerGroup {
        group_of_linear(self.name)
    }
}

impl fmt::Display for LinearId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "blocks.{}.{}", self.block, self.name)
    }
}

/// One linear layer's integer-executable weights: the packed codes of the
/// fused `W·T⁻¹` plus per-output-channel grids, and the codes unpacked
/// **once** into the kernel's persistent panel layout — weights are
/// static for the life of a `QuantConfig`, so the per-call `n×k` unpack
/// the kernel used to do (dominant at small batch) happens exactly once,
/// here at build time.
#[derive(Clone)]
pub struct QuantizedLinear {
    /// Packed integer codes (`out × in`).
    pub weight: QuantizedTensor,
    /// Persistent unpacked panels ([`crate::linalg::qmatmul_a_bt_panels`]
    /// input) — ~4× the packed bytes at W4, repaid on every forward.
    panels: QPanels,
}

impl QuantizedLinear {
    pub fn new(weight: QuantizedTensor) -> QuantizedLinear {
        let panels = QPanels::from_view(&weight.view());
        QuantizedLinear { weight, panels }
    }

    /// The persistent unpacked panels (kernel fast path).
    pub fn panels(&self) -> &QPanels {
        &self.panels
    }

    /// Dequantize back to f64 (PJRT `ArgPack`, analysis, the fake-quant
    /// parity reference).
    pub fn deq(&self) -> Mat {
        self.weight.deq()
    }

    /// Bytes of packed storage (codes + per-row metadata).
    pub fn packed_bytes(&self) -> usize {
        self.weight.packed_bytes()
    }

    /// Bytes of the persistent unpacked panels.
    pub fn panel_bytes(&self) -> usize {
        self.panels.bytes()
    }
}

/// Everything a quantized forward needs beyond the FP weights.
///
/// Activation quantization is **per layer group** — the seam mixed-
/// precision plans (e.g. attention W8A8 / MLP W4A4) flow through. The
/// KV cache keeps its own grid (`kv_act`), which a uniform plan pins to
/// the shared activation config, preserving the historical behavior.
#[derive(Clone)]
pub struct QuantConfig {
    /// Per-group activation quantization (the group input's dynamic grid).
    pub acts: HashMap<LayerGroup, ActQuantCfg>,
    /// Per-token grid for cached K/V rows.
    pub kv_act: ActQuantCfg,
    /// Transform name (`blocks.i.t_*`) → `T` (applied as `x·Tᵀ`).
    pub transforms: HashMap<String, Mat>,
    /// Linear id → packed fused `W·T⁻¹` codes.
    pub linears: HashMap<LinearId, QuantizedLinear>,
}

/// Bundle of `QuantConfig` + run metadata (which transform/quantizer built
/// it) — what the experiment grid iterates over.
pub struct QuantizedWeightsSet {
    pub label: String,
    pub qc: QuantConfig,
}

impl QuantConfig {
    /// The activation quantization of one layer group.
    pub fn act_for(&self, group: LayerGroup) -> ActQuantCfg {
        *self
            .acts
            .get(&group)
            .unwrap_or_else(|| panic!("no activation cfg for group {}", group.key()))
    }

    /// Set every group's activation cfg (and the KV grid) to `act` —
    /// the uniform-plan shape all pre-plan configs had.
    pub fn set_uniform_act(&mut self, act: ActQuantCfg) {
        for g in ALL_GROUPS {
            self.acts.insert(g, act);
        }
        self.kv_act = act;
    }

    /// One `act` entry per group (uniform-plan construction helper).
    pub fn uniform_acts(act: ActQuantCfg) -> HashMap<LayerGroup, ActQuantCfg> {
        ALL_GROUPS.into_iter().map(|g| (g, act)).collect()
    }

    /// The single activation config shared by every group *and* the KV
    /// grid, if this config is uniform — `None` for mixed-precision
    /// configs. Engines whose activation quantization is baked in (the
    /// compiled PJRT A4 graphs) must check this before serving.
    pub fn uniform_act(&self) -> Option<ActQuantCfg> {
        let a = self.act_for(ALL_GROUPS[0]);
        let same = |b: ActQuantCfg| b.scheme == a.scheme && b.clip_ratio == a.clip_ratio;
        if ALL_GROUPS.into_iter().all(|g| same(self.act_for(g))) && same(self.kv_act) {
            Some(a)
        } else {
            None
        }
    }

    /// Look up a packed linear by its parameter-store name
    /// (`"blocks.3.q_proj"`) — the string-keyed seam the PJRT `ArgPack`
    /// walks `param_spec` through.
    pub fn linear_named(&self, param: &str) -> Option<&QuantizedLinear> {
        LinearId::parse(param).and_then(|id| self.linears.get(&id))
    }

    /// Identity transforms + RTN(minmax) weights at `bits` — the "None"
    /// baseline and the tests' fixture.
    pub fn identity_for_test(model: &NativeModel, bits: u32) -> QuantConfig {
        let cfg = &model.cfg;
        let mut transforms = HashMap::new();
        for (name, shape) in cfg.transform_spec() {
            transforms.insert(name, Mat::eye(shape[0]));
        }
        let mut linears = HashMap::new();
        let wq = WeightQuantCfg::minmax(bits);
        for i in 0..cfg.n_layers {
            for g in ALL_GROUPS {
                for lin in g.linears() {
                    let id = LinearId::new(i, lin);
                    let w = &model.params[&id.to_string()];
                    linears.insert(id, QuantizedLinear::new(quantize_weights_rtn(w, wq).codes));
                }
            }
        }
        let act = ActQuantCfg { scheme: QScheme::asym(bits), clip_ratio: 1.0 };
        QuantConfig {
            acts: Self::uniform_acts(act),
            kv_act: act,
            transforms,
            linears,
        }
    }

    /// Dense f64 view of every packed weight, keyed by parameter-store
    /// name — the fake-quant reference for parity tests and the dense
    /// side of A/B benches.
    pub fn deq_weights(&self) -> HashMap<String, Mat> {
        self.linears.iter().map(|(id, l)| (id.to_string(), l.deq())).collect()
    }

    /// Total packed bytes across all linears (vs `8·out·in` per f64 mat).
    pub fn packed_bytes(&self) -> usize {
        self.linears.values().map(|l| l.packed_bytes()).sum()
    }

    /// Total bytes of persistent panels: the eager `QuantizedLinear`
    /// i16/i32 panels plus any lazily built f64 panel caches on the
    /// transforms (decode touches those through the GEMV path). The
    /// memory-for-latency side of the panel design, for capacity
    /// planning next to [`Self::packed_bytes`].
    pub fn panel_bytes(&self) -> usize {
        self.linears.values().map(|l| l.panel_bytes()).sum::<usize>()
            + self.transforms.values().map(|t| t.panel_cache_bytes()).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn groups_cover_all_linears() {
        let mut count = 0;
        for g in ALL_GROUPS {
            for lin in g.linears() {
                assert_eq!(group_of_linear(lin), g);
                count += 1;
            }
        }
        assert_eq!(count, 7);
    }

    #[test]
    fn t_names_match_transform_spec() {
        let cfg = ModelConfig::zoo("tiny").unwrap();
        let spec: Vec<String> = cfg.transform_spec().into_iter().map(|(n, _)| n).collect();
        for i in 0..cfg.n_layers {
            for g in ALL_GROUPS {
                assert!(spec.contains(&g.t_name(i)), "{}", g.t_name(i));
            }
        }
    }

    #[test]
    fn group_dims() {
        let cfg = ModelConfig::zoo("small").unwrap();
        assert_eq!(LayerGroup::AttnIn.dim(&cfg), cfg.d);
        assert_eq!(LayerGroup::DownIn.dim(&cfg), cfg.ff);
    }

    #[test]
    fn group_keys_roundtrip() {
        for g in ALL_GROUPS {
            assert_eq!(LayerGroup::from_key(g.key()), Some(g));
        }
        assert_eq!(LayerGroup::from_key("qkv_proj"), None);
    }

    #[test]
    fn linear_id_display_and_parse() {
        let id = LinearId::new(3, "gate_proj");
        assert_eq!(id.group(), LayerGroup::MlpIn);
        assert_eq!(id.block(), 3);
        assert_eq!(id.name(), "gate_proj");
        assert_eq!(id.to_string(), "blocks.3.gate_proj");
        assert_eq!(LinearId::parse("blocks.3.gate_proj"), Some(id));
        // Non-linear params and malformed names don't parse.
        assert_eq!(LinearId::parse("blocks.0.ln1"), None);
        assert_eq!(LinearId::parse("blocks.x.q_proj"), None);
        assert_eq!(LinearId::parse("tok_emb"), None);
        // Every canonical linear round-trips through Display.
        for g in ALL_GROUPS {
            for lin in g.linears() {
                let id = LinearId::new(7, lin);
                assert_eq!(LinearId::parse(&id.to_string()), Some(id));
            }
        }
    }

    #[test]
    fn identity_config_packs_every_linear() {
        let cfg = ModelConfig::zoo("tiny").unwrap();
        let model = NativeModel::init_random(cfg.clone(), 9);
        let qc = QuantConfig::identity_for_test(&model, 4);
        // The linear list is derived from ALL_GROUPS — 7 per block.
        assert_eq!(qc.linears.len(), cfg.n_layers * 7);
        let f64_bytes: usize = qc
            .linears
            .keys()
            .map(|id| {
                let m = &model.params[&id.to_string()];
                m.rows() * m.cols() * 8
            })
            .sum();
        // Nibble-packed W4 sits far below the f64 footprint (~16×; the
        // per-row metadata keeps it shy of exact).
        assert!(qc.packed_bytes() * 8 < f64_bytes, "{} vs {f64_bytes}", qc.packed_bytes());
        // The persistent i16 panels trade memory for per-call unpack
        // time: 2 bytes/code vs 0.5 packed — 4× the code bytes, still
        // 4× under f64.
        let panel_bytes: usize = qc.linears.values().map(|l| l.panel_bytes()).sum();
        assert!(panel_bytes > qc.packed_bytes() / 2, "panels are unpacked codes");
        assert!(panel_bytes * 4 <= f64_bytes, "panels stay well under f64");
    }

    #[test]
    fn per_group_acts_are_addressable() {
        let cfg = ModelConfig::zoo("tiny").unwrap();
        let model = NativeModel::init_random(cfg, 10);
        let mut qc = QuantConfig::identity_for_test(&model, 4);
        assert_eq!(qc.act_for(LayerGroup::MlpIn).scheme.bits, 4);
        let eight = ActQuantCfg { scheme: QScheme::asym(8), clip_ratio: 1.0 };
        assert_eq!(qc.uniform_act().map(|a| a.scheme.bits), Some(4));
        qc.acts.insert(LayerGroup::MlpIn, eight);
        assert_eq!(qc.act_for(LayerGroup::MlpIn).scheme.bits, 8);
        assert_eq!(qc.act_for(LayerGroup::AttnIn).scheme.bits, 4);
        // Mixed configs are no longer uniform (the PJRT A4 gate).
        assert!(qc.uniform_act().is_none());
        qc.set_uniform_act(eight);
        assert_eq!(qc.act_for(LayerGroup::AttnIn).scheme.bits, 8);
        assert_eq!(qc.kv_act.scheme.bits, 8);
        assert_eq!(qc.uniform_act().map(|a| a.scheme.bits), Some(8));
    }
}
