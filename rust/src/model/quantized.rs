//! Quantized-model configuration: transforms + packed integer weights.
//!
//! A [`QuantConfig`] is the output of the PTQ pipeline
//! ([`crate::pipeline`]) and the input to both engines. The native
//! forward executes the packed codes directly through the integer kernel;
//! the PJRT graphs still consume dense f32 runtime arguments, so the
//! `ArgPack` dequantizes once per pack build.

use super::{ModelConfig, NativeModel};
use crate::linalg::{Mat, QPanels};
use crate::quant::{quantize_weights_rtn, ActQuantCfg, QScheme, QuantizedTensor, WeightQuantCfg};
use std::collections::HashMap;

/// The four transform groups per block (layers sharing an input share a
/// transform — paper §3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerGroup {
    /// q/k/v projections (post-ln1 input).
    AttnIn,
    /// o projection (attention output).
    OIn,
    /// gate/up projections (post-ln2 input).
    MlpIn,
    /// down projection (SwiGLU hidden).
    DownIn,
}

pub const ALL_GROUPS: [LayerGroup; 4] =
    [LayerGroup::AttnIn, LayerGroup::OIn, LayerGroup::MlpIn, LayerGroup::DownIn];

impl LayerGroup {
    /// The transform parameter name for block `i`.
    pub fn t_name(&self, block: usize) -> String {
        let suffix = match self {
            LayerGroup::AttnIn => "t_attn",
            LayerGroup::OIn => "t_o",
            LayerGroup::MlpIn => "t_mlp",
            LayerGroup::DownIn => "t_down",
        };
        format!("blocks.{block}.{suffix}")
    }

    /// The linear layers consuming this group's input.
    pub fn linears(&self) -> &'static [&'static str] {
        match self {
            LayerGroup::AttnIn => &["q_proj", "k_proj", "v_proj"],
            LayerGroup::OIn => &["o_proj"],
            LayerGroup::MlpIn => &["gate_proj", "up_proj"],
            LayerGroup::DownIn => &["down_proj"],
        }
    }

    /// Input dimensionality of this group.
    pub fn dim(&self, cfg: &ModelConfig) -> usize {
        match self {
            LayerGroup::DownIn => cfg.ff,
            _ => cfg.d,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            LayerGroup::AttnIn => "qkv_proj",
            LayerGroup::OIn => "o_proj",
            LayerGroup::MlpIn => "gate_up_proj",
            LayerGroup::DownIn => "down_proj",
        }
    }
}

/// Map a linear layer's short name to its input group.
pub fn group_of_linear(name: &str) -> LayerGroup {
    match name {
        "q_proj" | "k_proj" | "v_proj" => LayerGroup::AttnIn,
        "o_proj" => LayerGroup::OIn,
        "gate_proj" | "up_proj" => LayerGroup::MlpIn,
        "down_proj" => LayerGroup::DownIn,
        _ => panic!("unknown linear {name}"),
    }
}

/// One linear layer's integer-executable weights: the packed codes of the
/// fused `W·T⁻¹` plus per-output-channel grids, and the codes unpacked
/// **once** into the kernel's persistent panel layout — weights are
/// static for the life of a `QuantConfig`, so the per-call `n×k` unpack
/// the kernel used to do (dominant at small batch) happens exactly once,
/// here at build time.
#[derive(Clone)]
pub struct QuantizedLinear {
    /// Packed integer codes (`out × in`).
    pub weight: QuantizedTensor,
    /// Persistent unpacked panels ([`crate::linalg::qmatmul_a_bt_panels`]
    /// input) — ~4× the packed bytes at W4, repaid on every forward.
    panels: QPanels,
}

impl QuantizedLinear {
    pub fn new(weight: QuantizedTensor) -> QuantizedLinear {
        let panels = QPanels::from_view(&weight.view());
        QuantizedLinear { weight, panels }
    }

    /// The persistent unpacked panels (kernel fast path).
    pub fn panels(&self) -> &QPanels {
        &self.panels
    }

    /// Dequantize back to f64 (PJRT `ArgPack`, analysis, the fake-quant
    /// parity reference).
    pub fn deq(&self) -> Mat {
        self.weight.deq()
    }

    /// Bytes of packed storage (codes + per-row metadata).
    pub fn packed_bytes(&self) -> usize {
        self.weight.packed_bytes()
    }

    /// Bytes of the persistent unpacked panels.
    pub fn panel_bytes(&self) -> usize {
        self.panels.bytes()
    }
}

/// Everything a quantized forward needs beyond the FP weights.
pub struct QuantConfig {
    pub act: ActQuantCfg,
    pub weight_bits: u32,
    /// Transform name (`blocks.i.t_*`) → `T` (applied as `x·Tᵀ`).
    pub transforms: HashMap<String, Mat>,
    /// Full weight name (`blocks.i.*_proj`) → packed fused `W·T⁻¹` codes.
    pub linears: HashMap<String, QuantizedLinear>,
}

/// Bundle of `QuantConfig` + run metadata (which transform/quantizer built
/// it) — what the experiment grid iterates over.
pub struct QuantizedWeightsSet {
    pub label: String,
    pub qc: QuantConfig,
}

impl QuantConfig {
    /// Identity transforms + RTN(minmax) weights at `bits` — the "None"
    /// baseline and the tests' fixture.
    pub fn identity_for_test(model: &NativeModel, bits: u32) -> QuantConfig {
        let cfg = &model.cfg;
        let mut transforms = HashMap::new();
        for (name, shape) in cfg.transform_spec() {
            transforms.insert(name, Mat::eye(shape[0]));
        }
        let mut linears = HashMap::new();
        let wq = WeightQuantCfg::minmax(bits);
        for i in 0..cfg.n_layers {
            for g in ALL_GROUPS {
                for lin in g.linears() {
                    let name = format!("blocks.{i}.{lin}");
                    let w = &model.params[&name];
                    linears
                        .insert(name, QuantizedLinear::new(quantize_weights_rtn(w, wq).codes));
                }
            }
        }
        QuantConfig {
            act: ActQuantCfg { scheme: QScheme::asym(bits), clip_ratio: 1.0 },
            weight_bits: bits,
            transforms,
            linears,
        }
    }

    /// Dense f64 view of every packed weight — the fake-quant reference
    /// for parity tests and the dense side of A/B benches.
    pub fn deq_weights(&self) -> HashMap<String, Mat> {
        self.linears.iter().map(|(k, l)| (k.clone(), l.deq())).collect()
    }

    /// Total packed bytes across all linears (vs `8·out·in` per f64 mat).
    pub fn packed_bytes(&self) -> usize {
        self.linears.values().map(|l| l.packed_bytes()).sum()
    }

    /// Total bytes of persistent panels: the eager `QuantizedLinear`
    /// i16/i32 panels plus any lazily built f64 panel caches on the
    /// transforms (decode touches those through the GEMV path). The
    /// memory-for-latency side of the panel design, for capacity
    /// planning next to [`Self::packed_bytes`].
    pub fn panel_bytes(&self) -> usize {
        self.linears.values().map(|l| l.panel_bytes()).sum::<usize>()
            + self.transforms.values().map(|t| t.panel_cache_bytes()).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn groups_cover_all_linears() {
        let mut count = 0;
        for g in ALL_GROUPS {
            for lin in g.linears() {
                assert_eq!(group_of_linear(lin), g);
                count += 1;
            }
        }
        assert_eq!(count, 7);
    }

    #[test]
    fn t_names_match_transform_spec() {
        let cfg = ModelConfig::zoo("tiny").unwrap();
        let spec: Vec<String> = cfg.transform_spec().into_iter().map(|(n, _)| n).collect();
        for i in 0..cfg.n_layers {
            for g in ALL_GROUPS {
                assert!(spec.contains(&g.t_name(i)), "{}", g.t_name(i));
            }
        }
    }

    #[test]
    fn group_dims() {
        let cfg = ModelConfig::zoo("small").unwrap();
        assert_eq!(LayerGroup::AttnIn.dim(&cfg), cfg.d);
        assert_eq!(LayerGroup::DownIn.dim(&cfg), cfg.ff);
    }

    #[test]
    fn identity_config_packs_every_linear() {
        let cfg = ModelConfig::zoo("tiny").unwrap();
        let model = NativeModel::init_random(cfg.clone(), 9);
        let qc = QuantConfig::identity_for_test(&model, 4);
        // The linear list is derived from ALL_GROUPS — 7 per block.
        assert_eq!(qc.linears.len(), cfg.n_layers * 7);
        let f64_bytes: usize = qc
            .linears
            .keys()
            .map(|n| model.params[n].rows() * model.params[n].cols() * 8)
            .sum();
        // Nibble-packed W4 sits far below the f64 footprint (~16×; the
        // per-row metadata keeps it shy of exact).
        assert!(qc.packed_bytes() * 8 < f64_bytes, "{} vs {f64_bytes}", qc.packed_bytes());
        // The persistent i16 panels trade memory for per-call unpack
        // time: 2 bytes/code vs 0.5 packed — 4× the code bytes, still
        // 4× under f64.
        let panel_bytes: usize = qc.linears.values().map(|l| l.panel_bytes()).sum();
        assert!(panel_bytes > qc.packed_bytes() / 2, "panels are unpacked codes");
        assert!(panel_bytes * 4 <= f64_bytes, "panels stay well under f64");
    }
}
