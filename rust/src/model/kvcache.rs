//! Per-sequence KV cache for incremental decode, backed by pool pages.
//!
//! One [`KvCache`] holds, per layer, the attention keys and values of
//! every token processed so far — the state that turns generation from
//! O(T²) full-prefix recomputes into O(T) single-token steps.
//!
//! Since the paged rework, a cache is a **page table**: each K/V stream
//! is a `Vec<Arc<KvPage>>` over fixed-size pages allocated from a
//! [`KvPagePool`] (see [`super::kvpool`]). `row(i)` resolves through the
//! table to `pages[i / page_rows]`, row `i % page_rows`. Pages may be
//! shared with other sequences (prefix cache hits, [`KvCache::fork`]);
//! an append into a shared page copies it first, so writers can never
//! corrupt a neighbour.
//!
//! Two storage modes, matching the two native forward paths:
//!
//! * **Fp** — raw f64 K/V rows, exactly what `forward` feeds attention.
//! * **Packed** — per-token *integer activation codes*
//!   ([`QuantizedTensor`]). The quantized forward fake-quantizes K/V
//!   per token, and per-token grids are row-local, so a token's codes
//!   never change as the sequence grows — and never change when its row
//!   moves into a page, which is why paging preserves bit-exactness vs
//!   `forward`/`forward_quant` by construction. A W4A4 cache stores a
//!   fraction of the FP bytes while reproducing logits exactly.
//!
//! [`QuantizedTensor`]: crate::quant::QuantizedTensor

use crate::model::kvpool::{KvPage, KvPagePool, PageMode, PoolState, PrefixHit};
use crate::model::ModelConfig;
use crate::quant::QScheme;
use std::sync::Arc;

/// One K or V stream of a sequence: a page table over pool-owned pages.
pub(crate) struct KvStream {
    pages: Vec<Arc<KvPage>>,
    len: usize,
    cols: usize,
    mode: PageMode,
    pool: Arc<PoolState>,
}

impl KvStream {
    fn new(cols: usize, mode: PageMode, pool: &Arc<PoolState>) -> KvStream {
        KvStream { pages: Vec::new(), len: 0, cols, mode, pool: pool.clone() }
    }

    fn page_rows(&self) -> usize {
        self.pool.cfg.page_rows
    }

    /// Grow the page table so `extra` more rows fit without allocating in
    /// the push hot path. All-or-nothing per stream: returns `false` (and
    /// leaves previously reserved pages in place) when the pool budget
    /// refuses a page — the caller rolls the whole cache back with
    /// [`Self::trim_reserved`].
    pub(crate) fn reserve_rows(&mut self, extra: usize) -> bool {
        let need = (self.len + extra).div_ceil(self.page_rows());
        while self.pages.len() < need {
            match KvPage::alloc(&self.pool, self.cols, self.mode) {
                Some(p) => self.pages.push(p),
                None => return false,
            }
        }
        true
    }

    /// Drop reserved-but-unused tail pages (rollback of a failed or
    /// abandoned reservation), releasing their pool charge.
    pub(crate) fn trim_reserved(&mut self) {
        let keep = self.len.div_ceil(self.page_rows());
        self.pages.truncate(keep);
    }

    /// The page the next pushed row lands in, uniquely held: allocates a
    /// fresh page at a page boundary, copies a shared page first
    /// (copy-on-write). Panics when the pool refuses either charge —
    /// serving callers reserve via [`KvCache::reserve_tokens`] first, so
    /// the push path itself never fails.
    fn writable_tail(&mut self) -> &mut KvPage {
        let pi = self.len / self.page_rows();
        if pi == self.pages.len() {
            let page = KvPage::alloc(&self.pool, self.cols, self.mode)
                .expect("KV page budget exhausted: reserve_tokens before pushing");
            self.pages.push(page);
        }
        if Arc::get_mut(&mut self.pages[pi]).is_none() {
            let copy = KvPage::cow_clone(&self.pages[pi])
                .expect("KV page budget exhausted during copy-on-write");
            self.pages[pi] = copy;
        }
        Arc::get_mut(&mut self.pages[pi]).expect("page uniquely held after CoW")
    }

    /// Append one token row. Packed mode quantizes on the row's dynamic
    /// per-token grid (the same grid `kv_quant` would pick).
    pub(crate) fn push(&mut self, row: &[f64]) {
        self.writable_tail().store.push(row);
        self.len += 1;
    }

    /// Append one token row and write the value attention should see
    /// back into `out`: the raw row for FP, the dequantized pushed codes
    /// for packed — bit-identical to per-token fake-quant of `row`.
    pub(crate) fn push_fake_quant(&mut self, row: &[f64], out: &mut [f64]) {
        self.writable_tail().store.push_fake_quant(row, out);
        self.len += 1;
    }

    /// Borrow token row `i`, resolving through the page table and
    /// dequantizing into `buf` when packed (`buf` must be `cols` wide).
    pub(crate) fn row<'a>(&'a self, i: usize, buf: &'a mut [f64]) -> &'a [f64] {
        debug_assert!(i < self.len);
        let pr = self.page_rows();
        self.pages[i / pr].store.row(i % pr, buf)
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// Pool bytes charged by this stream's pages. Shared pages count in
    /// every table that references them; the pool's `live_bytes` is the
    /// deduplicated truth.
    fn bytes(&self) -> usize {
        self.pages.iter().map(|p| p.bytes()).sum()
    }

    /// Share page `chunk` (must be full) — the prefix trie's handle.
    fn page(&self, chunk: usize) -> Arc<KvPage> {
        debug_assert!((chunk + 1) * self.page_rows() <= self.len, "only full pages are shared");
        self.pages[chunk].clone()
    }

    /// Seed an empty stream with shared full pages covering `rows` rows.
    fn seed(&mut self, pages: Vec<Arc<KvPage>>, rows: usize) {
        assert_eq!(self.len, 0, "seed_prefix on a non-empty cache");
        assert_eq!(rows, pages.len() * self.page_rows(), "prefix pages must be full");
        self.pages = pages;
        self.len = rows;
    }

    fn fork(&self) -> KvStream {
        KvStream {
            pages: self.pages.clone(),
            len: self.len,
            cols: self.cols,
            mode: self.mode,
            pool: self.pool.clone(),
        }
    }
}

/// K and V streams for one layer.
pub(crate) struct LayerKv {
    pub(crate) k: KvStream,
    pub(crate) v: KvStream,
}

/// The incremental-decode state of one sequence: per-layer K/V page
/// tables plus the number of tokens processed. Built by
/// [`NativeModel::prefill`] and advanced by [`NativeModel::decode_step`].
///
/// [`NativeModel::prefill`]: crate::model::NativeModel::prefill
/// [`NativeModel::decode_step`]: crate::model::NativeModel::decode_step
pub struct KvCache {
    pub(crate) layers: Vec<LayerKv>,
    len: usize,
    /// Max tokens (the model's positional-embedding budget).
    capacity: usize,
    pool: KvPagePool,
}

impl KvCache {
    fn build(cfg: &ModelConfig, mode: PageMode, pool: &KvPagePool) -> KvCache {
        let layers = (0..cfg.n_layers)
            .map(|_| LayerKv {
                k: KvStream::new(cfg.d, mode, pool.state()),
                v: KvStream::new(cfg.d, mode, pool.state()),
            })
            .collect();
        KvCache { layers, len: 0, capacity: cfg.seq, pool: pool.clone() }
    }

    /// FP cache for `cfg` on a private unbounded pool — the standalone
    /// path (evals, tests) where no serving budget applies.
    pub fn fp(cfg: &ModelConfig) -> KvCache {
        Self::fp_in(cfg, &KvPagePool::unbounded())
    }

    /// Packed cache on the quantized path's activation grid, private
    /// unbounded pool.
    pub fn packed(cfg: &ModelConfig, scheme: QScheme, clip_ratio: f64) -> KvCache {
        Self::packed_in(cfg, scheme, clip_ratio, &KvPagePool::unbounded())
    }

    /// FP cache drawing pages from a shared serving pool.
    pub fn fp_in(cfg: &ModelConfig, pool: &KvPagePool) -> KvCache {
        Self::build(cfg, PageMode::Fp, pool)
    }

    /// Packed cache drawing pages from a shared serving pool.
    pub fn packed_in(
        cfg: &ModelConfig,
        scheme: QScheme,
        clip_ratio: f64,
        pool: &KvPagePool,
    ) -> KvCache {
        Self::build(cfg, PageMode::Packed { scheme, clip_ratio }, pool)
    }

    /// Whether this cache stores packed codes (the quantized path) —
    /// decode steps must run with the matching `qc` argument.
    pub fn is_packed(&self) -> bool {
        self.packed_grid().is_some()
    }

    /// The packed cache's activation grid `(scheme, clip_ratio)`, if
    /// packed — decode steps assert it matches `qc.kv_act`, since cached
    /// codes from one grid are meaningless under another.
    pub(crate) fn packed_grid(&self) -> Option<(QScheme, f64)> {
        match self.layers.first().map(|l| l.k.mode) {
            Some(PageMode::Packed { scheme, clip_ratio }) => Some((scheme, clip_ratio)),
            _ => None,
        }
    }

    /// Tokens processed so far (= the next token's position).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Whether another token fits under the positional budget.
    pub fn has_room(&self) -> bool {
        self.len < self.capacity
    }

    /// Max tokens this cache (and its model) can hold.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// The pool this cache's pages charge against.
    pub fn pool(&self) -> &KvPagePool {
        &self.pool
    }

    /// Reserve page capacity for `n` more tokens across every stream.
    /// All-or-nothing: on a budget refusal every stream's unused reserved
    /// pages are rolled back and `false` is returned, so a failed
    /// admission leaves the pool exactly as it found it.
    pub fn reserve_tokens(&mut self, n: usize) -> bool {
        for l in &mut self.layers {
            if !l.k.reserve_rows(n) || !l.v.reserve_rows(n) {
                self.trim_reserved();
                return false;
            }
        }
        true
    }

    /// Drop reserved-but-unused tail pages on every stream.
    pub fn trim_reserved(&mut self) {
        for l in &mut self.layers {
            l.k.trim_reserved();
            l.v.trim_reserved();
        }
    }

    /// Advance the token count by `n` after every layer has pushed its
    /// K/V rows for those tokens.
    pub(crate) fn advance(&mut self, n: usize) {
        self.len += n;
        debug_assert!(self.layers.iter().all(|l| l.k.len() == self.len && l.v.len() == self.len));
    }

    /// A second table over the same pages: O(layers) to create, shares
    /// every page by refcount, and copy-on-write isolates the first
    /// divergent append. Both forks read back bit-identical rows for the
    /// shared prefix forever.
    pub fn fork(&self) -> KvCache {
        KvCache {
            layers: self
                .layers
                .iter()
                .map(|l| LayerKv { k: l.k.fork(), v: l.v.fork() })
                .collect(),
            len: self.len,
            capacity: self.capacity,
            pool: self.pool.clone(),
        }
    }

    /// Seed an empty cache with a prefix-cache hit: each stream's table
    /// starts with the shared full pages, and `len()` jumps to
    /// `hit.matched` so prefill continues from there. Stream order is
    /// `layer0.k, layer0.v, layer1.k, …` (the same order
    /// [`Self::stream_page`] exposes).
    pub(crate) fn seed_prefix(&mut self, hit: PrefixHit) {
        assert_eq!(self.len, 0, "seed_prefix on a non-empty cache");
        assert_eq!(hit.pages.len(), 2 * self.layers.len(), "prefix streams mismatch");
        let mut it = hit.pages.into_iter();
        for l in &mut self.layers {
            l.k.seed(it.next().expect("stream count checked"), hit.matched);
            l.v.seed(it.next().expect("stream count checked"), hit.matched);
        }
        self.len = hit.matched;
    }

    /// Share full page `chunk` of stream `stream` (order as in
    /// [`Self::seed_prefix`]) — what the prefix trie stores on insert.
    pub(crate) fn stream_page(&self, stream: usize, chunk: usize) -> Arc<KvPage> {
        let l = &self.layers[stream / 2];
        let s = if stream % 2 == 0 { &l.k } else { &l.v };
        s.page(chunk)
    }

    /// Total pool bytes this cache's page tables charge (packed codes +
    /// grids, or raw f64 rows, rounded up to whole pages) — the footprint
    /// number PERF.md's decode section reports. Pages shared with other
    /// caches are included; the pool's `live_bytes` deduplicates.
    pub fn kv_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.k.bytes() + l.v.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::model::kvpool::{page_bytes, KvPoolCfg};

    fn cfg() -> ModelConfig {
        ModelConfig { name: "t".into(), d: 32, n_layers: 2, n_heads: 4, ff: 64, seq: 16, vocab: 256 }
    }

    fn rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (0..d).map(|_| rng.normal()).collect()).collect()
    }

    fn push_row(c: &mut KvCache, r: &[f64]) {
        for l in &mut c.layers {
            l.k.push(r);
            l.v.push(r);
        }
        c.advance(1);
    }

    #[test]
    fn fp_cache_roundtrips_rows() {
        let cfg = cfg();
        let mut c = KvCache::fp(&cfg);
        let rows = rows(3, cfg.d, 1);
        for r in &rows {
            push_row(&mut c, r);
        }
        assert_eq!(c.len(), 3);
        let mut buf = vec![0.0; cfg.d];
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(c.layers[1].k.row(i, &mut buf), r.as_slice());
        }
    }

    #[test]
    fn rows_roundtrip_across_page_boundaries() {
        let cfg = cfg();
        let pool = KvPagePool::new(KvPoolCfg { page_rows: 4, budget_bytes: usize::MAX });
        let mut c = KvCache::fp_in(&cfg, &pool);
        let rows = rows(11, cfg.d, 7);
        for r in &rows {
            push_row(&mut c, r);
        }
        // 11 rows over 4-row pages = 3 pages per stream.
        let mut buf = vec![0.0; cfg.d];
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(c.layers[0].v.row(i, &mut buf), r.as_slice());
        }
        assert_eq!(c.kv_bytes(), 4 * 3 * page_bytes(cfg.d, PageMode::Fp, 4));
    }

    #[test]
    fn packed_cache_is_smaller_than_fp() {
        let cfg = cfg();
        let mut fp = KvCache::fp(&cfg);
        let mut pk = KvCache::packed(&cfg, QScheme::asym(4), 1.0);
        for row in &rows(8, cfg.d, 2) {
            push_row(&mut fp, row);
            push_row(&mut pk, row);
        }
        // 4-bit codes + per-row grids sit well under the f64 rows.
        assert!(pk.kv_bytes() * 4 < fp.kv_bytes(), "{} vs {}", pk.kv_bytes(), fp.kv_bytes());
    }

    #[test]
    fn room_tracks_capacity() {
        let cfg = cfg();
        let mut c = KvCache::fp(&cfg);
        assert!(c.has_room());
        for _ in 0..cfg.seq {
            push_row(&mut c, &vec![0.0; cfg.d]);
        }
        assert!(!c.has_room());
        assert_eq!(c.capacity(), cfg.seq);
    }

    #[test]
    fn fork_shares_pages_then_copies_on_write() {
        let cfg = cfg();
        let pool = KvPagePool::new(KvPoolCfg { page_rows: 4, budget_bytes: usize::MAX });
        let mut a = KvCache::fp_in(&cfg, &pool);
        let shared = rows(6, cfg.d, 3);
        for r in &shared {
            push_row(&mut a, r);
        }
        let bytes_one = pool.live_bytes();
        let mut b = a.fork();
        // Forking allocates nothing.
        assert_eq!(pool.live_bytes(), bytes_one);
        // Divergent appends: each fork CoW-copies only the partial tail
        // page of each stream (full pages stay shared).
        let ra = rows(1, cfg.d, 4);
        let rb = rows(1, cfg.d, 5);
        push_row(&mut a, &ra[0]);
        push_row(&mut b, &rb[0]);
        let per_stream = page_bytes(cfg.d, PageMode::Fp, 4);
        assert_eq!(pool.live_bytes(), bytes_one + 2 * 4 * per_stream);
        // Shared prefix identical, divergent row isolated.
        let mut buf = vec![0.0; cfg.d];
        for i in 0..6 {
            assert_eq!(a.layers[0].k.row(i, &mut buf).to_vec(), shared[i]);
            assert_eq!(b.layers[0].k.row(i, &mut buf).to_vec(), shared[i]);
        }
        assert_eq!(a.layers[0].k.row(6, &mut buf), ra[0].as_slice());
        assert_eq!(b.layers[0].k.row(6, &mut buf), rb[0].as_slice());
        drop(a);
        drop(b);
        assert_eq!(pool.live_bytes(), 0);
    }

    #[test]
    fn reserve_is_all_or_nothing_under_budget() {
        let cfg = cfg();
        // Budget: 4 streams × 1 page fits, a second page per stream does
        // not all fit.
        let per_stream = page_bytes(cfg.d, PageMode::Fp, 4);
        let pool = KvPagePool::new(KvPoolCfg { page_rows: 4, budget_bytes: 6 * per_stream });
        let mut c = KvCache::fp_in(&cfg, &pool);
        assert!(c.reserve_tokens(4));
        assert_eq!(pool.live_bytes(), 4 * per_stream);
        // Needs 4 more pages, only 2 fit: must roll back to exactly the
        // pre-call state.
        assert!(!c.reserve_tokens(8));
        assert_eq!(pool.live_bytes(), 4 * per_stream);
        // Rows already reserved still push fine.
        for r in &rows(4, cfg.d, 6) {
            push_row(&mut c, r);
        }
        assert_eq!(c.len(), 4);
        drop(c);
        assert_eq!(pool.live_bytes(), 0);
    }

    #[test]
    fn packed_grid_survives_paging() {
        let cfg = cfg();
        let pk = KvCache::packed(&cfg, QScheme::asym(4), 0.9);
        assert!(pk.is_packed());
        let (s, cr) = pk.packed_grid().unwrap();
        assert_eq!(s, QScheme::asym(4));
        assert_eq!(cr, 0.9);
        assert!(KvCache::fp(&cfg).packed_grid().is_none());
    }
}
