//! Per-sequence KV cache for incremental decode.
//!
//! One [`KvCache`] holds, per layer, the attention keys and values of
//! every token processed so far — the state that turns generation from
//! O(T²) full-prefix recomputes into O(T) single-token steps.
//!
//! Two storage modes, matching the two native forward paths:
//!
//! * **Fp** — raw f64 K/V rows, exactly what `forward` feeds attention.
//! * **Packed** — per-token *integer activation codes*
//!   ([`QuantizedTensor`]). The quantized forward fake-quantizes K/V
//!   per token, and per-token grids are row-local, so a token's codes
//!   never change as the sequence grows; dequantizing a cached row is
//!   bit-identical to the fake-quant value the full forward would
//!   compute. A W4A4 cache therefore stores ~1/16 the bytes of the FP
//!   cache while reproducing `forward_quant` logits exactly.

use crate::model::ModelConfig;
use crate::quant::{QScheme, QuantizedTensor};

/// Growable K or V storage for one layer.
pub(crate) enum KvStore {
    /// Row-major f64 rows (`len × cols`).
    Fp { data: Vec<f64>, cols: usize },
    /// Packed per-token codes on the activation scheme's grid.
    Packed { codes: QuantizedTensor, clip_ratio: f64 },
}

impl KvStore {
    /// `cap_rows` pre-reserves the positional budget so the decode hot
    /// loop's pushes never reallocate mid-generation.
    fn fp(cols: usize, cap_rows: usize) -> KvStore {
        KvStore::Fp { data: Vec::with_capacity(cols * cap_rows), cols }
    }

    fn packed(cols: usize, scheme: QScheme, clip_ratio: f64, cap_rows: usize) -> KvStore {
        KvStore::Packed {
            codes: QuantizedTensor::empty_with_capacity(cols, scheme, cap_rows),
            clip_ratio,
        }
    }

    /// Append one token row. Packed mode quantizes on the row's dynamic
    /// per-token grid (the same grid `kv_quant` would pick).
    pub(crate) fn push(&mut self, row: &[f64]) {
        match self {
            KvStore::Fp { data, cols } => {
                debug_assert_eq!(row.len(), *cols);
                data.extend_from_slice(row);
            }
            KvStore::Packed { codes, clip_ratio } => codes.push_row(row, *clip_ratio),
        }
    }

    /// Append one token row and write the value attention should see
    /// back into `out`: the raw row for FP, the dequantized pushed codes
    /// for packed — bit-identical to per-token fake-quant of `row`.
    pub(crate) fn push_fake_quant(&mut self, row: &[f64], out: &mut [f64]) {
        self.push(row);
        match self {
            KvStore::Fp { .. } => out.copy_from_slice(row),
            KvStore::Packed { codes, .. } => codes.deq_row_into(codes.rows() - 1, out),
        }
    }

    /// Borrow token row `i`, dequantizing into `buf` when packed. The FP
    /// mode returns the stored slice; `buf` must be `cols` wide.
    pub(crate) fn row<'a>(&'a self, i: usize, buf: &'a mut [f64]) -> &'a [f64] {
        match self {
            KvStore::Fp { data, cols } => &data[i * cols..(i + 1) * cols],
            KvStore::Packed { codes, .. } => {
                codes.deq_row_into(i, buf);
                buf
            }
        }
    }

    fn len(&self) -> usize {
        match self {
            KvStore::Fp { data, cols } => data.len() / cols,
            KvStore::Packed { codes, .. } => codes.rows(),
        }
    }

    fn bytes(&self) -> usize {
        match self {
            KvStore::Fp { data, .. } => data.len() * std::mem::size_of::<f64>(),
            KvStore::Packed { codes, .. } => codes.packed_bytes(),
        }
    }
}

/// K and V stores for one layer.
pub(crate) struct LayerKv {
    pub(crate) k: KvStore,
    pub(crate) v: KvStore,
}

/// The incremental-decode state of one sequence: per-layer K/V plus the
/// number of tokens processed. Built by [`NativeModel::prefill`] and
/// advanced by [`NativeModel::decode_step`].
///
/// [`NativeModel::prefill`]: crate::model::NativeModel::prefill
/// [`NativeModel::decode_step`]: crate::model::NativeModel::decode_step
pub struct KvCache {
    pub(crate) layers: Vec<LayerKv>,
    len: usize,
    /// Max tokens (the model's positional-embedding budget).
    capacity: usize,
}

impl KvCache {
    /// FP cache for `cfg` (K/V storage pre-reserved to the positional
    /// budget — no reallocation during decode).
    pub fn fp(cfg: &ModelConfig) -> KvCache {
        let layers = (0..cfg.n_layers)
            .map(|_| LayerKv { k: KvStore::fp(cfg.d, cfg.seq), v: KvStore::fp(cfg.d, cfg.seq) })
            .collect();
        KvCache { layers, len: 0, capacity: cfg.seq }
    }

    /// Packed cache on the quantized path's activation grid.
    pub fn packed(cfg: &ModelConfig, scheme: QScheme, clip_ratio: f64) -> KvCache {
        let layers = (0..cfg.n_layers)
            .map(|_| LayerKv {
                k: KvStore::packed(cfg.d, scheme, clip_ratio, cfg.seq),
                v: KvStore::packed(cfg.d, scheme, clip_ratio, cfg.seq),
            })
            .collect();
        KvCache { layers, len: 0, capacity: cfg.seq }
    }

    /// Whether this cache stores packed codes (the quantized path) —
    /// decode steps must run with the matching `qc` argument.
    pub fn is_packed(&self) -> bool {
        self.packed_grid().is_some()
    }

    /// The packed cache's activation grid `(scheme, clip_ratio)`, if
    /// packed — decode steps assert it matches `qc.kv_act`, since cached
    /// codes from one grid are meaningless under another.
    pub(crate) fn packed_grid(&self) -> Option<(QScheme, f64)> {
        match self.layers.first() {
            Some(LayerKv { k: KvStore::Packed { codes, clip_ratio }, .. }) => {
                Some((codes.scheme(), *clip_ratio))
            }
            _ => None,
        }
    }

    /// Tokens processed so far (= the next token's position).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Whether another token fits under the positional budget.
    pub fn has_room(&self) -> bool {
        self.len < self.capacity
    }

    /// Max tokens this cache (and its model) can hold.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Advance the token count by `n` after every layer has pushed its
    /// K/V rows for those tokens.
    pub(crate) fn advance(&mut self, n: usize) {
        self.len += n;
        debug_assert!(self.layers.iter().all(|l| l.k.len() == self.len && l.v.len() == self.len));
    }

    /// Total K/V bytes held (packed codes + grids, or raw f64) — the
    /// footprint number PERF.md's decode section reports.
    pub fn kv_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.k.bytes() + l.v.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    fn cfg() -> ModelConfig {
        ModelConfig { name: "t".into(), d: 32, n_layers: 2, n_heads: 4, ff: 64, seq: 16, vocab: 256 }
    }

    #[test]
    fn fp_cache_roundtrips_rows() {
        let cfg = cfg();
        let mut c = KvCache::fp(&cfg);
        let mut rng = Rng::new(1);
        let rows: Vec<Vec<f64>> =
            (0..3).map(|_| (0..cfg.d).map(|_| rng.normal()).collect()).collect();
        for r in &rows {
            for l in &mut c.layers {
                l.k.push(r);
                l.v.push(r);
            }
            c.advance(1);
        }
        assert_eq!(c.len(), 3);
        let mut buf = vec![0.0; cfg.d];
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(c.layers[1].k.row(i, &mut buf), r.as_slice());
        }
    }

    #[test]
    fn packed_cache_is_smaller_than_fp() {
        let cfg = cfg();
        let mut fp = KvCache::fp(&cfg);
        let mut pk = KvCache::packed(&cfg, QScheme::asym(4), 1.0);
        let mut rng = Rng::new(2);
        for _ in 0..8 {
            let row: Vec<f64> = (0..cfg.d).map(|_| rng.normal()).collect();
            for c in [&mut fp, &mut pk] {
                for l in &mut c.layers {
                    l.k.push(&row);
                    l.v.push(&row);
                }
                c.advance(1);
            }
        }
        // 4-bit codes + per-row grids sit well under the f64 rows.
        assert!(pk.kv_bytes() * 4 < fp.kv_bytes(), "{} vs {}", pk.kv_bytes(), fp.kv_bytes());
    }

    #[test]
    fn room_tracks_capacity() {
        let cfg = cfg();
        let mut c = KvCache::fp(&cfg);
        assert!(c.has_room());
        for _ in 0..cfg.seq {
            for l in &mut c.layers {
                l.k.push(&vec![0.0; cfg.d]);
                l.v.push(&vec![0.0; cfg.d]);
            }
            c.advance(1);
        }
        assert!(!c.has_room());
        assert_eq!(c.capacity(), cfg.seq);
    }
}
